"""Out-of-band mirror of the warm-model keepalive/eviction logic
(rust/src/engine/models.rs::ModelSlots + evict_rank).

This container has no Rust toolchain (same pattern as
test_queue_predictor.py), so this suite re-implements, line for line,
the multiplexed-model slot machinery whose exact draw order decides
which model evicts on every cold load, and pins it three ways:

* fixed rank vectors, byte-identical to the
  `evict_rank_matches_pinned_vectors` unit test in models.rs — both
  sides were generated from the same reference program, so a silent
  edit to either implementation breaks one of the two suites;
* a scripted eviction trace whose victim order exercises LRU, the
  keepalive shield, the transient-load path, and the salted tiebreak;
* fuzzed contracts: rank determinism, salt-domain separation from the
  queue predictor's stream, and keepalive monotonicity (protecting a
  model never makes it MORE evictable).

Eviction order is the one piece of the multiplexing layer whose exact
arithmetic shapes every multi-model replay (a different victim re-warms
a different model, shifting every later swap), so drift here silently
re-seeds fig91 and the hetero bench stage.
"""

from hypothesis import given, settings, strategies as st

MASK = (1 << 64) - 1

# b"MDLKEEP1"-flavored — the eviction tiebreak salt, verbatim from
# models.rs::MODEL_EVICT_SALT.
MODEL_EVICT_SALT = 0x4D444C4B45455031

# The queue predictor's salt (test_queue_predictor.py) — the two streams
# must never coincide.
PREDICT_SALT = 0x5150524544313337


def mix(h, x):
    """Line-for-line port of engine/queue.rs::mix (the splitmix64
    finalizer over `h ^ x * golden`, masked to 64 bits)."""
    z = (h ^ ((x * 0x9E3779B97F4A7C15) & MASK)) & MASK
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
    return (z ^ (z >> 31)) & MASK


def evict_rank(instance, model_id):
    """Port of models.rs::evict_rank: double-mixed salted rank; lower
    evicts first among exact last-use ties."""
    return mix(mix(MODEL_EVICT_SALT, instance), model_id)


class ModelSlots:
    """Port of models.rs::ModelSlots (warm list as (model_id, last_used)
    pairs in insertion order, swap_remove on eviction — the order the
    Rust Vec sees, so victim indices line up)."""

    def __init__(self, instance, max_warm, keepalive_us):
        self.instance = instance
        self.max_warm = max(max_warm, 1)
        self.keepalive_us = keepalive_us
        # Model 0 (the fleet default) ships warm at t=0.
        self.warm = [(0, 0)]
        self.cold_loads = 0
        self.evictions = 0

    def is_warm(self, model_id):
        return any(m == model_id for m, _ in self.warm)

    def touch(self, model_id, now_us):
        """Returns the evicted model id, or None (warm hit, free slot,
        or transient load against a fully protected set)."""
        for i, (m, t) in enumerate(self.warm):
            if m == model_id:
                self.warm[i] = (m, max(t, now_us))
                return None
        self.cold_loads += 1
        if len(self.warm) < self.max_warm:
            self.warm.append((model_id, now_us))
            return None
        victim = self._pick_victim(now_us)
        if victim is None:
            return None  # transient: swap paid, protected set untouched
        evicted, _ = self.warm[victim]
        # Rust's Vec::swap_remove: move the last element into the hole.
        self.warm[victim] = self.warm[-1]
        self.warm.pop()
        self.evictions += 1
        self.warm.append((model_id, now_us))
        return evicted

    def _pick_victim(self, now_us):
        expired = [
            i
            for i, (_, t) in enumerate(self.warm)
            if max(now_us - t, 0) >= self.keepalive_us
        ]
        if not expired:
            return None
        return min(
            expired,
            key=lambda i: (self.warm[i][1], evict_rank(self.instance, self.warm[i][0])),
        )


# --- pinned rank vectors (== models.rs::evict_rank_matches_pinned_vectors)

VECTORS = [
    (0, 0, 0x42B014BC5E6A2794),
    (0, 1, 0xEEB950446152D604),
    (3, 0, 0x324D70DCABC059E9),
    (3, 1, 0xDEC2698C7F699205),
    (3, 2, 0x0814D9F10BECF373),
    (7, 5, 0x302259ACF85C7604),
    (63, 4294967295, 0xF197362F808E79DF),
]


def test_pinned_rank_vectors_match_rust():
    for instance, model_id, expected in VECTORS:
        got = evict_rank(instance, model_id)
        assert got == expected, (instance, model_id, hex(got), hex(expected))


def test_scripted_eviction_draw_order():
    # The draw-order pin: a fixed touch script on instance 3 (2 warm
    # slots, 1s keepalive) must evict in exactly this sequence. Any
    # change to the rank stream, the LRU key, the keepalive arithmetic
    # or swap_remove's slot shuffling reorders it.
    s = ModelSlots(instance=3, max_warm=2, keepalive_us=1_000_000)
    trace = []
    trace.append(s.touch(1, 100))  # free slot: {0@0, 1@100}
    trace.append(s.touch(1, 900_000))  # warm refresh
    trace.append(s.touch(2, 1_100_000))  # 0 expired, 1 shielded -> evict 0
    trace.append(s.touch(1, 1_200_000))  # warm refresh
    trace.append(s.touch(3, 1_500_000))  # both shielded -> transient
    trace.append(s.touch(3, 2_300_000))  # 2 expired (idle 1.2s) -> evict 2
    trace.append(s.touch(2, 4_000_000))  # both expired, LRU is 1 -> evict 1
    assert trace == [None, None, 0, None, None, 2, 1]
    assert s.cold_loads == 5
    assert s.evictions == 3
    assert sorted(m for m, _ in s.warm) == [2, 3]


def test_exact_tie_breaks_by_rank_not_insertion_order():
    # Same last-use instant on instance 3: rank(3,0) < rank(3,1), so 0
    # evicts even though it was inserted first AND vectors above pin the
    # comparison the Rust side makes.
    assert evict_rank(3, 0) < evict_rank(3, 1)
    s = ModelSlots(instance=3, max_warm=2, keepalive_us=0)
    s.touch(1, 0)  # {0@0, 1@0}
    assert s.touch(2, 0) == 0
    # And the mirrored tie on instance 0 goes the same way (rank(0,0) <
    # rank(0,1)) — but via different rank values, per the vectors.
    s0 = ModelSlots(instance=0, max_warm=2, keepalive_us=0)
    s0.touch(1, 0)
    assert s0.touch(2, 0) == 0


# --- fuzzed contracts ---------------------------------------------------


@settings(max_examples=200, deadline=None)
@given(instance=st.integers(0, MASK), model_id=st.integers(0, (1 << 32) - 1))
def test_rank_is_deterministic_and_salt_separated(instance, model_id):
    r = evict_rank(instance, model_id)
    assert r == evict_rank(instance, model_id)
    # The eviction stream must not collapse onto the queue predictor's
    # stream (distinct salts => distinct domains), nor onto the unsalted
    # finalizer a naive port would produce.
    assert r != mix(mix(PREDICT_SALT, instance), model_id)
    assert r != mix(mix(0, instance), model_id)


@settings(max_examples=100, deadline=None)
@given(
    keepalive=st.integers(0, 2_000_000),
    touches=st.lists(
        st.tuples(st.integers(0, 5), st.integers(0, 10_000_000)), max_size=30
    ),
)
def test_protected_models_never_evict(keepalive, touches):
    # Keepalive contract: whatever the interleaving, an evicted model was
    # idle >= keepalive at eviction time (Ray's no-thrash guarantee).
    s = ModelSlots(instance=7, max_warm=2, keepalive_us=keepalive)
    now = 0
    last_used = {0: 0}
    for model_id, dt in touches:
        now += dt
        last_used.setdefault(model_id, now)
        if s.is_warm(model_id):
            last_used[model_id] = max(last_used[model_id], now)
        evicted = s.touch(model_id, now)
        if s.is_warm(model_id):
            last_used[model_id] = max(last_used[model_id], now)
        if evicted is not None:
            assert now - last_used[evicted] >= keepalive


def test_default_model_ships_warm():
    s = ModelSlots(instance=0, max_warm=1, keepalive_us=0)
    assert s.is_warm(0)
    assert s.touch(0, 50) is None
    assert s.cold_loads == 0
