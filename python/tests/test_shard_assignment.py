"""Out-of-band mirror of the sharded index's shard assignment
(rust/src/kvcache/sharded.rs::shard_of).

This container has no Rust toolchain (same pattern as
test_rate_program.py), so this suite re-implements, line for line, the
splitmix64-finalizer shard hash and pins it two ways:

* fixed reference vectors, byte-identical to the
  `shard_of_pinned_vectors` unit test in sharded.rs — both sides were
  generated from the same reference program, so a silent edit to either
  implementation breaks one of the two suites;
* fuzzed contracts: determinism, range, single-shard degeneracy,
  dependence on the FIRST block hash only (the property that makes
  shard-confined radix walks correct — chains with different first
  hashes share no nodes, so the walk never needs a second shard).
"""

from hypothesis import given, settings, strategies as st

MASK = (1 << 64) - 1


def shard_of(first_hash, n_shards):
    """Line-for-line port of kvcache/sharded.rs::shard_of.

    A raw `hash % n_shards` would alias chained block hashes that share
    low bits, so the Rust side runs the splitmix64 finalizer first; the
    constants below are that finalizer's, verbatim.
    """
    z = (first_hash ^ 0x9E3779B97F4A7C15) & MASK
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
    z = (z ^ (z >> 31)) & MASK
    return z % n_shards


# --- pinned reference vectors (== sharded.rs::shard_of_pinned_vectors) --

HASHES = [
    0,
    1,
    2,
    0xDEADBEEF,
    0x0123456789ABCDEF,
    (1 << 64) - 1,
    42,
    1000,
    123456789,
    0x9E3779B97F4A7C15,
]

EXPECT = {
    1: [0, 0, 0, 0, 0, 0, 0, 0, 0, 0],
    2: [1, 0, 0, 1, 1, 0, 1, 0, 0, 0],
    8: [7, 0, 6, 1, 1, 4, 5, 0, 6, 0],
    16: [15, 0, 14, 1, 9, 4, 5, 8, 14, 0],
    64: [47, 32, 14, 1, 57, 4, 21, 8, 46, 0],
}


def test_pinned_vectors_match_rust():
    for n_shards, expected in EXPECT.items():
        got = [shard_of(h, n_shards) for h in HASHES]
        assert got == expected, (n_shards, got)


# --- fuzzed contracts ---------------------------------------------------


@settings(max_examples=200, deadline=None)
@given(h=st.integers(0, MASK), s=st.integers(1, 4096))
def test_deterministic_and_in_range(h, s):
    a = shard_of(h, s)
    assert 0 <= a < s
    assert a == shard_of(h, s)
    assert shard_of(h, 1) == 0


@settings(max_examples=100, deadline=None)
@given(
    h=st.integers(0, MASK),
    s=st.integers(2, 64),
    tail=st.lists(st.integers(0, MASK), min_size=0, max_size=8),
)
def test_assignment_depends_on_first_hash_only(h, s, tail):
    """The chain's shard is its first block's shard: the tail — any tail —
    must not move it. (In Rust this is what lets one shard own an entire
    radix chain; here the contract is expressed on the assignment
    function itself, matching the Rust-side integration property
    `prop_shard_assignment_pure_function_of_first_hash`.)"""
    base = shard_of(h, s)
    for t in tail:
        # A chain [h, *tail] is assigned by h alone; simulate the walk's
        # entry decision for every prefix of the chain.
        assert shard_of(h, s) == base
        # And a chain starting at a different hash is free to differ —
        # but its assignment is still pure in its own first element.
        assert shard_of(t, s) == shard_of(t, s)


@settings(max_examples=50, deadline=None)
@given(s=st.integers(2, 32))
def test_spreads_sequential_hashes(s):
    """Block hashes are chained and often numerically clustered; the
    finalizer must spread a sequential run across shards rather than
    funnel it into `i % s` stripes. Weak but load-bearing: a lost
    finalizer (raw modulo) would put hashes 0..s-1 in s distinct shards
    with perfect stripes, and real chain bases into few."""
    assignments = {shard_of(h, s) for h in range(256)}
    assert len(assignments) == s
