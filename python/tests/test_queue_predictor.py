"""Out-of-band mirror of the engine queue's decode-length predictor
(rust/src/engine/queue.rs::predict_decode).

This container has no Rust toolchain (same pattern as
test_shard_assignment.py), so this suite re-implements, line for line,
the salted splitmix64 predictor the srpt/ltr queue policies score with,
and pins it two ways:

* fixed reference vectors, byte-identical to the
  `predictor_matches_pinned_vectors` unit test in queue.rs — both sides
  were generated from the same reference program, so a silent edit to
  either implementation breaks one of the two suites;
* fuzzed contracts: determinism, positivity, the [0.5, 1.5) noise band
  around the true output length, and salt sensitivity (the predictor
  must not collapse to the raw splitmix finalizer the KV shard hash
  uses — the two live in different domains).

The predictor is the one piece of the queue layer whose exact arithmetic
crosses the Rust/live boundary (`cluster/live.rs` stamps the identical
value), so drift here silently changes every srpt/ltr admission order.
"""

from hypothesis import given, settings, strategies as st

MASK = (1 << 64) - 1

# b"QPRED137" — the queue predictor's salt, verbatim from queue.rs.
PREDICT_SALT = 0x5150524544313337


def mix(h, x):
    """Line-for-line port of engine/queue.rs::mix (the splitmix64
    finalizer over `h ^ x * golden`, masked to 64 bits)."""
    z = (h ^ ((x * 0x9E3779B97F4A7C15) & MASK)) & MASK
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
    return (z ^ (z >> 31)) & MASK


def predict_decode(req_id, output_len):
    """Line-for-line port of engine/queue.rs::predict_decode: the true
    output length scaled by a per-request factor in [0.5, 1.5) drawn
    from the top 16 bits of the salted mix. Rust's `as u64` cast
    truncates toward zero; `int()` matches for the non-negative range."""
    z = mix(PREDICT_SALT, req_id)
    factor = 0.5 + (z >> 48) / 65536.0
    return max(int(max(output_len, 1) * factor), 1)


# --- pinned reference vectors (== queue.rs::predictor_matches_pinned_vectors)

VECTORS = [
    (0, 1, 1),
    (1, 64, 92),
    (2, 256, 193),
    (7, 100, 87),
    (42, 32, 34),
    (123456789, 1000, 1139),
    (1 << 63, 500, 618),
    ((1 << 64) - 1, 77, 67),
]


def test_pinned_vectors_match_rust():
    for req_id, output_len, expected in VECTORS:
        got = predict_decode(req_id, output_len)
        assert got == expected, (req_id, output_len, got, expected)


# --- fuzzed contracts ---------------------------------------------------


@settings(max_examples=200, deadline=None)
@given(req_id=st.integers(0, MASK), output_len=st.integers(0, (1 << 32) - 1))
def test_deterministic_positive_and_banded(req_id, output_len):
    p = predict_decode(req_id, output_len)
    assert p == predict_decode(req_id, output_len)
    assert p >= 1
    # The [0.5, 1.5) noise band around the (floored-at-1) true length.
    true_len = max(output_len, 1)
    assert 0.5 * true_len - 1 <= p < 1.5 * true_len + 1


@settings(max_examples=100, deadline=None)
@given(req_id=st.integers(0, MASK))
def test_salt_separates_domains(req_id):
    # The predictor's stream must not be the unsalted finalizer stream
    # (mix(0, x) is what a naive port would produce); pinning the salted
    # values above would miss a salt dropped on BOTH sides only if the
    # two streams coincided — they must not.
    assert mix(PREDICT_SALT, req_id) != mix(0, req_id)


def test_factor_band_is_exhaustive_at_the_extremes():
    # factor = 0.5 + top16/65536: the cast truncates, so output_len 1
    # always predicts 1 (factor < 2 => int(1 * factor) <= 1, floored to
    # >= 1) — the minimum-work request can never be predicted heavier
    # than a 2-token one.
    for req_id in range(256):
        assert predict_decode(req_id, 1) == 1
        assert predict_decode(req_id, 2) >= 1
