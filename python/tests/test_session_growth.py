"""Out-of-band mirror of the Rust session generator's turn-growth math.

`rust/src/trace/sessions.rs` exposes the closed-form recurrence

    ctx_0     = sys_len
    prompt_k  = min(ctx_k + user_k, max_input)
    full_k    = prompt_k + reply_k
    ctx_{k+1} = full_k

as `turn_growth(...)`, and the generator's token vectors are asserted
against it in Rust unit tests. This container has no Rust toolchain
(matches the PR 2/4 verification pattern), so this suite re-implements
the recurrence in Python and fuzzes it against an independent token-LIST
simulation (actually building, truncating and extending sequences), plus
the block-chain consequences the scheduler relies on:

* prompts never exceed max_input and never shrink turn over turn;
* turn k+1's prompt literally *starts with* (a truncated prefix of)
  turn k's full context — the structural prefix-sharing that makes
  session affinity worth anything;
* the guaranteed block-aligned hit of turn k+1 on an instance that
  cached full_k is min(full_k, prompt_{k+1}) // BLOCK blocks.
"""

from hypothesis import given, settings, strategies as st

BLOCK = 16  # rust: core::BLOCK_TOKENS


def turn_growth(sys_len, user_lens, reply_lens, max_input):
    """Line-for-line port of sessions.rs::turn_growth."""
    ctx = sys_len
    out = []
    for u, r in zip(user_lens, reply_lens):
        prompt = min(ctx + u, max_input)
        full = prompt + r
        out.append((prompt, full))
        ctx = full
    return out


def simulate_tokens(sys_len, user_lens, reply_lens, max_input):
    """Independent reference: actually build the token lists the Rust
    generator materializes (token *identity* stands in for content; the
    generator's spans are deterministic functions of (session, turn))."""
    prompt = [("sys", i) for i in range(sys_len)]
    turns = []
    for k, (u, r) in enumerate(zip(user_lens, reply_lens)):
        prompt = prompt + [("user", k, i) for i in range(u)]
        if len(prompt) > max_input:
            prompt = prompt[:max_input]
        this_prompt = list(prompt)
        prompt = prompt + [("reply", k, i) for i in range(r)]
        turns.append((this_prompt, list(prompt)))
    return turns


SETTINGS = dict(max_examples=60, deadline=None)


@settings(**SETTINGS)
@given(
    sys_len=st.integers(1, 4000),
    n_turns=st.integers(1, 12),
    max_input=st.integers(64, 6000),
    seed=st.integers(0, 2**31 - 1),
)
def test_recurrence_matches_token_list_simulation(sys_len, n_turns, max_input, seed):
    import random

    rng = random.Random(seed)
    sys_len = min(sys_len, max_input // 2 if max_input >= 2 else 1) or 1
    user_lens = [rng.randint(1, 800) for _ in range(n_turns)]
    reply_lens = [rng.randint(1, 1200) for _ in range(n_turns)]

    closed = turn_growth(sys_len, user_lens, reply_lens, max_input)
    sim = simulate_tokens(sys_len, user_lens, reply_lens, max_input)
    assert len(closed) == len(sim) == n_turns

    prev_full = None
    prev_prompt_len = 0
    for k, ((p_len, f_len), (p_toks, f_toks)) in enumerate(zip(closed, sim)):
        # Closed form == simulation, exactly.
        assert p_len == len(p_toks), f"turn {k}: prompt length mismatch"
        assert f_len == len(f_toks), f"turn {k}: full length mismatch"
        # Truncation guard & monotone growth.
        assert p_len <= max_input
        assert p_len >= prev_prompt_len
        assert f_len >= p_len
        prev_prompt_len = p_len
        # Structural prefix sharing: this prompt starts with (a prefix
        # of) the previous turn's full context.
        if prev_full is not None:
            shared = min(len(prev_full), p_len)
            assert p_toks[:shared] == prev_full[:shared], f"turn {k}: prefix broken"
            # Guaranteed block-aligned hit if full_{k-1} is cached.
            guaranteed_blocks = shared // BLOCK
            own_blocks = p_len // BLOCK
            assert guaranteed_blocks <= own_blocks
            # ...and the guarantee equals the recurrence's prediction.
            assert guaranteed_blocks == min(len(prev_full), p_len) // BLOCK
        prev_full = f_toks


@settings(**SETTINGS)
@given(
    sys_len=st.integers(1, 500),
    max_input=st.integers(100, 2000),
    n_turns=st.integers(2, 20),
)
def test_hit_fraction_rises_once_warm(sys_len, max_input, n_turns):
    """The monotonicity behind the fig42 per-turn hit curve: with a fixed
    user-span size, the guaranteed warm-hit fraction of turn k (prefix of
    full_{k-1} over prompt_k) is bounded below by 1 - (user+BLOCK)/prompt_k,
    which rises as prompts grow toward max_input."""
    user = 50
    reply = 80
    sys_len = min(sys_len, max_input - user - 1) or 1
    closed = turn_growth(sys_len, [user] * n_turns, [reply] * n_turns, max_input)
    exact = []
    for k in range(1, n_turns):
        prev_full = closed[k - 1][1]
        p = closed[k][0]
        guaranteed = (min(prev_full, p) // BLOCK) * BLOCK
        # Block flooring costs at most one block below the exact overlap.
        assert guaranteed / p >= 1.0 - (user + BLOCK) / p
        exact.append(min(prev_full, p) / p)
    # The exact (unfloored) warm-overlap fraction is monotone
    # non-decreasing: 1 - user/prompt while growing, then min(full,max)/max
    # climbing to 1.0 once the prompt saturates at max_input.
    for a, b in zip(exact, exact[1:]):
        assert b >= a - 1e-12


def test_recurrence_fixed_vectors():
    """The exact vectors pinned in the Rust unit test (sessions.rs)."""
    assert turn_growth(100, [10, 20, 30], [5, 5, 1000], 200) == [
        (110, 115),
        (135, 140),
        (170, 1170),
    ]
    assert turn_growth(100, [200, 10], [50, 1], 250) == [(250, 300), (250, 251)]
