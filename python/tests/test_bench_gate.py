"""The bench regression gate's decision table, exercised end-to-end.

scripts/check_bench_regression.py is the CI step that (once the baseline
is seeded) fails the build on a >20% req/s or steps/s regression. Its
tolerate-then-gate behaviour for newer JSON sections (guard, sessions,
overload, router_scale, fleet, engine_queue, hetero) must hold across
baseline generations, so this suite runs the
actual script as a subprocess through the four paths that matter:

1. unseeded baseline               -> report-only, exit 0
2. seeded legacy baseline (no
   sessions section)               -> sessions fields report-only, exit 0
3. seeded baseline with sessions   -> within budget, exit 0
4. seeded baseline with sessions,
   regressed current run           -> exit 1

plus --emit-seeded (the auto-arming path) and the quick_mode-mismatch
escape hatch.
"""

import json
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[2]
SCRIPT = REPO / "scripts" / "check_bench_regression.py"


def run_gate(tmp_path, current, baseline, extra=()):
    cur = tmp_path / "current.json"
    base = tmp_path / "baseline.json"
    cur.write_text(json.dumps(current))
    base.write_text(json.dumps(baseline))
    proc = subprocess.run(
        [sys.executable, str(SCRIPT), str(cur), str(base), *extra],
        capture_output=True,
        text=True,
    )
    return proc


def bench_doc(
    req_per_s=1000.0,
    with_sessions=True,
    seeded=False,
    with_overload=True,
    with_router_scale=True,
    with_fleet=True,
    with_engine_queue=True,
    with_hetero=True,
):
    doc = {
        "bench": "router_throughput",
        "seeded": seeded,
        "quick_mode": True,
        "des_end_to_end": {
            "requests": 2000,
            "req_per_s": req_per_s,
            "steps_per_s": 5 * req_per_s,
            "admit_radix_walks": 2000,
        },
        "scale_smoke": {
            "instances": 32,
            "requests": 50000,
            "wall_s": 10.0,
            "req_per_s": req_per_s * 3,
            "steps_per_s": req_per_s * 20,
            "admit_radix_walks": 50000,
        },
        "guard": {
            "natural_checks": 2000,
            "natural_degenerate": 0,
            "natural_inversion": 0,
            "natural_mitigated": 0,
            "flood_checks": 1600,
            "flood_degenerate": 900,
            "flood_inversion": 0,
            "flood_mitigated": 0,
        },
        "sweep": {"jobs": 5, "threads": 8, "speedup": 3.1},
    }
    if with_sessions:
        doc["sessions"] = {
            "sessions": 400,
            "turns": 2000,
            "wall_s": 2.0,
            "req_per_s": req_per_s / 2,
            "affinity_lmetric": 0.9,
            "affinity_sticky": 1.0,
            "turn0_hit": 0.3,
            "late_turn_hit": 0.85,
        }
    if with_overload:
        doc["overload"] = {
            "slo_ttft_s": 0.5,
            "slo_tpot_s": 0.05,
            "depth_threshold": 64,
            "goodput_at_capacity": 1.0,
            "goodput_overload_admit_all": 0.4,
            "goodput_overload_session_shed": 0.9,
            "shed_overload": 350,
            "orphaned_turns": 0,
        }
    if with_router_scale:
        doc["router_scale"] = {
            "instances": 256,
            "probes": 1000,
            "routers_max": 4,
            "decisions_per_s_r1": req_per_s * 10,
            "decisions_per_s_r2": req_per_s * 16,
            "decisions_per_s_r4": req_per_s * 24,
            "snapshot_age_p99": 12.0,
        }
    if with_fleet:
        doc["fleet"] = {
            "crashes": 1,
            "requeued": 40,
            "requeue_rate": 0.02,
            "recovery_ttft_p99": 0.8,
            "goodput_static": 0.55,
            "goodput_autoscaler": 0.85,
            "scale_ups": 3,
        }
    if with_engine_queue:
        doc["engine_queue"] = {
            "ttft_p99_fcfs": 2.4,
            "ttft_p99_srpt": 1.5,
            "ttft_p99_ltr": 1.7,
            "ttft_p99_ratio_srpt": 1.6,
            "promotions_ltr": 120,
        }
    if with_hetero:
        doc["hetero"] = {
            "slo_ttft_s": 0.6,
            "slo_tpot_s": 0.06,
            "goodput_fused": 0.9,
            "goodput_two_layer": 0.75,
            "goodput_ratio_fused_over_two_layer": 1.2,
            "cold_model_loads": 30,
            "model_evictions": 12,
        }
    return doc


def test_path1_unseeded_baseline_is_report_only(tmp_path):
    proc = run_gate(tmp_path, bench_doc(), bench_doc(seeded=False))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "report-only" in proc.stdout


def test_path2_seeded_legacy_baseline_tolerates_missing_sessions(tmp_path):
    # Baseline predates the sessions, overload, router_scale, fleet,
    # engine_queue AND hetero sections entirely; current carries all six.
    legacy = bench_doc(
        seeded=True,
        with_sessions=False,
        with_overload=False,
        with_router_scale=False,
        with_fleet=False,
        with_engine_queue=False,
        with_hetero=False,
    )
    proc = run_gate(tmp_path, bench_doc(req_per_s=990.0), legacy)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "sessions.req_per_s: baseline unseeded" in proc.stdout
    assert "overload.goodput_at_capacity: baseline unseeded" in proc.stdout
    assert "router_scale.decisions_per_s_r1: baseline unseeded" in proc.stdout
    assert "fleet.goodput_autoscaler: baseline unseeded" in proc.stdout
    assert "engine_queue.ttft_p99_ratio_srpt: baseline unseeded" in proc.stdout
    assert (
        "hetero.goodput_ratio_fused_over_two_layer: baseline unseeded" in proc.stdout
    )
    assert "OK: within regression budget" in proc.stdout


def test_path3_seeded_with_sessions_within_budget(tmp_path):
    proc = run_gate(tmp_path, bench_doc(req_per_s=900.0), bench_doc(seeded=True))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK: within regression budget" in proc.stdout


def test_path4_seeded_with_sessions_regression_fails(tmp_path):
    proc = run_gate(tmp_path, bench_doc(req_per_s=500.0), bench_doc(seeded=True))
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "FAIL" in proc.stdout
    assert "sessions.req_per_s" in proc.stdout


def test_sessions_only_regression_trips_gate(tmp_path):
    # des/scale numbers fine, ONLY the closed-loop rate collapsed.
    current = bench_doc(req_per_s=1000.0)
    current["sessions"]["req_per_s"] = 100.0
    proc = run_gate(tmp_path, current, bench_doc(seeded=True))
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "sessions.req_per_s" in proc.stdout


def test_overload_goodput_collapse_trips_gate(tmp_path):
    # Throughput fine, but goodput at capacity collapsed (admission
    # control broke): the gate must catch it.
    current = bench_doc(req_per_s=1000.0)
    current["overload"]["goodput_at_capacity"] = 0.5
    proc = run_gate(tmp_path, current, bench_doc(seeded=True))
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "overload.goodput_at_capacity" in proc.stdout


def test_router_scale_regression_trips_gate(tmp_path):
    # Serial DES throughput fine, but the concurrent read path's R=1
    # decision rate collapsed (e.g. the sharded walk grew a lock): the
    # gate must catch it. The multi-router rates are report-only and may
    # swing with runner core count without tripping anything.
    current = bench_doc(req_per_s=1000.0)
    current["router_scale"]["decisions_per_s_r1"] = 100.0
    current["router_scale"]["decisions_per_s_r4"] = 50.0  # report-only
    proc = run_gate(tmp_path, current, bench_doc(seeded=True))
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "router_scale.decisions_per_s_r1" in proc.stdout
    assert "decisions_per_s_r4 regressed" not in proc.stdout


def test_fleet_goodput_collapse_trips_gate(tmp_path):
    # Throughput fine, but the autoscaled overload goodput collapsed
    # (the reactive scaler stopped firing, or lifecycle requeue got
    # slow): the gate must catch it. The static-fleet goodput and the
    # recovery tail are report-only and may swing without tripping.
    current = bench_doc(req_per_s=1000.0)
    current["fleet"]["goodput_autoscaler"] = 0.3
    current["fleet"]["recovery_ttft_p99"] = 50.0  # report-only
    proc = run_gate(tmp_path, current, bench_doc(seeded=True))
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "fleet.goodput_autoscaler" in proc.stdout
    assert "recovery_ttft_p99 regressed" not in proc.stdout


def test_engine_queue_regression_trips_gate(tmp_path):
    # Throughput fine, but srpt lost its TTFT-tail win over fcfs (the
    # predictor or the ordering regressed, pushing the ratio toward 1):
    # the gate must catch it. The raw p99s and the ltr promotion count
    # are report-only and may swing without tripping.
    current = bench_doc(req_per_s=1000.0)
    current["engine_queue"]["ttft_p99_ratio_srpt"] = 1.0
    current["engine_queue"]["ttft_p99_ltr"] = 9.0  # report-only
    current["engine_queue"]["promotions_ltr"] = 0  # report-only
    proc = run_gate(tmp_path, current, bench_doc(seeded=True))
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "engine_queue.ttft_p99_ratio_srpt" in proc.stdout
    assert "ttft_p99_ltr regressed" not in proc.stdout


def test_hetero_ratio_collapse_trips_gate(tmp_path):
    # Throughput fine, but the fused score lost its goodput edge over the
    # two-layer baseline on the mixed fleet (cost-awareness or swap
    # pricing regressed, ratio decaying toward 1): the gate must catch
    # it. The swap counters are report-only and may swing without
    # tripping anything.
    current = bench_doc(req_per_s=1000.0)
    current["hetero"]["goodput_ratio_fused_over_two_layer"] = 0.9
    current["hetero"]["cold_model_loads"] = 500  # report-only
    proc = run_gate(tmp_path, current, bench_doc(seeded=True))
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "hetero.goodput_ratio_fused_over_two_layer" in proc.stdout
    assert "cold_model_loads regressed" not in proc.stdout


def test_quick_mode_mismatch_skips_gate(tmp_path):
    current = bench_doc(req_per_s=100.0)
    current["quick_mode"] = False
    proc = run_gate(tmp_path, current, bench_doc(seeded=True))
    assert proc.returncode == 0
    assert "quick_mode mismatch" in proc.stdout


def test_emit_seeded_never_writes_on_failure(tmp_path):
    # A regressed run must not be able to arm (or replace) the baseline.
    out = tmp_path / "should_not_exist.json"
    proc = run_gate(
        tmp_path,
        bench_doc(req_per_s=100.0),
        bench_doc(seeded=True),
        extra=["--emit-seeded", str(out)],
    )
    assert proc.returncode == 1
    assert not out.exists(), "failed runs must not emit a seeded baseline"


def test_emit_seeded_refuses_incomplete_current(tmp_path):
    # A run missing a gated field (bench sub-stage skipped) must not arm
    # the gate, even in report-only mode.
    current = bench_doc()
    del current["sessions"]
    out = tmp_path / "seeded.json"
    proc = run_gate(tmp_path, current, bench_doc(seeded=False), extra=["--emit-seeded", str(out)])
    assert proc.returncode == 0
    assert "refusing to seed" in proc.stdout
    assert not out.exists()


def test_emit_seeded_onto_baseline_path_compares_old_contents_first(tmp_path):
    # The CI wiring passes OUT == the baseline path itself: the gate must
    # compare against the OLD (unseeded) contents, then overwrite.
    cur = tmp_path / "current.json"
    base = tmp_path / "baseline.json"
    cur.write_text(json.dumps(bench_doc(req_per_s=777.0)))
    base.write_text(json.dumps(bench_doc(seeded=False)))
    proc = subprocess.run(
        [sys.executable, str(SCRIPT), str(cur), str(base), "--emit-seeded", str(base)],
        capture_output=True,
        text=True,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "report-only" in proc.stdout
    seeded = json.loads(base.read_text())
    assert seeded["seeded"] is True
    # Gated fields seed at the 0.85 headroom discount; the rest verbatim.
    assert seeded["des_end_to_end"]["req_per_s"] == 777.0 * 0.85
    assert seeded["des_end_to_end"]["requests"] == 2000


def test_emit_seeded_stamps_and_keeps_note(tmp_path):
    baseline = bench_doc(seeded=False)
    baseline["note"] = "schema documentation survives seeding"
    out = tmp_path / "seeded.json"
    proc = run_gate(tmp_path, bench_doc(), baseline, extra=["--emit-seeded", str(out)])
    assert proc.returncode == 0, proc.stdout + proc.stderr
    seeded = json.loads(out.read_text())
    assert seeded["seeded"] is True
    assert seeded["note"] == "schema documentation survives seeding"
    assert seeded["seed_headroom"] == 0.85
    assert seeded["des_end_to_end"]["req_per_s"] == 1000.0 * 0.85
    # And a seeded file arms the gate for the next run: a re-run at the
    # seeding run's own speed passes (headroom), a collapse fails.
    proc_same = run_gate(tmp_path, bench_doc(req_per_s=1000.0), seeded)
    assert proc_same.returncode == 0
    proc2 = run_gate(tmp_path, bench_doc(req_per_s=100.0), seeded)
    assert proc2.returncode == 1
