"""Out-of-band mirror of the Rust open-arrival engine (trace/open.rs).

This container has no Rust toolchain (same pattern as
test_session_growth.py), so this suite re-implements, line for line,

* the SplitMix64 PRNG (`rust/src/util/rng.rs`) — pinned to the published
  reference vectors so the mirror cannot drift from the algorithm;
* the piecewise rate segments (constant / ramp / diurnal / flash crowd)
  with their closed-form integrals;
* `sample_arrivals`: Poisson thinning of a homogeneous process at the
  program's peak rate, with Rust's committed draw order — exactly one
  `exp` gap then one `gen_bool` accept per candidate —

and fuzzes the contracts the Rust unit tests assert at fixed seeds:

* closed-form integrals == numeric quadrature on random programs;
* realized arrival counts per segment concentrate around the rate
  integral (Poisson concentration, random programs x random seeds);
* at constant rate the thinning test is vacuous, so the sampler emits
  the homogeneous candidate sequence verbatim (draw-order pin);
* flash-crowd bursts land aligned and dense.
"""

import math

from hypothesis import given, settings, strategies as st

MASK = (1 << 64) - 1
GOLDEN = 0x9E3779B97F4A7C15


class Rng:
    """Line-for-line port of rust/src/util/rng.rs (SplitMix64)."""

    def __init__(self, seed):
        self.state = (seed ^ GOLDEN) & MASK

    def next_u64(self):
        self.state = (self.state + GOLDEN) & MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
        return z ^ (z >> 31)

    def next_f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def gen_bool(self, p):
        return self.next_f64() < p

    def exp(self, mean):
        u = 1.0 - self.next_f64()  # (0, 1]
        return -mean * math.log(u)

    def fork(self, tag):
        return Rng(self.next_u64() ^ ((tag * 0xFF51AFD7ED558CCD) & MASK))


# --- rate segments (mirror of trace/open.rs::RateSegment) ---------------


class Constant:
    def __init__(self, rps, dur_s):
        self.rps, self.dur_s = rps, dur_s

    def rate_at(self, t):
        return self.rps

    def integral_to(self, t):
        return self.rps * t

    def peak(self):
        return self.rps


class Ramp:
    def __init__(self, from_rps, to_rps, dur_s):
        self.from_rps, self.to_rps, self.dur_s = from_rps, to_rps, dur_s

    def rate_at(self, t):
        return self.from_rps + (self.to_rps - self.from_rps) * (t / self.dur_s)

    def integral_to(self, t):
        return self.from_rps * t + (self.to_rps - self.from_rps) * t * t / (2.0 * self.dur_s)

    def peak(self):
        return max(self.from_rps, self.to_rps)


class Diurnal:
    def __init__(self, base_rps, amplitude, period_s, dur_s):
        self.base_rps, self.amplitude = base_rps, amplitude
        self.period_s, self.dur_s = period_s, dur_s

    def _w(self):
        return 2.0 * math.pi / self.period_s

    def rate_at(self, t):
        return self.base_rps * (1.0 + self.amplitude * math.sin(self._w() * t))

    def integral_to(self, t):
        w = self._w()
        return self.base_rps * (t + self.amplitude / w * (1.0 - math.cos(w * t)))

    def peak(self):
        return self.base_rps * (1.0 + self.amplitude)


class Flash:
    def __init__(self, base_rps, mult, at_s, burst_s, dur_s):
        self.base_rps, self.mult = base_rps, mult
        self.at_s, self.burst_s, self.dur_s = at_s, burst_s, dur_s

    def rate_at(self, t):
        if self.at_s <= t < self.at_s + self.burst_s:
            return self.base_rps * self.mult
        return self.base_rps

    def integral_to(self, t):
        overlap = max(min(t, self.at_s + self.burst_s) - self.at_s, 0.0)
        return self.base_rps * t + self.base_rps * (self.mult - 1.0) * overlap

    def peak(self):
        return self.base_rps * max(self.mult, 1.0)


class Program:
    """Mirror of RateProgram: segments played back to back."""

    def __init__(self, segments):
        self.segments = segments

    def duration_s(self):
        return sum(s.dur_s for s in self.segments)

    def rate_at(self, t):
        start = 0.0
        for seg in self.segments:
            end = start + seg.dur_s
            if start <= t < end:
                return seg.rate_at(t - start)
            start = end
        return 0.0

    def integral(self, t0, t1):
        total, start = 0.0, 0.0
        for seg in self.segments:
            end = start + seg.dur_s
            lo = min(max(max(t0, start) - start, 0.0), seg.dur_s)
            hi = min(max(min(t1, end) - start, 0.0), seg.dur_s)
            if hi > lo:
                total += seg.integral_to(hi) - seg.integral_to(lo)
            start = end
        return total

    def peak_rate(self):
        return max((s.peak() for s in self.segments), default=0.0)


def sample_arrivals(program, rng):
    """Mirror of trace/open.rs::sample_arrivals, draw order included."""
    peak = program.peak_rate()
    end = program.duration_s()
    out = []
    if peak <= 0.0 or end <= 0.0:
        return out
    t = 0.0
    while True:
        t += rng.exp(1.0 / peak)
        if t >= end:
            break
        if rng.gen_bool(program.rate_at(t) / peak):
            out.append(t)
    return out


# --- the mirror itself is pinned --------------------------------------


def test_splitmix64_reference_vectors():
    # Published SplitMix64 outputs for initial state 0. Rng::new XORs the
    # seed with the golden-ratio constant, so seeding with the constant
    # itself yields state 0.
    r = Rng(GOLDEN)
    assert r.state == 0
    assert [r.next_u64() for _ in range(3)] == [
        0xE220A8397B1DCDAF,
        0x6E789E6AA1B965F4,
        0x06C45D188009454F,
    ]


def test_uniform_and_exp_shapes():
    r = Rng(9)
    xs = [r.next_f64() for _ in range(20000)]
    assert all(0.0 <= x < 1.0 for x in xs)
    assert abs(sum(xs) / len(xs) - 0.5) < 0.01
    es = [r.exp(3.0) for _ in range(20000)]
    assert all(e >= 0.0 for e in es)
    assert abs(sum(es) / len(es) - 3.0) < 0.15


# --- fuzzed contracts ---------------------------------------------------


def build_program(shape, r1, r2, d1, d2, frac):
    """A 1-2 segment program from fuzzed scalars. `frac` in (0,1) places
    the flash window / diurnal period inside the segment."""
    if shape == "constant":
        return Program([Constant(r1, d1)])
    if shape == "ramp":
        return Program([Ramp(r1, r2, d1)])
    if shape == "diurnal":
        return Program([Diurnal(r1, frac, max(d1 * 0.3, 1.0), d1)])
    if shape == "flash":
        return Program([Flash(r1, 2.0 + r2, d1 * frac, d1 * 0.2, d1)])
    # "mixed": constant into ramp into flash.
    return Program(
        [
            Constant(r1, d1),
            Ramp(r1, r2, d2),
            Flash(r2, 3.0, d1 * frac, d1 * 0.25, d1),
        ]
    )


SHAPES = ["constant", "ramp", "diurnal", "flash", "mixed"]


@settings(max_examples=40, deadline=None)
@given(
    shape=st.sampled_from(SHAPES),
    r1=st.floats(0.5, 20.0),
    r2=st.floats(0.5, 20.0),
    d1=st.floats(5.0, 80.0),
    d2=st.floats(5.0, 80.0),
    frac=st.floats(0.1, 0.9),
)
def test_closed_form_integral_matches_quadrature(shape, r1, r2, d1, d2, frac):
    p = build_program(shape, r1, r2, d1, d2, frac)
    dur = p.duration_s()
    for t0, t1 in [(0.0, dur), (0.13 * dur, 0.71 * dur), (0.5 * dur, 0.97 * dur)]:
        n = 8000
        dt = (t1 - t0) / n
        quad = sum(p.rate_at(t0 + (i + 0.5) * dt) * dt for i in range(n))
        exact = p.integral(t0, t1)
        # Midpoint quadrature is exact up to the flash discontinuities:
        # allow one peak*dt slab per possible edge plus a relative term.
        tol = 4.0 * p.peak_rate() * dt + 1e-6 * max(exact, 1.0)
        assert abs(exact - quad) <= tol, (shape, t0, t1, exact, quad)


@settings(max_examples=25, deadline=None)
@given(
    shape=st.sampled_from(SHAPES),
    seed=st.integers(0, 2**32),
    r1=st.floats(2.0, 20.0),
    r2=st.floats(2.0, 20.0),
    d1=st.floats(20.0, 80.0),
    d2=st.floats(20.0, 80.0),
    frac=st.floats(0.1, 0.9),
)
def test_realized_counts_concentrate_on_the_integral(shape, seed, r1, r2, d1, d2, frac):
    p = build_program(shape, r1, r2, d1, d2, frac)
    arrivals = sample_arrivals(p, Rng(seed))
    start = 0.0
    for seg in p.segments:
        end = start + seg.dur_s
        expected = p.integral(start, end)
        got = sum(1 for t in arrivals if start <= t < end)
        # 6 sigma + slack: false-failure odds are negligible even across
        # the whole fuzz campaign, a systematic thinning bug is not.
        tol = 6.0 * math.sqrt(expected) + 6.0
        assert abs(got - expected) <= tol, (shape, seed, start, end, got, expected)
        start = end
    assert all(arrivals[i] <= arrivals[i + 1] for i in range(len(arrivals) - 1))
    assert all(0.0 <= t < p.duration_s() for t in arrivals)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**32), rps=st.floats(1.0, 30.0), dur=st.floats(10.0, 120.0))
def test_constant_rate_thinning_is_vacuous_and_draw_order_pins(seed, rps, dur):
    """At constant rate lambda == peak, every accept test compares
    next_f64() < 1.0 (always true), so the sampler must emit the
    homogeneous candidate walk verbatim — consuming exactly one exp gap
    and one gen_bool draw per candidate, in that order. A reordered or
    extra draw anywhere would shift every subsequent arrival."""
    p = Program([Constant(rps, dur)])
    arrivals = sample_arrivals(p, Rng(seed))

    rng = Rng(seed)  # replay the committed draw order by hand
    expected, t = [], 0.0
    while True:
        t += rng.exp(1.0 / rps)
        if t >= dur:
            break
        assert rng.gen_bool(1.0)
        expected.append(t)
    assert arrivals == expected


def test_flash_crowd_burst_is_aligned_and_dense():
    p = Program([Flash(2.0, 10.0, 100.0, 20.0, 300.0)])
    arrivals = sample_arrivals(p, Rng(5))
    in_burst = sum(1 for t in arrivals if 100.0 <= t < 120.0)
    before = sum(1 for t in arrivals if 60.0 <= t < 100.0)
    burst_density = in_burst / 20.0
    base_density = before / 40.0
    assert burst_density > 4.0 * base_density, (burst_density, base_density)


@settings(max_examples=30, deadline=None)
@given(
    shape=st.sampled_from(SHAPES),
    r1=st.floats(0.5, 20.0),
    r2=st.floats(0.5, 20.0),
    d1=st.floats(5.0, 80.0),
    d2=st.floats(5.0, 80.0),
    frac=st.floats(0.1, 0.9),
    a=st.floats(0.0, 1.0),
    b=st.floats(0.0, 1.0),
)
def test_integral_is_additive_and_monotone(shape, r1, r2, d1, d2, frac, a, b):
    p = build_program(shape, r1, r2, d1, d2, frac)
    dur = p.duration_s()
    lo, hi = sorted((a * dur, b * dur))
    mid = (lo + hi) / 2.0
    whole = p.integral(lo, hi)
    parts = p.integral(lo, mid) + p.integral(mid, hi)
    assert abs(whole - parts) <= 1e-7 * max(whole, 1.0)
    assert whole >= -1e-12
    assert p.integral(0.0, dur) >= whole - 1e-9


def test_fork_streams_are_decorrelated():
    base = Rng(21)
    f1, f2 = base.fork(1), base.fork(2)
    assert f1.next_u64() != f2.next_u64()
