"""AOT path: lowering produces valid HLO text + a manifest rust can trust."""

import json
import os

import numpy as np
import pytest

pytest.importorskip("jax", reason="JAX toolchain absent — AOT lowering tests skipped")

from compile.aot import build, lower_decode, lower_prefill, to_hlo_text
from compile.model import ModelConfig


def test_prefill_lowers_to_hlo_text():
    cfg = ModelConfig()
    text = to_hlo_text(lower_prefill(cfg, 16, len(cfg.param_names())))
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    # tuple return: (logits, kv)
    assert "f32[1024]" in text  # logits vocab
    assert "f32[2,2,8,4,512,32]" in text  # kv state


def test_decode_lowers_to_hlo_text():
    cfg = ModelConfig()
    text = to_hlo_text(lower_decode(cfg))
    assert text.startswith("HloModule")
    assert "f32[8,1024]" in text  # [slots, vocab] logits


def test_build_writes_manifest_and_params(tmp_path):
    cfg = ModelConfig(chunk_buckets=(16,))  # keep the test fast
    manifest = build(cfg, str(tmp_path))
    with open(tmp_path / "manifest.json") as f:
        on_disk = json.load(f)
    assert on_disk == manifest
    assert on_disk["model"]["vocab"] == cfg.vocab
    assert on_disk["chunk_buckets"] == [16]
    assert set(a["file"] for a in on_disk["artifacts"].values()) == {
        "prefill_c16.hlo.txt",
        "decode.hlo.txt",
        "extract_slot.hlo.txt",
        "inject_slot.hlo.txt",
    }
    # params.bin size must equal the declared shapes.
    total = sum(int(np.prod(p["shape"])) for p in on_disk["params"])
    assert os.path.getsize(tmp_path / "params.bin") == 4 * total
    for art in on_disk["artifacts"].values():
        assert (tmp_path / art["file"]).exists()


def test_params_bin_deterministic(tmp_path):
    """Same seed -> byte-identical params.bin (rust relies on this)."""
    cfg = ModelConfig(chunk_buckets=())
    build(cfg, str(tmp_path / "a"))
    build(cfg, str(tmp_path / "b"))
    a = (tmp_path / "a" / "params.bin").read_bytes()
    b = (tmp_path / "b" / "params.bin").read_bytes()
    assert a == b
