"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes, cache positions and slot-length vectors; every
case asserts allclose against the reference.
"""

import numpy as np
import pytest

pytest.importorskip("jax", reason="JAX toolchain absent — Pallas kernel tests skipped")
pytest.importorskip(
    "jax.experimental.pallas", reason="Pallas unavailable — kernel tests skipped"
)

import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    decode_attention,
    decode_attention_ref,
    prefill_attention,
    prefill_attention_ref,
)
from compile.kernels.attention import BLK_K

SETTINGS = dict(max_examples=12, deadline=None)


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


# ---------------------------------------------------------------- prefill


@settings(**SETTINGS)
@given(
    h=st.sampled_from([1, 2, 4]),
    c=st.sampled_from([16, 64, 128, 256]),
    s_blocks=st.sampled_from([2, 4]),
    d=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
    pos_frac=st.floats(0.0, 1.0),
)
def test_prefill_matches_ref(h, c, s_blocks, d, seed, pos_frac):
    s = s_blocks * BLK_K
    if c > s:
        c = s
    rng = np.random.default_rng(seed)
    pos = int(pos_frac * (s - c))
    q = _rand(rng, h, c, d)
    k = _rand(rng, h, s, d)
    v = _rand(rng, h, s, d)
    got = prefill_attention(q, k, v, pos)
    want = prefill_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_prefill_pos_zero_and_max():
    rng = np.random.default_rng(0)
    h, c, s, d = 2, 64, 2 * BLK_K, 32
    q, k, v = _rand(rng, h, c, d), _rand(rng, h, s, d), _rand(rng, h, s, d)
    for pos in (0, s - c):
        np.testing.assert_allclose(
            prefill_attention(q, k, v, pos),
            prefill_attention_ref(q, k, v, pos),
            atol=2e-5,
            rtol=2e-5,
        )


def test_prefill_first_token_attends_only_itself():
    """With pos=0, query 0 must attend only to key 0 -> output == v[:,0]."""
    rng = np.random.default_rng(3)
    h, c, s, d = 2, 16, BLK_K, 16
    q, k, v = _rand(rng, h, c, d), _rand(rng, h, s, d), _rand(rng, h, s, d)
    out = prefill_attention(q, k, v, 0)
    np.testing.assert_allclose(out[:, 0, :], v[:, 0, :], atol=2e-5, rtol=2e-5)


def test_prefill_ignores_garbage_beyond_causal_frontier():
    """Keys at positions > pos+i must not affect output."""
    rng = np.random.default_rng(4)
    h, c, s, d = 2, 16, BLK_K, 16
    pos = 40
    q, k, v = _rand(rng, h, c, d), _rand(rng, h, s, d), _rand(rng, h, s, d)
    out1 = prefill_attention(q, k, v, pos)
    k2 = k.at[:, pos + c :, :].set(1e3)
    v2 = v.at[:, pos + c :, :].set(-1e3)
    out2 = prefill_attention(q, k2, v2, pos)
    np.testing.assert_allclose(out1, out2, atol=2e-5, rtol=2e-5)


def test_prefill_rejects_bad_shapes():
    rng = np.random.default_rng(5)
    q = _rand(rng, 2, 16, 16)
    k = _rand(rng, 2, 100, 16)  # not a BLK_K multiple
    with pytest.raises(ValueError):
        prefill_attention(q, k, k, 0)


# ----------------------------------------------------------------- decode


@settings(**SETTINGS)
@given(
    b=st.sampled_from([1, 2, 4, 8]),
    h=st.sampled_from([1, 4]),
    s_blocks=st.sampled_from([2, 4]),
    d=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_matches_ref(b, h, s_blocks, d, seed):
    s = s_blocks * BLK_K
    rng = np.random.default_rng(seed)
    q = _rand(rng, b, h, d)
    k = _rand(rng, b, h, s, d)
    v = _rand(rng, b, h, s, d)
    lens = jnp.asarray(rng.integers(0, s + 1, b), jnp.int32)
    got = decode_attention(q, k, v, lens)
    want = decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_decode_inactive_slots_zero():
    rng = np.random.default_rng(7)
    b, h, s, d = 4, 2, BLK_K, 16
    q, k, v = _rand(rng, b, h, d), _rand(rng, b, h, s, d), _rand(rng, b, h, s, d)
    lens = jnp.asarray([0, 3, 0, s], jnp.int32)
    out = decode_attention(q, k, v, lens)
    assert float(jnp.abs(out[0]).max()) == 0.0
    assert float(jnp.abs(out[2]).max()) == 0.0
    assert float(jnp.abs(out[1]).max()) > 0.0


def test_decode_len_one_returns_v0():
    rng = np.random.default_rng(8)
    b, h, s, d = 2, 2, BLK_K, 16
    q, k, v = _rand(rng, b, h, d), _rand(rng, b, h, s, d), _rand(rng, b, h, s, d)
    lens = jnp.asarray([1, 1], jnp.int32)
    out = decode_attention(q, k, v, lens)
    np.testing.assert_allclose(out, v[:, :, 0, :], atol=2e-5, rtol=2e-5)


def test_decode_full_length():
    rng = np.random.default_rng(9)
    b, h, s, d = 2, 2, 2 * BLK_K, 16
    q, k, v = _rand(rng, b, h, d), _rand(rng, b, h, s, d), _rand(rng, b, h, s, d)
    lens = jnp.full((b,), s, jnp.int32)
    np.testing.assert_allclose(
        decode_attention(q, k, v, lens),
        decode_attention_ref(q, k, v, lens),
        atol=2e-5,
        rtol=2e-5,
    )
