"""L2 correctness: the chunked/batched serving path must reproduce the
monolithic full-sequence forward (reference_forward) exactly.

This is the property the whole serving engine rests on: processing a
prompt as (KV$-hit prefix skip + chunked prefill + batched decode) yields
the same logits as one full forward pass.
"""

import numpy as np
import pytest

pytest.importorskip("jax", reason="JAX toolchain absent — model tests skipped")

import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.model import (
    ModelConfig,
    decode_step,
    extract_slot,
    init_params,
    inject_slot,
    prefill_chunk,
    reference_forward,
)

CFG = ModelConfig()
PARAMS = init_params(CFG)
ATOL = 2e-4


def _tokens(rng, n):
    return jnp.asarray(rng.integers(1, CFG.vocab, n), jnp.int32)


def _pad(a, n):
    return jnp.concatenate([a, jnp.zeros(n - a.shape[0], jnp.int32)])


def _prefill_seq(tokens, slot, kv, chunk=16, start_pos=0):
    """Prefill tokens[start_pos:] in fixed-size chunks (cache holds
    tokens[:start_pos] already). Returns (last logits, kv)."""
    pos = start_pos
    n = tokens.shape[0]
    logits = None
    while pos < n:
        c = min(chunk, n - pos)
        buf = _pad(tokens[pos : pos + c], chunk)
        logits, kv = prefill_chunk(
            CFG, buf, jnp.int32(slot), jnp.int32(pos), jnp.int32(c), kv, *PARAMS
        )
        pos += c
    return logits, kv


def test_single_chunk_matches_reference():
    rng = np.random.default_rng(0)
    toks = _tokens(rng, 16)
    ref = reference_forward(CFG, toks, PARAMS)
    kv = jnp.zeros(CFG.kv_shape, jnp.float32)
    logits, _ = prefill_chunk(
        CFG, toks, jnp.int32(0), jnp.int32(0), jnp.int32(16), kv, *PARAMS
    )
    np.testing.assert_allclose(logits, ref[-1], atol=ATOL)


@settings(max_examples=8, deadline=None)
@given(
    n=st.integers(3, 80),
    chunk=st.sampled_from([16, 64]),
    slot=st.integers(0, CFG.slots - 1),
    seed=st.integers(0, 2**31 - 1),
)
def test_chunked_prefill_matches_reference(n, chunk, slot, seed):
    rng = np.random.default_rng(seed)
    toks = _tokens(rng, n)
    ref = reference_forward(CFG, toks, PARAMS)
    kv = jnp.zeros(CFG.kv_shape, jnp.float32)
    logits, _ = _prefill_seq(toks, slot, kv, chunk=chunk)
    np.testing.assert_allclose(logits, ref[-1], atol=ATOL)


def test_padding_does_not_change_logits():
    """Logits at chunk_len-1 are invariant to pad-token values."""
    rng = np.random.default_rng(2)
    toks = _tokens(rng, 10)
    kv = jnp.zeros(CFG.kv_shape, jnp.float32)
    buf1 = _pad(toks, 16)
    buf2 = jnp.concatenate([toks, jnp.full((6,), 999, jnp.int32)])
    l1, _ = prefill_chunk(CFG, buf1, jnp.int32(0), jnp.int32(0), jnp.int32(10), kv, *PARAMS)
    l2, _ = prefill_chunk(CFG, buf2, jnp.int32(0), jnp.int32(0), jnp.int32(10), kv, *PARAMS)
    np.testing.assert_allclose(l1, l2, atol=1e-5)


def test_kv_hit_prefix_skip_matches_full_prefill():
    """The KV$-reuse contract: if the cache already holds a prefix, starting
    prefill at pos=hit_len gives the same logits as prefilling everything."""
    rng = np.random.default_rng(3)
    prefix = _tokens(rng, 32)
    suffix = _tokens(rng, 20)
    full = jnp.concatenate([prefix, suffix])
    # Path A: prefill the whole prompt.
    kv_a = jnp.zeros(CFG.kv_shape, jnp.float32)
    la, _ = _prefill_seq(full, 1, kv_a)
    # Path B: prefill prefix (a previous request), then treat it as a KV$
    # hit and prefill only the suffix at pos=32.
    kv_b = jnp.zeros(CFG.kv_shape, jnp.float32)
    _, kv_b = _prefill_seq(prefix, 1, kv_b)
    lb, _ = _prefill_seq(full, 1, kv_b, start_pos=32)
    np.testing.assert_allclose(la, lb, atol=ATOL)


def test_decode_chain_matches_reference():
    rng = np.random.default_rng(4)
    toks = _tokens(rng, 24)
    kv = jnp.zeros(CFG.kv_shape, jnp.float32)
    logits, kv = _prefill_seq(toks, 3, kv)
    seq = toks
    for _ in range(4):
        nt = jnp.argmax(logits if logits.ndim == 1 else logits[3]).astype(jnp.int32)
        seq = jnp.concatenate([seq, nt[None]])
        ref = reference_forward(CFG, seq, PARAMS)
        tok_in = jnp.zeros(CFG.slots, jnp.int32).at[3].set(nt)
        lens = jnp.zeros(CFG.slots, jnp.int32).at[3].set(seq.shape[0] - 1)
        out, kv = decode_step(CFG, tok_in, lens, kv, *PARAMS)
        np.testing.assert_allclose(out[3], ref[-1], atol=ATOL)
        logits = out


def test_slots_are_isolated():
    """Prefilling slot A must not perturb slot B's decode results."""
    rng = np.random.default_rng(5)
    ta, tb = _tokens(rng, 20), _tokens(rng, 30)
    kv = jnp.zeros(CFG.kv_shape, jnp.float32)
    la_alone, _ = _prefill_seq(ta, 0, kv)
    _, kv = _prefill_seq(tb, 5, kv)  # other slot busy
    la_shared, _ = _prefill_seq(ta, 0, kv)
    np.testing.assert_allclose(la_alone, la_shared, atol=1e-5)


def test_batched_decode_matches_individual():
    """Decoding two slots in one batched step == decoding each alone."""
    rng = np.random.default_rng(6)
    ta, tb = _tokens(rng, 12), _tokens(rng, 18)
    kv = jnp.zeros(CFG.kv_shape, jnp.float32)
    la, kv = _prefill_seq(ta, 0, kv)
    lb, kv = _prefill_seq(tb, 1, kv)
    na = jnp.argmax(la).astype(jnp.int32)
    nb = jnp.argmax(lb).astype(jnp.int32)
    # Batched: both slots at once.
    tok_in = jnp.zeros(CFG.slots, jnp.int32).at[0].set(na).at[1].set(nb)
    lens = jnp.zeros(CFG.slots, jnp.int32).at[0].set(12).at[1].set(18)
    out_b, _ = decode_step(CFG, tok_in, lens, kv, *PARAMS)
    # Individual references.
    ra = reference_forward(CFG, jnp.concatenate([ta, na[None]]), PARAMS)[-1]
    rb = reference_forward(CFG, jnp.concatenate([tb, nb[None]]), PARAMS)[-1]
    np.testing.assert_allclose(out_b[0], ra, atol=ATOL)
    np.testing.assert_allclose(out_b[1], rb, atol=ATOL)


def test_extract_inject_roundtrip_preserves_kv_hit_path():
    """Snapshot a finished slot's KV, inject it into another slot, and
    continue from the hit — must equal prefilling from scratch. This is the
    live engine's cross-request KV$ mechanism."""
    rng = np.random.default_rng(7)
    prefix = _tokens(rng, 32)
    suffix = _tokens(rng, 16)
    full = jnp.concatenate([prefix, suffix])
    # Request 1 on slot 0 prefills the prefix; snapshot slot 0.
    kv = jnp.zeros(CFG.kv_shape, jnp.float32)
    _, kv = _prefill_seq(prefix, 0, kv)
    k_snap, v_snap = extract_slot(CFG, kv, jnp.int32(0))
    # Request 2 arrives on slot 4 with a KV$ hit on the prefix.
    kv2 = jnp.zeros(CFG.kv_shape, jnp.float32)
    kv2 = inject_slot(CFG, kv2, jnp.int32(4), k_snap, v_snap)
    l_hit, _ = _prefill_seq(full, 4, kv2, start_pos=32)
    # Oracle: full prefill with no cache.
    kv3 = jnp.zeros(CFG.kv_shape, jnp.float32)
    l_cold, _ = _prefill_seq(full, 2, kv3)
    np.testing.assert_allclose(l_hit, l_cold, atol=ATOL)


def test_param_layout_stable():
    """param_names()/param_shapes() define the params.bin ABI with rust —
    guard against accidental reordering."""
    names = CFG.param_names()
    assert names[0] == "embed" and names[1] == "pos_emb" and names[-1] == "lnf"
    assert len(names) == 2 + 8 * CFG.n_layers + 1
    shapes = CFG.param_shapes()
    total = sum(int(np.prod(shapes[n])) for n in names)
    flat = np.concatenate([np.asarray(p).ravel() for p in PARAMS])
    assert flat.size == total
