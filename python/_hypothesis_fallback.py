"""Deterministic fallback for the tiny `hypothesis` subset the tests use.

The property tests in python/tests use `@given` with `st.sampled_from`,
`st.integers`, `st.floats`, `st.lists` and `st.tuples`, plus
`@settings(max_examples=.., deadline=None)`. When the real hypothesis package is installed (CI path)
this module is never imported. In bare environments (offline container
with only jax+pytest), conftest installs this shim so the property tests
still execute: each `@given` test runs `max_examples` seeded-random cases.

This is NOT a hypothesis reimplementation — no shrinking, no database, no
edge-case bias — just enough to keep the kernel/model contracts exercised
where the real tool is unavailable.
"""

import random
import sys
import types

_SEED = 0x1A2B3C4D  # fixed seed: runs are reproducible


class _Strategy:
    def __init__(self, sample):
        self.sample = sample  # sample(rng) -> value


def sampled_from(elements):
    seq = list(elements)
    if not seq:
        raise ValueError("sampled_from: empty")
    return _Strategy(lambda rng: seq[rng.randrange(len(seq))])


def integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value, max_value):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def tuples(*elements):
    return _Strategy(lambda rng: tuple(e.sample(rng) for e in elements))


def lists(elements, min_size=0, max_size=10):
    return _Strategy(
        lambda rng: [elements.sample(rng) for _ in range(rng.randint(min_size, max_size))]
    )


def settings(*args, **kwargs):
    """Decorator-factory form only (how the tests use it); options other
    than max_examples are accepted and ignored."""

    def deco(fn):
        fn._fallback_settings = kwargs
        return fn

    return deco


def given(**strategies):
    def deco(fn):
        # NOTE: no functools.wraps — pytest must see a zero-argument
        # signature, not the strategy parameters (it would try to resolve
        # them as fixtures).
        def wrapper():
            opts = getattr(wrapper, "_fallback_settings", None) or getattr(
                fn, "_fallback_settings", {}
            )
            n = int(opts.get("max_examples", 10))
            rng = random.Random(_SEED)
            for case in range(n):
                drawn = {k: s.sample(rng) for k, s in strategies.items()}
                try:
                    fn(**drawn)
                except Exception as e:  # annotate which case failed
                    raise AssertionError(
                        f"fallback-hypothesis case {case}/{n} failed with "
                        f"arguments {drawn!r}: {e}"
                    ) from e

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco


def install():
    """Register shim modules as `hypothesis` / `hypothesis.strategies`."""
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    st = types.ModuleType("hypothesis.strategies")
    st.sampled_from = sampled_from
    st.integers = integers
    st.floats = floats
    st.lists = lists
    st.tuples = tuples
    hyp.strategies = st
    hyp.__fallback__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
