"""Layer-2 JAX model: a small decoder-only transformer served by the rust
instance engine on the live path.

The two entry points mirror exactly what a chunked-prefill, continuous-
batching engine executes per step (calling the Layer-1 Pallas kernels):

* ``prefill_chunk`` — process one chunk of NEW prompt tokens for one
  sequence slot, reusing whatever KV$ prefix is already in the cache
  (a KV$ hit means the engine starts at ``pos = hit_len`` and never
  recomputes the hit tokens — the source of the P-token indicator's
  cost model).
* ``decode_step`` — one token for every active slot, batched.

State layout: a single KV$ tensor ``kv[f32, (L, 2, SLOTS, H, S, D)]`` that
the rust runtime keeps resident on the PJRT device and threads through
successive calls (no host round-trip).

Python is build-time only: ``aot.py`` lowers these functions to HLO text
once per bucket; rust loads and executes them.
"""

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import decode_attention, prefill_attention


@dataclass(frozen=True)
class ModelConfig:
    """Tiny-transformer configuration (sized for CPU-PJRT live serving)."""

    vocab: int = 1024
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    d_head: int = 32
    d_ff: int = 384
    max_seq: int = 512
    slots: int = 8  # max concurrent sequences per instance (batch slots)
    chunk_buckets: tuple = (16, 64, 256)  # chunked-prefill bucket sizes
    seed: int = 20260710

    @property
    def kv_shape(self):
        return (
            self.n_layers,
            2,
            self.slots,
            self.n_heads,
            self.max_seq,
            self.d_head,
        )

    def param_names(self):
        """Deterministic flattening order — the AOT artifact signature and
        the rust runtime's params.bin layout both follow this order."""
        names = ["embed", "pos_emb"]
        for i in range(self.n_layers):
            names += [
                f"l{i}.ln1",
                f"l{i}.wq",
                f"l{i}.wk",
                f"l{i}.wv",
                f"l{i}.wo",
                f"l{i}.ln2",
                f"l{i}.w1",
                f"l{i}.w2",
            ]
        names.append("lnf")
        return names

    def param_shapes(self):
        d, hd = self.d_model, self.n_heads * self.d_head
        shapes = {
            "embed": (self.vocab, d),
            "pos_emb": (self.max_seq, d),
            "lnf": (d,),
        }
        for i in range(self.n_layers):
            shapes[f"l{i}.ln1"] = (d,)
            shapes[f"l{i}.wq"] = (d, hd)
            shapes[f"l{i}.wk"] = (d, hd)
            shapes[f"l{i}.wv"] = (d, hd)
            shapes[f"l{i}.wo"] = (hd, d)
            shapes[f"l{i}.ln2"] = (d,)
            shapes[f"l{i}.w1"] = (d, self.d_ff)
            shapes[f"l{i}.w2"] = (self.d_ff, d)
        return shapes


def init_params(cfg: ModelConfig):
    """Deterministic random init; returns params in param_names() order."""
    rng = np.random.default_rng(cfg.seed)
    shapes = cfg.param_shapes()
    out = []
    for name in cfg.param_names():
        shape = shapes[name]
        if name.endswith(("ln1", "ln2", "lnf")):
            arr = np.ones(shape, np.float32)
        else:
            scale = 0.02 if name in ("embed", "pos_emb") else 1.0 / np.sqrt(shape[0])
            arr = (rng.standard_normal(shape) * scale).astype(np.float32)
        out.append(jnp.asarray(arr))
    return tuple(out)


def _rmsnorm(x, scale):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * scale


def _unpack(cfg: ModelConfig, params):
    names = cfg.param_names()
    assert len(params) == len(names), (len(params), len(names))
    return dict(zip(names, params))


def prefill_chunk(cfg: ModelConfig, tokens, slot, pos, chunk_len, kv, *params):
    """Prefill one chunk of new tokens into a sequence slot.

    Args:
      tokens: i32[C] chunk tokens (padded to the bucket size).
      slot: i32 scalar — slot index in [0, cfg.slots).
      pos: i32 scalar — tokens already cached for this slot (KV$-hit prefix
        + previously prefilled chunks).
      chunk_len: i32 scalar — number of REAL tokens in the chunk (≤ C).
      kv: f32[kv_shape] cache state.
      *params: model parameters in param_names() order.

    Returns:
      (logits f32[vocab] at the chunk's last real token, updated kv).
    """
    p = _unpack(cfg, params)
    c = tokens.shape[0]
    h, dh, s = cfg.n_heads, cfg.d_head, cfg.max_seq
    positions = jnp.clip(pos + jnp.arange(c, dtype=jnp.int32), 0, s - 1)
    x = p["embed"][tokens] + p["pos_emb"][positions]  # [C, d]

    for i in range(cfg.n_layers):
        hx = _rmsnorm(x, p[f"l{i}.ln1"])
        q = (hx @ p[f"l{i}.wq"]).reshape(c, h, dh).transpose(1, 0, 2)  # [H,C,D]
        k = (hx @ p[f"l{i}.wk"]).reshape(c, h, dh).transpose(1, 0, 2)
        v = (hx @ p[f"l{i}.wv"]).reshape(c, h, dh).transpose(1, 0, 2)
        # Write the chunk's K/V into the cache at [pos, pos+C). Padding
        # beyond chunk_len lands at positions the next chunk overwrites and
        # is causally invisible to real queries.
        k6 = k[None, None, None]  # [1,1,1,H,C,D]
        v6 = v[None, None, None]
        zero = jnp.int32(0)
        kv = jax.lax.dynamic_update_slice(
            kv, k6, (jnp.int32(i), zero, slot, zero, pos, zero)
        )
        kv = jax.lax.dynamic_update_slice(
            kv, v6, (jnp.int32(i), jnp.int32(1), slot, zero, pos, zero)
        )
        kcache = jax.lax.dynamic_slice(
            kv, (jnp.int32(i), zero, slot, zero, zero, zero), (1, 1, 1, h, s, dh)
        ).reshape(h, s, dh)
        vcache = jax.lax.dynamic_slice(
            kv, (jnp.int32(i), jnp.int32(1), slot, zero, zero, zero), (1, 1, 1, h, s, dh)
        ).reshape(h, s, dh)
        attn = prefill_attention(q, kcache, vcache, pos)  # [H,C,D]
        x = x + attn.transpose(1, 0, 2).reshape(c, h * dh) @ p[f"l{i}.wo"]
        hx2 = _rmsnorm(x, p[f"l{i}.ln2"])
        x = x + jax.nn.gelu(hx2 @ p[f"l{i}.w1"]) @ p[f"l{i}.w2"]

    xf = _rmsnorm(x, p["lnf"])
    last = jax.lax.dynamic_slice(xf, (chunk_len - 1, jnp.int32(0)), (1, cfg.d_model))
    logits = (last @ p["embed"].T).reshape(cfg.vocab)
    return logits, kv


def decode_step(cfg: ModelConfig, tokens, lens, kv, *params):
    """One decode step for all slots (continuous-batching inner loop).

    Args:
      tokens: i32[SLOTS] last generated token per slot (0 for inactive).
      lens: i32[SLOTS] current cached length per slot BEFORE this token
        (0 for inactive slots — their writes land at position 0, which the
        next prefill of that slot overwrites).
      kv: f32[kv_shape] cache state.
      *params: model parameters.

    Returns:
      (logits f32[SLOTS, vocab], updated kv).
    """
    p = _unpack(cfg, params)
    sl, h, dh, s = cfg.slots, cfg.n_heads, cfg.d_head, cfg.max_seq
    safe_pos = jnp.clip(lens, 0, s - 1)
    x = p["embed"][tokens] + p["pos_emb"][safe_pos]  # [SL, d]

    def write_slot(cache_b, kb, len_b):
        # cache_b: [H,S,D], kb: [H,D] -> write at [:, len_b, :]
        return jax.lax.dynamic_update_slice(
            cache_b, kb[:, None, :], (jnp.int32(0), len_b, jnp.int32(0))
        )

    for i in range(cfg.n_layers):
        hx = _rmsnorm(x, p[f"l{i}.ln1"])
        q = (hx @ p[f"l{i}.wq"]).reshape(sl, h, dh)
        k = (hx @ p[f"l{i}.wk"]).reshape(sl, h, dh)
        v = (hx @ p[f"l{i}.wv"]).reshape(sl, h, dh)
        kcache = jax.vmap(write_slot)(kv[i, 0], k, safe_pos)  # [SL,H,S,D]
        vcache = jax.vmap(write_slot)(kv[i, 1], v, safe_pos)
        kv = kv.at[i, 0].set(kcache).at[i, 1].set(vcache)
        attn = decode_attention(q, kcache, vcache, lens + 1)  # [SL,H,D]
        x = x + attn.reshape(sl, h * dh) @ p[f"l{i}.wo"]
        hx2 = _rmsnorm(x, p[f"l{i}.ln2"])
        x = x + jax.nn.gelu(hx2 @ p[f"l{i}.w1"]) @ p[f"l{i}.w2"]

    xf = _rmsnorm(x, p["lnf"])
    logits = xf @ p["embed"].T  # [SL, vocab]
    return logits, kv


def extract_slot(cfg: ModelConfig, kv, slot):
    """Pull one slot's K and V planes out of the cache.

    Used by the live engine at request completion to snapshot the slot's
    KV$ into the host-side prefix store (the cross-request KV$ cache).

    Returns (k f32[L,H,S,D], v f32[L,H,S,D]).
    """
    l, h, s, dh = cfg.n_layers, cfg.n_heads, cfg.max_seq, cfg.d_head
    zero = jnp.int32(0)
    k = jax.lax.dynamic_slice(
        kv, (zero, zero, slot, zero, zero, zero), (l, 1, 1, h, s, dh)
    ).reshape(l, h, s, dh)
    v = jax.lax.dynamic_slice(
        kv, (zero, jnp.int32(1), slot, zero, zero, zero), (l, 1, 1, h, s, dh)
    ).reshape(l, h, s, dh)
    return k, v


def inject_slot(cfg: ModelConfig, kv, slot, k, v):
    """Write host-provided K/V planes into a slot — the KV$-hit fast path.

    The live engine injects a cached prefix here and then prefills only the
    remaining (new) tokens starting at pos = hit length. Content beyond the
    hit length is overwritten by subsequent prefill chunks and causally
    masked, so callers may pass a full-S plane.
    """
    l = cfg.n_layers
    zero = jnp.int32(0)
    kv = jax.lax.dynamic_update_slice(
        kv, k[:, None, None], (zero, zero, slot, zero, zero, zero)
    )
    kv = jax.lax.dynamic_update_slice(
        kv, v[:, None, None], (zero, jnp.int32(1), slot, zero, zero, zero)
    )
    return kv


def reference_forward(cfg: ModelConfig, tokens, params):
    """Monolithic full-sequence forward (no cache) — oracle for tests.

    Computes logits for every position of ``tokens`` (i32[T]) with plain
    causal attention; must match composing prefill_chunk/decode_step.
    """
    p = _unpack(cfg, params)
    t = tokens.shape[0]
    h, dh = cfg.n_heads, cfg.d_head
    x = p["embed"][tokens] + p["pos_emb"][jnp.arange(t)]
    mask = jnp.tril(jnp.ones((t, t), bool))
    for i in range(cfg.n_layers):
        hx = _rmsnorm(x, p[f"l{i}.ln1"])
        q = (hx @ p[f"l{i}.wq"]).reshape(t, h, dh)
        k = (hx @ p[f"l{i}.wk"]).reshape(t, h, dh)
        v = (hx @ p[f"l{i}.wv"]).reshape(t, h, dh)
        logits = jnp.einsum("qhd,khd->hqk", q, k) / np.sqrt(dh)
        logits = jnp.where(mask[None], logits, -1e30)
        att = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("hqk,khd->qhd", att, v).reshape(t, h * dh)
        x = x + o @ p[f"l{i}.wo"]
        hx2 = _rmsnorm(x, p[f"l{i}.ln2"])
        x = x + jax.nn.gelu(hx2 @ p[f"l{i}.w1"]) @ p[f"l{i}.w2"]
    xf = _rmsnorm(x, p["lnf"])
    return xf @ p["embed"].T  # [T, vocab]
