"""AOT compile path: lower the L2 model to HLO **text** artifacts.

Run once by ``make artifacts``; Python never appears on the request path.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids that the rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (to --out, default ../artifacts):
  prefill_c{B}.hlo.txt   one per chunk bucket B in cfg.chunk_buckets
  decode.hlo.txt         batched decode step over all slots
  params.bin             flat little-endian f32 params in param_names() order
  manifest.json          model config + artifact & parameter signatures
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import (
    ModelConfig,
    decode_step,
    extract_slot,
    init_params,
    inject_slot,
    prefill_chunk,
)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_prefill(cfg: ModelConfig, chunk: int, n_params: int):
    def fn(tokens, slot, pos, chunk_len, kv, *params):
        return prefill_chunk(cfg, tokens, slot, pos, chunk_len, kv, *params)

    i32 = lambda *shape: jax.ShapeDtypeStruct(shape, jnp.int32)  # noqa: E731
    shapes = cfg.param_shapes()
    param_specs = [
        jax.ShapeDtypeStruct(shapes[n], jnp.float32) for n in cfg.param_names()
    ]
    return jax.jit(fn).lower(
        i32(chunk),
        i32(),
        i32(),
        i32(),
        jax.ShapeDtypeStruct(cfg.kv_shape, jnp.float32),
        *param_specs,
    )


def lower_decode(cfg: ModelConfig):
    def fn(tokens, lens, kv, *params):
        return decode_step(cfg, tokens, lens, kv, *params)

    shapes = cfg.param_shapes()
    param_specs = [
        jax.ShapeDtypeStruct(shapes[n], jnp.float32) for n in cfg.param_names()
    ]
    return jax.jit(fn).lower(
        jax.ShapeDtypeStruct((cfg.slots,), jnp.int32),
        jax.ShapeDtypeStruct((cfg.slots,), jnp.int32),
        jax.ShapeDtypeStruct(cfg.kv_shape, jnp.float32),
        *param_specs,
    )


def lower_extract(cfg: ModelConfig):
    def fn(kv, slot):
        return extract_slot(cfg, kv, slot)

    return jax.jit(fn).lower(
        jax.ShapeDtypeStruct(cfg.kv_shape, jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32),
    )


def lower_inject(cfg: ModelConfig):
    plane = (cfg.n_layers, cfg.n_heads, cfg.max_seq, cfg.d_head)

    def fn(kv, slot, k, v):
        return (inject_slot(cfg, kv, slot, k, v),)

    return jax.jit(fn).lower(
        jax.ShapeDtypeStruct(cfg.kv_shape, jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct(plane, jnp.float32),
        jax.ShapeDtypeStruct(plane, jnp.float32),
    )


def build(cfg: ModelConfig, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    params = init_params(cfg)

    # params.bin — flat f32 concat in param_names() order.
    flat = np.concatenate([np.asarray(p, np.float32).ravel() for p in params])
    flat.tofile(os.path.join(out_dir, "params.bin"))

    artifacts = {}
    for chunk in cfg.chunk_buckets:
        name = f"prefill_c{chunk}"
        text = to_hlo_text(lower_prefill(cfg, chunk, len(params)))
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        artifacts[name] = {"file": f"{name}.hlo.txt", "chunk": chunk}
        print(f"  {name}: {len(text)} chars")

    for name, lowered in [
        ("decode", lower_decode(cfg)),
        ("extract_slot", lower_extract(cfg)),
        ("inject_slot", lower_inject(cfg)),
    ]:
        text = to_hlo_text(lowered)
        with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(text)
        artifacts[name] = {"file": f"{name}.hlo.txt"}
        print(f"  {name}: {len(text)} chars")

    shapes = cfg.param_shapes()
    manifest = {
        "model": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "d_head": cfg.d_head,
            "d_ff": cfg.d_ff,
            "max_seq": cfg.max_seq,
            "slots": cfg.slots,
            "seed": cfg.seed,
        },
        "chunk_buckets": list(cfg.chunk_buckets),
        "kv_shape": list(cfg.kv_shape),
        "params": [
            {"name": n, "shape": list(shapes[n])} for n in cfg.param_names()
        ],
        "params_bin": "params.bin",
        "artifacts": artifacts,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    cfg = ModelConfig()
    print(f"lowering model (vocab={cfg.vocab} d={cfg.d_model} L={cfg.n_layers}) ...")
    build(cfg, args.out)
    print(f"artifacts written to {args.out}")


if __name__ == "__main__":
    main()
