"""Layer-1 Pallas attention kernels for the serving instance's hot path.

Two kernels, mirroring what a PD-colocated vLLM-style engine executes:

* ``prefill_attention`` — chunked-prefill attention with KV-prefix reuse:
  the queries are the *new* tokens of the current chunk (everything before
  them was a KV$ hit or a previous chunk), the keys/values are the full
  cache. This is the op whose cost the LMetric scheduler's P-token
  indicator models: its work is proportional to the number of NEW prefill
  tokens, not the full prompt.

* ``decode_attention`` — batched single-token decode attention. Memory
  bound; its latency grows with batch size (the paper's Fig. 19b rationale
  for using BS as the decode-load indicator) but is nearly flat in context
  length for small batches.

Hardware adaptation (paper targets CUDA/H20; we target TPU-shaped Pallas):
instead of threadblock/shared-memory staging, the HBM->VMEM schedule is
expressed with a grid over (head, q-block) and an online-softmax
(flash-style) loop over 128-wide key blocks, so VMEM holds O(BLK) state and
the MXU sees [BLK_Q, D] x [D, BLK_K] matmuls. ``interpret=True`` everywhere:
the CPU PJRT plugin cannot run Mosaic custom-calls; real-TPU performance is
estimated analytically in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
BLK_K = 128  # key-block width: lane-dim aligned for the MXU/VPU
MAX_BLK_Q = 128  # query-block height cap


def _prefill_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, *, blk_q, blk_k, s):
    """Grid: (heads, n_q_blocks). Online softmax over key blocks."""
    qi = pl.program_id(1)
    pos = pos_ref[0]
    q = q_ref[0]  # [BLK_Q, D]
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    # Absolute positions of this q block's tokens.
    q_glob = pos + qi * blk_q + jax.lax.iota(jnp.int32, blk_q)

    n_k = s // blk_k

    def body(kb, carry):
        acc, m_i, l_i = carry
        kblk = k_ref[0, pl.ds(kb * blk_k, blk_k), :]
        vblk = v_ref[0, pl.ds(kb * blk_k, blk_k), :]
        logits = jnp.dot(q, kblk.T, preferred_element_type=jnp.float32) * scale
        k_glob = kb * blk_k + jax.lax.iota(jnp.int32, blk_k)
        mask = k_glob[None, :] <= q_glob[:, None]
        logits = jnp.where(mask, logits, NEG_INF)
        m_new = jnp.maximum(m_i, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[:, None])
        alpha = jnp.exp(m_i - m_new)
        l_new = l_i * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + jnp.dot(
            p, vblk, preferred_element_type=jnp.float32
        )
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((blk_q, d), jnp.float32)
    m0 = jnp.full((blk_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((blk_q,), jnp.float32)
    acc, _, l = jax.lax.fori_loop(0, n_k, body, (acc0, m0, l0))
    # Every query row attends at least to itself (its K/V is already in the
    # cache), so l > 0 for real rows; padding rows are harmless garbage.
    o_ref[0] = acc / jnp.maximum(l, 1e-30)[:, None]


def prefill_attention(q, k, v, pos):
    """Chunked-prefill attention with KV-prefix reuse (Pallas, interpret).

    Args:
      q: [H, C, D] queries of the new chunk (C = chunk bucket size).
      k: [H, S, D] key cache, chunk K already written at [pos, pos+C).
      v: [H, S, D] value cache.
      pos: scalar int32 — tokens already cached before this chunk
        (= KV$-hit prefix length + previously prefilled chunks).

    Returns:
      [H, C, D] chunk attention output.
    """
    h, c, d = q.shape
    s = k.shape[1]
    if s % BLK_K != 0:
        raise ValueError(f"cache len {s} must be a multiple of {BLK_K}")
    blk_q = min(c, MAX_BLK_Q)
    if c % blk_q != 0:
        raise ValueError(f"chunk {c} must be a multiple of {blk_q}")
    pos = jnp.asarray(pos, jnp.int32).reshape((1,))
    kernel = functools.partial(_prefill_kernel, blk_q=blk_q, blk_k=BLK_K, s=s)
    return pl.pallas_call(
        kernel,
        grid=(h, c // blk_q),
        in_specs=[
            pl.BlockSpec((1,), lambda hi, qi: (0,)),
            pl.BlockSpec((1, blk_q, d), lambda hi, qi: (hi, qi, 0)),
            pl.BlockSpec((1, s, d), lambda hi, qi: (hi, 0, 0)),
            pl.BlockSpec((1, s, d), lambda hi, qi: (hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, d), lambda hi, qi: (hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, c, d), jnp.float32),
        interpret=True,
    )(pos, q, k, v)


def _decode_kernel(lens_ref, q_ref, k_ref, v_ref, o_ref, *, blk_k, s):
    """Grid: (slots, heads). One query row; online softmax over key blocks."""
    b = pl.program_id(0)
    ln = lens_ref[b]
    q = q_ref[0, 0]  # [D]
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    n_k = s // blk_k

    def body(kb, carry):
        acc, m_i, l_i = carry
        kblk = k_ref[0, 0, pl.ds(kb * blk_k, blk_k), :]
        vblk = v_ref[0, 0, pl.ds(kb * blk_k, blk_k), :]
        logits = jnp.dot(kblk, q, preferred_element_type=jnp.float32) * scale
        k_glob = kb * blk_k + jax.lax.iota(jnp.int32, blk_k)
        logits = jnp.where(k_glob < ln, logits, NEG_INF)
        m_new = jnp.maximum(m_i, logits.max())
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(m_i - m_new)
        l_new = l_i * alpha + p.sum()
        acc_new = acc * alpha + jnp.dot(p, vblk, preferred_element_type=jnp.float32)
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((d,), jnp.float32)
    acc, _, l = jax.lax.fori_loop(0, n_k, body, (acc0, jnp.float32(NEG_INF), jnp.float32(0)))
    # Inactive slots (len == 0) have l == 0 -> output zeros.
    o_ref[0, 0] = jnp.where(ln > 0, acc / jnp.maximum(l, 1e-30), 0.0)


def decode_attention(q, k, v, lens):
    """Batched single-token decode attention (Pallas, interpret).

    Args:
      q: [B, H, D] one query per slot.
      k: [B, H, S, D] per-slot key cache (new token already at lens-1).
      v: [B, H, S, D] per-slot value cache.
      lens: [B] int32 valid KV length per slot (incl. new token); 0=inactive.

    Returns:
      [B, H, D] attention output, zeros for inactive slots.
    """
    b, h, d = q.shape
    s = k.shape[2]
    if s % BLK_K != 0:
        raise ValueError(f"cache len {s} must be a multiple of {BLK_K}")
    lens = jnp.asarray(lens, jnp.int32)
    kernel = functools.partial(_decode_kernel, blk_k=BLK_K, s=s)
    return pl.pallas_call(
        kernel,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((b,), lambda bi, hi: (0,)),
            pl.BlockSpec((1, 1, d), lambda bi, hi: (bi, hi, 0)),
            pl.BlockSpec((1, 1, s, d), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, s, d), lambda bi, hi: (bi, hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda bi, hi: (bi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), jnp.float32),
        interpret=True,
    )(lens, q, k, v)
