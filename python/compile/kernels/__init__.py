"""Layer-1 Pallas kernels (build-time only; lowered into the AOT HLO)."""

from .attention import decode_attention, prefill_attention  # noqa: F401
from .ref import decode_attention_ref, prefill_attention_ref  # noqa: F401
