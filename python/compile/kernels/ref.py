"""Pure-jnp reference oracle for the Pallas attention kernels.

These are the ground-truth implementations the Pallas kernels in
``attention.py`` are checked against (pytest + hypothesis). They use the
same masking semantics:

* ``prefill_attention_ref``: queries are the *new chunk* of ``chunk`` tokens
  that starts at absolute position ``pos`` (the KV$ cache already contains
  ``pos`` tokens AND the chunk's own K/V have been written at
  ``[pos, pos+chunk)``). Query ``i`` (absolute position ``pos+i``) attends
  to key positions ``j <= pos + i`` — i.e. the whole cached prefix plus the
  causal part of the chunk.

* ``decode_attention_ref``: a single query token per slot whose K/V has
  already been written at index ``len-1`` (``len`` = sequence length
  *including* the new token). The query attends to key positions
  ``j < len``. Inactive slots (``len == 0``) produce zeros.
"""

import jax.numpy as jnp

NEG_INF = -1e30


def prefill_attention_ref(q, k, v, pos):
    """Chunked-prefill attention with KV-prefix reuse.

    Args:
      q: [H, C, D] queries for the new chunk.
      k: [H, S, D] full key cache (prefix + chunk written at [pos, pos+C)).
      v: [H, S, D] full value cache.
      pos: scalar int — number of tokens already cached before this chunk.

    Returns:
      [H, C, D] attention output for the chunk.
    """
    h, c, d = q.shape
    s = k.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    logits = jnp.einsum("hcd,hsd->hcs", q, k) * scale
    q_pos = pos + jnp.arange(c)[:, None]  # [C,1] absolute position of query
    k_pos = jnp.arange(s)[None, :]  # [1,S]
    mask = k_pos <= q_pos  # causal over prefix+chunk
    logits = jnp.where(mask[None, :, :], logits, NEG_INF)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("hcs,hsd->hcd", p, v)


def decode_attention_ref(q, k, v, lens):
    """Batched single-token decode attention.

    Args:
      q: [B, H, D] one query per slot.
      k: [B, H, S, D] per-slot key cache (new token already at lens-1).
      v: [B, H, S, D] per-slot value cache.
      lens: [B] int32 — valid KV length per slot, 0 = inactive slot.

    Returns:
      [B, H, D] attention output (zeros for inactive slots).
    """
    b, h, d = q.shape
    s = k.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    logits = jnp.einsum("bhd,bhsd->bhs", q, k) * scale
    mask = jnp.arange(s)[None, :] < lens[:, None]  # [B,S]
    logits = jnp.where(mask[:, None, :], logits, NEG_INF)
    m = logits.max(axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    denom = p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhs,bhsd->bhd", p / jnp.maximum(denom, 1e-30), v)
    active = (lens > 0)[:, None, None]
    return jnp.where(active, out, 0.0)
