"""Shared pytest setup for python/tests.

* Make `compile.*` importable whether pytest runs from the repo root
  (`pytest python/tests`) or from python/ (`pytest tests`).
* If the real `hypothesis` package is absent (bare/offline environments),
  install the deterministic fallback shim so the property tests still run;
  CI installs the real package (python/requirements.txt) and never hits
  the shim. JAX-dependent modules self-skip via pytest.importorskip.
"""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

try:
    import hypothesis  # noqa: F401
except ImportError:
    from _hypothesis_fallback import install

    install()
