#!/usr/bin/env python3
"""CI gate: fail when router_throughput regresses >20% vs the committed baseline.

Usage: check_bench_regression.py CURRENT_JSON BASELINE_JSON

The committed baseline is BENCH_router_throughput.json at the repo root.
While the baseline carries "seeded": false (no toolchain-equipped run has
landed numbers yet), the gate runs in report-only mode: it prints the
fresh numbers and instructions for seeding, and exits 0. Once seeded, the
gate fails when any of these drops below 80% of its baseline:

  des_end_to_end.req_per_s
  scale_smoke.req_per_s
  scale_smoke.steps_per_s

(scale_smoke fields gate only when the seeded baseline carries non-null
values for them — report-only otherwise, matching how des_end_to_end was
armed.) The admit_radix_walks counters are reported for the artifact but
not gated: they are an exactness invariant (one fused radix walk per
admitted request) already asserted inside the bench binary itself.

The `guard` section (failure-condition guard counters: natural vs
shared-prefix-flood degenerate/inversion/mitigated counts) is likewise
report-only: legacy baselines without the section, and null-seeded
fields, never trip the gate. natural_mitigated is expected to read 0 —
the paper's "extremely rare in practice" claim — but it is enforced by
the tier-1 decision-replay test, not here.
"""

import json
import sys

THRESHOLD = 0.80  # fail below 80% of baseline (= >20% regression)

# (section, field, gated) — gated fields compare against the baseline;
# the rest are printed so the uploaded artifact/log carries them.
FIELDS = [
    ("des_end_to_end", "req_per_s", True),
    ("des_end_to_end", "steps_per_s", False),
    ("des_end_to_end", "admit_radix_walks", False),
    ("scale_smoke", "req_per_s", True),
    ("scale_smoke", "steps_per_s", True),
    ("scale_smoke", "admit_radix_walks", False),
    ("sweep", "speedup", False),
    ("sweep", "threads", False),
    ("guard", "natural_checks", False),
    ("guard", "natural_degenerate", False),
    ("guard", "natural_inversion", False),
    ("guard", "natural_mitigated", False),
    ("guard", "flood_checks", False),
    ("guard", "flood_degenerate", False),
    ("guard", "flood_inversion", False),
    ("guard", "flood_mitigated", False),
]


def get(doc, section, field):
    return (doc.get(section) or {}).get(field)


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    current_path, baseline_path = sys.argv[1], sys.argv[2]

    with open(current_path) as f:
        current = json.load(f)
    try:
        with open(baseline_path) as f:
            baseline = json.load(f)
    except FileNotFoundError:
        print(f"no committed baseline at {baseline_path}; skipping gate")
        return 0

    print("current router_throughput:")
    for section, field, _ in FIELDS:
        print(f"  {section}.{field} = {get(current, section, field)}")
    smoke = current.get("scale_smoke") or {}
    print(
        f"  scale_smoke: {smoke.get('requests')} requests @ "
        f"{smoke.get('instances')} instances in {smoke.get('wall_s')}s"
    )

    if not baseline.get("seeded", False):
        print(
            "\nbaseline is unseeded (report-only mode). To arm the gate, commit "
            "this run's JSON over BENCH_router_throughput.json with "
            '"seeded": true.'
        )
        return 0

    if current.get("quick_mode") != baseline.get("quick_mode"):
        print(
            "\nquick_mode mismatch between current run and baseline; "
            "numbers are not comparable — skipping gate"
        )
        return 0

    failed = False
    for section, field, gated in FIELDS:
        if not gated:
            continue
        base = get(baseline, section, field)
        cur = get(current, section, field)
        if not base:
            print(f"\n{section}.{field}: baseline unseeded for this field; report-only")
            continue
        if not cur:
            print(f"\nFAIL: {section}.{field} missing from current run")
            failed = True
            continue
        ratio = cur / base
        print(f"\n{section}.{field}: baseline {base:.1f}, current/baseline = {ratio:.3f}")
        if ratio < THRESHOLD:
            print(
                f"FAIL: {section}.{field} regressed "
                f">{(1 - THRESHOLD) * 100:.0f}% ({cur:.1f} vs {base:.1f})"
            )
            failed = True
    if failed:
        return 1
    print("OK: within regression budget")
    return 0


if __name__ == "__main__":
    sys.exit(main())
