#!/usr/bin/env python3
"""CI gate: fail when router_throughput regresses >20% vs the committed baseline.

Usage: check_bench_regression.py CURRENT_JSON BASELINE_JSON [--emit-seeded OUT]

The committed baseline is BENCH_router_throughput.json at the repo root.
While the baseline carries "seeded": false (no toolchain-equipped run has
landed numbers yet), the gate runs in report-only mode: it prints the
fresh numbers and instructions for seeding, and exits 0. Once seeded, the
gate fails when any of these drops below 80% of its baseline:

  des_end_to_end.req_per_s
  scale_smoke.req_per_s
  scale_smoke.steps_per_s
  sessions.req_per_s
  overload.goodput_at_capacity
  overload.goodput_overload_session_shed

(Fields beyond des_end_to_end gate only when the seeded baseline carries
non-null values for them — report-only otherwise, matching how
des_end_to_end was armed.) The admit_radix_walks counters are reported
for the artifact but not gated: they are an exactness invariant (one
fused radix walk per admitted request) already asserted inside the bench
binary itself.

The `guard` section (failure-condition guard counters: natural vs
shared-prefix-flood degenerate/inversion/mitigated counts) is likewise
report-only: legacy baselines without the section, and null-seeded
fields, never trip the gate. natural_mitigated is expected to read 0 —
the paper's "extremely rare in practice" claim — but it is enforced by
the tier-1 decision-replay test, not here. The `sessions` section
(closed-loop session replay) follows the same tolerate-then-gate shape:
baselines that predate it never trip the gate; once a seeded baseline
carries sessions.req_per_s, that one field gates and the affinity / hit
fields stay report-only (affinity_sticky == 1.0 is asserted inside the
bench itself).

The `overload` section (open-arrival admission control) gates the two
goodput ratios — at-capacity (0.8x, where shedding must be invisible and
goodput reads ~1.0) and past capacity under session-aware shedding. Both
are virtual-time quantities, deterministic run to run, so once a seeded
baseline carries them they gate like the throughput fields (legacy
baselines without the section stay report-only). The shed/orphan
counters are report-only: orphaned_turns == 0 is asserted inside the
bench itself.

The `fleet` section (lifecycle fault injection) gates
goodput_autoscaler — the overload trace replayed under the reactive
queue-depth autoscaler, a virtual-time ratio deterministic run to run —
with the usual tolerate-then-gate shape. goodput_static,
recovery_ttft_p99 (TTFT tail of requests arriving during the crash
outage window), requeue_rate and scale_ups are report-only: requeue
conservation (zero lost requests) is asserted inside the bench binary
itself.

The `engine_queue` section (within-instance scheduling) gates
ttft_p99_ratio_srpt — the coder-trace TTFT p99 under fcfs divided by
the p99 under srpt, both replayed under the lmetric router in virtual
time, so the ratio is deterministic run to run. It drops below baseline
when the decode-length predictor or the srpt ordering regresses (srpt
losing its tail win pushes the ratio toward 1). The raw p99s and the
ltr promotion count are report-only: conservation and exactly-once wait
sampling are asserted inside the bench binary, and the full-size
router x engine-queue grid with the mean-TTFT asserts lives in
fig81_engine_queue.

The `hetero` section (heterogeneous fleet + multi-model multiplexing)
gates goodput_ratio_fused_over_two_layer — the mixed h100/l40 fleet's
fused-vs-layered SLO-goodput ratio, a virtual-time quantity
deterministic run to run. It drops when the fused score stops pricing
cold swaps or hardware speed into the product (the ratio decays toward
1 or below). cold_model_loads and model_evictions are report-only:
cold_loads > 0 on the 4-model mix and the uniform-fleet byte-identity
degeneracy are asserted inside the bench binary itself.

The `router_scale` section (sharded concurrent data plane) gates the
single-router decision rate — the read path every run exercises — with
the same tolerate-then-gate shape: legacy baselines without the section,
or with it null-seeded, stay report-only. The R=2/R=4 rates and the
budget-64 snapshot-age p99 are report-only: multi-router speedup is too
runner-core-count-dependent to gate, and the staleness bound itself
(age ≤ budget) plus the budget-0 byte-identity are asserted inside the
bench binary.

--emit-seeded OUT writes the *current* run's JSON with "seeded": true to
OUT — but only after the checks ran AND passed, so a regressed or
corrupt run can never become the armed baseline (OUT may safely be the
baseline path itself: the comparison runs against the old contents
first). Gated throughput fields are recorded at SEED_HEADROOM (85%) of
the seeding run's measurement so a single fast runner can't lock in a
baseline that normal shared-runner variance fails. This is the one-step
way for CI to arm the gate from the first toolchain-equipped run on
main.
"""

import json
import sys

THRESHOLD = 0.80  # fail below 80% of baseline (= >20% regression)

# --emit-seeded records gated throughput fields at this fraction of the
# seeding run's measurement: one fast runner must not lock in a baseline
# that median shared-runner variance can't reach (the effective failure
# point becomes HEADROOM x THRESHOLD of the seeding run).
SEED_HEADROOM = 0.85

# (section, field, gated) — gated fields compare against the baseline;
# the rest are printed so the uploaded artifact/log carries them.
FIELDS = [
    ("des_end_to_end", "req_per_s", True),
    ("des_end_to_end", "steps_per_s", False),
    ("des_end_to_end", "admit_radix_walks", False),
    ("scale_smoke", "req_per_s", True),
    ("scale_smoke", "steps_per_s", True),
    ("scale_smoke", "admit_radix_walks", False),
    ("sweep", "speedup", False),
    ("sweep", "threads", False),
    ("guard", "natural_checks", False),
    ("guard", "natural_degenerate", False),
    ("guard", "natural_inversion", False),
    ("guard", "natural_mitigated", False),
    ("guard", "flood_checks", False),
    ("guard", "flood_degenerate", False),
    ("guard", "flood_inversion", False),
    ("guard", "flood_mitigated", False),
    ("sessions", "turns", False),
    ("sessions", "req_per_s", True),
    ("sessions", "affinity_lmetric", False),
    ("sessions", "affinity_sticky", False),
    ("sessions", "turn0_hit", False),
    ("sessions", "late_turn_hit", False),
    ("overload", "goodput_at_capacity", True),
    ("overload", "goodput_overload_session_shed", True),
    ("overload", "goodput_overload_admit_all", False),
    ("overload", "shed_overload", False),
    ("overload", "orphaned_turns", False),
    ("router_scale", "decisions_per_s_r1", True),
    ("router_scale", "decisions_per_s_r2", False),
    ("router_scale", "decisions_per_s_r4", False),
    ("router_scale", "snapshot_age_p99", False),
    ("fleet", "goodput_autoscaler", True),
    ("fleet", "goodput_static", False),
    ("fleet", "recovery_ttft_p99", False),
    ("fleet", "requeue_rate", False),
    ("fleet", "scale_ups", False),
    ("engine_queue", "ttft_p99_fcfs", False),
    ("engine_queue", "ttft_p99_srpt", False),
    ("engine_queue", "ttft_p99_ltr", False),
    ("engine_queue", "ttft_p99_ratio_srpt", True),
    ("engine_queue", "promotions_ltr", False),
    ("hetero", "goodput_ratio_fused_over_two_layer", True),
    ("hetero", "cold_model_loads", False),
    ("hetero", "model_evictions", False),
]


def get(doc, section, field):
    return (doc.get(section) or {}).get(field)


def main() -> int:
    args = list(sys.argv[1:])
    emit_seeded = None
    if "--emit-seeded" in args:
        i = args.index("--emit-seeded")
        try:
            emit_seeded = args[i + 1]
        except IndexError:
            print(__doc__)
            return 2
        del args[i : i + 2]
    if len(args) != 2:
        print(__doc__)
        return 2
    current_path, baseline_path = args

    with open(current_path) as f:
        current = json.load(f)

    try:
        with open(baseline_path) as f:
            baseline = json.load(f)
    except FileNotFoundError:
        baseline = None

    def write_seeded():
        # Only reached on a passing run (every failure path returns before
        # its caller), so a regressed/corrupt run can never become the
        # armed baseline — even when OUT is the baseline path itself, the
        # comparison above already ran against the *old* file contents.
        if not emit_seeded:
            return
        missing = [
            f"{s}.{f}" for s, f, gated in FIELDS if gated and not get(current, s, f)
        ]
        if missing:
            print(
                "refusing to seed: current run is missing gated fields "
                f"({', '.join(missing)}) — a bench sub-stage did not report"
            )
            return
        seeded_doc = json.loads(json.dumps(current))  # deep copy
        seeded_doc["seeded"] = True
        # Shared-runner wall-clock variance routinely approaches the gate's
        # 20% budget, and the seeding run is a single unvetted sample. Seed
        # the gated fields at a discount so the effective trip point is
        # (headroom x threshold) of the seeding run's throughput — a
        # median-speed runner stays green, a real regression still trips.
        seeded_doc["seed_headroom"] = SEED_HEADROOM
        for s, f, gated in FIELDS:
            if gated:
                seeded_doc[s][f] = get(current, s, f) * SEED_HEADROOM
        # Carry the committed baseline's schema note forward, so seeding
        # does not strip the documentation from the repo-root file.
        note = (baseline or {}).get("note")
        if note:
            seeded_doc["note"] = note
        with open(emit_seeded, "w") as f:
            json.dump(seeded_doc, f, indent=2)
            f.write("\n")
        print(f"wrote seeded baseline candidate to {emit_seeded}")

    if baseline is None:
        print(f"no committed baseline at {baseline_path}; skipping gate")
        write_seeded()
        return 0

    print("current router_throughput:")
    for section, field, _ in FIELDS:
        print(f"  {section}.{field} = {get(current, section, field)}")
    smoke = current.get("scale_smoke") or {}
    print(
        f"  scale_smoke: {smoke.get('requests')} requests @ "
        f"{smoke.get('instances')} instances in {smoke.get('wall_s')}s"
    )

    if not baseline.get("seeded", False):
        print(
            "\nbaseline is unseeded (report-only mode). To arm the gate, commit "
            "this run's JSON over BENCH_router_throughput.json with "
            '"seeded": true.'
        )
        write_seeded()
        return 0

    if current.get("quick_mode") != baseline.get("quick_mode"):
        print(
            "\nquick_mode mismatch between current run and baseline; "
            "numbers are not comparable — skipping gate"
        )
        return 0

    failed = False
    for section, field, gated in FIELDS:
        if not gated:
            continue
        base = get(baseline, section, field)
        cur = get(current, section, field)
        if not base:
            print(f"\n{section}.{field}: baseline unseeded for this field; report-only")
            continue
        if not cur:
            print(f"\nFAIL: {section}.{field} missing from current run")
            failed = True
            continue
        ratio = cur / base
        print(f"\n{section}.{field}: baseline {base:.1f}, current/baseline = {ratio:.3f}")
        if ratio < THRESHOLD:
            print(
                f"FAIL: {section}.{field} regressed "
                f">{(1 - THRESHOLD) * 100:.0f}% ({cur:.1f} vs {base:.1f})"
            )
            failed = True
    if failed:
        return 1
    print("OK: within regression budget")
    write_seeded()
    return 0


if __name__ == "__main__":
    sys.exit(main())
