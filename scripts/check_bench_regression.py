#!/usr/bin/env python3
"""CI gate: fail when router_throughput regresses >20% vs the committed baseline.

Usage: check_bench_regression.py CURRENT_JSON BASELINE_JSON

The committed baseline is BENCH_router_throughput.json at the repo root.
While the baseline carries "seeded": false (no toolchain-equipped run has
landed numbers yet), the gate runs in report-only mode: it prints the
fresh numbers and instructions for seeding, and exits 0. Once seeded, a
current des_end_to_end.req_per_s below 80% of the baseline fails the job.
"""

import json
import sys

THRESHOLD = 0.80  # fail below 80% of baseline req/s (= >20% regression)


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    current_path, baseline_path = sys.argv[1], sys.argv[2]

    with open(current_path) as f:
        current = json.load(f)
    try:
        with open(baseline_path) as f:
            baseline = json.load(f)
    except FileNotFoundError:
        print(f"no committed baseline at {baseline_path}; skipping gate")
        return 0

    cur_rps = (current.get("des_end_to_end") or {}).get("req_per_s")
    print("current router_throughput:")
    print(f"  des_end_to_end.req_per_s = {cur_rps}")
    smoke = current.get("scale_smoke") or {}
    print(
        f"  scale_smoke: {smoke.get('requests')} requests @ "
        f"{smoke.get('instances')} instances in {smoke.get('wall_s')}s "
        f"({smoke.get('req_per_s')} req/s)"
    )

    if not baseline.get("seeded", False):
        print(
            "\nbaseline is unseeded (report-only mode). To arm the gate, commit "
            "this run's JSON over BENCH_router_throughput.json with "
            '"seeded": true.'
        )
        return 0

    if current.get("quick_mode") != baseline.get("quick_mode"):
        print(
            "\nquick_mode mismatch between current run and baseline; "
            "numbers are not comparable — skipping gate"
        )
        return 0

    base_rps = (baseline.get("des_end_to_end") or {}).get("req_per_s")
    if not base_rps or not cur_rps:
        print("\nmissing req_per_s on one side; skipping gate")
        return 0

    ratio = cur_rps / base_rps
    print(f"\nbaseline req_per_s = {base_rps:.1f}; current/baseline = {ratio:.3f}")
    if ratio < THRESHOLD:
        print(
            f"FAIL: router_throughput regressed >{(1 - THRESHOLD) * 100:.0f}% "
            f"({cur_rps:.1f} vs {base_rps:.1f} req/s)"
        )
        return 1
    print("OK: within regression budget")
    return 0


if __name__ == "__main__":
    sys.exit(main())
