//! Fig 5-style characterization of the four synthetic workload families:
//! arrival rates, token distributions, and infinite-KV$ hit rates.
//!
//!     cargo run --release --example trace_explorer

use lmetric::trace::{generate, Workload, WorkloadSpec};
use lmetric::util::stats::{percentile, Summary};

fn main() {
    println!(
        "{:<10} {:>8} {:>9} {:>16} {:>16} {:>10} {:>8}",
        "workload", "requests", "req/s", "input p50/p95", "output p50/p95", "inf-KV$hit", "classes"
    );
    for w in [
        Workload::ChatBot,
        Workload::Coder,
        Workload::Agent,
        Workload::ToolAgent,
        Workload::Hotspot,
    ] {
        let t = generate(&WorkloadSpec::preset(w, 4000, 42));
        let mut inputs: Vec<f64> = t.requests.iter().map(|r| r.req.input_len() as f64).collect();
        let mut outputs: Vec<f64> = t.requests.iter().map(|r| r.req.output_len as f64).collect();
        inputs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        outputs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let classes: std::collections::BTreeSet<u32> =
            t.requests.iter().map(|r| r.req.class_id).collect();
        println!(
            "{:<10} {:>8} {:>9.2} {:>7.0} / {:>6.0} {:>7.0} / {:>6.0} {:>9.1}% {:>8}",
            t.name,
            t.requests.len(),
            t.steady_rps(),
            percentile(&inputs, 0.5),
            percentile(&inputs, 0.95),
            percentile(&outputs, 0.5),
            percentile(&outputs, 0.95),
            t.infinite_cache_hit_rate() * 100.0,
            classes.len()
        );
        let _ = Summary::of(&inputs); // full summaries available if needed
    }
    println!("\n(compare against the paper's Fig 5: ChatBot moderate prompts &");
    println!(" long outputs; Coder long prompts; Agent short bursty requests;");
    println!(" ToolAgent growing agent context with short outputs.)");
}
