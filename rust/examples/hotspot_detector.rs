//! The §5.2 adversarial case (Fig 21): a KV$-hotspot workload where the
//! bare multiplicative score breaks, and the two-phase detector repairs
//! it. Prints the per-minute popularity/coverage ratios (Fig 21a) and
//! the TTFT/TPOT comparison against a load-balance-only policy (Fig 21b-c).
//!
//!     cargo run --release --example hotspot_detector

use lmetric::cluster::{build_scaled_trace, cluster_config, run_des};
use lmetric::config::ExperimentConfig;
use lmetric::hotspot::HotspotGuarded;
use lmetric::metrics::{render_table, ResultRow};
use lmetric::policy;
use lmetric::util::stats::Windowed;

fn main() {
    let mut exp = ExperimentConfig::default();
    exp.workload = "hotspot".into();
    exp.requests = 4000;
    exp.instances = 8;
    let trace = build_scaled_trace(&exp);
    let cfg = cluster_config(&exp);
    let hot_class = 12u32; // one past the normal classes (see synth.rs)

    // Fig 21a: hot-class arrival share per minute.
    let mut share = Windowed::new(60_000_000);
    for tr in &trace.requests {
        share.add(
            tr.req.arrival_us,
            if tr.req.class_id == hot_class { 1.0 } else { 0.0 },
        );
    }
    println!("hot-class share per minute (Fig 21a pattern):");
    for (i, s) in share.means().iter().enumerate() {
        if !s.is_nan() {
            let bar = "#".repeat((s * 40.0) as usize);
            println!("  min {i:>3}: {:>5.1}% {bar}", s * 100.0);
        }
    }

    let profile = cfg.engine.profile.clone();
    let mut rows = Vec::new();
    for name in ["vllm", "lmetric"] {
        let mut pol = policy::build_default(name, &profile, exp.chunk_budget).unwrap();
        let m = run_des(&cfg, &trace, pol.as_mut());
        rows.push(
            ResultRow::from_metrics(&pol.name(), &m).with("imbalance_s", m.imbalance_score()),
        );
    }
    // Guarded run, keeping detector counters.
    let mut guarded = HotspotGuarded::new();
    let m = run_des(&cfg, &trace, &mut guarded);
    println!(
        "\ndetector: {} phase-1 alarms, {} mitigations",
        guarded.detector.phase1_alarms, guarded.detector.mitigations
    );
    rows.push(
        ResultRow::from_metrics("lmetric_guarded", &m).with("imbalance_s", m.imbalance_score()),
    );
    println!(
        "{}",
        render_table("adversarial hotspot workload (Fig 21b-c)", &rows)
    );
}
