//! End-to-end validation driver (EXPERIMENTS.md §E2E): serve a real
//! (small) transformer on a live threaded cluster — Pallas kernels →
//! JAX model → AOT HLO artifacts → rust PJRT runtime → chunked-prefill /
//! batched-decode engines with a working cross-request KV$ → the same
//! router + policies the DES uses — and report wall-clock TTFT / TPOT /
//! throughput for LMETRIC vs the load-balancing-only vLLM policy.
//!
//!     make artifacts && cargo run --release --example e2e_serving

use lmetric::cluster::live::{run_live, LiveClusterConfig};
use lmetric::metrics::{render_table, ResultRow};
use lmetric::policy;
use lmetric::trace::{generate, Workload, WorkloadSpec};

fn main() {
    // A ChatBot-shaped workload sized to the artifact model
    // (vocab 1024, max_seq 512): multi-turn sessions with shared system
    // prompts, so the live KV$ (extract/inject) path really fires.
    let n_requests = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(32);
    let mut spec = WorkloadSpec::preset(Workload::ChatBot, n_requests, 11);
    spec.vocab = 1023;
    spec.sys_prompt_median = 96.0;
    spec.user_span_median = 24.0;
    spec.output_median = 8.0;
    spec.output_sigma = 0.4;
    spec.max_input = 384;
    spec.mean_turns = 3.0;
    // Paced so think-time (after x8 compression) still exceeds service
    // time — turn k+1 must arrive after turn k's KV$ is cached, as in a
    // real conversation.
    spec.turn_gap_s = 40.0;
    spec.session_rate = 0.15;
    spec.n_classes = 4;
    let trace = generate(&spec);
    let (mean_in, mean_out) = trace.token_stats();
    println!(
        "live workload: {} requests, {:.0} in / {:.0} out tokens, {} classes",
        trace.requests.len(),
        mean_in,
        mean_out,
        trace
            .requests
            .iter()
            .map(|r| r.req.class_id)
            .collect::<std::collections::BTreeSet<_>>()
            .len()
    );

    let cfg = LiveClusterConfig {
        n_instances: 2,
        time_scale: 8.0, // compress trace think-time for the demo
        ..Default::default()
    };
    let profile = lmetric::engine::ModelProfile::moe_30b();

    let mut rows = Vec::new();
    for name in ["vllm", "lmetric"] {
        let mut pol = policy::build_default(name, &profile, 256).unwrap();
        println!("serving under {} on {} PJRT instances ...", pol.name(), cfg.n_instances);
        match run_live(&cfg, &trace, pol.as_mut()) {
            Ok(m) => {
                println!(
                    "  -> {} completions, {:.1} output tok/s, mean KV$ hit {:.1}%",
                    m.records.len(),
                    m.output_throughput(),
                    m.mean_hit_ratio() * 100.0
                );
                rows.push(
                    ResultRow::from_metrics(&pol.name(), &m)
                        .with("output_tok_per_s", m.output_throughput()),
                );
            }
            Err(e) => {
                eprintln!("live run failed: {e:#}\n(run `make artifacts` first)");
                std::process::exit(1);
            }
        }
    }
    println!(
        "{}",
        render_table("E2E live serving (wall clock, real PJRT transformer)", &rows)
    );
    println!("All layers composed: Pallas kernel -> JAX model -> HLO text ->");
    println!("PJRT runtime -> live engines (KV$ inject/extract) -> LMETRIC router.");
}
