//! Every policy on every workload family — the §6 evaluation matrix in
//! one command (a compact form of the fig22/fig23 benches).
//!
//!     cargo run --release --example policy_comparison [requests]

use lmetric::cluster::{build_scaled_trace, cluster_config, run_des};
use lmetric::config::ExperimentConfig;
use lmetric::metrics::{render_table, ResultRow};
use lmetric::policy;

fn main() {
    let requests = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(3000);
    let profile = lmetric::engine::ModelProfile::moe_30b();
    for workload in ["chatbot", "coder", "agent", "toolagent"] {
        let mut exp = ExperimentConfig::default();
        exp.workload = workload.into();
        exp.requests = requests;
        exp.instances = 8;
        let trace = build_scaled_trace(&exp);
        let cfg = cluster_config(&exp);
        let mut rows = Vec::new();
        for name in ["vllm", "linear", "dynamo", "filter_kv", "sim_llmd", "preble", "lmetric"] {
            let mut pol = policy::build_default(name, &profile, exp.chunk_budget).unwrap();
            let mut m = run_des(&cfg, &trace, pol.as_mut());
            m.discard_warmup(0.1);
            rows.push(ResultRow::from_metrics(&pol.name(), &m));
        }
        println!(
            "{}",
            render_table(
                &format!(
                    "{workload} — {} reqs @ {:.1} req/s on {} instances",
                    trace.requests.len(),
                    trace.steady_rps(),
                    exp.instances
                ),
                &rows
            )
        );
    }
}
