//! Quickstart: route a ChatBot workload through an 8-instance cluster
//! with the paper's multiplicative policy, in a dozen lines.
//!
//!     cargo run --release --example quickstart

use lmetric::cluster::{build_scaled_trace, cluster_config, run_des};
use lmetric::config::ExperimentConfig;
use lmetric::metrics::{render_table, ResultRow};
use lmetric::policy::LMetric;

fn main() {
    // 1. Describe the experiment (defaults: 16×moe-30b, chatbot, half of
    //    profiled capacity — the paper's §6 setup).
    let mut exp = ExperimentConfig::default();
    exp.instances = 8;
    exp.requests = 2000;

    // 2. Build the workload (synthetic trace fitted to the paper's Fig 5
    //    ChatBot characteristics, rate-scaled to the cluster).
    let trace = build_scaled_trace(&exp);
    println!(
        "trace: {} requests, steady rate {:.1} req/s, mean input {:.0} tokens",
        trace.requests.len(),
        trace.steady_rps(),
        trace.token_stats().0,
    );

    // 3. Route it with LMETRIC: score = P-token × (BS + 1), no tuning.
    let mut policy = LMetric::paper();
    let mut metrics = run_des(&cluster_config(&exp), &trace, &mut policy);
    metrics.discard_warmup(0.1);

    // 4. Read the results.
    let row = ResultRow::from_metrics("lmetric", &metrics)
        .with("output_tok_per_s", metrics.output_throughput());
    println!("{}", render_table("quickstart: chatbot / 8×moe-30b", &[row]));
    println!(
        "scheduling overhead: mean {:.1} µs/decision over {} decisions",
        metrics.sched_overhead_us.iter().sum::<f64>()
            / metrics.sched_overhead_us.len().max(1) as f64,
        metrics.sched_overhead_us.len()
    );
}
