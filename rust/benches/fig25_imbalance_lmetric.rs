//! Fig 25: workload imbalance of LMETRIC vs llm-d (the second-best
//! ChatBot policy): prefill seconds per 10-s window on the two most
//! divergent instances.
//!
//! Paper shape: LMETRIC better balanced than llm-d.

use lmetric::benchlib::{experiment, figure_banner, run_default, trace_for};
use lmetric::metrics::{save_results, ResultRow};

fn main() {
    figure_banner("Fig 25", "imbalance: LMETRIC vs llm-d (ChatBot)");
    let mut exp = experiment("chatbot", 8, 5000);
    exp.rate_scale = 0.6;
    let trace = trace_for(&exp);
    let mut rows = Vec::new();
    let mut scores = std::collections::BTreeMap::new();
    for name in ["sim_llmd", "lmetric"] {
        let (m, label) = run_default(&exp, &trace, name);
        let (ia, a, ib, b) = m.top2_imbalanced_instances().unwrap();
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
        println!(
            "{label:<22} divergent inst {ia}/{ib}: mean prefill {:.2}s vs {:.2}s, |gap| {:.3}s",
            mean(&a),
            mean(&b),
            m.imbalance_score()
        );
        scores.insert(name, m.imbalance_score());
        rows.push(ResultRow::from_metrics(&label, &m).with("imbalance_s", m.imbalance_score()));
    }
    let ratio = scores["lmetric"] / scores["sim_llmd"].max(1e-9);
    println!(
        "\nshape check: LMETRIC at least as balanced as llm-d (ratio {:.2} ≤ 1.25): {}",
        ratio,
        if ratio <= 1.25 { "YES" } else { "NO" }
    );
    println!(
        "note: the paper's llm-d imbalance came from simulator misprediction under\n\
         production load; our tuned simulator predicts the analytic engine almost\n\
         exactly, so both policies stay well balanced here (gaps are sub-second\n\
         per 10-s window for both — compare Fig 10's multi-second gaps at λ=0.9)."
    );
    let path = save_results("fig25_imbalance_lmetric", &rows, &[]).unwrap();
    println!("saved {}", path.display());
}
