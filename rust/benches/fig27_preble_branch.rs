//! Fig 27: Preble's KV$-aware branch selection rate as its filter
//! threshold T varies (ChatBot, moe-30b).
//!
//! Paper shape: the branch rate falls as T rises; at the default T=0.5
//! Preble takes the linear fallback most of the time — which is why it
//! performs like a linear-combination policy (§6.2).

use lmetric::benchlib::{experiment, figure_banner, run_boxed, trace_for};
use lmetric::metrics::{save_results, ResultRow};
use lmetric::policy::Preble;

fn main() {
    figure_banner("Fig 27", "Preble KV$-branch selection rate vs T");
    let exp = experiment("chatbot", 8, 4000);
    let trace = trace_for(&exp);
    let mut rows = Vec::new();
    println!("{:>6} {:>14} {:>12}", "T", "KV$-branch", "TTFT-mean");
    let mut prev = 1.1;
    let mut monotone = true;
    let mut rate_at_default = 1.0;
    for t in [0.1, 0.3, 0.5, 0.7, 0.9] {
        let mut pol = Preble::new(t);
        let m = run_boxed(&exp, &trace, &mut pol);
        let rate = pol.kv_branch_rate();
        println!(
            "{t:>6.1} {:>13.1}% {:>12}",
            rate * 100.0,
            lmetric::metrics::fmt_s(m.ttft_summary().mean)
        );
        if rate > prev + 0.02 {
            monotone = false;
        }
        prev = rate;
        if t == 0.5 {
            rate_at_default = rate;
        }
        rows.push(
            ResultRow::from_metrics(&format!("T={t}"), &m).with("kv_branch_rate", rate),
        );
    }
    println!(
        "\nshape check: branch rate non-increasing in T: {}",
        if monotone { "YES (matches paper)" } else { "NO" }
    );
    println!(
        "note: KV$-branch rate at T=0.5 is {:.0}% here vs a minority share in the\n\
         paper — our synthetic ChatBot shares a larger prompt fraction (system\n\
         prompt + full history) than the production trace, so the hit filter\n\
         clears its threshold more often. The paper's downstream conclusion —\n\
         lowering T does not help because it sacrifices load balancing — still\n\
         reproduces (see T=0.1's TTFT above).",
        rate_at_default * 100.0
    );
    let path = save_results("fig27_preble_branch", &rows, &[]).unwrap();
    println!("saved {}", path.display());
}
