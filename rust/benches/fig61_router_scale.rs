//! Fig 61: router-scale sweep over the sharded concurrent data plane.
//!
//! Two questions, two parts:
//!
//! **Part A — read-path scaling.** With the index sharded and the factory
//! score path lock-free (`IndicatorFactory::fill_route_ctx` takes `&self`),
//! R router workers can score decisions against one pinned factory view in
//! parallel. We warm a factory at 256 / 1024 / 4096 instances with a
//! chatbot prefix population, then measure raw decision throughput
//! (context fill + policy scoring, no commits) at R ∈ {1, 2, 4, 8}.
//! At ≥ 1024 instances a decision is dominated by the O(n_instances)
//! indicator build, so throughput must rise essentially monotonically
//! R = 1 → 4 whenever the host actually has ≥ 4 cores — asserted.
//!
//! **Part B — what staleness costs.** The full concurrent DES
//! ([`run_concurrent`]) replays one chatbot trace on 16 instances at
//! R ∈ {1, 4} under staleness budgets {0, 64, 512}. Budget 0 is asserted
//! record-for-record identical to the serial [`run_des`] — the refactor's
//! zero-cost anchor — and larger budgets chart TTFT / KV$-affinity
//! degradation as decisions commit against increasingly stale views.

use lmetric::benchlib::{decision_rate, figure_banner, scaled};
use lmetric::cluster::{build_scaled_trace, cluster_config, run_concurrent, run_des, ConcurrentCfg};
use lmetric::config::ExperimentConfig;
use lmetric::engine::ModelProfile;
use lmetric::metrics::{fmt_s, save_results, ResultRow};
use lmetric::policy;
use lmetric::router::IndicatorFactory;
use lmetric::trace::{generate, Workload, WorkloadSpec};
use lmetric::util::stats::Summary;

const PART_A_INSTANCES: [usize; 3] = [256, 1024, 4096];
const ROUTERS: [usize; 4] = [1, 2, 4, 8];
const BUDGETS: [usize; 3] = [0, 64, 512];

fn main() {
    figure_banner(
        "Fig 61",
        "router scaling on the sharded data plane: decisions/s vs R, staleness vs quality",
    );
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("host parallelism: {cores}");

    // --- Part A: read-path decision throughput --------------------------
    let mut rows: Vec<ResultRow> = Vec::new();
    println!("\n## Part A: decision throughput (read-only scoring, no commits)");
    println!(
        "{:<12} {:>10} {:>14} {:>14} {:>14} {:>14}",
        "instances", "probes", "R=1", "R=2", "R=4", "R=8"
    );
    for &n_inst in &PART_A_INSTANCES {
        let spec = WorkloadSpec::preset(Workload::ChatBot, scaled(6000), 61);
        let trace = generate(&spec);
        let profile = ModelProfile::moe_30b();
        // Warm: commit a prefix population through the serial path so
        // probe walks traverse a realistic radix (hits + misses).
        let mut factory = IndicatorFactory::new(n_inst, 8192);
        let warm = trace.requests.len() / 2;
        for (i, tr) in trace.requests.iter().take(warm).enumerate() {
            factory.route_ctx(&tr.req, tr.req.arrival_us);
            factory.on_route(i % n_inst, &tr.req, tr.req.arrival_us);
        }
        // Probe set shrinks with n_inst: one decision is O(n_inst), so
        // this keeps each (n, R) cell at roughly constant wall time.
        let n_probes = (512_000 / n_inst).clamp(50, trace.requests.len() - warm);
        let probes = &trace.requests[warm..warm + n_probes];

        let rates: Vec<f64> = ROUTERS
            .iter()
            .map(|&r| decision_rate(&factory, &profile, probes, r))
            .collect();
        println!(
            "{:<12} {:>10} {:>12.0}/s {:>12.0}/s {:>12.0}/s {:>12.0}/s",
            n_inst, n_probes, rates[0], rates[1], rates[2], rates[3]
        );
        for (&r, &rate) in ROUTERS.iter().zip(&rates) {
            rows.push(ResultRow {
                label: format!("throughput_n{n_inst}_r{r}"),
                ttft: Summary::of(&[]),
                tpot: Summary::of(&[]),
                hit_ratio: f64::NAN,
                extra: [("decisions_per_s".to_string(), rate)].into_iter().collect(),
            });
        }
        // The refactor's headline claim: at ≥ 1024 instances the scoring
        // loop dominates and extra routers buy real throughput. Gated on
        // the host actually having the cores to show it.
        if n_inst >= 1024 && cores >= 4 {
            assert!(
                rates[1] >= rates[0] * 0.9,
                "R=2 must not regress vs R=1 at {n_inst} instances ({} vs {})",
                rates[1],
                rates[0]
            );
            assert!(
                rates[2] >= rates[1] * 0.9,
                "R=4 must not regress vs R=2 at {n_inst} instances ({} vs {})",
                rates[2],
                rates[1]
            );
            assert!(
                rates[2] >= rates[0] * 1.25,
                "R=4 must scale ≥1.25x over R=1 at {n_inst} instances ({} vs {})",
                rates[2],
                rates[0]
            );
        }
    }

    // --- Part B: staleness budget vs decision quality -------------------
    println!("\n## Part B: staleness budget sweep (16 instances, chatbot, lmetric)");
    let mut exp = ExperimentConfig::default();
    exp.workload = "chatbot".into();
    exp.instances = 16;
    exp.requests = scaled(4000);
    let cfg = cluster_config(&exp);
    let profile = cfg.engine.profile.clone();
    let trace = build_scaled_trace(&exp);

    let mut serial_pol = policy::build_default("lmetric", &profile, exp.chunk_budget).unwrap();
    let serial = run_des(&cfg, &trace, serial_pol.as_mut());
    println!(
        "serial        TTFT {:>8}  hit {:>5.1}%  ({} records)",
        fmt_s(serial.ttft_summary().mean),
        serial.mean_hit_ratio() * 100.0,
        serial.records.len()
    );

    for &r in &[1usize, 4] {
        for &budget in &BUDGETS {
            let mut mk = || policy::build_default("lmetric", &profile, exp.chunk_budget).unwrap();
            let m = run_concurrent(&cfg, &trace, &mut mk, &ConcurrentCfg::new(r, budget));
            let age = m.snapshot_age_summary();
            println!(
                "R={r} budget={budget:<4} TTFT {:>8}  hit {:>5.1}%  age p99 {:>6.1}  \
                 decisions/s {:>10.0}",
                fmt_s(m.ttft_summary().mean),
                m.mean_hit_ratio() * 100.0,
                age.p99,
                m.decision_throughput()
            );
            assert_eq!(
                m.records.len(),
                serial.records.len(),
                "every request must complete at R={r} budget={budget}"
            );
            if budget == 0 {
                // Zero staleness ⇒ the concurrent core IS the serial core.
                for (a, b) in serial.records.iter().zip(&m.records) {
                    assert_eq!(
                        (a.id, a.instance, a.first_token_us, a.completion_us, a.cached_tokens),
                        (b.id, b.instance, b.first_token_us, b.completion_us, b.cached_tokens),
                        "budget-0 run must be byte-identical to run_des at R={r}"
                    );
                }
            }
            rows.push(
                ResultRow::from_metrics(&format!("stale_r{r}_b{budget}"), &m)
                    .with("routers", r as f64)
                    .with("staleness_budget", budget as f64)
                    .with("snapshot_age_mean", age.mean)
                    .with("snapshot_age_p99", age.p99)
                    .with("decisions_per_s", m.decision_throughput())
                    .with(
                        "ttft_delta_vs_serial",
                        m.ttft_summary().mean - serial.ttft_summary().mean,
                    ),
            );
        }
    }

    let path = save_results("fig61_router_scale", &rows, &[]).unwrap();
    println!("\nsaved {}", path.display());
}
