//! §3's framework claim: the Rust router's per-decision cost. The paper
//! reports its Rust reimplementation is 6.2× faster than vLLM's Python
//! router and 1.2× faster than AIBrix's Go one; here we measure absolute
//! µs/decision per policy at 16 / 64 / 256 instances (one shared-index
//! walk + borrowed scratch context per decision — the allocation-free hot
//! path), the DES harness's end-to-end routed-requests/s, and a
//! 32-instance × 50k-request DES scale smoke.
//!
//! The JSON this bench writes is the perf-trajectory record: CI compares
//! `des_end_to_end.req_per_s` against the committed baseline
//! (`BENCH_router_throughput.json`) and fails on a >20% regression.

use lmetric::benchlib::{bench, figure_banner, scaled};
use lmetric::engine::ModelProfile;
use lmetric::policy;
use lmetric::router::IndicatorFactory;
use lmetric::trace::{generate, Workload, WorkloadSpec};
use lmetric::util::json::Json;

fn main() {
    figure_banner("§3", "router scheduling-decision throughput (Rust framework)");
    let trace = generate(&WorkloadSpec::preset(Workload::ChatBot, scaled(2000), 42));
    let profile = ModelProfile::moe_30b();
    let mut json_rows: Vec<Json> = Vec::new();

    for n_instances in [16usize, 64, 256] {
        println!("\n--- {n_instances} instances ---");
        for name in ["vllm", "linear", "filter_kv", "preble", "sim_llmd", "lmetric"] {
            let mut pol = policy::build_default(name, &profile, 256).unwrap();
            let mut factory = IndicatorFactory::new(n_instances, 8192);
            // Pre-warm the shared KV index with some traffic.
            let warm = trace.requests.len() / 4;
            for tr in trace.requests.iter().take(warm) {
                let ctx = factory.route_ctx(&tr.req, tr.req.arrival_us);
                let d = pol.route(ctx);
                factory.on_route(d.instance, &tr.req, tr.req.arrival_us);
            }
            let mut idx = warm;
            let reqs = &trace.requests;
            let r = bench(&format!("{name} @ {n_instances} inst"), 1000, || {
                let tr = &reqs[idx % reqs.len()];
                let ctx = factory.route_ctx(&tr.req, tr.req.arrival_us);
                let d = pol.route(ctx);
                factory.on_route(d.instance, &tr.req, tr.req.arrival_us);
                idx += 1;
            });
            println!("{}", r.report());
            json_rows.push(Json::obj(vec![
                ("policy", Json::Str(name.to_string())),
                ("instances", Json::Num(n_instances as f64)),
                ("iters", Json::Num(r.iters as f64)),
                ("mean_ns", Json::Num(r.mean_ns)),
                ("p50_ns", Json::Num(r.p50_ns)),
                ("p99_ns", Json::Num(r.p99_ns)),
            ]));
        }
    }

    // End-to-end DES throughput (how fast the whole harness replays).
    println!("\n--- DES harness end-to-end ---");
    let mut exp = lmetric::config::ExperimentConfig::default();
    exp.instances = 16;
    exp.requests = scaled(2000);
    let trace = lmetric::cluster::build_scaled_trace(&exp);
    let cfg = lmetric::cluster::cluster_config(&exp);
    let t0 = std::time::Instant::now();
    let mut pol = policy::build_default("lmetric", &profile, 256).unwrap();
    let m = lmetric::cluster::run_des(&cfg, &trace, pol.as_mut());
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "replayed {} requests ({:.0}s virtual) in {:.2}s wall = {:.0} req/s, {:.0}x real-time",
        m.records.len(),
        m.duration_us as f64 / 1e6,
        wall,
        m.records.len() as f64 / wall,
        (m.duration_us as f64 / 1e6) / wall
    );

    // Scale smoke: 32 instances × 50k requests through the DES under
    // lmetric. Fixed size (NOT downscaled in quick mode) — this is the
    // CI proof that the shared-index router data plane holds up at
    // production-shaped scale inside the bench-smoke time budget.
    println!("\n--- scale smoke: 32 instances x 50k requests ---");
    let mut sexp = lmetric::config::ExperimentConfig::default();
    sexp.instances = 32;
    sexp.requests = 50_000;
    let strace = lmetric::cluster::build_scaled_trace(&sexp);
    let scfg = lmetric::cluster::cluster_config(&sexp);
    let t0 = std::time::Instant::now();
    let mut spol = policy::build_default("lmetric", &profile, 256).unwrap();
    let sm = lmetric::cluster::run_des(&scfg, &strace, spol.as_mut());
    let swall = t0.elapsed().as_secs_f64();
    assert_eq!(
        sm.records.len(),
        strace.requests.len(),
        "scale smoke lost requests"
    );
    println!(
        "replayed {} requests on 32 instances in {:.2}s wall = {:.0} req/s (mean hit ratio {:.3})",
        sm.records.len(),
        swall,
        sm.records.len() as f64 / swall.max(1e-9),
        sm.mean_hit_ratio()
    );

    // Machine-readable output: CI uploads this as the perf-trajectory
    // record and gates on it (BENCH_router_throughput.json is the
    // committed baseline; override the output path with
    // LMETRIC_BENCH_JSON).
    let doc = Json::obj(vec![
        ("bench", Json::Str("router_throughput".into())),
        ("quick_mode", Json::Bool(lmetric::benchlib::quick_mode())),
        ("decisions", Json::Arr(json_rows)),
        (
            "des_end_to_end",
            Json::obj(vec![
                ("requests", Json::Num(m.records.len() as f64)),
                ("virtual_s", Json::Num(m.duration_us as f64 / 1e6)),
                ("wall_s", Json::Num(wall)),
                ("req_per_s", Json::Num(m.records.len() as f64 / wall.max(1e-9))),
            ]),
        ),
        (
            "scale_smoke",
            Json::obj(vec![
                ("instances", Json::Num(32.0)),
                ("requests", Json::Num(sm.records.len() as f64)),
                ("wall_s", Json::Num(swall)),
                (
                    "req_per_s",
                    Json::Num(sm.records.len() as f64 / swall.max(1e-9)),
                ),
            ]),
        ),
    ]);
    let path = std::env::var("LMETRIC_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_router_throughput.json".to_string());
    std::fs::write(&path, doc.to_string()).expect("write bench json");
    println!("wrote {path}");
}
