//! §3's framework claim: the Rust router's per-decision cost. The paper
//! reports its Rust reimplementation is 6.2× faster than vLLM's Python
//! router and 1.2× faster than AIBrix's Go one; here we measure absolute
//! µs/decision per policy at 16 / 64 / 256 instances (one shared-index
//! walk + borrowed scratch context per decision — the allocation-free hot
//! path), the DES harness's end-to-end routed-requests/s, a 32-instance ×
//! 50k-request DES scale smoke, the concurrent data plane's decisions/s
//! at R ∈ {1, 2, 4} routers (plus its budget-0 byte-identity check and
//! budget-64 snapshot-age tail), the fleet-lifecycle stage (a crash /
//! recover replay's requeue conservation and recovery tail, and the
//! overload trace on a static fleet vs the reactive autoscaler), the
//! engine-queue stage (the coder trace at 0.95x capacity under fcfs /
//! srpt / ltr within-instance scheduling — the TTFT-tail record the
//! fcfs/srpt ratio gate holds), the heterogeneous-fleet stage (a mixed
//! h100/l40 fleet multiplexing 4 models, fused placement+balance vs the
//! two-layer baseline — the fused/two-layer goodput ratio gates), and
//! the parallel sweep harness's speedup over serial execution.
//!
//! The JSON this bench writes is the perf-trajectory record: CI compares
//! `des_end_to_end.req_per_s` (and, once seeded, the scale-smoke req/s
//! and steps/s) against the committed baseline
//! (`BENCH_router_throughput.json`) and fails on a >20% regression. The
//! `admit_radix_walks` counters prove the engine's fused admission: one
//! radix walk per admitted request.

use lmetric::benchlib::{
    bench, bench_threads, decision_rate, figure_banner, parallel_sweep, scaled,
};
use lmetric::cluster::{run_concurrent, ConcurrentCfg};
use lmetric::engine::ModelProfile;
use lmetric::policy;
use lmetric::router::IndicatorFactory;
use lmetric::trace::{generate, Workload, WorkloadSpec};
use lmetric::util::json::Json;

fn main() {
    figure_banner("§3", "router scheduling-decision throughput (Rust framework)");
    let trace = generate(&WorkloadSpec::preset(Workload::ChatBot, scaled(2000), 42));
    let profile = ModelProfile::moe_30b();
    let mut json_rows: Vec<Json> = Vec::new();

    // Decision microbenches stay strictly serial: co-running timed
    // iterations would contaminate each other's numbers.
    for n_instances in [16usize, 64, 256] {
        println!("\n--- {n_instances} instances ---");
        for name in ["vllm", "linear", "filter_kv", "preble", "sim_llmd", "lmetric"] {
            let mut pol = policy::build_default(name, &profile, 256).unwrap();
            let mut factory = IndicatorFactory::new(n_instances, 8192);
            // Pre-warm the shared KV index with some traffic.
            let warm = trace.requests.len() / 4;
            for tr in trace.requests.iter().take(warm) {
                let ctx = factory.route_ctx(&tr.req, tr.req.arrival_us);
                let d = pol.route(ctx);
                factory.on_route(d.instance, &tr.req, tr.req.arrival_us);
            }
            let mut idx = warm;
            let reqs = &trace.requests;
            let r = bench(&format!("{name} @ {n_instances} inst"), 1000, || {
                let tr = &reqs[idx % reqs.len()];
                let ctx = factory.route_ctx(&tr.req, tr.req.arrival_us);
                let d = pol.route(ctx);
                factory.on_route(d.instance, &tr.req, tr.req.arrival_us);
                idx += 1;
            });
            println!("{}", r.report());
            json_rows.push(Json::obj(vec![
                ("policy", Json::Str(name.to_string())),
                ("instances", Json::Num(n_instances as f64)),
                ("iters", Json::Num(r.iters as f64)),
                ("mean_ns", Json::Num(r.mean_ns)),
                ("p50_ns", Json::Num(r.p50_ns)),
                ("p99_ns", Json::Num(r.p99_ns)),
            ]));
        }
    }

    // End-to-end DES throughput (how fast the whole harness replays).
    println!("\n--- DES harness end-to-end ---");
    let mut exp = lmetric::config::ExperimentConfig::default();
    exp.instances = 16;
    exp.requests = scaled(2000);
    let trace = lmetric::cluster::build_scaled_trace(&exp);
    let cfg = lmetric::cluster::cluster_config(&exp);
    let t0 = std::time::Instant::now();
    let mut pol = policy::build_default("lmetric", &profile, 256).unwrap();
    let m = lmetric::cluster::run_des(&cfg, &trace, pol.as_mut());
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(
        m.admit_radix_walks,
        m.records.len() as u64,
        "fused admission: exactly one radix walk per request"
    );
    println!(
        "replayed {} requests ({:.0}s virtual) in {:.2}s wall = {:.0} req/s, \
         {:.0} steps/s, {:.0}x real-time",
        m.records.len(),
        m.duration_us as f64 / 1e6,
        wall,
        m.records.len() as f64 / wall,
        m.total_steps as f64 / wall.max(1e-9),
        (m.duration_us as f64 / 1e6) / wall
    );

    // Scale smoke: 32 instances × 50k requests through the DES under
    // lmetric. Fixed size (NOT downscaled in quick mode) — this is the
    // CI proof that the shared-index router data plane and the
    // allocation-free engine hot path hold up at production-shaped scale
    // inside the bench-smoke time budget.
    println!("\n--- scale smoke: 32 instances x 50k requests ---");
    let mut sexp = lmetric::config::ExperimentConfig::default();
    sexp.instances = 32;
    sexp.requests = 50_000;
    let strace = lmetric::cluster::build_scaled_trace(&sexp);
    let scfg = lmetric::cluster::cluster_config(&sexp);
    let t0 = std::time::Instant::now();
    let mut spol = policy::build_default("lmetric", &profile, 256).unwrap();
    let sm = lmetric::cluster::run_des(&scfg, &strace, spol.as_mut());
    let swall = t0.elapsed().as_secs_f64();
    assert_eq!(
        sm.records.len(),
        strace.requests.len(),
        "scale smoke lost requests"
    );
    assert_eq!(
        sm.admit_radix_walks,
        sm.records.len() as u64,
        "fused admission at scale: one radix walk per request"
    );
    println!(
        "replayed {} requests on 32 instances in {:.2}s wall = {:.0} req/s, \
         {:.0} steps/s (mean hit ratio {:.3}, {} admit walks)",
        sm.records.len(),
        swall,
        sm.records.len() as f64 / swall.max(1e-9),
        sm.total_steps as f64 / swall.max(1e-9),
        sm.mean_hit_ratio(),
        sm.admit_radix_walks
    );

    // Failure-condition guard counters: the same natural chatbot replay
    // under the guarded policy (the "extremely rare in practice" record
    // — natural_mitigated should stay 0), plus an adversarial
    // shared-prefix flood where the degenerate detector must fire.
    println!("\n--- failure-condition guard ---");
    let mut gpol = lmetric::policy::GuardedLMetric::new();
    let gm = lmetric::cluster::run_des(&cfg, &trace, &mut gpol);
    let natural = gm.guard;
    println!(
        "natural chatbot : checks {} degenerate {} inversion {} mitigated {}",
        natural.checks, natural.degenerate, natural.inversion, natural.mitigated
    );
    let fspec = lmetric::trace::AdversarialSpec::preset(
        lmetric::trace::AdversarialScenario::SharedPrefixFlood,
        scaled(1600),
        5,
    );
    let ftrace = lmetric::trace::generate_adversarial(&fspec);
    let mut fpol = lmetric::policy::GuardedLMetric::new();
    let fm = lmetric::cluster::run_des(&cfg, &ftrace, &mut fpol);
    let flood = fm.guard;
    assert_eq!(flood.checks, ftrace.requests.len() as u64);
    assert!(flood.degenerate > 0, "flood must trip the degenerate detector");
    println!(
        "prefix flood    : checks {} degenerate {} inversion {} mitigated {}",
        flood.checks, flood.degenerate, flood.inversion, flood.mitigated
    );

    // Closed-loop sessions: the reactive DES path (turn k+1 released at
    // turn k's completion + think time) replayed under plain lmetric and
    // under explicit session pinning. Records the closed-loop replay
    // rate plus the affinity/prefix-reuse headline numbers ("P-token
    // captures affinity for free") for the perf-trajectory JSON.
    println!("\n--- closed-loop sessions (chat archetype) ---");
    let ses_spec = lmetric::trace::SessionSpec::preset(
        lmetric::trace::SessionKind::Chat,
        scaled(2000),
        42,
    );
    let ses_trace = lmetric::cluster::build_scaled_sessions(&ses_spec, &cfg, 0.5);
    let t0 = std::time::Instant::now();
    let mut ses_pol = policy::build_default("lmetric", &profile, 256).unwrap();
    let ses_m = lmetric::cluster::run_session_des(&cfg, &ses_trace, ses_pol.as_mut());
    let ses_wall = t0.elapsed().as_secs_f64();
    assert_eq!(
        ses_m.records.len(),
        ses_trace.n_turns(),
        "closed loop lost session turns"
    );
    let ses_sm = lmetric::metrics::SessionMetrics::collect(&ses_m, &ses_trace);
    let mut sticky_pol = policy::build_default("sticky", &profile, 256).unwrap();
    let sticky_m = lmetric::cluster::run_session_des(&cfg, &ses_trace, sticky_pol.as_mut());
    let sticky_sm = lmetric::metrics::SessionMetrics::collect(&sticky_m, &ses_trace);
    assert!(
        (sticky_sm.affinity_ratio() - 1.0).abs() < 1e-12,
        "sticky affinity must be 1.0 by construction"
    );
    println!(
        "{} sessions / {} turns in {:.2}s wall = {:.0} turns/s; affinity \
         lmetric {:.1}% vs sticky {:.1}%; hit turn0 {:.1}% -> warm {:.1}%",
        ses_trace.sessions.len(),
        ses_m.records.len(),
        ses_wall,
        ses_m.records.len() as f64 / ses_wall.max(1e-9),
        ses_sm.affinity_ratio() * 100.0,
        sticky_sm.affinity_ratio() * 100.0,
        ses_sm.turn0_hit() * 100.0,
        ses_sm.late_turn_hit() * 100.0
    );

    // Overload control: goodput on an open mixed-archetype trace at
    // 0.8x (at capacity) and 1.2x (past it), admit_all vs session-aware
    // shedding. All virtual-time quantities — byte-stable run to run, so
    // the regression gate can hold the at-capacity goodput. Thresholds
    // and SLO are derived from an at-capacity probe (2x the peak depth,
    // 3x the worst request), so the 0.8x point sheds nothing by
    // construction and its goodput is exactly the SLO attainment.
    println!("\n--- overload control (open arrivals) ---");
    let ospec = lmetric::trace::OpenSpec::new(
        lmetric::trace::RateProgram::constant(10.0, 120.0),
        51,
    )
    .with_cap(scaled(2000));
    let under = lmetric::cluster::build_scaled_open(&ospec, &cfg, 0.8);
    let over = lmetric::cluster::build_scaled_open(&ospec, &cfg, 1.2);
    let mut probe = lmetric::cluster::QueueDepthShed::new(usize::MAX);
    let mut opol = policy::build_default("lmetric", &profile, 256).unwrap();
    let m_probe = lmetric::cluster::run(
        lmetric::cluster::RunSpec::sessions(&cfg, &under)
            .with_admission(Box::new(&mut probe)),
        opol.as_mut(),
    );
    assert_eq!(m_probe.overload.shed, 0, "probe must not shed");
    let worst_ttft = m_probe.ttfts().iter().copied().fold(0.0, f64::max);
    let worst_tpot = m_probe.tpots().iter().copied().fold(0.0, f64::max);
    let slo = lmetric::metrics::SloSpec::new(
        3.0 * worst_ttft.max(1e-3),
        3.0 * worst_tpot.max(1e-3),
    );
    let depth_thr = (2 * probe.peak_min_depth).max(8);
    let mk_sess_shed = || -> Box<dyn lmetric::cluster::AdmissionPolicy> {
        let inner = lmetric::cluster::QueueDepthShed::new(depth_thr);
        Box::new(lmetric::cluster::SessionAwareShed::new(Box::new(inner)))
    };
    let run_admitted = |strace: &lmetric::trace::SessionTrace,
                        adm: Box<dyn lmetric::cluster::AdmissionPolicy>| {
        let mut p = policy::build_default("lmetric", &profile, 256).unwrap();
        lmetric::cluster::run(
            lmetric::cluster::RunSpec::sessions(&cfg, strace)
                .with_admission(adm)
                .with_slo(slo),
            p.as_mut(),
        )
    };
    let m_under = run_admitted(&under, mk_sess_shed());
    let m_over_all = run_admitted(&over, Box::new(lmetric::cluster::AdmitAll));
    let m_over_sess = run_admitted(&over, mk_sess_shed());
    assert_eq!(m_under.overload.shed, 0, "derived threshold must not shed at 0.8x");
    assert!(
        m_under.goodput_ratio(slo) >= 0.99,
        "at-capacity goodput {} must be >= 99%",
        m_under.goodput_ratio(slo)
    );
    assert_eq!(
        m_over_sess.overload.orphaned_turns, 0,
        "session-aware shedding must never orphan turns"
    );
    println!(
        "0.8x session_shed: goodput {:.1}%; 1.2x admit_all {:.1}% vs session_shed \
         {:.1}% (shed {} of {}, {} orphans)",
        m_under.goodput_ratio(slo) * 100.0,
        m_over_all.goodput_ratio(slo) * 100.0,
        m_over_sess.goodput_ratio(slo) * 100.0,
        m_over_sess.overload.shed,
        m_over_sess.overload.offered,
        m_over_sess.overload.orphaned_turns
    );

    // Parallel sweep harness: K independent DES runs serial vs fanned
    // out over scoped threads. Results must be identical (virtual time is
    // deterministic); only wall-clock may differ — that ratio is the
    // recorded harness speedup.
    println!("\n--- parallel sweep harness ---");
    let sweep_jobs: Vec<&str> = vec!["vllm", "linear", "dynamo", "sim_llmd", "lmetric"];
    let mut jexp = lmetric::config::ExperimentConfig::default();
    jexp.instances = 8;
    jexp.requests = scaled(2000);
    let jtrace = lmetric::cluster::build_scaled_trace(&jexp);
    let jcfg = lmetric::cluster::cluster_config(&jexp);
    let run_job = |name: &str| {
        let mut p = policy::build_default(name, &profile, 256).unwrap();
        lmetric::cluster::run_des(&jcfg, &jtrace, p.as_mut())
    };
    let t0 = std::time::Instant::now();
    let serial: Vec<_> = sweep_jobs.iter().map(|name| run_job(name)).collect();
    let serial_wall = t0.elapsed().as_secs_f64();
    let t0 = std::time::Instant::now();
    let parallel = parallel_sweep(&sweep_jobs, |_, name| run_job(name));
    let parallel_wall = t0.elapsed().as_secs_f64();
    for (s, p) in serial.iter().zip(&parallel) {
        assert_eq!(s.records.len(), p.records.len(), "sweep determinism");
        for (a, b) in s.records.iter().zip(&p.records) {
            assert_eq!(
                (a.id, a.instance, a.completion_us),
                (b.id, b.instance, b.completion_us),
                "parallel sweep must replay identically to serial"
            );
        }
    }
    let speedup = serial_wall / parallel_wall.max(1e-9);
    println!(
        "{} DES runs: serial {:.2}s, parallel {:.2}s on {} threads = {:.2}x \
         (results identical)",
        sweep_jobs.len(),
        serial_wall,
        parallel_wall,
        bench_threads(),
        speedup
    );

    // Router scale: the sharded data plane's concurrent read path. R
    // workers score a pinned 256-instance factory in parallel (decisions
    // per second at R ∈ {1, 2, 4}), then the concurrent DES replays the
    // end-to-end trace — budget 0 asserted byte-identical to the serial
    // run above, budget 64 recording the snapshot-age tail the staleness
    // bound promises.
    println!("\n--- router scale (concurrent data plane) ---");
    let mut rs_factory = IndicatorFactory::new(256, 8192);
    let mut rs_warm_pol = policy::build_default("lmetric", &profile, 256).unwrap();
    let rs_warm = trace.requests.len() / 2;
    for tr in trace.requests.iter().take(rs_warm) {
        let ctx = rs_factory.route_ctx(&tr.req, tr.req.arrival_us);
        let d = rs_warm_pol.route(ctx);
        rs_factory.on_route(d.instance, &tr.req, tr.req.arrival_us);
    }
    let rs_probes = &trace.requests[rs_warm..];
    let rs_rates: Vec<f64> = [1usize, 2, 4]
        .iter()
        .map(|&r| decision_rate(&rs_factory, &profile, rs_probes, r))
        .collect();
    println!(
        "256 instances, {} probes: R=1 {:.0}/s  R=2 {:.0}/s  R=4 {:.0}/s",
        rs_probes.len(),
        rs_rates[0],
        rs_rates[1],
        rs_rates[2]
    );
    let mut mk_rs = || policy::build_default("lmetric", &profile, 256).unwrap();
    let m_b0 = run_concurrent(&cfg, &trace, &mut mk_rs, &ConcurrentCfg::new(2, 0));
    assert_eq!(m_b0.records.len(), m.records.len());
    for (a, b) in m.records.iter().zip(&m_b0.records) {
        assert_eq!(
            (a.id, a.instance, a.completion_us),
            (b.id, b.instance, b.completion_us),
            "budget-0 concurrent replay must be byte-identical to run_des"
        );
    }
    let m_b64 = run_concurrent(&cfg, &trace, &mut mk_rs, &ConcurrentCfg::new(2, 64));
    assert_eq!(m_b64.records.len(), m.records.len(), "budget-64 lost requests");
    let rs_age = m_b64.snapshot_age_summary();
    println!(
        "concurrent DES R=2: budget 0 identical to serial; budget 64 snapshot age \
         mean {:.2} p99 {:.1} ({:.0} decisions/s in-DES)",
        rs_age.mean,
        rs_age.p99,
        m_b64.decision_throughput()
    );

    // Fleet lifecycle: one crash/recover replay on the closed-loop
    // session trace (recovery-window TTFT tail + requeue rate), then
    // the 1.2x overload trace on a static fleet vs the reactive
    // queue-depth autoscaler (goodput under the probe-derived SLO
    // above). All virtual-time quantities — deterministic run to run —
    // so goodput_autoscaler gates once seeded. fig71_fleet_dynamics is
    // the full-size version with the cross-policy degradation asserts.
    println!("\n--- fleet lifecycle (crash recovery + autoscaler) ---");
    let fl_crash_at = ses_m.duration_us / 4;
    let fl_recover_at = ses_m.duration_us / 2;
    let fl_plan = lmetric::cluster::FaultPlan::new()
        .crash_at(fl_crash_at, 1)
        .recover_at(fl_recover_at, 1);
    let mut fl_pol = policy::build_default("lmetric", &profile, 256).unwrap();
    let fl_m = lmetric::cluster::run(
        lmetric::cluster::RunSpec::sessions(&cfg, &ses_trace).with_faults(fl_plan),
        fl_pol.as_mut(),
    );
    assert_eq!(fl_m.fault.crashes, 1, "crash must fire");
    assert_eq!(fl_m.fault.lost, 0, "fault injection must not lose requests");
    assert_eq!(
        fl_m.records.len(),
        ses_trace.n_turns(),
        "requeue conservation: every displaced turn completes"
    );
    let mut fl_window: Vec<f64> = fl_m
        .records
        .iter()
        .filter(|r| r.arrival_us >= fl_crash_at && r.arrival_us < fl_recover_at)
        .map(|r| r.ttft_s())
        .collect();
    fl_window.sort_by(|a, b| a.total_cmp(b));
    let recovery_ttft_p99 = if fl_window.is_empty() {
        f64::NAN
    } else {
        fl_window[(fl_window.len() * 99 / 100).min(fl_window.len() - 1)]
    };
    let requeue_rate = fl_m.fault.requeued as f64 / fl_m.records.len() as f64;
    let mut fs_pol = policy::build_default("lmetric", &profile, 256).unwrap();
    let fl_static = lmetric::cluster::run(
        lmetric::cluster::RunSpec::sessions(&cfg, &over).with_slo(slo),
        fs_pol.as_mut(),
    );
    let mut fa_pol = policy::build_default("lmetric", &profile, 256).unwrap();
    let fl_auto = lmetric::cluster::run(
        lmetric::cluster::RunSpec::sessions(&cfg, &over)
            .with_slo(slo)
            .with_autoscaler(
                Box::new(
                    lmetric::cluster::QueueDepthAutoscaler::new(
                        4.0,
                        1.0,
                        exp.instances,
                        exp.instances * 2,
                    )
                    .with_cooldown(2_000_000),
                ),
                1_000_000,
            ),
        fa_pol.as_mut(),
    );
    assert_eq!(
        fl_static.fault.lost + fl_auto.fault.lost,
        0,
        "overload lifecycle must not lose requests"
    );
    let goodput_static = fl_static.goodput_ratio(slo);
    let goodput_auto = fl_auto.goodput_ratio(slo);
    println!(
        "crash-window TTFT p99 {recovery_ttft_p99:.3}s, requeue rate {requeue_rate:.4}; \
         1.2x goodput static {:.1}% vs autoscaled {:.1}% ({} scale-ups, {} drains)",
        goodput_static * 100.0,
        goodput_auto * 100.0,
        fl_auto.fault.scale_ups,
        fl_auto.fault.drains
    );

    // Engine queue: within-instance scheduling under the lmetric router
    // on the long-tail coder trace at 0.95x capacity with small batches
    // (the deep-queue regime). Records the TTFT tail under fcfs / srpt /
    // ltr; the gated field is the p99 ratio fcfs/srpt — a virtual-time
    // quantity, deterministic run to run, that drops if the predictor or
    // the srpt ordering regresses. fig81_engine_queue is the full-size
    // router x engine-queue grid with the mean-TTFT asserts.
    println!("\n--- engine queue (within-instance scheduling) ---");
    let mut qexp = lmetric::config::ExperimentConfig::default();
    qexp.instances = 4;
    qexp.requests = scaled(1200);
    qexp.workload = "coder".into();
    qexp.rate_scale = 0.95;
    qexp.max_batch = 8;
    let qtrace = lmetric::cluster::build_scaled_trace(&qexp);
    let qcfg = lmetric::cluster::cluster_config(&qexp);
    let qnames: [&str; 3] = ["fcfs", "srpt", "ltr"];
    let q_runs = parallel_sweep(&qnames, |_, qp| {
        let mut p = policy::build_default("lmetric", &profile, 256).unwrap();
        lmetric::cluster::run(
            lmetric::cluster::RunSpec::open_loop(&qcfg, &qtrace).with_queue_policy(qp),
            p.as_mut(),
        )
    });
    for (qp, qm) in qnames.iter().zip(&q_runs) {
        assert_eq!(qm.records.len(), qtrace.requests.len(), "{qp}: reordering lost requests");
        assert_eq!(qm.total_stalled_steps(), 0, "{qp}: stalled steps");
        let samples: u64 = qm.queue.iter().map(|q| q.wait_samples).sum();
        assert_eq!(
            samples,
            qtrace.requests.len() as u64,
            "{qp}: every admission wait-sampled exactly once"
        );
    }
    let q_p99: Vec<f64> = q_runs.iter().map(|qm| qm.ttft_summary().p99).collect();
    let q_ratio_srpt = q_p99[0] / q_p99[1].max(1e-9);
    println!(
        "coder 0.95x under lmetric: TTFT p99 fcfs {:.4}s srpt {:.4}s ltr {:.4}s \
         (fcfs/srpt {:.3}); ltr promotions {}",
        q_p99[0],
        q_p99[1],
        q_p99[2],
        q_ratio_srpt,
        q_runs[2].total_promotions()
    );

    // Heterogeneous fleet: a mixed h100/l40 fleet multiplexing 4 models,
    // fused placement+balance vs the two-layer baseline. The gated field
    // is the fused/two-layer goodput ratio — virtual-time, deterministic
    // run to run, and it collapses if the fused score stops pricing the
    // swap (or the cost-aware P-time stops pricing the hardware).
    // fig91_hetero_fleet is the full-size version with the uniform
    // degeneracy asserts.
    println!("\n--- heterogeneous fleet (fused vs two-layer) ---");
    let mut hexp = lmetric::config::ExperimentConfig::default();
    hexp.requests = scaled(1200);
    hexp.n_models = 4;
    hexp.rate_scale = 0.6;
    hexp.fleet = Some(
        lmetric::config::FleetSpec::empty()
            .with_class(lmetric::engine::InstanceProfile::h100(), 1)
            .with_class(lmetric::engine::InstanceProfile::l40(), 3),
    );
    hexp.instances = 4;
    let htrace = lmetric::cluster::build_scaled_trace(&hexp);
    let hcfg = lmetric::cluster::cluster_config(&hexp);
    let mut hprobe_exp = hexp.clone();
    hprobe_exp.rate_scale = 0.25;
    hprobe_exp.requests = scaled(600);
    let hprobe_trace = lmetric::cluster::build_scaled_trace(&hprobe_exp);
    let mut hprobe_pol = policy::build_default("lmetric_fused", &profile, 256).unwrap();
    let hm_probe = lmetric::cluster::run(
        lmetric::cluster::RunSpec::open_loop(&hcfg, &hprobe_trace),
        hprobe_pol.as_mut(),
    );
    let h_worst_ttft = hm_probe.ttfts().iter().copied().fold(0.0, f64::max);
    let h_worst_tpot = hm_probe.tpots().iter().copied().fold(0.0, f64::max);
    let hslo = lmetric::metrics::SloSpec::new(
        3.0 * h_worst_ttft.max(1e-3),
        3.0 * h_worst_tpot.max(1e-3),
    );
    let hnames: [&str; 2] = ["lmetric_fused", "place_then_balance"];
    let h_runs = parallel_sweep(&hnames, |_, name| {
        let mut p = policy::build_default(name, &profile, 256).unwrap();
        lmetric::cluster::run(
            lmetric::cluster::RunSpec::open_loop(&hcfg, &htrace).with_slo(hslo),
            p.as_mut(),
        )
    });
    for (name, hm) in hnames.iter().zip(&h_runs) {
        assert_eq!(hm.records.len(), htrace.requests.len(), "{name}: hetero lost requests");
        assert!(hm.models.cold_loads > 0, "{name}: multiplexing must pay cold loads");
    }
    let h_fused = h_runs[0].goodput_ratio(hslo);
    let h_layered = h_runs[1].goodput_ratio(hslo);
    let h_ratio = h_fused / h_layered.max(1e-9);
    println!(
        "h100:1+l40:3, 4 models at 0.6x: goodput fused {:.1}% vs two-layer {:.1}% \
         (ratio {:.3}); cold loads fused {} vs layered {}",
        h_fused * 100.0,
        h_layered * 100.0,
        h_ratio,
        h_runs[0].models.cold_loads,
        h_runs[1].models.cold_loads
    );

    // Machine-readable output: CI uploads this as the perf-trajectory
    // record and gates on it (BENCH_router_throughput.json is the
    // committed baseline; override the output path with
    // LMETRIC_BENCH_JSON).
    let doc = Json::obj(vec![
        ("bench", Json::Str("router_throughput".into())),
        ("quick_mode", Json::Bool(lmetric::benchlib::quick_mode())),
        ("decisions", Json::Arr(json_rows)),
        (
            "des_end_to_end",
            Json::obj(vec![
                ("requests", Json::Num(m.records.len() as f64)),
                ("virtual_s", Json::Num(m.duration_us as f64 / 1e6)),
                ("wall_s", Json::Num(wall)),
                ("req_per_s", Json::Num(m.records.len() as f64 / wall.max(1e-9))),
                (
                    "steps_per_s",
                    Json::Num(m.total_steps as f64 / wall.max(1e-9)),
                ),
                ("admit_radix_walks", Json::Num(m.admit_radix_walks as f64)),
            ]),
        ),
        (
            "scale_smoke",
            Json::obj(vec![
                ("instances", Json::Num(32.0)),
                ("requests", Json::Num(sm.records.len() as f64)),
                ("wall_s", Json::Num(swall)),
                (
                    "req_per_s",
                    Json::Num(sm.records.len() as f64 / swall.max(1e-9)),
                ),
                (
                    "steps_per_s",
                    Json::Num(sm.total_steps as f64 / swall.max(1e-9)),
                ),
                ("admit_radix_walks", Json::Num(sm.admit_radix_walks as f64)),
            ]),
        ),
        (
            "guard",
            Json::obj(vec![
                ("natural_checks", Json::Num(natural.checks as f64)),
                ("natural_degenerate", Json::Num(natural.degenerate as f64)),
                ("natural_inversion", Json::Num(natural.inversion as f64)),
                ("natural_mitigated", Json::Num(natural.mitigated as f64)),
                ("flood_checks", Json::Num(flood.checks as f64)),
                ("flood_degenerate", Json::Num(flood.degenerate as f64)),
                ("flood_inversion", Json::Num(flood.inversion as f64)),
                ("flood_mitigated", Json::Num(flood.mitigated as f64)),
            ]),
        ),
        (
            "sessions",
            Json::obj(vec![
                ("sessions", Json::Num(ses_trace.sessions.len() as f64)),
                ("turns", Json::Num(ses_m.records.len() as f64)),
                ("wall_s", Json::Num(ses_wall)),
                (
                    "req_per_s",
                    Json::Num(ses_m.records.len() as f64 / ses_wall.max(1e-9)),
                ),
                ("affinity_lmetric", Json::Num(ses_sm.affinity_ratio())),
                ("affinity_sticky", Json::Num(sticky_sm.affinity_ratio())),
                ("turn0_hit", Json::Num(ses_sm.turn0_hit())),
                ("late_turn_hit", Json::Num(ses_sm.late_turn_hit())),
            ]),
        ),
        (
            "overload",
            Json::obj(vec![
                ("slo_ttft_s", Json::Num(slo.ttft_s)),
                ("slo_tpot_s", Json::Num(slo.tpot_s)),
                ("depth_threshold", Json::Num(depth_thr as f64)),
                (
                    "goodput_at_capacity",
                    Json::Num(m_under.goodput_ratio(slo)),
                ),
                (
                    "goodput_overload_admit_all",
                    Json::Num(m_over_all.goodput_ratio(slo)),
                ),
                (
                    "goodput_overload_session_shed",
                    Json::Num(m_over_sess.goodput_ratio(slo)),
                ),
                (
                    "shed_overload",
                    Json::Num(m_over_sess.overload.shed as f64),
                ),
                (
                    "orphaned_turns",
                    Json::Num(m_over_sess.overload.orphaned_turns as f64),
                ),
            ]),
        ),
        (
            "router_scale",
            Json::obj(vec![
                ("instances", Json::Num(256.0)),
                ("probes", Json::Num(rs_probes.len() as f64)),
                ("routers_max", Json::Num(4.0)),
                ("decisions_per_s_r1", Json::Num(rs_rates[0])),
                ("decisions_per_s_r2", Json::Num(rs_rates[1])),
                ("decisions_per_s_r4", Json::Num(rs_rates[2])),
                ("snapshot_age_p99", Json::Num(rs_age.p99)),
            ]),
        ),
        (
            "fleet",
            Json::obj(vec![
                ("crashes", Json::Num(fl_m.fault.crashes as f64)),
                ("requeued", Json::Num(fl_m.fault.requeued as f64)),
                ("requeue_rate", Json::Num(requeue_rate)),
                ("recovery_ttft_p99", Json::Num(recovery_ttft_p99)),
                ("goodput_static", Json::Num(goodput_static)),
                ("goodput_autoscaler", Json::Num(goodput_auto)),
                ("scale_ups", Json::Num(fl_auto.fault.scale_ups as f64)),
            ]),
        ),
        (
            "engine_queue",
            Json::obj(vec![
                ("ttft_p99_fcfs", Json::Num(q_p99[0])),
                ("ttft_p99_srpt", Json::Num(q_p99[1])),
                ("ttft_p99_ltr", Json::Num(q_p99[2])),
                ("ttft_p99_ratio_srpt", Json::Num(q_ratio_srpt)),
                (
                    "promotions_ltr",
                    Json::Num(q_runs[2].total_promotions() as f64),
                ),
            ]),
        ),
        (
            "hetero",
            Json::obj(vec![
                ("slo_ttft_s", Json::Num(hslo.ttft_s)),
                ("slo_tpot_s", Json::Num(hslo.tpot_s)),
                ("goodput_fused", Json::Num(h_fused)),
                ("goodput_two_layer", Json::Num(h_layered)),
                ("goodput_ratio_fused_over_two_layer", Json::Num(h_ratio)),
                (
                    "cold_model_loads",
                    Json::Num(h_runs[0].models.cold_loads as f64),
                ),
                (
                    "model_evictions",
                    Json::Num(h_runs[0].models.evictions as f64),
                ),
            ]),
        ),
        (
            "sweep",
            Json::obj(vec![
                ("jobs", Json::Num(sweep_jobs.len() as f64)),
                ("threads", Json::Num(bench_threads() as f64)),
                ("serial_wall_s", Json::Num(serial_wall)),
                ("parallel_wall_s", Json::Num(parallel_wall)),
                ("speedup", Json::Num(speedup)),
            ]),
        ),
    ]);
    let path = std::env::var("LMETRIC_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_router_throughput.json".to_string());
    std::fs::write(&path, doc.to_string()).expect("write bench json");
    println!("wrote {path}");
}
