//! Fig 31 (appendix A.1): Preble end-to-end performance as the filter
//! threshold T varies (ChatBot, moe-30b).
//!
//! Paper shape: T has little impact; the published default T=0.5 is
//! already (near-)optimal.

use lmetric::benchlib::{experiment, figure_banner, run_policy, trace_for};
use lmetric::metrics::{fmt_s, save_results, ResultRow};

fn main() {
    figure_banner("Fig 31", "Preble filter-threshold T sweep");
    let exp = experiment("chatbot", 8, 4000);
    let trace = trace_for(&exp);
    let mut rows = Vec::new();
    let mut ttfts = Vec::new();
    println!(
        "{:>6} {:>10} {:>10} {:>10} {:>10}",
        "T", "TTFT-mean", "TTFT-p99", "TPOT-mean", "TPOT-p99"
    );
    for t in [0.1, 0.25, 0.5, 0.75, 1.0] {
        let (m, label) = run_policy(&exp, &trace, "preble", t);
        let (tt, tp) = (m.ttft_summary(), m.tpot_summary());
        println!(
            "{t:>6.2} {:>10} {:>10} {:>10} {:>10}",
            fmt_s(tt.mean),
            fmt_s(tt.p99),
            fmt_s(tp.mean),
            fmt_s(tp.p99)
        );
        ttfts.push((t, tt.mean));
        rows.push(ResultRow::from_metrics(&label, &m).with("T", t));
    }
    let best = ttfts.iter().cloned().fold((0.0, f64::MAX), |a, b| if b.1 < a.1 { b } else { a });
    let at_default = ttfts.iter().find(|(t, _)| *t == 0.5).unwrap().1;
    println!(
        "\nshape check: default T=0.5 within 15% of the best (T={}): {}",
        best.0,
        if at_default <= best.1 * 1.15 { "YES (matches paper)" } else { "NO" }
    );
    let path = save_results("fig31_preble_t", &rows, &[]).unwrap();
    println!("saved {}", path.display());
}
