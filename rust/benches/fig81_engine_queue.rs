//! Fig 81 — within-instance queue scheduling under the router: the
//! router-policy × engine-queue-policy 2D grid.
//!
//! The paper's claim is about *routing* (the multiplicative P-token × BS
//! score); this figure asks whether the win survives — and compounds —
//! when each instance also reorders its own waiting queue. Three panels,
//! all pure virtual-time DES (deterministic run to run), each sweeping
//! routers {lmetric, vllm, sticky} × engine queues {fcfs, srpt, ltr}:
//!
//! A. **Chat.** The default chatbot trace at moderate load: shallow
//!    queues, so the engine policies should barely separate — the
//!    no-harm panel.
//!
//! B. **Coding (long-tail, heavy load).** The coder trace at 0.95×
//!    profiled capacity with small admission batches, the regime SRPT
//!    theory speaks to: waiting queues run deep and output lengths are
//!    heavy-tailed. The acceptance claims live here: under the lmetric
//!    router, `srpt` must beat `fcfs` on mean TTFT (shortest-predicted-
//!    work-first drains admission waits fastest), `ltr` must land close
//!    (its starvation quantum hands part of the SJF win back to aged
//!    requests), and lmetric's routing win over vllm must hold under
//!    *every* engine queue — reordering below the router must not break
//!    the paper's headline.
//!
//! C. **Open system.** Constant-rate open arrivals near capacity via the
//!    session engine — the queue policies ride under the closed-loop /
//!    open-arrival machinery unchanged.

use lmetric::benchlib::{figure_banner, parallel_sweep, scaled};
use lmetric::cluster::RunSpec;
use lmetric::engine::ModelProfile;
use lmetric::metrics::{render_table, save_results, ResultRow, RunMetrics};
use lmetric::policy;

const ROUTERS: [&str; 3] = ["lmetric", "vllm", "sticky"];
const QUEUES: [&str; 3] = ["fcfs", "srpt", "ltr"];

fn grid() -> Vec<(&'static str, &'static str)> {
    let mut g = Vec::new();
    for r in ROUTERS {
        for q in QUEUES {
            g.push((r, q));
        }
    }
    g
}

fn mean_ttft(m: &RunMetrics) -> f64 {
    let ttfts = m.ttfts();
    if ttfts.is_empty() {
        f64::NAN
    } else {
        ttfts.iter().sum::<f64>() / ttfts.len() as f64
    }
}

fn panel_rows(panel: &str, cells: &[(&str, &str)], runs: &[RunMetrics]) -> Vec<ResultRow> {
    let mut rows = Vec::new();
    for ((router, queue), m) in cells.iter().zip(runs) {
        println!(
            "{panel:<5} {router:<8} x {queue:<5} mean TTFT {:.4}s  p99 {:.4}s  \
             queue wait mean {:.4}s max {:.4}s  promotions {}",
            mean_ttft(m),
            m.ttft_summary().p99,
            m.mean_queue_wait_s(),
            m.max_queue_wait_s(),
            m.total_promotions()
        );
        rows.push(
            ResultRow::from_metrics(&format!("{panel}_{router}x{queue}"), m)
                .with("mean_ttft_s", mean_ttft(m))
                .with("queue_wait_mean_s", m.mean_queue_wait_s())
                .with("queue_wait_max_s", m.max_queue_wait_s())
                .with("promotions", m.total_promotions() as f64)
                .with("stalled_steps", m.total_stalled_steps() as f64),
        );
    }
    rows
}

fn main() {
    figure_banner("fig81", "within-instance queue scheduling: router x engine-queue 2D grid");
    let profile = ModelProfile::moe_30b();
    let cells = grid();
    let mut rows: Vec<ResultRow> = Vec::new();

    // ---------------------------------------------------------------
    // Panel A: chatbot at moderate load — shallow queues, no-harm.
    // ---------------------------------------------------------------
    println!("\n--- A: chat (moderate load) ---");
    let mut a_exp = lmetric::config::ExperimentConfig::default();
    a_exp.instances = 8;
    a_exp.requests = scaled(1600);
    let a_trace = lmetric::cluster::build_scaled_trace(&a_exp);
    let a_cfg = lmetric::cluster::cluster_config(&a_exp);
    let a_runs = parallel_sweep(&cells, |_, (router, queue)| {
        let mut p = policy::build_default(router, &profile, 256).unwrap();
        lmetric::cluster::run(
            RunSpec::open_loop(&a_cfg, &a_trace).with_queue_policy(queue),
            p.as_mut(),
        )
    });
    for m in &a_runs {
        assert_eq!(m.records.len(), a_trace.requests.len(), "A: conservation");
        assert_eq!(m.total_stalled_steps(), 0, "A: no stalled steps");
    }
    rows.extend(panel_rows("chat", &cells, &a_runs));

    // ---------------------------------------------------------------
    // Panel B: coder at 0.95x capacity, small batches — deep queues.
    // ---------------------------------------------------------------
    println!("\n--- B: coding (long-tail outputs, 0.95x capacity) ---");
    let mut b_exp = lmetric::config::ExperimentConfig::default();
    b_exp.instances = 4;
    b_exp.requests = scaled(1200);
    b_exp.workload = "coder".into();
    b_exp.rate_scale = 0.95;
    // Small admission batches: the waiting queue, not the KV cache, is
    // the bottleneck — the regime where queue *order* matters.
    b_exp.max_batch = 8;
    let b_trace = lmetric::cluster::build_scaled_trace(&b_exp);
    let b_cfg = lmetric::cluster::cluster_config(&b_exp);
    let b_runs = parallel_sweep(&cells, |_, (router, queue)| {
        let mut p = policy::build_default(router, &profile, 256).unwrap();
        lmetric::cluster::run(
            RunSpec::open_loop(&b_cfg, &b_trace).with_queue_policy(queue),
            p.as_mut(),
        )
    });
    for m in &b_runs {
        assert_eq!(m.records.len(), b_trace.requests.len(), "B: conservation");
    }
    rows.extend(panel_rows("coder", &cells, &b_runs));

    let cell = |router: &str, queue: &str| {
        cells.iter().position(|c| *c == (router, queue)).unwrap()
    };
    // The panel is only meaningful if admission actually queued.
    assert!(
        b_runs[cell("lmetric", "fcfs")].mean_queue_wait_s() > 0.0,
        "coder panel must form waiting queues (raise load or shrink batches)"
    );
    let (fcfs, srpt, ltr) = (
        mean_ttft(&b_runs[cell("lmetric", "fcfs")]),
        mean_ttft(&b_runs[cell("lmetric", "srpt")]),
        mean_ttft(&b_runs[cell("lmetric", "ltr")]),
    );
    println!(
        "coder x lmetric mean TTFT: fcfs {fcfs:.4}s, srpt {srpt:.4}s \
         ({:.2}x), ltr {ltr:.4}s ({:.2}x)",
        srpt / fcfs,
        ltr / fcfs
    );
    // The acceptance claims. srpt must strictly beat fcfs — shortest-
    // predicted-work-first is the textbook mean-wait win and the
    // predictor's ±50% noise band is not enough to erase it under a
    // heavy-tailed output distribution. ltr gets a small slack: its
    // starvation quantum deliberately gives part of that win back.
    assert!(
        srpt < fcfs,
        "srpt mean TTFT ({srpt:.4}s) must beat fcfs ({fcfs:.4}s) on the long-tail coder trace"
    );
    assert!(
        ltr < fcfs * 1.02,
        "ltr mean TTFT ({ltr:.4}s) must land within 2% of fcfs ({fcfs:.4}s) or better"
    );
    // Reordering under the router must not break the routing headline:
    // lmetric holds its win over vllm under every engine queue.
    for queue in QUEUES {
        let lm = mean_ttft(&b_runs[cell("lmetric", queue)]);
        let vl = mean_ttft(&b_runs[cell("vllm", queue)]);
        assert!(
            lm <= vl * 1.05,
            "{queue}: lmetric mean TTFT ({lm:.4}s) must stay within 5% of vllm ({vl:.4}s)"
        );
    }

    // ---------------------------------------------------------------
    // Panel C: open system — constant-rate arrivals near capacity.
    // ---------------------------------------------------------------
    println!("\n--- C: open system (constant-rate arrivals, 0.9x) ---");
    let c_spec =
        lmetric::trace::OpenSpec::new(lmetric::trace::RateProgram::constant(10.0, 120.0), 81)
            .with_cap(scaled(1600));
    let c_trace = lmetric::cluster::build_scaled_open(&c_spec, &a_cfg, 0.9);
    let c_runs = parallel_sweep(&cells, |_, (router, queue)| {
        let mut p = policy::build_default(router, &profile, 256).unwrap();
        lmetric::cluster::run(
            RunSpec::sessions(&a_cfg, &c_trace).with_queue_policy(queue),
            p.as_mut(),
        )
    });
    for m in &c_runs {
        assert_eq!(m.records.len(), c_trace.n_turns(), "C: conservation");
        assert_eq!(m.total_stalled_steps(), 0, "C: no stalled steps");
    }
    rows.extend(panel_rows("open", &cells, &c_runs));

    println!("{}", render_table("fig81 engine queue grid", &rows));
    println!("coder x lmetric: srpt/fcfs {:.3}, ltr/fcfs {:.3}", srpt / fcfs, ltr / fcfs);
    let path = save_results("fig81_engine_queue", &rows, &[]).expect("save results");
    println!("saved {}", path.display());
}
