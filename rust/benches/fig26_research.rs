//! Fig 26: LMETRIC vs the research schedulers Preble and PolyServe
//! (ChatBot, moe-30b) across request rates, with vLLM as reference.
//!
//! Paper shape: LMETRIC < Preble < PolyServe on both mean and P99
//! latency (PolyServe trades latency for a load gradient by design);
//! vs Preble: −56% mean TTFT, −8% mean TPOT.

use lmetric::benchlib::{experiment, figure_banner, run_default, trace_for};
use lmetric::metrics::{fmt_s, save_results, ResultRow};

fn main() {
    figure_banner("Fig 26", "LMETRIC vs Preble vs PolyServe, rate sweep");
    let mut all_rows = Vec::new();
    println!(
        "{:>6} {:>12} {:>10} {:>10} {:>10} {:>10}",
        "rate", "policy", "TTFT-mean", "TTFT-p99", "TPOT-mean", "TPOT-p99"
    );
    let mut at_half = std::collections::BTreeMap::new();
    for rate in [0.3, 0.5, 0.7, 0.85] {
        let mut exp = experiment("chatbot", 8, 4000);
        exp.rate_scale = rate;
        let trace = trace_for(&exp); // shared across policies
        for name in ["vllm", "preble", "polyserve", "lmetric"] {
            let (m, _) = run_default(&exp, &trace, name);
            let (t, p) = (m.ttft_summary(), m.tpot_summary());
            println!(
                "{rate:>6.2} {name:>12} {:>10} {:>10} {:>10} {:>10}",
                fmt_s(t.mean),
                fmt_s(t.p99),
                fmt_s(p.mean),
                fmt_s(p.p99)
            );
            if rate == 0.5 {
                at_half.insert(name, (t.mean, p.mean));
            }
            all_rows.push(
                ResultRow::from_metrics(&format!("{rate}/{name}"), &m).with("rate", rate),
            );
        }
    }
    let (lm_t, lm_p) = at_half["lmetric"];
    let (pr_t, pr_p) = at_half["preble"];
    let (ps_t, _) = at_half["polyserve"];
    println!(
        "\nat 0.5× capacity: LMETRIC vs Preble TTFT −{:.0}% (paper 56%), TPOT −{:.0}% (paper 8%)",
        (1.0 - lm_t / pr_t) * 100.0,
        (1.0 - lm_p / pr_p) * 100.0
    );
    println!(
        "shape checks: lmetric ≈ preble (within 15%): {} | both ≪ polyserve: {}",
        if lm_t < pr_t * 1.15 { "YES" } else { "NO" },
        if pr_t < ps_t * 0.5 && lm_t < ps_t * 0.5 { "YES (matches paper's ordering)" } else { "NO" }
    );
    println!(
        "note: Preble lands closer to LMETRIC here than in the paper because our\n\
         synthetic traces have a higher prompt prefix share, so its KV$ filter\n\
         branch (which then selects by P-token) fires on most requests — see\n\
         Fig 27. The paper's larger gap comes from Preble falling back to its\n\
         windowed linear score most of the time on the production traces."
    );
    let path = save_results("fig26_research", &all_rows, &[]).unwrap();
    println!("saved {}", path.display());
}
