//! Fig 91 — heterogeneous fleets & multi-model routing: does fusing
//! placement and balance into one multiplicative score beat the
//! classical two-layer architecture?
//!
//! Two panels, both pure virtual-time DES (deterministic run to run):
//!
//! A. **Degeneracy.** A uniform reference fleet on single-model traffic:
//!    `lmetric_fused` and `place_then_balance` must replay plain
//!    `lmetric` decision-for-decision (every penalty is 0 and P-time
//!    divides by exactly 1.0). The no-regression panel: heterogeneity
//!    support must cost the homogeneous paper setup nothing.
//!
//! B. **Mixed fleet, multiplexed models.** h100:2 + l40:6 serving a
//!    4-model chatbot mix. The fused score prices the cold-model swap
//!    into the same product as queue depth and hardware speed; the
//!    two-layer baseline places cold models least-loaded, then balances
//!    strictly inside the warm set. The acceptance claim lives here:
//!    **fused SLO-goodput ≥ two-layer** — the RouteBalance observation
//!    that the layer boundary itself costs goodput. `lmetric` (swap-
//!    blind) and `vllm` (swap- and hardware-blind) calibrate how much
//!    of the win is cost-awareness vs fusion.

use lmetric::benchlib::{figure_banner, parallel_sweep, scaled};
use lmetric::cluster::RunSpec;
use lmetric::config::FleetSpec;
use lmetric::engine::{InstanceProfile, ModelProfile};
use lmetric::metrics::{render_table, save_results, ResultRow, RunMetrics, SloSpec};
use lmetric::policy;

const POLICIES: [&str; 4] = ["lmetric_fused", "place_then_balance", "lmetric", "vllm"];

fn mean_ttft(m: &RunMetrics) -> f64 {
    let ttfts = m.ttfts();
    if ttfts.is_empty() {
        f64::NAN
    } else {
        ttfts.iter().sum::<f64>() / ttfts.len() as f64
    }
}

fn main() {
    figure_banner(
        "fig91",
        "heterogeneous fleets: fused placement+balance vs two-layer routing",
    );
    let profile = ModelProfile::moe_30b();
    let mut rows: Vec<ResultRow> = Vec::new();

    // ---------------------------------------------------------------
    // Panel A: degeneracy on the uniform single-model fleet.
    // ---------------------------------------------------------------
    println!("\n--- A: uniform fleet, single model (degeneracy) ---");
    let mut a_exp = lmetric::config::ExperimentConfig::default();
    a_exp.instances = 8;
    a_exp.requests = scaled(1200);
    let a_trace = lmetric::cluster::build_scaled_trace(&a_exp);
    let a_cfg = lmetric::cluster::cluster_config(&a_exp);
    let a_pols = ["lmetric", "lmetric_fused", "place_then_balance"];
    let a_runs = parallel_sweep(&a_pols, |_, name| {
        let mut p = policy::build_default(name, &profile, 256).unwrap();
        lmetric::cluster::run(RunSpec::open_loop(&a_cfg, &a_trace), p.as_mut())
    });
    for (name, m) in a_pols.iter().zip(&a_runs) {
        assert_eq!(m.records.len(), a_trace.requests.len(), "{name}: conservation");
        assert_eq!(m.models.cold_loads, 0, "{name}: single-model must never swap");
        rows.push(
            ResultRow::from_metrics(&format!("uniform_{name}"), m)
                .with("mean_ttft_s", mean_ttft(m)),
        );
    }
    for (name, m) in a_pols.iter().zip(&a_runs).skip(1) {
        let base = &a_runs[0];
        assert_eq!(base.records.len(), m.records.len());
        for (x, y) in base.records.iter().zip(&m.records) {
            assert_eq!(
                (x.id, x.instance, x.first_token_us, x.completion_us),
                (y.id, y.instance, y.first_token_us, y.completion_us),
                "{name} diverged from lmetric on the uniform fleet"
            );
        }
        println!("{name:<20} replays lmetric decision-for-decision");
    }

    // ---------------------------------------------------------------
    // Panel B: mixed fleet, 4 multiplexed models.
    // ---------------------------------------------------------------
    println!("\n--- B: h100:2 + l40:6 fleet, 4 models ---");
    let mut b_exp = lmetric::config::ExperimentConfig::default();
    b_exp.requests = scaled(1600);
    b_exp.n_models = 4;
    // 0.6x of the *reference* capacity: the mixed fleet's true capacity
    // is ~0.84x reference (2x2.0 + 6x0.45 over 8 slots), so this runs
    // hot enough that swap stalls and slow-slot queues cost goodput.
    b_exp.rate_scale = 0.6;
    b_exp.fleet = Some(
        FleetSpec::empty()
            .with_class(InstanceProfile::h100(), 2)
            .with_class(InstanceProfile::l40(), 6),
    );
    b_exp.instances = 8;
    let b_trace = lmetric::cluster::build_scaled_trace(&b_exp);
    let b_cfg = lmetric::cluster::cluster_config(&b_exp);

    // SLO the same way fig51/fig71 derive it: 3x the worst request of an
    // uncongested probe on the same fleet.
    let mut probe_exp = b_exp.clone();
    probe_exp.rate_scale = 0.25;
    probe_exp.requests = scaled(600);
    let probe_trace = lmetric::cluster::build_scaled_trace(&probe_exp);
    let mut probe = policy::build_default("lmetric_fused", &profile, 256).unwrap();
    let m_probe = lmetric::cluster::run(
        RunSpec::open_loop(&b_cfg, &probe_trace),
        probe.as_mut(),
    );
    let worst_ttft = m_probe.ttfts().iter().copied().fold(0.0, f64::max);
    let worst_tpot = m_probe.tpots().iter().copied().fold(0.0, f64::max);
    let slo = SloSpec::new(3.0 * worst_ttft.max(1e-3), 3.0 * worst_tpot.max(1e-3));
    println!("SLO: ttft <= {:.3}s, tpot <= {:.4}s", slo.ttft_s, slo.tpot_s);

    let b_runs = parallel_sweep(&POLICIES, |_, name| {
        let mut p = policy::build_default(name, &profile, 256).unwrap();
        lmetric::cluster::run(
            RunSpec::open_loop(&b_cfg, &b_trace).with_slo(slo),
            p.as_mut(),
        )
    });
    for (name, m) in POLICIES.iter().zip(&b_runs) {
        assert_eq!(m.records.len(), b_trace.requests.len(), "{name}: conservation");
        assert!(
            m.models.cold_loads > 0,
            "{name}: 4 models on 2-warm slots must pay cold loads"
        );
        println!(
            "{name:<20} goodput {:.1}%  mean TTFT {:.4}s  cold loads {}  \
             evictions {}  swap {:.2}s",
            m.goodput_ratio(slo) * 100.0,
            mean_ttft(m),
            m.models.cold_loads,
            m.models.evictions,
            m.models.swap_us as f64 / 1e6
        );
        rows.push(
            ResultRow::from_metrics(&format!("hetero_{name}"), m)
                .with("mean_ttft_s", mean_ttft(m))
                .with("goodput_ratio", m.goodput_ratio(slo))
                .with("cold_model_loads", m.models.cold_loads as f64)
                .with("model_evictions", m.models.evictions as f64)
                .with("swap_s", m.models.swap_us as f64 / 1e6),
        );
    }
    let at = |name: &str| POLICIES.iter().position(|p| *p == name).unwrap();
    let fused = b_runs[at("lmetric_fused")].goodput_ratio(slo);
    let layered = b_runs[at("place_then_balance")].goodput_ratio(slo);
    println!(
        "fused {:.1}% vs two-layer {:.1}% goodput (ratio {:.3})",
        fused * 100.0,
        layered * 100.0,
        fused / layered.max(1e-9)
    );
    // The acceptance claim: fusing the layers never loses to them.
    assert!(
        fused >= layered,
        "fused goodput ({fused:.4}) must be >= two-layer ({layered:.4}) on the mixed fleet"
    );

    println!("{}", render_table("fig91 heterogeneous fleet", &rows));
    let path = save_results("fig91_hetero_fleet", &rows, &[]).expect("save results");
    println!("saved {}", path.display());
}
