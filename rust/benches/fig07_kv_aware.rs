//! Fig 7 + Fig 8: load-balancing-only (vLLM) vs +KV$-awareness
//! (BAILIAN-style linear): TTFT/TPOT distributions and the KV$ hit-ratio
//! timeline that explains them.
//!
//! Paper shape: KV$-awareness cuts mean TTFT ~84% and mean TPOT ~17%,
//! with a much higher, stable hit ratio.

use lmetric::benchlib::{experiment, figure_banner, run_default, trace_for};
use lmetric::metrics::{render_table, save_results, ResultRow};

fn main() {
    figure_banner("Fig 7/8", "vLLM vs KV$-aware scheduling (ChatBot, moe-30b)");
    let exp = experiment("chatbot", 8, 5000);
    let trace = trace_for(&exp);
    println!(
        "trace: {} requests @ {:.1} req/s on {} instances",
        trace.requests.len(),
        trace.steady_rps(),
        exp.instances
    );

    let mut rows = Vec::new();
    let mut cdfs = Vec::new();
    for name in ["vllm", "linear"] {
        let (m, label) = run_default(&exp, &trace, name);
        println!("\n{label}: hit ratio per minute:");
        let tl = m.hit_ratio_timeline();
        let means = tl.means();
        for (i, h) in means.iter().enumerate().take(12) {
            if !h.is_nan() {
                println!("  min {i:>2}: {:>5.1}% {}", h * 100.0, "#".repeat((h * 40.0) as usize));
            }
        }
        cdfs.push((format!("ttft_{name}"), m.ttfts()));
        cdfs.push((format!("tpot_{name}"), m.tpots()));
        rows.push(ResultRow::from_metrics(&label, &m));
    }
    let ttft_cut = 1.0 - rows[1].ttft.mean / rows[0].ttft.mean;
    let tpot_cut = 1.0 - rows[1].tpot.mean / rows[0].tpot.mean;
    println!("{}", render_table("Fig 7: vLLM vs vLLM+KV$-awareness", &rows));
    println!(
        "KV$-awareness improvement: TTFT {:.0}% (paper: 84%), TPOT {:.0}% (paper: 17%)",
        ttft_cut * 100.0,
        tpot_cut * 100.0
    );
    let path = save_results("fig07_kv_aware", &rows, &cdfs).unwrap();
    println!("saved {}", path.display());
}
