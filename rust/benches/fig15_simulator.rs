//! Fig 15 + Fig 16: simulation-based scheduling vs simulator fidelity.
//! A well-tuned simulator (engine's own profile, no noise) vs a mis-tuned
//! one (another model's profile + residual noise): end-to-end latency
//! (Fig 15) and the TTFT prediction error-ratio CDF (Fig 16).
//!
//! Paper shape: tuned ≫ untuned on tails (−75.6% TTFT / −79.7% TPOT tail);
//! untuned error CDF stretches toward 100% error.

use lmetric::benchlib::{experiment, figure_banner, run_boxed, trace_for};
use lmetric::engine::ModelProfile;
use lmetric::metrics::{fmt_s, save_results, ResultRow};
use lmetric::policy::SimBased;
use lmetric::simulator::LatencySimulator;
use lmetric::util::stats::percentile;

fn main() {
    figure_banner("Fig 15/16", "tuned vs non-tuned simulator (sim-based policy)");
    let mut rows = Vec::new();
    let mut cdfs = Vec::new();
    for workload in ["chatbot", "coder", "agent", "toolagent"] {
        let mut exp = experiment(workload, 8, 4000);
        exp.rate_scale = 0.6; // mispredictions bite under load
        let trace = trace_for(&exp);
        let engine_profile = ModelProfile::moe_30b();
        let mut tuned = SimBased::new(LatencySimulator::tuned(engine_profile, 256));
        let mut untuned = SimBased::new(LatencySimulator::untuned(ModelProfile::dense_7b(), 256));
        let m_t = run_boxed(&exp, &trace, &mut tuned);
        let m_u = run_boxed(&exp, &trace, &mut untuned);
        println!(
            "\n{workload}: tuned   TTFT p95 {} p99 {} | TPOT p99 {}",
            fmt_s(m_t.ttft_summary().p95),
            fmt_s(m_t.ttft_summary().p99),
            fmt_s(m_t.tpot_summary().p99)
        );
        println!(
            "{:width$} untuned TTFT p95 {} p99 {} | TPOT p99 {}",
            "",
            fmt_s(m_u.ttft_summary().p95),
            fmt_s(m_u.ttft_summary().p99),
            fmt_s(m_u.tpot_summary().p99),
            width = workload.len() + 1
        );
        if workload == "chatbot" {
            // Fig 16: prediction error-ratio CDF.
            let mut te = m_t.sim_error_ratio.clone();
            let mut ue = m_u.sim_error_ratio.clone();
            te.sort_by(|a, b| a.partial_cmp(b).unwrap());
            ue.sort_by(|a, b| a.partial_cmp(b).unwrap());
            println!(
                "  error-ratio CDF (Fig 16): tuned p50 {:.2} p90 {:.2} | untuned p50 {:.2} p90 {:.2}",
                percentile(&te, 0.5),
                percentile(&te, 0.9),
                percentile(&ue, 0.5),
                percentile(&ue, 0.9)
            );
            cdfs.push(("error_tuned".to_string(), te));
            cdfs.push(("error_untuned".to_string(), ue));
        }
        rows.push(ResultRow::from_metrics(&format!("{workload}/tuned"), &m_t));
        rows.push(ResultRow::from_metrics(&format!("{workload}/untuned"), &m_u));
    }
    let path = save_results("fig15_simulator", &rows, &cdfs).unwrap();
    println!("\nsaved {}", path.display());
}
