//! Fig 51: open-arrival overload sweep — what admission control buys
//! once offered load crosses capacity.
//!
//! A mixed chat/API/coding open-arrival trace (Poisson session starts,
//! constant rate program) is replayed at 0.5×, 0.8×, 1.2× and 1.5× of
//! profiled capacity under the same router policy (`lmetric`) with each
//! admission policy: `admit_all`, `queue_shed`, `ttft_shed` and
//! session-aware `session_shed`. Thresholds are *derived*, not tuned: a
//! probe pass at ≤ 0.8× records the uncongested peak best-placement
//! depth and TTFT estimate, the shed thresholds are 2× those peaks, and
//! the SLO is 3× the worst request observed below capacity. By
//! construction no policy sheds below capacity (the trajectories are
//! byte-identical to `admit_all` — asserted), so the figure isolates
//! what happens past saturation: `admit_all` lets queues grow without
//! bound and goodput collapses, shedding bounds the admitted queue, and
//! the session-aware wrapper does it with zero orphaned turns.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use lmetric::benchlib::{figure_banner, parallel_sweep, scaled};
use lmetric::cluster::{
    build_scaled_open, run, AdmissionPolicy, AdmitAll, ClusterConfig, QueueDepthShed, RunSpec,
    SessionAwareShed, TtftShed,
};
use lmetric::engine::{EngineConfig, ModelProfile};
use lmetric::metrics::{fmt_s, save_results, ResultRow, RunMetrics, SloSpec};
use lmetric::policy;
use lmetric::router::RouteCtx;
use lmetric::trace::{OpenSpec, RateProgram};

const ADMISSIONS: [&str; 4] = ["admit_all", "queue_shed", "ttft_shed", "session_shed"];
const LOADS: [f64; 4] = [0.5, 0.8, 1.2, 1.5];

/// Admits everything while recording the peak best-placement depth and
/// TTFT estimate — exactly the quantities `QueueDepthShed` / `TtftShed`
/// threshold on — so the real thresholds can be derived from the
/// uncongested operating range instead of hand-tuned constants.
struct Probe {
    peak_depth: Arc<AtomicU64>,
    peak_est_us: Arc<AtomicU64>,
    step_fixed_us: f64,
    prefill_us_per_token: f64,
}

impl AdmissionPolicy for Probe {
    fn name(&self) -> String {
        "probe".into()
    }

    fn admit(&mut self, ctx: &RouteCtx) -> bool {
        let depth = (0..ctx.n()).map(|i| ctx.inds[i].bs()).min().unwrap_or(0);
        self.peak_depth.fetch_max(depth as u64, Ordering::Relaxed);
        let best = (0..ctx.n()).map(|i| ctx.p_token(i)).min().unwrap_or(0);
        let est = self.step_fixed_us + best as f64 * self.prefill_us_per_token;
        self.peak_est_us.fetch_max(est as u64, Ordering::Relaxed);
        true
    }
}

fn mk_admission(
    name: &str,
    depth_thr: usize,
    ttft_budget_us: f64,
    profile: &ModelProfile,
) -> Box<dyn AdmissionPolicy> {
    match name {
        "admit_all" => Box::new(AdmitAll),
        "queue_shed" => Box::new(QueueDepthShed::new(depth_thr)),
        "ttft_shed" => Box::new(TtftShed::new(ttft_budget_us, profile)),
        "session_shed" => {
            let inner = QueueDepthShed::new(depth_thr);
            Box::new(SessionAwareShed::new(Box::new(inner)))
        }
        other => panic!("unknown admission {other}"),
    }
}

fn main() {
    figure_banner(
        "Fig 51",
        "open-arrival overload sweep: admission policies vs goodput at/past capacity",
    );
    let cfg = ClusterConfig::new(8, EngineConfig::default());
    let profile = cfg.engine.profile.clone();
    let ospec = OpenSpec::new(RateProgram::constant(10.0, 150.0), 51).with_cap(scaled(3000));
    let straces: Vec<_> =
        LOADS.iter().map(|&l| build_scaled_open(&ospec, &cfg, l)).collect();

    // Probe the two below-capacity points: peak shed indicators + the
    // worst request either run produced. The derived thresholds (2× the
    // peaks) structurally cannot fire on these same traces, and the SLO
    // (3× the worst request) is met by every request below capacity.
    let peak_depth = Arc::new(AtomicU64::new(0));
    let peak_est = Arc::new(AtomicU64::new(0));
    let mut worst_ttft = 0.0f64;
    let mut worst_tpot = 0.0f64;
    for strace in straces.iter().take(2) {
        let mut pol = policy::build_default("lmetric", &profile, 256).unwrap();
        let probe = Probe {
            peak_depth: peak_depth.clone(),
            peak_est_us: peak_est.clone(),
            step_fixed_us: profile.step_fixed_us,
            prefill_us_per_token: profile.prefill_us_per_token,
        };
        let spec = RunSpec::sessions(&cfg, strace).with_admission(Box::new(probe));
        let m = run(spec, pol.as_mut());
        assert_eq!(m.overload.shed, 0, "probe must not shed");
        worst_ttft = worst_ttft.max(m.ttfts().iter().copied().fold(0.0, f64::max));
        worst_tpot = worst_tpot.max(m.tpots().iter().copied().fold(0.0, f64::max));
    }
    let depth_thr = (2 * peak_depth.load(Ordering::Relaxed) as usize).max(8);
    let ttft_budget_us = (2 * peak_est.load(Ordering::Relaxed)) as f64;
    let slo = SloSpec::new(3.0 * worst_ttft.max(1e-3), 3.0 * worst_tpot.max(1e-3));
    println!(
        "derived: depth threshold {depth_thr}, TTFT budget {}, SLO (ttft {}, tpot {})",
        fmt_s(ttft_budget_us / 1e6),
        fmt_s(slo.ttft_s),
        fmt_s(slo.tpot_s)
    );

    let mut rows: Vec<ResultRow> = Vec::new();
    for (li, strace) in straces.iter().enumerate() {
        let load = LOADS[li];
        println!(
            "\n--- {load}x capacity ({} sessions / {} turns) ---",
            strace.sessions.len(),
            strace.n_turns()
        );
        let results: Vec<RunMetrics> = parallel_sweep(&ADMISSIONS, |_, name| {
            let mut pol = policy::build_default("lmetric", &profile, 256).unwrap();
            let adm = mk_admission(name, depth_thr, ttft_budget_us, &profile);
            let spec = RunSpec::sessions(&cfg, strace).with_admission(adm).with_slo(slo);
            run(spec, pol.as_mut())
        });
        for (name, m) in ADMISSIONS.iter().zip(&results) {
            let o = m.overload;
            println!(
                "{:<12} goodput {:>5.1}%  TTFT {:>8}  offered {:>5}  shed {:>5}  \
                 mid-session {:>4}  orphans {:>4}",
                name,
                m.goodput_ratio(slo) * 100.0,
                fmt_s(m.ttft_summary().mean),
                o.offered,
                o.shed,
                o.shed_mid_session,
                o.orphaned_turns
            );
            rows.push(
                ResultRow::from_metrics(&format!("{name}_{load}x"), m)
                    .with("goodput", m.goodput_ratio(slo))
                    .with("offered", o.offered as f64)
                    .with("shed", o.shed as f64)
                    .with("shed_mid_session", o.shed_mid_session as f64)
                    .with("orphaned_turns", o.orphaned_turns as f64),
            );
        }
        let of = |name: &str| &results[ADMISSIONS.iter().position(|a| *a == name).unwrap()];
        let m_all = of("admit_all");
        let m_queue = of("queue_shed");
        let m_sess = of("session_shed");
        // The conversation-integrity contract, at every load.
        assert_eq!(
            m_sess.overload.orphaned_turns, 0,
            "session_shed must never orphan turns at {load}x"
        );
        if load <= 0.8 {
            for (name, m) in ADMISSIONS.iter().zip(&results) {
                assert_eq!(m.overload.shed, 0, "{name} must not shed at {load}x");
                assert!(
                    m.goodput_ratio(slo) >= 0.99,
                    "{name} at {load}x: goodput {} must be >= 99%",
                    m.goodput_ratio(slo)
                );
            }
            // No sheds -> every shedding run is the admit_all trajectory.
            assert_eq!(m_all.records.len(), m_queue.records.len());
            for (a, b) in m_all.records.iter().zip(&m_queue.records) {
                assert_eq!(
                    (a.id, a.instance, a.completion_us),
                    (b.id, b.instance, b.completion_us),
                    "no-shed trajectory must be byte-identical at {load}x"
                );
            }
        } else {
            assert!(m_queue.overload.shed > 0, "queue_shed must engage at {load}x");
            assert!(
                m_sess.goodput_ratio(slo) > m_all.goodput_ratio(slo),
                "session_shed goodput {} must beat admit_all {} at {load}x",
                m_sess.goodput_ratio(slo),
                m_all.goodput_ratio(slo)
            );
        }
    }

    let path = save_results("fig51_overload_sweep", &rows, &[]).unwrap();
    println!("\nsaved {}", path.display());
}
