//! Fig 23: end-to-end performance under different request rates
//! (0.3×–0.85× of profiled capacity), on the moe-30b model for three
//! workloads plus the dense-7b model for Agent (the paper's second row).
//!
//! Paper shape: LMETRIC lowest latency at every rate; gaps widen with
//! rate.
//!
//! The heaviest figure bench (4 traces × 4 rates × 5 policies = 80 DES
//! runs), so it fans out through `benchlib::parallel_sweep`: trace
//! construction per sweep point first, then every (point × policy) run,
//! all deterministic and reported in input order. `LMETRIC_BENCH_THREADS=1`
//! forces the historical serial behaviour.

use lmetric::benchlib::{experiment, figure_banner, parallel_sweep, run_default, trace_for};
use lmetric::metrics::{fmt_s, save_results, ResultRow};

const POLICIES: [&str; 5] = ["vllm", "linear", "dynamo", "sim_llmd", "lmetric"];
const RATES: [f64; 4] = [0.3, 0.5, 0.7, 0.85];

fn main() {
    figure_banner("Fig 23", "rate sweep × policies × workloads");
    let setups = [
        ("chatbot", "moe-30b"),
        ("agent", "dense-7b"),
        ("coder", "moe-30b"),
        ("toolagent", "moe-30b"),
    ];
    // Sweep points: build each point's scaled trace in parallel (trace
    // profiling is itself a DES run, and there are 16 of them).
    let mut point_defs = Vec::new();
    for (workload, profile) in setups {
        for rate in RATES {
            point_defs.push((workload, profile, rate));
        }
    }
    let points = parallel_sweep(&point_defs, |_, &(workload, profile, rate)| {
        let mut exp = experiment(workload, 8, 4000);
        exp.profile = profile.into();
        exp.rate_scale = rate;
        let trace = trace_for(&exp);
        (exp, trace)
    });
    // Every (sweep-point × policy) DES run, fanned out.
    let mut run_defs = Vec::new();
    for pi in 0..points.len() {
        for name in POLICIES {
            run_defs.push((pi, name));
        }
    }
    let runs = parallel_sweep(&run_defs, |_, &(pi, name)| {
        let (exp, trace) = &points[pi];
        let (m, _) = run_default(exp, trace, name);
        m
    });

    // Serial reporting in the original order.
    let mut all_rows = Vec::new();
    for (si, (workload, profile)) in setups.into_iter().enumerate() {
        println!("\n=== {workload} on {profile} ===");
        println!(
            "{:>6} {:>12} {:>10} {:>10} {:>10} {:>10}",
            "rate", "policy", "TTFT-mean", "TTFT-p99", "TPOT-mean", "TPOT-p99"
        );
        for (rj, rate) in RATES.into_iter().enumerate() {
            let mut best = (String::new(), f64::INFINITY);
            for (ki, name) in POLICIES.into_iter().enumerate() {
                // Index derived from the point_defs/run_defs construction
                // order above: point = setup-major, run = policy-minor.
                let pi = si * RATES.len() + rj;
                let m = &runs[pi * POLICIES.len() + ki];
                let (t, p) = (m.ttft_summary(), m.tpot_summary());
                println!(
                    "{rate:>6.2} {name:>12} {:>10} {:>10} {:>10} {:>10}",
                    fmt_s(t.mean),
                    fmt_s(t.p99),
                    fmt_s(p.mean),
                    fmt_s(p.p99)
                );
                if t.mean < best.1 {
                    best = (name.to_string(), t.mean);
                }
                all_rows.push(
                    ResultRow::from_metrics(&format!("{workload}/{profile}/{rate}/{name}"), m)
                        .with("rate", rate),
                );
            }
            println!("       -> best at {rate}: {}", best.0);
        }
    }
    let path = save_results("fig23_rate_sweep", &all_rows, &[]).unwrap();
    println!("saved {}", path.display());
}
