//! Fig 23: end-to-end performance under different request rates
//! (0.3×–0.85× of profiled capacity), on the moe-30b model for three
//! workloads plus the dense-7b model for Agent (the paper's second row).
//!
//! Paper shape: LMETRIC lowest latency at every rate; gaps widen with
//! rate.

use lmetric::benchlib::{experiment, figure_banner, run_default, trace_for};
use lmetric::metrics::{fmt_s, save_results, ResultRow};

const POLICIES: [&str; 5] = ["vllm", "linear", "dynamo", "sim_llmd", "lmetric"];

fn main() {
    figure_banner("Fig 23", "rate sweep × policies × workloads");
    let mut all_rows = Vec::new();
    for (workload, profile) in [
        ("chatbot", "moe-30b"),
        ("agent", "dense-7b"),
        ("coder", "moe-30b"),
        ("toolagent", "moe-30b"),
    ] {
        println!("\n=== {workload} on {profile} ===");
        println!(
            "{:>6} {:>12} {:>10} {:>10} {:>10} {:>10}",
            "rate", "policy", "TTFT-mean", "TTFT-p99", "TPOT-mean", "TPOT-p99"
        );
        for rate in [0.3, 0.5, 0.7, 0.85] {
            let mut best = (String::new(), f64::INFINITY);
            let mut exp = experiment(workload, 8, 4000);
            exp.profile = profile.into();
            exp.rate_scale = rate;
            let trace = trace_for(&exp); // shared across policies
            for name in POLICIES {
                let (m, _) = run_default(&exp, &trace, name);
                let (t, p) = (m.ttft_summary(), m.tpot_summary());
                println!(
                    "{rate:>6.2} {name:>12} {:>10} {:>10} {:>10} {:>10}",
                    fmt_s(t.mean),
                    fmt_s(t.p99),
                    fmt_s(p.mean),
                    fmt_s(p.p99)
                );
                if t.mean < best.1 {
                    best = (name.to_string(), t.mean);
                }
                all_rows.push(
                    ResultRow::from_metrics(&format!("{workload}/{profile}/{rate}/{name}"), &m)
                        .with("rate", rate),
                );
            }
            println!("       -> best at {rate}: {}", best.0);
        }
    }
    let path = save_results("fig23_rate_sweep", &all_rows, &[]).unwrap();
    println!("saved {}", path.display());
}
