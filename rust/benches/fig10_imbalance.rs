//! Fig 10: per-instance prefill-time imbalance under λ=0.7 vs λ=0.9.
//! For each run, pick the two instances with the highest stddev of
//! per-10s-window prefill seconds and compare their averages.
//!
//! Paper shape: λ=0.9 diverges (3.57s vs 2.17s per window); λ=0.7 stays
//! balanced (3.43s vs 3.40s).

use lmetric::benchlib::{experiment, figure_banner, run_policy, trace_for};
use lmetric::metrics::{save_results, ResultRow};

fn main() {
    figure_banner("Fig 10", "prefill-time imbalance: λ=0.7 vs λ=0.9 (ChatBot)");
    let exp = experiment("chatbot", 8, 5000);
    let trace = trace_for(&exp);
    let mut rows = Vec::new();
    let mut scores = Vec::new();
    for lambda in [0.7, 0.9] {
        let (m, label) = run_policy(&exp, &trace, "linear", lambda);
        let (ia, a, ib, b) = m.top2_imbalanced_instances().unwrap();
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
        println!(
            "\nλ={lambda}: most divergent instances {ia} and {ib} (prefill s / 10 s window)"
        );
        println!("  inst {ia}: mean {:.2}s   inst {ib}: mean {:.2}s", mean(&a), mean(&b));
        for w in 0..a.len().min(b.len()).min(20) {
            println!("    w{w:>2}: {:>6.2}s vs {:>6.2}s", a[w], b[w]);
        }
        let score = m.imbalance_score();
        println!("  imbalance score (mean |gap|): {score:.3}s");
        scores.push(score);
        rows.push(
            ResultRow::from_metrics(&label, &m)
                .with("lambda", lambda)
                .with("imbalance_s", score),
        );
    }
    println!(
        "\nshape check: λ=0.9 more imbalanced than λ=0.7: {}",
        if scores[1] > scores[0] { "YES (matches paper)" } else { "NO" }
    );
    let path = save_results("fig10_imbalance", &rows, &[]).unwrap();
    println!("saved {}", path.display());
}
