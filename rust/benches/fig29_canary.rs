//! Fig 29: the production canary protocol — split traffic across two
//! clusters sized for equal reqs/instance: 1/3 to LMETRIC, 2/3 to the
//! prior production scheduler (BAILIAN's tuned linear combination).
//!
//! Paper shape: LMETRIC cuts mean TTFT 39% and mean TPOT 51% at equal
//! per-instance load.

use lmetric::benchlib::{experiment, figure_banner, run_default, trace_for};
use lmetric::metrics::{render_table, save_results, ResultRow};

fn main() {
    figure_banner("Fig 29", "canary: 1/3 traffic on LMETRIC vs 2/3 on BAILIAN");
    // Equal reqs/GPU: the small cluster gets 1/3 of the instances AND 1/3
    // of the traffic (same rate_scale relative to its own capacity).
    // The production baseline is BAILIAN's *prior* scheduler: a linear
    // combination with one fleet-wide static λ — NOT retuned per workload
    // (§4.4 Cons #2 is exactly that a statically tuned weight drifts off
    // optimum as traffic changes). We model it as λ=0.45.
    let mut rows = Vec::new();
    let mut means = Vec::new();
    for (label, name, param, instances) in [
        ("canary (lmetric, 1/3)", "lmetric", 0.0, 4usize),
        ("baseline (bailian-static, 2/3)", "linear", 0.45, 8usize),
    ] {
        let exp = experiment("chatbot", instances, if instances == 4 { 3000 } else { 6000 });
        let trace = trace_for(&exp);
        let (m, _) = lmetric::benchlib::run_policy(&exp, &trace, name, param);
        println!(
            "{label}: {} instances, {:.1} req/s ({:.2} req/s/inst)",
            instances,
            trace.steady_rps(),
            trace.steady_rps() / instances as f64
        );
        means.push((m.ttft_summary().mean, m.tpot_summary().mean));
        rows.push(ResultRow::from_metrics(label, &m));
    }
    println!("{}", render_table("Fig 29: canary split", &rows));
    let ttft_cut = 1.0 - means[0].0 / means[1].0;
    let tpot_cut = 1.0 - means[0].1 / means[1].1;
    println!(
        "canary improvement: TTFT −{:.0}% (paper 39%), TPOT −{:.0}% (paper 51%)",
        ttft_cut * 100.0,
        tpot_cut * 100.0
    );
    let path = save_results("fig29_canary", &rows, &[]).unwrap();
    println!("saved {}", path.display());
}
