//! Fig 19: choosing the load-balancing indicator — BS vs #Tokens in
//! P-token × B (a), plus the profiled batch-size↔total-tokens relation
//! that justifies BS (b): decode step time is governed by batch size,
//! while total context tokens vary wildly at the same BS.

use lmetric::benchlib::{experiment, figure_banner, run_policy, trace_for};
use lmetric::engine::{EngineConfig, Instance};
use lmetric::metrics::{render_table, save_results, ResultRow};
use lmetric::trace::{generate, Workload, WorkloadSpec};

fn main() {
    figure_banner("Fig 19", "BS vs #Tokens as the load factor");
    let mut exp = experiment("chatbot", 8, 5000);
    exp.rate_scale = 0.6;
    let trace = trace_for(&exp);
    let (m_bs, _) = run_policy(&exp, &trace, "lmetric", 0.0);
    let (m_tok, _) = run_policy(&exp, &trace, "lmetric_tokens", 0.0);
    let rows = vec![
        ResultRow::from_metrics("P-Tkn × BS (paper)", &m_bs),
        ResultRow::from_metrics("P-Tkn × #Tokens", &m_tok),
    ];
    println!("{}", render_table("Fig 19a: TTFT/TPOT", &rows));

    // (b) profile the BS <-> total-tokens relationship on one saturated
    // instance serving the ChatBot mix.
    println!("Fig 19b: batch size vs total context tokens (one saturated instance):");
    let mut inst = Instance::new(0, EngineConfig::default());
    let sample = generate(&WorkloadSpec::preset(Workload::ChatBot, 300, 5));
    for tr in &sample.requests {
        inst.enqueue(tr.req.clone(), tr.full_hashes.clone(), 0);
    }
    let mut now = 0u64;
    let mut samples: Vec<(usize, usize)> = Vec::new();
    while inst.has_work() {
        let out = inst.step(now).unwrap();
        now += out.duration_us;
        samples.push((out.snapshot.r_bs, out.snapshot.total_context_tokens));
    }
    // Bucket by BS decile and report token spread.
    samples.sort();
    let mut spread_ratios = Vec::new();
    for chunk in samples.chunks(samples.len() / 8 + 1) {
        let bs_lo = chunk.first().unwrap().0;
        let bs_hi = chunk.last().unwrap().0;
        let toks: Vec<f64> = chunk.iter().map(|(_, t)| *t as f64).collect();
        let min = toks.iter().cloned().fold(f64::MAX, f64::min);
        let max = toks.iter().cloned().fold(f64::MIN, f64::max);
        println!("  BS {bs_lo:>3}-{bs_hi:>3}: total tokens {min:>8.0} .. {max:>8.0}");
        if min > 0.0 {
            spread_ratios.push(max / min);
        }
    }
    let wide = spread_ratios.iter().any(|r| *r > 1.5);
    println!(
        "shape check: tokens vary widely at similar BS (ratio>1.5 somewhere): {}",
        if wide { "YES — BS is the more stable decode-load signal" } else { "NO" }
    );
    let path = save_results("fig19_indicator_lb", &rows, &[]).unwrap();
    println!("saved {}", path.display());
}
