//! Fig 18: choosing the KV$-awareness indicator — P-token vs 1−hit-ratio
//! in the multiplicative score A × BS (ChatBot, moe-30b).
//!
//! Paper shape (a): P-token beats 1−hit (−14.4% p50 TTFT, −42.8% p95);
//! (b) similar hit ratios; (c) P-token achieves it by also seeing queued
//! prefill tokens, avoiding congested hit instances.

use lmetric::benchlib::{experiment, figure_banner, run_policy, trace_for};
use lmetric::metrics::{render_table, save_results, ResultRow};

fn main() {
    figure_banner("Fig 18", "P-token vs 1−KV$-hit-ratio as the KV$ factor");
    let mut exp = experiment("chatbot", 8, 5000);
    exp.rate_scale = 0.8; // queues must exist for the difference to show
    let trace = trace_for(&exp);
    let (m_pt, _) = run_policy(&exp, &trace, "lmetric", 0.0);
    let (m_hr, _) = run_policy(&exp, &trace, "lmetric_hit_ratio", 0.0);
    let rows = vec![
        ResultRow::from_metrics("P-Tkn × BS (paper)", &m_pt),
        ResultRow::from_metrics("(1-KVhit) × BS", &m_hr),
    ];
    println!("{}", render_table("Fig 18a: TTFT/TPOT", &rows));
    println!(
        "(b) hit ratios: P-token {:.1}% vs 1−hit {:.1}% — similar: {}",
        m_pt.mean_hit_ratio() * 100.0,
        m_hr.mean_hit_ratio() * 100.0,
        (m_pt.mean_hit_ratio() - m_hr.mean_hit_ratio()).abs() < 0.1
    );
    let p50_cut = 1.0 - m_pt.ttft_summary().p50 / m_hr.ttft_summary().p50;
    let p95_cut = 1.0 - m_pt.ttft_summary().p95 / m_hr.ttft_summary().p95;
    println!(
        "(a) P-token improvement: p50 TTFT {:.0}% (paper 14.4%), p95 TTFT {:.0}% (paper 42.8%)",
        p50_cut * 100.0,
        p95_cut * 100.0
    );
    println!(
        "(c) imbalance score: P-token {:.3}s vs 1−hit {:.3}s (lower = better balanced)",
        m_pt.imbalance_score(),
        m_hr.imbalance_score()
    );
    let path = save_results(
        "fig18_indicator_kv",
        &rows,
        &[
            ("ttft_ptoken".into(), m_pt.ttfts()),
            ("ttft_hitratio".into(), m_hr.ttfts()),
        ],
    )
    .unwrap();
    println!("saved {}", path.display());
}
