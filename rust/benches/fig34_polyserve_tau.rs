//! Fig 34 (appendix A.2): PolyServe end-to-end TTFT/TPOT as the TPOT-SLO
//! threshold τ varies (ChatBot, moe-30b).
//!
//! Paper shape: τ trades utilization against latency; a τ near the
//! natural decode step time is best, and the paper adopts τ=20 ms.

use lmetric::benchlib::{experiment, figure_banner, run_policy, trace_for};
use lmetric::metrics::{fmt_s, save_results, ResultRow};

fn main() {
    figure_banner("Fig 34", "PolyServe SLO_TPOT (τ) sweep");
    let exp = experiment("chatbot", 8, 4000);
    let trace = trace_for(&exp);
    let mut rows = Vec::new();
    println!(
        "{:>8} {:>10} {:>10} {:>10} {:>10}",
        "τ (ms)", "TTFT-mean", "TTFT-p99", "TPOT-mean", "TPOT-p99"
    );
    for tau_ms in [5.0, 10.0, 20.0, 40.0, 80.0] {
        let (m, label) = run_policy(&exp, &trace, "polyserve", tau_ms);
        let (t, p) = (m.ttft_summary(), m.tpot_summary());
        println!(
            "{tau_ms:>8.0} {:>10} {:>10} {:>10} {:>10}",
            fmt_s(t.mean),
            fmt_s(t.p99),
            fmt_s(p.mean),
            fmt_s(p.p99)
        );
        rows.push(ResultRow::from_metrics(&label, &m).with("tau_ms", tau_ms));
    }
    println!("\n(the paper tunes τ per-deployment and adopts 20 ms; SLO_TTFT held fixed)");
    let path = save_results("fig34_polyserve_tau", &rows, &[]).unwrap();
    println!("saved {}", path.display());
}
