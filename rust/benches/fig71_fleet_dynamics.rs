//! Fig 71 — fleet dynamics under fault injection: what each routing
//! policy pays when the fleet itself is unstable.
//!
//! Three panels, all pure virtual-time DES (deterministic run to run):
//!
//! A. **Crash recovery.** A closed-loop chat-session trace with one
//!    instance crashing mid-run and recovering later, replayed under
//!    lmetric / sticky / smetric. Every displaced request is requeued
//!    through the router (conservation asserted: zero lost turns), and
//!    the recorded numbers are each policy's *degradation* — post-crash
//!    TTFT over pre-crash TTFT, and the session-affinity drop vs the
//!    same policy's fault-free replay. The acceptance claim: lmetric's
//!    multiplicative signal re-spreads the displaced load, so its
//!    degradation is no worse than sticky's (whose pins all point at the
//!    dead instance and must be re-placed cold).
//!
//! B. **Scale-up warm-up.** The same open-loop trace scaled up mid-run
//!    with a cold KV cache vs a warm-seeded one (the DES seeds the new
//!    instance from the router's frequency-ranked warm set of completed
//!    prefix chains). The cold-start hit curve — hit ratio of the first
//!    completions on the new instance — is the record: warm joins skip
//!    the cache-miss trough.
//!
//! C. **Flash crowd.** An open-arrival trace with a 3x burst, replayed
//!    on a static fleet vs one governed by the reactive queue-depth
//!    autoscaler. Goodput under a probe-derived SLO is the record; the
//!    autoscaler must actually fire (scale_ups >= 1).

use lmetric::benchlib::{figure_banner, parallel_sweep, scaled};
use lmetric::cluster::{FaultPlan, QueueDepthAutoscaler, RunSpec};
use lmetric::engine::ModelProfile;
use lmetric::metrics::{render_table, save_results, ResultRow, RunMetrics, SessionMetrics};
use lmetric::policy;

/// Mean TTFT (seconds) of records whose request *arrived* in
/// `[from_us, to_us)` — arrival-windowed so a requeued request's wait
/// counts against the window the user actually entered in.
fn windowed_ttft(m: &RunMetrics, from_us: u64, to_us: u64) -> f64 {
    let (mut sum, mut n) = (0.0, 0usize);
    for r in &m.records {
        if r.arrival_us >= from_us && r.arrival_us < to_us {
            sum += r.ttft_s();
            n += 1;
        }
    }
    if n == 0 {
        f64::NAN
    } else {
        sum / n as f64
    }
}

fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        f64::NAN
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

fn main() {
    figure_banner(
        "fig71",
        "fleet dynamics: crash recovery, scale-up warm-up, flash-crowd autoscaling",
    );
    let profile = ModelProfile::moe_30b();
    let mut exp = lmetric::config::ExperimentConfig::default();
    exp.instances = 8;
    exp.requests = scaled(2000);
    let cfg = lmetric::cluster::cluster_config(&exp);
    let mut rows: Vec<ResultRow> = Vec::new();

    // ---------------------------------------------------------------
    // Panel A: crash + recover on a closed-loop session trace.
    // ---------------------------------------------------------------
    println!("\n--- A: crash recovery (chat sessions) ---");
    let ses_spec =
        lmetric::trace::SessionSpec::preset(lmetric::trace::SessionKind::Chat, scaled(2000), 42);
    let strace = lmetric::cluster::build_scaled_sessions(&ses_spec, &cfg, 0.5);
    // Probe the fault-free duration once so the crash lands mid-run for
    // every policy (same absolute schedule => comparable windows).
    let mut probe_pol = policy::build_default("lmetric", &profile, 256).unwrap();
    let m_probe = lmetric::cluster::run_session_des(&cfg, &strace, probe_pol.as_mut());
    let crash_at = m_probe.duration_us / 4;
    let recover_at = m_probe.duration_us / 2;
    let plan = FaultPlan::new().crash_at(crash_at, 1).recover_at(recover_at, 1);

    const POLICIES: [&str; 3] = ["lmetric", "sticky", "smetric"];
    // (baseline fault-free, faulted) per policy, fanned out — the jobs
    // are independent DES runs, exactly what parallel_sweep is for.
    let crash_runs = parallel_sweep(&POLICIES, |_, name| {
        let mut p0 = policy::build_default(name, &profile, 256).unwrap();
        let base = lmetric::cluster::run_session_des(&cfg, &strace, p0.as_mut());
        let mut p1 = policy::build_default(name, &profile, 256).unwrap();
        let faulted = lmetric::cluster::run(
            RunSpec::sessions(&cfg, &strace).with_faults(plan.clone()),
            p1.as_mut(),
        );
        (base, faulted)
    });

    let mut degradation = std::collections::BTreeMap::new();
    let mut affinity_drop = std::collections::BTreeMap::new();
    for (name, (base, faulted)) in POLICIES.iter().zip(&crash_runs) {
        assert_eq!(faulted.fault.crashes, 1, "{name}: crash must fire");
        assert_eq!(faulted.fault.recovers, 1, "{name}: recover must fire");
        assert_eq!(faulted.fault.lost, 0, "{name}: fault injection must not lose requests");
        assert_eq!(
            faulted.records.len(),
            strace.n_turns(),
            "{name}: every displaced turn must be requeued to completion"
        );
        let pre = windowed_ttft(faulted, 0, crash_at);
        let post = windowed_ttft(faulted, crash_at, recover_at);
        let deg = post / pre.max(1e-9);
        let aff_base = SessionMetrics::collect(base, &strace).affinity_ratio();
        let aff_fault = SessionMetrics::collect(faulted, &strace).affinity_ratio();
        let drop = aff_base - aff_fault;
        degradation.insert(*name, deg);
        affinity_drop.insert(*name, drop);
        println!(
            "{name:<8} TTFT pre {pre:.4}s -> post-crash {post:.4}s ({deg:.2}x); \
             affinity {:.3} -> {:.3} (drop {:.3}); requeued {} re-admitted {}",
            aff_base, aff_fault, drop, faulted.fault.requeued, faulted.fault.re_admitted
        );
        rows.push(
            ResultRow::from_metrics(&format!("crash_{name}"), faulted)
                .with("ttft_pre_crash_s", pre)
                .with("ttft_post_crash_s", post)
                .with("ttft_degradation", deg)
                .with("affinity_fault_free", aff_base)
                .with("affinity_faulted", aff_fault)
                .with("affinity_drop", drop)
                .with("requeued", faulted.fault.requeued as f64)
                .with("lost", faulted.fault.lost as f64),
        );
    }
    // The crash must have displaced work somewhere: a mid-run crash on a
    // half-loaded fleet can catch one policy's instance idle, but not
    // all three (sticky alone pins every session placed there).
    let total_killed: u64 = crash_runs.iter().map(|(_, f)| f.fault.killed).sum();
    assert!(total_killed > 0, "crash mid-load must displace work under some policy");
    // The acceptance claim. Small multiplicative slack: both sides are
    // deterministic, but the claim is about the mechanism (lmetric
    // re-spreads displaced load; sticky re-pins cold), not a hairline.
    assert!(
        degradation["lmetric"] <= degradation["sticky"] * 1.05,
        "lmetric post-crash TTFT degradation ({:.3}x) must be no worse than sticky's ({:.3}x)",
        degradation["lmetric"],
        degradation["sticky"]
    );
    assert!(
        affinity_drop["lmetric"] <= affinity_drop["sticky"] + 0.05,
        "lmetric affinity drop ({:.3}) must be no worse than sticky's ({:.3})",
        affinity_drop["lmetric"],
        affinity_drop["sticky"]
    );

    // ---------------------------------------------------------------
    // Panel B: scale-up warm-up — cold vs warm-seeded KV.
    // ---------------------------------------------------------------
    println!("\n--- B: scale-up warm-up (cold vs warm KV) ---");
    let trace = lmetric::cluster::build_scaled_trace(&exp);
    let mut b_probe = policy::build_default("lmetric", &profile, 256).unwrap();
    let mb = lmetric::cluster::run_des(&cfg, &trace, b_probe.as_mut());
    let scale_at = mb.duration_us / 4;
    let variants: [(&str, bool); 2] = [("cold", true), ("warm", false)];
    let warm_runs = parallel_sweep(&variants, |_, (_, cold)| {
        let mut p = policy::build_default("lmetric", &profile, 256).unwrap();
        lmetric::cluster::run(
            RunSpec::open_loop(&cfg, &trace)
                .with_faults(FaultPlan::new().scale_up_at(scale_at, *cold)),
            p.as_mut(),
        )
    });
    let mut warmup_mean = std::collections::BTreeMap::new();
    for ((label, _), m) in variants.iter().zip(&warm_runs) {
        assert_eq!(m.fault.scale_ups, 1, "{label}: scale-up must fire");
        assert_eq!(m.fault.lost, 0, "{label}: scale-up must not lose requests");
        assert_eq!(m.records.len(), trace.requests.len(), "{label}: conservation");
        assert!(
            m.fault.cold_samples > 0,
            "{label}: new instance must serve sampled completions"
        );
        let hit = mean(&m.cold_hit_samples);
        warmup_mean.insert(*label, hit);
        println!(
            "{label:<5} join: first-{} completion hit ratio {:.3} (fleet mean {:.3})",
            m.fault.cold_samples,
            hit,
            m.mean_hit_ratio()
        );
        rows.push(
            ResultRow::from_metrics(&format!("scaleup_{label}"), m)
                .with("warmup_hit_mean", hit)
                .with("cold_samples", m.fault.cold_samples as f64),
        );
    }

    // ---------------------------------------------------------------
    // Panel C: flash crowd — static fleet vs reactive autoscaler.
    // ---------------------------------------------------------------
    println!("\n--- C: flash crowd (static vs autoscaled) ---");
    // Probe an uncongested constant-rate trace to derive the SLO the
    // same way fig51 does: 3x the worst fault-free request.
    let under_spec =
        lmetric::trace::OpenSpec::new(lmetric::trace::RateProgram::constant(10.0, 120.0), 51)
            .with_cap(scaled(2000));
    let under = lmetric::cluster::build_scaled_open(&under_spec, &cfg, 0.5);
    let mut c_probe = policy::build_default("lmetric", &profile, 256).unwrap();
    let m_under = lmetric::cluster::run(RunSpec::sessions(&cfg, &under), c_probe.as_mut());
    let worst_ttft = m_under.ttfts().iter().copied().fold(0.0, f64::max);
    let worst_tpot = m_under.tpots().iter().copied().fold(0.0, f64::max);
    let slo =
        lmetric::metrics::SloSpec::new(3.0 * worst_ttft.max(1e-3), 3.0 * worst_tpot.max(1e-3));
    let flash_spec = lmetric::trace::OpenSpec::new(
        lmetric::trace::RateProgram::flash_crowd(10.0, 3.0, 30.0, 20.0, 120.0),
        71,
    )
    .with_cap(scaled(2000));
    // Base load 0.7x capacity: comfortable until the 3x burst hits.
    let flash = lmetric::cluster::build_scaled_open(&flash_spec, &cfg, 0.7);
    let flash_jobs: [bool; 2] = [false, true];
    let flash_runs = parallel_sweep(&flash_jobs, |_, autoscale| {
        let mut p = policy::build_default("lmetric", &profile, 256).unwrap();
        let mut spec = RunSpec::sessions(&cfg, &flash).with_slo(slo);
        if *autoscale {
            spec = spec.with_autoscaler(
                Box::new(
                    QueueDepthAutoscaler::new(4.0, 1.0, exp.instances, exp.instances * 2)
                        .with_cooldown(2_000_000),
                ),
                1_000_000,
            );
        }
        lmetric::cluster::run(spec, p.as_mut())
    });
    let (m_static, m_auto) = (&flash_runs[0], &flash_runs[1]);
    for (label, m) in [("static", m_static), ("autoscaled", m_auto)] {
        assert_eq!(m.fault.lost, 0, "{label}: flash crowd must not lose requests");
        assert_eq!(m.records.len(), flash.n_turns(), "{label}: conservation");
        println!(
            "{label:<10} goodput {:.1}% (scale-ups {}, drains {}, requeued {})",
            m.goodput_ratio(slo) * 100.0,
            m.fault.scale_ups,
            m.fault.drains,
            m.fault.requeued
        );
        rows.push(
            ResultRow::from_metrics(&format!("flash_{label}"), m)
                .with("goodput", m.goodput_ratio(slo))
                .with("scale_ups", m.fault.scale_ups as f64)
                .with("drains", m.fault.drains as f64),
        );
    }
    assert!(
        m_auto.fault.scale_ups >= 1,
        "the flash crowd must push queue depth past the autoscaler's up-threshold"
    );
    assert!(
        m_auto.goodput_ratio(slo) >= m_static.goodput_ratio(slo) * 0.95,
        "autoscaled goodput ({:.3}) must not trail the static fleet ({:.3})",
        m_auto.goodput_ratio(slo),
        m_static.goodput_ratio(slo)
    );

    println!("{}", render_table("fig71 fleet dynamics", &rows));
    println!(
        "warm-up: cold {:.3} vs warm {:.3}; flash goodput: static {:.3} vs autoscaled {:.3}",
        warmup_mean["cold"],
        warmup_mean["warm"],
        m_static.goodput_ratio(slo),
        m_auto.goodput_ratio(slo)
    );
    let path = save_results("fig71_fleet_dynamics", &rows, &[]).expect("save results");
    println!("saved {}", path.display());
}
