//! Fig 9: KV$ hit ratio as a function of the linear combination's KV$
//! weight λ (ChatBot, moe-30b). Paper shape: hit ratio rises
//! monotonically with λ.

use lmetric::benchlib::{experiment, figure_banner, run_policy, trace_for};
use lmetric::metrics::{save_results, ResultRow};

fn main() {
    figure_banner("Fig 9", "KV$ hit ratio vs linear-combination weight λ");
    let exp = experiment("chatbot", 8, 4000);
    let trace = trace_for(&exp);
    let mut rows = Vec::new();
    println!("{:>6} {:>10}", "λ", "KV$ hit");
    let mut hits = Vec::new();
    for lambda in [0.4, 0.5, 0.6, 0.7, 0.8, 0.9] {
        let (m, label) = run_policy(&exp, &trace, "linear", lambda);
        let hit = m.mean_hit_ratio();
        println!("{lambda:>6.1} {:>9.1}%", hit * 100.0);
        hits.push(hit);
        rows.push(ResultRow::from_metrics(&label, &m).with("lambda", lambda));
    }
    // Rising trend with a possible high-λ plateau (once λ is large enough
    // to always chase hits, extra weight adds nothing but imbalance).
    let rising = hits.last().unwrap() > &(hits[0] + 0.03)
        && hits.iter().cloned().fold(0.0, f64::max) > hits[0] + 0.05;
    println!(
        "shape check: hit ratio rises with λ (plateau at high λ allowed): {}",
        if rising { "YES (matches paper)" } else { "NO" }
    );
    let path = save_results("fig09_weight_sweep", &rows, &[]).unwrap();
    println!("saved {}", path.display());
}
