//! Fig 22 — the headline end-to-end comparison: TTFT and TPOT CDFs of
//! LMETRIC vs BAILIAN (linear), vLLM, Dynamo and llm-d on four
//! workloads at half-capacity load.
//!
//! Paper shape: LMETRIC best-or-tied on every trace; on ChatBot it cuts
//! mean TTFT 92% and mean TPOT 24% vs vLLM and beats llm-d's P99 TPOT
//! by 13%.
//!
//! 20 independent 6000-request DES runs: fanned out through
//! `benchlib::parallel_sweep` (deterministic; `LMETRIC_BENCH_THREADS=1`
//! forces serial).

use lmetric::benchlib::{experiment, figure_banner, parallel_sweep, run_default, trace_for};
use lmetric::metrics::{render_table, save_results, ResultRow};

const WORKLOADS: [&str; 4] = ["chatbot", "coder", "agent", "toolagent"];
const POLICIES: [&str; 5] = ["vllm", "linear", "dynamo", "sim_llmd", "lmetric"];

fn main() {
    figure_banner("Fig 22", "end-to-end TTFT/TPOT CDFs, 5 policies × 4 workloads");
    let points = parallel_sweep(&WORKLOADS, |_, &workload| {
        let exp = experiment(workload, 8, 6000);
        let trace = trace_for(&exp);
        (exp, trace)
    });
    let mut run_defs = Vec::new();
    for pi in 0..points.len() {
        for name in POLICIES {
            run_defs.push((pi, name));
        }
    }
    let runs = parallel_sweep(&run_defs, |_, &(pi, name)| {
        let (exp, trace) = &points[pi];
        run_default(exp, trace, name)
    });

    for (wi, workload) in WORKLOADS.iter().enumerate() {
        let (exp, trace) = &points[wi];
        let mut rows = Vec::new();
        let mut cdfs = Vec::new();
        let mut stats = std::collections::BTreeMap::new();
        for (ki, name) in POLICIES.into_iter().enumerate() {
            // Index derived from the run_defs construction order above.
            let (m, label) = &runs[wi * POLICIES.len() + ki];
            cdfs.push((format!("ttft_{name}"), m.ttfts()));
            cdfs.push((format!("tpot_{name}"), m.tpots()));
            stats.insert(name, (m.ttft_summary(), m.tpot_summary()));
            rows.push(ResultRow::from_metrics(label, m));
        }
        println!(
            "{}",
            render_table(
                &format!(
                    "Fig 22 — {workload} ({} reqs @ {:.1} req/s, {} inst)",
                    trace.requests.len(),
                    trace.steady_rps(),
                    exp.instances
                ),
                &rows
            )
        );
        if *workload == "chatbot" {
            let lm = &stats["lmetric"];
            let vl = &stats["vllm"];
            let sd = &stats["sim_llmd"];
            println!(
                "headline: LMETRIC vs vLLM  TTFT −{:.0}% (paper 92%), TPOT −{:.0}% (paper 24%)",
                (1.0 - lm.0.mean / vl.0.mean) * 100.0,
                (1.0 - lm.1.mean / vl.1.mean) * 100.0
            );
            println!(
                "          LMETRIC vs llm-d P99 TPOT −{:.0}% (paper 13%)",
                (1.0 - lm.1.p99 / sd.1.p99) * 100.0
            );
        }
        let path = save_results(&format!("fig22_e2e_{workload}"), &rows, &cdfs).unwrap();
        println!("saved {}", path.display());
    }
}
