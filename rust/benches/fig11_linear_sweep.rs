//! Fig 11: the linear combination's hyperparameter pain — TTFT/TPOT
//! p50/p95 as λ sweeps, on all four traces.
//!
//! Paper shape: U-shaped curves with a workload-dependent knee (ChatBot
//! optimum ≈ 0.7, API/Agent ≈ 0.55, etc.) — no single λ wins everywhere.
//!
//! All (workload × λ) runs fan out through `benchlib::parallel_sweep`
//! (deterministic; `LMETRIC_BENCH_THREADS=1` forces serial).

use lmetric::benchlib::{experiment, figure_banner, parallel_sweep, run_policy, trace_for};
use lmetric::metrics::{fmt_s, save_results, ResultRow};

const WORKLOADS: [&str; 4] = ["chatbot", "coder", "agent", "toolagent"];
const LAMBDAS: [f64; 5] = [0.4, 0.55, 0.7, 0.85, 0.95];

fn main() {
    figure_banner("Fig 11", "linear-combination λ sweep across traces");
    let points = parallel_sweep(&WORKLOADS, |_, &workload| {
        let exp = experiment(workload, 8, 4000);
        let trace = trace_for(&exp);
        (exp, trace)
    });
    let mut run_defs = Vec::new();
    for pi in 0..points.len() {
        for l in LAMBDAS {
            run_defs.push((pi, l));
        }
    }
    let runs = parallel_sweep(&run_defs, |_, &(pi, l)| {
        let (exp, trace) = &points[pi];
        let (m, _) = run_policy(exp, trace, "linear", l);
        m
    });

    let mut all_rows = Vec::new();
    let mut best: Vec<(String, f64)> = Vec::new();
    for (wi, workload) in WORKLOADS.into_iter().enumerate() {
        println!(
            "\n{workload}:  {:>6} {:>10} {:>10} {:>10} {:>10}",
            "λ", "TTFT-p50", "TTFT-p95", "TPOT-p50", "TPOT-p95"
        );
        let mut best_l = (0.0, f64::INFINITY);
        for (li, l) in LAMBDAS.into_iter().enumerate() {
            // Index derived from the run_defs construction order above.
            let m = &runs[wi * LAMBDAS.len() + li];
            let (t, p) = (m.ttft_summary(), m.tpot_summary());
            println!(
                "        {l:>6.2} {:>10} {:>10} {:>10} {:>10}",
                fmt_s(t.p50),
                fmt_s(t.p95),
                fmt_s(p.p50),
                fmt_s(p.p95)
            );
            if t.mean < best_l.1 {
                best_l = (l, t.mean);
            }
            all_rows.push(
                ResultRow::from_metrics(&format!("{workload}/λ={l}"), m).with("lambda", l),
            );
        }
        println!("        best λ for {workload}: {}", best_l.0);
        best.push((workload.to_string(), best_l.0));
    }
    let distinct: std::collections::BTreeSet<String> =
        best.iter().map(|(_, l)| format!("{l}")).collect();
    println!(
        "\nshape check: optimal λ varies across workloads ({:?}): {}",
        best,
        if distinct.len() > 1 { "YES (matches paper)" } else { "NO — all identical" }
    );
    let path = save_results("fig11_linear_sweep", &all_rows, &[]).unwrap();
    println!("saved {}", path.display());
}
