//! Fig 20: the Eq. 2 regime check — per-window class popularity x/x̄ vs
//! cache coverage |M|/|M̄| for the top-hit classes of each trace.
//!
//! Paper shape: on all four production-like traces every sampled class
//! satisfies x/x̄ ≤ |M|/|M̄| (no KV$ hotspot can overload instances), so
//! the multiplicative score is in its benign regime.

use lmetric::benchlib::{experiment, figure_banner, run_boxed, trace_for};
use lmetric::hotspot::HotspotDetector;
use lmetric::metrics::{save_results, ResultRow};
use lmetric::policy::LMetric;
use lmetric::router::{Policy, RouteCtx, RouteDecision};

/// LMetric instrumented with the Eq. 2 monitor; records per-decision
/// (pop_ratio, cov_ratio) samples for requests with any KV$ hit.
struct RatioProbe {
    inner: LMetric,
    det: HotspotDetector,
    samples: Vec<(f64, f64)>,
}

impl Policy for RatioProbe {
    fn name(&self) -> String {
        "ratio_probe".into()
    }
    fn route(&mut self, ctx: &RouteCtx) -> RouteDecision {
        // Feed the detector's popularity window, then read the ratios.
        // Skip the first two minutes: class shares over a near-empty
        // window are noise (the same warm-up guard the detector uses).
        self.det.check(ctx, &self.inner);
        let m = HotspotDetector::m_set(ctx);
        if ctx.now_us > 120_000_000 && !m.is_empty() && m.len() < ctx.n() {
            let (pop, cov) = self.det.ratios(ctx);
            if pop.is_finite() {
                self.samples.push((pop, cov));
            }
        }
        self.inner.route(ctx)
    }
}

fn main() {
    figure_banner("Fig 20", "x/x̄ vs |M|/|M̄| across traces (Eq. 2 check)");
    let mut rows = Vec::new();
    for workload in ["chatbot", "coder", "agent", "toolagent"] {
        let exp = experiment(workload, 8, 4000);
        let trace = trace_for(&exp);
        let mut probe = RatioProbe {
            inner: LMetric::paper(),
            det: HotspotDetector::new(),
            samples: Vec::new(),
        };
        let m = run_boxed(&exp, &trace, &mut probe);
        let n = probe.samples.len().max(1);
        let violations = probe.samples.iter().filter(|(p, c)| p > c).count();
        let max_pop = probe.samples.iter().map(|(p, _)| *p).fold(0.0, f64::max);
        let min_cov = probe.samples.iter().map(|(_, c)| *c).fold(f64::MAX, f64::min);
        println!(
            "{workload:<10} samples {:>6}  max x/x̄ {:>6.2}  min |M|/|M̄| {:>6.2}  Eq.2 violations {:>5.2}%",
            n,
            max_pop,
            min_cov,
            violations as f64 / n as f64 * 100.0
        );
        rows.push(
            ResultRow::from_metrics(workload, &m)
                .with("violation_pct", violations as f64 / n as f64 * 100.0)
                .with("max_pop_ratio", max_pop),
        );
    }
    println!("\nshape check (paper): violations ≈ 0% on all non-adversarial traces.");
    let path = save_results("fig20_hotspot_ratios", &rows, &[]).unwrap();
    println!("saved {}", path.display());
}
