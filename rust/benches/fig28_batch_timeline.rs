//! Fig 28: running batch size across all instances under PolyServe vs
//! LMETRIC (ChatBot, moe-30b).
//!
//! Paper shape: PolyServe concentrates load (a gradient: some instances
//! loaded, a tail idle — headroom for auto-scaling); LMETRIC spreads the
//! same aggregate load evenly.

use lmetric::benchlib::{experiment, figure_banner, run_default, trace_for};
use lmetric::metrics::{save_results, ResultRow};
use lmetric::util::stats::stddev;

fn main() {
    figure_banner("Fig 28", "per-instance running batch size: PolyServe vs LMETRIC");
    let exp = experiment("chatbot", 8, 5000);
    let trace = trace_for(&exp);
    let mut rows = Vec::new();
    let mut spreads = std::collections::BTreeMap::new();
    for name in ["polyserve", "lmetric"] {
        let (m, label) = run_default(&exp, &trace, name);
        // Mean running BS per instance over the run.
        let mut means: Vec<(usize, f64)> = m
            .batch_size
            .iter()
            .enumerate()
            .map(|(i, w)| {
                let ms = w.means();
                let valid: Vec<f64> = ms.iter().cloned().filter(|x| !x.is_nan()).collect();
                (i, valid.iter().sum::<f64>() / valid.len().max(1) as f64)
            })
            .collect();
        means.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        println!("\n{label}: mean running BS per instance (sorted):");
        for (i, bs) in &means {
            println!("  inst {i:>2}: {bs:>6.2} {}", "#".repeat((bs * 2.0) as usize));
        }
        let values: Vec<f64> = means.iter().map(|(_, b)| *b).collect();
        let sd = stddev(&values);
        println!("  cross-instance stddev: {sd:.2}");
        spreads.insert(name, sd);
        rows.push(ResultRow::from_metrics(&label, &m).with("bs_stddev", sd));
    }
    println!(
        "\nshape check: PolyServe gradient vs LMETRIC even spread (stddev ratio {:.1}x): {}",
        spreads["polyserve"] / spreads["lmetric"].max(1e-9),
        if spreads["polyserve"] > spreads["lmetric"] * 1.5 { "YES (matches paper)" } else { "NO" }
    );
    let path = save_results("fig28_batch_timeline", &rows, &[]).unwrap();
    println!("saved {}", path.display());
}
