//! Fig 24: KV$ hit-ratio comparison across policies (ChatBot, moe-30b).
//!
//! Paper shape: LMETRIC's hit ratio ≈ the other KV$-aware policies and
//! far above the KV$-unaware one (vLLM), stable over time.

use lmetric::benchlib::{experiment, figure_banner, run_default, trace_for};
use lmetric::metrics::{save_results, ResultRow};

fn main() {
    figure_banner("Fig 24", "KV$ hit ratio per policy over time (ChatBot)");
    let exp = experiment("chatbot", 8, 5000);
    let trace = trace_for(&exp);
    let mut rows = Vec::new();
    let mut hits = std::collections::BTreeMap::new();
    for name in ["vllm", "linear", "dynamo", "sim_llmd", "lmetric"] {
        let (m, label) = run_default(&exp, &trace, name);
        let tl = m.hit_ratio_timeline();
        let series: Vec<String> = tl
            .means()
            .iter()
            .take(10)
            .map(|h| if h.is_nan() { " -".into() } else { format!("{:>3.0}", h * 100.0) })
            .collect();
        println!(
            "{label:<22} mean {:>5.1}%  per-min: {}",
            m.mean_hit_ratio() * 100.0,
            series.join(" ")
        );
        hits.insert(name, m.mean_hit_ratio());
        rows.push(ResultRow::from_metrics(&label, &m));
    }
    let kv_aware_min = ["linear", "dynamo", "sim_llmd", "lmetric"]
        .iter()
        .map(|n| hits[*n])
        .fold(f64::MAX, f64::min);
    println!(
        "\nshape checks: lmetric within 10pp of best KV$-aware: {} | all KV$-aware ≫ vllm: {}",
        hits["lmetric"] + 0.10 >= hits.values().cloned().fold(0.0, f64::max),
        kv_aware_min > hits["vllm"] + 0.1
    );
    let path = save_results("fig24_hit_ratio", &rows, &[]).unwrap();
    println!("saved {}", path.display());
}
