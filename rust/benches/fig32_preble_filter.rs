//! Fig 32 (appendix A.1): Preble with (T=0.5) and without (T=1) its
//! KV$-aware filter branch.
//!
//! Paper shape: the filter gives a measurable but modest improvement —
//! Preble's behaviour is dominated by its linear-combination fallback.

use lmetric::benchlib::{experiment, figure_banner, run_policy, trace_for};
use lmetric::metrics::{render_table, save_results, ResultRow};

fn main() {
    figure_banner("Fig 32", "Preble with vs without the KV$-aware filter");
    let exp = experiment("chatbot", 8, 5000);
    let trace = trace_for(&exp);
    let (with, _) = run_policy(&exp, &trace, "preble", 0.5);
    let (without, _) = run_policy(&exp, &trace, "preble", 1.0);
    let rows = vec![
        ResultRow::from_metrics("preble T=0.5 (filter on)", &with),
        ResultRow::from_metrics("preble T=1.0 (filter off)", &without),
    ];
    println!("{}", render_table("Fig 32", &rows));
    let gain = 1.0 - with.ttft_summary().mean / without.ttft_summary().mean;
    println!(
        "shape check: the KV$ filter contributes a measurable improvement: {}",
        if gain > 0.0 { "YES" } else { "NO" }
    );
    println!(
        "note: TTFT −{:.0}% here vs a modest gain in the paper — with our traces'\n\
         higher prefix share the filter branch carries most of Preble's KV$\n\
         awareness (Fig 27), so disabling it costs more than on the production\n\
         traces where the windowed-linear fallback dominated.",
        gain * 100.0
    );
    let path = save_results("fig32_preble_filter", &rows, &[]).unwrap();
    println!("saved {}", path.display());
}
