//! Fig 41: closed-loop session workloads — does P-token capture session
//! affinity *for free*?
//!
//! For each session archetype (chat / API calls / coding agents, the
//! paper's claimed deployment mix) the sweep replays the same reactive
//! trace under the session-aware baselines (explicit `sticky` pinning,
//! the SMetric-style `smetric` balanced session scheduler), the
//! KV$-blind `vllm` load balancer, and plain `lmetric` /
//! `lmetric_safe`. The bench asserts the headline: the multiplicative
//! score earns high session affinity and prefix reuse *without* a
//! session id, and matches-or-beats explicit pinning on TTFT (pinning
//! gets reuse by construction but cannot shed load).

use lmetric::benchlib::{figure_banner, parallel_sweep, scaled};
use lmetric::cluster::{build_scaled_sessions, run_session_des, ClusterConfig};
use lmetric::engine::{EngineConfig, ModelProfile};
use lmetric::metrics::{fmt_s, save_results, ResultRow, RunMetrics, SessionMetrics};
use lmetric::policy;
use lmetric::trace::{SessionKind, SessionSpec};

const POLICIES: [&str; 5] = ["vllm", "sticky", "smetric", "lmetric", "lmetric_safe"];

fn main() {
    figure_banner(
        "Fig 41",
        "closed-loop session sweep: session-aware baselines vs plain LMETRIC",
    );
    let profile = ModelProfile::moe_30b();
    let cfg = ClusterConfig::new(8, EngineConfig::default());
    let mut rows: Vec<ResultRow> = Vec::new();

    for kind in [SessionKind::Chat, SessionKind::ApiCall, SessionKind::CodingAgent] {
        let spec = SessionSpec::preset(kind, scaled(3000), 41);
        let strace = build_scaled_sessions(&spec, &cfg, 0.5);
        println!(
            "\n--- {} ({} sessions, {} turns) ---",
            kind.name(),
            strace.sessions.len(),
            strace.n_turns()
        );
        let results: Vec<(RunMetrics, SessionMetrics)> = parallel_sweep(&POLICIES, |_, name| {
            let mut pol = policy::build_default(name, &profile, 256).unwrap();
            let m = run_session_des(&cfg, &strace, pol.as_mut());
            let sm = SessionMetrics::collect(&m, &strace);
            (m, sm)
        });
        for (name, (m, sm)) in POLICIES.iter().zip(&results) {
            assert_eq!(m.records.len(), strace.n_turns(), "{name} lost turns");
            println!(
                "{:<14} TTFT {:>8}  session-TTFT {:>8}  affinity {:>5.1}%  \
                 turn0 hit {:>5.1}%  warm hit {:>5.1}%",
                name,
                fmt_s(sm.turn_ttft.mean),
                fmt_s(sm.session_mean_ttft.p50),
                sm.affinity_ratio() * 100.0,
                sm.turn0_hit() * 100.0,
                sm.late_turn_hit() * 100.0
            );
            rows.push(
                ResultRow::from_metrics(&format!("{}_{name}", kind.name()), m)
                    .with("affinity", sm.affinity_ratio())
                    .with("turn0_hit", sm.turn0_hit())
                    .with("late_turn_hit", sm.late_turn_hit())
                    .with("session_ttft_p50", sm.session_mean_ttft.p50)
                    .with("session_span_p50", sm.session_span_s.p50),
            );
        }
        let of = |name: &str| &results[POLICIES.iter().position(|p| *p == name).unwrap()];
        let (m_vllm, _) = of("vllm");
        let (_, sm_sticky) = of("sticky");
        let (m_lm, sm_lm) = of("lmetric");
        // Pinning is perfect by construction; smetric's TTL never fires
        // at these think times.
        assert!(
            (sm_sticky.affinity_ratio() - 1.0).abs() < 1e-12,
            "{}: sticky affinity must be 1.0",
            kind.name()
        );
        assert!(
            of("smetric").1.affinity_ratio() > 0.99,
            "{}: smetric must stay sticky",
            kind.name()
        );
        // The headline: P-token earns affinity and reuse with no session
        // id, and explicit pinning buys no TTFT advantage over it.
        if sm_lm.affinity_total > 0 {
            assert!(
                sm_lm.affinity_ratio() > 0.5,
                "{}: lmetric affinity {} too low",
                kind.name(),
                sm_lm.affinity_ratio()
            );
        }
        assert!(
            m_lm.mean_hit_ratio() > m_vllm.mean_hit_ratio() + 0.02,
            "{}: lmetric hit {} must beat KV$-blind vllm {}",
            kind.name(),
            m_lm.mean_hit_ratio(),
            m_vllm.mean_hit_ratio()
        );
        assert!(
            sm_lm.turn_ttft.mean <= sm_sticky.turn_ttft.mean * 1.25,
            "{}: lmetric TTFT {} must match-or-beat sticky {} (within slop)",
            kind.name(),
            sm_lm.turn_ttft.mean,
            sm_sticky.turn_ttft.mean
        );
    }

    let path = save_results("fig41_session_sweep", &rows, &[]).unwrap();
    println!("\nsaved {}", path.display());
}
