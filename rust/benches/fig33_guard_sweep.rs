//! Fig 33: the failure-condition guard under its own failure regimes.
//!
//! Part A sweeps the cross-spread window directly on crafted router
//! snapshots: for every (KV-spread × load-spread) grid point it
//! measures the analytically predicted misranking fraction (breakpoint
//! oracle, [`window_slack`]), the detector's detection rate against it
//! (must be 100% of non-borderline predictions — asserted), and the
//! false-positive rate. A degenerate-tie sweep measures the secondary
//! key's mitigation: every re-ranked tie must gain (never lose) cached
//! prefix tokens.
//!
//! Part B replays the adversarial DES traces (idle-fleet bursts,
//! shared-prefix floods, spread stress) under plain LMETRIC vs the
//! guarded policy and records the guard counters plus the TTFT delta
//! of mitigation — non-negative by construction, since on
//! DES-reachable states the guard's overrides are confined to exact
//! ties it re-ranks toward max cache reuse.

use lmetric::benchlib::{figure_banner, parallel_sweep, scaled};
use lmetric::cluster::{run_des, ClusterConfig};
use lmetric::engine::EngineConfig;
use lmetric::metrics::{fmt_s, save_results, ResultRow, RunMetrics};
use lmetric::policy::{
    window_slack, FailureAnalyzer, GuardedLMetric, INVERSION_MARGIN, LMetric, W_HI, W_LO,
};
use lmetric::router::{select_min, Policy};
use lmetric::trace::adversarial::{degenerate_tie_ctx, spread_route_ctx};
use lmetric::trace::{generate_adversarial, AdversarialScenario, AdversarialSpec};
use lmetric::util::Rng;

/// Oracle slack below which a misranking counts as analytically
/// predicted; |slack| below it is borderline and skipped.
const SLACK_EPS: f64 = 1e-7;

struct SweepPoint {
    kv_spread: f64,
    load_spread: f64,
    cases: usize,
    predicted: usize,
    detected: usize,
    false_pos: usize,
    degenerate: usize,
    borderline: usize,
}

fn sweep_point(kv_spread: f64, load_spread: f64, cases: usize, seed: u64) -> SweepPoint {
    let mut rng = Rng::new(seed ^ 0xf1633);
    let score = LMetric::paper();
    let analyzer = FailureAnalyzer::default();
    let mut out = SweepPoint {
        kv_spread,
        load_spread,
        cases,
        predicted: 0,
        detected: 0,
        false_pos: 0,
        degenerate: 0,
        borderline: 0,
    };
    for _ in 0..cases {
        let ctx = spread_route_ctx(&mut rng, 8, 4096, kv_spread, load_spread);
        let p = select_min(&ctx, |i| score.score(&ctx, i));
        let v = analyzer.analyze(&ctx, &score, p);
        if v.degenerate() {
            out.degenerate += 1;
            continue; // the envelope question is posed on non-degenerate states
        }
        let kv: Vec<f64> = (0..ctx.n()).map(|i| score.factors(&ctx, i).0).collect();
        let ld: Vec<f64> = (0..ctx.n()).map(|i| score.factors(&ctx, i).1).collect();
        let slack = window_slack(&kv, &ld, p, W_LO, W_HI, INVERSION_MARGIN);
        if slack.abs() < SLACK_EPS {
            out.borderline += 1;
            continue;
        }
        if slack < 0.0 {
            out.predicted += 1;
            if v.inversion {
                out.detected += 1;
            }
        } else if v.inversion {
            out.false_pos += 1;
        }
    }
    out
}

fn main() {
    figure_banner(
        "Fig 33",
        "failure-condition guard: spread-window sweep + adversarial DES replay",
    );
    let cases = if lmetric::benchlib::quick_mode() { 120 } else { 400 };
    let mut rows: Vec<ResultRow> = Vec::new();

    // ---------------- Part A: the spread window ------------------------
    println!("\n--- spread-window sweep ({cases} snapshots per point) ---");
    println!(
        "{:>8} {:>8} {:>10} {:>10} {:>9} {:>10}",
        "kv", "load", "predicted", "detected", "falsepos", "degenerate"
    );
    let mut grid: Vec<(f64, f64)> = Vec::new();
    for &ks in &[1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0] {
        for &ls in &[1.0, 4.0, 16.0, 64.0] {
            grid.push((ks, ls));
        }
    }
    let points = parallel_sweep(&grid, |i, &(ks, ls)| sweep_point(ks, ls, cases, i as u64));
    let mut total_predicted = 0usize;
    let mut total_detected = 0usize;
    for p in &points {
        assert_eq!(
            p.detected, p.predicted,
            "detector must catch every non-borderline predicted misranking \
             (and only those) at kv={} load={}",
            p.kv_spread, p.load_spread
        );
        assert_eq!(
            p.false_pos, 0,
            "no false positives at kv={} load={}",
            p.kv_spread, p.load_spread
        );
        total_predicted += p.predicted;
        total_detected += p.detected;
        println!(
            "{:>7}x {:>7}x {:>10} {:>10} {:>9} {:>10}",
            p.kv_spread, p.load_spread, p.predicted, p.detected, p.false_pos, p.degenerate
        );
        let denom = p.cases.max(1) as f64;
        rows.push(
            ResultRow::from_metrics(
                &format!("sweep_kv{}x_load{}x", p.kv_spread, p.load_spread),
                &RunMetrics::new(1),
            )
            .with("predicted_frac", p.predicted as f64 / denom)
            .with("detected_frac", p.detected as f64 / denom)
            .with("false_pos", p.false_pos as f64)
            .with("borderline", p.borderline as f64),
        );
    }
    println!(
        "\ndetection: {total_detected}/{total_predicted} analytically predicted \
         misrankings caught (>= predicted fraction: {})",
        if total_detected >= total_predicted { "YES" } else { "NO" }
    );

    // Degenerate-tie mitigation: the secondary key may only move a tied
    // decision toward MORE cached prefix.
    let mut rng = Rng::new(4242);
    let mut guarded = GuardedLMetric::new();
    let mut plain = LMetric::paper();
    let (mut ties, mut moved, mut hit_gain_tokens) = (0usize, 0usize, 0i64);
    for _ in 0..cases {
        let ctx = degenerate_tie_ctx(&mut rng, 8, 2048);
        let g = guarded.route(&ctx).instance;
        let p = plain.route(&ctx).instance;
        ties += 1;
        if g != p {
            moved += 1;
        }
        let gain = ctx.hit_tokens[g] as i64 - ctx.hit_tokens[p] as i64;
        assert!(gain >= 0, "tie re-rank must never lose cached prefix");
        hit_gain_tokens += gain;
    }
    println!(
        "degenerate ties: {moved}/{ties} re-ranked, mean prefix gain {:.0} tokens",
        hit_gain_tokens as f64 / ties.max(1) as f64
    );
    assert!(moved > 0, "crafted ties must exercise the secondary key");
    assert_eq!(guarded.counters.degenerate, ties as u64);
    assert_eq!(guarded.counters.mitigated, moved as u64);
    rows.push(
        ResultRow::from_metrics("degenerate_tie_mitigation", &RunMetrics::new(1))
            .with("ties", ties as f64)
            .with("mitigated", moved as f64)
            .with("mean_hit_gain_tokens", hit_gain_tokens as f64 / ties.max(1) as f64),
    );

    // ---------------- Part B: adversarial DES replay --------------------
    println!("\n--- adversarial DES traces (8 instances) ---");
    let cfg = ClusterConfig::new(8, EngineConfig::default());
    for scenario in [
        AdversarialScenario::IdleFleetBurst,
        AdversarialScenario::SharedPrefixFlood,
        AdversarialScenario::SpreadStress,
    ] {
        let spec = AdversarialSpec::preset(scenario, scaled(1500), 17);
        let trace = generate_adversarial(&spec);
        let mut plain = lmetric::policy::build("lmetric", 0.0, &cfg.engine.profile, 256).unwrap();
        let m_plain = run_des(&cfg, &trace, plain.as_mut());
        let mut guarded = GuardedLMetric::new();
        let m_guard = run_des(&cfg, &trace, &mut guarded);
        assert_eq!(m_guard.guard, guarded.counters, "counters must flow into RunMetrics");
        assert_eq!(
            m_guard.guard.checks,
            trace.requests.len() as u64,
            "one guard check per routed request"
        );
        let ttft_delta = m_plain.ttft_summary().mean - m_guard.ttft_summary().mean;
        assert!(
            ttft_delta >= -1e-9,
            "{}: mitigation must not regress TTFT (delta {ttft_delta})",
            scenario.name()
        );
        println!(
            "{:<22} checks {:>6}  degenerate {:>6}  inversion {:>6}  mitigated {:>4}  \
             TTFT {} -> {} (improvement {:+.1}ms)",
            scenario.name(),
            m_guard.guard.checks,
            m_guard.guard.degenerate,
            m_guard.guard.inversion,
            m_guard.guard.mitigated,
            fmt_s(m_plain.ttft_summary().mean),
            fmt_s(m_guard.ttft_summary().mean),
            ttft_delta * 1e3
        );
        match scenario {
            AdversarialScenario::IdleFleetBurst | AdversarialScenario::SharedPrefixFlood => {
                assert!(
                    m_guard.guard.degenerate > 0,
                    "{}: degenerate regime must be detected",
                    scenario.name()
                );
            }
            AdversarialScenario::SpreadStress => {}
        }
        rows.push(
            ResultRow::from_metrics(&format!("des_{}", scenario.name()), &m_guard)
                .with("guard_checks", m_guard.guard.checks as f64)
                .with("guard_degenerate", m_guard.guard.degenerate as f64)
                .with("guard_inversion", m_guard.guard.inversion as f64)
                .with("guard_mitigated", m_guard.guard.mitigated as f64)
                .with("ttft_improvement_s", ttft_delta),
        );
    }

    let path = save_results("fig33_guard_sweep", &rows, &[]).unwrap();
    println!("saved {}", path.display());
}
