//! Fig 21: the adversarial KV$-hotspot case study — a burst of one class
//! with a long shared prefix, cached on few instances. (a) the Eq. 2
//! violation appears in the hot window; (b–c) bare LMETRIC loses to a
//! load-balance-only policy during the window, and the two-phase
//! detector (lmetric_guarded) recovers.

use lmetric::benchlib::{experiment, figure_banner, run_boxed, run_default, trace_for};
use lmetric::hotspot::HotspotGuarded;
use lmetric::metrics::{fmt_s, save_results, ResultRow};
use lmetric::util::stats::Summary;

fn main() {
    figure_banner("Fig 21", "adversarial hotspot: LMETRIC vs LB-only vs guarded");
    let exp = experiment("hotspot", 8, 6000);
    let trace = trace_for(&exp);
    let hot_class = 12u32;
    // The window by arrival time of hot-class requests.
    let hot_times: Vec<u64> = trace
        .requests
        .iter()
        .filter(|r| r.req.class_id == hot_class)
        .map(|r| r.req.arrival_us)
        .collect();
    let (w_lo, w_hi) = (
        *hot_times.iter().min().unwrap(),
        *hot_times.iter().max().unwrap(),
    );
    println!(
        "hot window: {:.0}s .. {:.0}s ({} hot requests of {})",
        w_lo as f64 / 1e6,
        w_hi as f64 / 1e6,
        hot_times.len(),
        trace.requests.len()
    );

    let mut rows = Vec::new();
    let mut window_ttft = std::collections::BTreeMap::new();
    let (m_v, _) = run_default(&exp, &trace, "vllm");
    let (m_l, _) = run_default(&exp, &trace, "lmetric");
    let mut guarded = HotspotGuarded::new();
    let m_g = run_boxed(&exp, &trace, &mut guarded);
    println!(
        "detector: {} phase-1 alarms, {} mitigations",
        guarded.detector.phase1_alarms, guarded.detector.mitigations
    );
    for (label, m) in [("vllm (LB-only)", &m_v), ("lmetric", &m_l), ("lmetric_guarded", &m_g)] {
        let in_w: Vec<f64> = m
            .records
            .iter()
            .filter(|r| r.arrival_us >= w_lo && r.arrival_us <= w_hi && r.output_len > 1)
            .map(|r| r.tpot_s())
            .collect();
        let in_w_ttft: Vec<f64> = m
            .records
            .iter()
            .filter(|r| r.arrival_us >= w_lo && r.arrival_us <= w_hi)
            .map(|r| r.ttft_s())
            .collect();
        let s = Summary::of(&in_w);
        let st = Summary::of(&in_w_ttft);
        println!(
            "{label:<18} in-window TPOT mean {} p95 {} | TTFT mean {} | overall TPOT {}",
            fmt_s(s.mean),
            fmt_s(s.p95),
            fmt_s(st.mean),
            fmt_s(m.tpot_summary().mean)
        );
        window_ttft.insert(label.to_string(), (s.mean, st.mean));
        rows.push(
            ResultRow::from_metrics(label, m)
                .with("window_tpot_mean", s.mean)
                .with("window_ttft_mean", st.mean)
                .with("imbalance_s", m.imbalance_score()),
        );
    }
    // The pile-on mechanism itself: how concentrated is the running batch
    // across instances during the hot window?
    let concentration = |m: &lmetric::metrics::RunMetrics| -> f64 {
        let lo_w = (w_lo / 1_000_000) as usize;
        let hi_w = (w_hi / 1_000_000) as usize;
        let means: Vec<f64> = m
            .batch_size
            .iter()
            .map(|w| {
                let ms = w.means();
                let in_w: Vec<f64> = ms
                    .iter()
                    .enumerate()
                    .filter(|(i, v)| *i >= lo_w && *i <= hi_w && !v.is_nan())
                    .map(|(_, v)| *v)
                    .collect();
                in_w.iter().sum::<f64>() / in_w.len().max(1) as f64
            })
            .collect();
        let max = means.iter().cloned().fold(0.0, f64::max);
        let mean = means.iter().sum::<f64>() / means.len() as f64;
        max / mean.max(1e-9) // 1.0 = perfectly even; >>1 = pile-on
    };
    let c_l = concentration(&m_l);
    let c_v = concentration(&m_v);
    let c_g = concentration(&m_g);
    println!("\nin-window batch concentration (max/mean instance BS):");
    println!("  vllm {c_v:.2}   lmetric {c_l:.2}   guarded {c_g:.2}");
    println!(
        "\nshape checks: lmetric concentrates the thinking burst (pile-on ≫ LB-only): {}",
        if c_l > c_v + 0.1 { "YES (the §5.2 mechanism)" } else { "NO" }
    );
    println!(
        "              detector fires on the burst: {}",
        if guarded.detector.mitigations > 0 { "YES" } else { "NO" }
    );
    println!(
        "              guarded reduces the concentration: {}",
        if c_g < c_l { "YES" } else { "NO" }
    );
    let lm = window_ttft["lmetric"];
    let vl = window_ttft["vllm (LB-only)"];
    println!(
        "\nnote: unlike the paper's production case, bare LMETRIC does not fall\n\
         behind LB-only here (in-window TPOT {} vs {}), because on this cost\n\
         substrate the 4k-prefix KV$ saving outweighs the decode imbalance it\n\
         causes; the pile-on and the detector behaviour — the §5.2 mechanism —\n\
         do reproduce (see EXPERIMENTS.md).",
        fmt_s(lm.0),
        fmt_s(vl.0)
    );
    let path = save_results("fig21_adversarial", &rows, &[]).unwrap();
    println!("saved {}", path.display());
}
