//! Fig 12: the filter-based combination's hyperparameter pain — Range
//! sweep {2,4,8,16} on all four traces, with the tuned linear baseline
//! (BL) for comparison.
//!
//! Paper shape: the optimal Range differs per workload, and filter-based
//! stays at-or-behind a well-tuned linear combination.
//!
//! All (workload × policy-point) runs fan out through
//! `benchlib::parallel_sweep` (deterministic; `LMETRIC_BENCH_THREADS=1`
//! forces serial).

use lmetric::benchlib::{experiment, figure_banner, parallel_sweep, run_policy, trace_for};
use lmetric::metrics::{fmt_s, save_results, ResultRow};

const WORKLOADS: [&str; 4] = ["chatbot", "coder", "agent", "toolagent"];
const RANGES: [f64; 4] = [2.0, 4.0, 8.0, 16.0];

fn main() {
    figure_banner("Fig 12", "filter-based Range sweep vs tuned linear (BL)");
    let points = parallel_sweep(&WORKLOADS, |_, &workload| {
        let exp = experiment(workload, 8, 4000);
        let trace = trace_for(&exp);
        (exp, trace)
    });
    // Per workload: one tuned-linear baseline run + the Range sweep.
    let mut run_defs = Vec::new();
    for pi in 0..points.len() {
        run_defs.push((pi, "linear", 0.7));
        for range in RANGES {
            run_defs.push((pi, "filter_kv", range));
        }
    }
    let runs = parallel_sweep(&run_defs, |_, &(pi, name, param)| {
        let (exp, trace) = &points[pi];
        let (m, _) = run_policy(exp, trace, name, param);
        m
    });

    let mut all_rows = Vec::new();
    let mut filter_never_beats_bl = true;
    let mut range_matters_somewhere = false;
    // Per-workload stride in run_defs: 1 BL run + the Range sweep.
    let stride = 1 + RANGES.len();
    for (wi, workload) in WORKLOADS.into_iter().enumerate() {
        let bl = &runs[wi * stride];
        println!(
            "\n{workload}:  {:>8} {:>10} {:>10} {:>10} {:>10}",
            "Range", "TTFT-p50", "TTFT-p95", "TPOT-p50", "TPOT-p95"
        );
        println!(
            "        {:>8} {:>10} {:>10} {:>10} {:>10}   (tuned linear)",
            "BL",
            fmt_s(bl.ttft_summary().p50),
            fmt_s(bl.ttft_summary().p95),
            fmt_s(bl.tpot_summary().p50),
            fmt_s(bl.tpot_summary().p95)
        );
        let mut best_filter = f64::INFINITY;
        let mut worst_filter: f64 = 0.0;
        for (ki, range) in RANGES.into_iter().enumerate() {
            let m = &runs[wi * stride + 1 + ki];
            let (t, p) = (m.ttft_summary(), m.tpot_summary());
            println!(
                "        {range:>8.0} {:>10} {:>10} {:>10} {:>10}",
                fmt_s(t.p50),
                fmt_s(t.p95),
                fmt_s(p.p50),
                fmt_s(p.p95)
            );
            best_filter = best_filter.min(t.mean);
            worst_filter = worst_filter.max(t.mean);
            all_rows.push(
                ResultRow::from_metrics(&format!("{workload}/range={range}"), m)
                    .with("range", range),
            );
        }
        // "Never meaningfully beats": within 10% counts as a tie.
        if best_filter < bl.ttft_summary().mean * 0.9 {
            filter_never_beats_bl = false;
        }
        if worst_filter > best_filter * 1.5 {
            range_matters_somewhere = true;
        }
        all_rows.push(ResultRow::from_metrics(&format!("{workload}/BL"), bl));
    }
    println!(
        "\nshape checks: Range is workload-sensitive (≥1.5x spread somewhere): {}",
        if range_matters_somewhere {
            "YES (matches paper: Coder 4→16 improves sharply)"
        } else {
            "NO"
        }
    );
    println!(
        "              filter-based never meaningfully beats tuned linear: {}",
        if filter_never_beats_bl { "YES (matches paper)" } else { "NO" }
    );
    let path = save_results("fig12_filter_sweep", &all_rows, &[]).unwrap();
    println!("saved {}", path.display());
}
