//! Fig 12: the filter-based combination's hyperparameter pain — Range
//! sweep {2,4,8,16} on all four traces, with the tuned linear baseline
//! (BL) for comparison.
//!
//! Paper shape: the optimal Range differs per workload, and filter-based
//! stays at-or-behind a well-tuned linear combination.

use lmetric::benchlib::{experiment, figure_banner, run_policy, trace_for};
use lmetric::metrics::{fmt_s, save_results, ResultRow};

fn main() {
    figure_banner("Fig 12", "filter-based Range sweep vs tuned linear (BL)");
    let mut all_rows = Vec::new();
    let mut filter_never_beats_bl = true;
    let mut range_matters_somewhere = false;
    for workload in ["chatbot", "coder", "agent", "toolagent"] {
        let exp = experiment(workload, 8, 4000);
        let trace = trace_for(&exp);
        let (bl, _) = run_policy(&exp, &trace, "linear", 0.7);
        println!(
            "\n{workload}:  {:>8} {:>10} {:>10} {:>10} {:>10}",
            "Range", "TTFT-p50", "TTFT-p95", "TPOT-p50", "TPOT-p95"
        );
        println!(
            "        {:>8} {:>10} {:>10} {:>10} {:>10}   (tuned linear)",
            "BL",
            fmt_s(bl.ttft_summary().p50),
            fmt_s(bl.ttft_summary().p95),
            fmt_s(bl.tpot_summary().p50),
            fmt_s(bl.tpot_summary().p95)
        );
        let mut best_filter = f64::INFINITY;
        let mut worst_filter: f64 = 0.0;
        for range in [2.0, 4.0, 8.0, 16.0] {
            let (m, _) = run_policy(&exp, &trace, "filter_kv", range);
            let (t, p) = (m.ttft_summary(), m.tpot_summary());
            println!(
                "        {range:>8.0} {:>10} {:>10} {:>10} {:>10}",
                fmt_s(t.p50),
                fmt_s(t.p95),
                fmt_s(p.p50),
                fmt_s(p.p95)
            );
            best_filter = best_filter.min(t.mean);
            worst_filter = worst_filter.max(t.mean);
            all_rows.push(
                ResultRow::from_metrics(&format!("{workload}/range={range}"), &m)
                    .with("range", range),
            );
        }
        // "Never meaningfully beats": within 10% counts as a tie.
        if best_filter < bl.ttft_summary().mean * 0.9 {
            filter_never_beats_bl = false;
        }
        if worst_filter > best_filter * 1.5 {
            range_matters_somewhere = true;
        }
        all_rows.push(ResultRow::from_metrics(&format!("{workload}/BL"), &bl));
    }
    println!(
        "\nshape checks: Range is workload-sensitive (≥1.5x spread somewhere): {}",
        if range_matters_somewhere {
            "YES (matches paper: Coder 4→16 improves sharply)"
        } else {
            "NO"
        }
    );
    println!(
        "              filter-based never meaningfully beats tuned linear: {}",
        if filter_never_beats_bl { "YES (matches paper)" } else { "NO" }
    );
    let path = save_results("fig12_filter_sweep", &all_rows, &[]).unwrap();
    println!("saved {}", path.display());
}
