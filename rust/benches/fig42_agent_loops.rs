//! Fig 42: coding-agent loops under the closed loop — how prefix reuse
//! compounds turn over turn.
//!
//! Replays a long-loop coding-agent session trace (chunky tool results,
//! machine-paced think times, deep turn chains) and profiles the
//! *per-turn* prefix-hit curve and TTFT by turn depth under a KV$-blind
//! balancer (`vllm`), explicit pinning (`sticky`) and plain `lmetric`.
//! Asserted shape: under `lmetric` the hit curve rises sharply after the
//! cold first turn (reactive release guarantees the previous context is
//! cached *somewhere*; P-token steers the turn back to it), far above
//! what load-only routing achieves on the identical trace.

use lmetric::benchlib::{figure_banner, parallel_sweep, scaled};
use lmetric::cluster::{build_scaled_sessions, run_session_des, ClusterConfig};
use lmetric::engine::{EngineConfig, ModelProfile};
use lmetric::metrics::{
    fmt_s, save_results, ResultRow, RunMetrics, SessionMetrics, TURN_CURVE_CAP,
};
use lmetric::policy;
use lmetric::trace::{SessionKind, SessionSpec};
use lmetric::util::stats::Summary;

const POLICIES: [&str; 3] = ["vllm", "sticky", "lmetric"];

fn main() {
    figure_banner("Fig 42", "coding-agent loops: per-turn prefix-hit compounding");
    let profile = ModelProfile::moe_30b();
    let cfg = ClusterConfig::new(8, EngineConfig::default());
    let mut spec = SessionSpec::preset(SessionKind::CodingAgent, scaled(2500), 42);
    spec.mean_turns = 12.0; // deep loops: the curve's tail is the point
    let strace = build_scaled_sessions(&spec, &cfg, 0.5);
    println!(
        "{} sessions, {} turns, mean {:.1} turns/session",
        strace.sessions.len(),
        strace.n_turns(),
        strace.n_turns() as f64 / strace.sessions.len() as f64
    );

    let results: Vec<(RunMetrics, SessionMetrics)> = parallel_sweep(&POLICIES, |_, name| {
        let mut pol = policy::build_default(name, &profile, 256).unwrap();
        let m = run_session_des(&cfg, &strace, pol.as_mut());
        let sm = SessionMetrics::collect(&m, &strace);
        (m, sm)
    });

    // Per-turn TTFT by depth (bucketed like the hit curve), per policy.
    let turn_of = strace.turn_index();
    let mut rows: Vec<ResultRow> = Vec::new();
    for (name, (m, sm)) in POLICIES.iter().zip(&results) {
        assert_eq!(m.records.len(), strace.n_turns(), "{name} lost turns");
        let mut ttft_by_turn: Vec<Vec<f64>> = vec![Vec::new(); TURN_CURVE_CAP];
        for r in &m.records {
            let (_, ti) = turn_of[&r.id];
            ttft_by_turn[ti.min(TURN_CURVE_CAP - 1)].push(r.ttft_s());
        }
        println!("\n--- {name} (affinity {:.1}%) ---", sm.affinity_ratio() * 100.0);
        println!("{:>6} {:>8} {:>10} {:>8}", "turn", "n", "hit", "TTFT");
        for ti in 0..TURN_CURVE_CAP {
            if sm.turn_hit_counts[ti] == 0 {
                continue;
            }
            let t = Summary::of(&ttft_by_turn[ti]);
            println!(
                "{:>6} {:>8} {:>9.1}% {:>8}",
                if ti == TURN_CURVE_CAP - 1 {
                    format!("{ti}+")
                } else {
                    ti.to_string()
                },
                sm.turn_hit_counts[ti],
                sm.turn_hit_curve[ti] * 100.0,
                fmt_s(t.mean)
            );
        }
        rows.push(
            ResultRow::from_metrics(&format!("agent_{name}"), m)
                .with("affinity", sm.affinity_ratio())
                .with("turn0_hit", sm.turn0_hit())
                .with("late_turn_hit", sm.late_turn_hit())
                .with("turn_ttft_mean", sm.turn_ttft.mean),
        );
    }

    let of = |name: &str| &results[POLICIES.iter().position(|p| *p == name).unwrap()];
    let (_, sm_vllm) = of("vllm");
    let (_, sm_lm) = of("lmetric");
    // The curve must rise after the cold entry turn, for every early
    // depth with a meaningful sample.
    for ti in 1..6 {
        if sm_lm.turn_hit_counts[ti] >= 20 {
            assert!(
                sm_lm.turn_hit_curve[ti] > sm_lm.turn0_hit(),
                "lmetric turn {ti} hit {} must beat cold turn 0 ({})",
                sm_lm.turn_hit_curve[ti],
                sm_lm.turn0_hit()
            );
        }
    }
    // And the compounding is a routing achievement, not a trace given:
    // load-only routing on the identical reactive trace reuses far less.
    assert!(
        sm_lm.late_turn_hit() > sm_vllm.late_turn_hit() + 0.1,
        "lmetric warm-turn hit {} must clear KV$-blind routing {}",
        sm_lm.late_turn_hit(),
        sm_vllm.late_turn_hit()
    );
    assert!(
        sm_lm.affinity_ratio() > 0.5,
        "lmetric affinity {} too low on agent loops",
        sm_lm.affinity_ratio()
    );

    let path = save_results("fig42_agent_loops", &rows, &[]).unwrap();
    println!("\nsaved {}", path.display());
}
