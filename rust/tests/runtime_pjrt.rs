//! Runtime round-trip tests: load the model runtime, execute the serving
//! entry points, and verify the contracts the live engine relies on.
//!
//! Default build: runs against the deterministic sim backend (no
//! artifacts needed), so CI exercises the full live-serving surface.
//! With `--features pjrt`: runs against the real PJRT transformer and
//! requires `make artifacts` (skips gracefully if absent).

use lmetric::runtime::{artifacts_dir, ModelRuntime, Runtime, Tensor};

fn runtime() -> Option<ModelRuntime> {
    let dir = artifacts_dir();
    if cfg!(feature = "pjrt") && !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts at {}", dir.display());
        return None;
    }
    Some(ModelRuntime::load(&dir).expect("runtime load"))
}

fn prefill_seq(
    rt: &ModelRuntime,
    kv: Tensor,
    tokens: &[i32],
    slot: usize,
    start: usize,
) -> (Vec<f32>, Tensor) {
    let mut kv = kv;
    let mut pos = start;
    let mut logits = Vec::new();
    while pos < tokens.len() {
        let remaining = tokens.len() - pos;
        let bucket = rt.bucket_for(remaining.min(rt.largest_bucket())).unwrap();
        let chunk_len = remaining.min(bucket);
        let mut buf = tokens[pos..pos + chunk_len].to_vec();
        buf.resize(bucket, 0);
        let (l, kv2) = rt.prefill_chunk(&kv, &buf, slot, pos, chunk_len).unwrap();
        kv = kv2;
        logits = l;
        pos += chunk_len;
    }
    (logits, kv)
}

fn toks(seed: u64, n: usize, vocab: usize) -> Vec<i32> {
    let mut rng = lmetric::util::Rng::new(seed);
    (0..n).map(|_| 1 + (rng.next_u64() % (vocab as u64 - 1)) as i32).collect()
}

#[test]
fn artifacts_load_and_shapes_match() {
    let Some(rt) = runtime() else { return };
    assert_eq!(rt.cfg.vocab, 1024);
    assert_eq!(rt.cfg.slots, 8);
    assert_eq!(rt.cfg.chunk_buckets, vec![16, 64, 256]);
    assert_eq!(rt.bucket_for(10), Some(16));
    assert_eq!(rt.bucket_for(64), Some(64));
    assert_eq!(rt.bucket_for(65), Some(256));
    assert_eq!(rt.bucket_for(9999), None);
}

#[test]
fn chunked_prefill_is_chunk_invariant() {
    // The same prompt split into different chunk sequences must produce
    // the same final logits (the chunked-prefill correctness contract).
    let Some(rt) = runtime() else { return };
    let tokens = toks(1, 80, rt.cfg.vocab);
    let (a, _) = prefill_seq(&rt, rt.zero_kv(), &tokens, 0, 0);
    // Force 16-token chunks by prefilling in 5 bucket-16 steps.
    let mut kv = rt.zero_kv();
    let mut logits = Vec::new();
    for c in 0..5 {
        let buf = tokens[c * 16..(c + 1) * 16].to_vec();
        let (l, kv2) = rt.prefill_chunk(&kv, &buf, 0, c * 16, 16).unwrap();
        kv = kv2;
        logits = l;
    }
    assert_eq!(a.len(), logits.len());
    for (x, y) in a.iter().zip(&logits) {
        assert!((x - y).abs() < 1e-3, "{x} vs {y}");
    }
}

#[test]
fn decode_continues_prefill() {
    let Some(rt) = runtime() else { return };
    let tokens = toks(2, 40, rt.cfg.vocab);
    let (logits, kv) = prefill_seq(&rt, rt.zero_kv(), &tokens, 3, 0);
    let next = ModelRuntime::argmax(&logits);
    // Decode one token on slot 3.
    let mut tok_in = vec![0i32; rt.cfg.slots];
    let mut lens = vec![0i32; rt.cfg.slots];
    tok_in[3] = next;
    lens[3] = 40;
    let (dlogits, _) = rt.decode_step(&kv, &tok_in, &lens).unwrap();
    // Oracle: prefill the 41-token sequence from scratch.
    let mut full = tokens.clone();
    full.push(next);
    let (ref_logits, _) = prefill_seq(&rt, rt.zero_kv(), &full, 0, 0);
    let row = &dlogits[3 * rt.cfg.vocab..4 * rt.cfg.vocab];
    for (x, y) in row.iter().zip(&ref_logits) {
        assert!((x - y).abs() < 1e-3, "{x} vs {y}");
    }
}

#[test]
fn extract_inject_roundtrip_gives_kv_hit() {
    // The live KV$ mechanism: finish a prompt on slot 0, snapshot it,
    // inject into slot 5 of a FRESH kv, continue from the hit point —
    // logits must match a cold full prefill.
    let Some(rt) = runtime() else { return };
    let prefix = toks(3, 48, rt.cfg.vocab);
    let suffix = toks(4, 16, rt.cfg.vocab);
    let mut full = prefix.clone();
    full.extend(&suffix);

    let (_, kv) = prefill_seq(&rt, rt.zero_kv(), &prefix, 0, 0);
    let (k, v) = rt.extract_slot(&kv, 0).unwrap();

    let kv2 = rt.inject_slot(&rt.zero_kv(), 5, &k, &v).unwrap();
    let (hit_logits, _) = prefill_seq(&rt, kv2, &full, 5, 48);

    let (cold_logits, _) = prefill_seq(&rt, rt.zero_kv(), &full, 2, 0);
    for (x, y) in hit_logits.iter().zip(&cold_logits) {
        assert!((x - y).abs() < 1e-3, "{x} vs {y}");
    }
}

#[test]
fn batched_decode_slots_are_independent() {
    let Some(rt) = runtime() else { return };
    let ta = toks(5, 32, rt.cfg.vocab);
    let tb = toks(6, 48, rt.cfg.vocab);
    let (la, kv) = prefill_seq(&rt, rt.zero_kv(), &ta, 0, 0);
    let (lb, kv) = prefill_seq(&rt, kv, &tb, 1, 0);
    let (na, nb) = (ModelRuntime::argmax(&la), ModelRuntime::argmax(&lb));
    // Batched decode of both slots.
    let mut tok_in = vec![0i32; rt.cfg.slots];
    let mut lens = vec![0i32; rt.cfg.slots];
    tok_in[0] = na;
    lens[0] = 32;
    tok_in[1] = nb;
    lens[1] = 48;
    let (batch, _) = rt.decode_step(&kv, &tok_in, &lens).unwrap();
    // Individual decode of slot 0 only.
    let mut t0 = vec![0i32; rt.cfg.slots];
    let mut l0 = vec![0i32; rt.cfg.slots];
    t0[0] = na;
    l0[0] = 32;
    let (solo_a, _) = rt.decode_step(&kv, &t0, &l0).unwrap();
    let va = rt.cfg.vocab;
    for (x, y) in batch[..va].iter().zip(&solo_a[..va]) {
        assert!((x - y).abs() < 1e-3);
    }
}

#[test]
fn live_cluster_end_to_end_smoke() {
    // A miniature live run: 2 runtime instances, a handful of chat turns.
    // Runs on the sim backend by default; needs artifacts under pjrt.
    if cfg!(feature = "pjrt") && !artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping: no artifacts");
        return;
    }
    use lmetric::cluster::live::{run_live, LiveClusterConfig};
    use lmetric::trace::{generate, Workload, WorkloadSpec};
    let mut spec = WorkloadSpec::preset(Workload::ChatBot, 8, 3);
    spec.vocab = 1023;
    spec.sys_prompt_median = 64.0;
    spec.user_span_median = 16.0;
    spec.output_median = 4.0;
    spec.output_sigma = 0.2;
    spec.max_input = 300;
    spec.mean_turns = 2.0;
    let trace = generate(&spec);
    let cfg = LiveClusterConfig {
        n_instances: 2,
        time_scale: 1000.0, // replay as fast as possible
        ..Default::default()
    };
    let mut pol = lmetric::policy::LMetric::paper();
    let m = run_live(&cfg, &trace, &mut pol).expect("live run");
    assert_eq!(m.records.len(), trace.requests.len());
    for r in &m.records {
        assert!(r.completion_us >= r.first_token_us);
        assert!(r.first_token_us >= r.arrival_us);
    }
}

#[test]
fn live_cluster_scale_up_spawns_a_thread_and_completes_everything() {
    // The live harness used to silently swallow ScaleUp events; now a
    // scheduled ScaleUp must spawn a real engine thread, widen the
    // router's routable mask, and the run still completes every request.
    if cfg!(feature = "pjrt") && !artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping: no artifacts");
        return;
    }
    use lmetric::cluster::live::{run_live, LiveClusterConfig};
    use lmetric::cluster::FaultPlan;
    use lmetric::trace::{generate, Workload, WorkloadSpec};
    let mut spec = WorkloadSpec::preset(Workload::ChatBot, 10, 5);
    spec.vocab = 1023;
    spec.sys_prompt_median = 64.0;
    spec.user_span_median = 16.0;
    spec.output_median = 4.0;
    spec.output_sigma = 0.2;
    spec.max_input = 300;
    spec.mean_turns = 2.0;
    let trace = generate(&spec);
    let cfg = LiveClusterConfig {
        n_instances: 1,
        time_scale: 1000.0,
        faults: FaultPlan::new().scale_up_at(1_000, true),
        ..Default::default()
    };
    let mut pol = lmetric::policy::LMetric::paper();
    let m = run_live(&cfg, &trace, &mut pol).expect("live run");
    assert_eq!(m.records.len(), trace.requests.len(), "no request lost");
    assert_eq!(m.fault.scale_ups, 1, "the ScaleUp fired on the live path");
    assert_eq!(m.batch_size.len(), 2, "metrics widened with the fleet");
}
