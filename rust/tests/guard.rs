//! End-to-end contracts of the failure-condition guard subsystem: the
//! adversarial generators drive the DES into the derived failure
//! regimes, the detector counts them, the counters flow into
//! `RunMetrics`, and an independent recount from the decision log
//! agrees with every counter.

use lmetric::cluster::{run_des, ClusterConfig};
use lmetric::engine::EngineConfig;
use lmetric::policy::{self, GuardedLMetric};
use lmetric::trace::{generate_adversarial, AdversarialScenario, AdversarialSpec};

fn cluster8() -> ClusterConfig {
    ClusterConfig::new(8, EngineConfig::default())
}

/// Idle-fleet bursts: every wave leader faces the all-idle degenerate
/// tie, so the detector must fire at least once per wave — while the
/// decisions stay byte-identical to bare lmetric (the re-ranked ties
/// are exact, zero-hit, equal-length: the secondary key agrees with
/// select_min on them).
#[test]
fn idle_fleet_bursts_fire_degenerate_and_replay_identically() {
    let cfg = cluster8();
    let spec = AdversarialSpec::preset(AdversarialScenario::IdleFleetBurst, 160, 3);
    let trace = generate_adversarial(&spec);
    let n_waves = trace.requests.len().div_ceil(spec.burst_size);
    let mut plain = policy::build_default("lmetric", &cfg.engine.profile, 256).unwrap();
    let m_p = run_des(&cfg, &trace, plain.as_mut());
    let mut guarded = GuardedLMetric::new();
    let m_g = run_des(&cfg, &trace, &mut guarded);
    assert_eq!(m_g.records.len(), trace.requests.len(), "all requests complete");
    for (a, b) in m_p.records.iter().zip(&m_g.records) {
        assert_eq!((a.id, a.instance), (b.id, b.instance), "decision diverged");
    }
    assert!(
        m_g.guard.degenerate >= n_waves as u64,
        "every drained-fleet wave leader is an all-idle tie: {} < {n_waves}",
        m_g.guard.degenerate
    );
    assert_eq!(m_g.guard.mitigated, 0, "equal ties re-rank to the same pick");
    assert_eq!(m_g.guard.checks, trace.requests.len() as u64);
}

/// Shared-prefix floods: once >= 2 instances hold the full prompt,
/// wave leaders see P-token == 0 on several instances — the
/// zero-annihilation degeneracy — and the hit ratio confirms the flood
/// actually reuses the prefix.
#[test]
fn shared_prefix_flood_fires_zero_annihilation() {
    let cfg = cluster8();
    let spec = AdversarialSpec::preset(AdversarialScenario::SharedPrefixFlood, 160, 5);
    let trace = generate_adversarial(&spec);
    let mut guarded = GuardedLMetric::new();
    let m = run_des(&cfg, &trace, &mut guarded);
    assert_eq!(m.records.len(), trace.requests.len());
    assert!(
        m.guard.degenerate > 0,
        "flood must trip the degenerate detector: {:?}",
        m.guard
    );
    assert!(
        m.mean_hit_ratio() > 0.5,
        "flood must actually hit the shared prefix: {}",
        m.mean_hit_ratio()
    );
    assert_eq!(m.guard.mitigated, 0, "zero-ties have equal (full) hits");
}

/// Spread stress completes and is checked decision-by-decision; the
/// counters flow into `RunMetrics` verbatim.
#[test]
fn spread_stress_counts_every_decision_into_run_metrics() {
    let cfg = cluster8();
    let spec = AdversarialSpec::preset(AdversarialScenario::SpreadStress, 300, 11);
    let trace = generate_adversarial(&spec);
    let mut guarded = GuardedLMetric::new();
    let m = run_des(&cfg, &trace, &mut guarded);
    assert_eq!(m.records.len(), trace.requests.len());
    assert_eq!(m.guard, guarded.counters, "RunMetrics must carry the counters");
    assert_eq!(m.guard.checks, trace.requests.len() as u64);
    // Unguarded policies report all-zero counters through the same path.
    let mut plain = policy::build_default("lmetric", &cfg.engine.profile, 256).unwrap();
    let m_p = run_des(&cfg, &trace, plain.as_mut());
    assert_eq!(m_p.guard, Default::default());
}

/// The churn contract: every `guard_*` counter equals an independent
/// recount from the decision log — no decision is double-counted or
/// dropped, across a DES run that mixes all three adversarial regimes.
#[test]
fn counters_equal_independent_recount_from_decision_log() {
    let cfg = cluster8();
    let mut guarded = GuardedLMetric::with_log();
    let mut total = 0u64;
    for (scenario, seed) in [
        (AdversarialScenario::IdleFleetBurst, 21u64),
        (AdversarialScenario::SharedPrefixFlood, 22),
        (AdversarialScenario::SpreadStress, 23),
    ] {
        let trace = generate_adversarial(&AdversarialSpec::preset(scenario, 120, seed));
        total += trace.requests.len() as u64;
        let m = run_des(&cfg, &trace, &mut guarded);
        // RunMetrics reports THIS run's delta even though the policy's
        // own counters accumulate across the three runs.
        assert_eq!(m.guard.checks, trace.requests.len() as u64, "per-run delta");
    }
    let log = guarded.log.as_ref().expect("with_log records decisions");
    assert_eq!(log.len() as u64, total, "one log entry per routed request");
    let recount_deg = log.iter().filter(|d| d.degenerate).count() as u64;
    let recount_inv = log.iter().filter(|d| d.inversion).count() as u64;
    let recount_mit = log.iter().filter(|d| d.product_choice != d.final_choice).count() as u64;
    assert_eq!(guarded.counters.checks, total);
    assert_eq!(guarded.counters.degenerate, recount_deg);
    assert_eq!(guarded.counters.inversion, recount_inv);
    assert_eq!(guarded.counters.mitigated, recount_mit);
    assert!(recount_deg > 0, "the adversarial mix must exercise the detector");
}

/// Registry contract: `lmetric_safe` is buildable by name, self-reports
/// its name, and exposes counters through the `Policy` trait (unguarded
/// policies return None).
#[test]
fn lmetric_safe_registry_and_trait_surface() {
    let profile = lmetric::engine::ModelProfile::moe_30b();
    let pol = policy::build_default("lmetric_safe", &profile, 256).unwrap();
    assert_eq!(pol.name(), "lmetric_safe");
    assert_eq!(pol.guard_counters(), Some(Default::default()));
    let plain = policy::build_default("lmetric", &profile, 256).unwrap();
    assert_eq!(plain.guard_counters(), None);
}
