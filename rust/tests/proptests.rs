//! Property-based tests over coordinator invariants (routing, batching,
//! KV$ state), using a small in-repo property harness (the proptest crate
//! is unavailable offline — DESIGN.md §1): each property runs across many
//! seeded random cases; failures report the seed for replay.

use std::collections::HashSet;

use lmetric::core::{Request, BLOCK_TOKENS};
use lmetric::engine::{EngineConfig, EngineEvent, Instance, ModelProfile};
use lmetric::kvcache::RadixTree;
use lmetric::policy::{
    window_slack, FailureAnalyzer, GuardedLMetric, INVERSION_MARGIN, LMetric, W_HI, W_LO,
};
use lmetric::router::{select_min, Indicators, Policy, RouteCtx};
use lmetric::tokenizer::block_hashes;
use lmetric::trace::adversarial::{degenerate_tie_ctx, spread_route_ctx};
use lmetric::util::Rng;

/// Run `case` for `n` seeds; panic with the seed on failure.
fn prop(name: &str, n: u64, case: impl Fn(&mut Rng)) {
    for seed in 0..n {
        let mut rng = Rng::new(seed.wrapping_mul(0x9e37_79b9) ^ 0xabcd);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            case(&mut rng);
        }));
        if let Err(e) = result {
            panic!("property '{name}' failed at seed {seed}: {e:?}");
        }
    }
}

// ---------------------------------------------------------------- KV$ --

/// Model-based check: the radix tree must agree with a naive
/// set-of-prefixes model on every lookup, under unbounded capacity.
#[test]
fn prop_radix_matches_naive_model_unbounded() {
    prop("radix=naive", 40, |rng| {
        let mut tree = RadixTree::new(0);
        let mut model: HashSet<Vec<u64>> = HashSet::new();
        for step in 0..200u64 {
            let base = rng.gen_range(0, 5);
            let len = rng.gen_range(1, 10) as usize;
            let chain: Vec<u64> = (0..len as u64).map(|i| base * 100 + i).collect();
            if rng.gen_bool(0.5) {
                tree.insert(&chain, step);
                for k in 1..=chain.len() {
                    model.insert(chain[..k].to_vec());
                }
            } else {
                let got = tree.match_prefix(&chain, step, false);
                let want = (0..=chain.len())
                    .rev()
                    .find(|&k| k == 0 || model.contains(&chain[..k]))
                    .unwrap();
                assert_eq!(got, want, "chain {chain:?}");
            }
        }
        tree.check_invariants().unwrap();
    });
}

/// Under any capacity and churn: never exceed capacity, never evict a
/// pinned path, invariants always hold.
#[test]
fn prop_radix_capacity_and_pinning() {
    prop("radix capacity+pin", 40, |rng| {
        let cap = rng.gen_range(4, 64) as usize;
        let mut tree = RadixTree::new(cap);
        let mut pinned: Vec<(Vec<u64>, usize)> = Vec::new();
        for step in 0..300u64 {
            let base = rng.gen_range(0, 6);
            let len = rng.gen_range(1, 8) as usize;
            let chain: Vec<u64> = (0..len as u64).map(|i| base * 50 + i).collect();
            match rng.gen_range(0, 4) {
                0 | 1 => {
                    tree.insert(&chain, step);
                }
                2 => {
                    tree.insert(&chain, step);
                    let resident = tree.match_prefix(&chain, step, false);
                    tree.pin(&chain, resident);
                    pinned.push((chain, resident));
                }
                _ => {
                    if let Some((c, r)) = pinned.pop() {
                        // Pinned paths must still be fully resident.
                        assert!(
                            tree.match_prefix(&c, step, false) >= r,
                            "pinned path evicted"
                        );
                        tree.unpin(&c, r, step);
                    }
                }
            }
            assert!(tree.used_blocks() <= cap, "over capacity");
        }
        tree.check_invariants().unwrap();
    });
}

/// Eviction never starves (regression property for the insert-refresh
/// starvation bug): under arbitrary churn — including refreshing every
/// resident chain, which used to invalidate every standing heap entry —
/// an over-capacity insert into a tree of *unpinned* blocks must always
/// evict its way in, and the lifetime eviction counter grows monotonically.
#[test]
fn prop_eviction_never_starves() {
    prop("eviction never starves", 30, |rng| {
        let cap = rng.gen_range(4, 32) as usize;
        let mut tree = RadixTree::new(cap);
        let mut inserted: Vec<Vec<u64>> = Vec::new();
        let mut last_evicted = 0u64;
        let mut fresh = 1_000_000u64;
        for step0 in 0..200u64 {
            let step = step0 * 10; // leave room for the +1/+2 sub-steps
            let base = rng.gen_range(0, 4);
            let len = rng.gen_range(1, 6) as usize;
            let chain: Vec<u64> = (0..len as u64).map(|i| base * 100 + i).collect();
            tree.insert(&chain, step);
            inserted.push(chain.clone());
            if rng.gen_bool(0.3) {
                // Transient pin/unpin cycle: nothing stays pinned.
                let resident = tree.match_prefix(&chain, step, false);
                tree.pin(&chain, resident);
                tree.unpin(&chain, resident, step);
            }
            assert!(tree.total_evicted_blocks >= last_evicted, "counter went backwards");
            last_evicted = tree.total_evicted_blocks;
            if tree.used_blocks() >= cap {
                // Refresh EVERY resident chain, at a timestamp strictly
                // after every heap push so far: with the old insert this
                // drained the eviction heap entirely (all entries stale,
                // nothing re-pushed).
                for c in &inserted {
                    let resident = tree.match_prefix(c, step + 1, false);
                    if resident > 0 {
                        tree.insert(&c[..resident], step + 1);
                    }
                }
                // The tree is full of unpinned blocks: a fresh insert must
                // always succeed in evicting.
                fresh += 1;
                assert_eq!(
                    tree.insert(&[fresh], step + 2),
                    1,
                    "eviction starved at step {step0}"
                );
                inserted.push(vec![fresh]);
                assert!(tree.total_evicted_blocks > last_evicted, "no eviction happened");
                last_evicted = tree.total_evicted_blocks;
            }
            assert!(tree.used_blocks() <= cap);
        }
        tree.check_invariants().unwrap();
    });
}

// ------------------------------------------------------------- engine --

fn random_request(rng: &mut Rng, id: u64) -> (Request, std::sync::Arc<[u64]>) {
    let class = rng.gen_range(0, 4) as u32;
    let input = rng.gen_range(8, 1200) as usize;
    let output = rng.gen_range(1, 120) as u32;
    let tokens = lmetric::tokenizer::span(class, rng.gen_range(0, 20), input, 4096);
    let hashes = block_hashes(&tokens);
    let mut full = tokens.clone();
    full.extend(lmetric::tokenizer::span(class, 1000 + id, output as usize, 4096));
    let full_hashes = block_hashes(&full);
    (
        Request {
            id,
            arrival_us: 0,
            class_id: class,
            session_id: 0,
            model_id: 0,
            tokens: tokens.into(),
            output_len: output,
            block_hashes: hashes.into(),
        },
        full_hashes.into(),
    )
}

/// Conservation: every enqueued request completes exactly once, with
/// causal timestamps and exactly `output_len` tokens; chunk budget and
/// max_batch are never exceeded; the engine always terminates.
#[test]
fn prop_engine_conservation() {
    prop("engine conservation", 30, |rng| {
        let cfg = EngineConfig {
            profile: ModelProfile::moe_30b(),
            chunk_budget: [64, 256, 512][rng.gen_range(0, 3) as usize],
            max_batch: rng.gen_range(1, 32) as usize,
            kv_capacity_blocks: [0, 256, 4096][rng.gen_range(0, 3) as usize],
            queue_policy: ["fcfs", "srpt", "ltr"][rng.gen_range(0, 3) as usize].to_string(),
        };
        let chunk_budget = cfg.chunk_budget;
        let max_batch = cfg.max_batch;
        let mut inst = Instance::new(0, cfg);
        let n = rng.gen_range(3, 25);
        let mut pending: HashSet<u64> = HashSet::new();
        let mut now = 0u64;
        for id in 0..n {
            let (req, full) = random_request(rng, id);
            inst.enqueue(req, full, now);
            pending.insert(id);
            // Sometimes interleave stepping with arrivals.
            if rng.gen_bool(0.5) {
                if let Some(out) = inst.step(now) {
                    assert!(out.prefill_tokens <= chunk_budget);
                    assert!(out.snapshot.r_bs <= max_batch);
                    now += out.duration_us;
                    for e in out.events {
                        if let EngineEvent::Completed { record } = e {
                            assert!(pending.remove(&record.id), "dup completion");
                            assert!(record.completion_us >= record.first_token_us);
                            assert!(record.first_token_us > record.arrival_us);
                        }
                    }
                }
            }
        }
        let mut guard = 0u64;
        while inst.has_work() {
            let out = inst.step(now).expect("has_work => step");
            assert!(out.duration_us > 0, "steps must advance time");
            assert!(out.prefill_tokens <= chunk_budget);
            assert!(out.snapshot.r_bs <= max_batch);
            now += out.duration_us;
            for e in out.events {
                if let EngineEvent::Completed { record } = e {
                    assert!(pending.remove(&record.id), "dup completion");
                }
            }
            guard += 1;
            assert!(guard < 2_000_000, "engine did not terminate");
        }
        assert!(pending.is_empty(), "lost requests: {pending:?}");
    });
}

/// KV$ hits can only shorten a request's service, never lengthen it,
/// and cached_tokens is always block-aligned and ≤ input_len.
#[test]
fn prop_engine_hits_never_hurt() {
    prop("hits never hurt", 20, |rng| {
        let (req, full) = random_request(rng, 1);
        let cold_t = {
            let mut inst = Instance::new(0, EngineConfig::default());
            inst.enqueue(req.clone(), full.clone(), 0);
            drain(&mut inst)
        };
        let warm_t = {
            let mut inst = Instance::new(0, EngineConfig::default());
            // Warm with the same prompt (different id).
            let mut r0 = req.clone();
            r0.id = 0;
            inst.enqueue(r0, full.clone(), 0);
            let t0 = drain(&mut inst);
            let mut r1 = req.clone();
            r1.arrival_us = t0;
            inst.enqueue(r1, full.clone(), t0);
            drain_from(&mut inst, t0) - t0
        };
        assert!(
            warm_t <= cold_t,
            "warm {warm_t} must not exceed cold {cold_t}"
        );
    });
}

fn drain(inst: &mut Instance) -> u64 {
    drain_from(inst, 0)
}

fn drain_from(inst: &mut Instance, start: u64) -> u64 {
    let mut now = start;
    while inst.has_work() {
        let out = inst.step(now).unwrap();
        now += out.duration_us;
    }
    now
}

// ------------------------------------------------------------- router --

fn random_ctx(rng: &mut Rng, n: usize) -> RouteCtx {
    let input = rng.gen_range(BLOCK_TOKENS as u64, 4000) as usize;
    let hit_tokens = (0..n)
        .map(|_| {
            let blocks = rng.gen_range(0, (input / BLOCK_TOKENS + 1) as u64) as usize;
            (blocks * BLOCK_TOKENS).min(input)
        })
        .collect();
    let inds = (0..n)
        .map(|_| Indicators {
            r_bs: rng.gen_range(0, 64) as usize,
            q_bs: rng.gen_range(0, 8) as usize,
            queued_prefill_tokens: rng.gen_range(0, 20_000) as usize,
            total_context_tokens: rng.gen_range(0, 200_000) as usize,
            kv_used_blocks: 0,
            kv_capacity_blocks: 0,
            routable: true,
        })
        .collect();
    RouteCtx::new(
        rng.next_u64() % 1_000_000_000,
        rng.next_u64(),
        rng.gen_range(0, 8) as u32,
        input,
        hit_tokens,
        inds,
    )
}

/// Every policy always routes in range, for arbitrary indicator states.
#[test]
fn prop_policies_route_in_range() {
    prop("policies in range", 30, |rng| {
        let profile = ModelProfile::moe_30b();
        let n = rng.gen_range(1, 20) as usize;
        for name in lmetric::policy::all_names() {
            let mut pol = lmetric::policy::build_default(name, &profile, 256).unwrap();
            for _ in 0..20 {
                let ctx = random_ctx(rng, n);
                let d = pol.route(&ctx);
                assert!(d.instance < n, "{name} routed {} of {n}", d.instance);
            }
        }
    });
}

/// The multiplicative score's hyperparameter-cancellation property: the
/// argmin is invariant under positive rescaling of either factor (the λ's
/// of the linear combination cancel — the paper's core claim, Fig 17a).
#[test]
fn prop_lmetric_scale_invariance() {
    prop("lmetric scale invariance", 50, |rng| {
        let n = rng.gen_range(2, 16) as usize;
        let ctx = random_ctx(rng, n);
        let p = LMetric::paper();
        let a = rng.gen_f64(0.01, 100.0);
        let b = rng.gen_f64(0.01, 100.0);
        let plain = select_min(&ctx, |i| p.score(&ctx, i));
        let scaled = select_min(&ctx, |i| {
            (a * ctx.p_token(i) as f64) * (b * (ctx.inds[i].bs() + 1) as f64)
        });
        assert_eq!(plain, scaled);
    });
}

/// Multiplication's cancellation survives heterogeneity: plant an
/// instance that strictly dominates both factors — P-*time* under
/// arbitrary positive per-instance prefill rates, and batch size — and
/// it stays the argmin of the product under any positive global
/// reweighting of either factor. The λ's cancel on mixed hardware
/// exactly as they did on uniform fleets (the cost-aware extension of
/// Fig 17a's claim).
#[test]
fn prop_cost_aware_p_time_keeps_a_planted_dominator_argmin() {
    prop("cost-aware planted dominance", 60, |rng| {
        let n = rng.gen_range(2, 12) as usize;
        let mut ctx = random_ctx(rng, n);
        // Arbitrary positive per-instance monotone rate scalings.
        ctx.fleet_prefill_scale = (0..n).map(|_| rng.gen_f64(0.05, 8.0)).collect();
        let d = rng.gen_range(0, n as u64) as usize;
        // Plant d strictly smallest on the load axis...
        ctx.inds[d].q_bs = 0;
        ctx.inds[d].r_bs = rng.gen_range(0, 8) as usize;
        let dbs = ctx.inds[d].bs();
        for i in 0..n {
            if i != d && ctx.inds[i].bs() <= dbs {
                ctx.inds[i].r_bs = dbs + 1 + rng.gen_range(0, 5) as usize;
            }
        }
        // ...and strictly smallest on the P-time axis, whatever the
        // rates: pile queued prefill onto anyone at or below it.
        ctx.inds[d].queued_prefill_tokens = 0;
        let pd = ctx.p_time(d);
        for i in 0..n {
            if i != d {
                while ctx.p_time(i) <= pd {
                    ctx.inds[i].queued_prefill_tokens += 1000;
                }
            }
        }
        let p = LMetric::paper();
        assert_eq!(select_min(&ctx, |i| p.score(&ctx, i)), d, "dominator lost");
        let a = rng.gen_f64(0.01, 100.0);
        let b = rng.gen_f64(0.01, 100.0);
        let reweighted = select_min(&ctx, |i| {
            (a * ctx.p_time(i)) * (b * (ctx.inds[i].bs() + 1) as f64)
        });
        assert_eq!(reweighted, d, "reweighting moved the argmin");
        // The fused policy scores identically while no penalty is armed.
        let fused = lmetric::policy::LMetricFused::new();
        assert_eq!(select_min(&ctx, |i| fused.score(&ctx, i)), d);
    });
}

/// select_min is total and stable: it picks an argmin, and among equal
/// scores the smaller batch size.
#[test]
fn prop_select_min_is_argmin() {
    prop("select_min argmin", 50, |rng| {
        let n = rng.gen_range(1, 12) as usize;
        let ctx = random_ctx(rng, n);
        let scores: Vec<f64> = (0..n).map(|_| rng.gen_range(0, 5) as f64).collect();
        let pick = select_min(&ctx, |i| scores[i]);
        let min = scores.iter().cloned().fold(f64::INFINITY, f64::min);
        assert_eq!(scores[pick], min);
        for i in 0..n {
            if scores[i] == min {
                assert!(
                    ctx.inds[pick].bs() <= ctx.inds[i].bs(),
                    "tie-break violated"
                );
            }
        }
    });
}

/// An instance whose queue strictly dominates (worse on every indicator,
/// no better hit) is never chosen by lmetric.
#[test]
fn prop_lmetric_never_picks_dominated() {
    prop("dominated never picked", 50, |rng| {
        let mut ctx = random_ctx(rng, 4);
        // Make instance 2 strictly dominated by instance 0.
        ctx.hit_tokens[2] = ctx.hit_tokens[0].saturating_sub(BLOCK_TOKENS);
        ctx.recompute_matched_mask();
        ctx.inds[2].r_bs = ctx.inds[0].r_bs + 5;
        ctx.inds[2].q_bs = ctx.inds[0].q_bs + 2;
        ctx.inds[2].queued_prefill_tokens = ctx.inds[0].queued_prefill_tokens + 1000;
        let mut p = LMetric::paper();
        assert_ne!(p.route(&ctx).instance, 2);
    });
}

// -------------------------------------------------- failure guard ------

/// The weight-cancellation theorem as an executable invariant, easy
/// direction: on snapshots with a strictly dominant instance (best on
/// BOTH indicator axes — provably outside every derived failure
/// window), the product argmin equals the argmin of `a·KV + b·LB` for
/// ALL sampled positive `(a, b)`, and the guard is fully inert.
#[test]
fn prop_guard_dominant_instance_agrees_for_all_weights() {
    prop("dominance => all-(a,b) agreement", 60, |rng| {
        let n = rng.gen_range(3, 10) as usize;
        let input = 160usize;
        let dom = rng.gen_range(0, n as u64) as usize;
        let mut hits = vec![0usize; n];
        let mut inds = vec![Indicators::default(); n];
        for i in 0..n {
            // KV axis via queued prefill (carried by a queued batch
            // member — DES-plausible); dominant strictly smallest.
            let k = if i == dom {
                200
            } else {
                rng.gen_range(300, 5000) as usize
            };
            let bs = if i == dom {
                1
            } else {
                rng.gen_range(2, 40) as usize
            };
            hits[i] = 0;
            inds[i] = Indicators {
                r_bs: bs - 1,
                q_bs: 1,
                queued_prefill_tokens: k - input,
                ..Default::default()
            };
        }
        let ctx = RouteCtx::new(0, 1, 0, input, hits, inds);
        let score = LMetric::paper();
        let p = select_min(&ctx, |i| score.score(&ctx, i));
        assert_eq!(p, dom, "the dominant instance is the product argmin");
        for _ in 0..25 {
            let a = rng.gen_f64(1e-3, 1e3);
            let b = rng.gen_f64(1e-3, 1e3);
            let lin = select_min(&ctx, |i| {
                let (kv, load) = score.factors(&ctx, i);
                a * kv + b * load
            });
            assert_eq!(lin, dom, "every positive linear combination agrees");
        }
        let mut guarded = GuardedLMetric::new();
        assert_eq!(guarded.route(&ctx).instance, dom);
        assert_eq!(guarded.counters.degenerate, 0);
        assert_eq!(guarded.counters.inversion, 0);
        assert_eq!(guarded.counters.mitigated, 0);
    });
}

/// Hard direction, via the independent breakpoint oracle: the O(N)
/// interval detector fires on exactly the snapshots where NO window
/// weight justifies the product argmin within the margin (inside the
/// derived window => the guard must fire; outside => it must not), and
/// whenever nothing fires the guarded policy replays the bare product
/// decision byte-identically.
#[test]
fn prop_guard_detector_matches_breakpoint_oracle() {
    prop("detector == oracle", 60, |rng| {
        let score = LMetric::paper();
        let analyzer = FailureAnalyzer::default();
        for _ in 0..20 {
            let n = rng.gen_range(2, 12) as usize;
            let ctx = if rng.gen_bool(0.5) {
                let ks = rng.gen_f64(1.0, 64.0);
                let ls = rng.gen_f64(1.0, 32.0);
                spread_route_ctx(rng, n, 4096, ks, ls)
            } else {
                random_ctx(rng, n)
            };
            let p = select_min(&ctx, |i| score.score(&ctx, i));
            let v = analyzer.analyze(&ctx, &score, p);
            let mut guarded = GuardedLMetric::new();
            let routed = guarded.route(&ctx).instance;
            if !v.fired() {
                assert_eq!(routed, p, "inert guard must be byte-identical");
                assert_eq!(guarded.counters.mitigated, 0);
            }
            if v.degenerate() {
                continue; // the envelope question is posed on non-degenerate states
            }
            let kv: Vec<f64> = (0..ctx.n()).map(|i| score.factors(&ctx, i).0).collect();
            let ld: Vec<f64> = (0..ctx.n()).map(|i| score.factors(&ctx, i).1).collect();
            let slack = window_slack(&kv, &ld, p, W_LO, W_HI, INVERSION_MARGIN);
            if slack.abs() < 1e-7 {
                continue; // borderline: fp-sensitive either way
            }
            assert_eq!(
                v.inversion,
                slack < 0.0,
                "detector vs oracle diverged (slack {slack}, kv {kv:?}, load {ld:?})"
            );
        }
    });
}

/// Inside the degenerate window the guard must fire, and its secondary
/// key must resolve the all-idle tie toward the max-hit instance —
/// never losing cached prefix relative to bare select_min's
/// lowest-index pick.
#[test]
fn prop_guard_degenerate_window_fires_and_reranks_to_max_hit() {
    prop("degenerate fires + max-hit rerank", 60, |rng| {
        // All-idle exact ties with distinct hits.
        let n = rng.gen_range(2, 10) as usize;
        let ctx = degenerate_tie_ctx(rng, n, 2048);
        let mut plain = LMetric::paper();
        let mut guarded = GuardedLMetric::new();
        let p = plain.route(&ctx).instance;
        let g = guarded.route(&ctx).instance;
        assert_eq!(guarded.counters.degenerate, 1, "all-idle tie must fire");
        let max_hit = *ctx.hit_tokens.iter().max().unwrap();
        assert_eq!(ctx.hit_tokens[g], max_hit, "guard picks a max-hit instance");
        assert!(ctx.hit_tokens[g] >= ctx.hit_tokens[p], "never lose prefix");
        // Zero-annihilation: >= 2 instances at P-token == 0 must fire.
        let n = 4usize;
        let input = 640usize;
        let mut inds = vec![Indicators::default(); n];
        let mut hits = vec![0usize; n];
        for i in 0..n {
            if i < 2 {
                hits[i] = input; // full hit, empty queue: P-token = 0
                inds[i].r_bs = rng.gen_range(0, 20) as usize;
            } else {
                hits[i] = 0;
                inds[i].r_bs = rng.gen_range(0, 20) as usize;
            }
        }
        let zctx = RouteCtx::new(0, 2, 0, input, hits, inds);
        let mut g2 = GuardedLMetric::new();
        g2.route(&zctx);
        assert_eq!(g2.counters.degenerate, 1, "zero-annihilation must fire");
    });
}

// ------------------------------------------------------------- traces --

/// Trace generator invariants: sorted arrivals, ≥1 output token, block
/// hashes consistent with tokens, full chain extends the prompt chain.
#[test]
fn prop_trace_wellformed() {
    use lmetric::trace::{generate, Workload, WorkloadSpec};
    prop("trace wellformed", 10, |rng| {
        let workloads = [
            Workload::ChatBot,
            Workload::Coder,
            Workload::Agent,
            Workload::ToolAgent,
            Workload::Hotspot,
        ];
        let w = workloads[rng.gen_range(0, 5) as usize];
        let t = generate(&WorkloadSpec::preset(w, 200, rng.next_u64()));
        let mut last = 0;
        for tr in &t.requests {
            assert!(tr.req.arrival_us >= last);
            last = tr.req.arrival_us;
            assert!(tr.req.output_len >= 1);
            assert_eq!(&tr.req.block_hashes[..], &block_hashes(&tr.req.tokens)[..]);
            assert!(tr.full_hashes.len() >= tr.req.block_hashes.len());
            assert_eq!(
                &tr.full_hashes[..tr.req.block_hashes.len()],
                &tr.req.block_hashes[..]
            );
        }
    });
}
