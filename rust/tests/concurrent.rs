//! Integration tests of the concurrent router data plane: snapshot
//! consistency under real writer/reader thread churn, shard-assignment
//! purity (mirrored by `python/tests/test_shard_assignment.py`), and the
//! R-router harness's byte-identity contract at zero staleness.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::RwLock;

use lmetric::cluster::{cluster_config, run_concurrent, run_des, ConcurrentCfg};
use lmetric::config::ExperimentConfig;
use lmetric::core::InstanceMask;
use lmetric::kvcache::{shard_of, ShardedRadixIndex};
use lmetric::policy;
use lmetric::util::Rng;

/// Run `case` for `n` seeds; panic with the seed on failure (same
/// in-repo property idiom as `tests/proptests.rs`).
fn prop(name: &str, n: u64, case: impl Fn(&mut Rng)) {
    for seed in 0..n {
        let mut rng = Rng::new(seed.wrapping_mul(0x9e37_79b9) ^ 0xc0c0);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            case(&mut rng);
        }));
        if let Err(e) = result {
            panic!("property '{name}' failed at seed {seed}: {e:?}");
        }
    }
}

fn chain(rng: &mut Rng) -> Vec<u64> {
    let base = rng.gen_range(0, 12);
    let len = rng.gen_range(1, 10) as usize;
    (0..len as u64).map(|i| base * 1000 + i).collect()
}

// ------------------------------------------------- snapshot consistency --

/// The pinning contract under real thread churn: while a reader holds a
/// read guard, the snapshot it pinned stays consistent (no torn shard
/// views), repeated walks of the same chain agree, and the write version
/// it observes across successive pins never goes backwards.
#[test]
fn writer_reader_churn_no_torn_views() {
    let ix = RwLock::new(ShardedRadixIndex::new(8, 64));
    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        // Writer: interleave inserts across instances and shards; check
        // structural invariants periodically under the write guard.
        scope.spawn(|| {
            let mut rng = Rng::new(0x517c_c1b7);
            for step in 0..4000u64 {
                let c = chain(&mut rng);
                let inst = rng.gen_range(0, 8) as usize;
                let mut guard = ix.write().unwrap();
                guard.insert(inst, &c, step);
                if step % 251 == 0 {
                    guard.check_invariants().unwrap();
                }
            }
            stop.store(true, Ordering::Release);
        });
        for t in 0..3u64 {
            let stop = &stop;
            let ix = &ix;
            scope.spawn(move || {
                let mut rng = Rng::new(0xbeef ^ t);
                let (mut h1, mut h2) = (Vec::new(), Vec::new());
                let (mut m1, mut m2) = (InstanceMask::default(), InstanceMask::default());
                let mut live = Vec::new();
                let mut last_version = 0u64;
                let mut iters = 0u64;
                while !stop.load(Ordering::Acquire) && iters < 200_000 {
                    iters += 1;
                    let c = chain(&mut rng);
                    let guard = ix.read().unwrap();
                    let snap = guard.snapshot();
                    assert!(snap.version() >= last_version, "version went backwards");
                    last_version = snap.version();
                    let s1 = snap.match_with(&c, &mut h1, &mut m1, &mut live);
                    // The guard is still held: the second walk must see
                    // the exact same world (torn shards would diverge).
                    let s2 = snap.match_with(&c, &mut h2, &mut m2, &mut live);
                    assert!(snap.is_consistent(), "snapshot torn under read guard");
                    assert_eq!(s1, s2);
                    assert_eq!(h1, h2);
                    assert_eq!(m1, m2);
                }
            });
        }
    });
    let ix = ix.into_inner().unwrap();
    ix.check_invariants().unwrap();
    assert!(ix.version() >= 4000, "writer must have published every insert");
}

// ---------------------------------------------------- shard assignment --

/// Shard assignment is a pure total function of the FIRST block hash:
/// deterministic across calls, always in range, invariant to everything
/// that isn't the first hash. The pinned vectors live in
/// `kvcache::sharded`'s unit tests and `python/tests/test_shard_assignment.py`.
#[test]
fn prop_shard_assignment_pure_function_of_first_hash() {
    prop("shard_of pure+in-range", 200, |rng| {
        let h = rng.next_u64();
        let s = rng.gen_range(1, 65) as usize;
        let a = shard_of(h, s);
        assert!(a < s, "shard_of({h:#x}, {s}) = {a} out of range");
        assert_eq!(a, shard_of(h, s), "shard_of must be deterministic");
        assert_eq!(shard_of(h, 1), 0, "single shard owns everything");
        // Chains sharing a first hash land in one shard regardless of
        // their tails: a two-chain index with a common first block keeps
        // every node in that one shard (all other shard epochs untouched).
        let mut ix = ShardedRadixIndex::with_shards(2, 0, s);
        let tail_a = rng.next_u64();
        let tail_b = rng.next_u64();
        ix.insert(0, &[h, tail_a], 0);
        ix.insert(1, &[h, tail_b], 1);
        let moved: Vec<usize> =
            (0..s).filter(|&sh| ix.shard_epoch(sh) != 0).collect();
        assert_eq!(moved, vec![a], "tails must not change the owning shard");
    });
}

// ------------------------------------------------- harness byte-identity --

fn record_key(m: &lmetric::metrics::RunMetrics) -> Vec<(u64, usize, u64, u64, u32)> {
    m.records
        .iter()
        .map(|r| (r.id, r.instance, r.first_token_us, r.completion_us, r.cached_tokens))
        .collect()
}

/// Budget 0 ⇒ every decision scores fully-fresh state ⇒ `run_concurrent`
/// is the serial DES, byte for byte, at any router count. A positive
/// budget may reorder placements but must still complete every request.
#[test]
fn run_concurrent_budget_zero_matches_run_des() {
    let mut exp = ExperimentConfig::default();
    exp.workload = "chatbot".into();
    exp.instances = 4;
    exp.requests = 400;
    exp.seed = 11;
    let cfg = cluster_config(&exp);
    let profile = cfg.engine.profile.clone();
    let trace = lmetric::cluster::build_scaled_trace(&exp);

    let mut pol = policy::build_default("lmetric", &profile, exp.chunk_budget).unwrap();
    let serial = run_des(&cfg, &trace, pol.as_mut());
    assert!(!serial.records.is_empty());

    for routers in [1usize, 2] {
        let mut mk = || policy::build_default("lmetric", &profile, exp.chunk_budget).unwrap();
        let m = run_concurrent(&cfg, &trace, &mut mk, &ConcurrentCfg::new(routers, 0));
        assert_eq!(
            record_key(&serial),
            record_key(&m),
            "budget-0 R={routers} must replay the serial trajectory"
        );
        assert_eq!(m.routers, routers);
        // Fresh views only: every recorded snapshot age is zero.
        assert!(m.snapshot_age.iter().all(|&a| a == 0.0));
        assert_eq!(m.guard, serial.guard, "guard deltas must match serial");
    }

    // Positive budget: decisions may commit against stale views, but the
    // run still serves the whole trace and ages stay within the budget.
    let mut mk = || policy::build_default("lmetric", &profile, exp.chunk_budget).unwrap();
    let m = run_concurrent(&cfg, &trace, &mut mk, &ConcurrentCfg::new(2, 64));
    assert_eq!(m.records.len(), serial.records.len());
    assert!(m.snapshot_age.iter().all(|&a| a <= 64.0));
}
