//! Cross-module integration tests: trace → router → engines → metrics,
//! through the public API only.

use lmetric::cluster::{build_scaled_trace, cluster_config, run_des};
use lmetric::config::{ConfigDoc, ExperimentConfig};
use lmetric::engine::ModelProfile;
use lmetric::metrics::save_results;
use lmetric::metrics::ResultRow;
use lmetric::policy;
use lmetric::trace::{generate, load_jsonl, save_jsonl, Workload, WorkloadSpec};

fn small_exp(workload: &str, requests: usize) -> ExperimentConfig {
    let mut exp = ExperimentConfig::default();
    exp.workload = workload.into();
    exp.requests = requests;
    exp.instances = 4;
    exp
}

#[test]
fn full_pipeline_all_workloads() {
    for workload in ["chatbot", "coder", "agent", "toolagent", "hotspot"] {
        let exp = small_exp(workload, 400);
        let mut pol = policy::build_default("lmetric", &ModelProfile::moe_30b(), 256).unwrap();
        let m = lmetric::cluster::run_experiment(&exp, pol.as_mut());
        assert_eq!(m.records.len(), 400, "{workload}: lost requests");
        assert!(m.ttft_summary().mean > 0.0);
        assert!(m.mean_hit_ratio() >= 0.0 && m.mean_hit_ratio() <= 1.0);
    }
}

#[test]
fn headline_claim_shape_chatbot() {
    // The paper's §6.1 headline: LMETRIC cuts ChatBot mean TTFT and TPOT
    // deeply vs the load-balancing-only vLLM policy, with a much higher
    // KV$ hit ratio — at half-capacity load on the DES testbed.
    let exp = small_exp("chatbot", 1500);
    let trace = build_scaled_trace(&exp);
    let cfg = cluster_config(&exp);
    let mut lm = policy::build_default("lmetric", &cfg.engine.profile, 256).unwrap();
    let mut vl = policy::build_default("vllm", &cfg.engine.profile, 256).unwrap();
    let mut m_lm = run_des(&cfg, &trace, lm.as_mut());
    let mut m_vl = run_des(&cfg, &trace, vl.as_mut());
    m_lm.discard_warmup(0.1);
    m_vl.discard_warmup(0.1);
    let ttft_cut = 1.0 - m_lm.ttft_summary().mean / m_vl.ttft_summary().mean;
    let tpot_cut = 1.0 - m_lm.tpot_summary().mean / m_vl.tpot_summary().mean;
    assert!(ttft_cut > 0.4, "TTFT reduction only {:.0}%", ttft_cut * 100.0);
    assert!(tpot_cut > 0.05, "TPOT reduction only {:.0}%", tpot_cut * 100.0);
    assert!(m_lm.mean_hit_ratio() > m_vl.mean_hit_ratio() + 0.15);
}

#[test]
fn hyperparameter_free_vs_mistuned_linear() {
    // The paper's motivation (§4.4): a mistuned λ hurts; LMETRIC needs no λ.
    let exp = small_exp("chatbot", 1200);
    let trace = build_scaled_trace(&exp);
    let cfg = cluster_config(&exp);
    let run = |name: &str, param: f64| {
        let mut p = policy::build(name, param, &cfg.engine.profile, 256).unwrap();
        let mut m = run_des(&cfg, &trace, p.as_mut());
        m.discard_warmup(0.1);
        m.ttft_summary().mean
    };
    let lmetric = run("lmetric", 0.0);
    let linear_bad = run("linear", 0.05); // nearly KV$-blind
    assert!(
        lmetric < linear_bad,
        "lmetric {lmetric} must beat mistuned linear {linear_bad}"
    );
}

#[test]
fn config_file_round_trip_drives_experiment() {
    let doc = ConfigDoc::parse(
        "[cluster]\ninstances = 3\nprofile = \"dense-7b\"\n[trace]\nworkload = \"agent\"\nrequests = 200\n[policy]\nname = \"vllm\"\n",
    )
    .unwrap();
    let exp = ExperimentConfig::from_doc(&doc).unwrap();
    assert_eq!(exp.instances, 3);
    let mut pol = policy::build_default(&exp.policy, &ModelProfile::dense_7b(), 256).unwrap();
    let m = lmetric::cluster::run_experiment(&exp, pol.as_mut());
    assert_eq!(m.records.len(), 200);
    // Only 3 instances should appear in records.
    assert!(m.records.iter().all(|r| r.instance < 3));
}

#[test]
fn trace_jsonl_replay_equivalence() {
    // Running a saved+reloaded trace must give identical results.
    let exp = small_exp("agent", 300);
    let trace = build_scaled_trace(&exp);
    let dir = std::env::temp_dir().join("lmetric_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("replay_eq.jsonl");
    save_jsonl(&trace, &path).unwrap();
    let reloaded = load_jsonl("agent", &path).unwrap();
    let cfg = cluster_config(&exp);
    let mut p1 = policy::build_default("lmetric", &cfg.engine.profile, 256).unwrap();
    let mut p2 = policy::build_default("lmetric", &cfg.engine.profile, 256).unwrap();
    let m1 = run_des(&cfg, &trace, p1.as_mut());
    let m2 = run_des(&cfg, &reloaded, p2.as_mut());
    assert_eq!(m1.records.len(), m2.records.len());
    for (a, b) in m1.records.iter().zip(&m2.records) {
        assert_eq!(a.completion_us, b.completion_us);
        assert_eq!(a.instance, b.instance);
    }
    std::fs::remove_file(path).ok();
}

#[test]
fn results_file_written_and_parse() {
    let exp = small_exp("chatbot", 200);
    let mut pol = policy::build_default("lmetric", &ModelProfile::moe_30b(), 256).unwrap();
    let m = lmetric::cluster::run_experiment(&exp, pol.as_mut());
    let rows = vec![ResultRow::from_metrics("lmetric", &m)];
    let path = save_results("_integration_test", &rows, &[("ttft".into(), m.ttfts())]).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let v = lmetric::util::json::Json::parse(&text).unwrap();
    assert!(v.get("rows").is_some());
    std::fs::remove_file(path).ok();
}

#[test]
fn rate_scaling_tracks_capacity_across_instance_counts() {
    // Doubling the cluster should roughly double the scaled arrival rate.
    // (The trace must be long enough that its horizon exceeds session
    // duration at the higher target, or the steady rate can't be reached.)
    let mut e2 = small_exp("chatbot", 2500);
    e2.instances = 2;
    let mut e4 = small_exp("chatbot", 2500);
    e4.instances = 4;
    let t2 = build_scaled_trace(&e2);
    let t4 = build_scaled_trace(&e4);
    let ratio = t4.steady_rps() / t2.steady_rps();
    assert!((1.4..=2.8).contains(&ratio), "ratio {ratio}");
}

#[test]
fn higher_rate_means_worse_latency() {
    // Monotonicity sanity for the Fig 23 rate sweeps.
    let mk = |rate: f64| {
        let mut exp = small_exp("chatbot", 2500);
        exp.instances = 2;
        exp.rate_scale = rate;
        let mut p = policy::build_default("lmetric", &ModelProfile::moe_30b(), 256).unwrap();
        let mut m = lmetric::cluster::run_experiment(&exp, p.as_mut());
        m.discard_warmup(0.1);
        m.ttft_summary().mean
    };
    let low = mk(0.3);
    let high = mk(0.85);
    assert!(high > low, "ttft@0.85={high} should exceed ttft@0.3={low}");
}

#[test]
fn untuned_simulator_degrades_sim_policy() {
    // Fig 15's effect through the whole stack.
    use lmetric::policy::SimBased;
    use lmetric::simulator::LatencySimulator;
    let mut exp = small_exp("chatbot", 2000);
    exp.rate_scale = 0.7; // mispredictions only bite under real load
    let trace = build_scaled_trace(&exp);
    let cfg = cluster_config(&exp);
    let mut tuned = SimBased::new(LatencySimulator::tuned(cfg.engine.profile.clone(), 256));
    let mut untuned = SimBased::new(LatencySimulator::untuned(ModelProfile::dense_7b(), 256));
    let mut m_t = run_des(&cfg, &trace, &mut tuned);
    let mut m_u = run_des(&cfg, &trace, &mut untuned);
    m_t.discard_warmup(0.1);
    m_u.discard_warmup(0.1);
    assert!(
        m_u.ttft_summary().p95 > m_t.ttft_summary().p95,
        "untuned p95 {} should exceed tuned {}",
        m_u.ttft_summary().p95,
        m_t.ttft_summary().p95
    );
    // Error ratios were recorded for both (Fig 16's CDF source).
    assert!(!m_t.sim_error_ratio.is_empty());
    assert!(!m_u.sim_error_ratio.is_empty());
    let mean_err = |v: &Vec<f64>| v.iter().sum::<f64>() / v.len() as f64;
    assert!(mean_err(&m_u.sim_error_ratio) > mean_err(&m_t.sim_error_ratio));
}

#[test]
fn guarded_lmetric_harmless_on_benign_traces() {
    // The detector must not fire (or must not hurt) on normal workloads.
    let exp = small_exp("chatbot", 1000);
    let trace = build_scaled_trace(&exp);
    let cfg = cluster_config(&exp);
    let mut plain = policy::build_default("lmetric", &cfg.engine.profile, 256).unwrap();
    let mut guarded = lmetric::hotspot::HotspotGuarded::new();
    let m_p = run_des(&cfg, &trace, plain.as_mut());
    let m_g = run_des(&cfg, &trace, &mut guarded);
    let ratio = m_g.ttft_summary().mean / m_p.ttft_summary().mean;
    assert!(ratio < 1.15, "guarded must not regress benign traffic: {ratio}");
}

#[test]
fn workload_families_have_distinct_hit_structure() {
    let coder = generate(&WorkloadSpec::preset(Workload::Coder, 1500, 1));
    let agent = generate(&WorkloadSpec::preset(Workload::Agent, 1500, 1));
    assert!(
        coder.infinite_cache_hit_rate() > agent.infinite_cache_hit_rate(),
        "coder (repo context reuse) must out-hit agent (short one-shots)"
    );
}
