//! Cross-policy semantic contracts: each baseline must implement its
//! paper pseudocode (Figs 6/13/14/17/30/33) on crafted indicator states.
//! These are the behaviours the §4 characterization attributes to each
//! combination strategy.

use lmetric::policy::{self, KvAwareIndicator, LMetric, LoadIndicator};
use lmetric::router::{Indicators, Policy, RouteCtx};

fn ctx(input: usize, hits: Vec<usize>, inds: Vec<Indicators>) -> RouteCtx {
    RouteCtx::new(1_000_000, 1, 0, input, hits, inds)
}

fn ind(r_bs: usize, q_bs: usize, queued_tok: usize, ctx_tok: usize) -> Indicators {
    Indicators {
        r_bs,
        q_bs,
        queued_prefill_tokens: queued_tok,
        total_context_tokens: ctx_tok,
        kv_used_blocks: 0,
        kv_capacity_blocks: 0,
        routable: true,
    }
}

// ------------------------------------------------- vLLM (Fig 6a) -------

#[test]
fn vllm_weights_queued_4x_running() {
    // 4·Q-BS + R-BS: 1 queued (score 4) loses to 3 running (score 3).
    let c = ctx(
        100,
        vec![0, 0],
        vec![ind(0, 1, 0, 0), ind(3, 0, 0, 0)],
    );
    let profile = lmetric::engine::ModelProfile::moe_30b();
    let mut p = policy::build_default("vllm", &profile, 256).unwrap();
    assert_eq!(p.route(&c).instance, 1);
}

#[test]
fn vllm_is_kv_blind() {
    // A full KV$ hit must not attract vLLM at equal load.
    let c = ctx(
        1000,
        vec![1000, 0],
        vec![ind(5, 0, 0, 0), ind(4, 0, 0, 0)],
    );
    let profile = lmetric::engine::ModelProfile::moe_30b();
    let mut p = policy::build_default("vllm", &profile, 256).unwrap();
    assert_eq!(p.route(&c).instance, 1, "vLLM ignores hits by design");
}

// ------------------------------------------- linear (Fig 6b) -----------

#[test]
fn linear_normalizes_bs_against_current_max() {
    // With BS normalized, the *relative* load matters: (hit 0%, bs 10/10)
    // vs (hit 0%, bs 9/10): λ=0.5 picks the smaller normalized bs.
    let c = ctx(
        100,
        vec![0, 0],
        vec![ind(10, 0, 0, 0), ind(9, 0, 0, 0)],
    );
    let profile = lmetric::engine::ModelProfile::moe_30b();
    let mut p = policy::build("linear", 0.5, &profile, 256).unwrap();
    assert_eq!(p.route(&c).instance, 1);
}

// ------------------------------------------------- lmetric (Fig 17) ----

#[test]
fn lmetric_score_matches_formula_exactly() {
    let c = ctx(
        800,
        vec![320, 0],
        vec![ind(3, 1, 500, 0), ind(2, 0, 100, 0)],
    );
    let p = LMetric::paper();
    // score_0 = (500 + (800-320)) × (3+1+1) = 980 × 5
    assert_eq!(p.score(&c, 0), (500.0 + 480.0) * 5.0);
    // score_1 = (100 + 800) × (2+1) = 900 × 3
    assert_eq!(p.score(&c, 1), 900.0 * 3.0);
}

#[test]
fn lmetric_all_variants_disagree_only_via_indicators() {
    // On a state where hit ratio and P-token rank instances identically
    // and BS == context proxy, all four variants agree.
    let c = ctx(
        320,
        vec![320, 0],
        vec![ind(2, 0, 0, 2 * 100), ind(2, 0, 0, 2 * 100)],
    );
    for (kv, load) in [
        (KvAwareIndicator::PToken, LoadIndicator::BatchSize),
        (KvAwareIndicator::OneMinusHitRatio, LoadIndicator::BatchSize),
        (KvAwareIndicator::PToken, LoadIndicator::TotalTokens),
        (KvAwareIndicator::OneMinusHitRatio, LoadIndicator::TotalTokens),
    ] {
        let mut p = LMetric::new(kv, load);
        assert_eq!(p.route(&c).instance, 0, "{kv:?}/{load:?}");
    }
}

// ------------------------------------------- filter_kv (Fig 13) --------

#[test]
fn filter_boundary_is_strict_greater() {
    // Fig 13 line 3: BS.max()-BS.min() > Range — equality stays in the
    // KV$ branch.
    let profile = lmetric::engine::ModelProfile::moe_30b();
    let c = ctx(
        100,
        vec![0, 96],
        vec![ind(0, 0, 0, 0), ind(4, 0, 0, 0)],
    );
    // range == 4 exactly: KV$ branch -> instance 1 (the hit).
    let mut p = policy::build("filter_kv", 4.0, &profile, 256).unwrap();
    assert_eq!(p.route(&c).instance, 1);
    // range 3 < 4: load-balance branch -> instance 0.
    let mut p = policy::build("filter_kv", 3.0, &profile, 256).unwrap();
    assert_eq!(p.route(&c).instance, 0);
}

// ------------------------------------------------ polyserve (Fig 33) ---

#[test]
fn polyserve_prefers_most_loaded_feasible() {
    use lmetric::policy::PolyServe;
    use lmetric::simulator::LatencySimulator;
    let sim = LatencySimulator::tuned(lmetric::engine::ModelProfile::moe_30b(), 256);
    let mut p = PolyServe::new(sim, 1_000_000.0); // 1 s SLO: everything feasible
    let c = ctx(
        100,
        vec![0, 0, 0],
        vec![ind(10, 0, 0, 10 * 300), ind(2, 0, 0, 2 * 300), ind(6, 0, 0, 6 * 300)],
    );
    // All feasible -> the most loaded (highest predicted TPOT) wins.
    assert_eq!(p.route(&c).instance, 0);
}

// ------------------------------------------------ guarded lmetric ------

#[test]
fn guarded_equals_plain_without_hotspot() {
    // On states with broad cache coverage the detector must be inert.
    let mut plain = LMetric::paper();
    let mut guarded = lmetric::hotspot::HotspotGuarded::new();
    let mut rng = lmetric::util::Rng::new(9);
    for k in 0..200u64 {
        let n = 4;
        let hits: Vec<usize> = (0..n).map(|_| (rng.gen_range(0, 5) * 16) as usize).collect();
        let inds: Vec<Indicators> = (0..n)
            .map(|_| ind(rng.gen_range(0, 20) as usize, 0, rng.gen_range(0, 2000) as usize, 0))
            .collect();
        let mut c = ctx(160, hits, inds);
        c.class_id = (k % 6) as u32;
        c.now_us = k * 50_000;
        assert_eq!(plain.route(&c).instance, guarded.route(&c).instance, "k={k}");
    }
}

// ------------------------------- failure-condition guard ---------------

/// The paper's "extremely rare in practice" claim as a regression test:
/// the failure-guarded policy (`lmetric_safe`) replays byte-identical
/// decisions to bare `LMetric::paper()` through the full DES on every
/// natural workload × seed — and its mitigation counter stays at 0.
/// (Detections may fire — idle lulls and full-hit annihilations exist in
/// natural traffic — but on DES-reachable indicator states the guard's
/// tie re-rank provably agrees with select_min, so decisions never
/// move.)
#[test]
fn safe_lmetric_replays_paper_decisions_on_all_natural_workloads() {
    use lmetric::cluster::{build_scaled_trace, cluster_config, run_des};
    use lmetric::config::ExperimentConfig;
    use lmetric::policy::GuardedLMetric;

    for workload in ["chatbot", "coder", "agent", "toolagent", "hotspot"] {
        for seed in [1u64, 7] {
            let mut exp = ExperimentConfig::default();
            exp.workload = workload.into();
            exp.instances = 8;
            exp.requests = 250;
            exp.rate_scale = 0.5;
            exp.seed = seed;
            let trace = build_scaled_trace(&exp);
            let cfg = cluster_config(&exp);
            let mut plain = policy::build_default("lmetric", &cfg.engine.profile, 256).unwrap();
            let m_p = run_des(&cfg, &trace, plain.as_mut());
            let mut guarded = GuardedLMetric::new();
            let m_g = run_des(&cfg, &trace, &mut guarded);
            assert_eq!(m_p.records.len(), m_g.records.len(), "{workload}/{seed}");
            for (a, b) in m_p.records.iter().zip(&m_g.records) {
                assert_eq!(
                    (a.id, a.instance, a.first_token_us, a.completion_us, a.cached_tokens),
                    (b.id, b.instance, b.first_token_us, b.completion_us, b.cached_tokens),
                    "{workload}/{seed}: guarded decision diverged at request {}",
                    a.id
                );
            }
            assert_eq!(
                m_g.guard.mitigated, 0,
                "{workload}/{seed}: mitigation fired on natural traffic"
            );
            assert_eq!(
                m_g.guard.checks,
                trace.requests.len() as u64,
                "{workload}/{seed}: one guard check per decision"
            );
        }
    }
}

/// Regression for the all-idle tie degeneracy: with every instance at
/// `BS == 0` and the products exactly tied, bare `select_min` resolves
/// the 0-spread tie by lowest index — discarding an 800-token cached
/// prefix difference. The guard's secondary key must pick the max-hit
/// instance. (The first assertion documents the old behaviour this
/// guards against; the second fails on pre-guard code.)
#[test]
fn all_idle_tie_guard_prefers_max_hit_instance() {
    use lmetric::policy::GuardedLMetric;
    // P-token: (0 + (1600-800), 800 + (1600-1600)) = (800, 800); BS = 0
    // everywhere, so the products tie at 800 x 1 with an 800-token hit
    // gap between the instances.
    let c = ctx(
        1600,
        vec![800, 1600],
        vec![ind(0, 0, 0, 0), ind(0, 0, 800, 0)],
    );
    let mut plain = LMetric::paper();
    assert_eq!(
        plain.route(&c).instance,
        0,
        "old code: lowest index wins the 0-spread tie"
    );
    let mut guarded = GuardedLMetric::new();
    assert_eq!(
        guarded.route(&c).instance,
        1,
        "guard must resolve the tie toward the longest cached prefix"
    );
    assert_eq!(guarded.counters.degenerate, 1);
    assert_eq!(guarded.counters.mitigated, 1);
}

// ------------------------------- shared-index routing equivalence ------

/// The tentpole contract of the shared presence-mask prefix index: for
/// every workload family and every (deterministic) policy, routing
/// decisions computed from the shared index are IDENTICAL to decisions
/// computed from the old one-radix-mirror-per-instance design. Three
/// legs replay the same trace — the real `IndicatorFactory` (now backed
/// by the *sharded* index), a `MirrorKvView` reference, and a bare
/// `SharedRadixIndex` (the pre-sharding monolith) fed the identical
/// insert sequence — with bounded per-instance KV$ so LRU eviction is
/// exercised. All three must agree on every hit vector, and the two
/// policy instances on every single decision: the sharding refactor is
/// pinned decision-identical to both ancestral designs.
#[test]
fn shared_index_reproduces_mirror_decisions_all_workloads_all_policies() {
    use lmetric::core::{InstanceMask, BLOCK_TOKENS};
    use lmetric::engine::ModelProfile;
    use lmetric::kvcache::{MirrorKvView, SharedRadixIndex};
    use lmetric::router::IndicatorFactory;
    use lmetric::trace::{generate, Workload, WorkloadSpec};

    let profile = ModelProfile::moe_30b();
    let n = 8usize;
    let cap_blocks = 128usize; // small: heavy per-instance eviction churn
    for workload in ["chatbot", "coder", "agent", "toolagent", "hotspot"] {
        let spec = WorkloadSpec::preset(Workload::by_name(workload).unwrap(), 400, 7);
        let trace = generate(&spec);
        for name in policy::all_names() {
            if *name == "random" {
                continue; // stateful RNG across calls by design
            }
            let mut p_shared = policy::build_default(name, &profile, 256).unwrap();
            let mut p_mirror = policy::build_default(name, &profile, 256).unwrap();
            let mut factory = IndicatorFactory::new(n, cap_blocks);
            let mut mirror = MirrorKvView::new(n, cap_blocks);
            let mut monolith = SharedRadixIndex::new(n, cap_blocks);
            let mut mono_blocks: Vec<usize> = Vec::new();
            let mut mono_mask = InstanceMask::default();
            for (k, tr) in trace.requests.iter().enumerate() {
                let now = tr.req.arrival_us;
                let input_len = tr.req.input_len();
                let mirror_hits: Vec<usize> = mirror
                    .match_all(&tr.req.block_hashes, now)
                    .iter()
                    .map(|b| (b * BLOCK_TOKENS).min(input_len))
                    .collect();
                monolith.match_into(&tr.req.block_hashes, &mut mono_blocks, &mut mono_mask);
                let mono_hits: Vec<usize> = mono_blocks
                    .iter()
                    .map(|b| (b * BLOCK_TOKENS).min(input_len))
                    .collect();
                let ctx = factory.route_ctx(&tr.req, now);
                assert_eq!(
                    ctx.hit_tokens, mirror_hits,
                    "{workload}/{name}: hit vector diverged at request {k}"
                );
                assert_eq!(
                    ctx.hit_tokens, mono_hits,
                    "{workload}/{name}: sharded index diverged from the \
                     pre-sharding SharedRadixIndex at request {k}"
                );
                let mirror_ctx = RouteCtx::new(
                    now,
                    tr.req.id,
                    tr.req.class_id,
                    input_len,
                    mirror_hits,
                    ctx.inds.clone(),
                )
                .with_session(tr.req.session_id);
                let d = p_shared.route(ctx).instance;
                let d_mirror = p_mirror.route(&mirror_ctx).instance;
                assert_eq!(
                    d, d_mirror,
                    "{workload}/{name}: decision diverged at request {k}"
                );
                factory.on_route(d, &tr.req, now);
                mirror.on_route(d_mirror, &tr.req.block_hashes, now);
                monolith.insert(d, &tr.req.block_hashes, now);
                // Periodic completion piggybacks (prompt+output chains),
                // like the DES's response path.
                if k % 3 == 0 {
                    factory.on_completion(d, &tr.full_hashes, now);
                    mirror.on_response(d_mirror, &tr.full_hashes, now);
                    monolith.insert(d, &tr.full_hashes, now);
                }
            }
            factory.kv.index().check_invariants().unwrap();
            monolith.check_invariants().unwrap();
            // The sharded refactor preserves per-instance occupancy too,
            // not just walk results.
            for i in 0..n {
                assert_eq!(
                    factory.kv.index().used_blocks(i),
                    monolith.used_blocks(i),
                    "{workload}/{name}: instance {i} occupancy diverged"
                );
            }
        }
    }
}

// ----------------------------------------- decision determinism --------

#[test]
fn all_policies_deterministic_given_state() {
    // Two fresh instances of the same policy must agree decision-by-
    // decision on an identical request stream (reproducibility of every
    // figure depends on this).
    let profile = lmetric::engine::ModelProfile::moe_30b();
    for name in policy::all_names() {
        if *name == "random" {
            continue; // seeded, but stateful across calls by design
        }
        let mut a = policy::build_default(name, &profile, 256).unwrap();
        let mut b = policy::build_default(name, &profile, 256).unwrap();
        let mut rng = lmetric::util::Rng::new(7);
        for k in 0..100u64 {
            let n = 6;
            let hits: Vec<usize> = (0..n).map(|_| (rng.gen_range(0, 10) * 16) as usize).collect();
            let inds: Vec<Indicators> = (0..n)
                .map(|_| {
                    ind(
                        rng.gen_range(0, 30) as usize,
                        rng.gen_range(0, 5) as usize,
                        rng.gen_range(0, 10_000) as usize,
                        rng.gen_range(0, 50_000) as usize,
                    )
                })
                .collect();
            let mut c = ctx(160, hits, inds);
            c.now_us = k * 10_000;
            c.req_id = k;
            assert_eq!(a.route(&c).instance, b.route(&c).instance, "{name} diverged at {k}");
        }
    }
}
