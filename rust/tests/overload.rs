//! Contracts of the unified `RunSpec` entry point and the overload
//! subsystem: the legacy wrappers replay byte-identically through
//! `run`, a no-op admission policy is invisible to the trajectory,
//! admission accounting is exact, and the orphan walk counts exactly
//! the turns stranded behind a mid-session shed.

use lmetric::cluster::{
    run, run_des, run_session_des, AdmissionPolicy, AdmitAll, ClusterConfig, QueueDepthShed,
    Release, RunSpec, SessionAwareShed,
};
use lmetric::core::RequestRecord;
use lmetric::engine::{EngineConfig, ModelProfile};
use lmetric::metrics::{OverloadCounters, SloSpec};
use lmetric::policy;
use lmetric::router::RouteCtx;
use lmetric::trace::{generate, generate_sessions, SessionKind, SessionSpec, Workload, WorkloadSpec};

fn cfg(n: usize) -> ClusterConfig {
    ClusterConfig::new(n, EngineConfig::default())
}

fn lmetric_policy() -> Box<dyn lmetric::router::Policy> {
    policy::build_default("lmetric", &ModelProfile::moe_30b(), 256).unwrap()
}

/// Every observable field of a record, for byte-identity comparisons.
#[allow(clippy::type_complexity)]
fn record_key(r: &RequestRecord) -> (u64, usize, u64, u64, u64, u32, u32, u32) {
    (
        r.id,
        r.instance,
        r.arrival_us,
        r.first_token_us,
        r.completion_us,
        r.cached_tokens,
        r.input_len,
        r.output_len,
    )
}

fn keys(records: &[RequestRecord]) -> Vec<(u64, usize, u64, u64, u64, u32, u32, u32)> {
    records.iter().map(record_key).collect()
}

/// `run(RunSpec)` is the one entry point: both legacy wrappers and the
/// explicit spec forms replay record-for-record identically, and a run
/// without an admission policy reports no overload accounting at all.
#[test]
fn run_spec_pins_both_legacy_wrappers_byte_identically() {
    let trace = generate(&WorkloadSpec::preset(Workload::ChatBot, 400, 11));
    let c = cfg(4);
    let m_wrap = run_des(&c, &trace, lmetric_policy().as_mut());
    let m_spec = run(RunSpec::open_loop(&c, &trace), lmetric_policy().as_mut());
    assert_eq!(m_wrap.records.len(), 400);
    assert_eq!(keys(&m_wrap.records), keys(&m_spec.records));
    assert_eq!(m_spec.admission_name, None);
    assert_eq!(m_spec.slo, None);
    assert_eq!(m_spec.overload, OverloadCounters::default());

    // On a flat trace the release mode is vacuous: there are no
    // follow-up chains to release reactively.
    let spec = RunSpec::open_loop(&c, &trace).with_release(Release::Reactive);
    let m_reactive = run(spec, lmetric_policy().as_mut());
    assert_eq!(keys(&m_wrap.records), keys(&m_reactive.records));

    let strace = generate_sessions(&SessionSpec::preset(SessionKind::Chat, 300, 7));
    let m_swrap = run_session_des(&c, &strace, lmetric_policy().as_mut());
    let m_sspec = run(RunSpec::sessions(&c, &strace), lmetric_policy().as_mut());
    assert_eq!(m_swrap.records.len(), strace.n_turns());
    assert_eq!(keys(&m_swrap.records), keys(&m_sspec.records));

    // Open-loop release of a session trace == classic replay of its
    // flattened form (pre-stamped arrivals, think times already baked).
    let flat = strace.flatten();
    let m_flat = run_des(&c, &flat, lmetric_policy().as_mut());
    let spec = RunSpec::sessions(&c, &strace).with_release(Release::OpenLoop);
    let m_open = run(spec, lmetric_policy().as_mut());
    assert_eq!(keys(&m_flat.records), keys(&m_open.records));
}

/// An admission policy that never sheds must be invisible: the
/// trajectory is byte-identical to the bare run, only the accounting
/// (offered == admitted, goodput under an infinite SLO == 1.0) differs.
#[test]
fn admit_all_is_invisible_to_the_trajectory() {
    let strace = generate_sessions(&SessionSpec::preset(SessionKind::Chat, 300, 7));
    let c = cfg(4);
    let m_bare = run(RunSpec::sessions(&c, &strace), lmetric_policy().as_mut());
    let slo = SloSpec::new(f64::INFINITY, f64::INFINITY);
    let spec = RunSpec::sessions(&c, &strace).with_admission(Box::new(AdmitAll)).with_slo(slo);
    let m_adm = run(spec, lmetric_policy().as_mut());
    assert_eq!(keys(&m_bare.records), keys(&m_adm.records));
    assert_eq!(m_adm.admission_name.as_deref(), Some("admit_all"));
    assert_eq!(m_adm.slo, Some(slo));
    let o = m_adm.overload;
    assert_eq!(o.offered, strace.n_turns() as u64);
    assert_eq!(o.admitted, o.offered);
    assert_eq!(o.shed, 0);
    assert_eq!(m_adm.goodput_ratio(slo), 1.0);
}

/// Shedding on an open-loop (flat) trace: exact offered/admitted/shed
/// accounting, and — because flat traces have no follow-up chains — the
/// orphan counter stays zero no matter how hard the shedding bites.
#[test]
fn open_loop_shed_accounting_is_exact() {
    let trace = generate(&WorkloadSpec::preset(Workload::ChatBot, 400, 3));
    let c = cfg(1);
    let spec = RunSpec::open_loop(&c, &trace).with_admission(Box::new(QueueDepthShed::new(1)));
    let m = run(spec, lmetric_policy().as_mut());
    let o = m.overload;
    assert_eq!(o.offered, trace.requests.len() as u64);
    assert_eq!(o.offered, o.admitted + o.shed);
    assert_eq!(m.records.len() as u64, o.admitted);
    assert!(o.admitted >= 1, "the first arrival lands on an empty cluster");
    assert!(o.shed > 0, "depth-1 threshold on one instance must shed");
    assert_eq!(o.orphaned_turns, 0, "flat traces have no chains to strand");
}

/// Admits exactly one turn of exactly one session; everything else is
/// shed. Makes the orphan walk's expected counts computable from the
/// trace alone.
struct AdmitOneTurn {
    sid: u64,
    used: bool,
}

impl AdmissionPolicy for AdmitOneTurn {
    fn name(&self) -> String {
        "admit_one_turn".into()
    }

    fn admit(&mut self, ctx: &RouteCtx) -> bool {
        if ctx.session_id == self.sid && !self.used {
            self.used = true;
            return true;
        }
        false
    }
}

/// A mid-session shed strands the rest of the conversation: shedding
/// turn 1 of an L-turn session must count one mid-session shed and
/// exactly L-2 orphaned turns; sessions rejected at turn 0 count as
/// shed sessions, not orphans.
#[test]
fn orphan_walk_counts_exactly_the_stranded_turns() {
    let strace = generate_sessions(&SessionSpec::preset(SessionKind::Chat, 300, 11));
    let target = strace
        .sessions
        .iter()
        .max_by_key(|s| (s.turns.len(), s.sid))
        .unwrap();
    let turns = target.turns.len();
    assert!(turns >= 2, "chat preset must produce a multi-turn session");

    let c = cfg(2);
    let adm = AdmitOneTurn {
        sid: target.sid,
        used: false,
    };
    let spec = RunSpec::sessions(&c, &strace).with_admission(Box::new(adm));
    let m = run(spec, lmetric_policy().as_mut());

    // Only the target's turn 0 runs; its turn 1 releases reactively,
    // gets shed mid-session, and strands turns 2..L. Every other
    // session is rejected at turn 0 and its chain never releases.
    let n_sessions = strace.sessions.len() as u64;
    assert_eq!(m.records.len(), 1);
    assert_eq!(m.records[0].id, target.turns[0].req.id);
    let o = m.overload;
    assert_eq!(o.offered, n_sessions + 1);
    assert_eq!(o.admitted, 1);
    assert_eq!(o.shed, n_sessions);
    assert_eq!(o.shed_sessions, n_sessions - 1);
    assert_eq!(o.shed_mid_session, 1);
    assert_eq!(o.orphaned_turns, turns as u64 - 2);
    assert_eq!(m.admission_name.as_deref(), Some("admit_one_turn"));
}

/// The conversation-integrity wrapper end to end: under a flood that
/// forces real shedding, admitted sessions complete every turn, refused
/// sessions run zero turns, and no turn is ever orphaned.
#[test]
fn session_aware_shed_never_orphans_under_flood() {
    let mut spec = SessionSpec::preset(SessionKind::Chat, 250, 13);
    spec.session_rate = 200.0; // ~5ms between session starts: a flood
    let strace = generate_sessions(&spec);
    let c = cfg(1);
    let adm = SessionAwareShed::new(Box::new(QueueDepthShed::new(1)));
    let rs = RunSpec::sessions(&c, &strace).with_admission(Box::new(adm));
    let m = run(rs, lmetric_policy().as_mut());
    let o = m.overload;

    assert_eq!(o.offered, o.admitted + o.shed);
    assert_eq!(m.records.len() as u64, o.admitted);
    assert!(o.shed > 0, "a 200/s flood on one instance must shed");
    assert_eq!(o.shed_mid_session, 0, "admitted sessions are never shed");
    assert_eq!(o.orphaned_turns, 0, "session-aware shedding cannot orphan");

    // All-or-nothing per session: every session either completes every
    // turn or runs none of them.
    let done: std::collections::HashSet<u64> = m.records.iter().map(|r| r.id).collect();
    for s in &strace.sessions {
        let hits = s.turns.iter().filter(|t| done.contains(&t.req.id)).count();
        assert!(
            hits == 0 || hits == s.turns.len(),
            "session {} ran {hits}/{} turns",
            s.sid,
            s.turns.len()
        );
    }
    assert!(o.shed_sessions > 0, "the flood must refuse whole sessions");
}
