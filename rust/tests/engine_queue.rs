//! Within-instance queue scheduling (`engine::queue`) through the public
//! API: the fcfs decision-replay pin (byte-identity with the seed
//! engine's pop-front admission), and starvation-freedom of the
//! reordering policies under adversarial floods.

use lmetric::cluster::{run, run_des, ClusterConfig, RunSpec};
use lmetric::engine::EngineConfig;
use lmetric::metrics::RunMetrics;
use lmetric::policy;
use lmetric::trace::{
    generate, generate_adversarial, AdversarialScenario, AdversarialSpec, Trace, Workload,
    WorkloadSpec,
};

fn assert_same_records(a: &RunMetrics, b: &RunMetrics, label: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{label}: completion count");
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(
            (x.id, x.instance, x.arrival_us, x.first_token_us, x.completion_us, x.cached_tokens),
            (y.id, y.instance, y.arrival_us, y.first_token_us, y.completion_us, y.cached_tokens),
            "{label}: records diverged"
        );
    }
    assert_eq!(a.duration_us, b.duration_us, "{label}: duration");
    assert_eq!(a.total_steps, b.total_steps, "{label}: steps");
}

/// The tentpole's no-regression pin: `fcfs` (the default queue policy)
/// must replay byte-identically to the seed engine's pop-front admission
/// on every workload family under every router policy. The left run uses
/// the plain legacy entry point, the right one the explicit
/// `with_queue_policy("fcfs")` override — identical trajectories prove
/// both that fcfs selection ≡ pop_front and that the override plumbing
/// adds no events, tiebreaks or arithmetic drift.
#[test]
fn fcfs_is_byte_identical_to_the_seed_engine_everywhere() {
    let cfg = ClusterConfig::new(4, EngineConfig::default());
    for workload in [
        Workload::ChatBot,
        Workload::Coder,
        Workload::Agent,
        Workload::ToolAgent,
        Workload::Hotspot,
    ] {
        let trace = generate(&WorkloadSpec::preset(workload, 150, 7));
        for name in policy::all_names() {
            if *name == "random" {
                continue; // load-oblivious coin flips; nothing to pin
            }
            let mut p1 = policy::build_default(name, &cfg.engine.profile, 256).unwrap();
            let mut p2 = policy::build_default(name, &cfg.engine.profile, 256).unwrap();
            let base = run_des(&cfg, &trace, p1.as_mut());
            let explicit = run(
                RunSpec::open_loop(&cfg, &trace).with_queue_policy("fcfs"),
                p2.as_mut(),
            );
            assert_same_records(&base, &explicit, &format!("{name}/{workload:?}"));
        }
    }
}

fn flood_trace(n: usize, seed: u64) -> Trace {
    generate_adversarial(&AdversarialSpec::preset(
        AdversarialScenario::SharedPrefixFlood,
        n,
        seed,
    ))
}

fn small_cluster(max_batch: usize) -> ClusterConfig {
    let mut engine = EngineConfig::default();
    engine.max_batch = max_batch;
    ClusterConfig::new(2, engine)
}

/// Starvation freedom under adversarial long-prompt floods: with tiny
/// batches the waiting queues run deep and srpt/ltr reorder hard, yet
/// every admitted request must still reach its first token and complete
/// exactly once. Only `ltr` pays for that with promotions — its
/// starvation quantum visibly fires — while `srpt` (no aging) and the
/// flood's finite length keep it conservation-safe here.
#[test]
fn reordering_policies_conserve_under_shared_prefix_flood() {
    for seed in [1u64, 2, 3] {
        let trace = flood_trace(96, seed);
        let cfg = small_cluster(4);
        let mut run_queue = |qp: &str| {
            let mut p = policy::build_default("lmetric", &cfg.engine.profile, 256).unwrap();
            run(
                RunSpec::open_loop(&cfg, &trace).with_queue_policy(qp),
                p.as_mut(),
            )
        };
        let m_srpt = run_queue("srpt");
        let m_ltr = run_queue("ltr");
        for (qp, m) in [("srpt", &m_srpt), ("ltr", &m_ltr)] {
            assert_eq!(
                m.records.len(),
                trace.requests.len(),
                "seed {seed}: {qp} lost requests"
            );
            let mut ids: Vec<u64> = m.records.iter().map(|r| r.id).collect();
            ids.sort();
            ids.dedup();
            assert_eq!(ids.len(), trace.requests.len(), "seed {seed}: {qp} duplicates");
            for r in &m.records {
                assert!(r.first_token_us > r.arrival_us, "seed {seed}: {qp} no first token");
            }
            // Every admission was wait-sampled exactly once.
            let samples: u64 = m.queue.iter().map(|q| q.wait_samples).sum();
            assert_eq!(samples, trace.requests.len() as u64, "seed {seed}: {qp} samples");
            assert_eq!(m.total_stalled_steps(), 0, "seed {seed}: {qp} stalled");
        }
        assert_eq!(m_srpt.total_promotions(), 0, "srpt never promotes");
        assert!(
            m_ltr.total_promotions() > 0,
            "seed {seed}: ltr must promote under a deep flood queue"
        );
    }
}

/// On a benign uniform trace with roomy batches nothing ever waits past
/// its first admission opportunity, so the ltr starvation quantum must
/// stay silent: zero promotions, identical conservation.
#[test]
fn ltr_promotions_stay_zero_on_uniform_traffic() {
    let trace = generate(&WorkloadSpec::preset(Workload::ChatBot, 200, 11));
    // max_batch above the whole trace: no batch can ever fill, so no
    // request is ever passed over at admission — the zero-promotion
    // claim is structural, not a tuning accident.
    let mut engine = EngineConfig::default();
    engine.max_batch = 256;
    let cfg = ClusterConfig::new(4, engine);
    let mut p = policy::build_default("lmetric", &cfg.engine.profile, 256).unwrap();
    let m = run(
        RunSpec::open_loop(&cfg, &trace).with_queue_policy("ltr"),
        p.as_mut(),
    );
    assert_eq!(m.records.len(), 200);
    assert_eq!(
        m.total_promotions(),
        0,
        "no batch ever filled, so nothing was passed over and nothing starved"
    );
    assert_eq!(m.total_stalled_steps(), 0);
}
