//! Closed-loop session engine contracts: determinism, reactive-arrival
//! causality, per-turn prefix-hit growth, and the open-loop equivalence
//! that pins the reactive DES core to the classic replay path.

use std::collections::HashMap;

use lmetric::cluster::{build_scaled_sessions, run_des, run_session_des, ClusterConfig};
use lmetric::core::{RequestRecord, BLOCK_TOKENS};
use lmetric::engine::EngineConfig;
use lmetric::metrics::SessionMetrics;
use lmetric::policy;
use lmetric::trace::{generate_sessions, SessionKind, SessionSpec};

fn cfg(n: usize) -> ClusterConfig {
    ClusterConfig::new(n, EngineConfig::default())
}

fn lmetric_policy() -> Box<dyn lmetric::router::Policy> {
    policy::build_default("lmetric", &lmetric::engine::ModelProfile::moe_30b(), 256).unwrap()
}

fn by_id(records: &[RequestRecord]) -> HashMap<u64, RequestRecord> {
    records.iter().map(|r| (r.id, *r)).collect()
}

/// Every observable field of a record, for byte-identity comparisons.
#[allow(clippy::type_complexity)]
fn record_key(r: &RequestRecord) -> (u64, usize, u64, u64, u64, u32, u32, u32) {
    (
        r.id,
        r.instance,
        r.arrival_us,
        r.first_token_us,
        r.completion_us,
        r.cached_tokens,
        r.input_len,
        r.output_len,
    )
}

/// Closed-loop replays are exactly as deterministic as open-loop ones:
/// the same seed replays record-for-record identically.
#[test]
fn session_des_deterministic_by_seed() {
    let spec = SessionSpec::preset(SessionKind::Chat, 300, 11);
    let strace = generate_sessions(&spec);
    let c = cfg(4);
    let mut p1 = lmetric_policy();
    let mut p2 = lmetric_policy();
    let a = run_session_des(&c, &strace, p1.as_mut());
    let b = run_session_des(&c, &strace, p2.as_mut());
    assert_eq!(a.records.len(), 300);
    assert_eq!(a.records.len(), b.records.len());
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(record_key(x), record_key(y));
    }
    // A different seed produces a different schedule.
    let other = generate_sessions(&SessionSpec::preset(SessionKind::Chat, 300, 12));
    let mut p3 = lmetric_policy();
    let m3 = run_session_des(&c, &other, p3.as_mut());
    assert!(
        m3.records.iter().zip(&a.records).any(|(x, y)| x.completion_us != y.completion_us),
        "different seeds must not replay identically"
    );
}

/// The reactive-release contract, exactly: turn k+1's stamped arrival is
/// turn k's completion plus the pre-sampled think time — so no turn can
/// ever enqueue before its predecessor has completed, no matter how
/// congested the cluster is.
#[test]
fn reactive_arrival_is_completion_plus_think() {
    let spec = SessionSpec::preset(SessionKind::Chat, 400, 7);
    let strace = generate_sessions(&spec);
    let c = cfg(2); // small fleet: real queueing delays push completions out
    let mut p = lmetric_policy();
    let m = run_session_des(&c, &strace, p.as_mut());
    assert_eq!(m.records.len(), strace.n_turns(), "every turn completes");
    let recs = by_id(&m.records);
    let mut pairs = 0usize;
    for s in &strace.sessions {
        for (ti, w) in s.turns.windows(2).enumerate() {
            let prev = recs[&w[0].req.id];
            let next = recs[&w[1].req.id];
            assert_eq!(
                next.arrival_us,
                prev.completion_us + w[1].think_us,
                "session {} turn {}: release must be completion + think",
                s.sid,
                ti + 1
            );
            assert!(next.arrival_us >= prev.completion_us, "causality");
            pairs += 1;
        }
        // First turns keep their scheduled session start.
        assert_eq!(recs[&s.turns[0].req.id].arrival_us, s.start_us);
    }
    assert!(pairs > 100, "chat sessions must be multi-turn (got {pairs} pairs)");
}

/// Decision-replay equivalence: a session trace with single-turn
/// sessions has no reactive edges, so the closed-loop runner must
/// reproduce the open-loop DES on the flattened trace byte-identically.
#[test]
fn single_turn_sessions_replay_open_loop_byte_identical() {
    let mut spec = SessionSpec::preset(SessionKind::Chat, 200, 4);
    spec.max_turns = 1;
    let strace = generate_sessions(&spec);
    let flat = strace.flatten();
    let c = cfg(4);
    let mut p_closed = lmetric_policy();
    let mut p_open = lmetric_policy();
    let closed = run_session_des(&c, &strace, p_closed.as_mut());
    let open = run_des(&c, &flat, p_open.as_mut());
    assert_eq!(closed.records.len(), open.records.len());
    for (a, b) in closed.records.iter().zip(&open.records) {
        assert_eq!(
            record_key(a),
            record_key(b),
            "single-turn closed loop must equal the open-loop replay"
        );
    }
    assert_eq!(closed.total_steps, open.total_steps);
    assert_eq!(closed.admit_radix_walks, open.admit_radix_walks);
}

/// Structural prefix-hit growth on one instance with an unbounded KV$:
/// because turn k+1 is only released after turn k completed (and its
/// full prompt+reply chain entered the cache), every later turn's cached
/// prefix must cover the whole previous full chain (or its truncated
/// prompt, whichever is shorter). This is the property reactive release
/// buys: an open-loop replay under load would break it.
#[test]
fn per_turn_prefix_hits_cover_previous_context_single_instance() {
    let mut spec = SessionSpec::preset(SessionKind::CodingAgent, 300, 13);
    // A short system prompt keeps turn 0 cold-ish (class sharing alone),
    // so the in-session growth dominates the curve contrast below.
    spec.sys_prompt_median = 200.0;
    let strace = generate_sessions(&spec);
    let mut engine = EngineConfig::default();
    engine.kv_capacity_blocks = 0; // unbounded: no eviction noise
    let c = ClusterConfig::new(1, engine);
    let mut p = lmetric_policy();
    let m = run_session_des(&c, &strace, p.as_mut());
    let recs = by_id(&m.records);
    let mut checked = 0usize;
    for s in &strace.sessions {
        for w in s.turns.windows(2) {
            let next = recs[&w[1].req.id];
            let own_blocks = w[1].req.input_len() / BLOCK_TOKENS;
            let guaranteed =
                (w[0].full_hashes.len() * BLOCK_TOKENS).min(own_blocks * BLOCK_TOKENS);
            assert!(
                next.cached_tokens as usize >= guaranteed,
                "turn hit {} must cover the previous full chain ({guaranteed})",
                next.cached_tokens
            );
            checked += 1;
        }
    }
    assert!(checked > 50);
    // And the aggregate curve reflects it: warm turns beat cold entry.
    let sm = SessionMetrics::collect(&m, &strace);
    assert!(
        sm.late_turn_hit() > sm.turn0_hit() + 0.1,
        "late {} vs turn0 {}",
        sm.late_turn_hit(),
        sm.turn0_hit()
    );
}

/// Multi-instance agent loops under LMETRIC: the per-turn prefix-hit
/// curve rises after turn 0 (P-token keeps pulling a session's turns
/// back to the instance that cached them), and the affinity it earns
/// without session pinning is substantial — while explicit pinning is
/// 1.0 by construction.
#[test]
fn agent_loop_hit_curve_and_affinity_multi_instance() {
    let mut spec = SessionSpec::preset(SessionKind::CodingAgent, 500, 17);
    // Short shared system prompt: turn 0 stays visibly colder than the
    // in-session continuation turns regardless of class popularity.
    spec.sys_prompt_median = 400.0;
    let c = cfg(4);
    let strace = build_scaled_sessions(&spec, &c, 0.5);
    let mut p = lmetric_policy();
    let m = run_session_des(&c, &strace, p.as_mut());
    assert_eq!(m.records.len(), strace.n_turns());
    let sm = SessionMetrics::collect(&m, &strace);
    for k in 1..4 {
        if sm.turn_hit_counts[k] >= 10 {
            assert!(
                sm.turn_hit_curve[k] > sm.turn0_hit(),
                "turn {k} hit {} must beat cold turn-0 hit {}",
                sm.turn_hit_curve[k],
                sm.turn0_hit()
            );
        }
    }
    assert!(
        sm.affinity_ratio() > 0.5,
        "P-token should earn affinity for free, got {}",
        sm.affinity_ratio()
    );
    // Explicit pinning on the identical trace: affinity 1.0 by
    // construction.
    let mut sticky = policy::StickySession::new();
    let ms = run_session_des(&c, &strace, &mut sticky);
    let sms = SessionMetrics::collect(&ms, &strace);
    assert_eq!(sms.affinity_hits, sms.affinity_total);
    assert!(sms.affinity_total > 0);
    assert!((sms.affinity_ratio() - 1.0).abs() < 1e-12);
}

/// Every registry policy survives a closed-loop replay (the reactive
/// path exercises stateful policies — simulators, session pinning — on
/// arrivals that depend on their own past decisions).
#[test]
fn every_policy_survives_a_session_run() {
    let spec = SessionSpec::preset(SessionKind::ApiCall, 120, 3);
    let strace = generate_sessions(&spec);
    let c = cfg(4);
    let profile = lmetric::engine::ModelProfile::moe_30b();
    for name in policy::all_names() {
        let mut p = policy::build_default(name, &profile, 256).unwrap();
        let m = run_session_des(&c, &strace, p.as_mut());
        assert_eq!(m.records.len(), strace.n_turns(), "{name} lost session turns");
        let mut ids: Vec<u64> = m.records.iter().map(|r| r.id).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), strace.n_turns(), "{name} duplicated turns");
    }
}

/// Session-balanced scheduling keeps sessions sticky too (its TTL is far
/// above the archetypes' think times), so both session-aware baselines
/// report perfect affinity on an uncongested replay.
#[test]
fn smetric_pins_live_sessions() {
    let spec = SessionSpec::preset(SessionKind::ApiCall, 200, 9);
    let strace = generate_sessions(&spec);
    let c = cfg(3);
    let mut p = policy::SessionBalance::new();
    let m = run_session_des(&c, &strace, &mut p);
    let sm = SessionMetrics::collect(&m, &strace);
    assert_eq!(m.records.len(), strace.n_turns());
    if sm.affinity_total > 0 {
        assert!((sm.affinity_ratio() - 1.0).abs() < 1e-12, "smetric must stay sticky");
    }
}

/// The session-rate scaler lands the open-loop request rate in the
/// target's neighbourhood and scaling is monotone in `rate_scale`.
#[test]
fn session_rate_scaler_is_monotone() {
    let spec = SessionSpec::preset(SessionKind::Chat, 400, 2);
    let c = cfg(4);
    let lo = build_scaled_sessions(&spec, &c, 0.3).flatten().steady_rps();
    let hi = build_scaled_sessions(&spec, &c, 0.9).flatten().steady_rps();
    assert!(lo.is_finite() && lo > 0.0);
    assert!(hi > lo, "higher rate_scale must produce a denser trace ({lo} vs {hi})");
}
