//! Minimal JSON codec: enough to read `artifacts/manifest.json`, stream
//! jsonl traces, and write results files — no external crates.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Numbers are f64 (adequate: token counts < 2^53).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|n| n as u64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access: `j.get("a")`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/Inf: write null (empty summaries).
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document. Returns Err with byte offset on failure.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let rest = &self.b[self.i..];
                    let ch_len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..ch_len.min(rest.len())])
                        .map_err(|_| "bad utf8")?;
                    s.push_str(chunk);
                    self.i += chunk.len();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut o = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(o));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            o.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(o));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":"x"}],"c":null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn parse_real_manifest_shape() {
        let text = r#"{
 "model": {"vocab": 1024, "d_model": 128},
 "chunk_buckets": [16, 64, 256],
 "params": [{"name": "embed", "shape": [1024, 128]}]
}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("model").unwrap().get("vocab").unwrap().as_usize(), Some(1024));
        assert_eq!(v.get("chunk_buckets").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".into());
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_string() {
        let v = Json::parse("\"héllo \\u00e9\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo é"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }
}
