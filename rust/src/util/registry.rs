//! Shared name-listing registry helper.
//!
//! Four builders used to hand-roll the same contract independently:
//! `policy::build*`, `engine::queue::build`,
//! `cluster::overload::build_admission`, and the model-placement builder
//! each map a registry name to a boxed implementation and, on an unknown
//! name, return an error that *lists every valid name* so a typo at the
//! CLI is self-correcting. [`Registry`] is the single home of that
//! contract. The exact error wording of each call site predates this
//! helper and is pinned by tests, so the kind label, the list label and
//! an optional suffix are all caller-supplied — migrating a builder here
//! must not change its error string by a single byte.

/// A named-entry registry: the list of valid names plus the pieces of the
/// unknown-name error message.
#[derive(Debug, Clone, Copy)]
pub struct Registry {
    /// What one entry is called in the error ("policy", "queue policy").
    kind: &'static str,
    /// What the list is called ("policies", "valid queue policies"…).
    list_label: &'static str,
    /// Trailing text appended verbatim after the name list (e.g. the
    /// router registry's "(plus ablations: …)" note). Usually empty.
    suffix: &'static str,
    names: &'static [&'static str],
}

impl Registry {
    pub const fn new(
        kind: &'static str,
        list_label: &'static str,
        names: &'static [&'static str],
    ) -> Registry {
        Registry {
            kind,
            list_label,
            suffix: "",
            names,
        }
    }

    pub const fn with_suffix(mut self, suffix: &'static str) -> Registry {
        self.suffix = suffix;
        self
    }

    /// Registry names, in display order.
    pub fn names(&self) -> Vec<&'static str> {
        self.names.to_vec()
    }

    /// The names as the static slice they were declared as (for callers
    /// whose pre-migration `all_*_names` signature returns a slice).
    pub const fn names_static(&self) -> &'static [&'static str] {
        self.names
    }

    pub fn contains(&self, name: &str) -> bool {
        self.names.iter().any(|&n| n == name)
    }

    /// The unknown-name error:
    /// `unknown <kind> '<name>'; valid <list_label>: <a, b, c><suffix>`.
    pub fn unknown(&self, name: &str) -> String {
        format!(
            "unknown {} '{name}'; valid {}: {}{}",
            self.kind,
            self.list_label,
            self.names.join(", "),
            self.suffix
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const R: Registry = Registry::new("widget", "widgets", &["alpha", "beta"]);

    #[test]
    fn lists_names_in_order() {
        assert_eq!(R.names(), vec!["alpha", "beta"]);
        assert!(R.contains("alpha") && !R.contains("gamma"));
    }

    #[test]
    fn unknown_error_lists_everything() {
        assert_eq!(
            R.unknown("gamma"),
            "unknown widget 'gamma'; valid widgets: alpha, beta"
        );
    }

    #[test]
    fn suffix_appends_verbatim() {
        const S: Registry =
            Registry::new("widget", "widgets", &["alpha"]).with_suffix(" (plus: beta)");
        assert_eq!(
            S.unknown("x"),
            "unknown widget 'x'; valid widgets: alpha (plus: beta)"
        );
    }
}
