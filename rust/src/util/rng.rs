//! Deterministic PRNG + the distributions the trace generators and cost
//! models need (uniform, exponential, normal/lognormal, Zipf, categorical).
//!
//! SplitMix64 core: tiny state, excellent statistical quality for
//! simulation purposes, and — crucially for reproducibility of every
//! figure — fully deterministic across platforms.

/// SplitMix64 PRNG (Steele, Lea & Flood 2014).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero fixed point and decorrelate small seeds.
        Rng {
            state: seed ^ 0x9e37_79b9_7f4a_7c15,
        }
    }

    /// Derive an independent stream (for per-component rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xff51_afd7_ed55_8ccd))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi) — panics if lo >= hi.
    pub fn gen_range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "gen_range: empty range [{lo},{hi})");
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform f64 in [lo, hi).
    pub fn gen_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Exponential with the given mean ( = 1/rate). Used for Poisson
    /// arrival gaps and think times.
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.next_f64(); // (0,1]
        -mean * u.ln()
    }

    /// Standard normal via Box–Muller (no spare caching: keeps Clone exact).
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal parameterized by the *median* and sigma of log-space.
    /// Token-length distributions in LLM traces are famously heavy-tailed;
    /// the paper's Fig. 5 box plots motivate this choice.
    pub fn lognormal(&mut self, median: f64, sigma: f64) -> f64 {
        (median.ln() + sigma * self.normal()).exp()
    }

    /// Choose an index from cumulative-weight slices.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Zipf sampler over {0, .., n-1} with exponent `s` (popularity skew of
/// request classes: a few system prompts dominate; paper §5.2's x/x̄ ratio
/// analysis is about exactly this skew).
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for v in cdf.iter_mut() {
            *v /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.next_f64();
        match self
            .cdf
            .binary_search_by(|p| p.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    /// Probability mass of index `i`.
    pub fn pmf(&self, i: usize) -> f64 {
        if i == 0 {
            self.cdf[0]
        } else {
            self.cdf[i] - self.cdf[i - 1]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.next_f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exp(3.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn lognormal_median() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let mut xs: Vec<f64> = (0..n).map(|_| r.lognormal(100.0, 1.0)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = xs[n / 2];
        assert!((med - 100.0).abs() / 100.0 < 0.1, "median={med}");
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            let v = r.gen_range(10, 20);
            assert!((10..20).contains(&v));
        }
    }

    #[test]
    fn zipf_skew() {
        let z = Zipf::new(100, 1.1);
        let mut r = Rng::new(3);
        let mut counts = vec![0u32; 100];
        for _ in 0..50_000 {
            counts[z.sample(&mut r)] += 1;
        }
        // Rank 0 must dominate rank 10 which dominates rank 90.
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[90]);
        // pmf sums to ~1.
        let total: f64 = (0..100).map(|i| z.pmf(i)).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(17);
        let mut counts = [0u32; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(21);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
