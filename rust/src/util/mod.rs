//! Offline-environment substrates: PRNG + distributions, a minimal JSON
//! codec, and statistics helpers. These replace the `rand`, `serde_json`
//! and `hdrhistogram`-style crates that are unavailable in this build
//! environment (see DESIGN.md §1 substitution ledger).

pub mod json;
pub mod registry;
pub mod rng;
pub mod stats;

pub use registry::Registry;
pub use rng::Rng;

use std::hash::{BuildHasherDefault, Hasher};

/// Multiply-xor hasher for u64 keys (block hashes are already
/// well-mixed 64-bit values; SipHash's DoS resistance is wasted on them
/// and costs ~2-3× per radix-tree lookup on the router's hot path —
/// EXPERIMENTS.md §Perf).
#[derive(Default)]
pub struct U64Hasher {
    state: u64,
}

impl Hasher for U64Hasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        // Generic fallback (rare on our hot paths).
        for &b in bytes {
            self.state = (self.state ^ b as u64).wrapping_mul(0x100_0000_01b3);
        }
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        let mut z = self.state ^ i;
        z = z.wrapping_mul(0xff51_afd7_ed55_8ccd);
        z ^= z >> 33;
        self.state = z;
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.write_u64(i as u64);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.write_u64(i as u64);
    }
}

/// `HashMap` build-hasher for well-mixed integer keys.
pub type FastHash = BuildHasherDefault<U64Hasher>;

#[cfg(test)]
mod hasher_tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn u64_hasher_works_in_hashmap() {
        let mut m: HashMap<u64, u32, FastHash> = HashMap::default();
        for i in 0..1000u64 {
            m.insert(i.wrapping_mul(0x9e37_79b9_7f4a_7c15), i as u32);
        }
        assert_eq!(m.len(), 1000);
        for i in 0..1000u64 {
            assert_eq!(m[&i.wrapping_mul(0x9e37_79b9_7f4a_7c15)], i as u32);
        }
    }

    #[test]
    fn distinct_keys_distinct_hashes() {
        use std::hash::{BuildHasher, Hash};
        let bh = FastHash::default();
        let hash_of = |k: u64| {
            let mut h = bh.build_hasher();
            k.hash(&mut h);
            h.finish()
        };
        let mut seen = std::collections::HashSet::new();
        for i in 0..10_000u64 {
            assert!(seen.insert(hash_of(i)), "collision at {i}");
        }
    }
}
