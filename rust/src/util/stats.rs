//! Statistics helpers: latency summaries (mean/percentiles), CDFs and
//! windowed time series — the measurement substrate behind every figure.

/// Percentile of a sorted slice using linear interpolation (q in [0,1]).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

/// Five-number-ish latency summary.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(values: &[f64]) -> Summary {
        if values.is_empty() {
            return Summary {
                n: 0,
                mean: f64::NAN,
                p50: f64::NAN,
                p90: f64::NAN,
                p95: f64::NAN,
                p99: f64::NAN,
                max: f64::NAN,
            };
        }
        let mut sorted: Vec<f64> = values.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n: sorted.len(),
            mean: sorted.iter().sum::<f64>() / sorted.len() as f64,
            p50: percentile(&sorted, 0.50),
            p90: percentile(&sorted, 0.90),
            p95: percentile(&sorted, 0.95),
            p99: percentile(&sorted, 0.99),
            max: *sorted.last().unwrap(),
        }
    }
}

/// Empirical CDF with a bounded number of points (for figure export).
pub fn cdf_points(values: &[f64], max_points: usize) -> Vec<(f64, f64)> {
    if values.is_empty() {
        return vec![];
    }
    let mut sorted: Vec<f64> = values.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = sorted.len();
    let step = (n / max_points.max(1)).max(1);
    let mut pts = Vec::new();
    let mut i = 0;
    while i < n {
        pts.push((sorted[i], (i + 1) as f64 / n as f64));
        i += step;
    }
    if pts.last().map(|p| p.1) != Some(1.0) {
        pts.push((sorted[n - 1], 1.0));
    }
    pts
}

/// Fixed-width windowed accumulator over (virtual or real) time in µs.
/// Used for per-instance prefill-seconds-per-10s (Figs 10/25), batch-size
/// timelines (Fig 28), hit-ratio-over-time (Figs 8/24), etc.
#[derive(Debug, Clone)]
pub struct Windowed {
    pub window_us: u64,
    sums: Vec<f64>,
    counts: Vec<u64>,
}

impl Windowed {
    pub fn new(window_us: u64) -> Self {
        assert!(window_us > 0);
        Windowed {
            window_us,
            sums: Vec::new(),
            counts: Vec::new(),
        }
    }

    fn idx(&mut self, t_us: u64) -> usize {
        let i = (t_us / self.window_us) as usize;
        if i >= self.sums.len() {
            self.sums.resize(i + 1, 0.0);
            self.counts.resize(i + 1, 0);
        }
        i
    }

    /// Add `v` into the window containing `t_us`.
    pub fn add(&mut self, t_us: u64, v: f64) {
        let i = self.idx(t_us);
        self.sums[i] += v;
        self.counts[i] += 1;
    }

    /// Sum per window.
    pub fn sums(&self) -> &[f64] {
        &self.sums
    }

    /// Mean per window (NaN for empty windows).
    pub fn means(&self) -> Vec<f64> {
        self.sums
            .iter()
            .zip(&self.counts)
            .map(|(s, c)| if *c == 0 { f64::NAN } else { s / *c as f64 })
            .collect()
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn n_windows(&self) -> usize {
        self.sums.len()
    }
}

/// Sample standard deviation.
pub fn stddev(values: &[f64]) -> f64 {
    if values.len() < 2 {
        return 0.0;
    }
    let mean = values.iter().sum::<f64>() / values.len() as f64;
    let var =
        values.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (values.len() - 1) as f64;
    var.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basics() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 0.5), 3.0);
        assert_eq!(percentile(&v, 1.0), 5.0);
        assert!((percentile(&v, 0.25) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn summary_of_uniform() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&v);
        assert_eq!(s.n, 100);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert!((s.p50 - 50.5).abs() < 1.0);
        assert!((s.p99 - 99.0).abs() < 1.1);
        assert_eq!(s.max, 100.0);
    }

    #[test]
    fn summary_empty_is_nan() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert!(s.mean.is_nan());
    }

    #[test]
    fn cdf_monotone_ends_at_one() {
        let v: Vec<f64> = (0..1000).map(|i| (i as f64).sin().abs()).collect();
        let pts = cdf_points(&v, 50);
        for w in pts.windows(2) {
            assert!(w[0].0 <= w[1].0);
            assert!(w[0].1 <= w[1].1);
        }
        assert_eq!(pts.last().unwrap().1, 1.0);
    }

    #[test]
    fn windowed_buckets() {
        let mut w = Windowed::new(10_000_000); // 10 s
        w.add(0, 1.0);
        w.add(9_999_999, 2.0);
        w.add(10_000_000, 5.0);
        w.add(35_000_000, 7.0);
        assert_eq!(w.sums(), &[3.0, 5.0, 0.0, 7.0]);
        assert_eq!(w.counts(), &[2, 1, 0, 1]);
        let means = w.means();
        assert_eq!(means[0], 1.5);
        assert!(means[2].is_nan());
    }

    #[test]
    fn stddev_known() {
        let v = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&v) - 2.138).abs() < 0.01);
    }
}
