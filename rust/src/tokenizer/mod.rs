//! Synthetic tokenization + block hashing.
//!
//! Real traces carry (hashed) content; our generators produce token-id
//! sequences directly. Two requests share KV$ exactly when their token
//! blocks match, so prefix structure is encoded by *reusing deterministic
//! token spans*: the class's system prompt span, the conversation history
//! spans, fresh user spans.
//!
//! Block hashing mirrors vLLM's prefix caching: the hash of block *i*
//! chains the hash of block *i-1* with the tokens of block *i*, so a
//! match of `n` leading hashes == a match of `n·BLOCK_TOKENS` leading
//! tokens.

use crate::core::BLOCK_TOKENS;
use crate::util::Rng;

/// FNV-1a-style mix used for block hashing (stable, fast, no deps).
#[inline]
fn mix(mut h: u64, x: u64) -> u64 {
    h ^= x;
    h = h.wrapping_mul(0x100_0000_01b3);
    h ^ (h >> 29)
}

/// Chained hashes of each full block of `tokens` (partial tail ignored —
/// a partial block can never be a KV$ hit).
pub fn block_hashes(tokens: &[u32]) -> Vec<u64> {
    let n_blocks = tokens.len() / BLOCK_TOKENS;
    let mut out = Vec::with_capacity(n_blocks);
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in 0..n_blocks {
        for t in &tokens[b * BLOCK_TOKENS..(b + 1) * BLOCK_TOKENS] {
            h = mix(h, *t as u64);
        }
        out.push(h);
    }
    out
}

/// Deterministic token span for a (class, stream, index) triple — the
/// building block of prefix-shared prompts. Same arguments → same tokens,
/// so e.g. every request of class 7 starts with the same system prompt.
pub fn span(class_id: u32, stream: u64, len: usize, vocab: u32) -> Vec<u32> {
    let seed = ((class_id as u64) << 32) ^ stream.wrapping_mul(0x9e37_79b9_7f4a_7c15);
    let mut rng = Rng::new(seed);
    // Avoid token 0 (the live engine uses it as padding).
    (0..len)
        .map(|_| 1 + (rng.next_u64() % (vocab as u64 - 1)) as u32)
        .collect()
}

/// Fresh (never-shared) tokens from a caller-owned rng.
pub fn fresh(rng: &mut Rng, len: usize, vocab: u32) -> Vec<u32> {
    (0..len).map(|_| 1 + (rng.next_u64() % (vocab as u64 - 1)) as u32).collect()
}

/// Longest shared block prefix of two hash chains.
pub fn shared_blocks(a: &[u64], b: &[u64]) -> usize {
    a.iter().zip(b.iter()).take_while(|(x, y)| x == y).count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hashes_deterministic() {
        let t: Vec<u32> = (0..64).collect();
        assert_eq!(block_hashes(&t), block_hashes(&t));
        assert_eq!(block_hashes(&t).len(), 64 / BLOCK_TOKENS);
    }

    #[test]
    fn partial_tail_ignored() {
        let t: Vec<u32> = (0..BLOCK_TOKENS as u32 + 5).collect();
        assert_eq!(block_hashes(&t).len(), 1);
    }

    #[test]
    fn chaining_distinguishes_prefixes() {
        // Same second block content, different first block -> different
        // second-block hashes (chained).
        let mut a: Vec<u32> = vec![1; BLOCK_TOKENS];
        let mut b: Vec<u32> = vec![2; BLOCK_TOKENS];
        let common: Vec<u32> = vec![3; BLOCK_TOKENS];
        a.extend(&common);
        b.extend(&common);
        let ha = block_hashes(&a);
        let hb = block_hashes(&b);
        assert_ne!(ha[0], hb[0]);
        assert_ne!(ha[1], hb[1]);
    }

    #[test]
    fn shared_prefix_shares_hashes() {
        let sys = span(7, 0, 64, 1024);
        let mut p1 = sys.clone();
        let mut p2 = sys.clone();
        p1.extend(span(7, 1, 32, 1024));
        p2.extend(span(7, 2, 32, 1024));
        let h1 = block_hashes(&p1);
        let h2 = block_hashes(&p2);
        assert_eq!(shared_blocks(&h1, &h2), 64 / BLOCK_TOKENS);
    }

    #[test]
    fn span_deterministic_and_classed() {
        assert_eq!(span(1, 0, 32, 1024), span(1, 0, 32, 1024));
        assert_ne!(span(1, 0, 32, 1024), span(2, 0, 32, 1024));
        assert_ne!(span(1, 0, 32, 1024), span(1, 1, 32, 1024));
    }

    #[test]
    fn tokens_in_vocab_nonzero() {
        let mut rng = Rng::new(1);
        for t in fresh(&mut rng, 1000, 100) {
            assert!(t >= 1 && t < 100);
        }
        for t in span(3, 9, 1000, 100) {
            assert!(t >= 1 && t < 100);
        }
    }
}
