//! Session-aware competitor policies: the baselines LMETRIC must match
//! or beat on closed-loop session workloads *without* ever looking at
//! the session id.
//!
//! * [`StickySession`] — classic session-affinity routing (the gateway
//!   pattern): a session's first turn is placed on the least-loaded
//!   instance, every later turn is pinned there. Perfect prefix reuse by
//!   construction, zero load adaptivity: a pinned instance that turns hot
//!   keeps its sessions forever.
//! * [`SessionBalance`] — an SMetric-style *balanced session-centric*
//!   scheduler (PAPERS.md): sessions stay sticky, but placement balances
//!   the per-instance sum of active-session context footprints (a
//!   session's cost ≈ its current prompt length, which grows every turn),
//!   and sessions idle past a TTL are retired from the account so dead
//!   conversations stop occupying routing weight.
//!
//! Both key their state on [`RouteCtx::session_id`]; on sessionless
//! traffic (`session_id == 0`) they degrade to their placement rule
//! applied per request, so they remain valid baselines on every
//! single-shot workload in the registry.

use std::collections::HashMap;

use crate::router::{select_min, Policy, RouteCtx, RouteDecision};

/// Plain session-affinity routing: first turn → least-BS instance, later
/// turns → wherever the session lives.
pub struct StickySession {
    pins: HashMap<u64, usize>,
}

impl StickySession {
    pub fn new() -> Self {
        StickySession {
            pins: HashMap::new(),
        }
    }
}

impl Default for StickySession {
    fn default() -> Self {
        Self::new()
    }
}

impl Policy for StickySession {
    fn name(&self) -> String {
        "sticky".into()
    }

    fn route(&mut self, ctx: &RouteCtx) -> RouteDecision {
        if ctx.session_id != 0 {
            if let Some(&i) = self.pins.get(&ctx.session_id) {
                // A pin only holds while its instance is alive and
                // accepting work; a crashed or draining home falls
                // through to fresh placement and re-pins below, instead
                // of routing the session into the void.
                if i < ctx.n() && ctx.inds[i].routable {
                    return RouteDecision::to(i);
                }
            }
        }
        let i = select_min(ctx, |i| ctx.inds[i].bs() as f64);
        if ctx.session_id != 0 {
            self.pins.insert(ctx.session_id, i);
        }
        RouteDecision::to(i)
    }
}

#[derive(Debug, Clone, Copy)]
struct SessionPin {
    inst: usize,
    /// Last observed context footprint (prompt tokens) of the session.
    ctx_tokens: usize,
    last_us: u64,
}

/// SMetric-style balanced session-centric scheduling: sticky placement,
/// but new sessions go to the instance carrying the least *live session
/// context*, and a returning turn updates its session's footprint in the
/// account (context grows every turn). Sessions idle longer than
/// `ttl_us` are expired lazily before each decision.
pub struct SessionBalance {
    ttl_us: u64,
    pins: HashMap<u64, SessionPin>,
    /// Per-instance sum of live-session context tokens.
    load: Vec<u64>,
    /// Virtual time of the last full expiry sweep. Sweeps are paced to
    /// once per TTL of virtual time, so the per-decision cost stays O(1)
    /// amortized (the routed session's own pin is TTL-checked lazily on
    /// lookup; the sweep only drains *abandoned* sessions from the load
    /// account).
    last_sweep_us: u64,
}

impl SessionBalance {
    /// Default TTL: 10 virtual minutes — an order of magnitude above the
    /// chat archetype's mean think time, so live conversations survive
    /// their gaps but abandoned ones drain from the account.
    pub const DEFAULT_TTL_US: u64 = 600_000_000;

    pub fn new() -> Self {
        Self::with_ttl(Self::DEFAULT_TTL_US)
    }

    pub fn with_ttl(ttl_us: u64) -> Self {
        SessionBalance {
            ttl_us,
            pins: HashMap::new(),
            load: Vec::new(),
            last_sweep_us: 0,
        }
    }

    /// Drop every pin idle past the TTL and drain its context tokens
    /// from the load account. Called at most once per TTL of virtual
    /// time — see `last_sweep_us`.
    fn sweep(&mut self, now_us: u64) {
        let ttl = self.ttl_us;
        let load = &mut self.load;
        self.pins.retain(|_, p| {
            if now_us.saturating_sub(p.last_us) > ttl {
                if let Some(l) = load.get_mut(p.inst) {
                    *l = l.saturating_sub(p.ctx_tokens as u64);
                }
                false
            } else {
                true
            }
        });
        self.last_sweep_us = now_us;
    }

    /// Live sessions currently pinned to `inst` would cost this many
    /// context tokens (tests / introspection).
    pub fn live_load(&self, inst: usize) -> u64 {
        self.load.get(inst).copied().unwrap_or(0)
    }
}

impl Default for SessionBalance {
    fn default() -> Self {
        Self::new()
    }
}

impl Policy for SessionBalance {
    fn name(&self) -> String {
        "smetric".into()
    }

    fn route(&mut self, ctx: &RouteCtx) -> RouteDecision {
        if self.load.len() < ctx.n() {
            self.load.resize(ctx.n(), 0);
        }
        if ctx.now_us.saturating_sub(self.last_sweep_us) > self.ttl_us {
            self.sweep(ctx.now_us);
        }
        if ctx.session_id != 0 {
            let mut stale = false;
            if let Some(p) = self.pins.get_mut(&ctx.session_id) {
                if ctx.now_us.saturating_sub(p.last_us) > self.ttl_us {
                    // Lazy per-pin TTL check: a returning-but-expired
                    // session re-places below instead of resuming.
                    stale = true;
                } else if p.inst >= ctx.n() || !ctx.inds[p.inst].routable {
                    // Pinned home crashed, is draining, or left the
                    // fleet: drain its account like an expired pin and
                    // re-place — never route a live session into the
                    // void.
                    stale = true;
                } else {
                    // Returning turn: refresh the footprint (the prompt
                    // now contains the whole history) and the liveness.
                    self.load[p.inst] += ctx.input_len.saturating_sub(p.ctx_tokens) as u64;
                    p.ctx_tokens = p.ctx_tokens.max(ctx.input_len);
                    p.last_us = ctx.now_us;
                    return RouteDecision::to(p.inst);
                }
            }
            if stale {
                if let Some(p) = self.pins.remove(&ctx.session_id) {
                    if let Some(l) = self.load.get_mut(p.inst) {
                        *l = l.saturating_sub(p.ctx_tokens as u64);
                    }
                }
            }
        }
        // New session (or sessionless request): balance live context.
        let i = select_min(ctx, |i| self.load[i] as f64);
        if ctx.session_id != 0 {
            self.pins.insert(
                ctx.session_id,
                SessionPin {
                    inst: i,
                    ctx_tokens: ctx.input_len,
                    last_us: ctx.now_us,
                },
            );
            self.load[i] += ctx.input_len as u64;
        }
        RouteDecision::to(i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::router::Indicators;

    fn ctx(n: usize, session: u64, input: usize, now: u64) -> RouteCtx {
        RouteCtx::new(now, 0, 0, input, vec![0; n], vec![Indicators::default(); n])
            .with_session(session)
    }

    #[test]
    fn sticky_pins_sessions_and_ignores_load_after() {
        let mut p = StickySession::new();
        let first = p.route(&ctx(3, 7, 100, 0)).instance;
        // Later turn, even with that instance drowning in batch, stays.
        let mut busy = ctx(3, 7, 500, 10);
        busy.inds[first].r_bs = 50;
        assert_eq!(p.route(&busy).instance, first);
        // A different session spreads by least BS (away from the busy one).
        assert_ne!(p.route(&busy.clone().with_session(8)).instance, first);
    }

    #[test]
    fn sticky_sessionless_does_not_pin() {
        let mut p = StickySession::new();
        let mut c = ctx(2, 0, 100, 0);
        c.inds[0].r_bs = 4;
        assert_eq!(p.route(&c).instance, 1);
        let mut c2 = ctx(2, 0, 100, 1);
        c2.inds[1].r_bs = 9;
        assert_eq!(p.route(&c2).instance, 0, "no pin: decisions stay load-driven");
        assert!(p.pins.is_empty());
    }

    #[test]
    fn smetric_balances_session_context_and_stays_sticky() {
        let mut p = SessionBalance::new();
        // Session 1 brings a huge context to instance 0 (first placement
        // tie-breaks to index 0 on an idle fleet).
        assert_eq!(p.route(&ctx(2, 1, 10_000, 0)).instance, 0);
        assert_eq!(p.live_load(0), 10_000);
        // Session 2 lands on the other instance: balanced placement.
        assert_eq!(p.route(&ctx(2, 2, 100, 1)).instance, 1);
        // Session 1's next turn returns to instance 0 and grows the
        // footprint to the new prompt length.
        assert_eq!(p.route(&ctx(2, 1, 12_000, 2)).instance, 0);
        assert_eq!(p.live_load(0), 12_000);
        // Session 3 avoids the heavy instance even though BS is equal.
        assert_eq!(p.route(&ctx(2, 3, 100, 3)).instance, 1);
    }

    #[test]
    fn smetric_lazy_expiry_between_sweeps() {
        let mut p = SessionBalance::with_ttl(1_000_000);
        assert_eq!(p.route(&ctx(2, 1, 4_000, 500_000)).instance, 0);
        // This decision triggers a sweep; session 1 (idle 0.5 s of the
        // 1 s TTL) survives it, and session 2 balances to instance 1.
        assert_eq!(p.route(&ctx(2, 2, 100, 1_000_001)).instance, 1);
        assert_eq!(p.live_load(0), 4_000);
        // Before the next sweep is due, session 1 returns expired: the
        // lazy per-pin check drains its stale 4 000-token account, so
        // placement sees load (0, 100) and picks the drained instance —
        // a leaked account would have sent it to instance 1.
        assert_eq!(p.route(&ctx(2, 1, 5_000, 1_600_000)).instance, 0);
        assert_eq!(p.live_load(0), 5_000);
        assert_eq!(p.live_load(1), 100);
    }

    #[test]
    fn smetric_expires_idle_sessions() {
        let mut p = SessionBalance::with_ttl(1_000_000); // 1 s TTL
        assert_eq!(p.route(&ctx(2, 1, 5_000, 0)).instance, 0);
        assert_eq!(p.live_load(0), 5_000);
        // 2 s later the session is dead: account drains, and a new
        // session sees a clean slate (ties back to instance 0).
        assert_eq!(p.route(&ctx(2, 2, 100, 2_000_000)).instance, 0);
        assert_eq!(p.live_load(0), 100);
        // The expired session's next turn re-places instead of pinning.
        let d = p.route(&ctx(2, 1, 6_000, 2_000_001)).instance;
        assert_eq!(d, 1, "expired session re-balances onto the lighter instance");
    }

    #[test]
    fn sticky_re_pins_when_home_instance_dies() {
        let mut p = StickySession::new();
        let home = p.route(&ctx(3, 7, 100, 0)).instance;
        assert_eq!(home, 0);
        // Home crashes: the next turn must NOT route into the void.
        let mut dead = ctx(3, 7, 200, 10);
        dead.inds[home].routable = false;
        dead.inds[2].r_bs = 1; // instance 1 is the least-loaded live one
        let new_home = p.route(&dead).instance;
        assert_eq!(new_home, 1, "fresh placement skips the dead instance");
        // The fallback re-pinned: once the old home recovers, the
        // session stays where it re-homed (its KV now lives there).
        let back = ctx(3, 7, 300, 20);
        assert_eq!(p.route(&back).instance, new_home);
    }

    #[test]
    fn sticky_survives_drain_then_repin_is_stable() {
        let mut p = StickySession::new();
        let home = p.route(&ctx(2, 5, 100, 0)).instance;
        let mut draining = ctx(2, 5, 150, 5);
        draining.inds[home].routable = false;
        let re = p.route(&draining).instance;
        assert_ne!(re, home);
        // Repeat turns while draining keep landing on the re-pin.
        let mut again = ctx(2, 5, 160, 6);
        again.inds[home].routable = false;
        assert_eq!(p.route(&again).instance, re);
    }

    #[test]
    fn smetric_drains_dead_pin_account_and_re_places() {
        let mut p = SessionBalance::new();
        assert_eq!(p.route(&ctx(2, 1, 8_000, 0)).instance, 0);
        assert_eq!(p.live_load(0), 8_000);
        // Instance 0 crashes; the returning turn re-places on a live
        // instance AND the dead pin's 8 000-token account drains — a
        // leaked account would poison placement long after recovery.
        let mut dead = ctx(2, 1, 9_000, 10);
        dead.inds[0].routable = false;
        assert_eq!(p.route(&dead).instance, 1);
        assert_eq!(p.live_load(0), 0, "dead pin's account drained");
        assert_eq!(p.live_load(1), 9_000, "re-pinned with fresh footprint");
        // After recovery the session stays at its new home.
        assert_eq!(p.route(&ctx(2, 1, 10_000, 20)).instance, 1);
        assert_eq!(p.live_load(1), 10_000);
    }
}
