//! The filter-based combination (§4.5, Fig 13) — AIBrix's prefix-cache
//! policy shape: if the cluster looks imbalanced (BS range exceeds a
//! threshold), abandon KV$-awareness and JSQ; otherwise route to the
//! instance with the most KV$ hits (ties: least loaded).

use crate::router::{select_min, Policy, RouteCtx, RouteDecision};

pub struct FilterKv {
    /// The imbalance threshold "Range" (workload-specific: Fig 12 sweeps
    /// {2,4,8,16}).
    pub range: usize,
}

impl FilterKv {
    pub fn new(range: usize) -> Self {
        FilterKv { range }
    }
}

impl Policy for FilterKv {
    fn name(&self) -> String {
        format!("filter_kv(range={})", self.range)
    }

    fn route(&mut self, ctx: &RouteCtx) -> RouteDecision {
        let bs_max = (0..ctx.n()).map(|i| ctx.inds[i].bs()).max().unwrap_or(0);
        let bs_min = (0..ctx.n()).map(|i| ctx.inds[i].bs()).min().unwrap_or(0);
        let inst = if bs_max - bs_min > self.range {
            // Imbalanced: pure load balancing, KV$ ignored entirely
            // (the paper's Cons #2: forgoes KV$ benefits).
            select_min(ctx, |i| ctx.inds[i].bs() as f64)
        } else {
            // Balanced: chase hits; select_min's BS tie-break implements
            // the `.select_min(BS)` second key of Fig 13 line 6.
            select_min(ctx, |i| -(ctx.hit_tokens[i] as f64))
        };
        RouteDecision::to(inst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::router::Indicators;

    fn ctx(hits: Vec<usize>, bss: Vec<usize>) -> RouteCtx {
        let inds = bss
            .iter()
            .map(|b| Indicators {
                r_bs: *b,
                ..Default::default()
            })
            .collect();
        RouteCtx::new(0, 0, 0, 100, hits, inds)
    }

    #[test]
    fn balanced_chases_hits() {
        let c = ctx(vec![0, 80], vec![3, 4]); // range 1 <= 4
        assert_eq!(FilterKv::new(4).route(&c).instance, 1);
    }

    #[test]
    fn imbalanced_ignores_hits() {
        let c = ctx(vec![0, 80], vec![1, 9]); // range 8 > 4
        assert_eq!(FilterKv::new(4).route(&c).instance, 0);
    }

    #[test]
    fn threshold_gates_the_switch() {
        let c = ctx(vec![0, 80], vec![1, 9]);
        // Generous range: still in KV$ branch.
        assert_eq!(FilterKv::new(16).route(&c).instance, 1);
    }

    #[test]
    fn hit_ties_break_on_load() {
        let c = ctx(vec![80, 80], vec![5, 2]);
        assert_eq!(FilterKv::new(8).route(&c).instance, 1);
    }
}
