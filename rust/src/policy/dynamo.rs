//! NVIDIA Dynamo's linear combination (§6.1): same weighted-sum shape as
//! BAILIAN's but with a different indicator choice — P-token for
//! KV$-awareness and total context tokens (#Tokens) for load balancing,
//! both normalized ("regulated") against the cross-instance max.

use crate::router::{select_min, Policy, RouteCtx, RouteDecision};

pub struct Dynamo {
    pub alpha: f64,
}

impl Dynamo {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Dynamo { alpha }
    }
}

impl Policy for Dynamo {
    fn name(&self) -> String {
        format!("dynamo(α={})", self.alpha)
    }

    fn route(&mut self, ctx: &RouteCtx) -> RouteDecision {
        let max_p = (0..ctx.n()).map(|i| ctx.p_token(i)).max().unwrap_or(0).max(1) as f64;
        let max_t = (0..ctx.n())
            .map(|i| ctx.inds[i].total_context_tokens)
            .max()
            .unwrap_or(0)
            .max(1) as f64;
        RouteDecision::to(select_min(ctx, |i| {
            self.alpha * (ctx.p_token(i) as f64 / max_p)
                + (1.0 - self.alpha) * (ctx.inds[i].total_context_tokens as f64 / max_t)
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::router::Indicators;

    #[test]
    fn balances_ptoken_and_tokens() {
        let mut i0 = Indicators::default();
        i0.total_context_tokens = 10_000; // heavy decode load
        let i1 = Indicators::default();
        // full hit on the loaded one
        let ctx = RouteCtx::new(0, 0, 0, 1000, vec![1000, 0], vec![i0, i1]);
        // KV-dominant α: hit instance wins despite decode load.
        assert_eq!(Dynamo::new(0.9).route(&ctx).instance, 0);
        // Load-dominant α: idle instance wins.
        assert_eq!(Dynamo::new(0.1).route(&ctx).instance, 1);
    }
}
