//! PolyServe (§6.2, Fig 33): a simulation-based *load-gradient* scheduler.
//! It optimizes for auto-scaling headroom, not latency: among instances
//! whose predicted TTFT/TPOT meet the SLO it picks the MOST loaded
//! (highest predicted TPOT), concentrating work so idle instances can be
//! released; only when nothing is feasible does it fall back to the
//! lowest-TPOT instance.

use crate::router::{select_max, select_min, Policy, RouteCtx, RouteDecision};
use crate::simulator::LatencySimulator;

pub struct PolyServe {
    sim: LatencySimulator,
    /// SLO_TPOT in µs (the paper's τ; Fig 34 sweeps it).
    pub slo_tpot_us: f64,
    /// SLO_TTFT in µs (held fixed in the paper's tuning, §A.2).
    pub slo_ttft_us: f64,
}

impl PolyServe {
    pub fn new(sim: LatencySimulator, slo_tpot_us: f64) -> Self {
        PolyServe {
            sim,
            slo_tpot_us,
            slo_ttft_us: 10_000_000.0, // 10 s — generous, as in the paper
        }
    }
}

impl Policy for PolyServe {
    fn name(&self) -> String {
        format!("polyserve(τ={}ms)", self.slo_tpot_us / 1000.0)
    }

    fn route(&mut self, ctx: &RouteCtx) -> RouteDecision {
        let n = ctx.n();
        let ttft: Vec<f64> = (0..n).map(|i| self.sim.predict_ttft(ctx, i)).collect();
        let tpot: Vec<f64> = (0..n)
            .map(|i| self.sim.predict_tpot(&ctx.inds[i], ctx.input_len))
            .collect();
        let feasible: Vec<usize> = (0..n)
            .filter(|&i| ttft[i] <= self.slo_ttft_us && tpot[i] <= self.slo_tpot_us)
            .collect();
        let inst = if feasible.is_empty() {
            // Load-balancing branch: least predicted TPOT.
            select_min(ctx, |i| tpot[i])
        } else {
            // Utilization branch: most loaded feasible instance.
            select_max(ctx, |i| {
                if feasible.contains(&i) {
                    tpot[i]
                } else {
                    f64::NEG_INFINITY
                }
            })
        };
        RouteDecision {
            instance: inst,
            predicted_ttft_us: Some(ttft[inst]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::ModelProfile;
    use crate::router::Indicators;

    fn mk(slo_ms: f64) -> PolyServe {
        PolyServe::new(
            LatencySimulator::tuned(ModelProfile::moe_30b(), 256),
            slo_ms * 1000.0,
        )
    }

    fn gradient_ctx() -> RouteCtx {
        // instance 0 moderately loaded, 1 idle, 2 overloaded.
        let mut i0 = Indicators::default();
        i0.r_bs = 8;
        i0.total_context_tokens = 8 * 500;
        let i1 = Indicators::default();
        let mut i2 = Indicators::default();
        i2.r_bs = 200;
        i2.total_context_tokens = 200 * 2000;
        RouteCtx::new(0, 0, 0, 500, vec![0, 0, 0], vec![i0, i1, i2])
    }

    #[test]
    fn packs_load_onto_feasible_busy_instance() {
        // Generous SLO: instance 0 (loaded but feasible) wins over idle 1.
        let mut p = mk(100.0);
        assert!(p.route(&gradient_ctx()).instance != 1);
    }

    #[test]
    fn falls_back_to_least_tpot_when_infeasible() {
        // Impossible SLO: pure load balancing -> idle instance 1.
        let mut p = mk(0.001);
        assert_eq!(p.route(&gradient_ctx()).instance, 1);
    }
}
