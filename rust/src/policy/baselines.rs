//! Trivial baselines: round-robin and uniform random routing. Not in the
//! paper's evaluation but indispensable sanity anchors for the harness.

use crate::router::{Policy, RouteCtx, RouteDecision};
use crate::util::Rng;

/// Route requests cyclically.
pub struct RoundRobin {
    next: usize,
}

impl RoundRobin {
    pub fn new() -> Self {
        RoundRobin { next: 0 }
    }
}

impl Default for RoundRobin {
    fn default() -> Self {
        Self::new()
    }
}

impl Policy for RoundRobin {
    fn name(&self) -> String {
        "round_robin".into()
    }

    fn route(&mut self, ctx: &RouteCtx) -> RouteDecision {
        let i = self.next % ctx.n();
        self.next = self.next.wrapping_add(1);
        RouteDecision::to(i)
    }
}

/// Route requests uniformly at random (deterministic seed).
pub struct Random {
    rng: Rng,
}

impl Random {
    pub fn new(seed: u64) -> Self {
        Random {
            rng: Rng::new(seed),
        }
    }
}

impl Policy for Random {
    fn name(&self) -> String {
        "random".into()
    }

    fn route(&mut self, ctx: &RouteCtx) -> RouteDecision {
        RouteDecision::to(self.rng.gen_range(0, ctx.n() as u64) as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::router::Indicators;

    fn ctx(n: usize) -> RouteCtx {
        RouteCtx::new(0, 0, 0, 10, vec![0; n], vec![Indicators::default(); n])
    }

    #[test]
    fn round_robin_cycles() {
        let mut p = RoundRobin::new();
        let c = ctx(3);
        let picks: Vec<usize> = (0..6).map(|_| p.route(&c).instance).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn random_covers_all_instances() {
        let mut p = Random::new(3);
        let c = ctx(4);
        let mut seen = [false; 4];
        for _ in 0..100 {
            seen[p.route(&c).instance] = true;
        }
        assert!(seen.iter().all(|s| *s));
    }
}
