//! The linear-combination (weighted-sum) policy (§4.4, Fig 6b) — the
//! production BAILIAN scheduler's shape:
//!
//! `score_i = λ·(1 − hit_ratio_i) + (1−λ)·norm(BS_i)`
//!
//! BS is normalized to [0,1] against the current max across instances so
//! the two indicators share a scale (§4.2 note (1)). λ is the
//! workload-specific hyperparameter whose tuning pain (Fig 11) motivates
//! the multiplicative score.

use crate::router::{select_min, Policy, RouteCtx, RouteDecision};

pub struct Linear {
    pub lambda: f64,
}

impl Linear {
    pub fn new(lambda: f64) -> Self {
        assert!((0.0..=1.0).contains(&lambda), "λ must be in [0,1]");
        Linear { lambda }
    }
}

impl Policy for Linear {
    fn name(&self) -> String {
        format!("linear(λ={})", self.lambda)
    }

    fn route(&mut self, ctx: &RouteCtx) -> RouteDecision {
        let max_bs = (0..ctx.n()).map(|i| ctx.inds[i].bs()).max().unwrap_or(0).max(1) as f64;
        RouteDecision::to(select_min(ctx, |i| {
            self.lambda * (1.0 - ctx.hit_ratio(i))
                + (1.0 - self.lambda) * (ctx.inds[i].bs() as f64 / max_bs)
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::router::Indicators;

    fn ctx(hits: Vec<usize>, bss: Vec<usize>) -> RouteCtx {
        let inds = bss
            .iter()
            .map(|b| Indicators {
                r_bs: *b,
                ..Default::default()
            })
            .collect();
        RouteCtx::new(0, 0, 0, 100, hits, inds)
    }

    #[test]
    fn high_lambda_chases_hits() {
        let c = ctx(vec![100, 0], vec![10, 0]);
        assert_eq!(Linear::new(0.9).route(&c).instance, 0, "hit wins at λ=0.9");
        assert_eq!(Linear::new(0.1).route(&c).instance, 1, "load wins at λ=0.1");
    }

    #[test]
    fn knee_behaviour_between() {
        // hit=60% on loaded instance vs 0% on idle: mid λ prefers idle,
        // high λ prefers the hit.
        let c = ctx(vec![60, 0], vec![10, 1]);
        assert_eq!(Linear::new(0.95).route(&c).instance, 0);
        assert_eq!(Linear::new(0.4).route(&c).instance, 1);
    }

    #[test]
    #[should_panic]
    fn rejects_bad_lambda() {
        Linear::new(1.5);
    }
}
