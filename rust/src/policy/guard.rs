//! The failure-condition analyzer + guarded LMETRIC — the paper's last
//! claim made executable: it "mathematically derive[s] the conditions
//! under which multiplication may fail, and find[s] that such conditions
//! are extremely rare in practice and can be detected (and mitigated)
//! beforehand".
//!
//! Multiplication compares instances by `kv_i × load_i`. The implicit
//! claim is that the product's argmin tracks the argmin of the true cost,
//! which is some positive linear combination `a·kv + b·load` whose
//! weights need no tuning because they cancel under cross-instance
//! comparison. The derived conditions where that cancellation breaks:
//!
//! * **Degenerate factor** — one factor stops discriminating, so the
//!   product collapses onto the other axis (or onto a tie):
//!   - *all-idle*: every candidate has `BS == 0`, so `BS+1` ties at 1
//!     cluster-wide and ties can no longer be broken by load;
//!   - *zero annihilation*: `P-token == 0` on ≥ 2 instances; their
//!     products all equal 0 regardless of load, so the score cannot
//!     rank them on the load axis at all.
//! * **Cross-spread inversion** — the spreads of the two indicator
//!   axes land in a window where the product's argmin is *provably*
//!   outside the moderate linear envelope: after per-axis mean
//!   normalization (the cancelled weights), there is **no** mixing
//!   weight `w ∈ [W_LO, W_HI]` for which the product's choice comes
//!   within [`INVERSION_MARGIN`] of minimizing
//!   `w·kv̂ + (1−w)·load̂`. Detected in one O(N) pass by intersecting,
//!   per instance, the half-interval of weights under which the product
//!   choice survives ([`FailureAnalyzer::analyze`]); empty intersection
//!   = misranking window.
//!
//! When a condition fires, [`GuardedLMetric`] applies the mitigation:
//! fall back to a deterministic secondary key — the lexicographic
//! `(P-token, BS)` comparison with the residual tie resolved toward the
//! *highest* prefix hit (max cache reuse), then lowest index — over the
//! set of instances the product left undetermined (its argmin tie set).
//! The two regimes differ in what that means:
//!
//! * Degenerate fires are discrimination collapses: the tie set is real
//!   (several instances share the minimal product) and the secondary
//!   key re-ranks it. This is where `guard_mitigated` can move.
//! * Inversion fires flag a *confident* product choice (singleton
//!   argmin); the guard reports it through the counters rather than
//!   forcibly re-ranking — any override there would replace one
//!   outside-the-envelope ranking with another (`fig33_guard_sweep`
//!   measures exactly this).
//!
//! On any decision where no condition fires, `GuardedLMetric` is
//! byte-identical to [`LMetric::paper`] by construction (it routes via
//! the same [`select_min`] over the same score). Moreover, on every
//! indicator state reachable through the DES/live data plane — where
//! queued prefill tokens imply queued batch members and prefix hits are
//! block-aligned prompt prefixes — the degenerate re-rank provably
//! agrees with `select_min`'s own tie-break, so `guard_mitigated == 0`
//! on natural traffic is a theorem; the decision-replay test enforces
//! it end to end.

use crate::router::{
    select_min, GuardCounters, IndicatorStats, Policy, RouteCtx, RouteDecision,
};

use super::lmetric::LMetric;

/// Lower edge of the moderate linear-envelope window: the true cost is
/// assumed to weight the (normalized) KV axis at least 1:3 vs load.
pub const W_LO: f64 = 0.25;
/// Upper edge of the envelope window (KV weighted at most 3:1 vs load).
pub const W_HI: f64 = 0.75;
/// Relative slack before an inversion counts: the product's choice must
/// be beaten by more than this fraction at *every* window weight.
/// Absorbs indicator staleness and sub-block P-token noise; borderline
/// inversions are not actionable misrankings.
pub const INVERSION_MARGIN: f64 = 0.25;

/// The per-decision analysis result.
#[derive(Debug, Clone, Copy, Default)]
pub struct GuardVerdict {
    /// All candidates idle: the load factor ties at 1 cluster-wide.
    pub degenerate_idle: bool,
    /// KV factor is exactly zero on ≥ 2 instances.
    pub degenerate_zero: bool,
    /// Product argmin provably outside the linear envelope window.
    pub inversion: bool,
    /// Cross-instance max/min ratio of the KV axis at this decision.
    pub kv_spread: f64,
    /// Cross-instance max/min ratio of the load axis.
    pub load_spread: f64,
}

impl GuardVerdict {
    pub fn degenerate(&self) -> bool {
        self.degenerate_idle || self.degenerate_zero
    }

    pub fn fired(&self) -> bool {
        self.degenerate() || self.inversion
    }
}

/// One logged routing decision of [`GuardedLMetric::with_log`]: enough
/// to recount every counter offline (the DES churn test does exactly
/// that).
#[derive(Debug, Clone, Copy)]
pub struct GuardDecision {
    pub req_id: u64,
    pub degenerate: bool,
    pub inversion: bool,
    /// What bare `select_min` over the product would have chosen.
    pub product_choice: usize,
    /// What the guarded policy actually chose.
    pub final_choice: usize,
}

/// The stateless failure-condition analyzer: evaluates the derived
/// misranking conditions on a borrowed [`RouteCtx`] in O(N) with zero
/// allocation.
#[derive(Debug, Clone, Copy)]
pub struct FailureAnalyzer {
    pub w_lo: f64,
    pub w_hi: f64,
    pub margin: f64,
}

impl Default for FailureAnalyzer {
    fn default() -> Self {
        FailureAnalyzer {
            w_lo: W_LO,
            w_hi: W_HI,
            margin: INVERSION_MARGIN,
        }
    }
}

impl FailureAnalyzer {
    /// Analyze one decision. `product_choice` must be the bare
    /// `select_min` argmin of `score` on this context (the caller just
    /// computed it to route).
    pub fn analyze(&self, ctx: &RouteCtx, score: &LMetric, product_choice: usize) -> GuardVerdict {
        let n = ctx.n();
        let stats = IndicatorStats::collect(ctx, |i| score.factors(ctx, i));
        let mut v = GuardVerdict {
            kv_spread: stats.kv_spread(),
            load_spread: stats.load_spread(),
            ..GuardVerdict::default()
        };
        if n < 2 {
            return v; // a single candidate cannot be misranked
        }
        v.degenerate_idle = stats.all_idle;
        v.degenerate_zero = stats.kv_zeros >= 2;
        let k_mean = stats.kv_mean();
        let l_mean = stats.load_mean();
        if v.degenerate() || k_mean <= 0.0 {
            // Tie/annihilation regimes are the degenerate detector's
            // job; the envelope is undefined on an all-zero KV axis.
            return v;
        }
        // Feasible-weight interval: the product choice `p` survives
        // weight w iff for every j,
        //   w·kv̂_j + (1−w)·load̂_j ≥ (1−margin)·(w·kv̂_p + (1−w)·load̂_p).
        // Each j contributes one linear constraint in w, i.e. one
        // half-interval; intersect them all with [w_lo, w_hi].
        let (kp, lp) = score.factors(ctx, product_choice);
        let kp = kp / k_mean * (1.0 - self.margin);
        let lp = lp / l_mean * (1.0 - self.margin);
        let mut lo = self.w_lo;
        let mut hi = self.w_hi;
        for j in 0..n {
            let (kj, lj) = score.factors(ctx, j);
            let a = kj / k_mean - kp;
            let b = lj / l_mean - lp;
            let d = a - b;
            if d > 0.0 {
                lo = lo.max(-b / d);
            } else if d < 0.0 {
                hi = hi.min(-b / d);
            } else if b < 0.0 {
                // Constant constraint, violated at every weight.
                lo = f64::INFINITY;
            }
            if lo > hi {
                break;
            }
        }
        v.inversion = lo > hi;
        v
    }

    /// The mitigation: re-rank the product's argmin *tie set* (every
    /// instance whose score equals `product_choice`'s — the set the
    /// product provably cannot discriminate) with the deterministic
    /// secondary key: lexicographic (KV factor asc, load factor asc,
    /// prefix hit desc, index asc). For the paper configuration this is
    /// the `(P-token, BS)` comparison, with residual ties resolved
    /// toward the instance holding the longest cached prefix.
    pub fn secondary_choice(
        &self,
        ctx: &RouteCtx,
        score: &LMetric,
        product_choice: usize,
    ) -> usize {
        let min_score = score.score(ctx, product_choice);
        let key = |i: usize| {
            let (kv, load) = score.factors(ctx, i);
            (kv, load, -(ctx.hit_tokens[i] as f64))
        };
        let mut best = product_choice;
        let mut best_key = key(product_choice);
        for i in 0..ctx.n() {
            if i == product_choice || score.score(ctx, i) != min_score {
                continue;
            }
            let k = key(i);
            if k < best_key {
                best_key = k;
                best = i;
            }
        }
        best
    }
}

/// Reference oracle for the inversion condition, by a *different*
/// algorithm than [`FailureAnalyzer::analyze`]'s interval intersection:
/// evaluate the survival slack
/// `min_j (L_w(j) − (1−margin)·L_w(i*))` at the window endpoints and at
/// every per-instance constraint root. The slack function is a min of
/// linear functions of w (concave piecewise linear), so its sign over
/// the window is decided at exactly these candidate weights. Returns
/// the best slack found: ≥ 0 ⟺ some window weight justifies `i_star`
/// (no inversion). Used by the property suite and `fig33_guard_sweep`
/// to cross-check the detector.
pub fn window_slack(
    kv: &[f64],
    load: &[f64],
    i_star: usize,
    w_lo: f64,
    w_hi: f64,
    margin: f64,
) -> f64 {
    let n = kv.len();
    assert_eq!(n, load.len());
    assert!(n >= 2, "window_slack needs >= 2 instances");
    let k_mean = kv.iter().sum::<f64>() / n as f64;
    let l_mean = load.iter().sum::<f64>() / n as f64;
    if k_mean <= 0.0 {
        return 0.0; // all-zero KV axis: envelope undefined, treat as safe
    }
    let kh = |i: usize| kv[i] / k_mean;
    let lh = |i: usize| load[i] / l_mean;
    let lw = |w: f64, i: usize| w * kh(i) + (1.0 - w) * lh(i);
    let slack_at = |w: f64| -> f64 {
        let target = (1.0 - margin) * lw(w, i_star);
        (0..n).map(|j| lw(w, j) - target).fold(f64::INFINITY, f64::min)
    };
    let mut best = slack_at(w_lo).max(slack_at(w_hi));
    for j in 0..n {
        let a = kh(j) - (1.0 - margin) * kh(i_star);
        let b = lh(j) - (1.0 - margin) * lh(i_star);
        let d = a - b;
        if d != 0.0 {
            let w = -b / d;
            if w > w_lo && w < w_hi {
                best = best.max(slack_at(w));
            }
        }
    }
    best
}

/// LMETRIC wrapped with the failure-condition guard — registry name
/// `lmetric_safe`. Identical to [`LMetric::paper`] on every decision
/// where no derived failure condition holds; on a degenerate detection,
/// re-ranks the product's tie set with the deterministic secondary key
/// and counts whether that actually changed the choice; on an inversion
/// detection, counts and flags (see the module docs for why a forced
/// override is not applied).
pub struct GuardedLMetric {
    inner: LMetric,
    pub analyzer: FailureAnalyzer,
    pub counters: GuardCounters,
    /// Per-decision record, enabled by [`GuardedLMetric::with_log`]
    /// (off by default: the hot path stays allocation-free).
    pub log: Option<Vec<GuardDecision>>,
}

impl GuardedLMetric {
    pub fn new() -> Self {
        GuardedLMetric {
            inner: LMetric::paper(),
            analyzer: FailureAnalyzer::default(),
            counters: GuardCounters::default(),
            log: None,
        }
    }

    /// A guarded policy that also records every decision (tests and
    /// offline analysis; the DES churn test recounts the counters from
    /// this log).
    pub fn with_log() -> Self {
        let mut g = GuardedLMetric::new();
        g.log = Some(Vec::new());
        g
    }

    pub fn inner(&self) -> &LMetric {
        &self.inner
    }
}

impl Default for GuardedLMetric {
    fn default() -> Self {
        Self::new()
    }
}

impl Policy for GuardedLMetric {
    fn name(&self) -> String {
        "lmetric_safe".into()
    }

    fn guard_counters(&self) -> Option<GuardCounters> {
        Some(self.counters)
    }

    fn route(&mut self, ctx: &RouteCtx) -> RouteDecision {
        self.counters.checks += 1;
        // Exactly the unguarded decision, same arithmetic + tie-breaks.
        let product_choice = select_min(ctx, |i| self.inner.score(ctx, i));
        let v = self.analyzer.analyze(ctx, &self.inner, product_choice);
        if v.degenerate() {
            self.counters.degenerate += 1;
        }
        if v.inversion {
            self.counters.inversion += 1;
        }
        let mut choice = product_choice;
        if v.degenerate() {
            // Discrimination collapse: re-rank the product's tie set
            // with the secondary key. Inversion fires leave the
            // (confident, singleton-argmin) choice standing and are
            // surfaced through the counters instead.
            let alt = self.analyzer.secondary_choice(ctx, &self.inner, product_choice);
            if alt != choice {
                self.counters.mitigated += 1;
                choice = alt;
            }
        }
        if let Some(log) = &mut self.log {
            log.push(GuardDecision {
                req_id: ctx.req_id,
                degenerate: v.degenerate(),
                inversion: v.inversion,
                product_choice,
                final_choice: choice,
            });
        }
        RouteDecision::to(choice)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::router::Indicators;

    fn ctx(input: usize, hits: Vec<usize>, bss: Vec<usize>, queued: Vec<usize>) -> RouteCtx {
        let inds = bss
            .iter()
            .zip(&queued)
            .map(|(b, q)| Indicators {
                r_bs: *b,
                queued_prefill_tokens: *q,
                ..Default::default()
            })
            .collect();
        RouteCtx::new(0, 0, 0, input, hits, inds)
    }

    fn analyze(c: &RouteCtx) -> GuardVerdict {
        let score = LMetric::paper();
        let a = FailureAnalyzer::default();
        let p = select_min(c, |i| score.score(c, i));
        a.analyze(c, &score, p)
    }

    #[test]
    fn benign_snapshot_fires_nothing() {
        // Distinct loads, distinct hits, product winner is also the
        // balanced winner: no condition holds.
        let c = ctx(1000, vec![800, 0], vec![4, 2], vec![0, 0]);
        let v = analyze(&c);
        assert!(!v.fired(), "{v:?}");
        assert!(v.kv_spread > 1.0);
    }

    #[test]
    fn all_idle_fleet_is_degenerate() {
        let c = ctx(1000, vec![0, 0, 0], vec![0, 0, 0], vec![0, 0, 0]);
        let v = analyze(&c);
        assert!(v.degenerate_idle);
        assert!(!v.degenerate_zero);
    }

    #[test]
    fn multi_zero_ptoken_is_degenerate() {
        // Full hit + empty queue on two instances: both products are 0,
        // load can no longer rank them.
        let c = ctx(320, vec![320, 320, 0], vec![3, 9, 1], vec![0, 0, 0]);
        let v = analyze(&c);
        assert!(v.degenerate_zero);
        assert!(!v.degenerate_idle);
        assert_eq!(v.kv_spread, f64::INFINITY);
    }

    #[test]
    fn single_zero_is_not_the_zero_degeneracy() {
        let c = ctx(320, vec![320, 0], vec![3, 1], vec![0, 0]);
        let v = analyze(&c);
        assert!(!v.degenerate_zero);
    }

    #[test]
    fn inversion_fires_when_product_choice_leaves_the_envelope() {
        // Instance 0: a tiny KV factor annihilates a huge batch — the
        // product drags the decision there. Instance 1 is moderately
        // good on BOTH axes and beats 0 at every window weight by more
        // than the margin (2 and 3 are plain cold instances).
        let c = ctx(1000, vec![960, 700, 0, 0], vec![40, 5, 1, 2], vec![0, 0, 0, 0]);
        // kv = p_token = (40, 300, 1000, 1000); load = (41, 6, 2, 3).
        // products: 1640, 1800, 2000, 3000 -> argmin = 0, but after
        // mean normalization instance 1 undercuts (1 - margin) of
        // instance 0's linear score across all of w in [0.25, 0.75].
        let score = LMetric::paper();
        let p = select_min(&c, |i| score.score(&c, i));
        assert_eq!(p, 0);
        let v = analyze(&c);
        assert!(v.inversion, "annihilated choice must be flagged: {v:?}");
        // Cross-check against the breakpoint oracle.
        let kv: Vec<f64> = (0..4).map(|i| score.factors(&c, i).0).collect();
        let ld: Vec<f64> = (0..4).map(|i| score.factors(&c, i).1).collect();
        assert!(window_slack(&kv, &ld, p, W_LO, W_HI, INVERSION_MARGIN) < 0.0);
    }

    #[test]
    fn balanced_product_choice_stays_inside_the_envelope() {
        // The overload_overrides_hit scenario: product picks the idle
        // instance — which any moderate linear weighting also prefers.
        let c = ctx(1000, vec![800, 0], vec![40, 1], vec![0, 0]);
        let v = analyze(&c);
        assert!(!v.inversion, "{v:?}");
        let score = LMetric::paper();
        let kv: Vec<f64> = (0..2).map(|i| score.factors(&c, i).0).collect();
        let ld: Vec<f64> = (0..2).map(|i| score.factors(&c, i).1).collect();
        assert!(window_slack(&kv, &ld, 1, W_LO, W_HI, INVERSION_MARGIN) >= 0.0);
    }

    #[test]
    fn guarded_identical_to_paper_when_inert() {
        let mut plain = LMetric::paper();
        let mut guarded = GuardedLMetric::new();
        let mut rng = crate::util::Rng::new(11);
        for k in 0..300u64 {
            let n = 5usize;
            let hits: Vec<usize> = (0..n).map(|_| (rng.gen_range(0, 20) * 16) as usize).collect();
            let bss: Vec<usize> = (0..n).map(|_| rng.gen_range(1, 30) as usize).collect();
            let queued: Vec<usize> = (0..n).map(|_| rng.gen_range(0, 4000) as usize).collect();
            let mut c = ctx(400, hits, bss, queued);
            c.req_id = k;
            let g = guarded.route(&c).instance;
            let p = plain.route(&c).instance;
            if guarded.counters.mitigated == 0 {
                assert_eq!(g, p, "inert guard must replay paper decisions (k={k})");
            }
        }
        assert_eq!(guarded.counters.checks, 300);
    }

    #[test]
    fn all_idle_tie_mitigation_picks_max_hit() {
        // Regression for the all-idle tie degeneracy: every instance at
        // BS = 0, scores tie (p_token equal via queued compensation),
        // but the prefix hits differ. Bare select_min resolves the
        // 0-spread tie by lowest index; the guard's secondary key must
        // pick the max-hit instance.
        let c = ctx(1000, vec![800, 1000], vec![0, 0], vec![0, 200]);
        // p_token: (0+200, 200+0) = (200, 200); BS+1 = (1, 1): exact tie.
        let mut plain = LMetric::paper();
        assert_eq!(
            plain.route(&c).instance,
            0,
            "the old tie-break: lowest index wins"
        );
        let mut g = GuardedLMetric::new();
        assert_eq!(
            g.route(&c).instance,
            1,
            "guard must prefer the instance holding the longer prefix"
        );
        assert_eq!(g.counters.degenerate, 1);
        assert_eq!(g.counters.mitigated, 1);
    }

    #[test]
    fn log_records_every_decision() {
        let mut g = GuardedLMetric::with_log();
        for k in 0..10u64 {
            let mut c = ctx(320, vec![0, 0], vec![1, 2], vec![0, 0]);
            c.req_id = k;
            g.route(&c);
        }
        let log = g.log.as_ref().unwrap();
        assert_eq!(log.len(), 10);
        assert_eq!(g.counters.checks, 10);
        let mitigated =
            log.iter().filter(|d| d.product_choice != d.final_choice).count() as u64;
        assert_eq!(mitigated, g.counters.mitigated);
    }

    #[test]
    fn single_instance_never_fires() {
        let c = ctx(100, vec![0], vec![0], vec![0]);
        let v = analyze(&c);
        assert!(!v.fired());
        let mut g = GuardedLMetric::new();
        assert_eq!(g.route(&c).instance, 0);
        assert_eq!(g.counters.degenerate + g.counters.inversion, 0);
    }
}
