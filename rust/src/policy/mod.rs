//! Every scheduling policy studied in the paper, implemented against the
//! same indicator factory for an apples-to-apples comparison (§3's
//! methodology, §6's baselines):
//!
//! | name           | paper | combination | hyperparameter |
//! |----------------|-------|-------------|----------------|
//! | `round_robin`  | —     | none        | — |
//! | `random`       | —     | none        | — |
//! | `vllm`         | §4.2  | load-balance only (JSQ: 4·Q-BS + R-BS) | — |
//! | `linear`       | §4.4 (BAILIAN) | λ·(1−hit) + (1−λ)·norm(BS) | λ |
//! | `dynamo`       | §6.1  | α·norm(P-token) + (1−α)·norm(#Tokens) | α |
//! | `filter_kv`    | §4.5 (AIBrix) | BS-range filter → max hit | Range |
//! | `sim_llmd`     | §4.6 (llm-d) | min simulated TTFT | simulator |
//! | `preble`       | §6.2/A.1 | hit filter → windowed linear fallback | T |
//! | `polyserve`    | §6.2/A.2 | SLO filter → load gradient | τ (SLO_TPOT) |
//! | `sticky`       | —     | session affinity: pin turns to first placement | — |
//! | `smetric`      | — (SMetric, PAPERS.md) | sticky + balanced live-session context | — |
//! | `lmetric`      | §5    | **P-token × BS** | none |
//! | `lmetric_guarded` | §5.2 | lmetric + two-phase hotspot detector | none |
//! | `lmetric_safe` | §5    | lmetric + failure-condition guard | none |
//! | `lmetric_fused` | — (RouteBalance, PAPERS.md) | (P-time + cold-swap) × BS | none |
//! | `place_then_balance` | — | model placement layer → lmetric in warm set | placement |
//!
//! Ablation variants for Figs 18/19: `lmetric_hit_ratio` uses
//! (1−hit-ratio)×BS; `lmetric_tokens` uses P-token×#Tokens.

mod baselines;
mod dynamo;
mod filter_kv;
mod guard;
mod hetero;
mod linear;
mod lmetric;
mod polyserve;
mod preble;
mod session;
mod sim_based;
mod vllm;

pub use baselines::{Random, RoundRobin};
pub use dynamo::Dynamo;
pub use filter_kv::FilterKv;
pub use guard::{
    window_slack, FailureAnalyzer, GuardDecision, GuardVerdict, GuardedLMetric,
    INVERSION_MARGIN, W_HI, W_LO,
};
pub use hetero::{
    all_placement_names, build_placement, FastestPlacement, LMetricFused,
    LeastLoadedPlacement, ModelPlacement, PlaceThenBalance,
};
pub use linear::Linear;
pub use lmetric::{KvAwareIndicator, LMetric, LoadIndicator};
pub use polyserve::PolyServe;
pub use preble::Preble;
pub use session::{SessionBalance, StickySession};
pub use sim_based::SimBased;
pub use vllm::Vllm;

use crate::engine::ModelProfile;
use crate::hotspot::HotspotGuarded;
use crate::router::Policy;
use crate::simulator::LatencySimulator;
use crate::util::Registry;

/// The shared name-listing registry (see [`crate::util::Registry`]); the
/// unknown-name rejection every entry point surfaces verbatim at the CLI
/// keeps its pre-migration wording byte-for-byte.
const REGISTRY: Registry = Registry::new(
    "policy",
    "policies",
    &[
        "round_robin",
        "random",
        "vllm",
        "linear",
        "dynamo",
        "filter_kv",
        "sim_llmd",
        "preble",
        "polyserve",
        "sticky",
        "smetric",
        "lmetric",
        "lmetric_guarded",
        "lmetric_safe",
        "lmetric_fused",
        "place_then_balance",
    ],
)
.with_suffix(" (plus ablations: lmetric_hit_ratio, lmetric_tokens)");

/// Build a policy by name. `param` is the policy's single hyperparameter
/// knob (λ / α / Range / T / τ-ms; ignored where hyperparameter-free).
/// Simulation-based policies get a *tuned* simulator for `profile`;
/// use [`build_with_simulator`] to study mis-tuned ones (Fig 15).
/// Unknown names are rejected with the name-listing error.
pub fn build(
    name: &str,
    param: f64,
    profile: &ModelProfile,
    chunk_budget: usize,
) -> Result<Box<dyn Policy>, String> {
    let sim = LatencySimulator::tuned(profile.clone(), chunk_budget);
    build_with_simulator(name, param, sim)
}

/// Build with an explicit simulator (tuned or untuned).
pub fn build_with_simulator(
    name: &str,
    param: f64,
    sim: LatencySimulator,
) -> Result<Box<dyn Policy>, String> {
    Ok(match name {
        "round_robin" => Box::new(RoundRobin::new()),
        "random" => Box::new(Random::new(7)),
        "vllm" => Box::new(Vllm::new()),
        "linear" => Box::new(Linear::new(param)),
        "dynamo" => Box::new(Dynamo::new(param)),
        "filter_kv" => Box::new(FilterKv::new(param as usize)),
        "sim_llmd" => Box::new(SimBased::new(sim)),
        "preble" => Box::new(Preble::new(param)),
        "polyserve" => Box::new(PolyServe::new(sim, param * 1000.0)),
        "sticky" => Box::new(StickySession::new()),
        "smetric" => Box::new(SessionBalance::new()),
        "lmetric" => Box::new(LMetric::paper()),
        "lmetric_hit_ratio" => Box::new(LMetric::new(
            KvAwareIndicator::OneMinusHitRatio,
            LoadIndicator::BatchSize,
        )),
        "lmetric_tokens" => Box::new(LMetric::new(
            KvAwareIndicator::PToken,
            LoadIndicator::TotalTokens,
        )),
        "lmetric_guarded" => Box::new(HotspotGuarded::new()),
        "lmetric_safe" => Box::new(GuardedLMetric::new()),
        "lmetric_fused" => Box::new(LMetricFused::new()),
        "place_then_balance" => Box::new(PlaceThenBalance::least_loaded()),
        _ => return Err(REGISTRY.unknown(name)),
    })
}

/// The per-policy default hyperparameter (the paper's tuned/default
/// values: λ=0.7 linear, α=0.7 dynamo, Range=8 AIBrix, T=0.5 Preble,
/// τ=20 ms PolyServe). Hyperparameter-free policies return 0.
pub fn default_param(name: &str) -> f64 {
    match name {
        "linear" => 0.7,
        "dynamo" => 0.7,
        "filter_kv" => 8.0,
        "preble" => 0.5,
        "polyserve" => 20.0, // ms
        _ => 0.0,
    }
}

/// Build a policy with its default hyperparameter. Unknown names are
/// rejected with the same name-listing error as [`build`].
pub fn build_default(
    name: &str,
    profile: &ModelProfile,
    chunk_budget: usize,
) -> Result<Box<dyn Policy>, String> {
    build(name, default_param(name), profile, chunk_budget)
}

/// All policy names (for `lmetric replay --policy all` sweeps).
pub fn all_names() -> &'static [&'static str] {
    REGISTRY.names_static()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_builds_everything() {
        let p = ModelProfile::moe_30b();
        for name in all_names() {
            let pol = build(name, 0.7, &p, 256);
            assert!(pol.is_ok(), "missing policy {name}");
        }
        assert!(build("lmetric_hit_ratio", 0.0, &p, 256).is_ok());
        assert!(build("lmetric_tokens", 0.0, &p, 256).is_ok());
        assert!(build("nope", 0.0, &p, 256).is_err());
    }

    #[test]
    fn every_entry_point_rejects_with_the_name_listing_error() {
        let p = ModelProfile::moe_30b();
        let sim = LatencySimulator::tuned(p.clone(), 256);
        let via_build = build("no_such_policy", 0.7, &p, 256).err().unwrap();
        let via_sim = build_with_simulator("no_such_policy", 0.7, sim).err().unwrap();
        let via_default = build_default("no_such_policy", &p, 256).err().unwrap();
        assert_eq!(via_build, via_sim);
        assert_eq!(via_build, via_default);
        for name in ["lmetric_safe", "sticky", "smetric"] {
            assert!(via_build.contains(name), "error lists '{name}': {via_build}");
        }
    }

    #[test]
    fn build_default_constructs_every_paper_policy_by_name() {
        let p = ModelProfile::moe_30b();
        for name in all_names() {
            let pol = build_default(name, &p, 256)
                .unwrap_or_else(|e| panic!("build_default({name}) failed: {e}"));
            // The constructed policy must self-report under the requested
            // registry name (parameterized names embed their default knob).
            assert!(
                pol.name().starts_with(name.split('_').next().unwrap())
                    || pol.name().contains("lmetric"),
                "{name} built {}",
                pol.name()
            );
        }
        for name in ["lmetric_hit_ratio", "lmetric_tokens"] {
            assert!(build_default(name, &p, 256).is_ok(), "{name}");
        }
    }

    #[test]
    fn unknown_policy_error_is_pinned_byte_for_byte() {
        let p = ModelProfile::moe_30b();
        let err = build("nope", 0.0, &p, 256).err().unwrap();
        assert_eq!(
            err,
            "unknown policy 'nope'; valid policies: round_robin, random, vllm, \
             linear, dynamo, filter_kv, sim_llmd, preble, polyserve, sticky, \
             smetric, lmetric, lmetric_guarded, lmetric_safe, lmetric_fused, \
             place_then_balance (plus ablations: lmetric_hit_ratio, \
             lmetric_tokens)"
        );
    }

    #[test]
    fn build_default_rejects_unknown_names_with_useful_error() {
        let p = ModelProfile::moe_30b();
        // (`unwrap_err` needs `Box<dyn Policy>: Debug`, which it isn't.)
        let err = build_default("no_such_policy", &p, 256).err().unwrap();
        assert!(err.contains("no_such_policy"), "error names the input: {err}");
        for name in all_names() {
            assert!(err.contains(name), "error lists '{name}': {err}");
        }
    }
}
