//! The simulation-based policy (§4.6, Fig 14) — llm-d's shape: predict
//! the TTFT of routing the request to every instance with a VIDUR-like
//! simulator, route to the minimum. The decision quality is exactly the
//! simulator's accuracy (Figs 15–16).

use crate::router::{select_min, Policy, RouteCtx, RouteDecision};
use crate::simulator::LatencySimulator;

pub struct SimBased {
    sim: LatencySimulator,
}

impl SimBased {
    pub fn new(sim: LatencySimulator) -> Self {
        SimBased { sim }
    }
}

impl Policy for SimBased {
    fn name(&self) -> String {
        if self.sim.noise_sigma == 0.0 {
            format!("sim_llmd[{}]", self.sim.profile.name)
        } else {
            format!("sim_llmd[untuned:{}]", self.sim.profile.name)
        }
    }

    fn route(&mut self, ctx: &RouteCtx) -> RouteDecision {
        let preds: Vec<f64> = (0..ctx.n()).map(|i| self.sim.predict_ttft(ctx, i)).collect();
        let inst = select_min(ctx, |i| preds[i]);
        RouteDecision {
            instance: inst,
            predicted_ttft_us: Some(preds[inst]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::ModelProfile;
    use crate::router::Indicators;

    #[test]
    fn routes_to_lowest_predicted_ttft() {
        let sim = LatencySimulator::tuned(ModelProfile::moe_30b(), 256);
        let mut p = SimBased::new(sim);
        let mut busy = Indicators::default();
        busy.queued_prefill_tokens = 50_000;
        let ctx = RouteCtx::new(0, 0, 0, 1000, vec![0, 0], vec![busy, Indicators::default()]);
        let d = p.route(&ctx);
        assert_eq!(d.instance, 1);
        assert!(d.predicted_ttft_us.unwrap() > 0.0);
    }

    #[test]
    fn kv_aware_through_the_simulator() {
        // The simulator models prefill-with-hits, so sim-based routing is
        // implicitly KV$-aware (a "higher-order combination", §4.6).
        let sim = LatencySimulator::tuned(ModelProfile::moe_30b(), 256);
        let mut p = SimBased::new(sim);
        let ctx = RouteCtx::new(
            0,
            0,
            0,
            2000,
            vec![1600, 0],
            vec![Indicators::default(), Indicators::default()],
        );
        assert_eq!(p.route(&ctx).instance, 0);
    }
}
