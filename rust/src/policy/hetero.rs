//! Heterogeneous-fleet policies: fused multi-model routing vs the
//! classical two-layer baseline.
//!
//! A multi-model fleet has two coupled decisions: *placement* (which
//! instance should hold this request's model warm) and *balance* (which
//! instance clears this request soonest). The classical architecture
//! solves them in layers — a placement controller pins models to
//! instances, then a load balancer spreads requests over the pinned set.
//! RouteBalance (PAPERS.md) shows the layering itself costs goodput:
//! the balancer can't see a cold load coming and the placer can't see
//! queue depth. [`LMetricFused`] collapses the two into one LMetric-style
//! product — the cold-load swap is just more predicted prefill time:
//!
//! `score_i = (P-time_i + cold_penalty_i) × (BS_i + 1)`
//!
//! Both terms are in reference prefill-token units, so the metric stays
//! hyperparameter-free: any common rescaling of the time axis cancels
//! under the cross-instance product comparison exactly like LMetric's
//! weights. On single-model traffic every penalty is 0 and the score
//! degenerates to plain (cost-aware) LMetric bit-for-bit.
//!
//! [`PlaceThenBalance`] is the two-layer baseline `fig91_hetero_fleet`
//! compares against: a [`ModelPlacement`] strategy picks who loads a
//! cold model, and LMetric balances strictly within the warm set.

use crate::router::{select_min, Policy, RouteCtx, RouteDecision};
use crate::util::Registry;

/// Fused placement + balance: one multiplicative score prices the
/// queue, the hardware speed, AND the cold-model swap together.
pub struct LMetricFused;

impl LMetricFused {
    pub fn new() -> Self {
        LMetricFused
    }

    /// The fused score for instance `i` (public so fig harnesses and the
    /// proptests evaluate the exact shipped arithmetic).
    pub fn score(&self, ctx: &RouteCtx, i: usize) -> f64 {
        (ctx.p_time(i) + ctx.cold_penalty(i)) * (ctx.inds[i].bs() + 1) as f64
    }
}

impl Default for LMetricFused {
    fn default() -> Self {
        Self::new()
    }
}

impl Policy for LMetricFused {
    fn name(&self) -> String {
        "lmetric_fused".into()
    }

    fn route(&mut self, ctx: &RouteCtx) -> RouteDecision {
        RouteDecision::to(select_min(ctx, |i| self.score(ctx, i)))
    }
}

/// Layer 1 of the two-layer baseline: given a request whose model is
/// cold everywhere, choose the instance that should load it.
pub trait ModelPlacement: Send {
    fn name(&self) -> &'static str;
    fn place(&mut self, ctx: &RouteCtx) -> usize;
}

/// Load the cold model on the least-loaded instance (smallest BS) —
/// what a Ray-Serve-style multiplexed deployment does by default.
pub struct LeastLoadedPlacement;

impl ModelPlacement for LeastLoadedPlacement {
    fn name(&self) -> &'static str {
        "least_loaded"
    }

    fn place(&mut self, ctx: &RouteCtx) -> usize {
        select_min(ctx, |i| ctx.inds[i].bs() as f64)
    }
}

/// Load the cold model on the fastest prefill slot — it pays the swap
/// quickest, at the cost of concentrating models on big hardware.
pub struct FastestPlacement;

impl ModelPlacement for FastestPlacement {
    fn name(&self) -> &'static str {
        "fastest"
    }

    fn place(&mut self, ctx: &RouteCtx) -> usize {
        select_min(ctx, |i| -ctx.prefill_scale(i))
    }
}

const PLACEMENT_REGISTRY: Registry = Registry::new(
    "placement policy",
    "placement policies",
    &["least_loaded", "fastest"],
);

/// Placement strategy names, in display order.
pub fn all_placement_names() -> &'static [&'static str] {
    PLACEMENT_REGISTRY.names_static()
}

/// Build a placement strategy by name; unknown names get the standard
/// name-listing rejection.
pub fn build_placement(name: &str) -> Result<Box<dyn ModelPlacement>, String> {
    Ok(match name {
        "least_loaded" => Box::new(LeastLoadedPlacement),
        "fastest" => Box::new(FastestPlacement),
        _ => return Err(PLACEMENT_REGISTRY.unknown(name)),
    })
}

/// The two-layer baseline: place (only when the model is cold
/// everywhere), then balance with LMetric strictly inside the warm set.
/// The balance layer is blind to swap costs and the placement layer is
/// blind to queues — the coupling `lmetric_fused` exploits.
pub struct PlaceThenBalance {
    placement: Box<dyn ModelPlacement>,
}

impl PlaceThenBalance {
    pub fn new(placement: Box<dyn ModelPlacement>) -> Self {
        PlaceThenBalance { placement }
    }

    /// The default configuration (least-loaded placement).
    pub fn least_loaded() -> Self {
        Self::new(Box::new(LeastLoadedPlacement))
    }
}

impl Policy for PlaceThenBalance {
    fn name(&self) -> String {
        format!("place_then_balance[{}]", self.placement.name())
    }

    fn route(&mut self, ctx: &RouteCtx) -> RouteDecision {
        // Single-model traffic (empty penalty vector): pure balance.
        if ctx.cold_penalty_tokens.is_empty() {
            return RouteDecision::to(select_min(ctx, |i| {
                ctx.p_time(i) * (ctx.inds[i].bs() + 1) as f64
            }));
        }
        let any_warm = (0..ctx.n()).any(|i| ctx.inds[i].routable && ctx.cold_penalty(i) == 0.0);
        if !any_warm {
            // Cold everywhere: the placement layer decides alone.
            return RouteDecision::to(self.placement.place(ctx));
        }
        // Balance inside the warm set only — the layer boundary.
        RouteDecision::to(select_min(ctx, |i| {
            if ctx.cold_penalty(i) == 0.0 {
                ctx.p_time(i) * (ctx.inds[i].bs() + 1) as f64
            } else {
                f64::INFINITY
            }
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::router::Indicators;

    fn ctx(queued: Vec<usize>, bss: Vec<usize>) -> RouteCtx {
        let n = queued.len();
        let inds = queued
            .iter()
            .zip(&bss)
            .map(|(q, b)| Indicators {
                r_bs: *b,
                queued_prefill_tokens: *q,
                ..Default::default()
            })
            .collect();
        RouteCtx::new(0, 0, 0, 1000, vec![0; n], inds)
    }

    #[test]
    fn fused_degenerates_to_lmetric_on_single_model_traffic() {
        let c = ctx(vec![500, 9000], vec![3, 1]);
        let fused = LMetricFused::new();
        let lm = crate::policy::LMetric::paper();
        for i in 0..2 {
            assert_eq!(fused.score(&c, i).to_bits(), lm.score(&c, i).to_bits());
        }
    }

    #[test]
    fn fused_prices_the_swap_into_the_product() {
        // Instance 0 is warm but busier; instance 1 idle but cold with a
        // penalty big enough to lose: fused sees both sides.
        let mut c = ctx(vec![2000, 0], vec![2, 0]);
        c.cold_penalty_tokens = vec![0.0, 20_000.0];
        let mut p = LMetricFused::new();
        // warm: (2000+1000)*4 = 12_000 < cold: (1000+20_000)*1 = 21_000
        assert_eq!(p.route(&c).instance, 0);
        // A small penalty flips it: idle hardware wins despite the swap.
        c.cold_penalty_tokens = vec![0.0, 5_000.0];
        assert_eq!(p.route(&c).instance, 1);
    }

    #[test]
    fn two_layer_never_routes_cold_while_anything_is_warm() {
        // The warm instance is drowning; fused defects to the cold idle
        // one, the layered baseline cannot.
        let mut c = ctx(vec![50_000, 0], vec![30, 0]);
        c.cold_penalty_tokens = vec![0.0, 5_000.0];
        let mut layered = PlaceThenBalance::least_loaded();
        let mut fused = LMetricFused::new();
        assert_eq!(layered.route(&c).instance, 0, "stuck inside the warm set");
        assert_eq!(fused.route(&c).instance, 1, "fused escapes the layer");
    }

    #[test]
    fn placement_layer_decides_when_cold_everywhere() {
        let mut c = ctx(vec![0, 0, 0], vec![5, 2, 9]);
        c.cold_penalty_tokens = vec![100.0, 100.0, 100.0];
        let mut p = PlaceThenBalance::least_loaded();
        assert_eq!(p.route(&c).instance, 1, "least-loaded places on min BS");
        let mut c2 = ctx(vec![0, 0, 0], vec![5, 2, 9]);
        c2.cold_penalty_tokens = vec![100.0; 3];
        c2.fleet_prefill_scale = vec![0.5, 1.0, 2.0];
        let mut pf = PlaceThenBalance::new(Box::new(FastestPlacement));
        assert_eq!(pf.route(&c2).instance, 2, "fastest places on max scale");
    }

    #[test]
    fn placement_registry_rejects_with_name_listing() {
        assert!(build_placement("least_loaded").is_ok());
        assert!(build_placement("fastest").is_ok());
        let err = build_placement("bogus").err().unwrap();
        assert_eq!(
            err,
            "unknown placement policy 'bogus'; valid placement policies: \
             least_loaded, fastest"
        );
        assert_eq!(all_placement_names(), &["least_loaded", "fastest"]);
    }
}
