//! vLLM-v1's default global scheduling policy (§4.2, Fig 6a): a
//! load-balancing-only JSQ variant scoring `4·Q-BS + R-BS`. Queued
//! requests weigh more than running ones because a queued request has all
//! of its work still ahead of it.

use crate::router::{select_min, Policy, RouteCtx, RouteDecision};

pub struct Vllm;

impl Vllm {
    pub fn new() -> Self {
        Vllm
    }
}

impl Default for Vllm {
    fn default() -> Self {
        Self::new()
    }
}

impl Policy for Vllm {
    fn name(&self) -> String {
        "vllm".into()
    }

    fn route(&mut self, ctx: &RouteCtx) -> RouteDecision {
        RouteDecision::to(select_min(ctx, |i| {
            (4 * ctx.inds[i].q_bs + ctx.inds[i].r_bs) as f64
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::router::Indicators;

    #[test]
    fn prefers_short_queue_over_small_batch() {
        let mut inds = vec![Indicators::default(); 2];
        inds[0].q_bs = 2; // score 8
        inds[0].r_bs = 0;
        inds[1].q_bs = 0;
        inds[1].r_bs = 7; // score 7
        // hits are IGNORED by design
        let ctx = RouteCtx::new(0, 0, 0, 100, vec![100, 0], inds);
        let mut p = Vllm::new();
        assert_eq!(p.route(&ctx).instance, 1);
    }
}
