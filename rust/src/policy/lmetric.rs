//! **LMETRIC** — the paper's contribution (§5, Fig 17): route to the
//! instance minimizing the *product* of one KV$-aware indicator and one
//! load-balancing indicator:
//!
//! `score_i = P-token_i × (BS_i + 1)`
//!
//! Multiplication preserves the trend of a linear combination but the
//! weights cancel under cross-instance comparison — no tuning. The `+1`
//! is the paper's `BS.update(1)` (Fig 17b line 3): the request itself
//! joins the batch, and it keeps an idle instance's load indicator from
//! annihilating the product.
//!
//! Indicator choices are explicit enum parameters so the Fig 18/19
//! ablations (`1−KV$-hit-ratio` vs `P-token`; `#Tokens` vs `BS`) are the
//! same code path.

use crate::router::{select_min, Policy, RouteCtx, RouteDecision};

/// The KV$-awareness factor (Fig 18 ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvAwareIndicator {
    /// New prefill tokens if routed there, *including* the instance's
    /// queued prefill tokens (the paper's choice, §5.1) — evaluated
    /// cost-aware through [`RouteCtx::p_time`]: on a heterogeneous
    /// fleet the token count divides by the slot's prefill speed, and
    /// on a uniform fleet the divisor is exactly 1.0 so the score is
    /// bit-identical to the token count itself.
    PToken,
    /// 1 − KV$ hit ratio (Preble/AIGW's choice; misses queue state).
    OneMinusHitRatio,
}

/// The load-balancing factor (Fig 19 ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadIndicator {
    /// Batch size (running + queued) — the paper's choice: decode time is
    /// governed by batch size, not context tokens (Fig 19b).
    BatchSize,
    /// Total context tokens (Dynamo/AIGW's choice).
    TotalTokens,
}

pub struct LMetric {
    pub kv: KvAwareIndicator,
    pub load: LoadIndicator,
}

impl LMetric {
    pub fn new(kv: KvAwareIndicator, load: LoadIndicator) -> Self {
        LMetric { kv, load }
    }

    /// The published configuration: P-token × BS.
    pub fn paper() -> Self {
        LMetric::new(KvAwareIndicator::PToken, LoadIndicator::BatchSize)
    }

    /// The two factors of the product for instance `i`: `(KV-aware,
    /// load)`. Public so the failure-condition guard's envelope analysis
    /// ([`crate::policy::FailureAnalyzer`]) evaluates the *same*
    /// indicator arithmetic it guards, factor by factor.
    pub fn factors(&self, ctx: &RouteCtx, i: usize) -> (f64, f64) {
        let kv = match self.kv {
            KvAwareIndicator::PToken => ctx.p_time(i),
            KvAwareIndicator::OneMinusHitRatio => 1.0 - ctx.hit_ratio(i),
        };
        let load = match self.load {
            LoadIndicator::BatchSize => (ctx.inds[i].bs() + 1) as f64,
            LoadIndicator::TotalTokens => (ctx.inds[i].total_context_tokens + 1) as f64,
        };
        (kv, load)
    }

    /// The multiplicative score for instance `i` (public so the hotspot
    /// detector's phase-2 comparison reuses the exact same arithmetic).
    pub fn score(&self, ctx: &RouteCtx, i: usize) -> f64 {
        let (kv, load) = self.factors(ctx, i);
        kv * load
    }
}

impl Policy for LMetric {
    fn name(&self) -> String {
        match (self.kv, self.load) {
            (KvAwareIndicator::PToken, LoadIndicator::BatchSize) => "lmetric".into(),
            (KvAwareIndicator::OneMinusHitRatio, LoadIndicator::BatchSize) => {
                "lmetric[1-hit×BS]".into()
            }
            (KvAwareIndicator::PToken, LoadIndicator::TotalTokens) => {
                "lmetric[P-tok×#Tok]".into()
            }
            _ => "lmetric[1-hit×#Tok]".into(),
        }
    }

    fn route(&mut self, ctx: &RouteCtx) -> RouteDecision {
        RouteDecision::to(select_min(ctx, |i| self.score(ctx, i)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::router::Indicators;

    fn ctx(input: usize, hits: Vec<usize>, bss: Vec<usize>, queued: Vec<usize>) -> RouteCtx {
        let inds = bss
            .iter()
            .zip(&queued)
            .map(|(b, q)| Indicators {
                r_bs: *b,
                queued_prefill_tokens: *q,
                ..Default::default()
            })
            .collect();
        RouteCtx::new(0, 0, 0, input, hits, inds)
    }

    #[test]
    fn hit_wins_when_balanced() {
        let c = ctx(1000, vec![800, 0], vec![4, 4], vec![0, 0]);
        // scores: 200*5=1000 vs 1000*5=5000
        assert_eq!(LMetric::paper().route(&c).instance, 0);
    }

    #[test]
    fn overload_overrides_hit() {
        // Hit instance is drowning in batch: (1000-800)*(41) = 8200 vs
        // 1000*(1+1) = 2000 -> idle instance wins despite zero hit.
        let c = ctx(1000, vec![800, 0], vec![40, 1], vec![0, 0]);
        assert_eq!(LMetric::paper().route(&c).instance, 1);
    }

    #[test]
    fn queued_prefill_breaks_hit_preference() {
        // §5.1's key property: P-token sees queued prefill tokens that the
        // hit-ratio variant is blind to.
        let c = ctx(1000, vec![800, 0], vec![4, 4], vec![20_000, 0]);
        assert_eq!(
            LMetric::paper().route(&c).instance,
            1,
            "P-token bypasses the congested hit instance"
        );
        let mut ablation = LMetric::new(
            KvAwareIndicator::OneMinusHitRatio,
            LoadIndicator::BatchSize,
        );
        assert_eq!(
            ablation.route(&c).instance,
            0,
            "hit-ratio variant chases the hit blindly"
        );
    }

    #[test]
    fn full_hit_idle_scores_zero_and_wins() {
        let c = ctx(320, vec![320, 0], vec![0, 0], vec![0, 0]);
        let p = LMetric::paper();
        assert_eq!(p.score(&c, 0), 0.0);
        let mut p = p;
        assert_eq!(p.route(&c).instance, 0);
    }

    #[test]
    fn no_hyperparameters_scale_invariance() {
        // Multiplying both factors by constants (the cancelled λ's) can't
        // change the argmin: verify score ordering is scale-free.
        let c = ctx(1000, vec![500, 200], vec![3, 7], vec![100, 50]);
        let p = LMetric::paper();
        let (a, b) = (p.score(&c, 0), p.score(&c, 1));
        assert_eq!(a < b, (2.5 * a) < (2.5 * b));
    }

    #[test]
    fn cost_aware_p_time_prefers_the_faster_slot() {
        // Identical tokens and batch everywhere; only the hardware
        // differs. The cost-aware P factor routes to the 2× slot.
        let mut c = ctx(1000, vec![0, 0], vec![4, 4], vec![500, 500]);
        c.fleet_prefill_scale = vec![0.5, 2.0];
        let mut p = LMetric::paper();
        assert_eq!(p.route(&c).instance, 1);
        // And enough queued work on the fast slot flips it back: the
        // scales re-weight, they don't override, the token signal.
        let mut c2 = ctx(1000, vec![0, 0], vec![4, 4], vec![500, 20_000]);
        c2.fleet_prefill_scale = vec![0.5, 2.0];
        assert_eq!(p.route(&c2).instance, 0);
    }

    #[test]
    fn tokens_variant_uses_context() {
        let mut i0 = Indicators::default();
        i0.total_context_tokens = 50_000;
        let i1 = Indicators {
            r_bs: 30, // huge BS but tiny contexts
            total_context_tokens: 100,
            ..Default::default()
        };
        let c = RouteCtx::new(0, 0, 0, 100, vec![0, 0], vec![i0, i1]);
        let mut tok = LMetric::new(KvAwareIndicator::PToken, LoadIndicator::TotalTokens);
        let mut bs = LMetric::paper();
        assert_eq!(tok.route(&c).instance, 1, "#Tokens variant avoids big ctx");
        assert_eq!(bs.route(&c).instance, 0, "BS variant avoids big batch");
    }
}
