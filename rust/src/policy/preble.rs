//! Preble (§6.2, §A.1, Fig 30): a hybrid of the filter-based and
//! linear-combination schemes. If some instance's cached prefix covers
//! more than a threshold `T` of the prompt, route to the best-hit
//! instance (ties: least prefill load). Otherwise fall back to a linear
//! score over 3-minute sliding-window per-instance cost sums:
//!
//! `argmin_i  α·Σ_window P-token_i + β·Σ_window BS_i`
//!
//! where the window sums accumulate the per-request prefill tokens and a
//! per-request decode cost for requests the router sent to instance `i`.

use std::collections::VecDeque;

use crate::router::{select_min, Policy, RouteCtx, RouteDecision};

/// Per-instance sliding window of (time, prefill_tokens, decode_cost).
#[derive(Debug, Default)]
struct Window {
    entries: VecDeque<(u64, f64, f64)>,
    sum_ptok: f64,
    sum_decode: f64,
}

impl Window {
    fn push(&mut self, now: u64, ptok: f64, decode: f64) {
        self.entries.push_back((now, ptok, decode));
        self.sum_ptok += ptok;
        self.sum_decode += decode;
    }

    fn expire(&mut self, now: u64, horizon_us: u64) {
        while let Some(&(t, p, d)) = self.entries.front() {
            if now.saturating_sub(t) > horizon_us {
                self.entries.pop_front();
                self.sum_ptok -= p;
                self.sum_decode -= d;
            } else {
                break;
            }
        }
    }
}

pub struct Preble {
    /// Hit-ratio filter threshold T (default 0.5, Fig 31 sweeps it;
    /// T = 1.0 disables the KV$ branch entirely — Fig 32).
    pub threshold: f64,
    /// Fallback weights (one effective degree of freedom α/β; Preble
    /// exposes both, §A.1 footnote).
    pub alpha: f64,
    pub beta: f64,
    window_us: u64,
    windows: Vec<Window>,
    /// Branch-selection accounting (Fig 27).
    pub kv_branch_routes: u64,
    pub fallback_routes: u64,
}

impl Preble {
    pub fn new(threshold: f64) -> Self {
        Preble {
            threshold,
            // Profiled per Preble's method: α ≈ per-token prefill cost,
            // β ≈ per-request decode cost, so both sums are in time units.
            alpha: 1.0,
            beta: 250.0,
            window_us: 180_000_000, // 3 minutes
            windows: Vec::new(),
            kv_branch_routes: 0,
            fallback_routes: 0,
        }
    }

    /// Fraction of routes taken through the KV$-aware branch (Fig 27).
    pub fn kv_branch_rate(&self) -> f64 {
        let total = self.kv_branch_routes + self.fallback_routes;
        if total == 0 {
            0.0
        } else {
            self.kv_branch_routes as f64 / total as f64
        }
    }
}

impl Policy for Preble {
    fn name(&self) -> String {
        format!("preble(T={})", self.threshold)
    }

    fn route(&mut self, ctx: &RouteCtx) -> RouteDecision {
        if self.windows.len() < ctx.n() {
            self.windows.resize_with(ctx.n(), Window::default);
        }
        for w in self.windows.iter_mut() {
            w.expire(ctx.now_us, self.window_us);
        }

        let best_hit = (0..ctx.n()).map(|i| ctx.hit_ratio(i)).fold(0.0, f64::max);
        let inst = if best_hit > self.threshold {
            self.kv_branch_routes += 1;
            // Among instances tied for the max hit ratio, least prefill
            // load (P-token) wins.
            select_min(ctx, |i| {
                if (ctx.hit_ratio(i) - best_hit).abs() < 1e-9 {
                    ctx.p_token(i) as f64
                } else {
                    f64::INFINITY
                }
            })
        } else {
            self.fallback_routes += 1;
            select_min(ctx, |i| {
                self.alpha * self.windows[i].sum_ptok + self.beta * self.windows[i].sum_decode
            })
        };
        // Accumulate this request's cost into the routed instance window.
        self.windows[inst].push(ctx.now_us, ctx.new_tokens(inst) as f64, 1.0);
        RouteDecision::to(inst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::router::Indicators;

    fn ctx(now: u64, hits: Vec<usize>, input: usize) -> RouteCtx {
        let n = hits.len();
        RouteCtx::new(now, 0, 0, input, hits, vec![Indicators::default(); n])
    }

    #[test]
    fn high_hit_takes_kv_branch() {
        let mut p = Preble::new(0.5);
        let c = ctx(0, vec![80, 0], 100);
        assert_eq!(p.route(&c).instance, 0);
        assert_eq!(p.kv_branch_routes, 1);
    }

    #[test]
    fn low_hit_falls_back_to_window_score() {
        let mut p = Preble::new(0.5);
        // Send a stream of misses: window sums should spread them.
        let mut counts = vec![0usize; 3];
        for k in 0..30 {
            let c = ctx(k * 1000, vec![0, 0, 0], 300);
            counts[p.route(&c).instance] += 1;
        }
        assert_eq!(p.fallback_routes, 30);
        // Balanced-ish: every instance used.
        assert!(counts.iter().all(|&c| c >= 5), "{counts:?}");
    }

    #[test]
    fn window_expiry_forgets_old_load() {
        let mut p = Preble::new(0.9);
        // Load instance 0 heavily at t=0.
        for _ in 0..10 {
            let mut c = ctx(0, vec![0, 0], 500);
            c.inds[1].q_bs = 1000; // force all early routes to 0
            p.route(&c);
        }
        // 4 minutes later the window is empty: route spread resumes at 0.
        let c = ctx(240_000_000, vec![0, 0], 500);
        let d = p.route(&c);
        assert_eq!(d.instance, 0, "expired window no longer penalizes 0");
    }

    #[test]
    fn threshold_one_disables_kv_branch() {
        let mut p = Preble::new(1.0);
        let c = ctx(0, vec![100, 0], 100); // 100% hit still ≤ T
        p.route(&c);
        assert_eq!(p.kv_branch_routes, 0);
        assert_eq!(p.fallback_routes, 1);
    }

    #[test]
    fn branch_rate_accounting() {
        let mut p = Preble::new(0.5);
        p.route(&ctx(0, vec![90, 0], 100)); // kv branch
        p.route(&ctx(1, vec![10, 0], 100)); // fallback
        assert!((p.kv_branch_rate() - 0.5).abs() < 1e-12);
    }
}
