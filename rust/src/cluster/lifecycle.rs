//! Fleet lifecycle & fault injection: deterministic, seed-driven schedules
//! of instance crash / drain / scale events, the counters that account for
//! every request they displace, and a reactive autoscaler closing the loop
//! from fleet observations back into lifecycle events.
//!
//! The DES ([`crate::cluster::RunSpec::with_faults`]) and the live
//! threaded cluster both consume a [`FaultPlan`]; recovery semantics are:
//!
//! * [`FaultEvent::Crash`] — the instance dies mid-step: its running
//!   batch and queue are killed, every killed request is *requeued*
//!   through the router (re-entering admission control, where a rejection
//!   counts as [`FaultCounters::lost`], never a silent drop), its
//!   engine-local KV$ is wiped, and the shared prefix index purges its
//!   presence bits and per-instance occupancy so a later recover or
//!   scale-up into the slot starts cold.
//! * [`FaultEvent::Drain`] — the instance stops accepting new work but
//!   finishes its in-flight batch; queued-but-unstarted requests requeue
//!   immediately. If the batch outlives the deadline the drain is forced
//!   (a [`FaultCounters::drain_violations`]) and the remainder requeues.
//! * [`FaultEvent::Recover`] — a dead slot rejoins the routable set,
//!   cold (its KV$ died with it).
//! * [`FaultEvent::ScaleUp`] — a new instance joins, reusing the lowest
//!   dead slot if one exists, else widening the fleet (mask-width resize
//!   via `resize_instances` on the shared index). With `cold_kv: false`
//!   it is pre-seeded with recently completed prefix chains (warm start).
//!
//! Determinism: scripted events fire at fixed virtual times; stochastic
//! schedules materialize up front from a SplitMix64 stream whose draw
//! order (inter-fault gap, victim, downtime — exactly three draws per
//! fault) is mirrored by `python/tests/test_fault_schedule.py` with
//! pinned vectors, the same cross-language contract `trace::open` and
//! `shard_of` already carry.

use crate::util::Rng;

/// Salt xor-ed into the user seed so the fault stream never collides with
/// the trace-generator streams derived from the same seed (mirrored in
/// `python/tests/test_fault_schedule.py`).
pub const FAULT_STREAM_SALT: u64 = 0xFA17_0000_0001;

/// One lifecycle event. Instance indices refer to fleet slots: slots stay
/// addressable after death so a `Recover` can target them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultEvent {
    /// Kill `instance` now: running + queued requests requeue through the
    /// router, engine KV$ and shared-index presence are wiped.
    Crash { instance: usize },
    /// Stop routing to `instance`; it finishes its in-flight batch, then
    /// leaves the fleet. Queued-but-unstarted work requeues immediately;
    /// a batch still running `deadline_us` after the drain started is
    /// forcibly killed (counted as a drain-deadline violation).
    Drain { instance: usize, deadline_us: u64 },
    /// Bring a dead slot back into the routable set (cold KV$).
    Recover { instance: usize },
    /// Add an instance to the fleet: the lowest dead slot is reused,
    /// else the fleet widens by one. `cold_kv: false` pre-seeds the new
    /// instance's KV$ (and its shared-index presence) with recently
    /// completed prefix chains.
    ScaleUp { cold_kv: bool },
}

/// A [`FaultEvent`] pinned to a virtual time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlannedFault {
    pub at_us: u64,
    pub event: FaultEvent,
}

/// Parameters of a stochastic crash/recover schedule. Faults arrive as a
/// Poisson process at `crash_rate_per_s` over `[0, horizon_s]`; each picks
/// a uniform victim slot and an exponential downtime with mean `mttr_s`,
/// after which the victim recovers.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StochasticFaults {
    pub seed: u64,
    /// Fleet-wide crash arrival rate, crashes per virtual second.
    pub crash_rate_per_s: f64,
    /// Mean time to recover, seconds (exponential downtime).
    pub mttr_s: f64,
    /// No crash is scheduled past this virtual time.
    pub horizon_s: f64,
}

/// A deterministic schedule of lifecycle events. Construct scripted plans
/// with the builder methods, stochastic ones with [`FaultPlan::stochastic`]
/// (or combine both — `schedule()` merges them stably by time).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    events: Vec<PlannedFault>,
}

impl FaultPlan {
    pub fn new() -> Self {
        Self::default()
    }

    /// An empty plan injects nothing: the DES run is byte-identical to a
    /// plain `run_des` (asserted by `empty_fault_plan_is_byte_identical`).
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn at(mut self, at_us: u64, event: FaultEvent) -> Self {
        self.events.push(PlannedFault { at_us, event });
        self
    }

    pub fn crash_at(self, at_us: u64, instance: usize) -> Self {
        self.at(at_us, FaultEvent::Crash { instance })
    }

    pub fn recover_at(self, at_us: u64, instance: usize) -> Self {
        self.at(at_us, FaultEvent::Recover { instance })
    }

    pub fn drain_at(self, at_us: u64, instance: usize, deadline_us: u64) -> Self {
        self.at(at_us, FaultEvent::Drain { instance, deadline_us })
    }

    pub fn scale_up_at(self, at_us: u64, cold_kv: bool) -> Self {
        self.at(at_us, FaultEvent::ScaleUp { cold_kv })
    }

    /// Materialize a stochastic crash/recover schedule over an `n`-slot
    /// fleet and append it to this plan. Draw order per fault — gap,
    /// victim, downtime — is the cross-language contract; see the module
    /// docs.
    pub fn stochastic(mut self, spec: &StochasticFaults, n_instances: usize) -> Self {
        assert!(n_instances > 0, "stochastic faults need a non-empty fleet");
        assert!(spec.crash_rate_per_s > 0.0, "crash rate must be positive");
        let mut rng = Rng::new(spec.seed ^ FAULT_STREAM_SALT);
        let mut t_s = 0.0f64;
        loop {
            t_s += rng.exp(1.0 / spec.crash_rate_per_s);
            if t_s > spec.horizon_s {
                break;
            }
            let victim = (rng.next_u64() % n_instances as u64) as usize;
            let down_s = rng.exp(spec.mttr_s);
            let at_us = (t_s * 1e6) as u64;
            let up_us = ((t_s + down_s) * 1e6) as u64;
            self.events.push(PlannedFault {
                at_us,
                event: FaultEvent::Crash { instance: victim },
            });
            self.events.push(PlannedFault {
                at_us: up_us,
                event: FaultEvent::Recover { instance: victim },
            });
        }
        self
    }

    /// The plan's events, stably sorted by time (ties keep insertion
    /// order, so scripted sequences at the same instant fire as written).
    pub fn schedule(&self) -> Vec<PlannedFault> {
        let mut evs = self.events.clone();
        evs.sort_by_key(|e| e.at_us);
        evs
    }
}

/// Accounting for everything a fault plan displaced. Carried on
/// `RunMetrics::fault`; all-zero when no plan ran. The conservation
/// contract — offered == completed + shed + lost, zero silent drops — is
/// asserted over these in `cluster::des` tests.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounters {
    pub crashes: u64,
    pub drains: u64,
    pub recovers: u64,
    pub scale_ups: u64,
    /// Requests killed on a crashed (or force-drained) instance — both
    /// the running batch and the local queue.
    pub killed: u64,
    /// Killed or drain-displaced requests pushed back through the router.
    pub requeued: u64,
    /// Requeued requests that passed admission control again (equals
    /// `requeued` when no admission policy runs).
    pub re_admitted: u64,
    /// Requeued requests rejected by admission on re-entry, plus
    /// requests still parked at run end because the fleet finished with
    /// zero routable instances — the only ways fault injection may lose
    /// work, and both are *counted*, never silent.
    pub lost: u64,
    /// Drains whose batch outlived the deadline and was forcibly killed.
    pub drain_violations: u64,
    /// Completions sampled into the cold-start hit curve (first
    /// completions on a freshly recovered / scaled-up instance).
    pub cold_samples: u64,
}

/// What an [`Autoscaler`] sees each tick: the routable fleet and its
/// queue pressure, straight from the router's indicator snapshots.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FleetObs {
    pub now_us: u64,
    /// Routable (alive, not draining) instances.
    pub alive: usize,
    /// Total fleet slots, including dead and draining ones.
    pub slots: usize,
    /// Sum of batch sizes (running + waiting) over routable instances.
    pub total_queue_depth: u64,
    /// Deepest routable queue.
    pub max_queue_depth: u64,
    /// Smallest predicted prefill backlog (P-token) over routable
    /// instances — the same quantity `ttft_shed` thresholds on, so a
    /// TTFT-driven autoscaler and TTFT-driven shedding see one signal.
    pub min_p_token: u64,
}

impl FleetObs {
    /// Mean routable queue depth (0 on an empty fleet).
    pub fn mean_queue_depth(&self) -> f64 {
        if self.alive == 0 {
            0.0
        } else {
            self.total_queue_depth as f64 / self.alive as f64
        }
    }
}

/// One lifecycle action an autoscaler may request per tick.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleAction {
    Up { cold_kv: bool },
    /// Drain the least-loaded routable instance (deadline chosen by the
    /// harness).
    Down,
}

/// A reactive autoscaler: observes the fleet each tick and may emit one
/// lifecycle action. Implementations must bound themselves (min/max
/// fleet, hysteresis, cooldown) — the harness applies whatever they ask.
pub trait Autoscaler {
    fn name(&self) -> String;
    fn tick(&mut self, obs: &FleetObs) -> Option<ScaleAction>;
}

impl<T: Autoscaler + ?Sized> Autoscaler for &mut T {
    fn name(&self) -> String {
        (**self).name()
    }
    fn tick(&mut self, obs: &FleetObs) -> Option<ScaleAction> {
        (**self).tick(obs)
    }
}

/// Queue-depth-driven autoscaler with hysteresis: scale up when the mean
/// routable queue depth exceeds `up_depth`, down when it falls below
/// `down_depth` (strictly smaller — the gap is the hysteresis band), at
/// most one action per `cooldown_us`, holding the fleet in
/// `[min_instances, max_instances]`.
#[derive(Debug, Clone)]
pub struct QueueDepthAutoscaler {
    pub up_depth: f64,
    pub down_depth: f64,
    pub min_instances: usize,
    pub max_instances: usize,
    pub cooldown_us: u64,
    /// Scale-ups join warm (pre-seeded) when false.
    pub cold_kv: bool,
    last_action_us: Option<u64>,
}

impl QueueDepthAutoscaler {
    pub fn new(up_depth: f64, down_depth: f64, min_instances: usize, max_instances: usize) -> Self {
        assert!(
            down_depth < up_depth,
            "hysteresis requires down_depth < up_depth ({down_depth} >= {up_depth})"
        );
        assert!(min_instances >= 1 && min_instances <= max_instances);
        QueueDepthAutoscaler {
            up_depth,
            down_depth,
            min_instances,
            max_instances,
            cooldown_us: 5_000_000,
            cold_kv: true,
            last_action_us: None,
        }
    }

    pub fn with_cooldown(mut self, cooldown_us: u64) -> Self {
        self.cooldown_us = cooldown_us;
        self
    }

    pub fn with_cold_kv(mut self, cold_kv: bool) -> Self {
        self.cold_kv = cold_kv;
        self
    }
}

impl Autoscaler for QueueDepthAutoscaler {
    fn name(&self) -> String {
        "queue_depth_autoscaler".into()
    }

    fn tick(&mut self, obs: &FleetObs) -> Option<ScaleAction> {
        if let Some(last) = self.last_action_us {
            if obs.now_us.saturating_sub(last) < self.cooldown_us {
                return None;
            }
        }
        let mean = obs.mean_queue_depth();
        let action = if mean > self.up_depth && obs.alive < self.max_instances {
            Some(ScaleAction::Up { cold_kv: self.cold_kv })
        } else if mean < self.down_depth && obs.alive > self.min_instances {
            Some(ScaleAction::Down)
        } else {
            None
        };
        if action.is_some() {
            self.last_action_us = Some(obs.now_us);
        }
        action
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scripted_plan_schedules_stably_by_time() {
        let plan = FaultPlan::new()
            .crash_at(2_000_000, 1)
            .recover_at(5_000_000, 1)
            .drain_at(2_000_000, 0, 1_000_000)
            .scale_up_at(1_000_000, true);
        let sched = plan.schedule();
        assert_eq!(sched.len(), 4);
        assert_eq!(sched[0].event, FaultEvent::ScaleUp { cold_kv: true });
        // Equal times keep insertion order: crash(1) before drain(0).
        assert_eq!(sched[1].event, FaultEvent::Crash { instance: 1 });
        assert_eq!(
            sched[2].event,
            FaultEvent::Drain { instance: 0, deadline_us: 1_000_000 }
        );
        assert_eq!(sched[3].event, FaultEvent::Recover { instance: 1 });
        assert!(!plan.is_empty());
        assert!(FaultPlan::new().is_empty());
    }

    #[test]
    fn stochastic_schedule_is_deterministic_and_paired() {
        let spec = StochasticFaults {
            seed: 42,
            crash_rate_per_s: 0.5,
            mttr_s: 2.0,
            horizon_s: 60.0,
        };
        let a = FaultPlan::new().stochastic(&spec, 8);
        let b = FaultPlan::new().stochastic(&spec, 8);
        assert_eq!(a, b, "same seed + spec must materialize identically");
        assert!(!a.is_empty(), "60 s at 0.5 crashes/s should draw faults");
        assert_eq!(a.len() % 2, 0, "every crash pairs with a recover");
        let sched = a.schedule();
        // Each crash precedes its recover, and victims stay in range.
        let mut crashes = 0usize;
        for ev in &sched {
            match ev.event {
                FaultEvent::Crash { instance } | FaultEvent::Recover { instance } => {
                    assert!(instance < 8);
                    if matches!(ev.event, FaultEvent::Crash { .. }) {
                        crashes += 1;
                    }
                }
                other => panic!("stochastic plan emitted {other:?}"),
            }
        }
        assert_eq!(crashes * 2, sched.len());
    }

    /// Pinned draw-order vectors, mirrored bit-for-bit (victims) and to
    /// microsecond precision (times) by python/tests/test_fault_schedule.py.
    /// Regenerate there if the draw order ever changes — both sides must
    /// move together.
    #[test]
    fn stochastic_schedule_pinned_vectors() {
        let spec = StochasticFaults {
            seed: 7,
            crash_rate_per_s: 0.5,
            mttr_s: 2.0,
            horizon_s: 20.0,
        };
        let plan = FaultPlan::new().stochastic(&spec, 4);
        let got: Vec<(u64, FaultEvent)> =
            plan.events.iter().map(|e| (e.at_us, e.event)).collect();
        let expect: Vec<(u64, FaultEvent)> = vec![
            (3_442_216, FaultEvent::Crash { instance: 0 }),
            (4_400_384, FaultEvent::Recover { instance: 0 }),
            (7_711_887, FaultEvent::Crash { instance: 0 }),
            (12_539_258, FaultEvent::Recover { instance: 0 }),
            (12_344_711, FaultEvent::Crash { instance: 1 }),
            (14_690_203, FaultEvent::Recover { instance: 1 }),
            (13_327_903, FaultEvent::Crash { instance: 1 }),
            (19_559_700, FaultEvent::Recover { instance: 1 }),
            (13_750_216, FaultEvent::Crash { instance: 2 }),
            (14_427_176, FaultEvent::Recover { instance: 2 }),
            (18_130_748, FaultEvent::Crash { instance: 2 }),
            (19_110_199, FaultEvent::Recover { instance: 2 }),
            (18_570_346, FaultEvent::Crash { instance: 0 }),
            (20_814_182, FaultEvent::Recover { instance: 0 }),
            (19_028_795, FaultEvent::Crash { instance: 1 }),
            (19_287_625, FaultEvent::Recover { instance: 1 }),
            (19_029_345, FaultEvent::Crash { instance: 3 }),
            (22_406_048, FaultEvent::Recover { instance: 3 }),
            (19_760_284, FaultEvent::Crash { instance: 2 }),
            (28_459_929, FaultEvent::Recover { instance: 2 }),
        ];
        assert_eq!(got, expect);
    }

    #[test]
    fn autoscaler_hysteresis_bounds_and_cooldown() {
        let mut a = QueueDepthAutoscaler::new(8.0, 2.0, 1, 4).with_cooldown(1_000_000);
        let obs = |now_us, alive, total| FleetObs {
            now_us,
            alive,
            slots: alive,
            total_queue_depth: total,
            max_queue_depth: total,
            min_p_token: 0,
        };
        // Deep queues: scale up.
        assert_eq!(
            a.tick(&obs(0, 2, 40)),
            Some(ScaleAction::Up { cold_kv: true })
        );
        // Cooldown swallows the immediate follow-up.
        assert_eq!(a.tick(&obs(500_000, 2, 40)), None);
        // After cooldown, still deep: up again — until the max bound.
        assert_eq!(
            a.tick(&obs(1_500_000, 3, 60)),
            Some(ScaleAction::Up { cold_kv: true })
        );
        assert_eq!(a.tick(&obs(3_000_000, 4, 80)), None, "max bound holds");
        // Inside the hysteresis band (2 < mean < 8): no action.
        assert_eq!(a.tick(&obs(4_500_000, 4, 20)), None);
        // Idle fleet: scale down — until the min bound.
        assert_eq!(a.tick(&obs(6_000_000, 4, 0)), Some(ScaleAction::Down));
        assert_eq!(a.tick(&obs(8_000_000, 1, 0)), None, "min bound holds");
    }

    #[test]
    #[should_panic(expected = "hysteresis")]
    fn autoscaler_rejects_inverted_thresholds() {
        QueueDepthAutoscaler::new(2.0, 8.0, 1, 4);
    }
}
