//! Live threaded cluster: the end-to-end validation path. N instance
//! threads each run the REAL transformer (AOT artifacts via PJRT) with
//! chunked prefill, batched decode and a host-side cross-request KV$
//! (extract/inject of slot K/V planes); the main thread is the router,
//! running the *same* policy + indicator-factory code as the DES.
//!
//! Wall-clock time. Indicators still travel piggybacked on instance
//! events, so router staleness is physical, not simulated.

use std::collections::{HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::lifecycle::{FaultEvent, FaultPlan};
use crate::core::{Request, RequestRecord, BLOCK_TOKENS};
use crate::engine::queue::{self, QueueEntry, QueuePolicy};
use crate::engine::InstanceSnapshot;
use crate::metrics::RunMetrics;
use crate::router::{IndicatorFactory, Policy};
use crate::runtime::{ModelRuntime, Runtime, Tensor};
use crate::trace::Trace;
use crate::util::stats::Windowed;

#[derive(Debug, Clone)]
pub struct LiveClusterConfig {
    pub n_instances: usize,
    pub artifacts_dir: PathBuf,
    /// Host prefix-store entries per instance (the live KV$ capacity).
    pub prefix_store_entries: usize,
    /// Wall-clock speedup of trace arrival times (2.0 = replay 2× faster).
    pub time_scale: f64,
    /// Scripted lifecycle events, fired at `at_us / time_scale` of wall
    /// clock. The live harness implements: Crash wipes an engine and
    /// requeues its work, Drain stops routing and requeues the waiting
    /// queue (no deadline enforcement), Recover re-opens the slot, and
    /// ScaleUp spawns a fresh engine thread and widens the router's
    /// routable mask (always cold — live state transfer doesn't exist;
    /// `cold_kv` is ignored). Plans must leave at least one routable
    /// instance or displaced requests can never complete.
    pub faults: FaultPlan,
    /// Within-instance queue ordering (`engine::queue` name: fcfs /
    /// srpt / ltr) — the same registry the DES engine uses, so a policy
    /// validated there behaves identically on the live path.
    pub queue_policy: String,
}

impl Default for LiveClusterConfig {
    fn default() -> Self {
        LiveClusterConfig {
            n_instances: 2,
            artifacts_dir: crate::runtime::artifacts_dir(),
            prefix_store_entries: 64,
            time_scale: 1.0,
            faults: FaultPlan::new(),
            queue_policy: "fcfs".to_string(),
        }
    }
}

enum Cmd {
    Serve(Box<Request>),
    /// Wipe the engine — slots, waiting queue, prefix store. Every
    /// displaced request comes back as [`Ev::Displaced`] with
    /// `killed: true`.
    Crash,
    /// Stop starting new work: the waiting queue comes back displaced
    /// (`killed: false`), the running batch finishes normally.
    Drain,
    Shutdown,
}

enum Ev {
    FirstToken {
        #[allow(dead_code)]
        req_id: u64,
        #[allow(dead_code)]
        at_us: u64,
    },
    Completed { record: RequestRecord },
    /// A request a crash or drain threw back at the router.
    Displaced { req: Box<Request>, killed: bool },
    Snapshot(InstanceSnapshot),
    Fatal(String),
}

/// Host-side cross-request KV$. A finished request's slot K/V planes are
/// stored once (shared via `Rc`) and indexed under EVERY block depth of
/// its prompt chain, so a future request sharing only the first d blocks
/// (e.g. a different conversation of the same class, sharing the system
/// prompt) still hits at depth d. Chained hashes make each depth's hash
/// unique to the whole prefix. LRU-bounded by stored plane count.
struct PrefixStore {
    cap: usize,
    /// Block-unit capacity bound: `cap` planes × the most blocks one
    /// plane's prompt chain can index (max_seq / BLOCK_TOKENS). The
    /// snapshot reports this so live and DES instances agree on the
    /// `kv_capacity_blocks` indicator's unit.
    capacity_blocks: usize,
    /// block-hash -> (hit_tokens at this depth, plane id)
    index: HashMap<u64, (usize, u64)>,
    /// plane id -> (shared k/v, last_use, index keys)
    planes: HashMap<u64, (std::rc::Rc<(Tensor, Tensor)>, u64, Vec<u64>)>,
    next_id: u64,
    clock: u64,
}

impl PrefixStore {
    fn new(cap: usize, blocks_per_plane: usize) -> Self {
        PrefixStore {
            cap,
            capacity_blocks: cap * blocks_per_plane,
            index: HashMap::new(),
            planes: HashMap::new(),
            next_id: 0,
            clock: 0,
        }
    }

    /// Upper bound on [`Self::indexed_blocks`], in the same BLOCK unit.
    fn capacity_blocks(&self) -> usize {
        self.capacity_blocks
    }

    /// Distinct prompt *blocks* currently indexed — the same unit as the
    /// DES engine's `RadixTree::used_blocks()`, so `kv_used_blocks` means
    /// the same thing to a policy regardless of backend. (`planes.len()`
    /// counts stored K/V planes — whole prompts — a different unit
    /// entirely, which is what the snapshot used to report.)
    fn indexed_blocks(&self) -> usize {
        self.index.len()
    }

    /// Longest stored prefix of `hashes`: (hit_tokens, shared k/v).
    fn lookup(
        &mut self,
        hashes: &[u64],
    ) -> Option<(usize, std::rc::Rc<(Tensor, Tensor)>)> {
        self.clock += 1;
        for i in (0..hashes.len()).rev() {
            if let Some(&(len, plane_id)) = self.index.get(&hashes[i]) {
                if let Some(p) = self.planes.get_mut(&plane_id) {
                    p.1 = self.clock;
                    return Some((len, p.0.clone()));
                }
            }
        }
        None
    }

    /// Store planes for a prompt whose block-hash chain is `hashes`.
    fn insert(&mut self, hashes: &[u64], k: Tensor, v: Tensor) {
        if hashes.is_empty() {
            return;
        }
        self.clock += 1;
        // Evict the LRU plane (and its index keys) if at capacity.
        if self.planes.len() >= self.cap {
            if let Some((&old, _)) = self.planes.iter().min_by_key(|(_, p)| p.1) {
                if let Some((_, _, keys)) = self.planes.remove(&old) {
                    for key in keys {
                        if self.index.get(&key).map(|(_, id)| *id) == Some(old) {
                            self.index.remove(&key);
                        }
                    }
                }
            }
        }
        let id = self.next_id;
        self.next_id += 1;
        let rc = std::rc::Rc::new((k, v));
        let mut keys = Vec::with_capacity(hashes.len());
        for (i, h) in hashes.iter().enumerate() {
            self.index.insert(*h, ((i + 1) * BLOCK_TOKENS, id));
            keys.push(*h);
        }
        self.planes.insert(id, (rc, self.clock, keys));
    }
}

struct LiveSeq {
    req: Request,
    /// Tokens whose KV is in the slot (injected prefix + prefilled).
    pos: usize,
    cached_tokens: usize,
    generated: u32,
    last_token: i32,
    first_token_us: Option<u64>,
}

/// A waiting request plus the ordering facts the queue policy scores —
/// the live mirror of the DES engine's per-`Seq` queue fields.
struct LiveQueued {
    req: Request,
    predicted_work: u64,
    enqueued_progress: u64,
    promote_level: u32,
}

/// One instance thread's engine.
struct LiveEngine {
    rt: ModelRuntime,
    kv: Tensor,
    slots: Vec<Option<LiveSeq>>,
    waiting: VecDeque<LiveQueued>,
    store: PrefixStore,
    /// Within-instance admission ordering (same registry as the DES).
    queue: Box<dyn QueuePolicy>,
    /// Monotone progress clock for starvation accounting: total tokens
    /// this engine has processed (prefilled + decoded).
    progress: u64,
    entries_scratch: Vec<QueueEntry>,
}

impl LiveEngine {
    fn new(rt: ModelRuntime, store_cap: usize, queue_policy: &str) -> Self {
        let kv = rt.zero_kv();
        let slots = (0..rt.cfg.slots).map(|_| None).collect();
        // A stored plane indexes at most one block per BLOCK_TOKENS of
        // the model's max sequence — the per-instance block budget the
        // snapshot advertises to the router.
        let blocks_per_plane = rt.cfg.max_seq.div_ceil(BLOCK_TOKENS);
        let queue = queue::build(queue_policy).unwrap_or_else(|e| panic!("{e}"));
        LiveEngine {
            rt,
            kv,
            slots,
            waiting: VecDeque::new(),
            store: PrefixStore::new(store_cap, blocks_per_plane),
            queue,
            progress: 0,
            entries_scratch: Vec::new(),
        }
    }

    fn has_work(&self) -> bool {
        !self.waiting.is_empty() || self.slots.iter().any(|s| s.is_some())
    }

    /// Queue a request with its policy-scoring facts stamped, exactly as
    /// the DES engine's `enqueue` computes them.
    fn enqueue(&mut self, req: Request) {
        let predicted_work =
            req.input_len() as u64 + queue::predict_decode(req.id, req.output_len);
        self.waiting.push_back(LiveQueued {
            req,
            predicted_work,
            enqueued_progress: self.progress,
            promote_level: 0,
        });
    }

    /// Drain eviction: hand back everything not yet admitted to a slot.
    fn extract_waiting(&mut self) -> Vec<Request> {
        self.waiting.drain(..).map(|q| q.req).collect()
    }

    /// Crash: hand back ALL work (waiting + running) and wipe the KV
    /// buffer and prefix store — the machine's memory is gone.
    fn crash(&mut self) -> Vec<Request> {
        let mut out = self.extract_waiting();
        for s in self.slots.iter_mut() {
            if let Some(seq) = s.take() {
                out.push(seq.req);
            }
        }
        self.kv = self.rt.zero_kv();
        let blocks_per_plane = self.rt.cfg.max_seq.div_ceil(BLOCK_TOKENS);
        self.store = PrefixStore::new(self.store.cap, blocks_per_plane);
        out
    }

    fn snapshot(&self) -> InstanceSnapshot {
        let running: Vec<&LiveSeq> = self.slots.iter().flatten().collect();
        InstanceSnapshot {
            r_bs: running.len(),
            q_bs: self.waiting.len(),
            queued_prefill_tokens: self.waiting.iter().map(|q| q.req.input_len()).sum::<usize>()
                + running
                    .iter()
                    .map(|s| s.req.input_len().saturating_sub(s.pos))
                    .sum::<usize>(),
            total_context_tokens: running
                .iter()
                .map(|s| s.req.input_len() + s.generated as usize)
                .sum(),
            // BLOCK units, matching the DES engine's snapshot (the store
            // used to report its plane/entry count here, which silently
            // changed the indicator's unit across backends). The capacity
            // is the plane bound converted to blocks — the most blocks
            // `cap` planes can index — so memory-pressure policies see a
            // real, same-unit budget on both backends.
            kv_used_blocks: self.store.indexed_blocks(),
            kv_capacity_blocks: self.store.capacity_blocks(),
        }
    }

    fn admit(&mut self) -> Result<()> {
        while let Some(free) = self.slots.iter().position(|s| s.is_none()) {
            if self.waiting.is_empty() {
                break;
            }
            // Delegate the pick to the queue policy (fcfs selects index
            // 0, preserving the old pop_front path bit-for-bit); write
            // promotion levels back so LTR's credit persists across
            // admission rounds.
            self.entries_scratch.clear();
            self.entries_scratch.extend(self.waiting.iter().map(|q| QueueEntry {
                req_id: q.req.id,
                predicted_work: q.predicted_work,
                enqueued_progress: q.enqueued_progress,
                promote_level: q.promote_level,
            }));
            let mut entries = std::mem::take(&mut self.entries_scratch);
            let picked = self.queue.select(&mut entries, self.progress);
            for (q, e) in self.waiting.iter_mut().zip(&entries) {
                q.promote_level = e.promote_level;
            }
            self.entries_scratch = entries;
            let Some(idx) = picked else { break };
            let req = self
                .waiting
                .remove(idx)
                .map(|q| q.req)
                .expect("selected index in range");
            let mut pos = 0usize;
            let mut cached = 0usize;
            if let Some((len, planes)) = self.store.lookup(&req.block_hashes) {
                let hit = len.min(req.input_len().saturating_sub(1));
                if hit > 0 {
                    self.kv = self.rt.inject_slot(&self.kv, free, &planes.0, &planes.1)?;
                    pos = hit;
                    cached = hit;
                }
            }
            self.slots[free] = Some(LiveSeq {
                req,
                pos,
                cached_tokens: cached,
                generated: 0,
                last_token: 0,
                first_token_us: None,
            });
        }
        Ok(())
    }

    /// One engine iteration: admit + one prefill chunk + one batched
    /// decode pass. Returns events (timestamped by the caller's clock fn).
    fn step(&mut self, now_us: impl Fn() -> u64) -> Result<Vec<Ev>> {
        self.admit()?;
        let mut events = Vec::new();

        // --- chunked prefill: one chunk for the first slot needing it ---
        if let Some(si) = self
            .slots
            .iter()
            .position(|s| s.as_ref().map(|q| q.pos < q.req.input_len()).unwrap_or(false))
        {
            let (tokens_buf, pos, chunk_len, bucket) = {
                let seq = self.slots[si].as_ref().unwrap();
                let remaining = seq.req.input_len() - seq.pos;
                let bucket = self
                    .rt
                    .bucket_for(remaining.min(self.rt.largest_bucket()))
                    .ok_or_else(|| anyhow!("no bucket"))?;
                let chunk_len = remaining.min(bucket);
                let mut buf: Vec<i32> = seq.req.tokens[seq.pos..seq.pos + chunk_len]
                    .iter()
                    .map(|t| *t as i32)
                    .collect();
                buf.resize(bucket, 0);
                (buf, seq.pos, chunk_len, bucket)
            };
            debug_assert_eq!(tokens_buf.len(), bucket);
            let (logits, kv_new) =
                self.rt
                    .prefill_chunk(&self.kv, &tokens_buf, si, pos, chunk_len)?;
            self.kv = kv_new;
            let seq = self.slots[si].as_mut().unwrap();
            seq.pos += chunk_len;
            self.progress += chunk_len as u64;
            if seq.pos >= seq.req.input_len() {
                // Prefill complete: first token now.
                seq.last_token = ModelRuntime::argmax(&logits);
                seq.generated = 1;
                let t = now_us();
                seq.first_token_us = Some(t);
                events.push(Ev::FirstToken {
                    req_id: seq.req.id,
                    at_us: t,
                });
            }
        }

        // --- batched decode over all decoding slots ---------------------
        let decoding: Vec<usize> = self
            .slots
            .iter()
            .enumerate()
            .filter(|(_, s)| {
                s.as_ref()
                    .map(|q| q.generated >= 1 && q.generated < q.req.output_len.max(1))
                    .unwrap_or(false)
            })
            .map(|(i, _)| i)
            .collect();
        if !decoding.is_empty() {
            let n_slots = self.rt.cfg.slots;
            let mut tokens = vec![0i32; n_slots];
            let mut lens = vec![0i32; n_slots];
            for &i in &decoding {
                let s = self.slots[i].as_ref().unwrap();
                tokens[i] = s.last_token;
                // KV length before this token: prompt + already-written
                // decode tokens (generated-1; the latest sampled token's
                // KV is written by THIS call).
                lens[i] = (s.req.input_len() + s.generated as usize - 1) as i32;
            }
            let (logits, kv_new) = self.rt.decode_step(&self.kv, &tokens, &lens)?;
            self.kv = kv_new;
            let vocab = self.rt.cfg.vocab;
            for &i in &decoding {
                let s = self.slots[i].as_mut().unwrap();
                s.last_token = ModelRuntime::argmax(&logits[i * vocab..(i + 1) * vocab]);
                s.generated += 1;
            }
            self.progress += decoding.len() as u64;
        }

        // --- completions ------------------------------------------------
        for i in 0..self.slots.len() {
            let done = self.slots[i]
                .as_ref()
                .map(|s| s.pos >= s.req.input_len() && s.generated >= s.req.output_len.max(1))
                .unwrap_or(false);
            if done {
                let seq = self.slots[i].take().unwrap();
                // Snapshot the slot's KV for future prefix hits.
                let prompt_blocks = seq.req.block_hashes.len();
                if prompt_blocks > 0 {
                    let (k, v) = self.rt.extract_slot(&self.kv, i)?;
                    self.store.insert(&seq.req.block_hashes, k, v);
                }
                let t = now_us();
                events.push(Ev::Completed {
                    record: RequestRecord {
                        id: seq.req.id,
                        class_id: seq.req.class_id,
                        instance: 0, // filled by the router thread
                        arrival_us: seq.req.arrival_us,
                        first_token_us: seq.first_token_us.unwrap_or(t),
                        completion_us: t,
                        input_len: seq.req.input_len() as u32,
                        output_len: seq.req.output_len.max(1),
                        cached_tokens: seq.cached_tokens as u32,
                    },
                });
            }
        }
        Ok(events)
    }
}

fn instance_thread(
    idx: usize,
    cfg: LiveClusterConfig,
    epoch: Instant,
    rx: mpsc::Receiver<Cmd>,
    tx: mpsc::Sender<(usize, Ev)>,
) {
    let rt = match ModelRuntime::load(&cfg.artifacts_dir) {
        Ok(rt) => rt,
        Err(e) => {
            let _ = tx.send((idx, Ev::Fatal(format!("instance {idx}: {e:#}"))));
            return;
        }
    };
    let mut eng = LiveEngine::new(rt, cfg.prefix_store_entries, &cfg.queue_policy);
    let now_us = move || epoch.elapsed().as_micros() as u64;
    let mut shutdown = false;
    loop {
        // Drain the command queue (non-blocking when busy).
        loop {
            match if eng.has_work() || shutdown {
                rx.try_recv().map_err(|_| ())
            } else {
                rx.recv_timeout(Duration::from_millis(2)).map_err(|_| ())
            } {
                Ok(Cmd::Serve(req)) => eng.enqueue(*req),
                Ok(Cmd::Crash) => {
                    for r in eng.crash() {
                        let _ = tx.send((idx, Ev::Displaced { req: Box::new(r), killed: true }));
                    }
                    let _ = tx.send((idx, Ev::Snapshot(eng.snapshot())));
                }
                Ok(Cmd::Drain) => {
                    for r in eng.extract_waiting() {
                        let _ = tx.send((idx, Ev::Displaced { req: Box::new(r), killed: false }));
                    }
                    let _ = tx.send((idx, Ev::Snapshot(eng.snapshot())));
                }
                Ok(Cmd::Shutdown) => shutdown = true,
                Err(()) => break,
            }
        }
        if !eng.has_work() {
            if shutdown {
                break;
            }
            continue;
        }
        match eng.step(&now_us) {
            Ok(events) => {
                for e in events {
                    let _ = tx.send((idx, e));
                }
                let _ = tx.send((idx, Ev::Snapshot(eng.snapshot())));
            }
            Err(e) => {
                let _ = tx.send((idx, Ev::Fatal(format!("instance {idx}: {e:#}"))));
                return;
            }
        }
    }
}

/// Replay `trace` through a live cluster under `policy`. Returns wall-
/// clock metrics. Prompts must fit the artifact model (vocab/max_seq).
pub fn run_live(
    cfg: &LiveClusterConfig,
    trace: &Trace,
    policy: &mut dyn Policy,
) -> Result<RunMetrics> {
    let mut n = cfg.n_instances;
    // Guard counters accumulate over the policy's lifetime; report this
    // run's delta.
    let guard_start = policy.guard_counters().unwrap_or_default();
    let epoch = Instant::now();
    // `ev_tx` stays alive for the whole run: a scheduled ScaleUp needs
    // it to wire up engine threads spawned mid-run. Instance threads
    // exit on Cmd::Shutdown, so channel disconnect is not the loop's
    // termination signal anyway (completion counting is).
    let (ev_tx, ev_rx) = mpsc::channel::<(usize, Ev)>();
    let mut cmd_txs = Vec::new();
    let mut handles = Vec::new();
    for i in 0..n {
        let (tx, rx) = mpsc::channel::<Cmd>();
        cmd_txs.push(tx);
        let c = cfg.clone();
        let etx = ev_tx.clone();
        handles.push(std::thread::spawn(move || instance_thread(i, c, epoch, rx, etx)));
    }

    // Router-side index stays unbounded (capacity 0): the per-instance
    // block budget reaches policies through the snapshot piggyback
    // (`kv_capacity_blocks` above), while the router's optimistic view
    // tracks presence only — mirroring production, where the router
    // cannot evict instance memory.
    let mut factory = IndicatorFactory::new(n, 0);
    let mut metrics = RunMetrics::new(n);
    let mut full_hashes: HashMap<u64, Arc<[u64]>> = HashMap::new();
    let mut completed = 0usize;
    let total = trace.requests.len();
    // Scripted lifecycle events, fired by wall clock (scaled like
    // arrivals); displaced requests buffer here until re-routed, parked
    // while zero instances are routable.
    let schedule = cfg.faults.schedule();
    let mut next_fault = 0usize;
    let mut displaced: Vec<Request> = Vec::new();
    let mut parked: Vec<Request> = Vec::new();

    let absorb = |ev: (usize, Ev),
                      factory: &mut IndicatorFactory,
                      metrics: &mut RunMetrics,
                      full_hashes: &mut HashMap<u64, Arc<[u64]>>,
                      completed: &mut usize,
                      displaced: &mut Vec<Request>|
     -> Result<()> {
        let (i, ev) = ev;
        match ev {
            Ev::Snapshot(s) => factory.on_snapshot(i, s),
            Ev::FirstToken { .. } => {}
            Ev::Completed { mut record } => {
                record.instance = i;
                if let Some(fh) = full_hashes.remove(&record.id) {
                    factory.on_completion(i, &fh, record.completion_us);
                }
                metrics.records.push(record);
                *completed += 1;
            }
            Ev::Displaced { req, killed } => {
                metrics.fault.requeued += 1;
                if killed {
                    metrics.fault.killed += 1;
                }
                displaced.push(*req);
            }
            Ev::Fatal(msg) => return Err(anyhow!(msg)),
        }
        Ok(())
    };

    // Fire every fault whose (scaled) time has passed. Mirrors the DES
    // handlers; see `LiveClusterConfig::faults` for the supported subset.
    macro_rules! fire_due_faults {
        () => {{
            let now = epoch.elapsed().as_micros() as u64;
            while next_fault < schedule.len()
                && (schedule[next_fault].at_us as f64 / cfg.time_scale) as u64 <= now
            {
                match schedule[next_fault].event {
                    FaultEvent::Crash { instance }
                        if instance < n && factory.is_routable(instance) =>
                    {
                        metrics.fault.crashes += 1;
                        factory.set_routable(instance, false);
                        factory.purge_instance(instance);
                        cmd_txs[instance].send(Cmd::Crash).map_err(|e| anyhow!("send: {e}"))?;
                    }
                    FaultEvent::Drain { instance, .. }
                        if instance < n && factory.is_routable(instance) =>
                    {
                        metrics.fault.drains += 1;
                        factory.set_routable(instance, false);
                        cmd_txs[instance].send(Cmd::Drain).map_err(|e| anyhow!("send: {e}"))?;
                    }
                    FaultEvent::Recover { instance }
                        if instance < n && !factory.is_routable(instance) =>
                    {
                        metrics.fault.recovers += 1;
                        factory.set_routable(instance, true);
                        displaced.append(&mut parked);
                    }
                    FaultEvent::ScaleUp { .. } => {
                        // Always cold: live engines can't ship KV planes
                        // to a machine that is still booting.
                        metrics.fault.scale_ups += 1;
                        let i = cmd_txs.len();
                        let (tx, rx) = mpsc::channel::<Cmd>();
                        cmd_txs.push(tx);
                        let c = cfg.clone();
                        let etx = ev_tx.clone();
                        handles.push(std::thread::spawn(move || {
                            instance_thread(i, c, epoch, rx, etx)
                        }));
                        n = cmd_txs.len();
                        factory.resize_instances(n);
                        metrics.prefill_time.push(Windowed::new(10_000_000));
                        metrics.batch_size.push(Windowed::new(1_000_000));
                        // The wider fleet can absorb anything parked
                        // while zero instances were routable.
                        displaced.append(&mut parked);
                    }
                    // Same-state races (e.g. crashing a dead slot) no-op.
                    _ => {}
                }
                next_fault += 1;
            }
        }};
    }

    // Re-route everything a fault displaced. Original `arrival_us` is
    // kept, so TTFT charges the whole displacement.
    macro_rules! reroute_displaced {
        () => {{
            for req in displaced.drain(..) {
                let now = epoch.elapsed().as_micros() as u64;
                let ctx = factory.route_ctx(&req, now);
                let mut d = policy.route(ctx).instance;
                if d >= n || !factory.is_routable(d) {
                    match (0..n).find(|&i| factory.is_routable(i)) {
                        Some(i) => d = i,
                        None => {
                            parked.push(req);
                            continue;
                        }
                    }
                }
                metrics.fault.re_admitted += 1;
                factory.on_route(d, &req, now);
                cmd_txs[d]
                    .send(Cmd::Serve(Box::new(req)))
                    .map_err(|e| anyhow!("send: {e}"))?;
            }
        }};
    }

    // Paced arrival loop.
    for tr in &trace.requests {
        let due_us = (tr.req.arrival_us as f64 / cfg.time_scale) as u64;
        loop {
            fire_due_faults!();
            reroute_displaced!();
            let now = epoch.elapsed().as_micros() as u64;
            if now >= due_us {
                break;
            }
            match ev_rx.recv_timeout(Duration::from_micros((due_us - now).min(2000))) {
                Ok(ev) => absorb(
                    ev,
                    &mut factory,
                    &mut metrics,
                    &mut full_hashes,
                    &mut completed,
                    &mut displaced,
                )?,
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(e) => return Err(anyhow!("event channel: {e}")),
            }
        }
        let now = epoch.elapsed().as_micros() as u64;
        let mut req = tr.req.clone();
        req.arrival_us = now; // wall-clock arrival
        let ctx = factory.route_ctx(&req, now);
        let t0 = Instant::now();
        let mut d = policy.route(ctx).instance;
        metrics
            .sched_overhead_us
            .push(t0.elapsed().as_nanos() as f64 / 1000.0);
        if d >= n || !factory.is_routable(d) {
            // The policy routed into a dead slot; fall back to any
            // routable instance (plans must leave one — see config docs).
            d = (0..n)
                .find(|&i| factory.is_routable(i))
                .ok_or_else(|| anyhow!("no routable instance for arrival {}", req.id))?;
        }
        factory.on_route(d, &req, now);
        full_hashes.insert(req.id, tr.full_hashes.clone());
        cmd_txs[d]
            .send(Cmd::Serve(Box::new(req)))
            .map_err(|e| anyhow!("send: {e}"))?;
    }

    // Drain completions. While faults are still pending, poll on a short
    // timeout so a scheduled Recover fires even when no events flow.
    while completed < total {
        fire_due_faults!();
        reroute_displaced!();
        let wait = if next_fault < schedule.len() {
            Duration::from_millis(2)
        } else {
            Duration::from_secs(120)
        };
        match ev_rx.recv_timeout(wait) {
            Ok(ev) => absorb(
                ev,
                &mut factory,
                &mut metrics,
                &mut full_hashes,
                &mut completed,
                &mut displaced,
            )?,
            Err(mpsc::RecvTimeoutError::Timeout) if next_fault < schedule.len() => {}
            Err(e) => return Err(anyhow!("timed out waiting for completions: {e}")),
        }
    }
    for tx in &cmd_txs {
        let _ = tx.send(Cmd::Shutdown);
    }
    for h in handles {
        let _ = h.join();
    }
    metrics.duration_us = epoch.elapsed().as_micros() as u64;
    metrics.records.sort_by_key(|r| r.id);
    metrics.guard = policy.guard_counters().unwrap_or_default().since(guard_start);
    Ok(metrics)
}

// Sim-backend only: the tests construct `SimTensor` planes directly and
// load the runtime without artifacts.
#[cfg(all(test, not(feature = "pjrt")))]
mod tests {
    use super::*;

    /// The PR 2 follow-up: live snapshots must advertise a REAL block
    /// budget (plane bound × blocks per plane), not the placeholder 0,
    /// and the store can never index past it.
    #[test]
    fn prefix_store_reports_block_capacity_and_stays_within_it() {
        let blocks_per_plane = 512usize.div_ceil(BLOCK_TOKENS); // sim max_seq
        let mut store = PrefixStore::new(3, blocks_per_plane);
        assert_eq!(store.capacity_blocks(), 3 * 32);
        assert!(store.capacity_blocks() > 0, "budget must be real, not 0");
        // Churn more prompts than the plane bound through the store; LRU
        // eviction keeps the indexed block count within the budget.
        for p in 0..10u64 {
            let hashes: Vec<u64> = (0..blocks_per_plane as u64).map(|b| p * 1000 + b).collect();
            store.insert(&hashes, Tensor::Plane(Vec::new()), Tensor::Plane(Vec::new()));
            assert!(
                store.indexed_blocks() <= store.capacity_blocks(),
                "indexed {} blocks over budget {}",
                store.indexed_blocks(),
                store.capacity_blocks()
            );
        }
        assert_eq!(store.planes.len(), 3, "LRU bound in planes");
        assert_eq!(store.indexed_blocks(), 3 * blocks_per_plane);
    }

    /// Crash semantics on the live engine: every queued request comes
    /// back (nothing silently dropped), and the machine's cache state —
    /// prefix store and KV buffer — is wiped like a real reboot.
    #[test]
    fn live_engine_crash_returns_all_work_and_wipes_cache() {
        let rt = ModelRuntime::load(std::path::Path::new("/nonexistent_lmetric_artifacts"))
            .expect("sim runtime needs no artifacts");
        let mut eng = LiveEngine::new(rt, 8, "fcfs");
        for id in 0..3u64 {
            eng.enqueue(Request {
                id,
                arrival_us: 0,
                class_id: 0,
                session_id: 0,
                model_id: 0,
                tokens: Arc::from(vec![1u32; 32].into_boxed_slice()),
                output_len: 4,
                block_hashes: Arc::from(vec![id + 1].into_boxed_slice()),
            });
        }
        eng.store
            .insert(&[99], Tensor::Plane(Vec::new()), Tensor::Plane(Vec::new()));
        assert!(eng.store.indexed_blocks() > 0);
        let out = eng.crash();
        let mut ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, vec![0, 1, 2], "crash must hand back every request");
        assert!(!eng.has_work());
        assert_eq!(eng.store.indexed_blocks(), 0, "prefix store survives a crash");
    }

    /// Live and DES engines must score waiting requests identically:
    /// the stamped `predicted_work` is input length plus the shared
    /// deterministic decode predictor (pinned vector: id 42, output 32
    /// → 34 predicted decode tokens).
    #[test]
    fn live_enqueue_stamps_the_shared_predictor() {
        let rt = ModelRuntime::load(std::path::Path::new("/nonexistent_lmetric_artifacts"))
            .expect("sim runtime needs no artifacts");
        let mut eng = LiveEngine::new(rt, 8, "srpt");
        eng.enqueue(Request {
            id: 42,
            arrival_us: 0,
            class_id: 0,
            session_id: 0,
            model_id: 0,
            tokens: Arc::from(vec![1u32; 32].into_boxed_slice()),
            output_len: 32,
            block_hashes: Arc::from(vec![7u64].into_boxed_slice()),
        });
        let q = eng.waiting.front().unwrap();
        assert_eq!(q.predicted_work, 32 + queue::predict_decode(42, 32));
        assert_eq!(queue::predict_decode(42, 32), 34, "pinned predictor vector");
        assert_eq!(q.enqueued_progress, 0);
        assert_eq!(eng.queue.name(), "srpt");
    }

    /// The engine derives the same budget from the model config that the
    /// store enforces, so `snapshot().kv_capacity_blocks` is consistent
    /// with DES semantics (used ≤ capacity, same BLOCK unit).
    #[test]
    fn live_engine_snapshot_capacity_matches_model_config() {
        // No manifest at this path -> the sim backend's default geometry.
        let rt = ModelRuntime::load(std::path::Path::new("/nonexistent_lmetric_artifacts"))
            .expect("sim runtime needs no artifacts");
        let max_seq = rt.config().max_seq;
        let eng = LiveEngine::new(rt, 64, "fcfs");
        let snap = eng.snapshot();
        assert_eq!(
            snap.kv_capacity_blocks,
            64 * max_seq.div_ceil(BLOCK_TOKENS)
        );
        assert!(snap.kv_used_blocks <= snap.kv_capacity_blocks);
    }
}
