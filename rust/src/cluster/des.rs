//! Discrete-event simulation of a router + N instances in virtual time.
//!
//! Event semantics mirror the live system: an arrival is routed
//! immediately (the router is far faster than the instances — §3); an
//! instance runs step-by-step, each step's outcome (first tokens,
//! completions, the indicator snapshot piggyback) materializing at the
//! step's *end*. Requests arriving mid-step wait for the next step
//! boundary, exactly like continuous batching on real engines.
//!
//! Two release modes share one event core ([`run_des_core`]):
//!
//! * **open-loop** ([`run_des`]) — every request's arrival is fixed by
//!   the trace (the classic replay every figure bench uses);
//! * **closed-loop** ([`run_session_des`]) — only each session's first
//!   turn is pre-scheduled; turn `k+1` is *released at turn `k`'s
//!   completion + think time*, so a congested cluster automatically
//!   delays the rest of the conversation, exactly like a real client
//!   that cannot send a follow-up before it has received the answer.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, HashSet};
use std::sync::Arc;
use std::time::Instant;

use super::lifecycle::{Autoscaler, FaultEvent, FaultPlan, FleetObs, PlannedFault, ScaleAction};
use super::overload::AdmissionPolicy;
use crate::config::{ExperimentConfig, FleetSpec};
use crate::engine::{
    EngineConfig, EngineEvent, Instance, InstanceProfile, ModelProfile, StepOutcome,
};
use crate::metrics::{QueueCounters, RunMetrics, SloSpec};
use crate::router::{IndicatorFactory, Policy};
use crate::trace::{
    generate, generate_open, generate_sessions, OpenSpec, SessionSpec, SessionTrace, Trace,
    Workload, WorkloadSpec,
};
use crate::util::stats::Windowed;

#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub n_instances: usize,
    pub engine: EngineConfig,
    /// Hardware composition of the fleet. [`ClusterConfig::new`] keeps
    /// the historical uniform-reference shape; [`with_fleet`]
    /// (`ClusterConfig::with_fleet`) opts a run into heterogeneity.
    pub fleet: FleetSpec,
}

impl ClusterConfig {
    pub fn new(n_instances: usize, engine: EngineConfig) -> Self {
        ClusterConfig {
            n_instances,
            engine,
            fleet: FleetSpec::uniform(n_instances),
        }
    }

    /// Replace the fleet composition; `n_instances` follows the spec.
    pub fn with_fleet(mut self, fleet: FleetSpec) -> Self {
        self.n_instances = fleet.n_instances();
        self.fleet = fleet;
        self
    }

    /// The engine configuration for instance slot `i`: the base engine
    /// with the slot's [`InstanceProfile`] applied (and its KV capacity
    /// override, when the class declares one). Reference slots return
    /// the base config untouched, so uniform fleets stay bit-identical
    /// to the pre-fleet code path.
    pub fn engine_for(&self, i: usize) -> EngineConfig {
        let profile = self.fleet.profile_for(i);
        if profile.is_reference() {
            return self.engine.clone();
        }
        let mut e = self.engine.clone();
        if let Some(kv) = profile.kv_capacity_blocks {
            e.kv_capacity_blocks = kv;
        }
        e.instance = profile.clone();
        e
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Event {
    Arrival(usize),
    StepEnd(usize),
    /// Re-present a displaced request (crash/drain eviction) to the
    /// router at the event's time; its `arrival_us` stays the original,
    /// so TTFT keeps charging the whole displacement.
    Requeue(usize),
    /// Fire `schedule[k]` of the run's fault plan.
    Fault(usize),
    /// Forced end of a drain: if the instance is still draining, its
    /// leftover batch is killed, requeued, and counted as a violation.
    DrainDeadline(usize),
    /// Periodic autoscaler observation.
    AutoscaleTick,
}

/// Reactive follow-up edge: when the request at the owning index
/// completes, the request at `next` is released `think_us` later (its
/// `arrival_us` is stamped at release).
#[derive(Debug, Clone, Copy)]
struct Followup {
    next: usize,
    think_us: u64,
}

/// What a [`RunSpec`] replays: a flat open-loop [`Trace`] or a
/// multi-turn [`SessionTrace`].
pub enum Source<'a> {
    Trace(&'a Trace),
    Sessions(&'a SessionTrace),
}

/// How follow-up turns are released. [`Release::OpenLoop`] pre-schedules
/// every arrival at its stamped time; [`Release::Reactive`] releases turn
/// `k+1` at turn `k`'s completion + think time. A flat [`Source::Trace`]
/// has no follow-up edges, so the two modes coincide there.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Release {
    OpenLoop,
    Reactive,
}

/// The unified run description: one entry point ([`run`]) for every
/// combination the harness supports — open- or closed-loop release,
/// optional admission control, optional SLO annotation for goodput
/// accounting. [`run_des`] and [`run_session_des`] are thin wrappers over
/// this.
pub struct RunSpec<'a> {
    pub cluster: &'a ClusterConfig,
    pub source: Source<'a>,
    pub release: Release,
    /// Non-`'static` so a bench can lend `Box::new(&mut probe)` and read
    /// the probe's peak counters back after the run.
    pub admission: Option<Box<dyn AdmissionPolicy + 'a>>,
    pub slo: Option<SloSpec>,
    /// Lifecycle fault schedule. An empty plan injects nothing and the
    /// run is byte-identical to one without it (asserted in tests).
    pub faults: FaultPlan,
    /// Reactive autoscaler, observing the fleet every `.1` µs of virtual
    /// time. Non-`'static` for the same lend-and-inspect reason as
    /// `admission`.
    pub autoscaler: Option<(Box<dyn Autoscaler + 'a>, u64)>,
    /// Within-instance queue-policy override (`engine::queue` name). When
    /// set, every instance is built with this ordering instead of the
    /// cluster config's; `None` leaves the config untouched, so existing
    /// specs replay byte-identically.
    pub queue_policy: Option<String>,
}

impl<'a> RunSpec<'a> {
    /// Open-loop replay of a flat trace — what [`run_des`] does.
    pub fn open_loop(cluster: &'a ClusterConfig, trace: &'a Trace) -> RunSpec<'a> {
        RunSpec {
            cluster,
            source: Source::Trace(trace),
            release: Release::OpenLoop,
            admission: None,
            slo: None,
            faults: FaultPlan::new(),
            autoscaler: None,
            queue_policy: None,
        }
    }

    /// Reactive replay of a session trace — what [`run_session_des`]
    /// does. Switch to open-loop release with [`RunSpec::with_release`].
    pub fn sessions(cluster: &'a ClusterConfig, strace: &'a SessionTrace) -> RunSpec<'a> {
        RunSpec {
            cluster,
            source: Source::Sessions(strace),
            release: Release::Reactive,
            admission: None,
            slo: None,
            faults: FaultPlan::new(),
            autoscaler: None,
            queue_policy: None,
        }
    }

    pub fn with_release(mut self, release: Release) -> RunSpec<'a> {
        self.release = release;
        self
    }

    pub fn with_admission(mut self, admission: Box<dyn AdmissionPolicy + 'a>) -> RunSpec<'a> {
        self.admission = Some(admission);
        self
    }

    pub fn with_slo(mut self, slo: SloSpec) -> RunSpec<'a> {
        self.slo = Some(slo);
        self
    }

    /// Inject a lifecycle fault schedule into the run.
    pub fn with_faults(mut self, faults: FaultPlan) -> RunSpec<'a> {
        self.faults = faults;
        self
    }

    /// Close the loop: observe the fleet every `interval_us` of virtual
    /// time and apply the autoscaler's scale/drain decisions as lifecycle
    /// events.
    pub fn with_autoscaler(
        mut self,
        autoscaler: Box<dyn Autoscaler + 'a>,
        interval_us: u64,
    ) -> RunSpec<'a> {
        self.autoscaler = Some((autoscaler, interval_us));
        self
    }

    /// Override the within-instance queue ordering for this run
    /// (`engine::queue` name: fcfs / srpt / ltr). Unknown names panic at
    /// instance construction — validate early with
    /// [`crate::engine::queue::build`] where the name is user input.
    pub fn with_queue_policy(mut self, name: &str) -> RunSpec<'a> {
        self.queue_policy = Some(name.to_string());
        self
    }
}

/// Run a [`RunSpec`] under `policy` — the single entry point the CLI,
/// benches and tests share. Without admission or SLO the trajectory is
/// byte-identical to the legacy wrappers ([`run_des`],
/// [`run_session_des`]); with them, shed/goodput accounting lands in
/// [`RunMetrics::overload`](crate::metrics::OverloadCounters) and
/// [`RunMetrics::slo`].
pub fn run(spec: RunSpec<'_>, policy: &mut dyn Policy) -> RunMetrics {
    let RunSpec {
        cluster,
        source,
        release,
        mut admission,
        slo,
        faults,
        mut autoscaler,
        queue_policy,
    } = spec;
    // A queue-policy override rebuilds the cluster config once up front;
    // without one the borrowed config is used as-is (no clone, no drift).
    let owned_cluster: ClusterConfig;
    let cluster = match queue_policy {
        Some(name) => {
            let mut c = cluster.clone();
            c.engine.queue_policy = name;
            owned_cluster = c;
            &owned_cluster
        }
        None => cluster,
    };
    let adm = admission.as_deref_mut();
    let schedule = faults.schedule();
    let auto = autoscaler
        .as_mut()
        .map(|(a, iv)| (a.as_mut() as &mut dyn Autoscaler, *iv));
    let mut m = match (source, release) {
        (Source::Trace(trace), _) => {
            // Cloning the request vector is refcount bumps (token/hash
            // storage is `Arc`-shared), not data copies; it lets the
            // reactive core own its requests so closed-loop runs can
            // stamp release times in place.
            let reqs = trace.requests.to_vec();
            let initial: Vec<usize> = (0..reqs.len()).collect();
            run_des_core(cluster, reqs, &initial, &[], policy, adm, &schedule, auto)
        }
        (Source::Sessions(strace), Release::OpenLoop) => {
            let flat = strace.flatten();
            let initial: Vec<usize> = (0..flat.requests.len()).collect();
            run_des_core(cluster, flat.requests, &initial, &[], policy, adm, &schedule, auto)
        }
        (Source::Sessions(strace), Release::Reactive) => {
            let (reqs, initial, followups) = session_schedule(strace);
            run_des_core(cluster, reqs, &initial, &followups, policy, adm, &schedule, auto)
        }
    };
    m.admission_name = admission.map(|a| a.name());
    m.slo = slo;
    m
}

/// Run `trace` through the cluster under `policy`. Virtual time; returns
/// the full metrics bundle. Open-loop: every arrival is pre-scheduled.
///
/// Legacy wrapper for `run(RunSpec::open_loop(cfg, trace), policy)` —
/// prefer [`run`], which also carries admission control and SLO specs.
pub fn run_des(cfg: &ClusterConfig, trace: &Trace, policy: &mut dyn Policy) -> RunMetrics {
    run(RunSpec::open_loop(cfg, trace), policy)
}

/// Run a closed-loop [`SessionTrace`]: each session's first turn arrives
/// at its scheduled time; every later turn is released at the previous
/// turn's completion + its pre-sampled think time. Join the returned
/// records back to sessions with
/// [`SessionMetrics::collect`](crate::metrics::SessionMetrics::collect).
///
/// Legacy wrapper for `run(RunSpec::sessions(cfg, strace), policy)` —
/// prefer [`run`], which also carries admission control and SLO specs.
pub fn run_session_des(
    cfg: &ClusterConfig,
    strace: &SessionTrace,
    policy: &mut dyn Policy,
) -> RunMetrics {
    run(RunSpec::sessions(cfg, strace), policy)
}

/// Lower a session trace to the core's request table: the flattened
/// request vector, the initial release set (first turns, in (time, id)
/// order — the same push order the open-loop path uses on a flattened
/// trace, so a single-turn session trace replays byte-identically to its
/// open-loop equivalent), and the reactive follow-up edges.
#[allow(clippy::type_complexity)]
fn session_schedule(
    strace: &SessionTrace,
) -> (Vec<crate::trace::TraceRequest>, Vec<usize>, Vec<Option<Followup>>) {
    let n_turns = strace.n_turns();
    let mut reqs: Vec<crate::trace::TraceRequest> = Vec::with_capacity(n_turns);
    let mut followups: Vec<Option<Followup>> = vec![None; n_turns];
    let mut initial: Vec<(u64, u64, usize)> = Vec::with_capacity(strace.sessions.len());
    for s in &strace.sessions {
        let base = reqs.len();
        for (ti, t) in s.turns.iter().enumerate() {
            reqs.push(crate::trace::TraceRequest {
                req: t.req.clone(),
                full_hashes: t.full_hashes.clone(),
            });
            if ti + 1 < s.turns.len() {
                followups[base + ti] = Some(Followup {
                    next: base + ti + 1,
                    think_us: s.turns[ti + 1].think_us,
                });
            }
        }
        if !s.turns.is_empty() {
            initial.push((s.start_us, reqs[base].req.id, base));
        }
    }
    initial.sort_by_key(|&(at, id, _)| (at, id));
    let initial: Vec<usize> = initial.into_iter().map(|(_, _, i)| i).collect();
    (reqs, initial, followups)
}

/// Completions sampled into the cold-start hit curve per (re)joined
/// instance — enough to see the warm-up knee without letting one noisy
/// recovery dominate [`RunMetrics::cold_hit_samples`].
const COLD_HIT_WINDOW: u32 = 32;

/// Distinct prefix chains the warm set tracks frequencies for.
const WARM_SET_CAP: usize = 512;

/// Chains actually seeded into a warm scale-up (the hottest `K` of the
/// tracked set — the same budget the old recency ring seeded).
const WARM_SEED_TOP_K: usize = 64;

/// Frequency-tracked completed prefix chains for warm scale-up seeding.
///
/// Replaces the pure-recency ring of the first lifecycle layer: under a
/// Zipf-skewed workload the ring's last-64-completions view is mostly
/// one-off tail chains, which evict each other without ever being hit
/// again, while the head prefixes that *would* be hit are crowded out.
/// Counting completions per chain — the hotspot detector's view of the
/// working set — seeds the new instance with the chains most likely to
/// be asked for next (asserted strictly better in
/// `warm_set_seeds_beat_recency_ring_on_zipf`).
struct WarmSet {
    /// Keyed by the chain's last block hash (identifies the full chain).
    map: HashMap<u64, WarmEntry>,
}

struct WarmEntry {
    count: u64,
    last_us: u64,
    chain: Arc<[u64]>,
}

impl WarmSet {
    fn new() -> WarmSet {
        WarmSet { map: HashMap::new() }
    }

    /// Record one completion of `chain` at `now`. Capped: when full, the
    /// coldest entry (fewest completions, oldest, then highest key) is
    /// evicted to admit a first-time chain.
    fn observe(&mut self, chain: Arc<[u64]>, now: u64) {
        let Some(&key) = chain.last() else { return };
        if let Some(e) = self.map.get_mut(&key) {
            e.count += 1;
            e.last_us = now;
            return;
        }
        if self.map.len() >= WARM_SET_CAP {
            let coldest = self
                .map
                .iter()
                .map(|(&k, e)| (e.count, e.last_us, std::cmp::Reverse(k)))
                .min()
                .map(|(_, _, std::cmp::Reverse(k))| k);
            if let Some(k) = coldest {
                self.map.remove(&k);
            }
        }
        self.map.insert(
            key,
            WarmEntry {
                count: 1,
                last_us: now,
                chain,
            },
        );
    }

    /// The hottest `k` chains, by (count desc, recency desc, key asc) —
    /// a total order, so seeding is deterministic.
    fn top_chains(&self, k: usize) -> Vec<Arc<[u64]>> {
        let mut ranked: Vec<(&u64, &WarmEntry)> = self.map.iter().collect();
        ranked.sort_by_key(|(&key, e)| (Reverse(e.count), Reverse(e.last_us), key));
        ranked
            .into_iter()
            .take(k)
            .map(|(_, e)| e.chain.clone())
            .collect()
    }
}

/// The shared event core. `initial` lists the indices released at their
/// pre-stamped `arrival_us` (in push order — ties break FIFO); `followups`
/// (empty for open-loop runs, else one slot per request) encodes the
/// reactive dependency edges resolved at completion time. `admission`,
/// when present, is consulted before every route decision: a shed request
/// never reaches the router, and the overload counters in the returned
/// metrics account for it. With `admission == None` the trajectory is
/// byte-identical to the pre-overload core.
///
/// `faults` (a [`FaultPlan::schedule`], sorted by time) and `autoscaler`
/// form the lifecycle layer: crash/drain/recover/scale events, displaced
/// requests requeued through the router (re-entering admission control),
/// and periodic fleet observations feeding scale decisions back in. With
/// an empty schedule and no autoscaler, no lifecycle event is ever
/// pushed, so the trajectory — heap tiebreaks included — is
/// byte-identical to the pre-lifecycle core (asserted in tests).
#[allow(clippy::too_many_arguments)]
fn run_des_core(
    cfg: &ClusterConfig,
    mut reqs: Vec<crate::trace::TraceRequest>,
    initial: &[usize],
    followups: &[Option<Followup>],
    policy: &mut dyn Policy,
    mut admission: Option<&mut dyn AdmissionPolicy>,
    faults: &[PlannedFault],
    mut autoscaler: Option<(&mut dyn Autoscaler, u64)>,
) -> RunMetrics {
    let n = cfg.n_instances;
    let reactive = followups.iter().any(Option::is_some);
    let lifecycle_active = !faults.is_empty() || autoscaler.is_some();
    // Completion → follow-up lookup; also the requeue path's id → index
    // map. Only built when reactive edges or lifecycle events can occur,
    // so plain open-loop runs pay nothing.
    let idx_of: HashMap<u64, usize> = if reactive || lifecycle_active {
        reqs.iter().enumerate().map(|(i, tr)| (tr.req.id, i)).collect()
    } else {
        HashMap::new()
    };
    // Guard counters accumulate over the policy's lifetime; report this
    // run's delta.
    let guard_start = policy.guard_counters().unwrap_or_default();
    let mut instances: Vec<Instance> = (0..n)
        .map(|i| Instance::new(i, cfg.engine_for(i)))
        .collect();
    let mut factory = IndicatorFactory::new(n, cfg.engine.kv_capacity_blocks);
    // Arm the router's fleet view only when heterogeneity or model
    // multiplexing is actually in play: uniform single-model runs keep
    // the factory's fleet vectors empty and replay bit-identically.
    if !cfg.fleet.is_uniform() || reqs.iter().any(|tr| tr.req.model_id != 0) {
        let profiles: Vec<InstanceProfile> =
            (0..n).map(|i| cfg.fleet.profile_for(i).clone()).collect();
        factory.set_fleet(&profiles, &cfg.engine.profile);
    }
    let mut metrics = RunMetrics::new(n);
    let mut stepping = vec![false; n];
    let mut pending: Vec<Option<StepOutcome>> = (0..n).map(|_| None).collect();
    // Per-in-flight-request bookkeeping. `full_hashes` values are
    // Arc-shared with the trace (refcount bump, not a copy); all three
    // maps are drained as requests progress — see the FirstToken /
    // Completed handlers — so long traces never accumulate dead entries.
    let mut full_hashes: HashMap<u64, Arc<[u64]>> = HashMap::new();
    let mut predicted: HashMap<u64, f64> = HashMap::new();
    let mut arrivals: HashMap<u64, u64> = HashMap::new();
    // Sessions that have at least one admitted turn — lets the shed
    // accounting distinguish a clean turn-0 rejection (the client saw it
    // and went away) from a mid-conversation orphan. Only populated when
    // admission control is active; `HashSet::new` does not allocate.
    let mut admitted_sessions: HashSet<u64> = HashSet::new();

    // ---- lifecycle state ------------------------------------------------
    // `alive` / `draining` shadow the factory's routable mask with the
    // extra distinction the factory doesn't need: a draining instance is
    // unroutable but still running its batch down. `step_end_at` stamps
    // the scheduled end of the in-flight step so a StepEnd popped after a
    // crash cancelled (or a recovery replaced) that step is recognized as
    // stale and skipped. `parked` holds requests routed while zero
    // instances were routable; they re-enter on the next recover/scale-up
    // or count as lost at run end.
    let mut alive = vec![true; n];
    let mut draining = vec![false; n];
    let mut drain_deadline_at = vec![0u64; n];
    let mut step_end_at = vec![0u64; n];
    let mut cold_left = vec![0u32; n];
    let mut parked: Vec<usize> = Vec::new();
    let mut warm_set = WarmSet::new();

    // (Reverse(time), Reverse(tiebreak), event)
    let mut queue: BinaryHeap<(Reverse<u64>, Reverse<u64>, Event)> = BinaryHeap::new();
    let mut tiebreak: u64 = 0;
    let push = |q: &mut BinaryHeap<(Reverse<u64>, Reverse<u64>, Event)>,
                    tb: &mut u64,
                    t: u64,
                    e: Event| {
        *tb += 1;
        q.push((Reverse(t), Reverse(*tb), e));
    };

    // Displace one request out of an instance (crash kill or drain
    // eviction): drop its in-flight bookkeeping and re-present it to the
    // router at `$now`. Its original `arrival_us` is kept, so TTFT keeps
    // charging the full displacement — recovery cost is visible, not
    // laundered.
    macro_rules! requeue_displaced {
        ($now:expr, $id:expr, $killed:expr) => {{
            let id: u64 = $id;
            predicted.remove(&id);
            arrivals.remove(&id);
            full_hashes.remove(&id);
            metrics.fault.requeued += 1;
            if $killed {
                metrics.fault.killed += 1;
            }
            let ridx = *idx_of.get(&id).expect("displaced request missing from index");
            push(&mut queue, &mut tiebreak, $now, Event::Requeue(ridx));
        }};
    }

    // A drain that has run its batch down: the slot goes dark and every
    // trace of it leaves the router's index (presence bits, snapshot,
    // occupancy) — proven equivalent to a mirror rebuild in the kvcache
    // tests.
    macro_rules! finalize_drain {
        ($i:expr) => {{
            let i = $i;
            draining[i] = false;
            alive[i] = false;
            let leftovers = instances[i].extract_all();
            debug_assert!(leftovers.is_empty(), "finalized drain had live work");
            drop(leftovers);
            factory.purge_instance(i);
        }};
    }

    // Capacity came back: re-present everything that was parked while the
    // fleet had zero routable instances.
    macro_rules! release_parked {
        ($now:expr) => {{
            for idx in parked.drain(..) {
                push(&mut queue, &mut tiebreak, $now, Event::Requeue(idx));
            }
        }};
    }

    macro_rules! drain_instance {
        ($now:expr, $i:expr, $deadline:expr) => {{
            let i = $i;
            metrics.fault.drains += 1;
            draining[i] = true;
            factory.set_routable(i, false);
            // Waiting requests never started; they re-route immediately.
            // The running batch is allowed to finish (or hit the deadline).
            for r in instances[i].extract_waiting() {
                requeue_displaced!($now, r.id, false);
            }
            if stepping[i] {
                drain_deadline_at[i] = $now + $deadline;
                push(&mut queue, &mut tiebreak, $now + $deadline, Event::DrainDeadline(i));
            } else {
                finalize_drain!(i);
            }
        }};
    }

    // Bring capacity up: reuse the lowest dead slot if one exists (a
    // recovered machine), otherwise grow every per-instance structure —
    // harness state, router index, metrics windows. `cold` controls
    // whether the new slot starts with an empty KV$ or is seeded from
    // recently completed prefix chains (modeling state transfer).
    macro_rules! scale_up {
        ($now:expr, $cold:expr) => {{
            let slot = (0..instances.len()).find(|&i| !alive[i] && !draining[i]);
            let i = match slot {
                Some(i) => {
                    alive[i] = true;
                    factory.set_routable(i, true);
                    i
                }
                None => {
                    let i = instances.len();
                    // Slots past the declared fleet inherit the last
                    // class (both here and in the factory's mirror).
                    instances.push(Instance::new(i, cfg.engine_for(i)));
                    factory.resize_instances(i + 1);
                    metrics.prefill_time.push(Windowed::new(10_000_000));
                    metrics.batch_size.push(Windowed::new(1_000_000));
                    stepping.push(false);
                    pending.push(None);
                    alive.push(true);
                    draining.push(false);
                    drain_deadline_at.push(0);
                    step_end_at.push(0);
                    cold_left.push(0);
                    i
                }
            };
            metrics.fault.scale_ups += 1;
            cold_left[i] = COLD_HIT_WINDOW;
            if !$cold {
                for chain in warm_set.top_chains(WARM_SEED_TOP_K) {
                    instances[i].kv_mut().insert(&chain, $now);
                    factory.on_completion(i, &chain, $now);
                }
            }
            release_parked!($now);
        }};
    }

    for &i in initial {
        push(&mut queue, &mut tiebreak, reqs[i].req.arrival_us, Event::Arrival(i));
    }
    for (k, f) in faults.iter().enumerate() {
        push(&mut queue, &mut tiebreak, f.at_us, Event::Fault(k));
    }
    if let Some((_, interval)) = autoscaler.as_ref() {
        push(&mut queue, &mut tiebreak, *interval, Event::AutoscaleTick);
    }

    let mut last_time = 0u64;
    while let Some((Reverse(now), _, event)) = queue.pop() {
        last_time = last_time.max(now);
        match event {
            Event::Arrival(idx) | Event::Requeue(idx) => {
                let is_requeue = matches!(event, Event::Requeue(_));
                let tr = &reqs[idx];
                // Borrowed scratch context: the whole route decision is
                // allocation-free on the router side.
                let ctx = factory.route_ctx(&tr.req, now);
                if let Some(adm) = admission.as_deref_mut() {
                    if !is_requeue {
                        metrics.overload.offered += 1;
                    }
                    let sid = tr.req.session_id;
                    if !adm.admit(ctx) {
                        if is_requeue {
                            // A displaced request re-enters admission
                            // control like any other work; a rejection
                            // here is a loss (it was already admitted
                            // once), not a second shed.
                            metrics.fault.lost += 1;
                            continue;
                        }
                        metrics.overload.shed += 1;
                        if sid != 0 && admitted_sessions.contains(&sid) {
                            metrics.overload.shed_mid_session += 1;
                            // Every later turn of this session is now
                            // stranded: its release was chained to this
                            // turn's completion, which will never happen.
                            let mut cur = idx;
                            while let Some(f) = followups.get(cur).copied().flatten() {
                                metrics.overload.orphaned_turns += 1;
                                cur = f.next;
                            }
                        } else if sid != 0 {
                            metrics.overload.shed_sessions += 1;
                        }
                        continue;
                    }
                    if !is_requeue {
                        metrics.overload.admitted += 1;
                        if sid != 0 {
                            admitted_sessions.insert(sid);
                        }
                    }
                }
                let t0 = Instant::now();
                let decision = policy.route(ctx);
                metrics
                    .sched_overhead_us
                    .push(t0.elapsed().as_nanos() as f64 / 1000.0);
                let mut d = decision.instance;
                if lifecycle_active && (d >= instances.len() || !alive[d] || draining[d]) {
                    // The policy routed into a dead or draining slot (its
                    // view can lag a just-fired fault, and `select_min`
                    // falls back to 0 when nothing is routable). Redirect
                    // to the least-loaded routable instance, or park the
                    // request until capacity returns.
                    let mut best: Option<(usize, usize)> = None;
                    for i in 0..instances.len() {
                        if alive[i] && !draining[i] {
                            let key = (ctx.inds[i].bs(), i);
                            if best.map_or(true, |b| key < b) {
                                best = Some(key);
                            }
                        }
                    }
                    match best {
                        Some((_, i)) => d = i,
                        None => {
                            parked.push(idx);
                            continue;
                        }
                    }
                }
                if is_requeue {
                    metrics.fault.re_admitted += 1;
                }
                debug_assert!(d < instances.len(), "policy routed out of range");
                factory.on_route(d, &tr.req, now);
                if let Some(p) = decision.predicted_ttft_us {
                    predicted.insert(tr.req.id, p);
                }
                arrivals.insert(tr.req.id, tr.req.arrival_us);
                full_hashes.insert(tr.req.id, tr.full_hashes.clone());
                instances[d].enqueue(tr.req.clone(), tr.full_hashes.clone(), now);
                if !stepping[d] {
                    if let Some(out) = begin_step(&mut instances[d], now, &mut metrics, d) {
                        let end = now + out.duration_us;
                        pending[d] = Some(out);
                        stepping[d] = true;
                        step_end_at[d] = end;
                        push(&mut queue, &mut tiebreak, end, Event::StepEnd(d));
                    }
                }
            }
            Event::StepEnd(d) => {
                // Stale-step guard: a crash (or deadline kill) cancelled
                // the step this event announced, and a later restart may
                // have stamped a new one. Only the StepEnd matching the
                // currently pending outcome's scheduled end is real. In
                // fault-free runs the guard never fires.
                if pending[d].is_none() || step_end_at[d] != now {
                    continue;
                }
                let out = pending[d].take().expect("StepEnd without outcome");
                for ev in &out.events {
                    match ev {
                        EngineEvent::FirstToken { req_id, at_us } => {
                            // TTFT is decided here: drop the prediction /
                            // arrival bookkeeping so long traces don't
                            // accumulate dead map entries.
                            let pred = predicted.remove(req_id);
                            let arr = arrivals.remove(req_id);
                            if let (Some(pred), Some(arr)) = (pred, arr) {
                                let actual = (*at_us - arr) as f64;
                                if actual > 0.0 {
                                    metrics
                                        .sim_error_ratio
                                        .push((pred - actual).abs() / actual);
                                }
                            }
                        }
                        EngineEvent::Completed { record } => {
                            metrics.records.push(*record);
                            if lifecycle_active && cold_left[d] > 0 {
                                // Warm-up visibility: the first completions
                                // after a slot (re)joins trace the cache
                                // hit curve from cold (or seeded) state.
                                cold_left[d] -= 1;
                                metrics.fault.cold_samples += 1;
                                metrics.cold_hit_samples.push(record.hit_ratio());
                            }
                            if let Some(fh) = full_hashes.remove(&record.id) {
                                factory.on_completion(d, &fh, now);
                                if lifecycle_active {
                                    warm_set.observe(fh, now);
                                }
                            }
                            // Defensive: FirstToken always precedes
                            // Completed, so these are normally no-ops.
                            predicted.remove(&record.id);
                            arrivals.remove(&record.id);
                            // Closed-loop release: the next turn of this
                            // request's session arrives think-time after
                            // the completion the client just observed.
                            if reactive {
                                let fu = idx_of.get(&record.id).and_then(|&i| followups[i]);
                                if let Some(f) = fu {
                                    let at = now + f.think_us;
                                    reqs[f.next].req.arrival_us = at;
                                    push(&mut queue, &mut tiebreak, at, Event::Arrival(f.next));
                                }
                            }
                        }
                    }
                }
                factory.on_snapshot(d, out.snapshot);
                // Hand the spent events buffer back: the DES steady state
                // ping-pongs one Vec per instance instead of allocating a
                // fresh one every step.
                instances[d].recycle_events(out.events);
                if draining[d] && !instances[d].has_work() {
                    // Batch ran down before the deadline: clean drain.
                    stepping[d] = false;
                    finalize_drain!(d);
                } else if instances[d].has_work() {
                    if let Some(out2) = begin_step(&mut instances[d], now, &mut metrics, d) {
                        let end = now + out2.duration_us;
                        pending[d] = Some(out2);
                        step_end_at[d] = end;
                        push(&mut queue, &mut tiebreak, end, Event::StepEnd(d));
                    } else {
                        stepping[d] = false;
                    }
                } else {
                    stepping[d] = false;
                }
            }
            Event::Fault(k) => match faults[k].event {
                FaultEvent::Crash { instance: i } if i < instances.len() && alive[i] => {
                    metrics.fault.crashes += 1;
                    alive[i] = false;
                    draining[i] = false; // a crash preempts an in-progress drain
                    factory.set_routable(i, false);
                    if let Some(out) = pending[i].take() {
                        stepping[i] = false;
                        // The cancelled step never happened: requests it
                        // would have completed were already moved out of
                        // the engine's running set, so requeue them from
                        // the outcome's own event list. Their records are
                        // NOT pushed — the tokens are gone with the node.
                        for ev in &out.events {
                            if let EngineEvent::Completed { record } = ev {
                                requeue_displaced!(now, record.id, true);
                            }
                        }
                    }
                    for r in instances[i].extract_all() {
                        requeue_displaced!(now, r.id, true);
                    }
                    factory.purge_instance(i);
                }
                FaultEvent::Recover { instance: i }
                    if i < instances.len() && !alive[i] && !draining[i] =>
                {
                    metrics.fault.recovers += 1;
                    alive[i] = true;
                    factory.set_routable(i, true);
                    // The machine is back but its KV$ is not: sample the
                    // cold-start hit curve as it refills.
                    cold_left[i] = COLD_HIT_WINDOW;
                    release_parked!(now);
                }
                FaultEvent::Drain {
                    instance: i,
                    deadline_us,
                } if i < instances.len() && alive[i] && !draining[i] => {
                    drain_instance!(now, i, deadline_us);
                }
                FaultEvent::ScaleUp { cold_kv } => {
                    scale_up!(now, cold_kv);
                }
                // Crash of a dead slot, recover of a live one, drain of a
                // drained one: plans may race their own events; ignore.
                _ => {}
            },
            Event::DrainDeadline(d) => {
                // Stale if the drain already finished cleanly (or a crash
                // superseded it).
                if !draining[d] || drain_deadline_at[d] != now {
                    continue;
                }
                metrics.fault.drain_violations += 1;
                if let Some(out) = pending[d].take() {
                    stepping[d] = false;
                    for ev in &out.events {
                        if let EngineEvent::Completed { record } = ev {
                            requeue_displaced!(now, record.id, true);
                        }
                    }
                }
                for r in instances[d].extract_all() {
                    requeue_displaced!(now, r.id, true);
                }
                draining[d] = false;
                alive[d] = false;
                factory.purge_instance(d);
            }
            Event::AutoscaleTick => {
                if let Some((scaler, interval)) = autoscaler.as_mut() {
                    let interval = *interval;
                    let mut obs = FleetObs {
                        now_us: now,
                        alive: 0,
                        slots: instances.len(),
                        total_queue_depth: 0,
                        max_queue_depth: 0,
                        min_p_token: 0,
                    };
                    let mut min_p: Option<u64> = None;
                    for i in 0..instances.len() {
                        if alive[i] && !draining[i] {
                            obs.alive += 1;
                            let s = instances[i].snapshot();
                            let depth = (s.r_bs + s.q_bs) as u64;
                            obs.total_queue_depth += depth;
                            obs.max_queue_depth = obs.max_queue_depth.max(depth);
                            let p = s.queued_prefill_tokens as u64;
                            min_p = Some(min_p.map_or(p, |m| m.min(p)));
                        }
                    }
                    obs.min_p_token = min_p.unwrap_or(0);
                    match scaler.tick(&obs) {
                        Some(ScaleAction::Up { cold_kv }) => scale_up!(now, cold_kv),
                        Some(ScaleAction::Down) => {
                            // Drain the shallowest routable instance; the
                            // deadline is two observation intervals, after
                            // which the leftover batch is requeued.
                            let mut best: Option<(u64, usize)> = None;
                            for i in 0..instances.len() {
                                if alive[i] && !draining[i] {
                                    let s = instances[i].snapshot();
                                    let key = ((s.r_bs + s.q_bs) as u64, i);
                                    if best.map_or(true, |b| key < b) {
                                        best = Some(key);
                                    }
                                }
                            }
                            if let Some((_, i)) = best {
                                drain_instance!(now, i, interval * 2);
                            }
                        }
                        None => {}
                    }
                    // Keep observing only while the simulation still has
                    // events — otherwise the tick chain would run forever.
                    if !queue.is_empty() {
                        push(&mut queue, &mut tiebreak, now + interval, Event::AutoscaleTick);
                    }
                }
            }
        }
    }

    // Requests still parked when the event heap drained had nowhere to
    // run: the fleet ended with zero routable instances. They are the
    // only way a routed request can fail to complete without an explicit
    // shed, and they are counted, never silently dropped.
    metrics.fault.lost += parked.len() as u64;

    metrics.duration_us = last_time;
    for inst in &instances {
        metrics.total_steps += inst.steps;
        metrics.admit_radix_walks += inst.kv().admit_radix_walks;
        metrics.queue.push(QueueCounters {
            promotions: inst.queue_promotions(),
            stalled_steps: inst.stalled_steps,
            wait_us_sum: inst.queue_wait_us_sum,
            wait_samples: inst.queue_wait_samples,
            wait_us_max: inst.queue_wait_us_max,
        });
        metrics.models.cold_loads += inst.models().cold_loads;
        metrics.models.evictions += inst.models().evictions;
        metrics.models.swap_us += inst.models().swap_us;
    }
    metrics.guard = policy.guard_counters().unwrap_or_default().since(guard_start);
    metrics
}

// Shared with `cluster::concurrent`, whose event loop must account
// steps identically to the serial core.
pub(crate) fn begin_step(
    inst: &mut Instance,
    now: u64,
    metrics: &mut RunMetrics,
    d: usize,
) -> Option<StepOutcome> {
    let out = inst.step(now)?;
    metrics.prefill_time[d].add(now, out.prefill_us / 1e6); // seconds per window
    metrics.batch_size[d].add(now, out.snapshot.r_bs as f64);
    Some(out)
}

/// Offline capacity profiling (§4.1): saturate ONE instance and measure
/// completed requests/second. Cluster capacity = n_instances × this.
///
/// Profiled *warm*: the first `sample` requests warm the KV$ (untimed),
/// the next `sample` are timed. This matches how the paper's provider
/// measures "the maximum rate of our testbed" — under its production
/// KV$-aware scheduler at steady state, where prefix hits are part of
/// capacity. (Profiling cold would understate capacity and push every
/// policy into an underloaded regime where they all look alike.)
pub fn profile_capacity_rps(engine: &EngineConfig, trace: &Trace, sample: usize) -> f64 {
    let mut inst = Instance::new(0, engine.clone());
    let half = sample.min(trace.requests.len() / 2).max(1);
    let mut now = 0u64;
    // Warm phase (untimed). Enqueue hands over Arc clones of the trace's
    // token/hash storage — no per-request Vec copies.
    for tr in trace.requests.iter().take(half) {
        inst.enqueue(tr.req.clone(), tr.full_hashes.clone(), now);
    }
    while inst.has_work() {
        let out = inst.step(now).expect("work pending");
        now += out.duration_us;
        inst.recycle_events(out.events);
    }
    // Timed phase on the warm cache.
    let start = now;
    let timed = trace.requests.iter().skip(half).take(half);
    let mut n_timed = 0usize;
    for tr in timed {
        inst.enqueue(tr.req.clone(), tr.full_hashes.clone(), now);
        n_timed += 1;
    }
    while inst.has_work() {
        let out = inst.step(now).expect("work pending");
        now += out.duration_us;
        inst.recycle_events(out.events);
    }
    if now == start {
        return f64::INFINITY;
    }
    n_timed as f64 / ((now - start) as f64 / 1e6)
}

/// Build trace + cluster from an [`ExperimentConfig`], scale the arrival
/// rate to `rate_scale × capacity`, run the policy, return metrics.
/// The same entry point the CLI, examples and benches all use.
pub fn run_experiment(exp: &ExperimentConfig, policy: &mut dyn Policy) -> RunMetrics {
    let trace = build_scaled_trace(exp);
    let cfg = cluster_config(exp);
    run_des(&cfg, &trace, policy)
}

/// The trace an experiment runs (scaled); public so benches can share one
/// trace across policies.
///
/// Load scaling follows the trace-upscaling literature the paper cites
/// (§4.1): the *session arrival rate* is scaled until the mean request
/// rate hits `rate_scale × profiled capacity`, with think times and
/// in-session causality preserved. (Naively compressing timestamps would
/// shrink think-times below decode residence, so conversation turns would
/// arrive before their previous turn's KV$ exists — destroying the very
/// prefix-reuse structure the schedulers compete on.)
pub fn build_scaled_trace(exp: &ExperimentConfig) -> Trace {
    let workload = Workload::by_name(&exp.workload)
        .unwrap_or_else(|| panic!("unknown workload {}", exp.workload));
    let mut spec =
        WorkloadSpec::preset(workload, exp.requests, exp.seed).with_n_models(exp.n_models);
    let probe = generate(&spec);
    let cfg = cluster_config(exp);
    let cap = profile_capacity_rps(&cfg.engine, &probe, 200);
    let target = exp.rate_scale * cap * exp.instances as f64;
    // Request rate is ~linear in session rate; a few correction passes
    // land within a few percent of the target steady-state rate.
    let mut trace = probe;
    for _ in 0..3 {
        let natural = trace.steady_rps();
        if !natural.is_finite() || natural <= 0.0 {
            break;
        }
        let ratio = (target / natural).clamp(0.05, 20.0);
        if (ratio - 1.0).abs() < 0.03 {
            break;
        }
        spec.session_rate *= ratio;
        trace = generate(&spec);
    }
    trace
}

/// Scale a session workload's *session arrival rate* until the open-loop
/// (flattened) request rate hits `rate_scale × profiled capacity` — the
/// same §4.1 methodology [`build_scaled_trace`] applies to the synth
/// traces, adapted to the closed loop: think times and in-session
/// causality are untouched (they are replayed reactively), only the
/// session inter-arrival gaps compress. The flattened rate is the load a
/// fast cluster would see; under congestion the closed loop throttles
/// itself below it, which is exactly the behaviour being studied.
pub fn build_scaled_sessions(
    spec: &SessionSpec,
    cfg: &ClusterConfig,
    rate_scale: f64,
) -> SessionTrace {
    let mut spec = spec.clone();
    let probe = generate_sessions(&spec);
    let cap = profile_capacity_rps(&cfg.engine, &probe.flatten(), 200);
    let target = rate_scale * cap * cfg.n_instances as f64;
    let mut strace = probe;
    // Request rate is sublinear in session rate (think-time gaps do not
    // compress); a few correction passes converge like the open-loop
    // scaler's.
    for _ in 0..3 {
        let natural = strace.flatten().steady_rps();
        if !natural.is_finite() || natural <= 0.0 {
            break;
        }
        let ratio = (target / natural).clamp(0.05, 20.0);
        if (ratio - 1.0).abs() < 0.03 {
            break;
        }
        spec.session_rate *= ratio;
        strace = generate_sessions(&spec);
    }
    strace
}

/// Scale an open-arrival workload's *rate program* until the flattened
/// request rate hits `rate_scale × profiled capacity` — the §4.1
/// methodology of [`build_scaled_sessions`], adapted to the open engine:
/// the whole program is multiplied by one factor ([`RateProgram::scaled`]
/// via [`OpenSpec`]), so ramps, diurnal swings and flash crowds keep
/// their *shape* while the mean load lands on target. `rate_scale > 1`
/// is the overload regime the admission policies are judged in.
pub fn build_scaled_open(spec: &OpenSpec, cfg: &ClusterConfig, rate_scale: f64) -> SessionTrace {
    let mut spec = spec.clone();
    let probe = generate_open(&spec);
    let cap = profile_capacity_rps(&cfg.engine, &probe.flatten(), 200);
    let target = rate_scale * cap * cfg.n_instances as f64;
    let mut strace = probe;
    for _ in 0..3 {
        let natural = strace.flatten().steady_rps();
        if !natural.is_finite() || natural <= 0.0 {
            break;
        }
        let ratio = (target / natural).clamp(0.05, 20.0);
        if (ratio - 1.0).abs() < 0.03 {
            break;
        }
        spec.program = spec.program.scaled(ratio);
        strace = generate_open(&spec);
    }
    strace
}

pub fn cluster_config(exp: &ExperimentConfig) -> ClusterConfig {
    let profile = ModelProfile::by_name(&exp.profile)
        .unwrap_or_else(|| panic!("unknown profile {}", exp.profile));
    ClusterConfig::new(
        exp.instances,
        EngineConfig {
            profile,
            instance: InstanceProfile::reference(),
            chunk_budget: exp.chunk_budget,
            max_batch: exp.max_batch,
            kv_capacity_blocks: exp.kv_capacity_blocks,
            queue_policy: exp.queue_policy.clone(),
        },
    )
    .with_fleet(exp.effective_fleet())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy;

    fn small_exp(policy_name: &str) -> (ExperimentConfig, Box<dyn Policy>) {
        let mut exp = ExperimentConfig::default();
        exp.instances = 4;
        exp.requests = 300;
        exp.rate_scale = 0.5;
        exp.policy = policy_name.to_string();
        let profile = ModelProfile::moe_30b();
        let p = policy::build(policy_name, 0.7, &profile, exp.chunk_budget).unwrap();
        (exp, p)
    }

    #[test]
    fn all_requests_complete_exactly_once() {
        let (exp, mut p) = small_exp("lmetric");
        let m = run_experiment(&exp, p.as_mut());
        assert_eq!(m.records.len(), 300);
        let mut ids: Vec<u64> = m.records.iter().map(|r| r.id).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), 300, "duplicate completions");
    }

    #[test]
    fn causality_holds() {
        let (exp, mut p) = small_exp("vllm");
        let m = run_experiment(&exp, p.as_mut());
        for r in &m.records {
            assert!(r.first_token_us > r.arrival_us);
            assert!(r.completion_us >= r.first_token_us);
        }
    }

    #[test]
    fn kv_aware_beats_load_only_on_chatbot() {
        // The paper's core claim (Fig 7) at miniature scale.
        let (exp, mut lm) = small_exp("lmetric");
        let trace = build_scaled_trace(&exp);
        let cfg = cluster_config(&exp);
        let m_lm = run_des(&cfg, &trace, lm.as_mut());
        let mut vllm = policy::build("vllm", 0.0, &cfg.engine.profile, 256).unwrap();
        let m_v = run_des(&cfg, &trace, vllm.as_mut());
        assert!(
            m_lm.mean_hit_ratio() > m_v.mean_hit_ratio() + 0.05,
            "lmetric hit {} vs vllm {}",
            m_lm.mean_hit_ratio(),
            m_v.mean_hit_ratio()
        );
        assert!(
            m_lm.ttft_summary().mean < m_v.ttft_summary().mean,
            "lmetric ttft {} vs vllm {}",
            m_lm.ttft_summary().mean,
            m_v.ttft_summary().mean
        );
    }

    /// Every request is admitted exactly once, and each admission costs
    /// exactly one fused radix walk — the per-request KV$ overhead of the
    /// whole harness, aggregated across instances.
    #[test]
    fn one_fused_radix_walk_per_request() {
        let (exp, mut p) = small_exp("lmetric");
        let m = run_experiment(&exp, p.as_mut());
        assert_eq!(m.records.len(), 300);
        assert_eq!(m.admit_radix_walks, 300, "admissions must fuse to one walk");
        assert!(m.total_steps > 0);
    }

    #[test]
    fn deterministic_runs() {
        let (exp, mut p1) = small_exp("lmetric");
        let (_, mut p2) = small_exp("lmetric");
        let m1 = run_experiment(&exp, p1.as_mut());
        let m2 = run_experiment(&exp, p2.as_mut());
        assert_eq!(m1.records.len(), m2.records.len());
        for (a, b) in m1.records.iter().zip(&m2.records) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.completion_us, b.completion_us);
            assert_eq!(a.instance, b.instance);
        }
    }

    #[test]
    fn capacity_profile_positive_finite() {
        let exp = ExperimentConfig::default();
        let cfg = cluster_config(&exp);
        let workload = WorkloadSpec::preset(Workload::ChatBot, 300, 1);
        let trace = generate(&workload);
        let cap = profile_capacity_rps(&cfg.engine, &trace, 100);
        assert!(cap > 0.1 && cap < 10_000.0, "capacity {cap}");
    }

    #[test]
    fn every_policy_survives_a_run() {
        for name in policy::all_names() {
            let (exp, mut p) = small_exp(name);
            let mut exp = exp;
            exp.requests = 120;
            let m = run_experiment(&exp, p.as_mut());
            assert_eq!(m.records.len(), 120, "{name} lost requests");
        }
    }

    // ---- lifecycle / fault injection ------------------------------------

    use crate::cluster::lifecycle::{FaultCounters, QueueDepthAutoscaler};

    fn assert_same_records(a: &RunMetrics, b: &RunMetrics) {
        assert_eq!(a.records.len(), b.records.len());
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(
                (x.id, x.instance, x.arrival_us, x.first_token_us, x.completion_us),
                (y.id, y.instance, y.arrival_us, y.first_token_us, y.completion_us)
            );
        }
        assert_eq!(a.duration_us, b.duration_us);
        assert_eq!(a.total_steps, b.total_steps);
    }

    /// Every id in the trace completes exactly once, regardless of how
    /// many times faults displaced it — the zero-silent-drops contract.
    fn assert_conserved(m: &RunMetrics, expect: usize) {
        assert_eq!(m.fault.lost, 0, "lost requests: {:?}", m.fault);
        assert_eq!(m.records.len(), expect, "completions: {:?}", m.fault);
        let mut ids: Vec<u64> = m.records.iter().map(|r| r.id).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), expect, "duplicate completions");
    }

    /// An empty plan pushes no events and touches no tiebreaks: the run
    /// must be indistinguishable from one without the lifecycle layer.
    #[test]
    fn empty_fault_plan_is_byte_identical() {
        let (exp, mut p1) = small_exp("lmetric");
        let (_, mut p2) = small_exp("lmetric");
        let trace = build_scaled_trace(&exp);
        let cfg = cluster_config(&exp);
        let base = run_des(&cfg, &trace, p1.as_mut());
        let spec = RunSpec::open_loop(&cfg, &trace).with_faults(FaultPlan::new());
        let faulted = run(spec, p2.as_mut());
        assert_same_records(&base, &faulted);
        assert_eq!(faulted.fault, FaultCounters::default());
        assert!(faulted.cold_hit_samples.is_empty());
    }

    /// Acceptance: a crash during load replays to completion with zero
    /// lost requests, every displaced request completing exactly once.
    #[test]
    fn crash_during_load_conserves_every_request() {
        let (exp, mut probe) = small_exp("lmetric");
        let trace = build_scaled_trace(&exp);
        let cfg = cluster_config(&exp);
        let dur = run_des(&cfg, &trace, probe.as_mut()).duration_us;
        let plan = FaultPlan::new()
            .crash_at(dur / 4, 1)
            .recover_at(dur / 2, 1);
        let (_, mut p) = small_exp("lmetric");
        let m = run(
            RunSpec::open_loop(&cfg, &trace).with_faults(plan),
            p.as_mut(),
        );
        assert_conserved(&m, 300);
        assert_eq!(m.fault.crashes, 1);
        assert_eq!(m.fault.recovers, 1);
        assert!(m.fault.killed > 0, "crash at {} displaced nothing", dur / 4);
        // Crash-only displacement: everything killed was requeued, and
        // with no admission control every requeue was re-admitted.
        assert_eq!(m.fault.requeued, m.fault.killed);
        assert_eq!(m.fault.re_admitted, m.fault.requeued);
    }

    /// Same seed, same plan — identical trajectory and counters.
    #[test]
    fn lifecycle_replay_is_deterministic() {
        let (exp, mut probe) = small_exp("lmetric");
        let trace = build_scaled_trace(&exp);
        let cfg = cluster_config(&exp);
        let dur = run_des(&cfg, &trace, probe.as_mut()).duration_us;
        let plan = FaultPlan::new()
            .crash_at(dur / 4, 0)
            .drain_at(dur / 3, 2, 2_000_000)
            .recover_at(dur / 2, 0)
            .scale_up_at(2 * dur / 3, true);
        let mut runs = (0..2).map(|_| {
            let (_, mut p) = small_exp("lmetric");
            run(
                RunSpec::open_loop(&cfg, &trace).with_faults(plan.clone()),
                p.as_mut(),
            )
        });
        let (a, b) = (runs.next().unwrap(), runs.next().unwrap());
        assert_same_records(&a, &b);
        assert_eq!(a.fault, b.fault);
        assert_eq!(a.cold_hit_samples, b.cold_hit_samples);
    }

    /// A drained instance finishes its batch (or hits the deadline), its
    /// waiting queue re-routes, and nothing is dropped.
    #[test]
    fn drain_requeues_waiting_and_conserves() {
        let (exp, mut probe) = small_exp("lmetric");
        let trace = build_scaled_trace(&exp);
        let cfg = cluster_config(&exp);
        let dur = run_des(&cfg, &trace, probe.as_mut()).duration_us;
        let plan = FaultPlan::new().drain_at(dur / 4, 1, 5_000_000);
        let (_, mut p) = small_exp("lmetric");
        let m = run(
            RunSpec::open_loop(&cfg, &trace).with_faults(plan),
            p.as_mut(),
        );
        assert_conserved(&m, 300);
        assert_eq!(m.fault.drains, 1);
        // After the drain no record may land on the drained slot's later
        // completions... it can still complete its own batch, but every
        // completion after the drain deadline must come from elsewhere.
        let cutoff = dur / 4 + 5_000_000;
        for r in &m.records {
            assert!(
                r.instance != 1 || r.completion_us <= cutoff,
                "instance 1 completed id {} after its drain deadline",
                r.id
            );
        }
    }

    /// Scale-up mid-run: the fleet widens, the new slot takes work, and
    /// its first completions are sampled into the cold-start hit curve.
    #[test]
    fn scale_up_widens_fleet_and_samples_cold_curve() {
        let (exp, mut probe) = small_exp("lmetric");
        let trace = build_scaled_trace(&exp);
        let cfg = cluster_config(&exp);
        let dur = run_des(&cfg, &trace, probe.as_mut()).duration_us;
        let plan = FaultPlan::new().scale_up_at(dur / 4, true);
        let (_, mut p) = small_exp("lmetric");
        let m = run(
            RunSpec::open_loop(&cfg, &trace).with_faults(plan),
            p.as_mut(),
        );
        assert_conserved(&m, 300);
        assert_eq!(m.fault.scale_ups, 1);
        assert!(
            m.records.iter().any(|r| r.instance == 4),
            "the new slot never completed anything"
        );
        assert!(m.fault.cold_samples > 0);
        assert_eq!(m.cold_hit_samples.len() as u64, m.fault.cold_samples);
    }

    /// The reactive loop closes: under a heavy trace the queue-depth
    /// autoscaler grows the fleet, and the run still conserves requests.
    #[test]
    fn autoscaler_scales_up_under_pressure() {
        let (mut exp, _) = small_exp("lmetric");
        exp.rate_scale = 3.0; // overloaded at the starting fleet size
        let trace = build_scaled_trace(&exp);
        let cfg = cluster_config(&exp);
        let scaler = QueueDepthAutoscaler::new(4.0, 1.0, exp.instances, exp.instances * 2)
            .with_cooldown(1_000_000);
        let (_, mut p) = small_exp("lmetric");
        let m = run(
            RunSpec::open_loop(&cfg, &trace)
                .with_autoscaler(Box::new(scaler), 500_000),
            p.as_mut(),
        );
        assert_conserved(&m, 300);
        assert!(m.fault.scale_ups > 0, "autoscaler never reacted: {:?}", m.fault);
    }

    /// Stochastic plans are a pure function of their seed (the Python
    /// mirror pins the draw contract); the DES replay of one is too.
    #[test]
    fn stochastic_plan_replays_deterministically() {
        let (exp, mut probe) = small_exp("lmetric");
        let trace = build_scaled_trace(&exp);
        let cfg = cluster_config(&exp);
        let dur = run_des(&cfg, &trace, probe.as_mut()).duration_us;
        let spec = crate::cluster::StochasticFaults {
            seed: 11,
            crash_rate_per_s: 2e6 / dur as f64, // ~2 crashes over the run
            mttr_s: dur as f64 / 4e6,
            horizon_s: dur as f64 / 1e6,
        };
        let plan = FaultPlan::new().stochastic(&spec, exp.instances);
        let mut runs = (0..2).map(|_| {
            let (_, mut p) = small_exp("lmetric");
            run(
                RunSpec::open_loop(&cfg, &trace).with_faults(plan.clone()),
                p.as_mut(),
            )
        });
        let (a, b) = (runs.next().unwrap(), runs.next().unwrap());
        assert_same_records(&a, &b);
        assert_eq!(a.fault, b.fault);
        assert_eq!(a.fault.lost, 0);
    }

    /// Draw a chain index from a Zipf-ish distribution over `n` chains
    /// (weight 1/(rank+1)^1.2) — the skew the hotspot workloads model.
    fn zipf_draw(rng: &mut crate::util::Rng, cdf: &[f64]) -> usize {
        let u = rng.gen_f64(0.0, 1.0);
        cdf.iter().position(|&c| u <= c).unwrap_or(cdf.len() - 1)
    }

    /// Satellite of the PR-8 lifecycle layer: warm scale-up seeding from
    /// the frequency-tracked hot set must beat the old last-64-completions
    /// recency ring on a Zipf-skewed completion stream — strictly more
    /// prefix blocks hit by the traffic the new instance then serves.
    #[test]
    fn warm_set_seeds_beat_recency_ring_on_zipf() {
        use crate::kvcache::RadixTree;
        use std::collections::VecDeque;
        let n_chains = 300usize;
        let weights: Vec<f64> = (0..n_chains).map(|i| 1.0 / (i as f64 + 1.0).powf(1.2)).collect();
        let total: f64 = weights.iter().sum();
        let mut acc = 0.0;
        let cdf: Vec<f64> = weights
            .iter()
            .map(|w| {
                acc += w / total;
                acc
            })
            .collect();
        let chains: Vec<Arc<[u64]>> = (0..n_chains)
            .map(|i| (0..4).map(|b| (i as u64 + 1) * 1000 + b).collect::<Vec<u64>>().into())
            .collect();
        let mut rng = crate::util::Rng::new(0xc01d);
        let mut warm = WarmSet::new();
        let mut ring: VecDeque<Arc<[u64]>> = VecDeque::new();
        for t in 0..2000u64 {
            let c = &chains[zipf_draw(&mut rng, &cdf)];
            warm.observe(c.clone(), t);
            ring.push_back(c.clone());
            if ring.len() > WARM_SEED_TOP_K {
                ring.pop_front();
            }
        }
        // Seed one fresh KV$ from each strategy (same 64-chain budget) and
        // replay held-out future draws from the same distribution.
        let mut kv_warm = RadixTree::new(0);
        let mut kv_ring = RadixTree::new(0);
        for c in warm.top_chains(WARM_SEED_TOP_K) {
            kv_warm.insert(&c, 0);
        }
        for c in &ring {
            kv_ring.insert(c, 0);
        }
        let (mut hits_warm, mut hits_ring) = (0usize, 0usize);
        for t in 0..500u64 {
            let c = &chains[zipf_draw(&mut rng, &cdf)];
            hits_warm += kv_warm.match_prefix(c, t, false);
            hits_ring += kv_ring.match_prefix(c, t, false);
        }
        assert!(
            hits_warm > hits_ring,
            "hot-set seeding ({hits_warm} blocks hit) must beat the recency ring ({hits_ring})"
        );
    }

    /// The warm set's cap holds, eviction prefers the coldest entry, and
    /// the top-K ranking is by completion count.
    #[test]
    fn warm_set_caps_and_ranks_by_frequency() {
        let mut w = WarmSet::new();
        let chain = |i: u64| -> Arc<[u64]> { vec![i * 10 + 1, i * 10 + 2].into() };
        // Entry 1 observed thrice, entry 2 twice, the rest once.
        for i in 1..=(WARM_SET_CAP as u64) {
            w.observe(chain(i), i);
        }
        w.observe(chain(1), 9_000);
        w.observe(chain(1), 9_001);
        w.observe(chain(2), 9_002);
        assert_eq!(w.map.len(), WARM_SET_CAP);
        // A new chain evicts the coldest (count-1) entry, not the hot ones.
        w.observe(chain(WARM_SET_CAP as u64 + 1), 9_003);
        assert_eq!(w.map.len(), WARM_SET_CAP);
        assert!(w.map.contains_key(&12), "hottest entry evicted");
        let top = w.top_chains(2);
        assert_eq!(top[0].as_ref(), chain(1).as_ref());
        assert_eq!(top[1].as_ref(), chain(2).as_ref());
    }

    /// Warm scale-up end-to-end: the seeded slot joins, conserves
    /// requests, and its cold-start samples see a non-trivial hit curve
    /// on a Zipf-skewed workload (the seeding visibly pre-warms).
    #[test]
    fn warm_scale_up_seeds_from_hot_set() {
        let (mut exp, mut probe) = small_exp("lmetric");
        exp.workload = "hotspot".to_string();
        let trace = build_scaled_trace(&exp);
        let cfg = cluster_config(&exp);
        let dur = run_des(&cfg, &trace, probe.as_mut()).duration_us;
        let run_with = |cold: bool| {
            let plan = FaultPlan::new().scale_up_at(dur / 3, cold);
            let (_, mut p) = small_exp("lmetric");
            run(
                RunSpec::open_loop(&cfg, &trace).with_faults(plan),
                p.as_mut(),
            )
        };
        let warm = run_with(false);
        let cold = run_with(true);
        assert_conserved(&warm, 300);
        assert_eq!(warm.fault.scale_ups, 1);
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
        assert!(
            mean(&warm.cold_hit_samples) >= mean(&cold.cold_hit_samples),
            "warm seeding ({:?}) must not start colder than a cold join ({:?})",
            mean(&warm.cold_hit_samples),
            mean(&cold.cold_hit_samples)
        );
    }

    // ---- heterogeneous fleets / multi-model ------------------------------

    /// The FleetSpec API contract: declaring the fleet as
    /// `uniform(instances)` instead of the deprecated scalar must replay
    /// every router policy's every decision byte-for-byte.
    #[test]
    fn uniform_fleetspec_replays_the_scalar_shim_byte_identical() {
        for name in policy::all_names() {
            let (mut exp, mut p_scalar) = small_exp(name);
            exp.requests = 120;
            let (_, mut p_fleet) = small_exp(name);
            let trace = build_scaled_trace(&exp);
            assert!(exp.fleet.is_none(), "scalar baseline must use the shim");
            let cfg_scalar = cluster_config(&exp);
            exp.fleet = Some(FleetSpec::uniform(exp.instances));
            let cfg_fleet = cluster_config(&exp);
            let a = run_des(&cfg_scalar, &trace, p_scalar.as_mut());
            let b = run_des(&cfg_fleet, &trace, p_fleet.as_mut());
            assert_same_records(&a, &b);
            assert_eq!(b.models, crate::metrics::ModelCounters::default(), "{name}");
        }
    }

    /// A mixed-hardware fleet conserves every request, and single-model
    /// traffic never touches the swap path even with the fleet view armed.
    #[test]
    fn hetero_fleet_conserves_and_never_swaps_on_single_model_traffic() {
        let (exp, mut p) = small_exp("lmetric");
        let trace = build_scaled_trace(&exp);
        let fleet = FleetSpec::empty()
            .with_class(InstanceProfile::h100(), 1)
            .with_class(InstanceProfile::l40(), 3);
        let cfg = cluster_config(&exp).with_fleet(fleet);
        let m = run_des(&cfg, &trace, p.as_mut());
        assert_conserved(&m, 300);
        assert_eq!(
            m.models,
            crate::metrics::ModelCounters::default(),
            "model 0 ships warm everywhere"
        );
        // Heterogeneity must actually reach the engines and the router:
        // the same trace on a uniform fleet cannot replay identically
        // (step durations scale by 2.0 / 0.45 on the mixed one).
        let (_, mut p_u) = small_exp("lmetric");
        let uni = run_des(&cluster_config(&exp), &trace, p_u.as_mut());
        let differs = m.duration_us != uni.duration_us
            || m.records
                .iter()
                .zip(&uni.records)
                .any(|(a, b)| (a.id, a.instance, a.completion_us) != (b.id, b.instance, b.completion_us));
        assert!(differs, "mixed fleet replayed identically to uniform");
    }

    /// Multi-model traffic on a mixed fleet: the fused policy pays cold
    /// loads (counted, swap time charged) and still conserves requests.
    #[test]
    fn multi_model_traffic_pays_counted_cold_loads() {
        let (exp, _) = small_exp("lmetric");
        let mut spec = WorkloadSpec::preset(Workload::ChatBot, 300, exp.seed).with_n_models(4);
        spec.session_rate *= 0.5;
        let trace = generate(&spec);
        let fleet = FleetSpec::empty()
            .with_class(InstanceProfile::h100(), 2)
            .with_class(InstanceProfile::l40(), 2);
        let cfg = cluster_config(&exp).with_fleet(fleet);
        for name in ["lmetric_fused", "place_then_balance"] {
            let mut p = policy::build(name, 0.0, &cfg.engine.profile, 256).unwrap();
            let m = run_des(&cfg, &trace, p.as_mut());
            assert_conserved(&m, 300);
            assert!(m.models.cold_loads > 0, "{name}: 4 models on 2-warm slots must swap");
            assert_eq!(
                m.models.swap_us > 0,
                m.models.cold_loads > 0,
                "{name}: every cold load charges swap time"
            );
        }
    }
}
