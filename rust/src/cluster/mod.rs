//! Cluster harnesses: the discrete-event simulation driver (virtual time —
//! every figure bench runs on this), the R-router [`concurrent`] harness
//! scoring batched decisions in parallel from the sharded index, the
//! [`overload`] admission-control subsystem the DES consults under
//! open-system load, the [`lifecycle`] fault-injection layer
//! (crash/drain/recover/scale events, requeue recovery, reactive
//! autoscaling), and the live threaded cluster (wall-clock time + real
//! PJRT transformer compute — the end-to-end validation path).

mod concurrent;
mod des;
pub mod lifecycle;
pub mod live;
pub mod overload;

pub use concurrent::{run_concurrent, ConcurrentCfg};
pub use des::{
    build_scaled_open, build_scaled_sessions, build_scaled_trace, cluster_config,
    profile_capacity_rps, run, run_des, run_experiment, run_session_des, ClusterConfig, Release,
    RunSpec, Source,
};
pub use lifecycle::{
    Autoscaler, FaultCounters, FaultEvent, FaultPlan, FleetObs, PlannedFault,
    QueueDepthAutoscaler, ScaleAction, StochasticFaults,
};
pub use overload::{
    all_admission_names, build_admission, default_admission_param, AdmissionPolicy, AdmitAll,
    QueueDepthShed, SessionAwareShed, TtftShed,
};
