//! Cluster harnesses: the discrete-event simulation driver (virtual time —
//! every figure bench runs on this) and the live threaded cluster
//! (wall-clock time + real PJRT transformer compute — the end-to-end
//! validation path).

mod des;
pub mod live;

pub use des::{
    build_scaled_sessions, build_scaled_trace, cluster_config, profile_capacity_rps, run_des,
    run_experiment, run_session_des, ClusterConfig,
};
