//! Overload control: pluggable admission policies for the open-system
//! regime where offered load can exceed profiled capacity.
//!
//! A routing policy decides *where* an admitted request runs; an
//! [`AdmissionPolicy`] decides *whether* it runs at all. The DES core
//! consults the admission policy before the route decision — a shed
//! request never touches the router, never costs a radix walk, and
//! never occupies a queue slot. Shedding is what turns throughput into
//! *goodput* under overload: past saturation, `admit_all` lets queues
//! grow without bound and every request blows its SLO, while a shedding
//! policy keeps the admitted fraction inside the latency budget (see
//! `benches/fig51_overload_sweep.rs`).
//!
//! Policies:
//!
//! * [`AdmitAll`] — the closed-system baseline; never sheds.
//! * [`QueueDepthShed`] — sheds when every instance's engine-visible
//!   depth (running + queued) is at or above a threshold.
//! * [`TtftShed`] — sheds on a cost-model TTFT estimate: pending prefill
//!   tokens on the least-loaded instance, priced by the profile.
//! * [`SessionAwareShed`] — wraps any inner policy with the
//!   conversation-integrity rule: a session with admitted turns is never
//!   shed mid-conversation (its later turns bypass the inner check), and
//!   a session rejected at turn 0 stays rejected, so no orphaned turns
//!   are ever produced.

use std::collections::HashSet;

use crate::engine::ModelProfile;
use crate::router::RouteCtx;
use crate::util::Registry;

/// The shared name-listing registry ([`crate::util::Registry`]). Note
/// the historical wording: this builder says "valid policies", not
/// "valid admission policies", and the migration keeps it byte-exact.
const REGISTRY: Registry = Registry::new(
    "admission policy",
    "policies",
    &["admit_all", "queue_shed", "ttft_shed", "session_shed"],
);

/// Decides, per arrival, whether the cluster accepts the request.
/// Stateful (counters, session memory) and consulted in arrival order.
pub trait AdmissionPolicy: Send {
    fn name(&self) -> String;
    /// `true` = admit (route + enqueue), `false` = shed.
    fn admit(&mut self, ctx: &RouteCtx) -> bool;
}

/// Forwarding impl so a caller can lend a policy to a run and inspect
/// its state (peak counters) afterwards:
/// `spec.with_admission(Box::new(&mut probe))`.
impl<T: AdmissionPolicy + ?Sized> AdmissionPolicy for &mut T {
    fn name(&self) -> String {
        (**self).name()
    }

    fn admit(&mut self, ctx: &RouteCtx) -> bool {
        (**self).admit(ctx)
    }
}

/// Admit everything — the degenerate policy every closed-system run
/// implicitly uses.
#[derive(Debug, Default)]
pub struct AdmitAll;

impl AdmissionPolicy for AdmitAll {
    fn name(&self) -> String {
        "admit_all".into()
    }

    fn admit(&mut self, _ctx: &RouteCtx) -> bool {
        true
    }
}

/// Shed when the *least-loaded* instance already holds `max_depth`
/// requests (running + queued): if even the best placement is saturated,
/// the cluster as a whole is. `peak_min_depth` records the high-water
/// mark of that best-placement depth, so a probe run with
/// `max_depth = usize::MAX` measures the uncongested operating range.
#[derive(Debug)]
pub struct QueueDepthShed {
    pub max_depth: usize,
    pub peak_min_depth: usize,
}

impl QueueDepthShed {
    pub fn new(max_depth: usize) -> QueueDepthShed {
        QueueDepthShed {
            max_depth,
            peak_min_depth: 0,
        }
    }
}

impl AdmissionPolicy for QueueDepthShed {
    fn name(&self) -> String {
        format!("queue_shed({})", self.max_depth)
    }

    fn admit(&mut self, ctx: &RouteCtx) -> bool {
        // Only routable instances can take the request — a crashed or
        // draining replica's (empty) queue must not make the cluster look
        // uncongested. With no routable instance at all the request is
        // admitted and parked by the DES until one recovers.
        let min_depth = (0..ctx.n())
            .filter(|&i| ctx.inds[i].routable)
            .map(|i| ctx.inds[i].bs())
            .min()
            .unwrap_or(0);
        self.peak_min_depth = self.peak_min_depth.max(min_depth);
        min_depth < self.max_depth
    }
}

/// Shed on a cost-model TTFT estimate: the pending prefill work ahead of
/// this request on its best placement (queued prefill tokens + its own
/// new tokens), priced at the profile's per-token prefill rate. Cheap,
/// allocation-free, and directly in SLO units. `peak_est_us` records the
/// largest estimate seen, for probe runs.
#[derive(Debug)]
pub struct TtftShed {
    pub budget_us: f64,
    pub peak_est_us: f64,
    step_fixed_us: f64,
    prefill_us_per_token: f64,
}

impl TtftShed {
    pub fn new(budget_us: f64, profile: &ModelProfile) -> TtftShed {
        TtftShed {
            budget_us,
            peak_est_us: 0.0,
            step_fixed_us: profile.step_fixed_us,
            prefill_us_per_token: profile.prefill_us_per_token,
        }
    }

    fn estimate_us(&self, ctx: &RouteCtx) -> f64 {
        // Best *routable* placement only — see QueueDepthShed::admit.
        let best = (0..ctx.n())
            .filter(|&i| ctx.inds[i].routable)
            .map(|i| ctx.p_token(i))
            .min()
            .unwrap_or(0);
        self.step_fixed_us + best as f64 * self.prefill_us_per_token
    }
}

impl AdmissionPolicy for TtftShed {
    fn name(&self) -> String {
        format!("ttft_shed({:.0}ms)", self.budget_us / 1000.0)
    }

    fn admit(&mut self, ctx: &RouteCtx) -> bool {
        let est = self.estimate_us(ctx);
        self.peak_est_us = self.peak_est_us.max(est);
        est <= self.budget_us
    }
}

/// Conversation-integrity wrapper: shed decisions are made once per
/// *session*, at its first turn, by the inner policy. Later turns of an
/// admitted session always pass (a mid-conversation rejection orphans
/// the session's cached context and wastes every token already spent on
/// it); turns of a rejected session always fail (the client saw the
/// rejection and went away). Sessionless requests (`session_id == 0`)
/// fall through to the inner policy per-request.
pub struct SessionAwareShed {
    inner: Box<dyn AdmissionPolicy>,
    admitted: HashSet<u64>,
    rejected: HashSet<u64>,
}

impl SessionAwareShed {
    pub fn new(inner: Box<dyn AdmissionPolicy>) -> SessionAwareShed {
        SessionAwareShed {
            inner,
            admitted: HashSet::new(),
            rejected: HashSet::new(),
        }
    }
}

impl AdmissionPolicy for SessionAwareShed {
    fn name(&self) -> String {
        format!("session_shed[{}]", self.inner.name())
    }

    fn admit(&mut self, ctx: &RouteCtx) -> bool {
        let sid = ctx.session_id;
        if sid == 0 {
            return self.inner.admit(ctx);
        }
        if self.admitted.contains(&sid) {
            return true;
        }
        if self.rejected.contains(&sid) {
            return false;
        }
        let ok = self.inner.admit(ctx);
        if ok {
            self.admitted.insert(sid);
        } else {
            self.rejected.insert(sid);
        }
        ok
    }
}

/// Registry names, in display order. Mirrors `policy::all_names`.
pub fn all_admission_names() -> Vec<&'static str> {
    REGISTRY.names()
}

/// The parameter each named policy gets when the caller has no opinion:
/// queue depths in requests, TTFT budgets in seconds.
pub fn default_admission_param(name: &str) -> f64 {
    match name {
        "queue_shed" | "session_shed" => 192.0,
        "ttft_shed" => 2.0,
        _ => 0.0,
    }
}

/// Build an admission policy by registry name. `param` is the queue
/// depth for `queue_shed`/`session_shed` and the TTFT budget (seconds)
/// for `ttft_shed`; ignored by `admit_all`. The error lists the valid
/// names, mirroring `policy::build`'s contract.
pub fn build_admission(
    name: &str,
    param: f64,
    profile: &ModelProfile,
) -> Result<Box<dyn AdmissionPolicy>, String> {
    Ok(match name {
        "admit_all" => Box::new(AdmitAll),
        "queue_shed" => Box::new(QueueDepthShed::new(param.max(1.0) as usize)),
        "ttft_shed" => Box::new(TtftShed::new(param * 1e6, profile)),
        "session_shed" => {
            let inner = QueueDepthShed::new(param.max(1.0) as usize);
            Box::new(SessionAwareShed::new(Box::new(inner)))
        }
        _ => return Err(REGISTRY.unknown(name)),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::router::{Indicators, RouteCtx};

    fn inds(depths: &[usize]) -> Vec<Indicators> {
        depths
            .iter()
            .map(|&d| Indicators {
                r_bs: d,
                q_bs: 0,
                queued_prefill_tokens: d * 100,
                total_context_tokens: 0,
                kv_used_blocks: 0,
                kv_capacity_blocks: 1000,
                routable: true,
            })
            .collect()
    }

    fn ctx(inds: &[Indicators], sid: u64) -> RouteCtx {
        RouteCtx::new(0, 1, 0, 200, vec![0; inds.len()], inds.to_vec()).with_session(sid)
    }

    #[test]
    fn queue_depth_uses_least_loaded_instance() {
        let mut p = QueueDepthShed::new(4);
        let free = inds(&[9, 9, 1]);
        assert!(p.admit(&ctx(&free, 0)), "one free instance admits");
        let full = inds(&[9, 9, 4]);
        assert!(!p.admit(&ctx(&full, 0)), "all at threshold sheds");
        assert_eq!(p.peak_min_depth, 4, "probe records the best-placement peak");
    }

    #[test]
    fn ttft_shed_prices_pending_prefill() {
        let profile = ModelProfile::moe_30b();
        let mut tight = TtftShed::new(profile.step_fixed_us + 1.0, &profile);
        let loaded = inds(&[2, 3, 4]);
        assert!(!tight.admit(&ctx(&loaded, 0)), "pending prefill blows a ~0 budget");
        let mut lavish = TtftShed::new(1e9, &profile);
        assert!(lavish.admit(&ctx(&loaded, 0)));
        assert!(lavish.peak_est_us > 0.0);
    }

    #[test]
    fn shed_policies_ignore_unroutable_instances() {
        // The idle instance is dead: its empty queue must not admit.
        let mut i = inds(&[9, 9, 0]);
        i[2].routable = false;
        let mut q = QueueDepthShed::new(4);
        assert!(!q.admit(&ctx(&i, 0)), "dead idle replica cannot admit");
        let profile = ModelProfile::moe_30b();
        let mut t = TtftShed::new(profile.step_fixed_us + 1.0, &profile);
        assert!(!t.admit(&ctx(&i, 0)), "dead replica cannot price TTFT");
        // No routable instance at all: admit and let the DES park it.
        let mut all_dead = inds(&[9, 9]);
        all_dead[0].routable = false;
        all_dead[1].routable = false;
        assert!(q.admit(&ctx(&all_dead, 0)));
        assert!(t.admit(&ctx(&all_dead, 0)));
    }

    #[test]
    fn session_shed_is_sticky_both_ways() {
        // Inner threshold 1: admits only when some instance is empty.
        let mut p = SessionAwareShed::new(Box::new(QueueDepthShed::new(1)));
        let free = inds(&[0, 0]);
        let busy = inds(&[5, 5]);
        assert!(p.admit(&ctx(&free, 7)), "session 7 admitted at turn 0");
        assert!(p.admit(&ctx(&busy, 7)), "later turns bypass the inner check");
        assert!(!p.admit(&ctx(&busy, 8)), "session 8 rejected at turn 0");
        assert!(!p.admit(&ctx(&free, 8)), "rejected sessions stay rejected");
        // Sessionless traffic falls through per-request.
        assert!(p.admit(&ctx(&free, 0)));
        assert!(!p.admit(&ctx(&busy, 0)));
    }

    #[test]
    fn registry_builds_and_rejects_with_name_list() {
        let profile = ModelProfile::moe_30b();
        for name in all_admission_names() {
            let p = build_admission(name, default_admission_param(name), &profile);
            assert!(p.is_ok(), "{name} must build");
        }
        let err = build_admission("yolo", 1.0, &profile).err().unwrap();
        assert_eq!(
            err,
            "unknown admission policy 'yolo'; valid policies: admit_all, \
             queue_shed, ttft_shed, session_shed",
            "pre-migration wording, byte-exact"
        );
        for name in all_admission_names() {
            assert!(err.contains(name), "error must list {name}");
        }
        assert_eq!(
            all_admission_names(),
            vec!["admit_all", "queue_shed", "ttft_shed", "session_shed"]
        );
    }
}
