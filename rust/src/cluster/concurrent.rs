//! R-router concurrent scheduling over the sharded data plane.
//!
//! [`run_concurrent`] replays an open-loop trace through the same
//! discrete-event core as [`super::run_des`], but fans route decisions
//! across R worker threads scoring a PINNED factory view in parallel:
//!
//! 1. **Batch.** Consecutive `Arrival` events at the head of the event
//!    queue are drained into a batch of at most `staleness_budget + 1`
//!    requests (a `StepEnd` stops the drain, so batching never reorders
//!    router-visible engine feedback).
//! 2. **Score.** The factory epoch is pinned; `std::thread::scope`
//!    workers fill worker-owned [`RouteCtx`]s through the read-only
//!    [`IndicatorFactory::fill_route_ctx`] path (`&self`, no lock — the
//!    sharded index's `match_with` is the reason this is sound) and run
//!    their own policy replica. Request-to-worker assignment is a pure
//!    function of the global decision counter, so a run's decision→worker
//!    mapping is deterministic and independent of thread timing.
//! 3. **Merge.** Decisions commit in arrival order through
//!    [`IndicatorFactory::commit_route`], replaying exactly the serial
//!    core's mutation sequence. The j-th decision of a batch scored a view
//!    j commits stale — that j is recorded as the decision's snapshot age,
//!    bounded by construction at `staleness_budget`.
//!
//! With `staleness_budget == 0` every batch has one request, each decision
//! scores the fully-fresh state, and the run is byte-identical to
//! [`super::run_des`] — `tests/concurrent.rs` pins this for R ∈ {1, 2}.
//! With R > 1 the policy is replicated per worker, so runs are identical
//! to serial for stateless policies (every registered indicator policy;
//! stateful ones like `sticky` shard their affinity state per worker and
//! may diverge — by design, that's what per-router state costs).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::Arc;
use std::time::Instant;

use super::des::{begin_step, ClusterConfig};
use crate::engine::{EngineEvent, Instance, InstanceProfile, StepOutcome};
use crate::metrics::RunMetrics;
use crate::router::{GuardCounters, IndicatorFactory, Policy, RouteCtx};
use crate::trace::{Trace, TraceRequest};

/// Knobs of the concurrent harness.
#[derive(Debug, Clone, Copy)]
pub struct ConcurrentCfg {
    /// Router workers scoring in parallel (≥ 1).
    pub routers: usize,
    /// Max commits a decision's pinned view may be stale by. 0 = every
    /// decision scores fresh state (byte-identical to the serial core);
    /// larger budgets admit bigger scoring batches.
    pub staleness_budget: usize,
}

impl ConcurrentCfg {
    pub fn new(routers: usize, staleness_budget: usize) -> Self {
        assert!(routers >= 1, "need at least one router");
        ConcurrentCfg {
            routers,
            staleness_budget,
        }
    }
}

// `cluster::des`'s Event is private to its core; the concurrent loop
// keeps its own copy with identical ordering semantics.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Event {
    Arrival(usize),
    StepEnd(usize),
}

/// One router worker: an owned policy replica plus the scratch buffers
/// its read-only context fills live in. Workers never touch the factory
/// mutably — all commits happen at the merge step on the coordinator.
struct RouterWorker {
    policy: Box<dyn Policy>,
    ctx: RouteCtx,
    live: Vec<u64>,
    /// Guard counters at worker creation, so the run reports deltas even
    /// though policy replicas accumulate over their lifetime.
    guard_start: GuardCounters,
}

/// A worker's routing output, merged on the coordinator in arrival order.
#[derive(Debug, Clone, Copy, Default)]
struct RoutedOut {
    instance: usize,
    predicted_ttft_us: Option<f64>,
    /// `ctx.new_tokens(instance)` at decision time — the worker's view
    /// priced this, so the commit must apply this (not a recomputed one).
    new_tokens: usize,
    /// Raw hit-block sum of the walk, recorded at merge.
    hit_blocks: usize,
    /// Policy scoring time (the decision-throughput numerator excludes
    /// context fills on purpose: serial `sched_overhead_us` times only
    /// `policy.route` too).
    decision_ns: u64,
}

impl RouterWorker {
    fn route_one(&mut self, factory: &IndicatorFactory, tr: &TraceRequest) -> RoutedOut {
        let hit_blocks =
            factory.fill_route_ctx(&tr.req, tr.req.arrival_us, &mut self.ctx, &mut self.live);
        let t0 = Instant::now();
        let decision = self.policy.route(&self.ctx);
        let decision_ns = t0.elapsed().as_nanos() as u64;
        RoutedOut {
            instance: decision.instance,
            predicted_ttft_us: decision.predicted_ttft_us,
            new_tokens: self.ctx.new_tokens(decision.instance),
            hit_blocks,
            decision_ns,
        }
    }
}

/// Replay `trace` open-loop with `ccfg.routers` concurrent router workers
/// under a bounded staleness budget. `make_policy` builds one policy
/// replica per worker (they must be built identically — same name, same
/// parameters — for the determinism contract to hold).
///
/// Returns the same [`RunMetrics`] as [`super::run_des`], plus the
/// concurrency extras: `snapshot_age` (commits of staleness per
/// decision), `route_wall_s` (wall time of the scoring phase, the
/// decisions/s denominator) and `routers`.
pub fn run_concurrent(
    cfg: &ClusterConfig,
    trace: &Trace,
    make_policy: &mut dyn FnMut() -> Box<dyn Policy>,
    ccfg: &ConcurrentCfg,
) -> RunMetrics {
    let n = cfg.n_instances;
    let r = ccfg.routers;
    let reqs: Vec<TraceRequest> = trace.requests.to_vec();
    let mut workers: Vec<RouterWorker> = (0..r)
        .map(|_| {
            let policy = make_policy();
            let guard_start = policy.guard_counters().unwrap_or_default();
            RouterWorker {
                policy,
                ctx: RouteCtx::default(),
                live: Vec::new(),
                guard_start,
            }
        })
        .collect();

    let mut instances: Vec<Instance> = (0..n)
        .map(|i| Instance::new(i, cfg.engine_for(i)))
        .collect();
    let mut factory = IndicatorFactory::new(n, cfg.engine.kv_capacity_blocks);
    // Same arming rule as the serial core: uniform single-model runs
    // keep the fleet vectors empty and replay bit-identically.
    if !cfg.fleet.is_uniform() || reqs.iter().any(|tr| tr.req.model_id != 0) {
        let profiles: Vec<InstanceProfile> =
            (0..n).map(|i| cfg.fleet.profile_for(i).clone()).collect();
        factory.set_fleet(&profiles, &cfg.engine.profile);
    }
    let mut metrics = RunMetrics::new(n);
    let mut stepping = vec![false; n];
    let mut pending: Vec<Option<StepOutcome>> = (0..n).map(|_| None).collect();
    let mut full_hashes: HashMap<u64, Arc<[u64]>> = HashMap::new();
    let mut predicted: HashMap<u64, f64> = HashMap::new();
    let mut arrivals: HashMap<u64, u64> = HashMap::new();

    let mut queue: BinaryHeap<(Reverse<u64>, Reverse<u64>, Event)> = BinaryHeap::new();
    let mut tiebreak: u64 = 0;
    let push = |q: &mut BinaryHeap<(Reverse<u64>, Reverse<u64>, Event)>,
                    tb: &mut u64,
                    t: u64,
                    e: Event| {
        *tb += 1;
        q.push((Reverse(t), Reverse(*tb), e));
    };
    for (i, tr) in reqs.iter().enumerate() {
        push(&mut queue, &mut tiebreak, tr.req.arrival_us, Event::Arrival(i));
    }

    // Deterministic request→worker assignment: the k-th decision of the
    // run goes to worker k % R, independent of batch boundaries.
    let mut decision_counter: usize = 0;
    let mut route_wall = std::time::Duration::ZERO;
    let mut batch: Vec<usize> = Vec::new();
    let mut routed: Vec<RoutedOut> = Vec::new();

    let mut last_time = 0u64;
    while let Some((Reverse(now), _, event)) = queue.pop() {
        last_time = last_time.max(now);
        match event {
            Event::Arrival(idx) => {
                // Drain consecutive arrivals into one scoring batch. A
                // StepEnd at the queue head stops the drain: engine
                // feedback is never reordered past a decision.
                batch.clear();
                batch.push(idx);
                while batch.len() < ccfg.staleness_budget + 1 {
                    match queue.peek() {
                        Some(&(Reverse(t), _, Event::Arrival(_))) => {
                            let Some((_, _, Event::Arrival(j))) = queue.pop() else {
                                unreachable!("peeked arrival");
                            };
                            last_time = last_time.max(t);
                            batch.push(j);
                        }
                        _ => break,
                    }
                }

                // Score the whole batch from the pinned factory state.
                let pin_epoch = factory.epoch();
                routed.clear();
                routed.resize(batch.len(), RoutedOut::default());
                let t0 = Instant::now();
                if r == 1 || batch.len() == 1 {
                    // Degenerate fan-out: score inline on the owning
                    // worker (identical assignment, no thread overhead).
                    for (j, &bidx) in batch.iter().enumerate() {
                        let w = (decision_counter + j) % r;
                        routed[j] = workers[w].route_one(&factory, &reqs[bidx]);
                    }
                } else {
                    let factory_ref = &factory;
                    let reqs_ref = &reqs;
                    let batch_ref = &batch;
                    let dc = decision_counter;
                    let outs: Vec<Vec<(usize, RoutedOut)>> = std::thread::scope(|scope| {
                        let handles: Vec<_> = workers
                            .iter_mut()
                            .enumerate()
                            .map(|(w, worker)| {
                                scope.spawn(move || {
                                    let mut outs = Vec::new();
                                    for (j, &bidx) in batch_ref.iter().enumerate() {
                                        if (dc + j) % r == w {
                                            outs.push((
                                                j,
                                                worker.route_one(factory_ref, &reqs_ref[bidx]),
                                            ));
                                        }
                                    }
                                    outs
                                })
                            })
                            .collect();
                        handles.into_iter().map(|h| h.join().unwrap()).collect()
                    });
                    for outs in outs {
                        for (j, out) in outs {
                            routed[j] = out;
                        }
                    }
                }
                route_wall += t0.elapsed();
                debug_assert_eq!(
                    factory.epoch(),
                    pin_epoch,
                    "torn snapshot: factory mutated during the scoring phase"
                );

                // Merge: commit every decision in arrival order, exactly
                // the serial core's per-arrival sequence.
                for (j, &bidx) in batch.iter().enumerate() {
                    let tr = &reqs[bidx];
                    let out = routed[j];
                    let now_j = tr.req.arrival_us;
                    metrics
                        .sched_overhead_us
                        .push(out.decision_ns as f64 / 1000.0);
                    // Commits since pin == j: the age this decision's
                    // view had accumulated when it merged.
                    metrics.snapshot_age.push((factory.epoch() - pin_epoch) as f64);
                    let d = out.instance;
                    debug_assert!(d < n, "policy routed out of range");
                    factory.kv.record_lookup(tr.req.block_hashes.len(), out.hit_blocks);
                    factory.commit_route(d, &tr.req, out.new_tokens, now_j);
                    if let Some(p) = out.predicted_ttft_us {
                        predicted.insert(tr.req.id, p);
                    }
                    arrivals.insert(tr.req.id, tr.req.arrival_us);
                    full_hashes.insert(tr.req.id, tr.full_hashes.clone());
                    instances[d].enqueue(tr.req.clone(), tr.full_hashes.clone(), now_j);
                    if !stepping[d] {
                        if let Some(out2) = begin_step(&mut instances[d], now_j, &mut metrics, d) {
                            let end = now_j + out2.duration_us;
                            pending[d] = Some(out2);
                            stepping[d] = true;
                            push(&mut queue, &mut tiebreak, end, Event::StepEnd(d));
                        }
                    }
                    decision_counter += 1;
                }
            }
            Event::StepEnd(d) => {
                let out = pending[d].take().expect("StepEnd without outcome");
                for ev in &out.events {
                    match ev {
                        EngineEvent::FirstToken { req_id, at_us } => {
                            let pred = predicted.remove(req_id);
                            let arr = arrivals.remove(req_id);
                            if let (Some(pred), Some(arr)) = (pred, arr) {
                                let actual = (*at_us - arr) as f64;
                                if actual > 0.0 {
                                    metrics
                                        .sim_error_ratio
                                        .push((pred - actual).abs() / actual);
                                }
                            }
                        }
                        EngineEvent::Completed { record } => {
                            metrics.records.push(*record);
                            if let Some(fh) = full_hashes.remove(&record.id) {
                                factory.on_completion(d, &fh, now);
                            }
                            predicted.remove(&record.id);
                            arrivals.remove(&record.id);
                        }
                    }
                }
                factory.on_snapshot(d, out.snapshot);
                instances[d].recycle_events(out.events);
                if instances[d].has_work() {
                    if let Some(out2) = begin_step(&mut instances[d], now, &mut metrics, d) {
                        let end = now + out2.duration_us;
                        pending[d] = Some(out2);
                        push(&mut queue, &mut tiebreak, end, Event::StepEnd(d));
                    } else {
                        stepping[d] = false;
                    }
                } else {
                    stepping[d] = false;
                }
            }
        }
    }

    metrics.duration_us = last_time;
    for inst in &instances {
        metrics.total_steps += inst.steps;
        metrics.admit_radix_walks += inst.kv().admit_radix_walks;
        metrics.models.cold_loads += inst.models().cold_loads;
        metrics.models.evictions += inst.models().evictions;
        metrics.models.swap_us += inst.models().swap_us;
    }
    // Guard counters: sum each worker replica's delta since creation.
    let mut guard = GuardCounters::default();
    for w in &workers {
        let d = w
            .policy
            .guard_counters()
            .unwrap_or_default()
            .since(w.guard_start);
        guard.checks += d.checks;
        guard.degenerate += d.degenerate;
        guard.inversion += d.inversion;
        guard.mitigated += d.mitigated;
    }
    metrics.guard = guard;
    metrics.routers = r;
    metrics.route_wall_s = route_wall.as_secs_f64();
    metrics
}
