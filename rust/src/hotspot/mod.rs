//! §5.2 — the KV$-hotspot failure-case detector and mitigation.
//!
//! The multiplicative score fails only when a *hotspot class* violates
//! Eq. 2: its relative popularity x/x̄ exceeds its relative cache coverage
//! |M|/|M̄| (M = instances caching the class prefix). Then every class
//! request lands on M, BS growth cannot offset the P-token discount, and
//! M overloads.
//!
//! The detector runs alongside every scheduling decision:
//! * **Phase 1** (necessary condition): per class, over a sliding 1-minute
//!   window, monitor x/x̄ vs |M|/|M̄|; violation raises an alarm.
//! * **Phase 2** (confirmation): after an alarm, count consecutive class
//!   requests whose best multiplicative score on M undercuts the best on
//!   M̄ — i.e. requests that would *actually* keep piling onto M. At
//!   2·|M| consecutive confirmations, activate mitigation: filter M out
//!   of the routing targets for this class and fall back to
//!   load-balancing-only routing for a cool-down window.

use std::collections::HashMap;

use crate::policy::LMetric;
use crate::router::{select_min, Policy, RouteCtx, RouteDecision};

const WINDOW_US: u64 = 60_000_000; // 1-minute popularity window
const COOLDOWN_US: u64 = 60_000_000; // mitigation duration
/// Minimum arrivals in the popularity window before phase 1 may alarm —
/// class shares over a handful of samples are pure noise.
const MIN_SAMPLES: u64 = 30;

/// Rolling per-class arrival counts over the current 1-minute window.
#[derive(Debug, Default)]
struct PopularityWindow {
    window_start: u64,
    total: u64,
    per_class: HashMap<u32, u64>,
    // Previous window's totals (smooths the boundary).
    prev_total: u64,
    prev_per_class: HashMap<u32, u64>,
}

impl PopularityWindow {
    fn observe(&mut self, class: u32, now: u64) {
        let elapsed = now.saturating_sub(self.window_start);
        if elapsed >= WINDOW_US {
            if elapsed >= 2 * WINDOW_US {
                // Idle gap longer than a full window: the "current"
                // counts are themselves ancient. Rolling them into prev
                // (the old behaviour) would blend traffic from arbitrarily
                // far in the past into the Eq. 2 ratio — drop both.
                self.prev_total = 0;
                self.prev_per_class.clear();
                self.total = 0;
                self.per_class.clear();
            } else {
                self.prev_total = self.total;
                self.prev_per_class = std::mem::take(&mut self.per_class);
                self.total = 0;
            }
            self.window_start = now;
        }
        self.total += 1;
        *self.per_class.entry(class).or_insert(0) += 1;
    }

    fn samples(&self) -> u64 {
        self.total + self.prev_total
    }

    /// Class share x over current+previous windows.
    fn share(&self, class: u32) -> f64 {
        let total = self.total + self.prev_total;
        if total == 0 {
            return 0.0;
        }
        let c = self.per_class.get(&class).copied().unwrap_or(0)
            + self.prev_per_class.get(&class).copied().unwrap_or(0);
        c as f64 / total as f64
    }
}

#[derive(Debug, Default)]
struct AlarmState {
    consecutive: usize,
    mitigated_until: u64,
}

/// The two-phase detector. Generic over the wrapped score via [`LMetric`]
/// (the phase-2 comparison must reuse the production score arithmetic).
pub struct HotspotDetector {
    popularity: PopularityWindow,
    alarms: HashMap<u32, AlarmState>,
    /// Counters for analysis (Figs 20/21).
    pub phase1_alarms: u64,
    pub mitigations: u64,
}

impl HotspotDetector {
    pub fn new() -> Self {
        HotspotDetector {
            popularity: PopularityWindow::default(),
            alarms: HashMap::new(),
            phase1_alarms: 0,
            mitigations: 0,
        }
    }

    /// The M set: instances whose KV$ holds the request's class prefix
    /// (any cached block of this prompt counts as holding the prefix).
    /// Reads the matched mask the shared prefix index produced during the
    /// routing walk — no re-scan of `hit_tokens`, no allocation on the
    /// decision path (this `Vec` form is for offline analysis; `check`
    /// itself consumes the mask directly).
    pub fn m_set(ctx: &RouteCtx) -> Vec<usize> {
        ctx.matched_mask.iter_ones().collect()
    }

    /// Eq. 2 monitor: x/x̄ vs |M|/|M̄|. Returns the two ratios.
    pub fn ratios(&self, ctx: &RouteCtx) -> (f64, f64) {
        let x = self.popularity.share(ctx.class_id);
        let m = ctx.matched_mask.count();
        let n = ctx.n();
        let pop_ratio = if x >= 1.0 { f64::INFINITY } else { x / (1.0 - x) };
        let cov_ratio = if m >= n {
            f64::INFINITY
        } else {
            m as f64 / (n - m) as f64
        };
        (pop_ratio, cov_ratio)
    }

    /// Run the detector for one request. Returns `true` if mitigation is
    /// active for this class (caller must filter M and load-balance).
    pub fn check(&mut self, ctx: &RouteCtx, score: &LMetric) -> bool {
        self.popularity.observe(ctx.class_id, ctx.now_us);
        // The M-set arrives for free as the routing walk's matched mask —
        // this whole check is allocation-free.
        let m_len = ctx.matched_mask.count();
        let (pop, cov) = self.ratios(ctx);
        let state = self.alarms.entry(ctx.class_id).or_default();

        // Active mitigation?
        if ctx.now_us < state.mitigated_until {
            return true;
        }

        if m_len == 0 || m_len >= ctx.n() {
            state.consecutive = 0;
            return false; // no hotspot possible: nothing cached, or cached everywhere
        }

        if self.popularity.samples() < MIN_SAMPLES {
            return false; // class shares are noise at tiny sample counts
        }

        if pop <= cov {
            // Eq. 2 holds: benign regime; reset phase 2.
            state.consecutive = 0;
            return false;
        }
        self.phase1_alarms += 1;

        // Phase 2: would this request actually pile onto M?
        let mut best_m = f64::INFINITY;
        let mut best_not_m = f64::INFINITY;
        for i in 0..ctx.n() {
            let s = score.score(ctx, i);
            if ctx.matched_mask.get(i) {
                best_m = best_m.min(s);
            } else {
                best_not_m = best_not_m.min(s);
            }
        }
        if best_m <= best_not_m {
            state.consecutive += 1;
            if state.consecutive >= 2 * m_len {
                state.mitigated_until = ctx.now_us + COOLDOWN_US;
                state.consecutive = 0;
                self.mitigations += 1;
                return true;
            }
        } else {
            state.consecutive = 0;
        }
        false
    }
}

impl Default for HotspotDetector {
    fn default() -> Self {
        Self::new()
    }
}

/// LMetric wrapped with the detector — registry name `lmetric_guarded`.
/// On mitigation, routes by pure load balancing restricted to M̄ (the
/// paper's "filter out the suspected instances"). (Previously named
/// `GuardedLMetric`; that name now belongs to the §5 failure-condition
/// guard, [`crate::policy::GuardedLMetric`].)
pub struct HotspotGuarded {
    inner: LMetric,
    pub detector: HotspotDetector,
}

impl HotspotGuarded {
    pub fn new() -> Self {
        HotspotGuarded {
            inner: LMetric::paper(),
            detector: HotspotDetector::new(),
        }
    }
}

impl Default for HotspotGuarded {
    fn default() -> Self {
        Self::new()
    }
}

impl Policy for HotspotGuarded {
    fn name(&self) -> String {
        "lmetric_guarded".into()
    }

    fn route(&mut self, ctx: &RouteCtx) -> RouteDecision {
        if self.detector.check(ctx, &self.inner) {
            // Load-balance over M̄ only (membership straight off the
            // matched mask — no M-set materialization).
            let inst = select_min(ctx, |i| {
                if ctx.matched_mask.get(i) {
                    f64::INFINITY
                } else {
                    ctx.inds[i].bs() as f64
                }
            });
            return RouteDecision::to(inst);
        }
        self.inner.route(ctx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::router::Indicators;

    /// A hotspot-shaped context: class cached on 1 of 4 instances,
    /// everyone idle, full hit on the hot one.
    fn hotspot_ctx(now: u64, class: u32) -> RouteCtx {
        RouteCtx::new(
            now,
            0,
            class,
            1000,
            vec![1000, 0, 0, 0],
            vec![Indicators::default(); 4],
        )
    }

    #[test]
    fn benign_class_never_mitigated() {
        let mut det = HotspotDetector::new();
        let score = LMetric::paper();
        // Mixed traffic: class 1 is only 20% of arrivals, coverage 1/3.
        for k in 0..200u64 {
            let class = if k % 5 == 0 { 1 } else { 2 + (k % 7) as u32 };
            let mut ctx = hotspot_ctx(k * 100_000, class);
            if class != 1 {
                ctx.hit_tokens = vec![0, 1000, 0, 0];
                ctx.recompute_matched_mask();
            }
            det.check(&ctx, &score);
        }
        assert_eq!(det.mitigations, 0);
    }

    #[test]
    fn hotspot_class_detected_and_mitigated() {
        let mut det = HotspotDetector::new();
        let score = LMetric::paper();
        // 100% of traffic is class 1, cached on 1/4 instances:
        // x/x̄ = inf > 1/3 -> phase 1 fires (once past the warmup sample
        // gate), phase 2 confirms after 2|M|=2 consecutive pile-ons.
        let mut mitigated = false;
        for k in 0..60u64 {
            mitigated = det.check(&hotspot_ctx(k * 1000, 1), &score);
            if mitigated {
                break;
            }
        }
        assert!(mitigated, "hotspot must be caught");
        assert!(det.phase1_alarms >= 2);
        assert_eq!(det.mitigations, 1);
    }

    #[test]
    fn mitigation_filters_m_and_load_balances() {
        let mut p = HotspotGuarded::new();
        // Drive into mitigation.
        let mut routed = Vec::new();
        for k in 0..60u64 {
            let mut ctx = hotspot_ctx(k * 1000, 1);
            // make instance 0 visibly loaded so unguarded lmetric still
            // picks it (score 0 from full hit... p_token=0 -> 0 * bs).
            ctx.inds[0].r_bs = 30;
            routed.push(p.route(&ctx).instance);
        }
        // Early routes hit instance 0 (the hotspot), later ones must not.
        assert_eq!(routed[0], 0);
        assert!(
            routed[40..].iter().all(|&i| i != 0),
            "mitigated routes avoid M: {routed:?}"
        );
        assert!(p.detector.mitigations >= 1);
    }

    #[test]
    fn phase2_resets_when_balance_restores() {
        let mut det = HotspotDetector::new();
        let score = LMetric::paper();
        // Alternate: one confirming ctx, then one where M is overloaded
        // enough that the product already favors M̄ (no pile-on).
        for k in 0..120u64 {
            let mut ctx = hotspot_ctx(k * 1000, 1);
            if k % 2 == 1 {
                ctx.hit_tokens = vec![900, 0, 0, 0]; // partial hit
                ctx.recompute_matched_mask();
                ctx.inds[0].r_bs = 100; // (1000-900)*101 > 1000*1
            }
            det.check(&ctx, &score);
        }
        assert_eq!(det.mitigations, 0, "alternating pattern never confirms");
    }

    #[test]
    fn ratios_computed() {
        let mut det = HotspotDetector::new();
        let ctx = hotspot_ctx(0, 1);
        det.check(&ctx, &LMetric::paper());
        let (pop, cov) = det.ratios(&ctx);
        assert!(pop > cov, "single-class traffic on 1/4 coverage violates Eq.2");
        assert!((cov - 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn m_set_reads_matched_mask() {
        let mut ctx = hotspot_ctx(0, 1);
        assert_eq!(HotspotDetector::m_set(&ctx), vec![0]);
        ctx.hit_tokens = vec![16, 0, 32, 0];
        ctx.recompute_matched_mask();
        assert_eq!(HotspotDetector::m_set(&ctx), vec![0, 2]);
    }

    /// Regression for the stale-window bug: after an idle gap longer than
    /// one full window, `observe` used to roll the ancient counts into
    /// `prev_*`, so `share()` kept blending traffic from arbitrarily far
    /// in the past into the Eq. 2 ratio.
    #[test]
    fn idle_gap_expires_previous_window() {
        let mut w = PopularityWindow::default();
        // A burst of pure class-7 traffic in minute 0.
        for k in 0..50u64 {
            w.observe(7, k * 1000);
        }
        assert!((w.share(7) - 1.0).abs() < 1e-12);
        // >2 windows of silence, then one class-9 arrival: the ancient
        // class-7 counts must be gone, not smoothed into prev.
        w.observe(9, 3 * WINDOW_US);
        assert_eq!(w.share(7), 0.0, "ancient traffic leaked into the window");
        assert!((w.share(9) - 1.0).abs() < 1e-12);
        assert_eq!(w.samples(), 1);
        // A normal (< 2 windows) rollover still smooths via prev.
        for k in 0..10u64 {
            w.observe(9, 3 * WINDOW_US + k);
        }
        w.observe(9, 3 * WINDOW_US + WINDOW_US + 1);
        assert!(w.samples() > 1, "adjacent-window smoothing preserved");
    }
}
