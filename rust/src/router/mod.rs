//! The global scheduler's indicator factory and scheduling framework —
//! the paper's §3 analysis framework, reimplemented as a library.
//!
//! The factory owns (a) the last piggybacked [`InstanceSnapshot`] per
//! instance — refreshed whenever a response arrives, exactly as stale as
//! the real system's — plus (b) router-side *optimistic deltas* applied at
//! routing time (the router knows what it just sent where), and (c) the
//! shared multi-instance KV$ prefix index
//! ([`RouterKvView`](crate::kvcache::RouterKvView)): one radix tree whose
//! nodes carry a per-instance presence bitmask, so one walk per request
//! yields every instance's hit length at once.
//!
//! A scheduling policy is a function from a [`RouteCtx`] — the request's
//! per-instance indicator values — to an instance choice, mirroring the
//! paper's Fig. 4 programming model (`score` + `select_min`).
//!
//! **Hot-path contract:** [`IndicatorFactory::route_ctx`] fills reusable
//! scratch buffers (`hit_tokens`, `inds`, `matched_mask`) and hands the
//! policy a *borrowed* [`RouteCtx`]; steady-state routing performs zero
//! heap allocation. Commit the decision with
//! [`IndicatorFactory::on_route`] immediately after (it consumes the
//! scratch state of the same request).

use crate::core::{InstanceMask, Request};
use crate::engine::{InstanceProfile, InstanceSnapshot, ModelProfile, ModelSlots};
use crate::kvcache::RouterKvView;

/// Effective per-instance indicator values at decision time:
/// last snapshot + optimistic deltas since.
#[derive(Debug, Clone, Copy)]
pub struct Indicators {
    pub r_bs: usize,
    pub q_bs: usize,
    pub queued_prefill_tokens: usize,
    pub total_context_tokens: usize,
    pub kv_used_blocks: usize,
    pub kv_capacity_blocks: usize,
    /// Whether the instance accepts new work. Crashed and draining
    /// instances (see [`crate::cluster::lifecycle`]) are kept in the
    /// indicator vector so indices stay stable, but `select_min` /
    /// `select_max` and the session policies skip them.
    pub routable: bool,
}

impl Default for Indicators {
    fn default() -> Self {
        Indicators {
            r_bs: 0,
            q_bs: 0,
            queued_prefill_tokens: 0,
            total_context_tokens: 0,
            kv_used_blocks: 0,
            kv_capacity_blocks: 0,
            // A default-constructed instance is a healthy one: every
            // pre-lifecycle call site (tests, offline tools) builds
            // contexts this way and must keep routing to all instances.
            routable: true,
        }
    }
}

impl Indicators {
    /// The BS indicator (running + queued batch size).
    pub fn bs(&self) -> usize {
        self.r_bs + self.q_bs
    }
}

/// Everything a policy may consult for one routing decision.
#[derive(Debug, Clone, Default)]
pub struct RouteCtx {
    pub now_us: u64,
    pub req_id: u64,
    pub class_id: u32,
    /// Session the request belongs to (0 = sessionless). Lets
    /// session-aware policies key affinity state without any side
    /// channel; indicator-based policies ignore it.
    pub session_id: u64,
    pub input_len: usize,
    /// Prompt tokens already cached per instance (block-aligned).
    pub hit_tokens: Vec<usize>,
    /// Instances holding ≥ 1 cached block of this prompt — the hotspot
    /// detector's M-set, produced by the shared-index walk for free.
    /// Invariant: bit `i` set ⟺ `hit_tokens[i] > 0`.
    pub matched_mask: InstanceMask,
    pub inds: Vec<Indicators>,
    /// Model the request wants served (0 = the fleet-default model,
    /// which every instance holds warm from boot).
    pub model_id: u32,
    /// Per-instance prefill speed relative to the reference device.
    /// EMPTY on uniform fleets — [`Self::prefill_scale`] then reads 1.0
    /// and [`Self::p_time`] divides by exactly 1.0, an IEEE-754
    /// identity, so pre-fleet decisions replay byte-identical.
    pub fleet_prefill_scale: Vec<f64>,
    /// Cold-model penalty per instance, in reference prefill-token
    /// units (0.0 where the request's model is warm). EMPTY on
    /// single-model traffic, however heterogeneous the hardware.
    pub cold_penalty_tokens: Vec<f64>,
}

impl RouteCtx {
    /// Build a context, deriving `matched_mask` from `hit_tokens` (the
    /// factory's hot path fills the mask directly from the index walk;
    /// tests and offline tools construct contexts through here).
    pub fn new(
        now_us: u64,
        req_id: u64,
        class_id: u32,
        input_len: usize,
        hit_tokens: Vec<usize>,
        inds: Vec<Indicators>,
    ) -> Self {
        let matched_mask = InstanceMask::from_hit_tokens(&hit_tokens);
        RouteCtx {
            now_us,
            req_id,
            class_id,
            session_id: 0,
            input_len,
            hit_tokens,
            matched_mask,
            inds,
            model_id: 0,
            fleet_prefill_scale: Vec::new(),
            cold_penalty_tokens: Vec::new(),
        }
    }

    /// Attach a session id (builder-style; [`RouteCtx::new`] defaults to
    /// sessionless so the many non-session call sites stay unchanged).
    pub fn with_session(mut self, session_id: u64) -> Self {
        self.session_id = session_id;
        self
    }

    /// Re-derive `matched_mask` from `hit_tokens` — call after mutating
    /// `hit_tokens` directly (tests crafting adversarial states).
    pub fn recompute_matched_mask(&mut self) {
        self.matched_mask.fill_from_hit_tokens(&self.hit_tokens);
    }

    pub fn n(&self) -> usize {
        self.inds.len()
    }

    /// KV$ hit ratio on instance `i` if routed there.
    pub fn hit_ratio(&self, i: usize) -> f64 {
        if self.input_len == 0 {
            0.0
        } else {
            self.hit_tokens[i] as f64 / self.input_len as f64
        }
    }

    /// New prefill tokens this request would add on instance `i`.
    pub fn new_tokens(&self, i: usize) -> usize {
        self.input_len.saturating_sub(self.hit_tokens[i])
    }

    /// The paper's P-token indicator: queued new prefill tokens on `i`
    /// plus this request's new tokens if routed there (§5.1).
    pub fn p_token(&self, i: usize) -> usize {
        self.inds[i].queued_prefill_tokens + self.new_tokens(i)
    }

    /// Prefill speed of instance `i` relative to the reference device
    /// (1.0 on uniform fleets, where the scale vector is empty).
    pub fn prefill_scale(&self, i: usize) -> f64 {
        self.fleet_prefill_scale.get(i).copied().unwrap_or(1.0)
    }

    /// The cost-aware P indicator: predicted prefill *time* on `i`, in
    /// reference-token units — `p_token / prefill_scale`. On a uniform
    /// fleet the divisor is exactly 1.0, so this is bit-identical to
    /// `p_token as f64`; and because LMetric compares *products*, the
    /// metric's weight cancellation survives any per-instance positive
    /// monotone rescaling (proptest in `tests/proptests.rs`).
    pub fn p_time(&self, i: usize) -> f64 {
        self.p_token(i) as f64 / self.prefill_scale(i)
    }

    /// Cold-model load penalty if routed to `i`, in the same
    /// reference-token units as [`Self::p_time`] (0.0 when the
    /// request's model is warm there, and on single-model traffic).
    pub fn cold_penalty(&self, i: usize) -> f64 {
        self.cold_penalty_tokens.get(i).copied().unwrap_or(0.0)
    }
}

/// A routing decision; `predicted_ttft_us` is filled by simulation-based
/// policies so harnesses can measure simulator error (Fig 16).
#[derive(Debug, Clone, Copy)]
pub struct RouteDecision {
    pub instance: usize,
    pub predicted_ttft_us: Option<f64>,
}

impl RouteDecision {
    pub fn to(instance: usize) -> Self {
        RouteDecision {
            instance,
            predicted_ttft_us: None,
        }
    }
}

/// A scheduling policy (one per baseline; see [`crate::policy`]).
///
/// **Read-only score path.** `route` receives the context by shared
/// reference and has no channel back into the factory or the KV index —
/// a policy can only mutate its OWN state (guard counters, per-session
/// affinity maps). This is audited across `crate::policy` and is what
/// lets `cluster::run_concurrent` score the same pinned snapshot from R
/// workers in parallel: each worker owns a policy replica, and all
/// factory/index mutation happens at the serialized merge step via
/// [`IndicatorFactory::commit_route`].
pub trait Policy: Send {
    fn name(&self) -> String;
    fn route(&mut self, ctx: &RouteCtx) -> RouteDecision;

    /// Failure-condition guard counters, for policies that carry the
    /// guard (see [`crate::policy::GuardedLMetric`]); `None` for
    /// unguarded policies. The DES and live harnesses fold these into
    /// [`crate::metrics::RunMetrics::guard`] at the end of a run.
    fn guard_counters(&self) -> Option<GuardCounters> {
        None
    }
}

/// Counters of the failure-condition guard, one bump per routing
/// decision analyzed. `checks` counts decisions, `degenerate` /
/// `inversion` count detections of the two derived failure regimes, and
/// `mitigated` counts decisions the secondary-key fallback actually
/// *changed* — the paper's "extremely rare in practice" claim is
/// `mitigated == 0` on natural traffic.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GuardCounters {
    pub checks: u64,
    pub degenerate: u64,
    pub inversion: u64,
    pub mitigated: u64,
}

impl GuardCounters {
    /// Counter delta since `start` — policies accumulate over their
    /// lifetime, so a harness reusing one policy across runs snapshots
    /// the counters at run start and reports the difference.
    pub fn since(self, start: GuardCounters) -> GuardCounters {
        GuardCounters {
            checks: self.checks.saturating_sub(start.checks),
            degenerate: self.degenerate.saturating_sub(start.degenerate),
            inversion: self.inversion.saturating_sub(start.inversion),
            mitigated: self.mitigated.saturating_sub(start.mitigated),
        }
    }
}

/// One-pass summary statistics of a decision's two indicator axes — the
/// per-snapshot analysis the failure-condition guard (and any offline
/// tooling) evaluates in O(N) with zero allocation. `axes(i)` returns
/// the (KV-aware, load) factor pair of instance `i`.
#[derive(Debug, Clone, Copy)]
pub struct IndicatorStats {
    pub n: usize,
    pub kv_min: f64,
    pub kv_max: f64,
    pub kv_sum: f64,
    pub load_min: f64,
    pub load_max: f64,
    pub load_sum: f64,
    /// Instances whose KV-axis factor is exactly zero (P-token = 0 in
    /// the paper configuration: full prefix hit and an empty queue).
    pub kv_zeros: usize,
    /// Every instance idle (`BS == 0`, so the load factor ties at 1).
    pub all_idle: bool,
}

impl IndicatorStats {
    pub fn collect(ctx: &RouteCtx, mut axes: impl FnMut(usize) -> (f64, f64)) -> IndicatorStats {
        let n = ctx.n();
        let mut s = IndicatorStats {
            n,
            kv_min: f64::INFINITY,
            kv_max: 0.0,
            kv_sum: 0.0,
            load_min: f64::INFINITY,
            load_max: 0.0,
            load_sum: 0.0,
            kv_zeros: 0,
            all_idle: n > 0,
        };
        for i in 0..n {
            let (kv, load) = axes(i);
            s.kv_min = s.kv_min.min(kv);
            s.kv_max = s.kv_max.max(kv);
            s.kv_sum += kv;
            s.load_min = s.load_min.min(load);
            s.load_max = s.load_max.max(load);
            s.load_sum += load;
            if kv == 0.0 {
                s.kv_zeros += 1;
            }
            if ctx.inds[i].bs() != 0 {
                s.all_idle = false;
            }
        }
        s
    }

    pub fn kv_mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.kv_sum / self.n as f64
        }
    }

    pub fn load_mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.load_sum / self.n as f64
        }
    }

    /// Cross-instance spread ratio (max/min) of the KV axis: 1.0 when
    /// flat (or empty), ∞ when a zero coexists with a non-zero value.
    pub fn kv_spread(&self) -> f64 {
        spread_ratio(self.kv_min, self.kv_max)
    }

    /// Cross-instance spread ratio of the load axis.
    pub fn load_spread(&self) -> f64 {
        spread_ratio(self.load_min, self.load_max)
    }
}

fn spread_ratio(min: f64, max: f64) -> f64 {
    if max <= 0.0 || !min.is_finite() {
        1.0
    } else if min == 0.0 {
        f64::INFINITY
    } else {
        max / min
    }
}

/// `instances.select_min(score)` from the paper's programming model:
/// minimal score wins; ties break on smaller BS, then lower index
/// (deterministic, so every figure is reproducible).
///
/// Unroutable instances (crashed / draining; see
/// [`crate::cluster::lifecycle`]) are skipped — when every instance is
/// routable the scan is bit-for-bit the pre-lifecycle one. If *no*
/// instance is routable the fallback is index 0; harnesses must not
/// dispatch in that state (the DES requeues instead).
pub fn select_min(ctx: &RouteCtx, score: impl Fn(usize) -> f64) -> usize {
    let mut best = 0usize;
    let mut best_key = (f64::INFINITY, usize::MAX);
    for i in 0..ctx.n() {
        if !ctx.inds[i].routable {
            continue;
        }
        let key = (score(i), ctx.inds[i].bs());
        if key.0 < best_key.0 || (key.0 == best_key.0 && key.1 < best_key.1) {
            best_key = key;
            best = i;
        }
    }
    best
}

/// `select_max` with the same deterministic tie-breaks.
pub fn select_max(ctx: &RouteCtx, score: impl Fn(usize) -> f64) -> usize {
    select_min(ctx, |i| -score(i))
}

/// The indicator factory (§3): holds stale snapshots + optimistic deltas
/// + the shared KV$ prefix index; builds [`RouteCtx`]s into reusable
/// scratch buffers; absorbs response piggybacks.
pub struct IndicatorFactory {
    snapshots: Vec<InstanceSnapshot>,
    // Optimistic deltas since the instance's last response.
    opt_q_bs: Vec<usize>,
    opt_prefill_tokens: Vec<usize>,
    opt_ctx_tokens: Vec<usize>,
    /// Router-side routability flags (lifecycle layer): `false` for
    /// crashed or draining instances. Copied into every context's
    /// [`Indicators`] so policies see liveness with zero extra plumbing.
    routable: Vec<bool>,
    pub kv: RouterKvView,
    /// Reusable decision context — the allocation-free hot path.
    scratch: RouteCtx,
    /// Reusable live-set scratch for the serial walk.
    walk_live: Vec<u64>,
    /// Factory-state epoch: bumped on every mutation (route commit,
    /// snapshot absorb, completion). Concurrent readers pin this to
    /// measure how many commits their view is stale by.
    epoch: u64,
    // --- heterogeneous-fleet state (all EMPTY on uniform single-model
    // fleets — the byte-identity fast path never consults it) ----------
    /// Per-slot hardware profile, as installed by [`Self::set_fleet`].
    fleet_profiles: Vec<InstanceProfile>,
    /// `prefill_scale` of each slot, copied into every context.
    fleet_scales: Vec<f64>,
    /// Cold-load penalty of each slot in reference prefill-token units:
    /// `swap_cost_us / prefill_us_per_token` of the serving model.
    fleet_cold_tokens: Vec<f64>,
    /// The serving model's per-token prefill cost, kept so scale-up can
    /// derive a new slot's penalty in the same units `set_fleet` used.
    fleet_model_tok_us: f64,
    /// The router's optimistic mirror of each instance's warm-model
    /// set, advanced at commit time with the same keepalive/eviction
    /// draw as the engine's authoritative [`ModelSlots`].
    model_dirs: Vec<ModelSlots>,
    /// Set once any committed request asked for a model other than 0.
    /// Until then `cold_penalty_tokens` stays empty, so single-model
    /// traffic prices decisions identically to pre-multiplexing code.
    multi_seen: bool,
}

impl IndicatorFactory {
    pub fn new(n_instances: usize, kv_capacity_blocks: usize) -> Self {
        IndicatorFactory {
            snapshots: vec![InstanceSnapshot::default(); n_instances],
            opt_q_bs: vec![0; n_instances],
            opt_prefill_tokens: vec![0; n_instances],
            opt_ctx_tokens: vec![0; n_instances],
            routable: vec![true; n_instances],
            kv: RouterKvView::new(n_instances, kv_capacity_blocks),
            scratch: RouteCtx {
                now_us: 0,
                req_id: u64::MAX,
                class_id: 0,
                session_id: 0,
                input_len: 0,
                hit_tokens: Vec::with_capacity(n_instances),
                matched_mask: InstanceMask::with_capacity(n_instances),
                inds: Vec::with_capacity(n_instances),
                model_id: 0,
                fleet_prefill_scale: Vec::new(),
                cold_penalty_tokens: Vec::new(),
            },
            walk_live: Vec::new(),
            epoch: 0,
            fleet_profiles: Vec::new(),
            fleet_scales: Vec::new(),
            fleet_cold_tokens: Vec::new(),
            fleet_model_tok_us: 0.0,
            model_dirs: Vec::new(),
            multi_seen: false,
        }
    }

    /// Install per-instance hardware profiles and arm the warm-model
    /// directory — the heterogeneous / multi-model mode switch. Uniform
    /// single-model harnesses never call this, and the factory then
    /// never fills a scale or penalty vector (byte-identity). `model`
    /// is the served [`ModelProfile`]; it converts each slot's swap
    /// cost into the reference-token units [`RouteCtx::p_time`] uses.
    pub fn set_fleet(&mut self, profiles: &[InstanceProfile], model: &ModelProfile) {
        assert_eq!(
            profiles.len(),
            self.snapshots.len(),
            "one profile per instance"
        );
        self.fleet_profiles = profiles.to_vec();
        self.fleet_scales = profiles.iter().map(|p| p.prefill_scale).collect();
        self.fleet_model_tok_us = model.prefill_us_per_token;
        self.fleet_cold_tokens = profiles
            .iter()
            .map(|p| p.swap_cost_us() as f64 / model.prefill_us_per_token)
            .collect();
        self.model_dirs = profiles
            .iter()
            .enumerate()
            .map(|(i, p)| ModelSlots::new(i, p))
            .collect();
        self.multi_seen = false;
        self.epoch += 1;
    }

    /// The router's optimistic view of instance `i`'s warm-model set
    /// (`None` until [`Self::set_fleet`] arms the directory).
    pub fn model_dir(&self, i: usize) -> Option<&ModelSlots> {
        self.model_dirs.get(i)
    }

    pub fn n_instances(&self) -> usize {
        self.snapshots.len()
    }

    /// Mutation epoch of the whole factory state (indicators + KV index):
    /// bumped once per commit/snapshot/completion. A concurrent router
    /// pins it before scoring and measures snapshot age as "commits since
    /// pin" at its own merge time.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Build the per-instance indicator view for a request into CALLER-
    /// owned buffers, through `&self` — the concurrent read path. Any
    /// number of router workers can fill contexts from the same pinned
    /// factory in parallel (no lock, no counter writes). Returns the raw
    /// hit-block sum of the index walk; the serialized merge step must
    /// pass it to `kv.record_lookup` so lifetime stats match a serial run.
    pub fn fill_route_ctx(
        &self,
        req: &Request,
        now_us: u64,
        ctx: &mut RouteCtx,
        live: &mut Vec<u64>,
    ) -> usize {
        let input_len = req.input_len();
        let hit = self.kv.match_with(
            &req.block_hashes,
            &mut ctx.hit_tokens,
            &mut ctx.matched_mask,
            live,
        );
        // The walk wrote matched *blocks*; convert to hit tokens in place.
        for h in ctx.hit_tokens.iter_mut() {
            *h = (*h * crate::core::BLOCK_TOKENS).min(input_len);
        }
        ctx.inds.clear();
        for i in 0..self.snapshots.len() {
            let s = &self.snapshots[i];
            ctx.inds.push(Indicators {
                r_bs: s.r_bs,
                q_bs: s.q_bs + self.opt_q_bs[i],
                queued_prefill_tokens: s.queued_prefill_tokens + self.opt_prefill_tokens[i],
                total_context_tokens: s.total_context_tokens + self.opt_ctx_tokens[i],
                kv_used_blocks: s.kv_used_blocks,
                kv_capacity_blocks: s.kv_capacity_blocks,
                routable: self.routable[i],
            });
        }
        ctx.now_us = now_us;
        ctx.req_id = req.id;
        ctx.class_id = req.class_id;
        ctx.session_id = req.session_id;
        ctx.input_len = input_len;
        ctx.model_id = req.model_id;
        ctx.fleet_prefill_scale.clear();
        ctx.fleet_prefill_scale.extend_from_slice(&self.fleet_scales);
        ctx.cold_penalty_tokens.clear();
        // Penalties materialize only once multiplexing is real: the
        // directory is armed AND some request has asked for a non-default
        // model (this one counts). Until then the vector stays empty and
        // every policy prices exactly the pre-multiplexing decision.
        if !self.model_dirs.is_empty() && (self.multi_seen || req.model_id != 0) {
            for (i, dir) in self.model_dirs.iter().enumerate() {
                ctx.cold_penalty_tokens.push(if dir.is_warm(req.model_id) {
                    0.0
                } else {
                    self.fleet_cold_tokens[i]
                });
            }
        }
        hit
    }

    /// Build the per-instance indicator view for a request into the
    /// factory's scratch buffers and lend it out. ONE shared-index walk
    /// answers `hit_tokens` for all instances (and the matched mask);
    /// no heap allocation in steady state. Call [`Self::on_route`] with
    /// the same request right after the policy decides.
    pub fn route_ctx(&mut self, req: &Request, now_us: u64) -> &RouteCtx {
        let mut ctx = std::mem::take(&mut self.scratch);
        let mut live = std::mem::take(&mut self.walk_live);
        let hit = self.fill_route_ctx(req, now_us, &mut ctx, &mut live);
        self.scratch = ctx;
        self.walk_live = live;
        self.kv.record_lookup(req.block_hashes.len(), hit);
        &self.scratch
    }

    /// Commit a routing decision for the request whose context was just
    /// built by [`Self::route_ctx`]: optimistic indicator bumps + shared
    /// KV$ index insert.
    pub fn on_route(&mut self, inst: usize, req: &Request, now_us: u64) {
        debug_assert_eq!(
            self.scratch.req_id, req.id,
            "on_route must follow route_ctx for the same request"
        );
        let new_tokens = self.scratch.new_tokens(inst);
        self.commit_route(inst, req, new_tokens, now_us);
    }

    /// Commit a routing decision whose context was built OUT of the
    /// factory's scratch (the concurrent harness builds contexts on
    /// worker-owned buffers, then commits them here in arrival order).
    /// `new_tokens` is the context's `new_tokens(inst)` at decision time
    /// — passed in, because the worker's view (not the factory's current
    /// state) is what the decision priced.
    pub fn commit_route(&mut self, inst: usize, req: &Request, new_tokens: usize, now_us: u64) {
        self.opt_q_bs[inst] += 1;
        self.opt_prefill_tokens[inst] += new_tokens;
        self.opt_ctx_tokens[inst] += req.input_len();
        self.kv.on_route(inst, &req.block_hashes, now_us);
        if !self.model_dirs.is_empty() {
            if req.model_id != 0 {
                self.multi_seen = true;
            }
            // Advance the optimistic warm-set mirror with the same
            // touch the engine will make at admission (the mirror may
            // run slightly ahead — route time vs admission time — the
            // same optimism the indicator deltas already carry).
            self.model_dirs[inst].touch(req.model_id, now_us);
        }
        self.epoch += 1;
    }

    /// Absorb a response piggyback: authoritative snapshot replaces the
    /// stale one and clears that instance's optimistic deltas.
    pub fn on_snapshot(&mut self, inst: usize, snap: InstanceSnapshot) {
        self.snapshots[inst] = snap;
        self.opt_q_bs[inst] = 0;
        self.opt_prefill_tokens[inst] = 0;
        self.opt_ctx_tokens[inst] = 0;
        self.epoch += 1;
    }

    /// Completion piggyback: cache the full (prompt+output) chain in the
    /// shared KV$ index (the next conversation turn will hit it).
    pub fn on_completion(&mut self, inst: usize, full_hashes: &[u64], now_us: u64) {
        self.kv.on_response(inst, full_hashes, now_us);
        self.epoch += 1;
    }

    // --- lifecycle layer (crash / drain / recover / scale) --------------

    /// Whether the router may dispatch new work to `inst`.
    pub fn is_routable(&self, inst: usize) -> bool {
        self.routable[inst]
    }

    /// Flip the routability of `inst` (crash/drain clears it, recover and
    /// scale-up set it). A mutation like any other: bumps the epoch so
    /// concurrent readers observe the liveness change as staleness.
    pub fn set_routable(&mut self, inst: usize, routable: bool) {
        self.routable[inst] = routable;
        self.epoch += 1;
    }

    /// Forget everything the router believes about a crashed instance:
    /// its presence bits and occupancy in the shared KV$ index, its last
    /// snapshot, and any optimistic deltas. The instance's *slot*
    /// survives (indices stay stable for recovery); routability is
    /// governed separately by [`Self::set_routable`].
    pub fn purge_instance(&mut self, inst: usize) {
        self.kv.purge_instance(inst);
        self.snapshots[inst] = InstanceSnapshot::default();
        self.opt_q_bs[inst] = 0;
        self.opt_prefill_tokens[inst] = 0;
        self.opt_ctx_tokens[inst] = 0;
        if let Some(dir) = self.model_dirs.get_mut(inst) {
            // A restarted process holds only the default model warm.
            dir.reset_warm();
        }
        self.epoch += 1;
    }

    /// Grow (or shrink) the indicator fleet to `new_n` instances. New
    /// slots start routable with empty snapshots and a cold KV$ presence;
    /// shrinking requires the dropped tail to have been purged first
    /// (asserted by the KV index). Scratch buffers self-size on the next
    /// `route_ctx` call.
    pub fn resize_instances(&mut self, new_n: usize) {
        self.kv.resize_instances(new_n);
        self.snapshots.resize_with(new_n, InstanceSnapshot::default);
        self.opt_q_bs.resize(new_n, 0);
        self.opt_prefill_tokens.resize(new_n, 0);
        self.opt_ctx_tokens.resize(new_n, 0);
        self.routable.resize(new_n, true);
        if !self.fleet_profiles.is_empty() {
            // Scaled-up slots inherit the LAST declared class — the
            // same rule `config::FleetSpec::profile_for` applies.
            let tail = self.fleet_profiles.last().cloned().expect("non-empty");
            let model_tok = self.fleet_model_tok_us;
            while self.fleet_profiles.len() < new_n {
                let i = self.fleet_profiles.len();
                self.fleet_scales.push(tail.prefill_scale);
                self.fleet_cold_tokens
                    .push(tail.swap_cost_us() as f64 / model_tok);
                self.model_dirs.push(ModelSlots::new(i, &tail));
                self.fleet_profiles.push(tail.clone());
            }
            self.fleet_profiles.truncate(new_n);
            self.fleet_scales.truncate(new_n);
            self.fleet_cold_tokens.truncate(new_n);
            self.model_dirs.truncate(new_n);
        }
        self.epoch += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokenizer::block_hashes;

    fn mk_req(id: u64, n_tokens: usize) -> Request {
        let tokens = crate::tokenizer::span(9, id, n_tokens, 1024);
        let block_hashes = block_hashes(&tokens);
        Request {
            id,
            arrival_us: 0,
            class_id: 9,
            session_id: 0,
            model_id: 0,
            tokens: tokens.into(),
            output_len: 10,
            block_hashes: block_hashes.into(),
        }
    }

    #[test]
    fn optimistic_deltas_accumulate_and_reset() {
        let mut f = IndicatorFactory::new(2, 0);
        let req = mk_req(1, 160);
        let ctx = f.route_ctx(&req, 0);
        assert_eq!(ctx.inds[0].bs(), 0);
        f.on_route(0, &req, 0);
        let ctx2 = f.route_ctx(&req, 1);
        assert_eq!(ctx2.inds[0].q_bs, 1);
        // 2nd route sees the index insert from the 1st -> full hit.
        assert_eq!(ctx2.hit_tokens[0], 160);
        assert_eq!(ctx2.inds[0].queued_prefill_tokens, 160);
        assert!(ctx2.matched_mask.get(0));
        assert!(!ctx2.matched_mask.get(1));
        // Snapshot resets deltas.
        f.on_snapshot(0, crate::engine::InstanceSnapshot::default());
        let ctx3 = f.route_ctx(&req, 2);
        assert_eq!(ctx3.inds[0].q_bs, 0);
        assert_eq!(ctx3.inds[0].queued_prefill_tokens, 0);
    }

    #[test]
    fn p_token_combines_queue_and_miss() {
        let mut f = IndicatorFactory::new(2, 0);
        let mut snap = crate::engine::InstanceSnapshot::default();
        snap.queued_prefill_tokens = 500;
        f.on_snapshot(0, snap);
        let req = mk_req(2, 320);
        let ctx = f.route_ctx(&req, 0);
        assert_eq!(ctx.p_token(0), 500 + 320);
        assert_eq!(ctx.p_token(1), 320);
        assert_eq!(ctx.new_tokens(0), 320);
    }

    #[test]
    fn select_min_tiebreaks_deterministic() {
        let ctx = RouteCtx::new(
            0,
            0,
            0,
            0,
            vec![0, 0, 0],
            vec![
                Indicators {
                    q_bs: 5,
                    ..Default::default()
                },
                Indicators {
                    q_bs: 1,
                    ..Default::default()
                },
                Indicators {
                    q_bs: 3,
                    ..Default::default()
                },
            ],
        );
        // equal scores -> smallest bs wins (instance 1)
        assert_eq!(select_min(&ctx, |_| 1.0), 1);
        // distinct scores -> min wins regardless of bs
        assert_eq!(select_min(&ctx, |i| [3.0, 2.0, 1.0][i]), 2);
        assert_eq!(select_max(&ctx, |i| [3.0, 2.0, 1.0][i]), 0);
    }

    #[test]
    fn hit_ratio_and_new_tokens() {
        let mut f = IndicatorFactory::new(2, 0);
        let req = mk_req(3, 320);
        f.kv.on_response(1, &req.block_hashes[..10], 0); // 160 tokens cached
        let ctx = f.route_ctx(&req, 1);
        assert_eq!(ctx.hit_tokens, vec![0, 160]);
        assert!((ctx.hit_ratio(1) - 0.5).abs() < 1e-12);
        assert_eq!(ctx.new_tokens(1), 160);
    }

    #[test]
    fn route_ctx_mask_matches_hits_and_ctx_new_agrees() {
        let mut f = IndicatorFactory::new(3, 0);
        let req = mk_req(4, 320);
        f.kv.on_response(2, &req.block_hashes, 0);
        let ctx = f.route_ctx(&req, 1);
        assert_eq!(
            ctx.matched_mask.iter_ones().collect::<Vec<_>>(),
            vec![2],
            "mask = instances with any hit"
        );
        // RouteCtx::new derives the identical mask from hit_tokens.
        let rebuilt = RouteCtx::new(
            ctx.now_us,
            ctx.req_id,
            ctx.class_id,
            ctx.input_len,
            ctx.hit_tokens.clone(),
            ctx.inds.clone(),
        );
        assert_eq!(rebuilt.matched_mask, ctx.matched_mask);
    }

    #[test]
    fn indicator_stats_one_pass_summary() {
        let ctx = RouteCtx::new(
            0,
            0,
            0,
            1000,
            vec![1000, 0, 500],
            vec![
                Indicators::default(), // full hit, idle: kv axis = 0
                Indicators {
                    r_bs: 4,
                    ..Default::default()
                },
                Indicators {
                    q_bs: 1,
                    queued_prefill_tokens: 500,
                    ..Default::default()
                },
            ],
        );
        let s = IndicatorStats::collect(&ctx, |i| {
            (ctx.p_token(i) as f64, (ctx.inds[i].bs() + 1) as f64)
        });
        assert_eq!(s.n, 3);
        assert_eq!(s.kv_zeros, 1);
        assert!(!s.all_idle);
        assert_eq!(s.kv_min, 0.0);
        assert_eq!(s.kv_max, 1000.0);
        assert_eq!(s.load_min, 1.0);
        assert_eq!(s.load_max, 5.0);
        assert_eq!(s.kv_spread(), f64::INFINITY);
        assert_eq!(s.load_spread(), 5.0);
        // kv axis = p_token = (0, 1000, 500 + 500) -> mean 2000/3.
        assert!((s.kv_mean() - 2000.0 / 3.0).abs() < 1e-12);
        // An all-idle fleet reports the degenerate load tie.
        let idle = RouteCtx::new(0, 0, 0, 100, vec![0, 0], vec![Indicators::default(); 2]);
        let si = IndicatorStats::collect(&idle, |i| (idle.p_token(i) as f64, 1.0));
        assert!(si.all_idle);
        assert_eq!(si.kv_spread(), 1.0);
        assert_eq!(si.load_spread(), 1.0);
    }

    #[test]
    fn fill_route_ctx_matches_serial_path_and_is_read_only() {
        let mut f = IndicatorFactory::new(2, 0);
        let req = mk_req(7, 160);
        f.kv.on_response(1, &req.block_hashes[..5], 0); // 80 tokens cached
        let e0 = f.epoch();
        let lookups0 = f.kv.index().total_lookup_blocks;
        // Concurrent read path: caller-owned buffers, `&self` only.
        let mut ctx = RouteCtx::default();
        let mut live = Vec::new();
        let hit = f.fill_route_ctx(&req, 3, &mut ctx, &mut live);
        assert_eq!(hit, 5, "raw hit-block sum of the walk");
        assert_eq!(f.epoch(), e0, "read path must not bump the epoch");
        assert_eq!(
            f.kv.index().total_lookup_blocks,
            lookups0,
            "read path must not touch counters"
        );
        // Field-for-field identical to the serial scratch path.
        let serial = f.route_ctx(&req, 3).clone();
        assert_eq!(ctx.hit_tokens, serial.hit_tokens);
        assert_eq!(ctx.matched_mask, serial.matched_mask);
        assert_eq!(ctx.req_id, serial.req_id);
        assert_eq!(ctx.input_len, serial.input_len);
        assert_eq!(ctx.inds.len(), serial.inds.len());
        for i in 0..ctx.inds.len() {
            assert_eq!(ctx.p_token(i), serial.p_token(i));
            assert_eq!(ctx.inds[i].bs(), serial.inds[i].bs());
        }
    }

    #[test]
    fn commit_route_equals_on_route_and_bumps_epoch() {
        let mut a = IndicatorFactory::new(2, 0);
        let mut b = IndicatorFactory::new(2, 0);
        let req = mk_req(8, 320);
        // Serial path on `a`.
        a.route_ctx(&req, 1);
        a.on_route(0, &req, 1);
        // Concurrent path on `b`: worker-owned ctx, explicit commit.
        let mut ctx = RouteCtx::default();
        let mut live = Vec::new();
        let hit = b.fill_route_ctx(&req, 1, &mut ctx, &mut live);
        let e_pin = b.epoch();
        b.kv.record_lookup(req.block_hashes.len(), hit);
        b.commit_route(0, &req, ctx.new_tokens(0), 1);
        assert_eq!(b.epoch(), e_pin + 1, "commit publishes one epoch");
        // Both factories now price the next request identically.
        let next = mk_req(9, 320);
        let ca = a.route_ctx(&next, 2).clone();
        let cb = b.route_ctx(&next, 2).clone();
        assert_eq!(ca.hit_tokens, cb.hit_tokens);
        for i in 0..2 {
            assert_eq!(ca.p_token(i), cb.p_token(i));
            assert_eq!(ca.inds[i].bs(), cb.inds[i].bs());
        }
        assert_eq!(
            a.kv.index().total_lookup_blocks,
            b.kv.index().total_lookup_blocks
        );
        assert_eq!(a.kv.index().total_hit_blocks, b.kv.index().total_hit_blocks);
    }

    #[test]
    fn recompute_matched_mask_tracks_mutation() {
        let mut ctx = RouteCtx::new(0, 0, 0, 100, vec![0, 50], vec![Indicators::default(); 2]);
        assert!(ctx.matched_mask.get(1));
        ctx.hit_tokens = vec![100, 0];
        ctx.recompute_matched_mask();
        assert!(ctx.matched_mask.get(0) && !ctx.matched_mask.get(1));
    }

    #[test]
    fn select_min_skips_unroutable_instances() {
        let mut inds = vec![Indicators::default(); 3];
        inds[0].routable = false; // best score, but down
        let ctx = RouteCtx::new(0, 0, 0, 0, vec![0, 0, 0], inds);
        assert_eq!(select_min(&ctx, |i| [0.0, 2.0, 1.0][i]), 2);
        assert_eq!(select_max(&ctx, |i| [9.0, 2.0, 1.0][i]), 1);
        // No routable instance at all: documented fallback to index 0
        // (the DES never dispatches in this state — it requeues).
        let all_down = RouteCtx::new(
            0,
            0,
            0,
            0,
            vec![0, 0],
            vec![
                Indicators {
                    routable: false,
                    ..Default::default()
                };
                2
            ],
        );
        assert_eq!(select_min(&all_down, |i| i as f64), 0);
    }

    #[test]
    fn set_routable_flows_into_ctx_and_bumps_epoch() {
        let mut f = IndicatorFactory::new(3, 0);
        assert!(f.is_routable(1));
        let e0 = f.epoch();
        f.set_routable(1, false);
        assert_eq!(f.epoch(), e0 + 1);
        assert!(!f.is_routable(1));
        let req = mk_req(11, 160);
        let ctx = f.route_ctx(&req, 0);
        assert!(ctx.inds[0].routable && !ctx.inds[1].routable && ctx.inds[2].routable);
        f.set_routable(1, true);
        let ctx2 = f.route_ctx(&req, 1);
        assert!(ctx2.inds[1].routable);
    }

    #[test]
    fn purge_instance_forgets_snapshot_deltas_and_kv_presence() {
        let mut f = IndicatorFactory::new(2, 0);
        let req = mk_req(12, 320);
        let mut snap = crate::engine::InstanceSnapshot::default();
        snap.r_bs = 3;
        snap.queued_prefill_tokens = 777;
        f.on_snapshot(0, snap);
        f.route_ctx(&req, 0);
        f.on_route(0, &req, 0);
        let e0 = f.epoch();
        f.purge_instance(0);
        assert_eq!(f.epoch(), e0 + 1);
        let ctx = f.route_ctx(&req, 1);
        assert_eq!(ctx.hit_tokens[0], 0, "presence bits gone");
        assert_eq!(ctx.inds[0].bs(), 0, "snapshot and deltas gone");
        assert_eq!(ctx.inds[0].queued_prefill_tokens, 0);
        assert!(ctx.inds[0].routable, "purge does not govern routability");
    }

    #[test]
    fn p_time_is_p_token_on_uniform_fleets_and_scales_on_hetero() {
        let mut f = IndicatorFactory::new(2, 0);
        let req = mk_req(20, 320);
        let ctx = f.route_ctx(&req, 0).clone();
        // No fleet installed: empty scale vector, divisor exactly 1.0.
        assert!(ctx.fleet_prefill_scale.is_empty());
        for i in 0..2 {
            assert_eq!(ctx.p_time(i).to_bits(), (ctx.p_token(i) as f64).to_bits());
        }
        // Hetero fleet: the faster slot's predicted prefill time shrinks.
        f.set_fleet(
            &[InstanceProfile::h100(), InstanceProfile::l40()],
            &ModelProfile::dense_7b(),
        );
        let ctx2 = f.route_ctx(&req, 1).clone();
        assert_eq!(ctx2.fleet_prefill_scale, vec![2.0, 0.45]);
        assert_eq!(ctx2.p_time(0), ctx2.p_token(0) as f64 / 2.0);
        assert_eq!(ctx2.p_time(1), ctx2.p_token(1) as f64 / 0.45);
        assert!(ctx2.p_time(0) < ctx2.p_time(1));
    }

    #[test]
    fn cold_penalties_arm_only_when_multiplexing_is_real() {
        let mut f = IndicatorFactory::new(2, 0);
        f.set_fleet(
            &[InstanceProfile::reference(), InstanceProfile::reference()],
            &ModelProfile::dense_7b(),
        );
        // Default-model traffic on an armed directory: no penalties.
        let req0 = mk_req(30, 160);
        let ctx = f.route_ctx(&req0, 0).clone();
        assert!(ctx.cold_penalty_tokens.is_empty());
        assert_eq!(ctx.cold_penalty(0), 0.0);
        f.on_route(0, &req0, 0);
        // A request for model 7 sees every instance cold; the penalty is
        // the swap cost in token units (2s / 300µs-per-token).
        let mut req7 = mk_req(31, 160);
        req7.model_id = 7;
        let ctx7 = f.route_ctx(&req7, 1).clone();
        let expect = InstanceProfile::reference().swap_cost_us() as f64
            / ModelProfile::dense_7b().prefill_us_per_token;
        assert_eq!(ctx7.cold_penalty_tokens, vec![expect, expect]);
        f.on_route(1, &req7, 1);
        assert!(f.model_dir(1).unwrap().is_warm(7));
        // The warm instance now prices model 7 at zero; the cold one
        // still pays. And default-model traffic keeps penalty vectors
        // because model 0 could itself go cold once multiplexing began.
        let mut req7b = mk_req(32, 160);
        req7b.model_id = 7;
        let ctx7b = f.route_ctx(&req7b, 2).clone();
        assert_eq!(ctx7b.cold_penalty(1), 0.0);
        assert_eq!(ctx7b.cold_penalty(0), expect);
        let ctx0 = f.route_ctx(&req0, 3).clone();
        assert_eq!(ctx0.cold_penalty_tokens.len(), 2);
        assert_eq!(ctx0.cold_penalty(0), 0.0, "model 0 still warm");
    }

    #[test]
    fn purge_resets_the_warm_mirror_and_resize_inherits_last_class() {
        let mut f = IndicatorFactory::new(2, 0);
        f.set_fleet(
            &[InstanceProfile::h100(), InstanceProfile::l40()],
            &ModelProfile::dense_7b(),
        );
        let mut req = mk_req(40, 160);
        req.model_id = 3;
        f.route_ctx(&req, 0);
        f.on_route(1, &req, 0);
        assert!(f.model_dir(1).unwrap().is_warm(3));
        f.purge_instance(1);
        assert!(!f.model_dir(1).unwrap().is_warm(3));
        assert!(f.model_dir(1).unwrap().is_warm(0));
        // Scale-up: the new slot inherits the LAST declared class (l40).
        f.resize_instances(3);
        let ctx = f.route_ctx(&req, 1).clone();
        assert_eq!(ctx.fleet_prefill_scale, vec![2.0, 0.45, 0.45]);
        assert_eq!(ctx.cold_penalty_tokens.len(), 3);
        assert_eq!(ctx.cold_penalty(2), ctx.cold_penalty(1));
    }

    #[test]
    fn resize_instances_grows_fleet_with_cold_routable_slots() {
        let mut f = IndicatorFactory::new(2, 0);
        let req = mk_req(13, 160);
        f.route_ctx(&req, 0);
        f.on_route(1, &req, 0);
        f.resize_instances(4);
        assert_eq!(f.n_instances(), 4);
        let ctx = f.route_ctx(&req, 1);
        assert_eq!(ctx.inds.len(), 4);
        assert_eq!(ctx.hit_tokens.len(), 4);
        assert_eq!(ctx.hit_tokens[1], 160, "existing presence survives");
        assert_eq!(ctx.hit_tokens[2], 0);
        assert!(ctx.inds[2].routable && ctx.inds[3].routable);
        // Shrink back after purging the dropped tail.
        f.purge_instance(2);
        f.purge_instance(3);
        f.resize_instances(2);
        assert_eq!(f.n_instances(), 2);
        let ctx2 = f.route_ctx(&req, 2);
        assert_eq!(ctx2.inds.len(), 2);
        assert_eq!(ctx2.hit_tokens[1], 160);
    }
}
