//! The global scheduler's indicator factory and scheduling framework —
//! the paper's §3 analysis framework, reimplemented as a library.
//!
//! The factory owns (a) the last piggybacked [`InstanceSnapshot`] per
//! instance — refreshed whenever a response arrives, exactly as stale as
//! the real system's — plus (b) router-side *optimistic deltas* applied at
//! routing time (the router knows what it just sent where), and (c) the
//! per-instance KV$ radix mirrors ([`RouterKvView`]).
//!
//! A scheduling policy is a function from a [`RouteCtx`] — the request's
//! per-instance indicator values — to an instance choice, mirroring the
//! paper's Fig. 4 programming model (`score` + `select_min`).

use crate::core::Request;
use crate::engine::InstanceSnapshot;
use crate::kvcache::RouterKvView;

/// Effective per-instance indicator values at decision time:
/// last snapshot + optimistic deltas since.
#[derive(Debug, Clone, Copy, Default)]
pub struct Indicators {
    pub r_bs: usize,
    pub q_bs: usize,
    pub queued_prefill_tokens: usize,
    pub total_context_tokens: usize,
    pub kv_used_blocks: usize,
    pub kv_capacity_blocks: usize,
}

impl Indicators {
    /// The BS indicator (running + queued batch size).
    pub fn bs(&self) -> usize {
        self.r_bs + self.q_bs
    }
}

/// Everything a policy may consult for one routing decision.
#[derive(Debug, Clone)]
pub struct RouteCtx {
    pub now_us: u64,
    pub req_id: u64,
    pub class_id: u32,
    pub input_len: usize,
    /// Prompt tokens already cached per instance (block-aligned).
    pub hit_tokens: Vec<usize>,
    pub inds: Vec<Indicators>,
}

impl RouteCtx {
    pub fn n(&self) -> usize {
        self.inds.len()
    }

    /// KV$ hit ratio on instance `i` if routed there.
    pub fn hit_ratio(&self, i: usize) -> f64 {
        if self.input_len == 0 {
            0.0
        } else {
            self.hit_tokens[i] as f64 / self.input_len as f64
        }
    }

    /// New prefill tokens this request would add on instance `i`.
    pub fn new_tokens(&self, i: usize) -> usize {
        self.input_len.saturating_sub(self.hit_tokens[i])
    }

    /// The paper's P-token indicator: queued new prefill tokens on `i`
    /// plus this request's new tokens if routed there (§5.1).
    pub fn p_token(&self, i: usize) -> usize {
        self.inds[i].queued_prefill_tokens + self.new_tokens(i)
    }
}

/// A routing decision; `predicted_ttft_us` is filled by simulation-based
/// policies so harnesses can measure simulator error (Fig 16).
#[derive(Debug, Clone, Copy)]
pub struct RouteDecision {
    pub instance: usize,
    pub predicted_ttft_us: Option<f64>,
}

impl RouteDecision {
    pub fn to(instance: usize) -> Self {
        RouteDecision {
            instance,
            predicted_ttft_us: None,
        }
    }
}

/// A scheduling policy (one per baseline; see [`crate::policy`]).
pub trait Policy: Send {
    fn name(&self) -> String;
    fn route(&mut self, ctx: &RouteCtx) -> RouteDecision;
}

/// `instances.select_min(score)` from the paper's programming model:
/// minimal score wins; ties break on smaller BS, then lower index
/// (deterministic, so every figure is reproducible).
pub fn select_min(ctx: &RouteCtx, score: impl Fn(usize) -> f64) -> usize {
    let mut best = 0usize;
    let mut best_key = (f64::INFINITY, usize::MAX);
    for i in 0..ctx.n() {
        let key = (score(i), ctx.inds[i].bs());
        if key.0 < best_key.0 || (key.0 == best_key.0 && key.1 < best_key.1) {
            best_key = key;
            best = i;
        }
    }
    best
}

/// `select_max` with the same deterministic tie-breaks.
pub fn select_max(ctx: &RouteCtx, score: impl Fn(usize) -> f64) -> usize {
    select_min(ctx, |i| -score(i))
}

/// The indicator factory (§3): holds stale snapshots + optimistic deltas
/// + KV$ mirrors; builds [`RouteCtx`]s; absorbs response piggybacks.
pub struct IndicatorFactory {
    snapshots: Vec<InstanceSnapshot>,
    // Optimistic deltas since the instance's last response.
    opt_q_bs: Vec<usize>,
    opt_prefill_tokens: Vec<usize>,
    opt_ctx_tokens: Vec<usize>,
    pub kv: RouterKvView,
}

impl IndicatorFactory {
    pub fn new(n_instances: usize, kv_capacity_blocks: usize) -> Self {
        IndicatorFactory {
            snapshots: vec![InstanceSnapshot::default(); n_instances],
            opt_q_bs: vec![0; n_instances],
            opt_prefill_tokens: vec![0; n_instances],
            opt_ctx_tokens: vec![0; n_instances],
            kv: RouterKvView::new(n_instances, kv_capacity_blocks),
        }
    }

    pub fn n_instances(&self) -> usize {
        self.snapshots.len()
    }

    /// Build the per-instance indicator view for a request.
    pub fn route_ctx(&mut self, req: &Request, now_us: u64) -> RouteCtx {
        let hit_blocks = self.kv.match_all(&req.block_hashes, now_us);
        let input_len = req.input_len();
        let hit_tokens: Vec<usize> = hit_blocks
            .iter()
            .map(|b| (b * crate::core::BLOCK_TOKENS).min(input_len))
            .collect();
        let inds = (0..self.snapshots.len())
            .map(|i| {
                let s = &self.snapshots[i];
                Indicators {
                    r_bs: s.r_bs,
                    q_bs: s.q_bs + self.opt_q_bs[i],
                    queued_prefill_tokens: s.queued_prefill_tokens
                        + self.opt_prefill_tokens[i],
                    total_context_tokens: s.total_context_tokens + self.opt_ctx_tokens[i],
                    kv_used_blocks: s.kv_used_blocks,
                    kv_capacity_blocks: s.kv_capacity_blocks,
                }
            })
            .collect();
        RouteCtx {
            now_us,
            req_id: req.id,
            class_id: req.class_id,
            input_len,
            hit_tokens,
            inds,
        }
    }

    /// Commit a routing decision: optimistic indicator bumps + KV mirror.
    pub fn on_route(&mut self, inst: usize, ctx: &RouteCtx, req: &Request, now_us: u64) {
        self.opt_q_bs[inst] += 1;
        self.opt_prefill_tokens[inst] += ctx.new_tokens(inst);
        self.opt_ctx_tokens[inst] += ctx.input_len;
        self.kv.on_route(inst, &req.block_hashes, now_us);
    }

    /// Absorb a response piggyback: authoritative snapshot replaces the
    /// stale one and clears that instance's optimistic deltas.
    pub fn on_snapshot(&mut self, inst: usize, snap: InstanceSnapshot) {
        self.snapshots[inst] = snap;
        self.opt_q_bs[inst] = 0;
        self.opt_prefill_tokens[inst] = 0;
        self.opt_ctx_tokens[inst] = 0;
    }

    /// Completion piggyback: cache the full (prompt+output) chain in the
    /// KV mirror (the next conversation turn will hit it).
    pub fn on_completion(&mut self, inst: usize, full_hashes: &[u64], now_us: u64) {
        self.kv.on_response(inst, full_hashes, now_us);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokenizer::block_hashes;

    fn mk_req(id: u64, n_tokens: usize) -> Request {
        let tokens = crate::tokenizer::span(9, id, n_tokens, 1024);
        let block_hashes = block_hashes(&tokens);
        Request {
            id,
            arrival_us: 0,
            class_id: 9,
            tokens,
            output_len: 10,
            block_hashes,
        }
    }

    #[test]
    fn optimistic_deltas_accumulate_and_reset() {
        let mut f = IndicatorFactory::new(2, 0);
        let req = mk_req(1, 160);
        let ctx = f.route_ctx(&req, 0);
        assert_eq!(ctx.inds[0].bs(), 0);
        f.on_route(0, &ctx, &req, 0);
        let ctx2 = f.route_ctx(&req, 1);
        assert_eq!(ctx2.inds[0].q_bs, 1);
        // 2nd route sees the mirror insert from the 1st -> full hit.
        assert_eq!(ctx2.hit_tokens[0], 160);
        assert_eq!(ctx2.inds[0].queued_prefill_tokens, 160);
        // Snapshot resets deltas.
        f.on_snapshot(0, crate::engine::InstanceSnapshot::default());
        let ctx3 = f.route_ctx(&req, 2);
        assert_eq!(ctx3.inds[0].q_bs, 0);
        assert_eq!(ctx3.inds[0].queued_prefill_tokens, 0);
    }

    #[test]
    fn p_token_combines_queue_and_miss() {
        let mut f = IndicatorFactory::new(2, 0);
        let mut snap = crate::engine::InstanceSnapshot::default();
        snap.queued_prefill_tokens = 500;
        f.on_snapshot(0, snap);
        let req = mk_req(2, 320);
        let ctx = f.route_ctx(&req, 0);
        assert_eq!(ctx.p_token(0), 500 + 320);
        assert_eq!(ctx.p_token(1), 320);
        assert_eq!(ctx.new_tokens(0), 320);
    }

    #[test]
    fn select_min_tiebreaks_deterministic() {
        let ctx = RouteCtx {
            now_us: 0,
            req_id: 0,
            class_id: 0,
            input_len: 0,
            hit_tokens: vec![0, 0, 0],
            inds: vec![
                Indicators {
                    q_bs: 5,
                    ..Default::default()
                },
                Indicators {
                    q_bs: 1,
                    ..Default::default()
                },
                Indicators {
                    q_bs: 3,
                    ..Default::default()
                },
            ],
        };
        // equal scores -> smallest bs wins (instance 1)
        assert_eq!(select_min(&ctx, |_| 1.0), 1);
        // distinct scores -> min wins regardless of bs
        assert_eq!(select_min(&ctx, |i| [3.0, 2.0, 1.0][i]), 2);
        assert_eq!(select_max(&ctx, |i| [3.0, 2.0, 1.0][i]), 0);
    }

    #[test]
    fn hit_ratio_and_new_tokens() {
        let mut f = IndicatorFactory::new(2, 0);
        let req = mk_req(3, 320);
        f.kv.on_response(1, &req.block_hashes[..10], 0); // 160 tokens cached
        let ctx = f.route_ctx(&req, 1);
        assert_eq!(ctx.hit_tokens, vec![0, 160]);
        assert!((ctx.hit_ratio(1) - 0.5).abs() < 1e-12);
        assert_eq!(ctx.new_tokens(1), 160);
    }
}
