//! The global scheduler's indicator factory and scheduling framework —
//! the paper's §3 analysis framework, reimplemented as a library.
//!
//! The factory owns (a) the last piggybacked [`InstanceSnapshot`] per
//! instance — refreshed whenever a response arrives, exactly as stale as
//! the real system's — plus (b) router-side *optimistic deltas* applied at
//! routing time (the router knows what it just sent where), and (c) the
//! shared multi-instance KV$ prefix index
//! ([`RouterKvView`](crate::kvcache::RouterKvView)): one radix tree whose
//! nodes carry a per-instance presence bitmask, so one walk per request
//! yields every instance's hit length at once.
//!
//! A scheduling policy is a function from a [`RouteCtx`] — the request's
//! per-instance indicator values — to an instance choice, mirroring the
//! paper's Fig. 4 programming model (`score` + `select_min`).
//!
//! **Hot-path contract:** [`IndicatorFactory::route_ctx`] fills reusable
//! scratch buffers (`hit_tokens`, `inds`, `matched_mask`) and hands the
//! policy a *borrowed* [`RouteCtx`]; steady-state routing performs zero
//! heap allocation. Commit the decision with
//! [`IndicatorFactory::on_route`] immediately after (it consumes the
//! scratch state of the same request).

use crate::core::{InstanceMask, Request};
use crate::engine::InstanceSnapshot;
use crate::kvcache::RouterKvView;

/// Effective per-instance indicator values at decision time:
/// last snapshot + optimistic deltas since.
#[derive(Debug, Clone, Copy)]
pub struct Indicators {
    pub r_bs: usize,
    pub q_bs: usize,
    pub queued_prefill_tokens: usize,
    pub total_context_tokens: usize,
    pub kv_used_blocks: usize,
    pub kv_capacity_blocks: usize,
    /// Whether the instance accepts new work. Crashed and draining
    /// instances (see [`crate::cluster::lifecycle`]) are kept in the
    /// indicator vector so indices stay stable, but `select_min` /
    /// `select_max` and the session policies skip them.
    pub routable: bool,
}

impl Default for Indicators {
    fn default() -> Self {
        Indicators {
            r_bs: 0,
            q_bs: 0,
            queued_prefill_tokens: 0,
            total_context_tokens: 0,
            kv_used_blocks: 0,
            kv_capacity_blocks: 0,
            // A default-constructed instance is a healthy one: every
            // pre-lifecycle call site (tests, offline tools) builds
            // contexts this way and must keep routing to all instances.
            routable: true,
        }
    }
}

impl Indicators {
    /// The BS indicator (running + queued batch size).
    pub fn bs(&self) -> usize {
        self.r_bs + self.q_bs
    }
}

/// Everything a policy may consult for one routing decision.
#[derive(Debug, Clone, Default)]
pub struct RouteCtx {
    pub now_us: u64,
    pub req_id: u64,
    pub class_id: u32,
    /// Session the request belongs to (0 = sessionless). Lets
    /// session-aware policies key affinity state without any side
    /// channel; indicator-based policies ignore it.
    pub session_id: u64,
    pub input_len: usize,
    /// Prompt tokens already cached per instance (block-aligned).
    pub hit_tokens: Vec<usize>,
    /// Instances holding ≥ 1 cached block of this prompt — the hotspot
    /// detector's M-set, produced by the shared-index walk for free.
    /// Invariant: bit `i` set ⟺ `hit_tokens[i] > 0`.
    pub matched_mask: InstanceMask,
    pub inds: Vec<Indicators>,
}

impl RouteCtx {
    /// Build a context, deriving `matched_mask` from `hit_tokens` (the
    /// factory's hot path fills the mask directly from the index walk;
    /// tests and offline tools construct contexts through here).
    pub fn new(
        now_us: u64,
        req_id: u64,
        class_id: u32,
        input_len: usize,
        hit_tokens: Vec<usize>,
        inds: Vec<Indicators>,
    ) -> Self {
        let matched_mask = InstanceMask::from_hit_tokens(&hit_tokens);
        RouteCtx {
            now_us,
            req_id,
            class_id,
            session_id: 0,
            input_len,
            hit_tokens,
            matched_mask,
            inds,
        }
    }

    /// Attach a session id (builder-style; [`RouteCtx::new`] defaults to
    /// sessionless so the many non-session call sites stay unchanged).
    pub fn with_session(mut self, session_id: u64) -> Self {
        self.session_id = session_id;
        self
    }

    /// Re-derive `matched_mask` from `hit_tokens` — call after mutating
    /// `hit_tokens` directly (tests crafting adversarial states).
    pub fn recompute_matched_mask(&mut self) {
        self.matched_mask.fill_from_hit_tokens(&self.hit_tokens);
    }

    pub fn n(&self) -> usize {
        self.inds.len()
    }

    /// KV$ hit ratio on instance `i` if routed there.
    pub fn hit_ratio(&self, i: usize) -> f64 {
        if self.input_len == 0 {
            0.0
        } else {
            self.hit_tokens[i] as f64 / self.input_len as f64
        }
    }

    /// New prefill tokens this request would add on instance `i`.
    pub fn new_tokens(&self, i: usize) -> usize {
        self.input_len.saturating_sub(self.hit_tokens[i])
    }

    /// The paper's P-token indicator: queued new prefill tokens on `i`
    /// plus this request's new tokens if routed there (§5.1).
    pub fn p_token(&self, i: usize) -> usize {
        self.inds[i].queued_prefill_tokens + self.new_tokens(i)
    }
}

/// A routing decision; `predicted_ttft_us` is filled by simulation-based
/// policies so harnesses can measure simulator error (Fig 16).
#[derive(Debug, Clone, Copy)]
pub struct RouteDecision {
    pub instance: usize,
    pub predicted_ttft_us: Option<f64>,
}

impl RouteDecision {
    pub fn to(instance: usize) -> Self {
        RouteDecision {
            instance,
            predicted_ttft_us: None,
        }
    }
}

/// A scheduling policy (one per baseline; see [`crate::policy`]).
///
/// **Read-only score path.** `route` receives the context by shared
/// reference and has no channel back into the factory or the KV index —
/// a policy can only mutate its OWN state (guard counters, per-session
/// affinity maps). This is audited across `crate::policy` and is what
/// lets `cluster::run_concurrent` score the same pinned snapshot from R
/// workers in parallel: each worker owns a policy replica, and all
/// factory/index mutation happens at the serialized merge step via
/// [`IndicatorFactory::commit_route`].
pub trait Policy: Send {
    fn name(&self) -> String;
    fn route(&mut self, ctx: &RouteCtx) -> RouteDecision;

    /// Failure-condition guard counters, for policies that carry the
    /// guard (see [`crate::policy::GuardedLMetric`]); `None` for
    /// unguarded policies. The DES and live harnesses fold these into
    /// [`crate::metrics::RunMetrics::guard`] at the end of a run.
    fn guard_counters(&self) -> Option<GuardCounters> {
        None
    }
}

/// Counters of the failure-condition guard, one bump per routing
/// decision analyzed. `checks` counts decisions, `degenerate` /
/// `inversion` count detections of the two derived failure regimes, and
/// `mitigated` counts decisions the secondary-key fallback actually
/// *changed* — the paper's "extremely rare in practice" claim is
/// `mitigated == 0` on natural traffic.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GuardCounters {
    pub checks: u64,
    pub degenerate: u64,
    pub inversion: u64,
    pub mitigated: u64,
}

impl GuardCounters {
    /// Counter delta since `start` — policies accumulate over their
    /// lifetime, so a harness reusing one policy across runs snapshots
    /// the counters at run start and reports the difference.
    pub fn since(self, start: GuardCounters) -> GuardCounters {
        GuardCounters {
            checks: self.checks.saturating_sub(start.checks),
            degenerate: self.degenerate.saturating_sub(start.degenerate),
            inversion: self.inversion.saturating_sub(start.inversion),
            mitigated: self.mitigated.saturating_sub(start.mitigated),
        }
    }
}

/// One-pass summary statistics of a decision's two indicator axes — the
/// per-snapshot analysis the failure-condition guard (and any offline
/// tooling) evaluates in O(N) with zero allocation. `axes(i)` returns
/// the (KV-aware, load) factor pair of instance `i`.
#[derive(Debug, Clone, Copy)]
pub struct IndicatorStats {
    pub n: usize,
    pub kv_min: f64,
    pub kv_max: f64,
    pub kv_sum: f64,
    pub load_min: f64,
    pub load_max: f64,
    pub load_sum: f64,
    /// Instances whose KV-axis factor is exactly zero (P-token = 0 in
    /// the paper configuration: full prefix hit and an empty queue).
    pub kv_zeros: usize,
    /// Every instance idle (`BS == 0`, so the load factor ties at 1).
    pub all_idle: bool,
}

impl IndicatorStats {
    pub fn collect(ctx: &RouteCtx, mut axes: impl FnMut(usize) -> (f64, f64)) -> IndicatorStats {
        let n = ctx.n();
        let mut s = IndicatorStats {
            n,
            kv_min: f64::INFINITY,
            kv_max: 0.0,
            kv_sum: 0.0,
            load_min: f64::INFINITY,
            load_max: 0.0,
            load_sum: 0.0,
            kv_zeros: 0,
            all_idle: n > 0,
        };
        for i in 0..n {
            let (kv, load) = axes(i);
            s.kv_min = s.kv_min.min(kv);
            s.kv_max = s.kv_max.max(kv);
            s.kv_sum += kv;
            s.load_min = s.load_min.min(load);
            s.load_max = s.load_max.max(load);
            s.load_sum += load;
            if kv == 0.0 {
                s.kv_zeros += 1;
            }
            if ctx.inds[i].bs() != 0 {
                s.all_idle = false;
            }
        }
        s
    }

    pub fn kv_mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.kv_sum / self.n as f64
        }
    }

    pub fn load_mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.load_sum / self.n as f64
        }
    }

    /// Cross-instance spread ratio (max/min) of the KV axis: 1.0 when
    /// flat (or empty), ∞ when a zero coexists with a non-zero value.
    pub fn kv_spread(&self) -> f64 {
        spread_ratio(self.kv_min, self.kv_max)
    }

    /// Cross-instance spread ratio of the load axis.
    pub fn load_spread(&self) -> f64 {
        spread_ratio(self.load_min, self.load_max)
    }
}

fn spread_ratio(min: f64, max: f64) -> f64 {
    if max <= 0.0 || !min.is_finite() {
        1.0
    } else if min == 0.0 {
        f64::INFINITY
    } else {
        max / min
    }
}

/// `instances.select_min(score)` from the paper's programming model:
/// minimal score wins; ties break on smaller BS, then lower index
/// (deterministic, so every figure is reproducible).
///
/// Unroutable instances (crashed / draining; see
/// [`crate::cluster::lifecycle`]) are skipped — when every instance is
/// routable the scan is bit-for-bit the pre-lifecycle one. If *no*
/// instance is routable the fallback is index 0; harnesses must not
/// dispatch in that state (the DES requeues instead).
pub fn select_min(ctx: &RouteCtx, score: impl Fn(usize) -> f64) -> usize {
    let mut best = 0usize;
    let mut best_key = (f64::INFINITY, usize::MAX);
    for i in 0..ctx.n() {
        if !ctx.inds[i].routable {
            continue;
        }
        let key = (score(i), ctx.inds[i].bs());
        if key.0 < best_key.0 || (key.0 == best_key.0 && key.1 < best_key.1) {
            best_key = key;
            best = i;
        }
    }
    best
}

/// `select_max` with the same deterministic tie-breaks.
pub fn select_max(ctx: &RouteCtx, score: impl Fn(usize) -> f64) -> usize {
    select_min(ctx, |i| -score(i))
}

/// The indicator factory (§3): holds stale snapshots + optimistic deltas
/// + the shared KV$ prefix index; builds [`RouteCtx`]s into reusable
/// scratch buffers; absorbs response piggybacks.
pub struct IndicatorFactory {
    snapshots: Vec<InstanceSnapshot>,
    // Optimistic deltas since the instance's last response.
    opt_q_bs: Vec<usize>,
    opt_prefill_tokens: Vec<usize>,
    opt_ctx_tokens: Vec<usize>,
    /// Router-side routability flags (lifecycle layer): `false` for
    /// crashed or draining instances. Copied into every context's
    /// [`Indicators`] so policies see liveness with zero extra plumbing.
    routable: Vec<bool>,
    pub kv: RouterKvView,
    /// Reusable decision context — the allocation-free hot path.
    scratch: RouteCtx,
    /// Reusable live-set scratch for the serial walk.
    walk_live: Vec<u64>,
    /// Factory-state epoch: bumped on every mutation (route commit,
    /// snapshot absorb, completion). Concurrent readers pin this to
    /// measure how many commits their view is stale by.
    epoch: u64,
}

impl IndicatorFactory {
    pub fn new(n_instances: usize, kv_capacity_blocks: usize) -> Self {
        IndicatorFactory {
            snapshots: vec![InstanceSnapshot::default(); n_instances],
            opt_q_bs: vec![0; n_instances],
            opt_prefill_tokens: vec![0; n_instances],
            opt_ctx_tokens: vec![0; n_instances],
            routable: vec![true; n_instances],
            kv: RouterKvView::new(n_instances, kv_capacity_blocks),
            scratch: RouteCtx {
                now_us: 0,
                req_id: u64::MAX,
                class_id: 0,
                session_id: 0,
                input_len: 0,
                hit_tokens: Vec::with_capacity(n_instances),
                matched_mask: InstanceMask::with_capacity(n_instances),
                inds: Vec::with_capacity(n_instances),
            },
            walk_live: Vec::new(),
            epoch: 0,
        }
    }

    pub fn n_instances(&self) -> usize {
        self.snapshots.len()
    }

    /// Mutation epoch of the whole factory state (indicators + KV index):
    /// bumped once per commit/snapshot/completion. A concurrent router
    /// pins it before scoring and measures snapshot age as "commits since
    /// pin" at its own merge time.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Build the per-instance indicator view for a request into CALLER-
    /// owned buffers, through `&self` — the concurrent read path. Any
    /// number of router workers can fill contexts from the same pinned
    /// factory in parallel (no lock, no counter writes). Returns the raw
    /// hit-block sum of the index walk; the serialized merge step must
    /// pass it to `kv.record_lookup` so lifetime stats match a serial run.
    pub fn fill_route_ctx(
        &self,
        req: &Request,
        now_us: u64,
        ctx: &mut RouteCtx,
        live: &mut Vec<u64>,
    ) -> usize {
        let input_len = req.input_len();
        let hit = self.kv.match_with(
            &req.block_hashes,
            &mut ctx.hit_tokens,
            &mut ctx.matched_mask,
            live,
        );
        // The walk wrote matched *blocks*; convert to hit tokens in place.
        for h in ctx.hit_tokens.iter_mut() {
            *h = (*h * crate::core::BLOCK_TOKENS).min(input_len);
        }
        ctx.inds.clear();
        for i in 0..self.snapshots.len() {
            let s = &self.snapshots[i];
            ctx.inds.push(Indicators {
                r_bs: s.r_bs,
                q_bs: s.q_bs + self.opt_q_bs[i],
                queued_prefill_tokens: s.queued_prefill_tokens + self.opt_prefill_tokens[i],
                total_context_tokens: s.total_context_tokens + self.opt_ctx_tokens[i],
                kv_used_blocks: s.kv_used_blocks,
                kv_capacity_blocks: s.kv_capacity_blocks,
                routable: self.routable[i],
            });
        }
        ctx.now_us = now_us;
        ctx.req_id = req.id;
        ctx.class_id = req.class_id;
        ctx.session_id = req.session_id;
        ctx.input_len = input_len;
        hit
    }

    /// Build the per-instance indicator view for a request into the
    /// factory's scratch buffers and lend it out. ONE shared-index walk
    /// answers `hit_tokens` for all instances (and the matched mask);
    /// no heap allocation in steady state. Call [`Self::on_route`] with
    /// the same request right after the policy decides.
    pub fn route_ctx(&mut self, req: &Request, now_us: u64) -> &RouteCtx {
        let mut ctx = std::mem::take(&mut self.scratch);
        let mut live = std::mem::take(&mut self.walk_live);
        let hit = self.fill_route_ctx(req, now_us, &mut ctx, &mut live);
        self.scratch = ctx;
        self.walk_live = live;
        self.kv.record_lookup(req.block_hashes.len(), hit);
        &self.scratch
    }

    /// Commit a routing decision for the request whose context was just
    /// built by [`Self::route_ctx`]: optimistic indicator bumps + shared
    /// KV$ index insert.
    pub fn on_route(&mut self, inst: usize, req: &Request, now_us: u64) {
        debug_assert_eq!(
            self.scratch.req_id, req.id,
            "on_route must follow route_ctx for the same request"
        );
        let new_tokens = self.scratch.new_tokens(inst);
        self.commit_route(inst, req, new_tokens, now_us);
    }

    /// Commit a routing decision whose context was built OUT of the
    /// factory's scratch (the concurrent harness builds contexts on
    /// worker-owned buffers, then commits them here in arrival order).
    /// `new_tokens` is the context's `new_tokens(inst)` at decision time
    /// — passed in, because the worker's view (not the factory's current
    /// state) is what the decision priced.
    pub fn commit_route(&mut self, inst: usize, req: &Request, new_tokens: usize, now_us: u64) {
        self.opt_q_bs[inst] += 1;
        self.opt_prefill_tokens[inst] += new_tokens;
        self.opt_ctx_tokens[inst] += req.input_len();
        self.kv.on_route(inst, &req.block_hashes, now_us);
        self.epoch += 1;
    }

    /// Absorb a response piggyback: authoritative snapshot replaces the
    /// stale one and clears that instance's optimistic deltas.
    pub fn on_snapshot(&mut self, inst: usize, snap: InstanceSnapshot) {
        self.snapshots[inst] = snap;
        self.opt_q_bs[inst] = 0;
        self.opt_prefill_tokens[inst] = 0;
        self.opt_ctx_tokens[inst] = 0;
        self.epoch += 1;
    }

    /// Completion piggyback: cache the full (prompt+output) chain in the
    /// shared KV$ index (the next conversation turn will hit it).
    pub fn on_completion(&mut self, inst: usize, full_hashes: &[u64], now_us: u64) {
        self.kv.on_response(inst, full_hashes, now_us);
        self.epoch += 1;
    }

    // --- lifecycle layer (crash / drain / recover / scale) --------------

    /// Whether the router may dispatch new work to `inst`.
    pub fn is_routable(&self, inst: usize) -> bool {
        self.routable[inst]
    }

    /// Flip the routability of `inst` (crash/drain clears it, recover and
    /// scale-up set it). A mutation like any other: bumps the epoch so
    /// concurrent readers observe the liveness change as staleness.
    pub fn set_routable(&mut self, inst: usize, routable: bool) {
        self.routable[inst] = routable;
        self.epoch += 1;
    }

    /// Forget everything the router believes about a crashed instance:
    /// its presence bits and occupancy in the shared KV$ index, its last
    /// snapshot, and any optimistic deltas. The instance's *slot*
    /// survives (indices stay stable for recovery); routability is
    /// governed separately by [`Self::set_routable`].
    pub fn purge_instance(&mut self, inst: usize) {
        self.kv.purge_instance(inst);
        self.snapshots[inst] = InstanceSnapshot::default();
        self.opt_q_bs[inst] = 0;
        self.opt_prefill_tokens[inst] = 0;
        self.opt_ctx_tokens[inst] = 0;
        self.epoch += 1;
    }

    /// Grow (or shrink) the indicator fleet to `new_n` instances. New
    /// slots start routable with empty snapshots and a cold KV$ presence;
    /// shrinking requires the dropped tail to have been purged first
    /// (asserted by the KV index). Scratch buffers self-size on the next
    /// `route_ctx` call.
    pub fn resize_instances(&mut self, new_n: usize) {
        self.kv.resize_instances(new_n);
        self.snapshots.resize_with(new_n, InstanceSnapshot::default);
        self.opt_q_bs.resize(new_n, 0);
        self.opt_prefill_tokens.resize(new_n, 0);
        self.opt_ctx_tokens.resize(new_n, 0);
        self.routable.resize(new_n, true);
        self.epoch += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokenizer::block_hashes;

    fn mk_req(id: u64, n_tokens: usize) -> Request {
        let tokens = crate::tokenizer::span(9, id, n_tokens, 1024);
        let block_hashes = block_hashes(&tokens);
        Request {
            id,
            arrival_us: 0,
            class_id: 9,
            session_id: 0,
            tokens: tokens.into(),
            output_len: 10,
            block_hashes: block_hashes.into(),
        }
    }

    #[test]
    fn optimistic_deltas_accumulate_and_reset() {
        let mut f = IndicatorFactory::new(2, 0);
        let req = mk_req(1, 160);
        let ctx = f.route_ctx(&req, 0);
        assert_eq!(ctx.inds[0].bs(), 0);
        f.on_route(0, &req, 0);
        let ctx2 = f.route_ctx(&req, 1);
        assert_eq!(ctx2.inds[0].q_bs, 1);
        // 2nd route sees the index insert from the 1st -> full hit.
        assert_eq!(ctx2.hit_tokens[0], 160);
        assert_eq!(ctx2.inds[0].queued_prefill_tokens, 160);
        assert!(ctx2.matched_mask.get(0));
        assert!(!ctx2.matched_mask.get(1));
        // Snapshot resets deltas.
        f.on_snapshot(0, crate::engine::InstanceSnapshot::default());
        let ctx3 = f.route_ctx(&req, 2);
        assert_eq!(ctx3.inds[0].q_bs, 0);
        assert_eq!(ctx3.inds[0].queued_prefill_tokens, 0);
    }

    #[test]
    fn p_token_combines_queue_and_miss() {
        let mut f = IndicatorFactory::new(2, 0);
        let mut snap = crate::engine::InstanceSnapshot::default();
        snap.queued_prefill_tokens = 500;
        f.on_snapshot(0, snap);
        let req = mk_req(2, 320);
        let ctx = f.route_ctx(&req, 0);
        assert_eq!(ctx.p_token(0), 500 + 320);
        assert_eq!(ctx.p_token(1), 320);
        assert_eq!(ctx.new_tokens(0), 320);
    }

    #[test]
    fn select_min_tiebreaks_deterministic() {
        let ctx = RouteCtx::new(
            0,
            0,
            0,
            0,
            vec![0, 0, 0],
            vec![
                Indicators {
                    q_bs: 5,
                    ..Default::default()
                },
                Indicators {
                    q_bs: 1,
                    ..Default::default()
                },
                Indicators {
                    q_bs: 3,
                    ..Default::default()
                },
            ],
        );
        // equal scores -> smallest bs wins (instance 1)
        assert_eq!(select_min(&ctx, |_| 1.0), 1);
        // distinct scores -> min wins regardless of bs
        assert_eq!(select_min(&ctx, |i| [3.0, 2.0, 1.0][i]), 2);
        assert_eq!(select_max(&ctx, |i| [3.0, 2.0, 1.0][i]), 0);
    }

    #[test]
    fn hit_ratio_and_new_tokens() {
        let mut f = IndicatorFactory::new(2, 0);
        let req = mk_req(3, 320);
        f.kv.on_response(1, &req.block_hashes[..10], 0); // 160 tokens cached
        let ctx = f.route_ctx(&req, 1);
        assert_eq!(ctx.hit_tokens, vec![0, 160]);
        assert!((ctx.hit_ratio(1) - 0.5).abs() < 1e-12);
        assert_eq!(ctx.new_tokens(1), 160);
    }

    #[test]
    fn route_ctx_mask_matches_hits_and_ctx_new_agrees() {
        let mut f = IndicatorFactory::new(3, 0);
        let req = mk_req(4, 320);
        f.kv.on_response(2, &req.block_hashes, 0);
        let ctx = f.route_ctx(&req, 1);
        assert_eq!(
            ctx.matched_mask.iter_ones().collect::<Vec<_>>(),
            vec![2],
            "mask = instances with any hit"
        );
        // RouteCtx::new derives the identical mask from hit_tokens.
        let rebuilt = RouteCtx::new(
            ctx.now_us,
            ctx.req_id,
            ctx.class_id,
            ctx.input_len,
            ctx.hit_tokens.clone(),
            ctx.inds.clone(),
        );
        assert_eq!(rebuilt.matched_mask, ctx.matched_mask);
    }

    #[test]
    fn indicator_stats_one_pass_summary() {
        let ctx = RouteCtx::new(
            0,
            0,
            0,
            1000,
            vec![1000, 0, 500],
            vec![
                Indicators::default(), // full hit, idle: kv axis = 0
                Indicators {
                    r_bs: 4,
                    ..Default::default()
                },
                Indicators {
                    q_bs: 1,
                    queued_prefill_tokens: 500,
                    ..Default::default()
                },
            ],
        );
        let s = IndicatorStats::collect(&ctx, |i| {
            (ctx.p_token(i) as f64, (ctx.inds[i].bs() + 1) as f64)
        });
        assert_eq!(s.n, 3);
        assert_eq!(s.kv_zeros, 1);
        assert!(!s.all_idle);
        assert_eq!(s.kv_min, 0.0);
        assert_eq!(s.kv_max, 1000.0);
        assert_eq!(s.load_min, 1.0);
        assert_eq!(s.load_max, 5.0);
        assert_eq!(s.kv_spread(), f64::INFINITY);
        assert_eq!(s.load_spread(), 5.0);
        // kv axis = p_token = (0, 1000, 500 + 500) -> mean 2000/3.
        assert!((s.kv_mean() - 2000.0 / 3.0).abs() < 1e-12);
        // An all-idle fleet reports the degenerate load tie.
        let idle = RouteCtx::new(0, 0, 0, 100, vec![0, 0], vec![Indicators::default(); 2]);
        let si = IndicatorStats::collect(&idle, |i| (idle.p_token(i) as f64, 1.0));
        assert!(si.all_idle);
        assert_eq!(si.kv_spread(), 1.0);
        assert_eq!(si.load_spread(), 1.0);
    }

    #[test]
    fn fill_route_ctx_matches_serial_path_and_is_read_only() {
        let mut f = IndicatorFactory::new(2, 0);
        let req = mk_req(7, 160);
        f.kv.on_response(1, &req.block_hashes[..5], 0); // 80 tokens cached
        let e0 = f.epoch();
        let lookups0 = f.kv.index().total_lookup_blocks;
        // Concurrent read path: caller-owned buffers, `&self` only.
        let mut ctx = RouteCtx::default();
        let mut live = Vec::new();
        let hit = f.fill_route_ctx(&req, 3, &mut ctx, &mut live);
        assert_eq!(hit, 5, "raw hit-block sum of the walk");
        assert_eq!(f.epoch(), e0, "read path must not bump the epoch");
        assert_eq!(
            f.kv.index().total_lookup_blocks,
            lookups0,
            "read path must not touch counters"
        );
        // Field-for-field identical to the serial scratch path.
        let serial = f.route_ctx(&req, 3).clone();
        assert_eq!(ctx.hit_tokens, serial.hit_tokens);
        assert_eq!(ctx.matched_mask, serial.matched_mask);
        assert_eq!(ctx.req_id, serial.req_id);
        assert_eq!(ctx.input_len, serial.input_len);
        assert_eq!(ctx.inds.len(), serial.inds.len());
        for i in 0..ctx.inds.len() {
            assert_eq!(ctx.p_token(i), serial.p_token(i));
            assert_eq!(ctx.inds[i].bs(), serial.inds[i].bs());
        }
    }

    #[test]
    fn commit_route_equals_on_route_and_bumps_epoch() {
        let mut a = IndicatorFactory::new(2, 0);
        let mut b = IndicatorFactory::new(2, 0);
        let req = mk_req(8, 320);
        // Serial path on `a`.
        a.route_ctx(&req, 1);
        a.on_route(0, &req, 1);
        // Concurrent path on `b`: worker-owned ctx, explicit commit.
        let mut ctx = RouteCtx::default();
        let mut live = Vec::new();
        let hit = b.fill_route_ctx(&req, 1, &mut ctx, &mut live);
        let e_pin = b.epoch();
        b.kv.record_lookup(req.block_hashes.len(), hit);
        b.commit_route(0, &req, ctx.new_tokens(0), 1);
        assert_eq!(b.epoch(), e_pin + 1, "commit publishes one epoch");
        // Both factories now price the next request identically.
        let next = mk_req(9, 320);
        let ca = a.route_ctx(&next, 2).clone();
        let cb = b.route_ctx(&next, 2).clone();
        assert_eq!(ca.hit_tokens, cb.hit_tokens);
        for i in 0..2 {
            assert_eq!(ca.p_token(i), cb.p_token(i));
            assert_eq!(ca.inds[i].bs(), cb.inds[i].bs());
        }
        assert_eq!(
            a.kv.index().total_lookup_blocks,
            b.kv.index().total_lookup_blocks
        );
        assert_eq!(a.kv.index().total_hit_blocks, b.kv.index().total_hit_blocks);
    }

    #[test]
    fn recompute_matched_mask_tracks_mutation() {
        let mut ctx = RouteCtx::new(0, 0, 0, 100, vec![0, 50], vec![Indicators::default(); 2]);
        assert!(ctx.matched_mask.get(1));
        ctx.hit_tokens = vec![100, 0];
        ctx.recompute_matched_mask();
        assert!(ctx.matched_mask.get(0) && !ctx.matched_mask.get(1));
    }

    #[test]
    fn select_min_skips_unroutable_instances() {
        let mut inds = vec![Indicators::default(); 3];
        inds[0].routable = false; // best score, but down
        let ctx = RouteCtx::new(0, 0, 0, 0, vec![0, 0, 0], inds);
        assert_eq!(select_min(&ctx, |i| [0.0, 2.0, 1.0][i]), 2);
        assert_eq!(select_max(&ctx, |i| [9.0, 2.0, 1.0][i]), 1);
        // No routable instance at all: documented fallback to index 0
        // (the DES never dispatches in this state — it requeues).
        let all_down = RouteCtx::new(
            0,
            0,
            0,
            0,
            vec![0, 0],
            vec![
                Indicators {
                    routable: false,
                    ..Default::default()
                };
                2
            ],
        );
        assert_eq!(select_min(&all_down, |i| i as f64), 0);
    }

    #[test]
    fn set_routable_flows_into_ctx_and_bumps_epoch() {
        let mut f = IndicatorFactory::new(3, 0);
        assert!(f.is_routable(1));
        let e0 = f.epoch();
        f.set_routable(1, false);
        assert_eq!(f.epoch(), e0 + 1);
        assert!(!f.is_routable(1));
        let req = mk_req(11, 160);
        let ctx = f.route_ctx(&req, 0);
        assert!(ctx.inds[0].routable && !ctx.inds[1].routable && ctx.inds[2].routable);
        f.set_routable(1, true);
        let ctx2 = f.route_ctx(&req, 1);
        assert!(ctx2.inds[1].routable);
    }

    #[test]
    fn purge_instance_forgets_snapshot_deltas_and_kv_presence() {
        let mut f = IndicatorFactory::new(2, 0);
        let req = mk_req(12, 320);
        let mut snap = crate::engine::InstanceSnapshot::default();
        snap.r_bs = 3;
        snap.queued_prefill_tokens = 777;
        f.on_snapshot(0, snap);
        f.route_ctx(&req, 0);
        f.on_route(0, &req, 0);
        let e0 = f.epoch();
        f.purge_instance(0);
        assert_eq!(f.epoch(), e0 + 1);
        let ctx = f.route_ctx(&req, 1);
        assert_eq!(ctx.hit_tokens[0], 0, "presence bits gone");
        assert_eq!(ctx.inds[0].bs(), 0, "snapshot and deltas gone");
        assert_eq!(ctx.inds[0].queued_prefill_tokens, 0);
        assert!(ctx.inds[0].routable, "purge does not govern routability");
    }

    #[test]
    fn resize_instances_grows_fleet_with_cold_routable_slots() {
        let mut f = IndicatorFactory::new(2, 0);
        let req = mk_req(13, 160);
        f.route_ctx(&req, 0);
        f.on_route(1, &req, 0);
        f.resize_instances(4);
        assert_eq!(f.n_instances(), 4);
        let ctx = f.route_ctx(&req, 1);
        assert_eq!(ctx.inds.len(), 4);
        assert_eq!(ctx.hit_tokens.len(), 4);
        assert_eq!(ctx.hit_tokens[1], 160, "existing presence survives");
        assert_eq!(ctx.hit_tokens[2], 0);
        assert!(ctx.inds[2].routable && ctx.inds[3].routable);
        // Shrink back after purging the dropped tail.
        f.purge_instance(2);
        f.purge_instance(3);
        f.resize_instances(2);
        assert_eq!(f.n_instances(), 2);
        let ctx2 = f.route_ctx(&req, 2);
        assert_eq!(ctx2.inds.len(), 2);
        assert_eq!(ctx2.hit_tokens[1], 160);
    }
}
