//! The serving-runtime facade: loads the AOT artifacts produced by
//! `python/compile/aot.py` and exposes the serving entry points (chunked
//! prefill / batched decode / KV$ extract & inject) to the live engine.
//! Python never runs here.
//!
//! Two interchangeable backends implement the [`Runtime`] trait:
//!
//! * **sim** (default) — [`sim::SimRuntime`]: a dependency-free
//!   deterministic stand-in. Per-slot state is the token history; logits
//!   are a pure hash of that history, so all the contracts the live
//!   engine relies on (chunk-invariant prefill, decode-continues-prefill,
//!   extract/inject round-trips, slot independence) hold exactly. This is
//!   what `cargo build`/`cargo test` and CI exercise — the whole live
//!   threaded cluster runs on it with no artifacts present.
//! * **pjrt** (`--features pjrt`) — [`pjrt::PjrtRuntime`]: the real path,
//!   compiling the AOT HLO-text artifacts once on the PJRT CPU client.
//!   Interchange is HLO *text* (not serialized protos): jax >= 0.5 emits
//!   64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//!   parser reassigns ids. State (KV$ tensor + parameters) travels as
//!   host literals between calls — on the CPU plugin "device" memory is
//!   host memory, so these are memcpys (DESIGN.md §Perf).
//!
//! [`ModelRuntime`] / [`Tensor`] alias whichever backend is active, so
//! `cluster/live.rs`, `main.rs` and the integration tests are written once
//! against the trait.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(not(feature = "pjrt"))]
mod sim;

/// The active backend.
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtRuntime as ModelRuntime;
#[cfg(not(feature = "pjrt"))]
pub use sim::SimRuntime as ModelRuntime;

/// The active backend's KV$/plane handle.
#[cfg(feature = "pjrt")]
pub type Tensor = xla::Literal;
#[cfg(not(feature = "pjrt"))]
pub type Tensor = sim::SimTensor;

/// Model geometry read from `manifest.json` (must match the Python
/// `ModelConfig`).
#[derive(Debug, Clone)]
pub struct LiveModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub max_seq: usize,
    pub slots: usize,
    pub chunk_buckets: Vec<usize>,
    pub kv_shape: Vec<usize>,
}

/// One parameter tensor's metadata (pjrt backend: params.bin layout).
#[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
#[derive(Debug, Clone)]
pub(crate) struct ParamSpec {
    pub(crate) name: String,
    pub(crate) shape: Vec<usize>,
}

/// The serving-runtime interface the live engine programs against.
pub trait Runtime: Sized {
    /// Opaque KV$-state / extracted-plane handle.
    type Tensor: Clone;

    /// Load (and, for pjrt, compile) everything under `dir`.
    fn load(dir: &Path) -> Result<Self>;

    /// Model geometry.
    fn config(&self) -> &LiveModelConfig;

    /// Zero-initialized KV$ state.
    fn zero_kv(&self) -> Self::Tensor;

    /// Prefill one chunk of new tokens into `slot` at position `pos`.
    /// `tokens.len()` must equal a chunk bucket; `chunk_len` <= bucket is
    /// the real token count. Returns (last-token logits, new KV$).
    fn prefill_chunk(
        &self,
        kv: &Self::Tensor,
        tokens: &[i32],
        slot: usize,
        pos: usize,
        chunk_len: usize,
    ) -> Result<(Vec<f32>, Self::Tensor)>;

    /// One decode step over all slots. `lens[i]` is slot i's context
    /// length BEFORE this token (0 = inactive). Returns
    /// (logits[slots x vocab] row-major, new KV$).
    fn decode_step(
        &self,
        kv: &Self::Tensor,
        tokens: &[i32],
        lens: &[i32],
    ) -> Result<(Vec<f32>, Self::Tensor)>;

    /// Snapshot a slot's K/V planes (host tensors) for the prefix store.
    fn extract_slot(&self, kv: &Self::Tensor, slot: usize)
        -> Result<(Self::Tensor, Self::Tensor)>;

    /// Write cached K/V planes into a slot (the KV$-hit fast path).
    fn inject_slot(
        &self,
        kv: &Self::Tensor,
        slot: usize,
        k: &Self::Tensor,
        v: &Self::Tensor,
    ) -> Result<Self::Tensor>;

    /// Smallest chunk bucket that fits `n` new tokens (None if n exceeds
    /// the largest bucket — caller loops chunks).
    fn bucket_for(&self, n: usize) -> Option<usize> {
        self.config().chunk_buckets.iter().copied().find(|&b| b >= n)
    }

    fn largest_bucket(&self) -> usize {
        self.config().chunk_buckets.iter().copied().max().unwrap_or(0)
    }

    /// Greedy sampling helper: argmax of one slot's logits row.
    fn argmax(logits_row: &[f32]) -> i32 {
        argmax(logits_row)
    }
}

/// Argmax of a logits row (free function shared by both backends).
pub fn argmax(logits_row: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut best_v = f32::MIN;
    for (i, &v) in logits_row.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as i32
}

/// Parse `manifest.json`: model geometry, parameter specs, artifact paths.
pub(crate) fn load_manifest(
    dir: &Path,
) -> Result<(LiveModelConfig, Vec<ParamSpec>, BTreeMap<String, PathBuf>)> {
    let text = std::fs::read_to_string(dir.join("manifest.json")).with_context(|| {
        format!("reading {}/manifest.json (run `make artifacts`)", dir.display())
    })?;
    let v = Json::parse(&text).map_err(|e| anyhow!("manifest.json: {e}"))?;
    let model = v.get("model").ok_or_else(|| anyhow!("manifest: no model"))?;
    let geti = |k: &str| -> Result<usize> {
        model
            .get(k)
            .and_then(|x| x.as_usize())
            .ok_or_else(|| anyhow!("manifest: missing model.{k}"))
    };
    let cfg = LiveModelConfig {
        vocab: geti("vocab")?,
        d_model: geti("d_model")?,
        n_layers: geti("n_layers")?,
        n_heads: geti("n_heads")?,
        d_head: geti("d_head")?,
        max_seq: geti("max_seq")?,
        slots: geti("slots")?,
        chunk_buckets: v
            .get("chunk_buckets")
            .and_then(|x| x.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
            .unwrap_or_default(),
        kv_shape: v
            .get("kv_shape")
            .and_then(|x| x.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
            .unwrap_or_default(),
    };
    let params: Vec<ParamSpec> = v
        .get("params")
        .and_then(|x| x.as_arr())
        .ok_or_else(|| anyhow!("manifest: no params"))?
        .iter()
        .map(|p| ParamSpec {
            name: p.get("name").and_then(|x| x.as_str()).unwrap_or("").to_string(),
            shape: p
                .get("shape")
                .and_then(|x| x.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default(),
        })
        .collect();
    let mut artifacts = BTreeMap::new();
    if let Some(obj) = v.get("artifacts").and_then(|x| x.as_obj()) {
        for (name, a) in obj {
            if let Some(file) = a.get("file").and_then(|x| x.as_str()) {
                artifacts.insert(name.clone(), dir.join(file));
            }
        }
    }
    Ok((cfg, params, artifacts))
}

/// Default artifacts directory: `$LMETRIC_ARTIFACTS` or `artifacts/`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("LMETRIC_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_max() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        assert_eq!(ModelRuntime::argmax(&[0.1, 3.0, -1.0]), 1);
    }

    #[test]
    fn manifest_parses() {
        // Per-process dir: concurrent `cargo test` runs must not race.
        let dir = std::env::temp_dir()
            .join(format!("lmetric_manifest_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{
 "model": {"vocab": 1024, "d_model": 128, "n_layers": 2, "n_heads": 4,
           "d_head": 32, "max_seq": 512, "slots": 8},
 "chunk_buckets": [16, 64, 256],
 "kv_shape": [2, 2, 8, 4, 512, 32],
 "params": [{"name": "embed", "shape": [1024, 128]}],
 "artifacts": {"decode": {"file": "decode.hlo.txt"}}
}"#,
        )
        .unwrap();
        let (cfg, params, artifacts) = load_manifest(&dir).unwrap();
        assert_eq!(cfg.vocab, 1024);
        assert_eq!(cfg.slots, 8);
        assert_eq!(cfg.chunk_buckets, vec![16, 64, 256]);
        assert_eq!(params.len(), 1);
        assert!(artifacts.contains_key("decode"));
        std::fs::remove_dir_all(&dir).ok();
    }

    // Full runtime round-trip tests live in rust/tests/runtime_pjrt.rs
    // (they run against the sim backend by default and against real PJRT
    // artifacts under --features pjrt).
}
