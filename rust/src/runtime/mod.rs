//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them once on the PJRT CPU client,
//! and exposes the serving entry points (chunked prefill / batched decode
//! / KV$ extract & inject) to the live engine. Python never runs here.
//!
//! Interchange is HLO *text* (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! State strategy: the KV$ tensor and parameters travel as host
//! [`xla::Literal`]s between calls. On the CPU PJRT plugin "device"
//! memory is host memory, so these are memcpys — the simple, correct
//! choice for the validation path (a TPU deployment would keep buffers
//! device-resident and donate them instead; DESIGN.md §Perf).

use std::collections::BTreeMap;
use std::io::Read;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Model geometry read from `manifest.json` (must match the Python
/// [`ModelConfig`]).
#[derive(Debug, Clone)]
pub struct LiveModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub max_seq: usize,
    pub slots: usize,
    pub chunk_buckets: Vec<usize>,
    pub kv_shape: Vec<usize>,
}

/// One parameter tensor's metadata.
#[derive(Debug, Clone)]
struct ParamSpec {
    name: String,
    shape: Vec<usize>,
}

/// The compiled model: one executable per entry point.
pub struct ModelRuntime {
    pub cfg: LiveModelConfig,
    #[allow(dead_code)]
    client: xla::PjRtClient,
    prefill: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    decode: xla::PjRtLoadedExecutable,
    extract: xla::PjRtLoadedExecutable,
    inject: xla::PjRtLoadedExecutable,
    params: Vec<xla::Literal>,
}

fn load_manifest(dir: &Path) -> Result<(LiveModelConfig, Vec<ParamSpec>, BTreeMap<String, PathBuf>)> {
    let text = std::fs::read_to_string(dir.join("manifest.json"))
        .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
    let v = Json::parse(&text).map_err(|e| anyhow!("manifest.json: {e}"))?;
    let model = v.get("model").ok_or_else(|| anyhow!("manifest: no model"))?;
    let geti = |k: &str| -> Result<usize> {
        model
            .get(k)
            .and_then(|x| x.as_usize())
            .ok_or_else(|| anyhow!("manifest: missing model.{k}"))
    };
    let cfg = LiveModelConfig {
        vocab: geti("vocab")?,
        d_model: geti("d_model")?,
        n_layers: geti("n_layers")?,
        n_heads: geti("n_heads")?,
        d_head: geti("d_head")?,
        max_seq: geti("max_seq")?,
        slots: geti("slots")?,
        chunk_buckets: v
            .get("chunk_buckets")
            .and_then(|x| x.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
            .unwrap_or_default(),
        kv_shape: v
            .get("kv_shape")
            .and_then(|x| x.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
            .unwrap_or_default(),
    };
    let params: Vec<ParamSpec> = v
        .get("params")
        .and_then(|x| x.as_arr())
        .ok_or_else(|| anyhow!("manifest: no params"))?
        .iter()
        .map(|p| ParamSpec {
            name: p.get("name").and_then(|x| x.as_str()).unwrap_or("").to_string(),
            shape: p
                .get("shape")
                .and_then(|x| x.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default(),
        })
        .collect();
    let mut artifacts = BTreeMap::new();
    if let Some(obj) = v.get("artifacts").and_then(|x| x.as_obj()) {
        for (name, a) in obj {
            if let Some(file) = a.get("file").and_then(|x| x.as_str()) {
                artifacts.insert(name.clone(), dir.join(file));
            }
        }
    }
    Ok((cfg, params, artifacts))
}

fn load_params_bin(dir: &Path, specs: &[ParamSpec]) -> Result<Vec<xla::Literal>> {
    let mut f = std::fs::File::open(dir.join("params.bin"))
        .with_context(|| format!("{}/params.bin", dir.display()))?;
    let mut bytes = Vec::new();
    f.read_to_end(&mut bytes)?;
    let total: usize = specs.iter().map(|s| s.shape.iter().product::<usize>()).sum();
    if bytes.len() != total * 4 {
        bail!(
            "params.bin has {} bytes, manifest declares {} floats",
            bytes.len(),
            total
        );
    }
    let floats: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    let mut out = Vec::with_capacity(specs.len());
    let mut off = 0usize;
    for s in specs {
        let n: usize = s.shape.iter().product();
        let dims: Vec<i64> = s.shape.iter().map(|d| *d as i64).collect();
        let lit = xla::Literal::vec1(&floats[off..off + n])
            .reshape(&dims)
            .with_context(|| format!("param {} reshape", s.name))?;
        out.push(lit);
        off += n;
    }
    Ok(out)
}

fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().ok_or_else(|| anyhow!("bad path"))?,
    )
    .map_err(|e| anyhow!("{}: {e:?}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))
}

impl ModelRuntime {
    /// Load + compile everything under `dir` (default `artifacts/`).
    pub fn load(dir: &Path) -> Result<ModelRuntime> {
        let (cfg, param_specs, artifacts) = load_manifest(dir)?;
        let params = load_params_bin(dir, &param_specs)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e:?}"))?;
        let mut prefill = BTreeMap::new();
        for &c in &cfg.chunk_buckets {
            let path = artifacts
                .get(&format!("prefill_c{c}"))
                .ok_or_else(|| anyhow!("manifest missing prefill_c{c}"))?;
            prefill.insert(c, compile(&client, path)?);
        }
        let decode = compile(
            &client,
            artifacts.get("decode").ok_or_else(|| anyhow!("missing decode"))?,
        )?;
        let extract = compile(
            &client,
            artifacts
                .get("extract_slot")
                .ok_or_else(|| anyhow!("missing extract_slot"))?,
        )?;
        let inject = compile(
            &client,
            artifacts
                .get("inject_slot")
                .ok_or_else(|| anyhow!("missing inject_slot"))?,
        )?;
        Ok(ModelRuntime {
            cfg,
            client,
            prefill,
            decode,
            extract,
            inject,
            params,
        })
    }

    /// Zero-initialized KV$ state.
    pub fn zero_kv(&self) -> xla::Literal {
        let dims: Vec<usize> = self.cfg.kv_shape.clone();
        xla::Literal::create_from_shape(xla::PrimitiveType::F32, &dims)
    }

    /// Smallest chunk bucket that fits `n` new tokens (None if n exceeds
    /// the largest bucket — caller loops chunks).
    pub fn bucket_for(&self, n: usize) -> Option<usize> {
        self.cfg.chunk_buckets.iter().copied().find(|&b| b >= n)
    }

    pub fn largest_bucket(&self) -> usize {
        self.cfg.chunk_buckets.iter().copied().max().unwrap_or(0)
    }

    fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let out = exe
            .execute::<&xla::Literal>(args)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))
    }

    /// Prefill one chunk of new tokens into `slot` at position `pos`.
    /// `tokens.len()` must equal a chunk bucket; `chunk_len` ≤ bucket is
    /// the real token count. Returns (last-token logits, new KV$).
    pub fn prefill_chunk(
        &self,
        kv: &xla::Literal,
        tokens: &[i32],
        slot: usize,
        pos: usize,
        chunk_len: usize,
    ) -> Result<(Vec<f32>, xla::Literal)> {
        let exe = self
            .prefill
            .get(&tokens.len())
            .ok_or_else(|| anyhow!("no prefill bucket of size {}", tokens.len()))?;
        let tok = xla::Literal::vec1(tokens);
        let slot_l = xla::Literal::scalar(slot as i32);
        let pos_l = xla::Literal::scalar(pos as i32);
        let len_l = xla::Literal::scalar(chunk_len as i32);
        let mut args: Vec<&xla::Literal> = vec![&tok, &slot_l, &pos_l, &len_l, kv];
        args.extend(self.params.iter());
        let mut parts = self.run(exe, &args)?;
        let kv_new = parts.pop().ok_or_else(|| anyhow!("prefill: missing kv"))?;
        let logits = parts
            .pop()
            .ok_or_else(|| anyhow!("prefill: missing logits"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("logits: {e:?}"))?;
        Ok((logits, kv_new))
    }

    /// One decode step over all slots. `lens[i]` is slot i's context
    /// length BEFORE this token (0 = inactive). Returns
    /// (logits[slots×vocab] row-major, new KV$).
    pub fn decode_step(
        &self,
        kv: &xla::Literal,
        tokens: &[i32],
        lens: &[i32],
    ) -> Result<(Vec<f32>, xla::Literal)> {
        if tokens.len() != self.cfg.slots || lens.len() != self.cfg.slots {
            bail!("decode_step wants {} slots", self.cfg.slots);
        }
        let tok = xla::Literal::vec1(tokens);
        let len_l = xla::Literal::vec1(lens);
        let mut args: Vec<&xla::Literal> = vec![&tok, &len_l, kv];
        args.extend(self.params.iter());
        let mut parts = self.run(&self.decode, &args)?;
        let kv_new = parts.pop().ok_or_else(|| anyhow!("decode: missing kv"))?;
        let logits = parts
            .pop()
            .ok_or_else(|| anyhow!("decode: missing logits"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("logits: {e:?}"))?;
        Ok((logits, kv_new))
    }

    /// Snapshot a slot's K/V planes (host literals) for the prefix store.
    pub fn extract_slot(&self, kv: &xla::Literal, slot: usize) -> Result<(xla::Literal, xla::Literal)> {
        let slot_l = xla::Literal::scalar(slot as i32);
        let mut parts = self.run(&self.extract, &[kv, &slot_l])?;
        let v = parts.pop().ok_or_else(|| anyhow!("extract: missing v"))?;
        let k = parts.pop().ok_or_else(|| anyhow!("extract: missing k"))?;
        Ok((k, v))
    }

    /// Write cached K/V planes into a slot (the KV$-hit fast path).
    pub fn inject_slot(
        &self,
        kv: &xla::Literal,
        slot: usize,
        k: &xla::Literal,
        v: &xla::Literal,
    ) -> Result<xla::Literal> {
        let slot_l = xla::Literal::scalar(slot as i32);
        let mut parts = self.run(&self.inject, &[kv, &slot_l, k, v])?;
        parts.pop().ok_or_else(|| anyhow!("inject: missing kv"))
    }

    /// Greedy sampling helper: argmax of one slot's logits row.
    pub fn argmax(logits_row: &[f32]) -> i32 {
        let mut best = 0usize;
        let mut best_v = f32::MIN;
        for (i, &v) in logits_row.iter().enumerate() {
            if v > best_v {
                best_v = v;
                best = i;
            }
        }
        best as i32
    }
}

/// Default artifacts directory: `$LMETRIC_ARTIFACTS` or `artifacts/`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("LMETRIC_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_max() {
        assert_eq!(ModelRuntime::argmax(&[0.1, 3.0, -1.0]), 1);
        assert_eq!(ModelRuntime::argmax(&[5.0]), 0);
    }

    // Full PJRT round-trip tests live in rust/tests/runtime_pjrt.rs (they
    // need artifacts/ built).
}
