//! Dependency-free deterministic runtime backend (the default build).
//!
//! The "model" is a pure function of each slot's token history: the KV$
//! tensor holds the token history per slot, and a logits row is derived by
//! hashing that history. Because output depends only on the final history
//! — never on how it was chunked, which slot computed it, or what other
//! slots contain — every contract the live engine relies on holds exactly:
//!
//! * chunked prefill is chunk-partition invariant,
//! * decode continues prefill (same logits as prefilling the longer
//!   sequence from scratch),
//! * extract/inject round-trips reproduce the KV$-hit path bit-for-bit,
//! * batched decode slots are independent.
//!
//! This lets `cargo test` and CI drive the full live threaded cluster
//! (threads, prefix store, chunking, piggybacked indicators) with no
//! artifacts, no Python and no PJRT. Real transformer execution lives in
//! the `pjrt` backend (`--features pjrt`).

use std::path::Path;

use anyhow::{bail, Result};

use super::{load_manifest, LiveModelConfig, Runtime};

/// Splitmix-style mix for deterministic pseudo-logits.
#[inline]
fn mix(h: u64, x: u64) -> u64 {
    let mut z = h ^ x.wrapping_mul(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// KV$ state / extracted plane of the sim backend.
#[derive(Debug, Clone)]
pub enum SimTensor {
    /// Full per-instance cache: one token history per slot.
    Kv(Vec<Vec<i32>>),
    /// A snapshot of one slot's history (what extract/inject carry).
    Plane(Vec<i32>),
}

/// The deterministic stand-in runtime.
pub struct SimRuntime {
    pub cfg: LiveModelConfig,
}

impl SimRuntime {
    /// Geometry matching `python/compile/model.py::ModelConfig`, used when
    /// no artifacts directory is present (the sim backend needs no
    /// artifacts to run).
    fn default_config() -> LiveModelConfig {
        LiveModelConfig {
            vocab: 1024,
            d_model: 128,
            n_layers: 2,
            n_heads: 4,
            d_head: 32,
            max_seq: 512,
            slots: 8,
            chunk_buckets: vec![16, 64, 256],
            kv_shape: vec![2, 2, 8, 4, 512, 32],
        }
    }

    /// Deterministic pseudo-logits for a token history.
    fn logits_for(&self, hist: &[i32]) -> Vec<f32> {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for t in hist {
            h = mix(h, *t as u64 ^ 0x5bd1_e995);
        }
        (0..self.cfg.vocab)
            .map(|v| (mix(h, v as u64) >> 11) as f32 / (1u64 << 53) as f32)
            .collect()
    }

    fn slots<'a>(&self, kv: &'a SimTensor, what: &str) -> Result<&'a Vec<Vec<i32>>> {
        match kv {
            SimTensor::Kv(slots) => Ok(slots),
            SimTensor::Plane(_) => bail!("{what}: expected a KV$ tensor, got a plane"),
        }
    }
}

impl Runtime for SimRuntime {
    type Tensor = SimTensor;

    fn load(dir: &Path) -> Result<SimRuntime> {
        let cfg = if dir.join("manifest.json").exists() {
            load_manifest(dir)?.0
        } else {
            SimRuntime::default_config()
        };
        if cfg.slots == 0 || cfg.vocab == 0 || cfg.chunk_buckets.is_empty() {
            bail!("sim runtime: degenerate model config in {}", dir.display());
        }
        Ok(SimRuntime { cfg })
    }

    fn config(&self) -> &LiveModelConfig {
        &self.cfg
    }

    fn zero_kv(&self) -> SimTensor {
        SimTensor::Kv(vec![Vec::new(); self.cfg.slots])
    }

    fn prefill_chunk(
        &self,
        kv: &SimTensor,
        tokens: &[i32],
        slot: usize,
        pos: usize,
        chunk_len: usize,
    ) -> Result<(Vec<f32>, SimTensor)> {
        if !self.cfg.chunk_buckets.contains(&tokens.len()) {
            bail!("no prefill bucket of size {}", tokens.len());
        }
        if chunk_len == 0 || chunk_len > tokens.len() {
            bail!("prefill: chunk_len {chunk_len} out of range for bucket {}", tokens.len());
        }
        let mut slots = self.slots(kv, "prefill_chunk")?.clone();
        if slot >= slots.len() {
            bail!("prefill: slot {slot} out of range ({} slots)", slots.len());
        }
        if pos > slots[slot].len() {
            bail!(
                "prefill: pos {pos} beyond slot {slot}'s cached length {}",
                slots[slot].len()
            );
        }
        // Writing at `pos` masks everything the slot held beyond it —
        // exactly the causal-masking semantics of the real KV cache.
        slots[slot].truncate(pos);
        slots[slot].extend_from_slice(&tokens[..chunk_len]);
        let logits = self.logits_for(&slots[slot]);
        Ok((logits, SimTensor::Kv(slots)))
    }

    fn decode_step(
        &self,
        kv: &SimTensor,
        tokens: &[i32],
        lens: &[i32],
    ) -> Result<(Vec<f32>, SimTensor)> {
        if tokens.len() != self.cfg.slots || lens.len() != self.cfg.slots {
            bail!("decode_step wants {} slots", self.cfg.slots);
        }
        let mut slots = self.slots(kv, "decode_step")?.clone();
        let vocab = self.cfg.vocab;
        let mut logits = vec![0.0f32; self.cfg.slots * vocab];
        for i in 0..self.cfg.slots {
            if lens[i] <= 0 {
                continue; // inactive slot: zero row, state untouched
            }
            if slots[i].len() != lens[i] as usize {
                bail!(
                    "decode: slot {i} holds {} cached tokens but lens says {}",
                    slots[i].len(),
                    lens[i]
                );
            }
            slots[i].push(tokens[i]);
            let row = self.logits_for(&slots[i]);
            logits[i * vocab..(i + 1) * vocab].copy_from_slice(&row);
        }
        Ok((logits, SimTensor::Kv(slots)))
    }

    fn extract_slot(&self, kv: &SimTensor, slot: usize) -> Result<(SimTensor, SimTensor)> {
        let slots = self.slots(kv, "extract_slot")?;
        if slot >= slots.len() {
            bail!("extract: slot {slot} out of range");
        }
        Ok((
            SimTensor::Plane(slots[slot].clone()),
            SimTensor::Plane(slots[slot].clone()),
        ))
    }

    fn inject_slot(
        &self,
        kv: &SimTensor,
        slot: usize,
        k: &SimTensor,
        _v: &SimTensor,
    ) -> Result<SimTensor> {
        let mut slots = self.slots(kv, "inject_slot")?.clone();
        if slot >= slots.len() {
            bail!("inject: slot {slot} out of range");
        }
        let SimTensor::Plane(hist) = k else {
            bail!("inject: expected a plane tensor");
        };
        slots[slot] = hist.clone();
        Ok(SimTensor::Kv(slots))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rt() -> SimRuntime {
        SimRuntime {
            cfg: SimRuntime::default_config(),
        }
    }

    #[test]
    fn load_without_artifacts_uses_defaults() {
        let rt = SimRuntime::load(Path::new("/definitely/not/a/dir")).unwrap();
        assert_eq!(rt.cfg.vocab, 1024);
        assert_eq!(rt.cfg.slots, 8);
        assert_eq!(rt.cfg.chunk_buckets, vec![16, 64, 256]);
    }

    #[test]
    fn logits_depend_only_on_history() {
        let rt = rt();
        let kv = rt.zero_kv();
        let toks: Vec<i32> = (1..=32).collect();
        // One 64-bucket chunk vs two 16-bucket chunks.
        let mut buf = toks.clone();
        buf.resize(64, 0);
        let (a, _) = rt.prefill_chunk(&kv, &buf, 0, 0, 32).unwrap();
        let (_, kv1) = rt.prefill_chunk(&kv, &toks[..16].to_vec(), 3, 0, 16).unwrap();
        let (b, _) = rt.prefill_chunk(&kv1, &toks[16..].to_vec(), 3, 16, 16).unwrap();
        assert_eq!(a, b, "chunk-partition and slot invariance");
    }

    #[test]
    fn decode_continues_prefill() {
        let rt = rt();
        let toks: Vec<i32> = (1..=16).collect();
        let (l, kv) = rt.prefill_chunk(&rt.zero_kv(), &toks, 2, 0, 16).unwrap();
        let next = crate::runtime::argmax(&l);
        let mut tok_in = vec![0i32; 8];
        let mut lens = vec![0i32; 8];
        tok_in[2] = next;
        lens[2] = 16;
        let (dl, _) = rt.decode_step(&kv, &tok_in, &lens).unwrap();
        // Oracle: prefill the 17-token sequence (bucket 64).
        let mut full = toks.clone();
        full.push(next);
        full.resize(64, 0);
        let (ol, _) = rt.prefill_chunk(&rt.zero_kv(), &full, 0, 0, 17).unwrap();
        assert_eq!(&dl[2 * 1024..3 * 1024], &ol[..]);
        // Inactive slots stay zero.
        assert!(dl[..1024].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn extract_inject_roundtrip() {
        let rt = rt();
        let toks: Vec<i32> = (1..=16).collect();
        let (_, kv) = rt.prefill_chunk(&rt.zero_kv(), &toks, 0, 0, 16).unwrap();
        let (k, v) = rt.extract_slot(&kv, 0).unwrap();
        let kv2 = rt.inject_slot(&rt.zero_kv(), 5, &k, &v).unwrap();
        // Continue from the hit on slot 5 with 4 fresh tokens.
        let mut buf = vec![90, 91, 92, 93];
        buf.resize(16, 0);
        let (hit, _) = rt.prefill_chunk(&kv2, &buf, 5, 16, 4).unwrap();
        let mut full = toks;
        full.extend([90, 91, 92, 93]);
        full.resize(64, 0);
        let (cold, _) = rt.prefill_chunk(&rt.zero_kv(), &full, 1, 0, 20).unwrap();
        assert_eq!(hit, cold);
    }

    #[test]
    fn contract_violations_error() {
        let rt = rt();
        let kv = rt.zero_kv();
        assert!(rt.prefill_chunk(&kv, &[1; 17], 0, 0, 17).is_err(), "bad bucket");
        assert!(rt.prefill_chunk(&kv, &[1; 16], 9, 0, 16).is_err(), "bad slot");
        assert!(rt.prefill_chunk(&kv, &[1; 16], 0, 4, 16).is_err(), "pos gap");
        let lens = vec![3i32; 8];
        assert!(rt.decode_step(&kv, &[1; 8], &lens).is_err(), "len mismatch");
    }
}
