//! Real PJRT runtime backend (`--features pjrt`): loads the AOT HLO-text
//! artifacts, compiles them once on the PJRT CPU client, and executes the
//! serving entry points. See the module docs in `runtime/mod.rs` for the
//! interchange-format and state-strategy rationale.
//!
//! Built against the vendored API stub by default (keeps this path
//! compiling in offline CI); point the `xla` dependency at the crates.io
//! `xla` crate to actually execute.

use std::collections::BTreeMap;
use std::io::Read;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::{load_manifest, LiveModelConfig, ParamSpec, Runtime};

/// The compiled model: one executable per entry point.
pub struct PjrtRuntime {
    pub cfg: LiveModelConfig,
    #[allow(dead_code)]
    client: xla::PjRtClient,
    prefill: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    decode: xla::PjRtLoadedExecutable,
    extract: xla::PjRtLoadedExecutable,
    inject: xla::PjRtLoadedExecutable,
    params: Vec<xla::Literal>,
}

fn load_params_bin(dir: &Path, specs: &[ParamSpec]) -> Result<Vec<xla::Literal>> {
    let mut f = std::fs::File::open(dir.join("params.bin"))
        .with_context(|| format!("{}/params.bin", dir.display()))?;
    let mut bytes = Vec::new();
    f.read_to_end(&mut bytes)?;
    let total: usize = specs.iter().map(|s| s.shape.iter().product::<usize>()).sum();
    if bytes.len() != total * 4 {
        bail!(
            "params.bin has {} bytes, manifest declares {} floats",
            bytes.len(),
            total
        );
    }
    let floats: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    let mut out = Vec::with_capacity(specs.len());
    let mut off = 0usize;
    for s in specs {
        let n: usize = s.shape.iter().product();
        let dims: Vec<i64> = s.shape.iter().map(|d| *d as i64).collect();
        let lit = xla::Literal::vec1(&floats[off..off + n])
            .reshape(&dims)
            .with_context(|| format!("param {} reshape", s.name))?;
        out.push(lit);
        off += n;
    }
    Ok(out)
}

fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().ok_or_else(|| anyhow!("bad path"))?,
    )
    .map_err(|e| anyhow!("{}: {e:?}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))
}

impl PjrtRuntime {
    fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let out = exe
            .execute::<&xla::Literal>(args)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))
    }
}

impl Runtime for PjrtRuntime {
    type Tensor = xla::Literal;

    /// Load + compile everything under `dir` (default `artifacts/`).
    fn load(dir: &Path) -> Result<PjrtRuntime> {
        let (cfg, param_specs, artifacts) = load_manifest(dir)?;
        let params = load_params_bin(dir, &param_specs)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e:?}"))?;
        let mut prefill = BTreeMap::new();
        for &c in &cfg.chunk_buckets {
            let path = artifacts
                .get(&format!("prefill_c{c}"))
                .ok_or_else(|| anyhow!("manifest missing prefill_c{c}"))?;
            prefill.insert(c, compile(&client, path)?);
        }
        let decode = compile(
            &client,
            artifacts.get("decode").ok_or_else(|| anyhow!("missing decode"))?,
        )?;
        let extract = compile(
            &client,
            artifacts
                .get("extract_slot")
                .ok_or_else(|| anyhow!("missing extract_slot"))?,
        )?;
        let inject = compile(
            &client,
            artifacts
                .get("inject_slot")
                .ok_or_else(|| anyhow!("missing inject_slot"))?,
        )?;
        Ok(PjrtRuntime {
            cfg,
            client,
            prefill,
            decode,
            extract,
            inject,
            params,
        })
    }

    fn config(&self) -> &LiveModelConfig {
        &self.cfg
    }

    fn zero_kv(&self) -> xla::Literal {
        let dims: Vec<usize> = self.cfg.kv_shape.clone();
        xla::Literal::create_from_shape(xla::PrimitiveType::F32, &dims)
    }

    fn prefill_chunk(
        &self,
        kv: &xla::Literal,
        tokens: &[i32],
        slot: usize,
        pos: usize,
        chunk_len: usize,
    ) -> Result<(Vec<f32>, xla::Literal)> {
        let exe = self
            .prefill
            .get(&tokens.len())
            .ok_or_else(|| anyhow!("no prefill bucket of size {}", tokens.len()))?;
        let tok = xla::Literal::vec1(tokens);
        let slot_l = xla::Literal::scalar(slot as i32);
        let pos_l = xla::Literal::scalar(pos as i32);
        let len_l = xla::Literal::scalar(chunk_len as i32);
        let mut args: Vec<&xla::Literal> = vec![&tok, &slot_l, &pos_l, &len_l, kv];
        args.extend(self.params.iter());
        let mut parts = self.run(exe, &args)?;
        let kv_new = parts.pop().ok_or_else(|| anyhow!("prefill: missing kv"))?;
        let logits = parts
            .pop()
            .ok_or_else(|| anyhow!("prefill: missing logits"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("logits: {e:?}"))?;
        Ok((logits, kv_new))
    }

    fn decode_step(
        &self,
        kv: &xla::Literal,
        tokens: &[i32],
        lens: &[i32],
    ) -> Result<(Vec<f32>, xla::Literal)> {
        if tokens.len() != self.cfg.slots || lens.len() != self.cfg.slots {
            bail!("decode_step wants {} slots", self.cfg.slots);
        }
        let tok = xla::Literal::vec1(tokens);
        let len_l = xla::Literal::vec1(lens);
        let mut args: Vec<&xla::Literal> = vec![&tok, &len_l, kv];
        args.extend(self.params.iter());
        let mut parts = self.run(&self.decode, &args)?;
        let kv_new = parts.pop().ok_or_else(|| anyhow!("decode: missing kv"))?;
        let logits = parts
            .pop()
            .ok_or_else(|| anyhow!("decode: missing logits"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("logits: {e:?}"))?;
        Ok((logits, kv_new))
    }

    fn extract_slot(
        &self,
        kv: &xla::Literal,
        slot: usize,
    ) -> Result<(xla::Literal, xla::Literal)> {
        let slot_l = xla::Literal::scalar(slot as i32);
        let mut parts = self.run(&self.extract, &[kv, &slot_l])?;
        let v = parts.pop().ok_or_else(|| anyhow!("extract: missing v"))?;
        let k = parts.pop().ok_or_else(|| anyhow!("extract: missing k"))?;
        Ok((k, v))
    }

    fn inject_slot(
        &self,
        kv: &xla::Literal,
        slot: usize,
        k: &xla::Literal,
        v: &xla::Literal,
    ) -> Result<xla::Literal> {
        let slot_l = xla::Literal::scalar(slot as i32);
        let mut parts = self.run(&self.inject, &[kv, &slot_l, k, v])?;
        parts.pop().ok_or_else(|| anyhow!("inject: missing kv"))
    }
}
