//! Analytic per-step cost model for a serving instance.
//!
//! An engine step executes (chunked-prefill tokens ‖ one decode token for
//! every running sequence) as one fused batch (Sarathi-Serve-style, what
//! vLLM-v1 does). Its duration decomposes into:
//!
//! * a fixed step overhead (kernel launch, scheduler, sampler),
//! * a compute term linear in new prefill tokens (GEMM-bound),
//! * an attention term ∝ new-token × context (the quadratic part —
//!   this is what KV$ hits avoid, and why the P-token indicator is the
//!   right KV$-awareness signal),
//! * a decode term: a weight-read floor plus per-sequence and per-context-
//!   token costs (memory-bound; nearly flat in tokens at small batch —
//!   the paper's Fig. 19b rationale for BS as the decode-load indicator).
//!
//! The constants are calibrated so the *ratios* match an H20-class device
//! serving the paper's two model families; `lmetric calibrate` cross-checks
//! the shape against the real PJRT transformer (EXPERIMENTS.md §Calib).

/// Cost-model parameters for one model family on the testbed hardware.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelProfile {
    pub name: &'static str,
    /// Fixed per-step overhead, µs.
    pub step_fixed_us: f64,
    /// Prefill GEMM cost per new token, µs.
    pub prefill_us_per_token: f64,
    /// Prefill attention cost per (new token × 1k context tokens), µs.
    pub prefill_attn_us_per_tok_kctx: f64,
    /// Decode weight-read floor per step (if any sequence decodes), µs.
    pub decode_base_us: f64,
    /// Decode marginal cost per running sequence, µs.
    pub decode_us_per_seq: f64,
    /// Decode KV-read cost per context token in the batch, µs.
    pub decode_us_per_kv_token: f64,
}

impl ModelProfile {
    /// Qwen2-7B-class dense model on an H20-class GPU.
    pub fn dense_7b() -> ModelProfile {
        ModelProfile {
            name: "dense-7b",
            step_fixed_us: 300.0,
            prefill_us_per_token: 300.0,
            prefill_attn_us_per_tok_kctx: 25.0,
            decode_base_us: 3500.0,
            decode_us_per_seq: 40.0,
            decode_us_per_kv_token: 0.020,
        }
    }

    /// Qwen3-30B-class MoE (≈3B active) on an H20-class GPU: cheaper
    /// per-token compute than dense-7B, heavier weight-read floor.
    pub fn moe_30b() -> ModelProfile {
        ModelProfile {
            name: "moe-30b",
            step_fixed_us: 350.0,
            prefill_us_per_token: 150.0,
            prefill_attn_us_per_tok_kctx: 18.0,
            decode_base_us: 9000.0,
            decode_us_per_seq: 60.0,
            decode_us_per_kv_token: 0.020,
        }
    }

    pub fn by_name(name: &str) -> Option<ModelProfile> {
        match name {
            "dense-7b" => Some(Self::dense_7b()),
            "moe-30b" => Some(Self::moe_30b()),
            _ => None,
        }
    }

    /// Duration of one engine step, µs.
    ///
    /// * `prefill_tokens` — new prefill tokens in this step's chunk budget.
    /// * `prefill_ctx_tokens` — Σ over prefilled tokens of their context
    ///   length, in units of token·kcontext (attention work).
    /// * `decode_seqs` — sequences producing one token this step.
    /// * `decode_ctx_tokens` — Σ context length over decoding sequences.
    pub fn step_us(
        &self,
        prefill_tokens: usize,
        prefill_ctx_tok_kctx: f64,
        decode_seqs: usize,
        decode_ctx_tokens: usize,
    ) -> f64 {
        if prefill_tokens == 0 && decode_seqs == 0 {
            return 0.0;
        }
        let mut t = self.step_fixed_us;
        if prefill_tokens > 0 {
            t += prefill_tokens as f64 * self.prefill_us_per_token
                + prefill_ctx_tok_kctx * self.prefill_attn_us_per_tok_kctx;
        }
        if decode_seqs > 0 {
            t += self.decode_base_us
                + decode_seqs as f64 * self.decode_us_per_seq
                + decode_ctx_tokens as f64 * self.decode_us_per_kv_token;
        }
        t
    }

    /// Latency estimate for prefilling `new_tokens` on an otherwise-idle
    /// instance (used by capacity profiling and the VIDUR-like simulator).
    pub fn prefill_us(&self, new_tokens: usize, start_ctx: usize, chunk_budget: usize) -> f64 {
        if new_tokens == 0 {
            // A fully-cached prompt still needs one step to emit a token.
            return self.step_fixed_us + self.prefill_us_per_token;
        }
        let mut left = new_tokens;
        let mut ctx = start_ctx;
        let mut total = 0.0;
        while left > 0 {
            let chunk = left.min(chunk_budget);
            let avg_kctx = (ctx as f64 + chunk as f64 / 2.0) / 1000.0;
            total += self.step_us(chunk, chunk as f64 * avg_kctx, 0, 0);
            ctx += chunk;
            left -= chunk;
        }
        total
    }
}

/// Hardware class of one fleet slot.
///
/// A heterogeneous fleet mixes device classes (H100-class, L40-class, …)
/// that run the *same* [`ModelProfile`] at different speeds. The profile
/// captures the model's cost shape; the instance profile captures the
/// slot's throughput relative to the reference device the profile was
/// calibrated on. The reference class multiplies nothing — every scale is
/// exactly `1.0` and each derived cost divides by `1.0`, which is an
/// IEEE-754 identity, so uniform fleets replay byte-identical to the
/// pre-fleet code paths (asserted in `cluster::des` tests).
#[derive(Debug, Clone, PartialEq)]
pub struct InstanceProfile {
    /// Registry name of the class ("default", "h100", "l40", "a10").
    pub class: &'static str,
    /// Prefill-side speed relative to the reference device (2.0 = twice
    /// as fast; prefill-bound costs divide by this).
    pub prefill_scale: f64,
    /// Decode-side speed relative to the reference device.
    pub decode_scale: f64,
    /// KV block budget override (`None` = keep the experiment's budget).
    pub kv_capacity_blocks: Option<usize>,
    /// Weight-paging cost of a cold model load on the *reference* device,
    /// µs. Charged scaled by `prefill_scale` (see [`Self::swap_cost_us`])
    /// when a request for a cold model is admitted.
    pub model_swap_us: u64,
    /// How many models this slot can hold warm at once.
    pub max_warm_models: usize,
    /// A warm model is preferred for eviction only after it has been idle
    /// this long (Ray-Serve-style multiplexing keepalive).
    pub model_keepalive_us: u64,
}

impl InstanceProfile {
    /// The reference class: the device every pre-fleet experiment
    /// implicitly assumed. All scales are exactly 1.0.
    pub fn reference() -> InstanceProfile {
        InstanceProfile {
            class: "default",
            prefill_scale: 1.0,
            decode_scale: 1.0,
            kv_capacity_blocks: None,
            model_swap_us: 2_000_000,
            max_warm_models: 2,
            model_keepalive_us: 10_000_000,
        }
    }

    /// H100-class: roughly 2× the reference on prefill GEMMs, 1.6× on
    /// memory-bound decode, with a deeper KV budget.
    pub fn h100() -> InstanceProfile {
        InstanceProfile {
            class: "h100",
            prefill_scale: 2.0,
            decode_scale: 1.6,
            kv_capacity_blocks: Some(12_288),
            ..Self::reference()
        }
    }

    /// L40-class: about half the reference, shallower KV budget.
    pub fn l40() -> InstanceProfile {
        InstanceProfile {
            class: "l40",
            prefill_scale: 0.45,
            decode_scale: 0.55,
            kv_capacity_blocks: Some(6_144),
            ..Self::reference()
        }
    }

    /// A10-class: the small spot-market device.
    pub fn a10() -> InstanceProfile {
        InstanceProfile {
            class: "a10",
            prefill_scale: 0.25,
            decode_scale: 0.30,
            kv_capacity_blocks: Some(4_096),
            max_warm_models: 1,
            ..Self::reference()
        }
    }

    /// Class registry names, in display order.
    pub fn all_class_names() -> Vec<&'static str> {
        vec!["default", "h100", "l40", "a10"]
    }

    pub fn by_name(name: &str) -> Option<InstanceProfile> {
        match name {
            "default" => Some(Self::reference()),
            "h100" => Some(Self::h100()),
            "l40" => Some(Self::l40()),
            "a10" => Some(Self::a10()),
            _ => None,
        }
    }

    /// True iff this slot runs at reference speed with the experiment's
    /// KV budget — the predicate the byte-identity fast paths branch on.
    pub fn is_reference(&self) -> bool {
        self.prefill_scale == 1.0
            && self.decode_scale == 1.0
            && self.kv_capacity_blocks.is_none()
    }

    /// Cold-load swap cost on this slot, µs: the reference paging cost
    /// scaled by the slot's prefill-side bandwidth.
    pub fn swap_cost_us(&self) -> u64 {
        (self.model_swap_us as f64 / self.prefill_scale).ceil() as u64
    }

    /// Duration of one engine step on this slot, µs: the reference
    /// profile's terms with prefill work divided by `prefill_scale` and
    /// decode work by `decode_scale` (the fixed overhead is device-local
    /// scheduling and does not scale). With both scales at 1.0 this
    /// reproduces [`ModelProfile::step_us`] bit-for-bit, but the engine's
    /// hot path never relies on that — it branches on
    /// [`Self::is_reference`] and calls the unscaled method directly.
    pub fn step_us(
        &self,
        p: &ModelProfile,
        prefill_tokens: usize,
        prefill_ctx_tok_kctx: f64,
        decode_seqs: usize,
        decode_ctx_tokens: usize,
    ) -> f64 {
        if prefill_tokens == 0 && decode_seqs == 0 {
            return 0.0;
        }
        let mut t = p.step_fixed_us;
        if prefill_tokens > 0 {
            t += (prefill_tokens as f64 * p.prefill_us_per_token
                + prefill_ctx_tok_kctx * p.prefill_attn_us_per_tok_kctx)
                / self.prefill_scale;
        }
        if decode_seqs > 0 {
            t += (p.decode_base_us
                + decode_seqs as f64 * p.decode_us_per_seq
                + decode_ctx_tokens as f64 * p.decode_us_per_kv_token)
                / self.decode_scale;
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_step_free() {
        let p = ModelProfile::dense_7b();
        assert_eq!(p.step_us(0, 0.0, 0, 0), 0.0);
    }

    #[test]
    fn prefill_scales_with_tokens() {
        let p = ModelProfile::dense_7b();
        let t1 = p.step_us(64, 0.0, 0, 0);
        let t2 = p.step_us(256, 0.0, 0, 0);
        assert!(t2 > t1 * 3.0 && t2 < t1 * 4.5);
    }

    #[test]
    fn attention_term_grows_with_context() {
        let p = ModelProfile::dense_7b();
        let near = p.step_us(64, 64.0 * 0.1, 0, 0); // ctx 100
        let far = p.step_us(64, 64.0 * 8.0, 0, 0); // ctx 8000
        assert!(far > near);
    }

    #[test]
    fn decode_nearly_flat_in_ctx_but_linear_in_bs() {
        // The Fig 19b property the BS indicator is chosen for.
        let p = ModelProfile::moe_30b();
        let small_ctx = p.step_us(0, 0.0, 8, 8 * 200);
        let big_ctx = p.step_us(0, 0.0, 8, 8 * 2000);
        let big_bs = p.step_us(0, 0.0, 64, 64 * 200);
        assert!(big_ctx / small_ctx < 1.6, "ctx should matter mildly");
        // 10x context grows the step far less than 8x batch size does.
        assert!(
            (big_bs - small_ctx) > 2.0 * (big_ctx - small_ctx),
            "bs must dominate ctx as the decode-load driver"
        );
    }

    #[test]
    fn kv_hit_halves_prefill() {
        let p = ModelProfile::moe_30b();
        let cold = p.prefill_us(2048, 0, 256);
        let hot = p.prefill_us(1024, 1024, 256);
        assert!(hot < cold * 0.7, "cold={cold} hot={hot}");
    }

    #[test]
    fn full_hit_still_costs_one_step() {
        let p = ModelProfile::moe_30b();
        assert!(p.prefill_us(0, 2048, 256) > 0.0);
    }

    #[test]
    fn profiles_by_name() {
        assert!(ModelProfile::by_name("dense-7b").is_some());
        assert!(ModelProfile::by_name("moe-30b").is_some());
        assert!(ModelProfile::by_name("nope").is_none());
    }

    #[test]
    fn instance_classes_by_name() {
        for name in InstanceProfile::all_class_names() {
            let ip = InstanceProfile::by_name(name).expect(name);
            assert_eq!(ip.class, name);
            assert!(ip.prefill_scale > 0.0 && ip.decode_scale > 0.0);
        }
        assert!(InstanceProfile::by_name("tpu9").is_none());
        assert!(InstanceProfile::reference().is_reference());
        assert!(!InstanceProfile::h100().is_reference());
    }

    #[test]
    fn reference_scaled_step_is_bit_identical() {
        let p = ModelProfile::moe_30b();
        let r = InstanceProfile::reference();
        for (pt, kctx, ds, dc) in
            [(0usize, 0.0f64, 0usize, 0usize), (64, 6.4, 0, 0), (0, 0.0, 8, 1600), (256, 100.0, 32, 9000)]
        {
            let a = p.step_us(pt, kctx, ds, dc);
            let b = r.step_us(&p, pt, kctx, ds, dc);
            assert_eq!(a.to_bits(), b.to_bits(), "pt={pt} ds={ds}");
        }
        assert_eq!(r.swap_cost_us(), r.model_swap_us);
    }

    #[test]
    fn faster_class_runs_the_step_faster() {
        let p = ModelProfile::moe_30b();
        let fast = InstanceProfile::h100();
        let slow = InstanceProfile::l40();
        let reference = p.step_us(256, 100.0, 32, 9000);
        assert!(fast.step_us(&p, 256, 100.0, 32, 9000) < reference);
        assert!(slow.step_us(&p, 256, 100.0, 32, 9000) > reference);
        // Swap cost scales with prefill bandwidth.
        assert!(fast.swap_cost_us() < slow.swap_cost_us());
        // Idle steps stay free on every class.
        assert_eq!(fast.step_us(&p, 0, 0.0, 0, 0), 0.0);
    }
}
