//! Analytic per-step cost model for a serving instance.
//!
//! An engine step executes (chunked-prefill tokens ‖ one decode token for
//! every running sequence) as one fused batch (Sarathi-Serve-style, what
//! vLLM-v1 does). Its duration decomposes into:
//!
//! * a fixed step overhead (kernel launch, scheduler, sampler),
//! * a compute term linear in new prefill tokens (GEMM-bound),
//! * an attention term ∝ new-token × context (the quadratic part —
//!   this is what KV$ hits avoid, and why the P-token indicator is the
//!   right KV$-awareness signal),
//! * a decode term: a weight-read floor plus per-sequence and per-context-
//!   token costs (memory-bound; nearly flat in tokens at small batch —
//!   the paper's Fig. 19b rationale for BS as the decode-load indicator).
//!
//! The constants are calibrated so the *ratios* match an H20-class device
//! serving the paper's two model families; `lmetric calibrate` cross-checks
//! the shape against the real PJRT transformer (EXPERIMENTS.md §Calib).

/// Cost-model parameters for one model family on the testbed hardware.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelProfile {
    pub name: &'static str,
    /// Fixed per-step overhead, µs.
    pub step_fixed_us: f64,
    /// Prefill GEMM cost per new token, µs.
    pub prefill_us_per_token: f64,
    /// Prefill attention cost per (new token × 1k context tokens), µs.
    pub prefill_attn_us_per_tok_kctx: f64,
    /// Decode weight-read floor per step (if any sequence decodes), µs.
    pub decode_base_us: f64,
    /// Decode marginal cost per running sequence, µs.
    pub decode_us_per_seq: f64,
    /// Decode KV-read cost per context token in the batch, µs.
    pub decode_us_per_kv_token: f64,
}

impl ModelProfile {
    /// Qwen2-7B-class dense model on an H20-class GPU.
    pub fn dense_7b() -> ModelProfile {
        ModelProfile {
            name: "dense-7b",
            step_fixed_us: 300.0,
            prefill_us_per_token: 300.0,
            prefill_attn_us_per_tok_kctx: 25.0,
            decode_base_us: 3500.0,
            decode_us_per_seq: 40.0,
            decode_us_per_kv_token: 0.020,
        }
    }

    /// Qwen3-30B-class MoE (≈3B active) on an H20-class GPU: cheaper
    /// per-token compute than dense-7B, heavier weight-read floor.
    pub fn moe_30b() -> ModelProfile {
        ModelProfile {
            name: "moe-30b",
            step_fixed_us: 350.0,
            prefill_us_per_token: 150.0,
            prefill_attn_us_per_tok_kctx: 18.0,
            decode_base_us: 9000.0,
            decode_us_per_seq: 60.0,
            decode_us_per_kv_token: 0.020,
        }
    }

    pub fn by_name(name: &str) -> Option<ModelProfile> {
        match name {
            "dense-7b" => Some(Self::dense_7b()),
            "moe-30b" => Some(Self::moe_30b()),
            _ => None,
        }
    }

    /// Duration of one engine step, µs.
    ///
    /// * `prefill_tokens` — new prefill tokens in this step's chunk budget.
    /// * `prefill_ctx_tokens` — Σ over prefilled tokens of their context
    ///   length, in units of token·kcontext (attention work).
    /// * `decode_seqs` — sequences producing one token this step.
    /// * `decode_ctx_tokens` — Σ context length over decoding sequences.
    pub fn step_us(
        &self,
        prefill_tokens: usize,
        prefill_ctx_tok_kctx: f64,
        decode_seqs: usize,
        decode_ctx_tokens: usize,
    ) -> f64 {
        if prefill_tokens == 0 && decode_seqs == 0 {
            return 0.0;
        }
        let mut t = self.step_fixed_us;
        if prefill_tokens > 0 {
            t += prefill_tokens as f64 * self.prefill_us_per_token
                + prefill_ctx_tok_kctx * self.prefill_attn_us_per_tok_kctx;
        }
        if decode_seqs > 0 {
            t += self.decode_base_us
                + decode_seqs as f64 * self.decode_us_per_seq
                + decode_ctx_tokens as f64 * self.decode_us_per_kv_token;
        }
        t
    }

    /// Latency estimate for prefilling `new_tokens` on an otherwise-idle
    /// instance (used by capacity profiling and the VIDUR-like simulator).
    pub fn prefill_us(&self, new_tokens: usize, start_ctx: usize, chunk_budget: usize) -> f64 {
        if new_tokens == 0 {
            // A fully-cached prompt still needs one step to emit a token.
            return self.step_fixed_us + self.prefill_us_per_token;
        }
        let mut left = new_tokens;
        let mut ctx = start_ctx;
        let mut total = 0.0;
        while left > 0 {
            let chunk = left.min(chunk_budget);
            let avg_kctx = (ctx as f64 + chunk as f64 / 2.0) / 1000.0;
            total += self.step_us(chunk, chunk as f64 * avg_kctx, 0, 0);
            ctx += chunk;
            left -= chunk;
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_step_free() {
        let p = ModelProfile::dense_7b();
        assert_eq!(p.step_us(0, 0.0, 0, 0), 0.0);
    }

    #[test]
    fn prefill_scales_with_tokens() {
        let p = ModelProfile::dense_7b();
        let t1 = p.step_us(64, 0.0, 0, 0);
        let t2 = p.step_us(256, 0.0, 0, 0);
        assert!(t2 > t1 * 3.0 && t2 < t1 * 4.5);
    }

    #[test]
    fn attention_term_grows_with_context() {
        let p = ModelProfile::dense_7b();
        let near = p.step_us(64, 64.0 * 0.1, 0, 0); // ctx 100
        let far = p.step_us(64, 64.0 * 8.0, 0, 0); // ctx 8000
        assert!(far > near);
    }

    #[test]
    fn decode_nearly_flat_in_ctx_but_linear_in_bs() {
        // The Fig 19b property the BS indicator is chosen for.
        let p = ModelProfile::moe_30b();
        let small_ctx = p.step_us(0, 0.0, 8, 8 * 200);
        let big_ctx = p.step_us(0, 0.0, 8, 8 * 2000);
        let big_bs = p.step_us(0, 0.0, 64, 64 * 200);
        assert!(big_ctx / small_ctx < 1.6, "ctx should matter mildly");
        // 10x context grows the step far less than 8x batch size does.
        assert!(
            (big_bs - small_ctx) > 2.0 * (big_ctx - small_ctx),
            "bs must dominate ctx as the decode-load driver"
        );
    }

    #[test]
    fn kv_hit_halves_prefill() {
        let p = ModelProfile::moe_30b();
        let cold = p.prefill_us(2048, 0, 256);
        let hot = p.prefill_us(1024, 1024, 256);
        assert!(hot < cold * 0.7, "cold={cold} hot={hot}");
    }

    #[test]
    fn full_hit_still_costs_one_step() {
        let p = ModelProfile::moe_30b();
        assert!(p.prefill_us(0, 2048, 256) > 0.0);
    }

    #[test]
    fn profiles_by_name() {
        assert!(ModelProfile::by_name("dense-7b").is_some());
        assert!(ModelProfile::by_name("moe-30b").is_some());
        assert!(ModelProfile::by_name("nope").is_none());
    }
}
