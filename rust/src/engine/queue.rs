//! Pluggable within-instance queue scheduling: the ordering of
//! `Instance.waiting` behind the router's placement decision.
//!
//! The paper's BS×P-token score decides *which instance* gets a request;
//! this module decides *which waiting request that instance admits next*.
//! Three policies, registry-built like `policy::build`:
//!
//! | name   | ordering | reference |
//! |--------|----------|-----------|
//! | `fcfs` | arrival order (the seed engine, byte-identical) | vLLM default |
//! | `srpt` | predicted total remaining work, shortest first  | Intelligent Router (PAPERS.md) |
//! | `ltr`  | `srpt` + starvation-quantum promotion           | vLLM LTR scheduler (SNIPPETS.md #1–2) |
//!
//! `srpt`/`ltr` rank by *predicted* work: the prefill debt is known at
//! enqueue time, the decode length is estimated by a deterministic
//! salted-SplitMix64 predictor (same mix as `runtime/sim.rs`, draw order
//! Python-mirrored in `python/tests/test_queue_predictor.py`) that
//! multiplies the true output length by a per-request factor in
//! [0.5, 1.5) — a stand-in for an imperfect learned length predictor.
//!
//! `ltr` replicates the vLLM LTR scheduler's anti-starvation scheme: a
//! request that has waited [`LTR_STARVATION_THRESHOLD`] tokens of engine
//! progress gains one promotion level, and each level subtracts
//! [`LTR_PRIORITY_QUANTUM`] from its effective work. Levels only grow, so
//! every waiting request's effective priority is strictly decreasing in
//! engine progress and nothing waits forever (the starvation-freedom
//! proptest in `rust/tests/engine_queue.rs` pins this).

/// One waiting request, as the queue policy sees it. The engine builds
/// these into a reusable scratch buffer (no per-step allocation) and
/// writes any `promote_level` updates back to its own queue state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueueEntry {
    /// The request id (stable across requeues).
    pub req_id: u64,
    /// Predicted total remaining work at enqueue time: prefill debt
    /// (estimated new tokens) + predicted decode length.
    pub predicted_work: u64,
    /// Engine progress-clock reading (total prefill + decode tokens
    /// computed) when the request entered the queue.
    pub enqueued_progress: u64,
    /// Starvation promotions already granted (`ltr` only; 0 elsewhere).
    pub promote_level: u32,
}

/// The within-instance scheduling contract: given the waiting queue in
/// arrival order and the instance's token-progress clock, pick the index
/// to admit next. Implementations may update `promote_level` in place
/// (the engine persists it); they must not reorder the slice.
pub trait QueuePolicy: Send {
    fn name(&self) -> &'static str;
    /// Index of the next entry to admit, or `None` on an empty queue.
    fn select(&mut self, entries: &mut [QueueEntry], progress: u64) -> Option<usize>;
    /// Cumulative starvation promotions granted (`ltr`; 0 elsewhere).
    fn promotions(&self) -> u64 {
        0
    }
}

/// Arrival order — the seed engine's `VecDeque::pop_front`, pinned
/// byte-identical by always selecting index 0.
pub struct Fcfs;

impl QueuePolicy for Fcfs {
    fn name(&self) -> &'static str {
        "fcfs"
    }

    fn select(&mut self, entries: &mut [QueueEntry], _progress: u64) -> Option<usize> {
        if entries.is_empty() {
            None
        } else {
            Some(0)
        }
    }
}

/// Shortest predicted remaining processing time first (ties broken by
/// arrival order, so equal-work requests stay FCFS).
pub struct Srpt;

impl QueuePolicy for Srpt {
    fn name(&self) -> &'static str {
        "srpt"
    }

    fn select(&mut self, entries: &mut [QueueEntry], _progress: u64) -> Option<usize> {
        let mut best: Option<(u64, usize)> = None;
        for (i, e) in entries.iter().enumerate() {
            if best.map_or(true, |(w, _)| e.predicted_work < w) {
                best = Some((e.predicted_work, i));
            }
        }
        best.map(|(_, i)| i)
    }
}

/// Tokens of engine progress a request must wait before gaining one
/// promotion level (the vLLM LTR scheduler's
/// `VLLM_LTR_STARVATION_THRESHOLD` waited-tokens default).
pub const LTR_STARVATION_THRESHOLD: u64 = 256;

/// Effective-work discount per promotion level (the LTR scheduler's
/// `VLLM_LTR_PRIORITY_QUANTUM` default).
pub const LTR_PRIORITY_QUANTUM: u64 = 32;

/// The vLLM LTR scheduler's score-priority queue: SRPT by predicted work,
/// but every [`LTR_STARVATION_THRESHOLD`] waited tokens promote a request
/// by one level, and each level subtracts [`LTR_PRIORITY_QUANTUM`] from
/// its effective work (which may go negative — a starved request
/// eventually outranks everything, so the queue is starvation-free).
pub struct Ltr {
    promotions: u64,
}

impl Ltr {
    pub fn new() -> Self {
        Ltr { promotions: 0 }
    }
}

impl Default for Ltr {
    fn default() -> Self {
        Self::new()
    }
}

impl QueuePolicy for Ltr {
    fn name(&self) -> &'static str {
        "ltr"
    }

    fn select(&mut self, entries: &mut [QueueEntry], progress: u64) -> Option<usize> {
        let mut best: Option<(i64, usize)> = None;
        for (i, e) in entries.iter_mut().enumerate() {
            let waited = progress.saturating_sub(e.enqueued_progress);
            let target = (waited / LTR_STARVATION_THRESHOLD) as u32;
            if target > e.promote_level {
                self.promotions += u64::from(target - e.promote_level);
                e.promote_level = target;
            }
            let effective = e.predicted_work as i64
                - (u64::from(e.promote_level) * LTR_PRIORITY_QUANTUM) as i64;
            if best.map_or(true, |(w, _)| effective < w) {
                best = Some((effective, i));
            }
        }
        best.map(|(_, i)| i)
    }

    fn promotions(&self) -> u64 {
        self.promotions
    }
}

/// The shared registry: names in display order plus the unknown-name
/// error pieces. The rendered error predates [`crate::util::Registry`]
/// and is pinned by `registry_builds_everything_and_rejects_unknown_names`
/// — the migration kept it byte-identical.
const REGISTRY: crate::util::Registry =
    crate::util::Registry::new("queue policy", "queue policies", &["fcfs", "srpt", "ltr"]);

/// Build a queue policy by name. Unknown names are rejected with the
/// name-listing error (CLI / config / benches surface it verbatim,
/// mirroring `policy::build`).
pub fn build(name: &str) -> Result<Box<dyn QueuePolicy>, String> {
    Ok(match name {
        "fcfs" => Box::new(Fcfs),
        "srpt" => Box::new(Srpt),
        "ltr" => Box::new(Ltr::new()),
        _ => return Err(REGISTRY.unknown(name)),
    })
}

/// All queue-policy names (for sweeps and the CLI usage text).
pub fn all_names() -> &'static [&'static str] {
    REGISTRY.names_static()
}

/// Salt for the decode-length predictor ("QPRED137"). Distinct from the
/// sim backend's logits hash so the two deterministic streams never
/// correlate.
const PREDICT_SALT: u64 = 0x5150_5245_4431_3337;

/// Splitmix-style mix — the same finalizer as `runtime/sim.rs`. Shared
/// with the model-keepalive eviction rank in `engine::models`.
#[inline]
pub(crate) fn mix(h: u64, x: u64) -> u64 {
    let mut z = h ^ x.wrapping_mul(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Deterministic decode-length prediction: the true output length scaled
/// by a per-request factor in [0.5, 1.5) drawn from the top 16 bits of
/// the salted mix. Models a learned predictor that is directionally
/// right but individually noisy; byte-stable across runs and mirrored
/// bit-for-bit in `python/tests/test_queue_predictor.py`.
pub fn predict_decode(req_id: u64, output_len: u32) -> u64 {
    let z = mix(PREDICT_SALT, req_id);
    let factor = 0.5 + (z >> 48) as f64 / 65536.0;
    ((f64::from(output_len.max(1)) * factor) as u64).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(req_id: u64, work: u64, enq: u64) -> QueueEntry {
        QueueEntry {
            req_id,
            predicted_work: work,
            enqueued_progress: enq,
            promote_level: 0,
        }
    }

    #[test]
    fn registry_builds_everything_and_rejects_unknown_names() {
        for name in all_names() {
            let pol = build(name).unwrap_or_else(|e| panic!("build({name}): {e}"));
            assert_eq!(pol.name(), *name);
        }
        let err = build("no_such_queue").err().unwrap();
        assert!(err.contains("no_such_queue"), "error names the input: {err}");
        for name in all_names() {
            assert!(err.contains(name), "error lists '{name}': {err}");
        }
        // The exact pre-util::Registry wording, pinned byte-for-byte.
        assert_eq!(
            err,
            "unknown queue policy 'no_such_queue'; valid queue policies: fcfs, srpt, ltr"
        );
        assert_eq!(all_names(), &["fcfs", "srpt", "ltr"]);
    }

    #[test]
    fn fcfs_always_selects_the_front() {
        let mut q = build("fcfs").unwrap();
        let mut e = vec![entry(1, 500, 0), entry(2, 10, 0), entry(3, 900, 0)];
        assert_eq!(q.select(&mut e, 0), Some(0));
        assert_eq!(q.select(&mut [], 0), None);
        assert_eq!(q.promotions(), 0);
    }

    #[test]
    fn srpt_selects_minimum_predicted_work_with_fcfs_ties() {
        let mut q = build("srpt").unwrap();
        let mut e = vec![entry(1, 500, 0), entry(2, 10, 0), entry(3, 10, 0)];
        // 10 beats 500; the earlier of the two 10s wins the tie.
        assert_eq!(q.select(&mut e, 0), Some(1));
        assert_eq!(q.select(&mut [], 0), None);
    }

    #[test]
    fn ltr_promotes_a_starved_request_past_shorter_work() {
        let mut q = Ltr::new();
        // A long request enqueued at progress 0 next to a stream of short
        // ones: with no waiting it loses...
        let mut e = vec![entry(1, 1000, 0), entry(2, 100, 0)];
        assert_eq!(q.select(&mut e, 0), Some(1));
        assert_eq!(q.promotions(), 0);
        // ...but after (1000-100)/32 * 256 = 7200 tokens of progress its
        // promotion discount closes the 900-token work gap.
        let catch_up = (1000 - 100) / LTR_PRIORITY_QUANTUM * LTR_STARVATION_THRESHOLD;
        let mut e = vec![entry(1, 1000, 0), entry(2, 100, catch_up)];
        assert_eq!(q.select(&mut e, catch_up), Some(0));
        assert!(q.promotions() > 0);
        // The engine persists the written-back level.
        assert_eq!(e[0].promote_level, ((1000 - 100) / LTR_PRIORITY_QUANTUM) as u32);
    }

    #[test]
    fn ltr_effective_priority_is_strictly_decreasing_in_progress() {
        // Starvation-freedom core: for a fixed entry, more progress never
        // raises effective work, and it strictly drops across threshold
        // crossings (so any entry eventually outranks any fixed rival).
        let mut q = Ltr::new();
        let mut last_level = 0;
        for k in 1..=64u64 {
            let mut e = vec![entry(1, 1_000_000, 0)];
            q.select(&mut e, k * LTR_STARVATION_THRESHOLD);
            assert!(e[0].promote_level >= last_level, "levels only grow");
            assert_eq!(e[0].promote_level, k as u32, "one level per threshold");
            last_level = e[0].promote_level;
        }
        assert_eq!(q.promotions(), 64);
    }

    #[test]
    fn predictor_matches_pinned_vectors() {
        // Pinned against python/tests/test_queue_predictor.py (the Python
        // mirror computes these with masked 64-bit arithmetic; the two
        // lists must stay literally identical).
        let cases: &[(u64, u32, u64)] = &[
            (0, 1, 1),
            (1, 64, 92),
            (2, 256, 193),
            (7, 100, 87),
            (42, 32, 34),
            (123_456_789, 1000, 1139),
            (9_223_372_036_854_775_808, 500, 618),
            (u64::MAX, 77, 67),
        ];
        for &(id, out, want) in cases {
            assert_eq!(predict_decode(id, out), want, "predict_decode({id}, {out})");
        }
    }

    #[test]
    fn predictor_stays_in_band_and_is_deterministic() {
        for id in 0..512u64 {
            let p = predict_decode(id, 1000);
            assert!((500..1500).contains(&p), "factor in [0.5, 1.5): {p}");
            assert_eq!(p, predict_decode(id, 1000), "deterministic");
            assert!(predict_decode(id, 0) >= 1, "floor at one token");
        }
    }
}
