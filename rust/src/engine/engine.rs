//! The instance engine: continuous batching + chunked prefill over the
//! radix-tree KV$, stepped in virtual time by the analytic cost model.
//!
//! One [`Instance::step`] = one fused engine iteration (vLLM-v1 style):
//! up to `chunk_budget` new prefill tokens are co-scheduled with one
//! decode token for every running sequence. The returned
//! [`StepOutcome`] carries the step's duration, emitted events
//! (timestamped at step end) and the post-step indicator snapshot that
//! the router receives piggybacked on responses.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::core::{Request, RequestRecord, BLOCK_TOKENS};
use crate::kvcache::RadixTree;

use super::cost::{InstanceProfile, ModelProfile};
use super::models::ModelSlots;
use super::queue::{self, QueueEntry, QueuePolicy};
use super::InstanceSnapshot;

#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub profile: ModelProfile,
    /// Hardware class of this slot (prefill/decode speed relative to the
    /// reference device, warm-model slots). The reference class keeps
    /// every cost path bit-identical to the pre-fleet engine.
    pub instance: InstanceProfile,
    /// Max new prefill tokens co-scheduled per step (chunked prefill).
    /// Must be >= 1: a zero budget livelocks a busy instance (rejected at
    /// config build and debug-asserted at construction).
    pub chunk_budget: usize,
    /// Max admitted (running) sequences.
    pub max_batch: usize,
    /// KV$ capacity in blocks (0 = unbounded).
    pub kv_capacity_blocks: usize,
    /// Within-instance queue ordering (`engine::queue::build` name:
    /// fcfs / srpt / ltr).
    pub queue_policy: String,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            profile: ModelProfile::moe_30b(),
            instance: InstanceProfile::reference(),
            chunk_budget: 256,
            max_batch: 64,
            kv_capacity_blocks: 8192,
            queue_policy: "fcfs".to_string(),
        }
    }
}

/// Emitted by a step; timestamps are the step's end time.
#[derive(Debug, Clone)]
pub enum EngineEvent {
    /// Prefill finished — first output token produced (TTFT point).
    FirstToken { req_id: u64, at_us: u64 },
    /// All output tokens produced; the full request record.
    Completed { record: RequestRecord },
}

/// Result of one engine step.
#[derive(Debug, Clone)]
pub struct StepOutcome {
    pub duration_us: u64,
    /// Portion of the step spent on prefill work, µs (Fig 10/25 profiles).
    pub prefill_us: f64,
    pub prefill_tokens: usize,
    pub decode_seqs: usize,
    pub events: Vec<EngineEvent>,
    /// Post-step indicators (piggybacked to the router).
    pub snapshot: InstanceSnapshot,
}

#[derive(Debug)]
struct Seq {
    req: Request,
    /// Prompt tokens served from KV$ at admission.
    cached_tokens: usize,
    /// Blocks pinned in the KV$ for this sequence.
    pinned_blocks: usize,
    /// New prefill tokens required ( = input_len - cached ).
    new_total: usize,
    /// New tokens prefilled so far.
    prefilled: usize,
    generated: u32,
    first_token_us: Option<u64>,
    /// Block hashes of prompt+output, inserted into KV$ at completion
    /// (multi-turn reuse: the next turn's prompt extends this chain).
    /// Shared with the trace — enqueue costs a refcount bump, not a copy.
    full_hashes: Arc<[u64]>,
    /// Virtual time the request entered the waiting queue (queue-wait
    /// metrics measure admission minus this).
    enqueued_us: u64,
    /// Progress-clock reading at enqueue (the ltr starvation clock).
    enqueued_progress: u64,
    /// Predicted total remaining work at enqueue: estimated prefill debt
    /// + hash-predicted decode length (frozen — it is a prediction).
    predicted_work: u64,
    /// Starvation promotions granted so far (ltr persists levels here
    /// between admission rounds).
    promote_level: u32,
}

impl Seq {
    fn prefill_remaining(&self) -> usize {
        self.new_total - self.prefilled
    }
    fn context_len(&self) -> usize {
        self.req.input_len() + self.generated as usize
    }
}

/// A PD-colocated serving instance.
pub struct Instance {
    pub id: usize,
    pub cfg: EngineConfig,
    kv: RadixTree,
    waiting: VecDeque<Seq>,
    running: Vec<Seq>,
    /// Incrementally-maintained indicator counters, updated on
    /// enqueue/admit/prefill-progress/decode/completion so
    /// [`Self::snapshot`] is O(1) instead of rescanning every sequence at
    /// every step end. [`Self::recompute_snapshot`] is the from-scratch
    /// reference; debug builds assert equality after every step.
    queued_prefill_tokens: usize,
    total_context_tokens: usize,
    /// Recycled event buffer: [`Self::step`] moves it into the
    /// [`StepOutcome`]; callers hand it back via
    /// [`Self::recycle_events`] so the steady state allocates no fresh
    /// events Vec per step.
    events_scratch: Vec<EngineEvent>,
    /// Within-instance queue ordering (built from
    /// `cfg.queue_policy` — `fcfs` reproduces the seed engine's
    /// pop-front byte-for-byte).
    queue: Box<dyn QueuePolicy>,
    /// Reusable entry buffer handed to the queue policy at admission
    /// (no per-admission allocation in steady state).
    entries_scratch: Vec<QueueEntry>,
    /// Warm-model slots (multi-model multiplexing). Model 0 ships warm,
    /// so single-model traces never touch the swap path.
    models: ModelSlots,
    /// Swap time charged by admissions since the last step, added to
    /// that step's duration (0 on every step of a single-model trace).
    pending_swap_us: u64,
    /// Lifetime counters.
    pub steps: u64,
    pub busy_us: u64,
    pub total_prefill_tokens: u64,
    pub total_decode_tokens: u64,
    /// Steps where a non-empty running batch had nothing runnable —
    /// the release-mode escape hatch for the livelock invariant (always
    /// 0 with `chunk_budget >= 1`; debug builds assert instead).
    pub stalled_steps: u64,
    /// Queue-wait accounting (enqueue -> admission), harvested into
    /// `RunMetrics.queue` at end of run.
    pub queue_wait_us_sum: u64,
    pub queue_wait_samples: u64,
    pub queue_wait_us_max: u64,
}

impl Instance {
    /// Panics (debug) on `chunk_budget == 0` — a zero budget makes a
    /// busy instance unsteppable and livelocks the DES; the config layer
    /// rejects it with a proper error before construction. Panics on an
    /// unknown `queue_policy` name for the same reason (the CLI/config
    /// layers validate names first and surface the listing error).
    pub fn new(id: usize, cfg: EngineConfig) -> Self {
        debug_assert!(
            cfg.chunk_budget > 0,
            "chunk_budget must be >= 1 (a zero budget livelocks a busy instance)"
        );
        let kv = RadixTree::new(cfg.kv_capacity_blocks);
        let queue = queue::build(&cfg.queue_policy).unwrap_or_else(|e| panic!("{e}"));
        let models = ModelSlots::new(id, &cfg.instance);
        Instance {
            id,
            cfg,
            kv,
            waiting: VecDeque::new(),
            running: Vec::new(),
            queued_prefill_tokens: 0,
            total_context_tokens: 0,
            events_scratch: Vec::new(),
            queue,
            entries_scratch: Vec::new(),
            models,
            pending_swap_us: 0,
            steps: 0,
            busy_us: 0,
            total_prefill_tokens: 0,
            total_decode_tokens: 0,
            stalled_steps: 0,
            queue_wait_us_sum: 0,
            queue_wait_samples: 0,
            queue_wait_us_max: 0,
        }
    }

    /// Engine token-progress clock: every prefill + decode token computed
    /// so far. This is the `ltr` starvation clock — waiting requests are
    /// promoted by tokens of progress they sat through, not wall time.
    pub fn progress_tokens(&self) -> u64 {
        self.total_prefill_tokens + self.total_decode_tokens
    }

    /// Cumulative starvation promotions granted by the queue policy
    /// (`ltr`; 0 for fcfs/srpt).
    pub fn queue_promotions(&self) -> u64 {
        self.queue.promotions()
    }

    /// The active within-instance queue policy name.
    pub fn queue_policy_name(&self) -> &'static str {
        self.queue.name()
    }

    /// The instance's warm-model slots (swap counters, warm-set reads).
    pub fn models(&self) -> &ModelSlots {
        &self.models
    }

    /// Route a request to this instance (enters the waiting queue).
    /// `full_hashes` covers prompt+output blocks for completion-time
    /// cache insertion (what the next conversation turn will hit).
    pub fn enqueue(&mut self, req: Request, full_hashes: Arc<[u64]>, now_us: u64) {
        // Estimate the KV$ hit now so the queued-prefill-token indicator
        // is hit-aware ("new prefill tokens considering KV$ hits", §5.1).
        // A read-only peek: the estimate must not touch LRU state — the
        // authoritative, LRU-refreshing match happens at admission.
        let est_hit = self.kv.peek_prefix(&req.block_hashes);
        let est_cached = (est_hit * BLOCK_TOKENS).min(req.input_len());
        let new_total = (req.input_len() - est_cached).max(1);
        self.queued_prefill_tokens += new_total;
        let predicted_work = new_total as u64 + queue::predict_decode(req.id, req.output_len);
        self.waiting.push_back(Seq {
            cached_tokens: 0,
            pinned_blocks: 0,
            new_total,
            prefilled: 0,
            generated: 0,
            first_token_us: None,
            full_hashes,
            enqueued_us: now_us,
            enqueued_progress: self.progress_tokens(),
            predicted_work,
            promote_level: 0,
            req,
        });
    }

    /// Hand a spent [`StepOutcome::events`] buffer back for reuse by the
    /// next [`Self::step`] (cleared here). Optional: dropping the Vec is
    /// always correct, recycling just keeps the hot loop allocation-free.
    pub fn recycle_events(&mut self, mut events: Vec<EngineEvent>) {
        events.clear();
        self.events_scratch = events;
    }

    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty() || !self.running.is_empty()
    }

    /// Direct read of the instance's KV$ (tests/analysis).
    pub fn kv(&self) -> &RadixTree {
        &self.kv
    }

    /// Mutable KV$ access (tests/analysis: match_prefix needs &mut for
    /// LRU bookkeeping).
    pub fn kv_mut(&mut self) -> &mut RadixTree {
        &mut self.kv
    }

    /// O(1): assembled from the incrementally-maintained counters (plus
    /// the tree's own O(1) occupancy counters) — no rescan of the
    /// waiting/running sets at every step end.
    pub fn snapshot(&self) -> InstanceSnapshot {
        InstanceSnapshot {
            r_bs: self.running.len(),
            q_bs: self.waiting.len(),
            queued_prefill_tokens: self.queued_prefill_tokens,
            total_context_tokens: self.total_context_tokens,
            kv_used_blocks: self.kv.used_blocks(),
            kv_capacity_blocks: self.kv.capacity_blocks(),
        }
    }

    /// From-scratch O(waiting+running) recomputation of
    /// [`Self::snapshot`] — the reference implementation the incremental
    /// counters are validated against (asserted after every step in debug
    /// builds, and by the randomized churn test).
    pub fn recompute_snapshot(&self) -> InstanceSnapshot {
        let queued_prefill_tokens = self
            .waiting
            .iter()
            .map(|s| s.prefill_remaining())
            .chain(self.running.iter().map(|s| s.prefill_remaining()))
            .sum();
        InstanceSnapshot {
            r_bs: self.running.len(),
            q_bs: self.waiting.len(),
            queued_prefill_tokens,
            total_context_tokens: self.running.iter().map(|s| s.context_len()).sum(),
            kv_used_blocks: self.kv.used_blocks(),
            kv_capacity_blocks: self.kv.capacity_blocks(),
        }
    }

    // --- lifecycle (crash / drain) ----------------------------------

    /// Drain the *waiting* queue for requeue elsewhere (the drain path:
    /// the instance stops accepting work but finishes its running
    /// batch). Running sequences, their KV$ pins, and the cache itself
    /// are untouched; the queued-prefill account is settled per seq.
    /// Returns the extracted requests in queue order.
    pub fn extract_waiting(&mut self) -> Vec<Request> {
        let mut out = Vec::with_capacity(self.waiting.len());
        while let Some(seq) = self.waiting.pop_front() {
            self.queued_prefill_tokens -= seq.prefill_remaining();
            out.push(seq.req);
        }
        debug_assert_eq!(self.snapshot(), self.recompute_snapshot());
        out
    }

    /// Crash semantics: every queued AND in-flight request is extracted
    /// for requeue (prefill progress and generated tokens are lost —
    /// the requeued request restarts from scratch, keeping its original
    /// arrival time so TTFT stays honest), indicator counters reset,
    /// and the KV$ is wiped to a fresh tree (a dead replica's cache
    /// does not survive). Returns waiting-then-running requests.
    pub fn extract_all(&mut self) -> Vec<Request> {
        let mut out = self.extract_waiting();
        for seq in self.running.drain(..) {
            out.push(seq.req);
        }
        self.queued_prefill_tokens = 0;
        self.total_context_tokens = 0;
        self.kv = RadixTree::new(self.cfg.kv_capacity_blocks);
        // A crashed process loses its resident weights along with its
        // KV$: only the default model survives a restart (counters are
        // lifetime totals and persist for the end-of-run harvest).
        self.models.reset_warm();
        self.pending_swap_us = 0;
        debug_assert_eq!(self.snapshot(), self.recompute_snapshot());
        out
    }

    fn admit(&mut self, now_us: u64) {
        while self.running.len() < self.cfg.max_batch && !self.waiting.is_empty() {
            // Let the queue policy pick the next admission. `fcfs`
            // always selects index 0 (== the seed engine's pop_front);
            // `srpt`/`ltr` reorder by predicted work. Promotion levels
            // the policy writes into the scratch entries are persisted
            // back onto the queued sequences before the pick is removed.
            self.entries_scratch.clear();
            self.entries_scratch.extend(self.waiting.iter().map(|s| QueueEntry {
                req_id: s.req.id,
                predicted_work: s.predicted_work,
                enqueued_progress: s.enqueued_progress,
                promote_level: s.promote_level,
            }));
            let progress = self.progress_tokens();
            let mut entries = std::mem::take(&mut self.entries_scratch);
            let picked = self.queue.select(&mut entries, progress);
            for (seq, e) in self.waiting.iter_mut().zip(&entries) {
                seq.promote_level = e.promote_level;
            }
            self.entries_scratch = entries;
            let Some(idx) = picked else { break };
            let mut seq = self.waiting.remove(idx).expect("selected index in range");
            let wait_us = now_us.saturating_sub(seq.enqueued_us);
            self.queue_wait_us_sum += wait_us;
            self.queue_wait_samples += 1;
            self.queue_wait_us_max = self.queue_wait_us_max.max(wait_us);
            // ONE fused KV$ walk: match the cached prefix (LRU-refreshed),
            // make the rest of the prompt chain resident, and pin it all
            // for the sequence lifetime (truncated under pinned-full
            // pressure — pin covers exactly what is resident).
            // The estimate is settled PER SEQUENCE: `est_remaining` is
            // read off the *selected* seq (not the queue front), so the
            // account stays exact under any admission order.
            let est_remaining = seq.prefill_remaining();
            // Multi-model multiplexing: admitting a cold model pays a
            // profile-scaled weight swap, charged to the admitting step.
            // Model 0 is always warm, so single-model traces never enter
            // the swap path and replay byte-identical.
            self.pending_swap_us += self.models.touch(seq.req.model_id, now_us);
            let out = self.kv.admit_chain(&seq.req.block_hashes, now_us);
            seq.pinned_blocks = out.resident;
            seq.cached_tokens = (out.hit_blocks * BLOCK_TOKENS).min(seq.req.input_len());
            // A fully-cached prompt still prefills its last token to
            // produce the first output logit (vLLM recomputes ≥1 token).
            seq.new_total = (seq.req.input_len() - seq.cached_tokens).max(1);
            // Replace the enqueue-time estimate with the authoritative
            // prefill debt, and move the sequence's context into the
            // running account.
            self.queued_prefill_tokens -= est_remaining;
            self.queued_prefill_tokens += seq.prefill_remaining();
            self.total_context_tokens += seq.context_len();
            self.running.push(seq);
        }
    }

    /// Execute one engine step starting at `now_us`. Returns None if idle.
    pub fn step(&mut self, now_us: u64) -> Option<StepOutcome> {
        self.admit(now_us);
        if self.running.is_empty() {
            return None;
        }

        // ---- plan the fused batch ----------------------------------
        let mut budget = self.cfg.chunk_budget;
        let mut prefill_tokens = 0usize;
        let mut prefill_attn_tok_kctx = 0.0f64;
        let mut prefill_plan: Vec<(usize, usize)> = Vec::new(); // (idx, chunk)
        let mut decode_seqs = 0usize;
        let mut decode_ctx = 0usize;

        for (i, seq) in self.running.iter().enumerate() {
            if seq.prefill_remaining() > 0 {
                if budget == 0 {
                    continue;
                }
                let chunk = seq.prefill_remaining().min(budget);
                budget -= chunk;
                let ctx0 = seq.cached_tokens + seq.prefilled;
                prefill_attn_tok_kctx +=
                    chunk as f64 * (ctx0 as f64 + chunk as f64 / 2.0) / 1000.0;
                prefill_tokens += chunk;
                prefill_plan.push((i, chunk));
            } else if seq.generated > 0 && seq.generated < seq.req.output_len.max(1) {
                decode_seqs += 1;
                decode_ctx += seq.context_len();
            }
        }

        if prefill_tokens == 0 && decode_seqs == 0 {
            // Invariant violation: a running sequence always carries
            // prefill or decode work when chunk_budget >= 1 (enforced at
            // config build and construction). Returning None here with a
            // non-empty running batch would livelock the DES (the
            // instance is permanently "busy" yet never steps), so debug
            // builds fail loudly; release builds count the stall so the
            // harvested `RunMetrics.queue` counters expose it.
            debug_assert!(
                false,
                "unsteppable running batch ({} seqs) — chunk_budget misconfigured?",
                self.running.len()
            );
            self.stalled_steps += 1;
            return None;
        }

        // ---- cost ---------------------------------------------------
        // The reference class takes the original unscaled arithmetic
        // path, so uniform fleets replay byte-identical by construction
        // (not by trusting `x / 1.0` identities — though those hold too).
        let p = &self.cfg.profile;
        let (total_us, prefill_only_us) = if self.cfg.instance.is_reference() {
            let total =
                p.step_us(prefill_tokens, prefill_attn_tok_kctx, decode_seqs, decode_ctx);
            let pre = if prefill_tokens > 0 {
                p.step_us(prefill_tokens, prefill_attn_tok_kctx, 0, 0) - p.step_fixed_us
            } else {
                0.0
            };
            (total, pre)
        } else {
            let ip = &self.cfg.instance;
            let total =
                ip.step_us(p, prefill_tokens, prefill_attn_tok_kctx, decode_seqs, decode_ctx);
            let pre = if prefill_tokens > 0 {
                ip.step_us(p, prefill_tokens, prefill_attn_tok_kctx, 0, 0) - p.step_fixed_us
            } else {
                0.0
            };
            (total, pre)
        };
        // Cold-model swaps charged by this step's admissions extend the
        // step (always 0 on single-model traces).
        let swap_us = std::mem::take(&mut self.pending_swap_us);
        let duration_us = total_us.ceil() as u64 + swap_us;
        let end_us = now_us + duration_us;

        // ---- apply --------------------------------------------------
        // Reuse the recycled buffer: no fresh events Vec per step.
        let mut events = std::mem::take(&mut self.events_scratch);
        debug_assert!(events.is_empty());
        for (i, chunk) in prefill_plan {
            let seq = &mut self.running[i];
            seq.prefilled += chunk;
            self.queued_prefill_tokens -= chunk;
            self.total_prefill_tokens += chunk as u64;
            if seq.prefill_remaining() == 0 {
                // Prefill complete -> first output token at step end.
                seq.generated = 1;
                self.total_context_tokens += 1;
                seq.first_token_us = Some(end_us);
                events.push(EngineEvent::FirstToken {
                    req_id: seq.req.id,
                    at_us: end_us,
                });
            }
        }
        for seq in self.running.iter_mut() {
            if seq.prefill_remaining() == 0
                && seq.generated > 0
                && seq.first_token_us.map(|t| t < end_us).unwrap_or(false)
                && seq.generated < seq.req.output_len.max(1)
            {
                seq.generated += 1;
                self.total_context_tokens += 1;
                self.total_decode_tokens += 1;
            }
        }

        // ---- completions -------------------------------------------
        let mut i = 0;
        while i < self.running.len() {
            let done = {
                let s = &self.running[i];
                s.prefill_remaining() == 0 && s.generated >= s.req.output_len.max(1)
            };
            if done {
                let seq = self.running.swap_remove(i);
                self.total_context_tokens -= seq.context_len();
                self.kv.unpin(&seq.req.block_hashes, seq.pinned_blocks, end_us);
                // Cache prompt+output for future turns.
                self.kv.insert(&seq.full_hashes, end_us);
                events.push(EngineEvent::Completed {
                    record: RequestRecord {
                        id: seq.req.id,
                        class_id: seq.req.class_id,
                        instance: self.id,
                        arrival_us: seq.req.arrival_us,
                        first_token_us: seq.first_token_us.unwrap_or(end_us),
                        completion_us: end_us,
                        input_len: seq.req.input_len() as u32,
                        output_len: seq.req.output_len.max(1),
                        cached_tokens: seq.cached_tokens as u32,
                    },
                });
            } else {
                i += 1;
            }
        }

        self.steps += 1;
        self.busy_us += duration_us;
        debug_assert_eq!(
            self.snapshot(),
            self.recompute_snapshot(),
            "incremental snapshot counters diverged from recompute"
        );

        Some(StepOutcome {
            duration_us,
            prefill_us: prefill_only_us,
            prefill_tokens,
            decode_seqs,
            events,
            snapshot: self.snapshot(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokenizer::block_hashes;

    fn mk_req(id: u64, input: usize, output: u32, class: u32) -> (Request, Arc<[u64]>) {
        let tokens = crate::tokenizer::span(class, id, input, 1024);
        let hashes = block_hashes(&tokens);
        // full = prompt + output tokens (distinct per request id)
        let mut full_tokens = tokens.clone();
        full_tokens.extend(crate::tokenizer::span(class, id ^ 0xdead, output as usize, 1024));
        let full_hashes = block_hashes(&full_tokens);
        (
            Request {
                id,
                arrival_us: 0,
                class_id: class,
                session_id: 0,
                model_id: 0,
                tokens: tokens.into(),
                output_len: output,
                block_hashes: hashes.into(),
            },
            full_hashes.into(),
        )
    }

    /// Drive an instance to completion, returning records and total time.
    fn drain(inst: &mut Instance, start_us: u64) -> (Vec<RequestRecord>, u64) {
        let mut now = start_us;
        let mut records = Vec::new();
        while inst.has_work() {
            let out = inst.step(now).expect("has_work implies steppable");
            now += out.duration_us;
            for e in out.events {
                if let EngineEvent::Completed { record } = e {
                    records.push(record);
                }
            }
        }
        (records, now)
    }

    #[test]
    fn single_request_lifecycle() {
        let mut inst = Instance::new(0, EngineConfig::default());
        let (req, full) = mk_req(1, 512, 10, 0);
        inst.enqueue(req, full, 0);
        let (recs, end) = drain(&mut inst, 0);
        assert_eq!(recs.len(), 1);
        let r = &recs[0];
        assert_eq!(r.output_len, 10);
        assert!(r.first_token_us > 0);
        assert!(r.completion_us >= r.first_token_us);
        assert!(end >= r.completion_us);
        assert!(!inst.has_work());
    }

    #[test]
    fn ttft_spans_prefill_chunks() {
        // 1024 input tokens at 256-chunk budget = 4 prefill steps.
        let mut inst = Instance::new(0, EngineConfig::default());
        let (req, full) = mk_req(1, 1024, 2, 0);
        inst.enqueue(req, full, 0);
        let mut now = 0;
        let mut prefill_steps = 0;
        let mut first_token = None;
        while inst.has_work() {
            let out = inst.step(now).unwrap();
            if out.prefill_tokens > 0 {
                prefill_steps += 1;
                assert!(out.prefill_tokens <= 256, "chunk budget respected");
            }
            now += out.duration_us;
            for e in &out.events {
                if let EngineEvent::FirstToken { at_us, .. } = e {
                    first_token = Some(*at_us);
                }
            }
        }
        assert_eq!(prefill_steps, 4);
        assert!(first_token.is_some());
    }

    #[test]
    fn kv_hit_shortens_ttft() {
        let cfg = EngineConfig::default();
        // Cold: fresh instance.
        let mut cold = Instance::new(0, cfg.clone());
        let (req, full) = mk_req(1, 1024, 4, 7);
        cold.enqueue(req, full, 0);
        let (cold_recs, _) = drain(&mut cold, 0);
        // Warm: same class prompt served before.
        let mut warm = Instance::new(0, cfg);
        let (req1, full1) = mk_req(2, 1024, 4, 7);
        warm.enqueue(req1, full1, 0);
        let (_, t1) = drain(&mut warm, 0);
        let (mut req2, full2) = mk_req(2, 1024, 4, 7); // same id -> same tokens
        req2.arrival_us = t1; // TTFT is measured from arrival
        warm.enqueue(req2, full2, t1);
        let (warm_recs, _) = drain(&mut warm, t1);
        let cold_ttft = cold_recs[0].ttft_s();
        let warm_ttft = warm_recs[0].ttft_s();
        assert!(
            warm_ttft < cold_ttft * 0.3,
            "hit should slash TTFT: cold={cold_ttft} warm={warm_ttft}"
        );
        assert!(warm_recs[0].cached_tokens >= 1000);
    }

    #[test]
    fn continuous_batching_interleaves_prefill_and_decode() {
        let mut inst = Instance::new(0, EngineConfig::default());
        let (r1, f1) = mk_req(1, 256, 50, 0);
        inst.enqueue(r1, f1, 0);
        // Step once: r1 prefills fully.
        let out1 = inst.step(0).unwrap();
        assert_eq!(out1.prefill_tokens, 256);
        let mut now = out1.duration_us;
        // New arrival while r1 decodes.
        let (r2, f2) = mk_req(2, 512, 5, 1);
        inst.enqueue(r2, f2, now);
        let out2 = inst.step(now).unwrap();
        // Step co-schedules r2's prefill with r1's decode.
        assert!(out2.prefill_tokens > 0);
        assert_eq!(out2.decode_seqs, 1);
        now += out2.duration_us;
        let (recs, _) = drain(&mut inst, now);
        assert_eq!(recs.len(), 2);
    }

    #[test]
    fn max_batch_gates_admission() {
        let mut cfg = EngineConfig::default();
        cfg.max_batch = 2;
        let mut inst = Instance::new(0, cfg);
        for i in 0..5 {
            let (r, f) = mk_req(i, 64, 100, i as u32);
            inst.enqueue(r, f, 0);
        }
        let out = inst.step(0).unwrap();
        assert_eq!(out.snapshot.r_bs, 2);
        assert_eq!(out.snapshot.q_bs, 3);
        assert_eq!(out.snapshot.bs(), 5);
    }

    #[test]
    fn snapshot_counts_queued_prefill_tokens() {
        let mut cfg = EngineConfig::default();
        cfg.max_batch = 1;
        let mut inst = Instance::new(0, cfg);
        let (r1, f1) = mk_req(1, 600, 5, 0);
        let (r2, f2) = mk_req(2, 400, 5, 1);
        inst.enqueue(r1, f1, 0);
        inst.enqueue(r2, f2, 0);
        let out = inst.step(0).unwrap();
        // r1: 600-256 = 344 left; r2 still waiting with 400.
        assert_eq!(out.snapshot.queued_prefill_tokens, 344 + 400);
    }

    #[test]
    fn completion_inserts_full_chain_for_next_turn() {
        let mut inst = Instance::new(0, EngineConfig::default());
        let (req, full) = mk_req(1, 256, 32, 3);
        let full_clone = full.clone();
        inst.enqueue(req, full, 0);
        let _ = drain(&mut inst, 0);
        // The full (prompt+output) chain must now be cached.
        let kv_matched = inst.kv_mut().match_prefix(&full_clone, 999, false);
        assert_eq!(kv_matched, full_clone.len());
    }

    #[test]
    fn single_output_token_completes_at_prefill() {
        let mut inst = Instance::new(0, EngineConfig::default());
        let (req, full) = mk_req(1, 128, 1, 0);
        inst.enqueue(req, full, 0);
        let (recs, _) = drain(&mut inst, 0);
        assert_eq!(recs[0].first_token_us, recs[0].completion_us);
    }

    /// Acceptance proof for the fused admission: the KV$ is walked
    /// exactly ONCE per admitted sequence (the old path walked it three
    /// times per admission, plus once per enqueue estimate).
    #[test]
    fn one_radix_walk_per_admission() {
        let mut inst = Instance::new(0, EngineConfig::default());
        let n = 12u64;
        for i in 0..n {
            let (r, f) = mk_req(i, 200, 5, i as u32);
            inst.enqueue(r, f, 0);
        }
        assert_eq!(inst.kv().admit_radix_walks, 0, "enqueue must not walk");
        let _ = drain(&mut inst, 0);
        assert_eq!(inst.kv().admit_radix_walks, n, "one walk per admission");
    }

    /// Satellite: randomized churn over mixed enqueue/step/complete
    /// cycles PLUS drain/crash requeue interleavings, across all three
    /// queue policies, asserting the incremental snapshot counters equal
    /// a from-scratch recompute after EVERY operation. Under srpt/ltr the
    /// admission order is arbitrary, so this pins the per-sequence
    /// estimate settling (the pre-fix code settled against the queue
    /// front and would diverge on any reorder).
    #[test]
    fn incremental_snapshot_matches_recompute_under_churn() {
        use std::collections::HashMap;
        for seed in 0..9u64 {
            let mut rng = crate::util::Rng::new(0x5eed ^ seed);
            let cfg = EngineConfig {
                profile: ModelProfile::moe_30b(),
                instance: InstanceProfile::reference(),
                chunk_budget: [64, 256][seed as usize % 2],
                max_batch: 1 + (seed as usize % 7),
                kv_capacity_blocks: [0, 96, 1024][(seed as usize / 3) % 3],
                queue_policy: ["fcfs", "srpt", "ltr"][seed as usize % 3].to_string(),
            };
            let mut inst = Instance::new(0, cfg);
            let mut now = 0u64;
            let mut next_id = 0u64;
            // Requeue needs the full-chain hashes back, like the DES
            // cluster's own displaced-request map.
            let mut full_by_id: HashMap<u64, Arc<[u64]>> = HashMap::new();
            for _ in 0..140 {
                match rng.gen_range(0, 8) {
                    0..=2 => {
                        let input = rng.gen_range(8, 900) as usize;
                        let output = rng.gen_range(1, 40) as u32;
                        let class = rng.gen_range(0, 5) as u32;
                        let (r, f) = mk_req(next_id, input, output, class);
                        full_by_id.insert(next_id, f.clone());
                        next_id += 1;
                        inst.enqueue(r, f, now);
                        assert_eq!(inst.snapshot(), inst.recompute_snapshot());
                    }
                    3..=5 => {
                        if let Some(out) = inst.step(now) {
                            now += out.duration_us;
                            inst.recycle_events(out.events);
                        }
                        assert_eq!(
                            inst.snapshot(),
                            inst.recompute_snapshot(),
                            "diverged at seed {seed}, t={now}"
                        );
                    }
                    6 => {
                        // Drain: evict the waiting queue mid-reorder,
                        // then requeue (what the lifecycle layer does).
                        let evicted = inst.extract_waiting();
                        assert_eq!(inst.snapshot(), inst.recompute_snapshot());
                        for r in evicted {
                            let f = full_by_id[&r.id].clone();
                            inst.enqueue(r, f, now);
                        }
                        assert_eq!(inst.snapshot(), inst.recompute_snapshot());
                    }
                    _ => {
                        // Crash: everything (waiting + running) is
                        // displaced and requeued from scratch.
                        let evicted = inst.extract_all();
                        assert_eq!(inst.snapshot(), inst.recompute_snapshot());
                        for r in evicted {
                            let f = full_by_id[&r.id].clone();
                            inst.enqueue(r, f, now);
                        }
                        assert_eq!(inst.snapshot(), inst.recompute_snapshot());
                    }
                }
            }
            // Drain to empty: counters must return to zero.
            while inst.has_work() {
                let out = inst.step(now).unwrap();
                now += out.duration_us;
                inst.recycle_events(out.events);
                assert_eq!(inst.snapshot(), inst.recompute_snapshot());
            }
            let end = inst.snapshot();
            assert_eq!(end.queued_prefill_tokens, 0);
            assert_eq!(end.total_context_tokens, 0);
            assert_eq!((end.r_bs, end.q_bs), (0, 0));
            assert_eq!(inst.stalled_steps, 0, "no stalls under a legal config");
        }
    }

    /// Regression (livelock bugfix): a zero chunk budget must fail fast
    /// at construction instead of yielding an engine whose `has_work()`
    /// stays true while `step()` returns None forever. The pre-fix
    /// engine accepted the config silently and livelocked the DES on the
    /// first busy instance.
    #[test]
    #[should_panic(expected = "chunk_budget")]
    fn zero_chunk_budget_is_rejected_at_construction() {
        let cfg = EngineConfig {
            chunk_budget: 0,
            ..Default::default()
        };
        let _ = Instance::new(0, cfg);
    }

    #[test]
    fn srpt_admits_shortest_predicted_work_first() {
        // A long job arrives ahead of a short one; max_batch 1 makes the
        // admission order observable as the completion order.
        let run_order = |policy: &str| -> Vec<u64> {
            let mut cfg = EngineConfig::default();
            cfg.max_batch = 1;
            cfg.queue_policy = policy.to_string();
            let mut inst = Instance::new(0, cfg);
            let (r1, f1) = mk_req(1, 900, 200, 0);
            let (r2, f2) = mk_req(2, 64, 1, 1);
            inst.enqueue(r1, f1, 0);
            inst.enqueue(r2, f2, 0);
            let (recs, _) = drain(&mut inst, 0);
            recs.iter().map(|r| r.id).collect()
        };
        assert_eq!(run_order("fcfs"), [1, 2], "fcfs keeps arrival order");
        assert_eq!(run_order("srpt"), [2, 1], "srpt runs the short job first");
    }

    #[test]
    fn ltr_promotes_and_finishes_everything_under_a_deep_queue() {
        let mut cfg = EngineConfig::default();
        cfg.max_batch = 1;
        cfg.queue_policy = "ltr".to_string();
        let mut inst = Instance::new(0, cfg);
        for i in 0..12u64 {
            let (r, f) = mk_req(i, 512, 20, i as u32);
            inst.enqueue(r, f, 0);
        }
        let (recs, _) = drain(&mut inst, 0);
        assert_eq!(recs.len(), 12, "starvation-free: every request completes");
        assert!(
            inst.queue_promotions() > 0,
            "a deep queue must trip starvation promotions"
        );
        assert_eq!(inst.queue_policy_name(), "ltr");
    }

    #[test]
    fn extract_waiting_settles_accounts_and_keeps_batch() {
        let mut cfg = EngineConfig::default();
        cfg.max_batch = 1;
        let mut inst = Instance::new(0, cfg);
        let (r1, f1) = mk_req(1, 600, 5, 0);
        let (r2, f2) = mk_req(2, 400, 5, 1);
        let (r3, f3) = mk_req(3, 300, 5, 2);
        inst.enqueue(r1, f1, 0);
        inst.enqueue(r2, f2, 0);
        inst.enqueue(r3, f3, 0);
        let out = inst.step(0).unwrap(); // admits r1 only (max_batch 1)
        let evicted = inst.extract_waiting();
        assert_eq!(evicted.iter().map(|r| r.id).collect::<Vec<_>>(), [2, 3]);
        let snap = inst.snapshot();
        assert_eq!((snap.r_bs, snap.q_bs), (1, 0), "running batch survives");
        // Only r1's own remaining debt stays on the account.
        assert_eq!(snap.queued_prefill_tokens, 600 - 256);
        assert!(snap.kv_used_blocks > 0, "drain keeps the cache");
        inst.recycle_events(out.events);
        let (recs, _) = drain(&mut inst, out.duration_us);
        assert_eq!(recs.len(), 1, "running seq finishes normally");
    }

    #[test]
    fn extract_all_requeues_everything_and_wipes_state() {
        let mut cfg = EngineConfig::default();
        cfg.max_batch = 2;
        let mut inst = Instance::new(0, cfg);
        for i in 0..4 {
            let (r, f) = mk_req(i, 300, 20, i as u32);
            inst.enqueue(r, f, 0);
        }
        let out = inst.step(0).unwrap(); // 2 running, 2 waiting
        assert_eq!((out.snapshot.r_bs, out.snapshot.q_bs), (2, 2));
        let evicted = inst.extract_all();
        let mut ids: Vec<u64> = evicted.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, [0, 1, 2, 3], "nothing is silently dropped");
        let snap = inst.snapshot();
        assert_eq!((snap.r_bs, snap.q_bs), (0, 0));
        assert_eq!(snap.queued_prefill_tokens, 0);
        assert_eq!(snap.total_context_tokens, 0);
        assert_eq!(snap.kv_used_blocks, 0, "crash loses the replica cache");
        assert!(!inst.has_work());
        assert!(inst.step(1).is_none());
        // The instance is reusable after recovery.
        let (r, f) = mk_req(9, 256, 3, 0);
        inst.enqueue(r, f, 10);
        let (recs, _) = drain(&mut inst, 10);
        assert_eq!(recs.len(), 1);
    }

    #[test]
    fn slower_class_stretches_the_run() {
        let run_end = |instance: InstanceProfile| -> u64 {
            let cfg = EngineConfig {
                instance,
                ..Default::default()
            };
            let mut inst = Instance::new(0, cfg);
            let (r, f) = mk_req(1, 512, 40, 0);
            inst.enqueue(r, f, 0);
            drain(&mut inst, 0).1
        };
        let reference = run_end(InstanceProfile::reference());
        assert!(run_end(InstanceProfile::h100()) < reference);
        assert!(run_end(InstanceProfile::l40()) > reference);
    }

    #[test]
    fn cold_model_swap_extends_the_admitting_step() {
        let mut inst = Instance::new(0, EngineConfig::default());
        let swap = inst.cfg.instance.swap_cost_us();
        // Model 0 (warm) first: baseline step length.
        let (r0, f0) = mk_req(1, 256, 1, 0);
        inst.enqueue(r0, f0, 0);
        let base = inst.step(0).unwrap().duration_us;
        assert_eq!(inst.models().cold_loads, 0);
        let (recs, end) = drain(&mut inst, base);
        assert_eq!(recs.len(), 1);
        // Same-shape request (distinct class: no KV$ hit skews the
        // compute) against a cold model: the admitting step carries the
        // full swap on top of its compute.
        let (mut r1, f1) = mk_req(2, 256, 1, 1);
        r1.model_id = 5;
        inst.enqueue(r1, f1, end);
        let cold = inst.step(end).unwrap().duration_us;
        assert!(
            cold >= base + swap,
            "cold admission ({cold}) must pay the {swap}us swap over base ({base})"
        );
        assert_eq!(inst.models().cold_loads, 1);
        assert_eq!(inst.models().swap_us, swap);
        assert!(inst.models().is_warm(5));
        let _ = drain(&mut inst, end + cold);
        // Warm now: back to compute-only pricing.
        let (mut r2, f2) = mk_req(3, 256, 1, 2);
        r2.model_id = 5;
        let t = 10 * (end + cold);
        inst.enqueue(r2, f2, t);
        let warm = inst.step(t).unwrap().duration_us;
        assert!(warm < base + swap, "warm model must not re-pay the swap");
        assert_eq!(inst.models().cold_loads, 1);
    }

    #[test]
    fn decode_time_grows_with_batch_size() {
        // Cost-model sanity at the engine level: 16 decoding seqs step
        // slower than 2.
        let run = |n: usize| -> f64 {
            let mut inst = Instance::new(0, EngineConfig::default());
            for i in 0..n {
                let (r, f) = mk_req(i as u64, 64, 200, i as u32);
                inst.enqueue(r, f, 0);
            }
            let (recs, _) = drain(&mut inst, 0);
            recs.iter().map(|r| r.tpot_s()).sum::<f64>() / recs.len() as f64
        };
        assert!(run(16) > run(2));
    }
}
