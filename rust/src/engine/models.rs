//! Warm-model slots: Ray-Serve-style model multiplexing for one fleet
//! slot.
//!
//! A multi-model fleet serves several models over shared instances. Each
//! instance holds at most `max_warm_models` warm (weights resident);
//! serving a cold model first pays a profile-scaled weight swap
//! ([`crate::engine::InstanceProfile::swap_cost_us`]). Eviction follows
//! the Ray multiplexed-replica scheduler's shape: least-recently-used,
//! but a model idle less than `model_keepalive_us` is kept over one past
//! its keepalive, and exact last-use ties are broken by a deterministic
//! salted rank so eviction order is byte-stable across runs (the rank
//! stream is mirrored by `python/tests/test_model_keepalive.py`, the
//! same cross-language contract `engine::queue`'s predictor carries).
//!
//! Model 0 — the fleet's default model — starts warm on every instance
//! and single-model traces never touch another id, so they never swap,
//! never evict, and replay byte-identical to the pre-multiplexing paths.

use super::cost::InstanceProfile;
use super::queue::mix;

/// Salt for the eviction tiebreak rank ("MDLKEEP1"-flavored). Distinct
/// from the queue predictor's and the fault stream's salts so the three
/// deterministic streams never correlate.
pub const MODEL_EVICT_SALT: u64 = 0x4D44_4C4B_4545_5031;

/// Deterministic eviction tiebreak: lower rank evicts first among models
/// with identical last-use times. Mirrored bit-for-bit by
/// `python/tests/test_model_keepalive.py`.
pub fn evict_rank(instance: u64, model_id: u32) -> u64 {
    mix(mix(MODEL_EVICT_SALT, instance), u64::from(model_id))
}

#[derive(Debug, Clone, Copy)]
struct WarmModel {
    model_id: u32,
    last_used_us: u64,
}

/// The warm set of one instance, plus the swap accounting the metrics
/// harvest reads.
#[derive(Debug, Clone)]
pub struct ModelSlots {
    instance: u64,
    max_warm: usize,
    keepalive_us: u64,
    swap_cost_us: u64,
    warm: Vec<WarmModel>,
    /// Admissions that found their model cold (each paid one swap).
    pub cold_loads: u64,
    /// Warm models displaced to make room for a cold load.
    pub evictions: u64,
    /// Total µs of swap time charged to engine steps.
    pub swap_us: u64,
}

impl ModelSlots {
    pub fn new(instance: usize, profile: &InstanceProfile) -> ModelSlots {
        let mut s = ModelSlots {
            instance: instance as u64,
            max_warm: profile.max_warm_models.max(1),
            keepalive_us: profile.model_keepalive_us,
            swap_cost_us: profile.swap_cost_us(),
            warm: Vec::new(),
            cold_loads: 0,
            evictions: 0,
            swap_us: 0,
        };
        // The default model ships warm: a fleet that never multiplexes
        // never swaps.
        s.warm.push(WarmModel {
            model_id: 0,
            last_used_us: 0,
        });
        s
    }

    /// Drop every warm model except the default (crash semantics: a
    /// restarted process holds only model 0). Lifetime counters persist.
    pub fn reset_warm(&mut self) {
        self.warm.clear();
        self.warm.push(WarmModel {
            model_id: 0,
            last_used_us: 0,
        });
    }

    pub fn is_warm(&self, model_id: u32) -> bool {
        self.warm.iter().any(|w| w.model_id == model_id)
    }

    /// Warm model ids, most-recently-used last.
    pub fn warm_ids(&self) -> Vec<u32> {
        let mut ids: Vec<(u64, u64, u32)> = self
            .warm
            .iter()
            .map(|w| (w.last_used_us, evict_rank(self.instance, w.model_id), w.model_id))
            .collect();
        ids.sort();
        ids.into_iter().map(|(_, _, id)| id).collect()
    }

    /// Serve `model_id` at `now_us`: refresh its slot if warm, else pay a
    /// cold load. Returns the swap time to charge to the admitting step,
    /// in µs — 0 when warm.
    ///
    /// A cold load fills a free slot if one exists; otherwise it evicts
    /// the least-recently-used *expired* model (idle ≥ keepalive, exact
    /// last-use ties broken by the salted rank). When every warm model is
    /// still inside its keepalive the load is *transient* — the swap is
    /// paid but the protected warm set is not displaced (Ray's keepalive
    /// contract: recently-used models never get thrashed out).
    pub fn touch(&mut self, model_id: u32, now_us: u64) -> u64 {
        if let Some(w) = self.warm.iter_mut().find(|w| w.model_id == model_id) {
            w.last_used_us = w.last_used_us.max(now_us);
            return 0;
        }
        self.cold_loads += 1;
        let slot_free = self.warm.len() < self.max_warm;
        if slot_free {
            self.warm.push(WarmModel {
                model_id,
                last_used_us: now_us,
            });
        } else if let Some(victim) = self.pick_victim(now_us) {
            self.warm.swap_remove(victim);
            self.evictions += 1;
            self.warm.push(WarmModel {
                model_id,
                last_used_us: now_us,
            });
        }
        self.swap_us += self.swap_cost_us;
        self.swap_cost_us
    }

    /// Eviction candidate: the least-recently-used model past its
    /// keepalive (idle ≥ `keepalive_us`), exact last-use ties broken by
    /// the salted rank. `None` when every warm model is protected.
    fn pick_victim(&self, now_us: u64) -> Option<usize> {
        (0..self.warm.len())
            .filter(|&i| {
                now_us.saturating_sub(self.warm[i].last_used_us) >= self.keepalive_us
            })
            .min_by_key(|&i| {
                let w = &self.warm[i];
                (w.last_used_us, evict_rank(self.instance, w.model_id))
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slots(max_warm: usize, keepalive_us: u64) -> ModelSlots {
        let mut p = InstanceProfile::reference();
        p.max_warm_models = max_warm;
        p.model_keepalive_us = keepalive_us;
        ModelSlots::new(3, &p)
    }

    #[test]
    fn default_model_ships_warm_and_never_swaps() {
        let mut s = slots(2, 1_000_000);
        assert!(s.is_warm(0));
        for t in 0..100u64 {
            assert_eq!(s.touch(0, t * 1000), 0);
        }
        assert_eq!(s.cold_loads, 0);
        assert_eq!(s.evictions, 0);
        assert_eq!(s.swap_us, 0);
    }

    #[test]
    fn cold_load_pays_the_profile_swap_and_warms_the_model() {
        let mut s = slots(2, 1_000_000);
        let swap = s.touch(7, 500);
        assert_eq!(swap, InstanceProfile::reference().swap_cost_us());
        assert!(s.is_warm(7));
        assert_eq!(s.cold_loads, 1);
        assert_eq!(s.evictions, 0, "a free slot evicts nothing");
        // Warm now: free.
        assert_eq!(s.touch(7, 600), 0);
        assert_eq!(s.cold_loads, 1);
    }

    #[test]
    fn keepalive_shields_recent_models_from_eviction() {
        let mut s = slots(2, 1_000_000);
        s.touch(1, 100); // fills the free slot: {0@0, 1@100}
        s.touch(1, 900_000); // refresh 1 inside keepalive
        // At t=1.1s model 0 is expired (idle 1.1s ≥ 1s), model 1 is
        // protected (idle 0.2s): 0 evicts.
        let _ = s.touch(2, 1_100_000);
        assert!(!s.is_warm(0));
        assert!(s.is_warm(1) && s.is_warm(2));
        assert_eq!(s.evictions, 1);
    }

    #[test]
    fn fully_protected_set_makes_the_load_transient() {
        let mut s = slots(2, u64::MAX);
        s.touch(1, 100); // {0@0, 1@100}, both protected forever
        let swap = s.touch(2, 200);
        assert!(swap > 0, "transient load still pays the swap");
        assert!(!s.is_warm(2), "protected warm set is not displaced");
        assert!(s.is_warm(0) && s.is_warm(1));
        assert_eq!(s.evictions, 0);
        assert_eq!(s.cold_loads, 2);
        // Every repeat stays cold and keeps paying.
        assert!(s.touch(2, 300) > 0);
        assert_eq!(s.cold_loads, 3);
    }

    #[test]
    fn exact_tie_breaks_by_pinned_salted_rank() {
        // Both warm models last used at the same instant: the salted rank
        // decides, deterministically.
        let mut s = slots(2, 0);
        s.touch(1, 0); // {0@0, 1@0}
        let r0 = evict_rank(3, 0);
        let r1 = evict_rank(3, 1);
        let expect_victim = if r0 < r1 { 0 } else { 1 };
        let _ = s.touch(2, 0);
        assert!(!s.is_warm(expect_victim), "rank order r0={r0:#x} r1={r1:#x}");
    }

    /// Pinned rank vectors, mirrored bit-for-bit by
    /// python/tests/test_model_keepalive.py. Regenerate both sides
    /// together if the salt or mix ever changes.
    #[test]
    fn evict_rank_matches_pinned_vectors() {
        let cases: &[(u64, u32, u64)] = &[
            (0, 0, 0x42b0_14bc_5e6a_2794),
            (0, 1, 0xeeb9_5044_6152_d604),
            (3, 0, 0x324d_70dc_abc0_59e9),
            (3, 1, 0xdec2_698c_7f69_9205),
            (3, 2, 0x0814_d9f1_0bec_f373),
            (7, 5, 0x3022_59ac_f85c_7604),
            (63, 4_294_967_295, 0xf197_362f_808e_79df),
        ];
        for &(inst, model, want) in cases {
            assert_eq!(
                evict_rank(inst, model),
                want,
                "evict_rank({inst}, {model})"
            );
        }
    }

    #[test]
    fn warm_ids_orders_lru_first() {
        let mut s = slots(3, 0);
        s.touch(1, 50);
        s.touch(2, 20);
        assert_eq!(s.warm_ids(), vec![0, 2, 1]);
    }
}
