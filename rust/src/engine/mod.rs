//! The serving-instance substrate: a vLLM-v1-like engine with continuous
//! batching, chunked prefill and radix-tree KV$ prefix caching, stepped by
//! an analytic cost model (DESIGN.md §1 explains why this substitution
//! preserves the scheduling-relevant behaviour of the paper's H20+vLLM
//! testbed).

mod cost;
mod engine;
pub mod models;
pub mod queue;

pub use cost::{InstanceProfile, ModelProfile};
pub use engine::{EngineConfig, EngineEvent, Instance, StepOutcome};
pub use models::ModelSlots;
pub use queue::{QueueEntry, QueuePolicy};

/// Per-instance indicators, as exported to the router piggybacked on
/// responses (the paper's Fig. 2 "direct system indicators"). All fields
/// are *instance truth at snapshot time*; the router's view of them is as
/// stale as the last response from that instance — exactly the staleness
/// structure of the real system (§3).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct InstanceSnapshot {
    /// R-BS: requests admitted into the running batch.
    pub r_bs: usize,
    /// Q-BS: requests waiting in the instance queue (not yet admitted).
    pub q_bs: usize,
    /// New prefill tokens not yet computed, across waiting + running
    /// requests (the queued-prefill component of the P-token indicator).
    pub queued_prefill_tokens: usize,
    /// Total context tokens across admitted requests (#Tokens indicator,
    /// used by Dynamo-style load balancing).
    pub total_context_tokens: usize,
    /// KV$ occupancy.
    pub kv_used_blocks: usize,
    pub kv_capacity_blocks: usize,
}

impl InstanceSnapshot {
    /// The paper's BS indicator: running + waiting requests.
    pub fn bs(&self) -> usize {
        self.r_bs + self.q_bs
    }
}
