//! KV$ cache modelling: a block-granular radix (prefix) tree with
//! reference counting and LRU eviction — the structure vLLM-style engines
//! use for prefix caching, and the structure the router mirrors per
//! instance to compute KV$-awareness indicators (`KV$.match(req)` in the
//! paper's pseudocode).

mod radix;

pub use radix::RadixTree;

/// Router-side per-instance KV$ views (the `KV` symbolic indicator of the
/// paper's indicator factory). The router cannot see instance memory; it
/// maintains one radix mirror per instance, updated when it routes a
/// request (optimistic insert of the prompt) and when a response arrives
/// (authoritative insert of prompt+output, piggybacked — §3).
#[derive(Debug)]
pub struct RouterKvView {
    views: Vec<RadixTree>,
}

impl RouterKvView {
    pub fn new(n_instances: usize, capacity_blocks: usize) -> Self {
        RouterKvView {
            views: (0..n_instances)
                .map(|_| RadixTree::new(capacity_blocks))
                .collect(),
        }
    }

    pub fn n_instances(&self) -> usize {
        self.views.len()
    }

    /// Matched *blocks* of `hashes` on each instance. The per-instance
    /// KV$-hit length in tokens is `matched * BLOCK_TOKENS`.
    pub fn match_all(&mut self, hashes: &[u64], now_us: u64) -> Vec<usize> {
        self.views
            .iter_mut()
            .map(|v| v.match_prefix(hashes, now_us, false))
            .collect()
    }

    /// Matched blocks on one instance.
    pub fn match_one(&mut self, inst: usize, hashes: &[u64], now_us: u64) -> usize {
        self.views[inst].match_prefix(hashes, now_us, false)
    }

    /// Optimistic insert at routing time (the routed instance will have
    /// this prefix cached by the time the request prefills).
    pub fn on_route(&mut self, inst: usize, hashes: &[u64], now_us: u64) {
        self.views[inst].insert(hashes, now_us);
    }

    /// Authoritative insert at response time (prompt + generated tokens).
    pub fn on_response(&mut self, inst: usize, full_hashes: &[u64], now_us: u64) {
        self.views[inst].insert(full_hashes, now_us);
    }

    pub fn view(&self, inst: usize) -> &RadixTree {
        &self.views[inst]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn router_view_tracks_routing() {
        let mut rv = RouterKvView::new(3, 1000);
        let h = vec![1, 2, 3, 4];
        assert_eq!(rv.match_all(&h, 0), vec![0, 0, 0]);
        rv.on_route(1, &h[..2], 10);
        assert_eq!(rv.match_all(&h, 20), vec![0, 2, 0]);
        rv.on_response(1, &h, 30);
        assert_eq!(rv.match_all(&h, 40), vec![0, 4, 0]);
    }
}
