//! KV$ cache modelling.
//!
//! Two structures live here:
//!
//! * [`RadixTree`] — the block-granular prefix tree with refcount pinning
//!   and lazy-heap LRU eviction that each *engine instance* uses for its
//!   own prefix cache (`KV$.match(req)` in the paper's pseudocode).
//! * [`SharedRadixIndex`] — the *router-side* view: ONE shared radix tree
//!   whose nodes carry a per-instance presence bitmask ([`InstanceMask`],
//!   growable past 64 instances). A single prefix walk per request yields
//!   the hit length for every instance at once (N× fewer hash-chain walks
//!   than the previous one-mirror-per-instance design) and produces the
//!   hotspot detector's M-set for free. Per-instance writes replicate the
//!   dedicated-mirror LRU semantics exactly, so routing decisions are
//!   identical to the N-mirror design — `MirrorKvView` keeps the old
//!   implementation alive as the reference model the equivalence tests
//!   (here and in `tests/policy_semantics.rs`) replay against.
//! * [`ShardedRadixIndex`] — the monolithic index split into S first-hash
//!   shards behind epoch-stamped snapshot reads, so R router workers can
//!   score concurrently through `&self` while writes serialize at a merge
//!   point (see `kvcache::sharded` and `cluster::run_concurrent`). Its
//!   per-instance LRU state is global across shards, keeping decisions
//!   byte-identical to `SharedRadixIndex` — pinned by the three-way churn
//!   test below, which replays identical traffic through the sharded
//!   index, the monolithic index AND the per-instance mirrors.
//!
//! [`RouterKvView`] is the thin facade the indicator factory uses: it
//! wraps the sharded index, is updated optimistically when the router
//! routes a request and authoritatively when a response arrives
//! (piggybacked, §3), and exposes the allocation-free `match_into` walk
//! plus the lock-free read path (`match_with`).

mod radix;
mod shared;
mod sharded;

pub use radix::{AdmitOutcome, RadixTree};
pub use shared::SharedRadixIndex;
pub use sharded::{shard_of, IndexSnapshot, ShardedRadixIndex, DEFAULT_SHARDS};

use crate::core::InstanceMask;

/// Router-side KV$ view over all instances (the `KV` symbolic indicator
/// of the paper's indicator factory), backed by the sharded presence-mask
/// prefix index. The router cannot see instance memory; it updates the
/// view when it routes a request (optimistic insert of the prompt) and
/// when a response arrives (authoritative insert of prompt+output, §3).
#[derive(Debug)]
pub struct RouterKvView {
    index: ShardedRadixIndex,
}

impl RouterKvView {
    /// `capacity_blocks` is per instance; 0 means unbounded.
    pub fn new(n_instances: usize, capacity_blocks: usize) -> Self {
        RouterKvView {
            index: ShardedRadixIndex::new(n_instances, capacity_blocks),
        }
    }

    pub fn n_instances(&self) -> usize {
        self.index.n_instances()
    }

    /// Matched *blocks* of `hashes` on every instance in ONE walk,
    /// written into reusable buffers (`hit_blocks[i]` = blocks instance
    /// `i` holds; `matched` = instances holding ≥ 1 block). The hot path:
    /// zero allocation in steady state.
    pub fn match_into(
        &mut self,
        hashes: &[u64],
        hit_blocks: &mut Vec<usize>,
        matched: &mut InstanceMask,
    ) {
        self.index.match_into(hashes, hit_blocks, matched);
    }

    /// The concurrent read path: identical fill semantics to
    /// [`Self::match_into`] but through `&self` with caller-owned live-set
    /// scratch and NO counter updates — R router workers call this in
    /// parallel from a pinned view, and the merge step records the
    /// returned hit-block sum via [`Self::record_lookup`] so the lifetime
    /// counters stay identical to a serial run.
    pub fn match_with(
        &self,
        hashes: &[u64],
        hit_blocks: &mut Vec<usize>,
        matched: &mut InstanceMask,
        live: &mut Vec<u64>,
    ) -> usize {
        self.index.match_with(hashes, hit_blocks, matched, live)
    }

    /// Record the accounting of a walk done earlier through
    /// [`Self::match_with`] (at the serialized merge point).
    pub fn record_lookup(&mut self, lookup_blocks: usize, hit_blocks: usize) {
        self.index.record_lookup(lookup_blocks, hit_blocks);
    }

    /// Allocating convenience wrapper over [`Self::match_into`] (tests
    /// and offline tools; the router uses the buffered form).
    pub fn match_all(&mut self, hashes: &[u64], _now_us: u64) -> Vec<usize> {
        let mut hits = Vec::new();
        let mut matched = InstanceMask::default();
        self.index.match_into(hashes, &mut hits, &mut matched);
        hits
    }

    /// Optimistic insert at routing time (the routed instance will have
    /// this prefix cached by the time the request prefills).
    pub fn on_route(&mut self, inst: usize, hashes: &[u64], now_us: u64) {
        self.index.insert(inst, hashes, now_us);
    }

    /// Authoritative insert at response time (prompt + generated tokens).
    pub fn on_response(&mut self, inst: usize, full_hashes: &[u64], now_us: u64) {
        self.index.insert(inst, full_hashes, now_us);
    }

    /// Lifecycle: wipe a dead instance's presence everywhere (crash /
    /// drain-complete). Equivalent to replacing that slot of a
    /// `MirrorKvView` with a fresh `RadixTree` — pinned by the purge
    /// churn test below.
    pub fn purge_instance(&mut self, inst: usize) {
        self.index.purge_instance(inst);
    }

    /// Lifecycle: change the fleet width (scale-up past the current slot
    /// count). Shrinking requires the dropped tail slots purged first.
    pub fn resize_instances(&mut self, new_n: usize) {
        self.index.resize_instances(new_n);
    }

    /// The underlying sharded index (stats, snapshots, invariant checks).
    pub fn index(&self) -> &ShardedRadixIndex {
        &self.index
    }
}

/// The pre-shared-index router view: N independent per-instance radix
/// mirrors. Kept as the *reference model* for the shared index — the
/// equivalence tests replay identical traffic through both and assert
/// bit-identical hit vectors (and therefore routing decisions). Not used
/// on any production path.
#[derive(Debug)]
pub struct MirrorKvView {
    views: Vec<RadixTree>,
}

impl MirrorKvView {
    pub fn new(n_instances: usize, capacity_blocks: usize) -> Self {
        MirrorKvView {
            views: (0..n_instances)
                .map(|_| RadixTree::new(capacity_blocks))
                .collect(),
        }
    }

    pub fn n_instances(&self) -> usize {
        self.views.len()
    }

    /// Matched blocks of `hashes` on each instance (N separate walks).
    pub fn match_all(&mut self, hashes: &[u64], now_us: u64) -> Vec<usize> {
        self.views
            .iter_mut()
            .map(|v| v.match_prefix(hashes, now_us, false))
            .collect()
    }

    pub fn on_route(&mut self, inst: usize, hashes: &[u64], now_us: u64) {
        self.views[inst].insert(hashes, now_us);
    }

    pub fn on_response(&mut self, inst: usize, full_hashes: &[u64], now_us: u64) {
        self.views[inst].insert(full_hashes, now_us);
    }

    pub fn view(&self, inst: usize) -> &RadixTree {
        &self.views[inst]
    }

    /// Reference-model instance removal: the slot simply becomes a fresh
    /// tree (per-instance state is physically separate here, which is
    /// exactly why this is the specification the shared/sharded purge is
    /// proven against).
    pub fn purge_instance(&mut self, inst: usize) {
        let cap = self.views[inst].capacity_blocks();
        self.views[inst] = RadixTree::new(cap);
    }

    /// Reference-model fleet resize: truncate or extend the mirror list
    /// (new slots start empty, dropped tail slots must be purgeable by
    /// construction — they are independent trees).
    pub fn resize_instances(&mut self, new_n: usize, capacity_blocks: usize) {
        self.views.truncate(new_n);
        while self.views.len() < new_n {
            self.views.push(RadixTree::new(capacity_blocks));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn router_view_tracks_routing() {
        let mut rv = RouterKvView::new(3, 1000);
        let h = vec![1, 2, 3, 4];
        assert_eq!(rv.match_all(&h, 0), vec![0, 0, 0]);
        rv.on_route(1, &h[..2], 10);
        assert_eq!(rv.match_all(&h, 20), vec![0, 2, 0]);
        rv.on_response(1, &h, 30);
        assert_eq!(rv.match_all(&h, 40), vec![0, 4, 0]);
    }

    #[test]
    fn match_into_reuses_buffers_and_fills_mask() {
        let mut rv = RouterKvView::new(2, 0);
        rv.on_route(1, &[5, 6], 0);
        let mut hits = Vec::new();
        let mut mask = InstanceMask::default();
        rv.match_into(&[5, 6, 7], &mut hits, &mut mask);
        assert_eq!(hits, vec![0, 2]);
        assert_eq!(mask.iter_ones().collect::<Vec<_>>(), vec![1]);
        // Second call with the same buffers: fully overwritten.
        rv.match_into(&[9], &mut hits, &mut mask);
        assert_eq!(hits, vec![0, 0]);
        assert!(mask.is_empty());
    }

    /// The load-bearing contract of this module: under arbitrary mixed
    /// traffic — optimistic and authoritative inserts on random instances,
    /// bounded capacities forcing per-instance LRU eviction — the sharded
    /// router view, the monolithic `SharedRadixIndex` and N dedicated
    /// per-instance mirrors report IDENTICAL hit vectors on every lookup.
    /// Eviction order, timestamp refresh and free-list reuse are
    /// replicated exactly across all three, so any divergence (which
    /// would change routing decisions) fails here.
    #[test]
    fn shared_index_equals_per_instance_mirrors_under_churn() {
        for seed in 0..6u64 {
            for cap in [0usize, 8, 32] {
                let n = 5usize;
                let mut sharded = RouterKvView::new(n, cap);
                let mut mono = SharedRadixIndex::new(n, cap);
                let mut mirror = MirrorKvView::new(n, cap);
                let mut mono_hits = Vec::new();
                let mut mono_mask = InstanceMask::default();
                let mut rng = Rng::new(seed.wrapping_mul(0x9e37_79b9) ^ 0x5eed);
                for step in 0..1500u64 {
                    let base = rng.gen_range(0, 6);
                    let len = rng.gen_range(1, 10) as usize;
                    let chain: Vec<u64> =
                        (0..len as u64).map(|i| base * 1000 + i).collect();
                    match rng.gen_range(0, 4) {
                        0 => {
                            let i = rng.gen_range(0, n as u64) as usize;
                            sharded.on_route(i, &chain, step);
                            mono.insert(i, &chain, step);
                            mirror.on_route(i, &chain, step);
                        }
                        1 => {
                            let i = rng.gen_range(0, n as u64) as usize;
                            sharded.on_response(i, &chain, step);
                            mono.insert(i, &chain, step);
                            mirror.on_response(i, &chain, step);
                        }
                        _ => {
                            let hits = sharded.match_all(&chain, step);
                            mono.match_into(&chain, &mut mono_hits, &mut mono_mask);
                            assert_eq!(
                                hits, mono_hits,
                                "sharded vs monolithic diverged: seed {seed} cap {cap} step {step} chain {chain:?}"
                            );
                            assert_eq!(
                                hits,
                                mirror.match_all(&chain, step),
                                "sharded vs mirrors diverged: seed {seed} cap {cap} step {step} chain {chain:?}"
                            );
                        }
                    }
                    if step % 251 == 0 {
                        sharded.index().check_invariants().unwrap();
                    }
                }
                // Full-state probe: every possible chain agrees at the end.
                for base in 0..6u64 {
                    let chain: Vec<u64> = (0..10).map(|i| base * 1000 + i).collect();
                    let hits = sharded.match_all(&chain, 10_000);
                    mono.match_into(&chain, &mut mono_hits, &mut mono_mask);
                    assert_eq!(
                        hits, mono_hits,
                        "final state diverged (monolithic): seed {seed} cap {cap} base {base}"
                    );
                    assert_eq!(
                        hits,
                        mirror.match_all(&chain, 10_000),
                        "final state diverged (mirrors): seed {seed} cap {cap} base {base}"
                    );
                }
                sharded.index().check_invariants().unwrap();
            }
        }
    }

    /// Satellite regression for instance removal: purging an instance
    /// from the sharded router view (and from the monolithic index) must
    /// be indistinguishable from replacing that slot of the mirror model
    /// with a fresh tree — including under CONTINUED churn afterwards, so
    /// stale occupancy (slots, free-lists, heaps) leaking across a purge
    /// shows up as a hit-vector divergence or an invariant failure.
    #[test]
    fn purge_instance_equals_fresh_mirror_slot_under_churn() {
        for seed in 0..4u64 {
            for cap in [0usize, 8, 32] {
                let n = 5usize;
                let mut sharded = RouterKvView::new(n, cap);
                let mut mono = SharedRadixIndex::new(n, cap);
                let mut mirror = MirrorKvView::new(n, cap);
                let mut mono_hits = Vec::new();
                let mut mono_mask = InstanceMask::default();
                let mut rng = Rng::new(seed.wrapping_mul(0xfa17) ^ 0x9e37_79b9);
                for step in 0..1500u64 {
                    let base = rng.gen_range(0, 6);
                    let len = rng.gen_range(1, 10) as usize;
                    let chain: Vec<u64> = (0..len as u64).map(|i| base * 1000 + i).collect();
                    match rng.gen_range(0, 5) {
                        0 | 1 => {
                            let i = rng.gen_range(0, n as u64) as usize;
                            sharded.on_route(i, &chain, step);
                            mono.insert(i, &chain, step);
                            mirror.on_route(i, &chain, step);
                        }
                        2 => {
                            // The fault path under test: kill an instance
                            // in all three models.
                            let i = rng.gen_range(0, n as u64) as usize;
                            sharded.purge_instance(i);
                            mono.purge_instance(i);
                            mirror.purge_instance(i);
                        }
                        _ => {
                            let hits = sharded.match_all(&chain, step);
                            mono.match_into(&chain, &mut mono_hits, &mut mono_mask);
                            assert_eq!(
                                hits, mono_hits,
                                "purge diverged (monolithic): seed {seed} cap {cap} step {step}"
                            );
                            assert_eq!(
                                hits,
                                mirror.match_all(&chain, step),
                                "purge diverged (mirrors): seed {seed} cap {cap} step {step}"
                            );
                        }
                    }
                    if step % 251 == 0 {
                        sharded.index().check_invariants().unwrap();
                        mono.check_invariants().unwrap();
                    }
                }
                sharded.index().check_invariants().unwrap();
                mono.check_invariants().unwrap();
            }
        }
    }

    /// Fleet-width equivalence: growing all three models mid-churn (and
    /// shrinking back after purging the tail) keeps hit vectors aligned.
    #[test]
    fn resize_equals_mirror_resize_under_churn() {
        let cap = 16usize;
        let mut n = 3usize;
        let mut sharded = RouterKvView::new(n, cap);
        let mut mirror = MirrorKvView::new(n, cap);
        let mut rng = Rng::new(0x5ca1_e5);
        for step in 0..1200u64 {
            let base = rng.gen_range(0, 6);
            let len = rng.gen_range(1, 8) as usize;
            let chain: Vec<u64> = (0..len as u64).map(|i| base * 1000 + i).collect();
            match step {
                300 => {
                    // Scale up past the old width.
                    n = 70;
                    sharded.resize_instances(n);
                    mirror.resize_instances(n, cap);
                }
                900 => {
                    // Scale back down: purge the tail slots first.
                    for i in 4..n {
                        sharded.purge_instance(i);
                        mirror.purge_instance(i);
                    }
                    n = 4;
                    sharded.resize_instances(n);
                    mirror.resize_instances(n, cap);
                }
                _ => {}
            }
            if rng.gen_bool(0.5) {
                let i = rng.gen_range(0, n as u64) as usize;
                sharded.on_route(i, &chain, step);
                mirror.on_route(i, &chain, step);
            } else {
                assert_eq!(
                    sharded.match_all(&chain, step),
                    mirror.match_all(&chain, step),
                    "resize diverged at step {step}"
                );
            }
            if step % 199 == 0 {
                sharded.index().check_invariants().unwrap();
            }
        }
    }
}
