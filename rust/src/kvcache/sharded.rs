//! The sharded, concurrently-readable router prefix index.
//!
//! [`super::SharedRadixIndex`] already collapsed N per-instance mirrors
//! into one presence-mask radix tree, but every router decision still
//! reads the *same* monolithic structure a writer mutates — one thread,
//! one lock domain. This module splits that structure into S shards so R
//! router workers can score concurrently from `&self` reads while commits
//! stay serialized at a merge point:
//!
//! * **Shard partition.** In a radix tree over block-hash *chains*, two
//!   chains share nodes only below a common first block, so sharding by
//!   the first block's hash ([`shard_of`]) partitions the node set
//!   exactly: every request walks exactly ONE shard, and no node is
//!   reachable from two shards.
//! * **Global per-instance LRU.** Capacity, slot allocation, the lazy
//!   eviction heap and timestamps stay per-*instance* and global across
//!   shards (an instance's LRU block may live in any shard, and eviction
//!   must pick the globally oldest). Node references in the per-instance
//!   state are packed `(shard, node)` ids. Because the per-instance
//!   machinery is a verbatim transplant of `SharedRadixIndex`'s, insert
//!   order, eviction order and slot tie-breaks are byte-identical to the
//!   monolithic index — the churn test in `kvcache/mod.rs` and the
//!   all-policies replay in `tests/policy_semantics.rs` pin this.
//! * **Epochs.** Every shard carries an epoch bumped on each mutation of
//!   its nodes/masks, and the index carries a global `version` bumped per
//!   write call. A reader pins a [`IndexSnapshot`] (a `&self` borrow plus
//!   the stamps): in safe code the borrow itself freezes the index for
//!   the snapshot's lifetime, and under an `RwLock` the read guard does —
//!   [`IndexSnapshot::is_consistent`] asserts the discipline held. Note
//!   that *eviction can cross shards* (global LRU), which is exactly why
//!   consistency is pinned at whole-index granularity rather than by
//!   locking one shard at a time.
//!
//! The read path ([`ShardedRadixIndex::match_with`]) takes `&self` and
//! caller-owned scratch, so any number of workers may walk concurrently;
//! the serial wrapper [`ShardedRadixIndex::match_into`] keeps the old
//! `&mut self` counter-bumping contract for drop-in compatibility.

use std::collections::{BinaryHeap, HashMap};

use crate::core::InstanceMask;
use crate::util::FastHash;

const ROOT: usize = 0;
/// Packed `(shard, node)` reference: shard in the high 24 bits, node
/// index in the low 40 (a shard arena of 2^40 nodes is unreachable).
const NODE_BITS: u32 = 40;
const NONE_REF: u64 = u64::MAX;

/// Shards a chain by its FIRST block hash — the pure function the whole
/// partition rests on (and the one `python/tests/test_shard_assignment.py`
/// mirrors line-for-line with pinned vectors). SplitMix64's finalizer
/// over `hash ^ golden-ratio`, then a modulo: cheap, stateless, and
/// avalanching enough that consecutive class hashes spread evenly.
#[inline]
pub fn shard_of(first_hash: u64, n_shards: usize) -> usize {
    let mut z = first_hash ^ 0x9e37_79b9_7f4a_7c15;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^= z >> 31;
    (z % n_shards as u64) as usize
}

#[inline]
fn pack(shard: usize, node: usize) -> u64 {
    debug_assert!(node < (1usize << NODE_BITS));
    ((shard as u64) << NODE_BITS) | node as u64
}

#[inline]
fn unpack(r: u64) -> (usize, usize) {
    ((r >> NODE_BITS) as usize, (r & ((1u64 << NODE_BITS) - 1)) as usize)
}

#[derive(Debug)]
struct ShardNode {
    hash: u64,
    parent: usize,
    children: HashMap<u64, usize, FastHash>,
    alive: bool,
}

/// One shard: a self-contained radix arena (own root at index 0, own
/// free-list) plus the epoch stamp readers pin against.
#[derive(Debug)]
struct Shard {
    nodes: Vec<ShardNode>,
    /// Flat node masks: `masks[node*words .. (node+1)*words]`.
    masks: Vec<u64>,
    free_nodes: Vec<usize>,
    /// Bumped on every mutation of this shard's nodes or masks.
    epoch: u64,
}

impl Shard {
    fn new(words: usize) -> Self {
        Shard {
            nodes: vec![ShardNode {
                hash: 0,
                parent: ROOT,
                children: HashMap::default(),
                alive: true,
            }],
            masks: vec![0; words],
            free_nodes: Vec::new(),
            epoch: 0,
        }
    }
}

/// Max-heap entry ordered by *oldest* access first; ties break on the
/// smaller per-instance slot — identical to `SharedRadixIndex`'s.
#[derive(Debug, PartialEq, Eq)]
struct EvictCandidate {
    last_access: u64,
    slot: usize,
}

impl Ord for EvictCandidate {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other
            .last_access
            .cmp(&self.last_access)
            .then(other.slot.cmp(&self.slot))
    }
}
impl PartialOrd for EvictCandidate {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Per-(node, instance) LRU metadata, keyed by packed node refs.
#[derive(Debug)]
struct InstMeta {
    last_access: u64,
    /// Children of this node present on this instance (0 = instance-leaf).
    children: u32,
    /// Instance-local slot id (monotone counter + LIFO free-list reuse),
    /// replicating the dedicated-mirror node ids so eviction tie-breaks
    /// match the mirror — and `SharedRadixIndex` — exactly.
    slot: usize,
}

/// Per-instance eviction state — global across shards, because an
/// instance's capacity and LRU order are properties of the instance, not
/// of any shard. This is what keeps sharded decisions byte-identical to
/// the monolithic index: the slot/heap/timestamp machinery below is a
/// verbatim transplant with node ids widened to packed refs.
#[derive(Debug)]
struct InstanceState {
    used: usize,
    meta: HashMap<u64, InstMeta, FastHash>,
    heap: BinaryHeap<EvictCandidate>,
    free_slots: Vec<usize>,
    next_slot: usize,
    /// slot -> packed node ref currently occupying it (NONE_REF = free).
    slot_node: Vec<u64>,
}

impl InstanceState {
    fn new() -> Self {
        InstanceState {
            used: 0,
            meta: HashMap::default(),
            heap: BinaryHeap::new(),
            free_slots: Vec::new(),
            // Slot 0 is the root sentinel (mirrors index their root at 0
            // and never push it), so real slots start at 1.
            next_slot: 1,
            slot_node: vec![NONE_REF],
        }
    }
}

/// Default shard count: enough that 8–16 router workers rarely contend
/// on a hot shard under Zipf-skewed first blocks, small enough that the
/// per-shard arenas stay cache-friendly at bench scale.
pub const DEFAULT_SHARDS: usize = 16;

/// The sharded presence-mask prefix index. Drop-in for
/// [`super::SharedRadixIndex`] (same `capacity` semantics: per instance,
/// in blocks, 0 = unbounded) plus the concurrent read path.
#[derive(Debug)]
pub struct ShardedRadixIndex {
    n_instances: usize,
    /// Mask words per node: ceil(n_instances / 64) — growable past 64.
    words: usize,
    capacity: usize,
    shards: Vec<Shard>,
    inst: Vec<InstanceState>,
    /// Bumped once per write call (`insert`) — the publish event readers
    /// measure staleness against.
    version: u64,
    /// Scratch live-set for the serial `match_into` walk.
    live: Vec<u64>,
    /// Cumulative lookup accounting, aggregated over instances.
    pub total_lookup_blocks: u64,
    pub total_hit_blocks: u64,
    pub total_evicted_blocks: u64,
}

impl ShardedRadixIndex {
    /// `capacity_blocks` is per instance; 0 means unbounded.
    pub fn new(n_instances: usize, capacity_blocks: usize) -> Self {
        Self::with_shards(n_instances, capacity_blocks, DEFAULT_SHARDS)
    }

    pub fn with_shards(n_instances: usize, capacity_blocks: usize, n_shards: usize) -> Self {
        assert!(n_shards >= 1, "need at least one shard");
        let words = n_instances.div_ceil(64);
        ShardedRadixIndex {
            n_instances,
            words,
            capacity: capacity_blocks,
            shards: (0..n_shards).map(|_| Shard::new(words)).collect(),
            inst: (0..n_instances).map(|_| InstanceState::new()).collect(),
            version: 0,
            live: vec![0; words],
            total_lookup_blocks: 0,
            total_hit_blocks: 0,
            total_evicted_blocks: 0,
        }
    }

    pub fn n_instances(&self) -> usize {
        self.n_instances
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn capacity_blocks(&self) -> usize {
        self.capacity
    }

    /// Blocks instance `inst` currently holds.
    pub fn used_blocks(&self, inst: usize) -> usize {
        self.inst[inst].used
    }

    /// Global write version: bumped once per `insert` call. Readers age
    /// their pinned view in "writes since pin".
    pub fn version(&self) -> u64 {
        self.version
    }

    /// A shard's mutation epoch (every node/mask change bumps it — note
    /// that cross-shard eviction means a write keyed to shard A may bump
    /// shard B's epoch too).
    pub fn shard_epoch(&self, shard: usize) -> u64 {
        self.shards[shard].epoch
    }

    fn epoch_sum(&self) -> u64 {
        self.shards.iter().map(|s| s.epoch).sum()
    }

    /// Pin an epoch-stamped read view. The borrow freezes the index for
    /// the snapshot's lifetime (or the `RwLock` read guard does, in the
    /// concurrent harness), so every walk through the snapshot sees one
    /// consistent state across all shards.
    pub fn snapshot(&self) -> IndexSnapshot<'_> {
        IndexSnapshot {
            index: self,
            version: self.version,
            epoch_sum: self.epoch_sum(),
        }
    }

    #[inline]
    fn mask_get(&self, shard: usize, node: usize, i: usize) -> bool {
        self.shards[shard].masks[node * self.words + i / 64] & (1u64 << (i % 64)) != 0
    }

    #[inline]
    fn mask_set(&mut self, shard: usize, node: usize, i: usize) {
        self.shards[shard].masks[node * self.words + i / 64] |= 1u64 << (i % 64);
    }

    #[inline]
    fn mask_clear(&mut self, shard: usize, node: usize, i: usize) {
        self.shards[shard].masks[node * self.words + i / 64] &= !(1u64 << (i % 64));
    }

    fn mask_is_empty(&self, shard: usize, node: usize) -> bool {
        self.shards[shard].masks[node * self.words..(node + 1) * self.words]
            .iter()
            .all(|&w| w == 0)
    }

    /// The concurrent read path: one walk of the chain's shard answers
    /// every instance at once, through `&self` and caller-owned scratch
    /// (`live` is the shrinking live-set buffer), so R workers can score
    /// in parallel without any lock. Returns the summed hit blocks (the
    /// accounting a merge step later records via [`Self::record_lookup`]).
    /// Identical fill semantics to `SharedRadixIndex::match_into`.
    pub fn match_with(
        &self,
        hashes: &[u64],
        hit_blocks: &mut Vec<usize>,
        matched: &mut InstanceMask,
        live: &mut Vec<u64>,
    ) -> usize {
        let n = self.n_instances;
        let words = self.words;
        hit_blocks.clear();
        hit_blocks.resize(n, 0);
        matched.reset(n);
        live.clear();
        live.resize(words, 0);
        for (w, lw) in live.iter_mut().enumerate() {
            let rem = n - w * 64;
            *lw = if rem >= 64 { u64::MAX } else { (1u64 << rem) - 1 };
        }
        let mut depth = 0usize;
        if let Some(&first) = hashes.first() {
            let shard = &self.shards[shard_of(first, self.shards.len())];
            let mut cur = ROOT;
            for h in hashes {
                let Some(&next) = shard.nodes[cur].children.get(h) else {
                    break;
                };
                let mask = &shard.masks[next * words..(next + 1) * words];
                let mut any = false;
                for w in 0..words {
                    let dropped = live[w] & !mask[w];
                    if dropped != 0 {
                        // Instances leaving the live-set matched exactly
                        // the blocks BEFORE this node.
                        let mut bits = dropped;
                        while bits != 0 {
                            let b = bits.trailing_zeros() as usize;
                            hit_blocks[w * 64 + b] = depth;
                            bits &= bits - 1;
                        }
                        live[w] &= mask[w];
                    }
                    if live[w] != 0 {
                        any = true;
                    }
                }
                if !any {
                    break; // no instance holds this block
                }
                depth += 1;
                if depth == 1 {
                    // Survivors of the first block are exactly the
                    // instances holding ≥ 1 block of this prompt.
                    matched.copy_from_words(live);
                }
                cur = next;
            }
        }
        // Instances that survived the whole walk matched `depth` blocks.
        for (w, &lw) in live.iter().enumerate() {
            let mut bits = lw;
            while bits != 0 {
                let b = bits.trailing_zeros() as usize;
                hit_blocks[w * 64 + b] = depth;
                bits &= bits - 1;
            }
        }
        hit_blocks.iter().sum()
    }

    /// Serial wrapper keeping `SharedRadixIndex::match_into`'s exact
    /// contract (including the counter bumps), via internal scratch.
    pub fn match_into(
        &mut self,
        hashes: &[u64],
        hit_blocks: &mut Vec<usize>,
        matched: &mut InstanceMask,
    ) {
        let mut live = std::mem::take(&mut self.live);
        let hit = self.match_with(hashes, hit_blocks, matched, &mut live);
        self.live = live;
        self.record_lookup(hashes.len(), hit);
    }

    /// Record lookup accounting decoupled from the walk — the concurrent
    /// harness walks read-only on workers and records at the serialized
    /// merge, keeping the counters identical to a serial run.
    pub fn record_lookup(&mut self, lookup_blocks: usize, hit_blocks: usize) {
        self.total_lookup_blocks += (lookup_blocks * self.n_instances) as u64;
        self.total_hit_blocks += hit_blocks as u64;
    }

    /// Insert the chain for one instance, evicting that instance's LRU
    /// blocks as needed — the same per-instance semantics as
    /// `SharedRadixIndex::insert` (which itself replicates the dedicated
    /// per-instance mirror byte-for-byte), with the walk confined to the
    /// chain's shard. Returns new blocks added for this instance.
    pub fn insert(&mut self, inst_id: usize, hashes: &[u64], now: u64) -> usize {
        self.version += 1;
        let Some(&first) = hashes.first() else {
            return 0;
        };
        let sid = shard_of(first, self.shards.len());
        self.shards[sid].epoch += 1;
        let mut cur = ROOT;
        let mut cur_slot = 0usize; // root sentinel; never a candidate slot
        let mut created = 0usize;
        for h in hashes {
            let child = self.shards[sid].nodes[cur].children.get(h).copied();
            if let Some(c) = child {
                if self.mask_get(sid, c, inst_id) {
                    // Already present: refresh LRU state; free leaves are
                    // re-pushed so they stay evictable.
                    let state = &mut self.inst[inst_id];
                    let m = state
                        .meta
                        .get_mut(&pack(sid, c))
                        .expect("present bit without meta");
                    m.last_access = now;
                    let slot = m.slot;
                    let is_leaf = m.children == 0;
                    if self.capacity != 0 && is_leaf {
                        state.heap.push(EvictCandidate {
                            last_access: now,
                            slot,
                        });
                    }
                    cur = c;
                    cur_slot = slot;
                    continue;
                }
            }
            // The instance doesn't hold this block: make room, then add
            // its presence (reusing the shared node when one exists).
            if self.capacity != 0
                && self.inst[inst_id].used >= self.capacity
                && !self.evict_one(inst_id, cur_slot)
            {
                break; // full and nothing evictable
            }
            let idx = match child {
                Some(c) => c,
                None => self.alloc_node(sid, *h, cur),
            };
            self.mask_set(sid, idx, inst_id);
            let push_candidate = self.capacity != 0;
            let state = &mut self.inst[inst_id];
            let slot = match state.free_slots.pop() {
                Some(s) => s,
                None => {
                    let s = state.next_slot;
                    state.next_slot += 1;
                    s
                }
            };
            if slot >= state.slot_node.len() {
                state.slot_node.resize(slot + 1, NONE_REF);
            }
            state.slot_node[slot] = pack(sid, idx);
            state.meta.insert(
                pack(sid, idx),
                InstMeta {
                    last_access: now,
                    children: 0,
                    slot,
                },
            );
            if push_candidate {
                state.heap.push(EvictCandidate {
                    last_access: now,
                    slot,
                });
            }
            state.used += 1;
            if cur != ROOT {
                state
                    .meta
                    .get_mut(&pack(sid, cur))
                    .expect("parent missing instance meta")
                    .children += 1;
            }
            created += 1;
            cur = idx;
            cur_slot = slot;
        }
        self.maybe_compact_heap(inst_id);
        created
    }

    /// Compact an instance's lazy heap when stale entries dominate —
    /// same trigger and validity predicate as `SharedRadixIndex`.
    fn maybe_compact_heap(&mut self, inst_id: usize) {
        let state = &mut self.inst[inst_id];
        if state.heap.len() <= 4 * state.used.max(16) {
            return;
        }
        let old = std::mem::take(&mut state.heap);
        let meta = &state.meta;
        let slot_node = &state.slot_node;
        state.heap = old
            .into_iter()
            .filter(|c| {
                let node = slot_node.get(c.slot).copied().unwrap_or(NONE_REF);
                if node == NONE_REF {
                    return false;
                }
                match meta.get(&node) {
                    Some(m) => {
                        m.slot == c.slot && m.children == 0 && m.last_access == c.last_access
                    }
                    None => false,
                }
            })
            .collect();
    }

    /// Evict one LRU block of `inst_id`. Candidates are GLOBAL across
    /// shards (the instance's oldest block wins wherever it lives), with
    /// the same deferred-candidate discipline as `SharedRadixIndex`:
    /// a valid-but-protected entry is parked and restored on exit.
    fn evict_one(&mut self, inst_id: usize, protect_slot: usize) -> bool {
        let mut deferred: Option<EvictCandidate> = None;
        let mut evicted = false;
        while let Some(cand) = self.inst[inst_id].heap.pop() {
            let nref = self.inst[inst_id]
                .slot_node
                .get(cand.slot)
                .copied()
                .unwrap_or(NONE_REF);
            if nref == NONE_REF {
                continue;
            }
            // Lazy validation: the entry must still describe reality
            // (instance-leaf, timestamp unchanged since push).
            let valid = match self.inst[inst_id].meta.get(&nref) {
                Some(m) => {
                    m.slot == cand.slot && m.children == 0 && m.last_access == cand.last_access
                }
                None => false,
            };
            if !valid {
                continue;
            }
            if cand.slot == protect_slot {
                deferred = Some(cand);
                continue;
            }
            let (sid, node) = unpack(nref);
            self.mask_clear(sid, node, inst_id);
            let parent = self.shards[sid].nodes[node].parent;
            {
                let state = &mut self.inst[inst_id];
                state.meta.remove(&nref);
                state.slot_node[cand.slot] = NONE_REF;
                state.free_slots.push(cand.slot);
                state.used -= 1;
                if parent != ROOT {
                    if let Some(pm) = state.meta.get_mut(&pack(sid, parent)) {
                        pm.children -= 1;
                        if pm.children == 0 {
                            // Parent became this instance's leaf.
                            let (la, slot) = (pm.last_access, pm.slot);
                            state.heap.push(EvictCandidate {
                                last_access: la,
                                slot,
                            });
                        }
                    }
                }
            }
            self.total_evicted_blocks += 1;
            // Shared-structure GC: unlink nodes no instance holds. By the
            // closure invariant such a node has no live children.
            if self.mask_is_empty(sid, node) {
                debug_assert!(
                    self.shards[sid].nodes[node].children.is_empty(),
                    "presence closure violated"
                );
                let hash = self.shards[sid].nodes[node].hash;
                self.shards[sid].nodes[parent].children.remove(&hash);
                self.shards[sid].nodes[node].alive = false;
                self.shards[sid].free_nodes.push(node);
            }
            // The mutated shard may differ from the insert's shard —
            // cross-shard eviction publishes on the shard it touched.
            self.shards[sid].epoch += 1;
            evicted = true;
            break;
        }
        if let Some(c) = deferred {
            self.inst[inst_id].heap.push(c);
        }
        evicted
    }

    fn alloc_node(&mut self, sid: usize, hash: u64, parent: usize) -> usize {
        let words = self.words;
        let shard = &mut self.shards[sid];
        let idx = if let Some(idx) = shard.free_nodes.pop() {
            debug_assert!(
                shard.masks[idx * words..(idx + 1) * words]
                    .iter()
                    .all(|&w| w == 0),
                "recycled node with live presence bits"
            );
            let n = &mut shard.nodes[idx];
            debug_assert!(n.children.is_empty());
            n.hash = hash;
            n.parent = parent;
            n.alive = true;
            idx
        } else {
            shard.nodes.push(ShardNode {
                hash,
                parent,
                children: HashMap::default(),
                alive: true,
            });
            shard.masks.resize(shard.nodes.len() * words, 0);
            shard.nodes.len() - 1
        };
        shard.nodes[parent].children.insert(hash, idx);
        idx
    }

    /// Remove every trace of `inst_id` from the index: presence bits in
    /// every shard, LRU metadata, slot allocator, heap and free-lists —
    /// the instance slot comes back as if freshly constructed, so a later
    /// scale-up reusing it inherits no stale occupancy. Per-shard GC
    /// follows the same closure argument as
    /// `SharedRadixIndex::purge_instance`: a node the purge empties had
    /// mask == {inst_id}, so its whole subtree is in this instance's meta
    /// set and dies in the same pass. Bumps the global version and each
    /// touched shard's epoch (readers pinned before a crash must notice).
    pub fn purge_instance(&mut self, inst_id: usize) {
        self.version += 1;
        let state = std::mem::replace(&mut self.inst[inst_id], InstanceState::new());
        // meta is a hash map: sort the packed refs so mask clearing, GC
        // free-list order and epoch bumps are deterministic.
        let mut touched: Vec<u64> = state.meta.keys().copied().collect();
        touched.sort_unstable();
        let mut last_sid = usize::MAX;
        for &nref in &touched {
            let (sid, node) = unpack(nref);
            self.mask_clear(sid, node, inst_id);
            if sid != last_sid {
                self.shards[sid].epoch += 1;
                last_sid = sid;
            }
        }
        for &nref in &touched {
            let (sid, node) = unpack(nref);
            if self.shards[sid].nodes[node].alive && self.mask_is_empty(sid, node) {
                let parent = self.shards[sid].nodes[node].parent;
                let hash = self.shards[sid].nodes[node].hash;
                self.shards[sid].nodes[parent].children.remove(&hash);
                self.shards[sid].nodes[node].alive = false;
                // Remaining child links point at nodes this same pass
                // kills (their masks were ⊆ ours); clear them so the
                // recycled node satisfies `alloc_node`'s empty-children
                // contract regardless of processing order.
                self.shards[sid].nodes[node].children.clear();
                self.shards[sid].free_nodes.push(node);
            }
        }
    }

    /// Change the fleet width (the mask-width refactor behind
    /// scale-up/scale-down). Growth appends fresh, empty instance slots
    /// and widens every shard's mask rows when a new 64-bit word is
    /// needed; shrink requires the dropped tail slots to have been purged
    /// first (asserted). Bumps the version — a resize is a write.
    pub fn resize_instances(&mut self, new_n: usize) {
        assert!(new_n > 0, "fleet cannot resize to zero instances");
        if new_n < self.n_instances {
            for i in new_n..self.n_instances {
                assert_eq!(
                    self.inst[i].used, 0,
                    "resize_instances shrink requires purged tail slot {i}"
                );
            }
        }
        self.version += 1;
        let new_words = new_n.div_ceil(64);
        if new_words != self.words {
            let copy = self.words.min(new_words);
            for shard in &mut self.shards {
                let n_nodes = shard.nodes.len();
                let mut masks = vec![0u64; n_nodes * new_words];
                for node in 0..n_nodes {
                    masks[node * new_words..node * new_words + copy].copy_from_slice(
                        &shard.masks[node * self.words..node * self.words + copy],
                    );
                }
                shard.masks = masks;
                shard.epoch += 1;
            }
            self.words = new_words;
            self.live = vec![0; new_words];
        }
        self.inst.resize_with(new_n, InstanceState::new);
        self.n_instances = new_n;
    }

    /// Lifetime block hit rate across all instances.
    pub fn hit_rate(&self) -> f64 {
        if self.total_lookup_blocks == 0 {
            0.0
        } else {
            self.total_hit_blocks as f64 / self.total_lookup_blocks as f64
        }
    }

    /// Alive non-root nodes across all shards (arena-bound assertions).
    pub fn alive_nodes(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.nodes.iter().skip(1).filter(|n| n.alive).count())
            .sum()
    }

    /// Invariant checker used by the property/equivalence tests: per-shard
    /// structural invariants (links, presence closure, no orphan nodes)
    /// plus cross-shard per-instance accounting (used counts, slot maps,
    /// children counters).
    pub fn check_invariants(&self) -> Result<(), String> {
        let words = self.words;
        let mut per_inst_live = vec![0usize; self.n_instances];
        for (sid, shard) in self.shards.iter().enumerate() {
            for (i, n) in shard.nodes.iter().enumerate() {
                if !n.alive {
                    continue;
                }
                if i != ROOT {
                    let p = &shard.nodes[n.parent];
                    if !p.alive {
                        return Err(format!("shard {sid} node {i} has dead parent {}", n.parent));
                    }
                    if p.children.get(&n.hash) != Some(&i) {
                        return Err(format!("shard {sid} node {i} not linked from parent"));
                    }
                    let mut empty = true;
                    for w in 0..words {
                        let nm = shard.masks[i * words + w];
                        // The root implicitly holds everything.
                        let pm = if n.parent == ROOT {
                            u64::MAX
                        } else {
                            shard.masks[n.parent * words + w]
                        };
                        if nm & !pm != 0 {
                            return Err(format!(
                                "presence closure violated at shard {sid} node {i}"
                            ));
                        }
                        if nm != 0 {
                            empty = false;
                        }
                    }
                    if empty {
                        return Err(format!("alive shard {sid} node {i} held by no instance"));
                    }
                    for (inst, cnt) in per_inst_live.iter_mut().enumerate() {
                        if self.mask_get(sid, i, inst) {
                            *cnt += 1;
                        }
                    }
                }
                for (&h, &c) in &n.children {
                    let ch = &shard.nodes[c];
                    if !ch.alive || ch.parent != i || ch.hash != h {
                        return Err(format!("bad child link {i}->{c} in shard {sid}"));
                    }
                }
            }
        }
        for (inst, state) in self.inst.iter().enumerate() {
            if state.used != per_inst_live[inst] {
                return Err(format!(
                    "instance {inst}: used={} but mask bits={}",
                    state.used, per_inst_live[inst]
                ));
            }
            if self.capacity != 0 && state.used > self.capacity {
                return Err(format!(
                    "instance {inst} over capacity: {}>{}",
                    state.used, self.capacity
                ));
            }
            if state.meta.len() != state.used {
                return Err(format!(
                    "instance {inst}: meta {} entries vs used {}",
                    state.meta.len(),
                    state.used
                ));
            }
            for (&nref, m) in &state.meta {
                let (sid, node) = unpack(nref);
                if !self.shards[sid].nodes[node].alive || !self.mask_get(sid, node, inst) {
                    return Err(format!(
                        "instance {inst}: meta for absent shard {sid} node {node}"
                    ));
                }
                if state.slot_node.get(m.slot).copied().unwrap_or(NONE_REF) != nref {
                    return Err(format!(
                        "instance {inst}: slot map broken at shard {sid} node {node}"
                    ));
                }
                let cnt = self.shards[sid].nodes[node]
                    .children
                    .values()
                    .filter(|&&c| self.mask_get(sid, c, inst))
                    .count() as u32;
                if cnt != m.children {
                    return Err(format!(
                        "instance {inst}: shard {sid} node {node} children {} vs counted {cnt}",
                        m.children
                    ));
                }
            }
        }
        Ok(())
    }
}

/// An epoch-stamped pinned read view over the whole index. While this
/// exists, the `&` borrow (or the `RwLock` read guard holding it) keeps
/// every shard frozen, so all walks observe one consistent state — the
/// "(index_snapshot, instance_snapshot)" pinning contract the concurrent
/// DES harness relies on.
#[derive(Debug, Clone, Copy)]
pub struct IndexSnapshot<'a> {
    index: &'a ShardedRadixIndex,
    version: u64,
    epoch_sum: u64,
}

impl IndexSnapshot<'_> {
    /// The write version this view was pinned at.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Whether the underlying index is still exactly as pinned: no write
    /// version bump AND no shard epoch movement (epochs only grow, so
    /// their sum detects any torn shard even if the version were somehow
    /// unchanged). Always true under the borrow/lock discipline; the
    /// writer/reader churn test asserts it from reader threads.
    pub fn is_consistent(&self) -> bool {
        self.version == self.index.version && self.epoch_sum == self.index.epoch_sum()
    }

    /// Read-only walk through the pinned view — see
    /// [`ShardedRadixIndex::match_with`].
    pub fn match_with(
        &self,
        hashes: &[u64],
        hit_blocks: &mut Vec<usize>,
        matched: &mut InstanceMask,
        live: &mut Vec<u64>,
    ) -> usize {
        self.index.match_with(hashes, hit_blocks, matched, live)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::SharedRadixIndex;
    use crate::util::Rng;

    fn hits(ix: &mut ShardedRadixIndex, hashes: &[u64]) -> Vec<usize> {
        let mut h = Vec::new();
        let mut m = InstanceMask::default();
        ix.match_into(hashes, &mut h, &mut m);
        h
    }

    /// Pinned against python/tests/test_shard_assignment.py — both sides
    /// were generated from the same reference program, so a silent edit
    /// to either implementation breaks one of the two suites.
    #[test]
    fn shard_of_pinned_vectors() {
        let hashes: [u64; 10] = [
            0,
            1,
            2,
            0xDEAD_BEEF,
            0x0123_4567_89AB_CDEF,
            u64::MAX,
            42,
            1000,
            123_456_789,
            0x9e37_79b9_7f4a_7c15,
        ];
        let expect_2: [usize; 10] = [1, 0, 0, 1, 1, 0, 1, 0, 0, 0];
        let expect_8: [usize; 10] = [7, 0, 6, 1, 1, 4, 5, 0, 6, 0];
        let expect_16: [usize; 10] = [15, 0, 14, 1, 9, 4, 5, 8, 14, 0];
        let expect_64: [usize; 10] = [47, 32, 14, 1, 57, 4, 21, 8, 46, 0];
        for (i, &h) in hashes.iter().enumerate() {
            assert_eq!(shard_of(h, 1), 0);
            assert_eq!(shard_of(h, 2), expect_2[i], "hash {h:#x} % 2");
            assert_eq!(shard_of(h, 8), expect_8[i], "hash {h:#x} % 8");
            assert_eq!(shard_of(h, 16), expect_16[i], "hash {h:#x} % 16");
            assert_eq!(shard_of(h, 64), expect_64[i], "hash {h:#x} % 64");
        }
    }

    #[test]
    fn one_walk_matches_all_instances() {
        let mut ix = ShardedRadixIndex::new(3, 0);
        ix.insert(1, &[1, 2], 10);
        ix.insert(2, &[1, 2, 3, 4], 20);
        assert_eq!(hits(&mut ix, &[1, 2, 3, 4, 5]), vec![0, 2, 4]);
        assert_eq!(hits(&mut ix, &[9]), vec![0, 0, 0]);
        ix.check_invariants().unwrap();
    }

    #[test]
    fn read_only_match_with_needs_no_mut() {
        let mut ix = ShardedRadixIndex::new(2, 0);
        ix.insert(0, &[1, 2, 3], 0);
        let snap = ix.snapshot();
        let (mut h, mut m, mut live) = (Vec::new(), InstanceMask::default(), Vec::new());
        let sum = snap.match_with(&[1, 2, 3, 4], &mut h, &mut m, &mut live);
        assert_eq!(h, vec![3, 0]);
        assert_eq!(sum, 3);
        assert!(snap.is_consistent());
        // Read-only: no counters moved.
        assert_eq!(ix.total_lookup_blocks, 0);
        assert_eq!(ix.total_hit_blocks, 0);
    }

    #[test]
    fn version_and_epochs_advance_on_writes() {
        let mut ix = ShardedRadixIndex::with_shards(2, 0, 4);
        let v0 = ix.version();
        let e0: Vec<u64> = (0..4).map(|s| ix.shard_epoch(s)).collect();
        ix.insert(0, &[1, 2], 0);
        assert_eq!(ix.version(), v0 + 1);
        let moved: usize = (0..4).filter(|&s| ix.shard_epoch(s) != e0[s]).count();
        assert_eq!(moved, 1, "one insert publishes exactly one shard");
        // A stale snapshot notices the write.
        let snap = ix.snapshot();
        assert!(snap.is_consistent());
        drop(snap);
        let pinned_version = ix.version();
        ix.insert(1, &[1, 2], 1);
        assert_eq!(ix.version(), pinned_version + 1);
    }

    #[test]
    fn per_instance_capacity_and_eviction() {
        let mut ix = ShardedRadixIndex::new(2, 4);
        ix.insert(0, &[1, 2], 0);
        ix.insert(0, &[10, 20], 100);
        // Instance 0 is at capacity; instance 1 untouched.
        ix.insert(0, &[30], 200); // evicts instance-0 LRU leaf (2)
        assert_eq!(ix.used_blocks(0), 4);
        assert_eq!(ix.used_blocks(1), 0);
        assert_eq!(hits(&mut ix, &[1, 2]), vec![1, 0]);
        assert_eq!(hits(&mut ix, &[10, 20]), vec![2, 0]);
        assert_eq!(hits(&mut ix, &[30]), vec![1, 0]);
        // Instance 1 has its own budget: same chains fit fresh.
        ix.insert(1, &[1, 2], 300);
        assert_eq!(ix.used_blocks(1), 2);
        ix.check_invariants().unwrap();
    }

    #[test]
    fn cross_shard_gc_bounds_arena() {
        let mut ix = ShardedRadixIndex::new(2, 2);
        ix.insert(0, &[1, 2], 0);
        // Churn fresh single-block chains through: their first hashes land
        // on DIFFERENT shards, yet global LRU eviction + per-shard GC keep
        // the total alive node count at the capacity bound.
        ix.insert(0, &[7], 10);
        ix.insert(0, &[8], 20);
        ix.insert(0, &[9], 30);
        ix.check_invariants().unwrap();
        assert!(ix.total_evicted_blocks >= 2);
        assert_eq!(ix.alive_nodes(), ix.used_blocks(0) + ix.used_blocks(1));
    }

    #[test]
    fn refreshed_leaves_stay_evictable_per_instance() {
        let mut ix = ShardedRadixIndex::new(1, 2);
        ix.insert(0, &[1, 2], 0);
        assert_eq!(ix.insert(0, &[1, 2], 5), 0); // pure refresh
        assert_eq!(ix.insert(0, &[9], 10), 1, "eviction starved");
        assert_eq!(hits(&mut ix, &[9]), vec![1]);
        ix.check_invariants().unwrap();
    }

    #[test]
    fn truncated_insert_keeps_tail_evictable() {
        let mut ix = ShardedRadixIndex::new(1, 2);
        assert_eq!(ix.insert(0, &[1, 2, 3], 10), 2);
        assert_eq!(ix.insert(0, &[9], 20), 1, "protected candidate was discarded");
        assert_eq!(hits(&mut ix, &[9]), vec![1]);
        ix.check_invariants().unwrap();
    }

    #[test]
    fn truncates_when_everything_unevictable() {
        let mut ix = ShardedRadixIndex::new(1, 1);
        assert_eq!(ix.insert(0, &[1, 2, 3], 0), 1);
        assert_eq!(ix.used_blocks(0), 1);
        assert_eq!(hits(&mut ix, &[1, 2, 3]), vec![1]);
        ix.check_invariants().unwrap();
    }

    #[test]
    fn supports_more_than_64_instances() {
        let n = 70;
        let mut ix = ShardedRadixIndex::new(n, 8);
        ix.insert(68, &[1, 2, 3], 0);
        ix.insert(1, &[1, 2], 1);
        let mut h = Vec::new();
        let mut m = InstanceMask::default();
        ix.match_into(&[1, 2, 3], &mut h, &mut m);
        assert_eq!(h.len(), n);
        assert_eq!(h[68], 3);
        assert_eq!(h[1], 2);
        assert_eq!(h[0], 0);
        assert_eq!(m.iter_ones().collect::<Vec<_>>(), vec![1, 68]);
        ix.check_invariants().unwrap();
    }

    #[test]
    fn refresh_heap_stays_bounded_below_capacity() {
        let mut ix = ShardedRadixIndex::new(2, 1024);
        ix.insert(0, &[1, 2, 3], 0);
        for now in 1..5000u64 {
            ix.insert(0, &[1, 2, 3], now); // pure refresh, one push each
        }
        assert!(
            ix.inst[0].heap.len() <= 4 * ix.used_blocks(0).max(16),
            "heap leaked: {} entries for {} blocks",
            ix.inst[0].heap.len(),
            ix.used_blocks(0)
        );
        ix.check_invariants().unwrap();
    }

    #[test]
    fn hit_accounting_aggregates_instances() {
        let mut ix = ShardedRadixIndex::new(2, 0);
        ix.insert(0, &[1, 2], 0);
        hits(&mut ix, &[1, 2]); // inst0: 2/2, inst1: 0/2
        assert!((ix.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn purge_instance_clears_every_shard_and_bumps_epochs() {
        let mut ix = ShardedRadixIndex::with_shards(2, 0, 4);
        // Chains with different first hashes spread over shards.
        ix.insert(0, &[1, 2, 3], 0);
        ix.insert(0, &[7, 8], 1);
        ix.insert(0, &[9], 2);
        ix.insert(1, &[1, 2], 3);
        let v0 = ix.version();
        let snap_sum = ix.epoch_sum();
        ix.purge_instance(0);
        assert_eq!(ix.used_blocks(0), 0);
        assert!(ix.version() > v0, "purge is a write");
        assert!(ix.epoch_sum() > snap_sum, "touched shards must publish");
        // Instance 1's presence survives; instance 0 is gone everywhere.
        assert_eq!(hits(&mut ix, &[1, 2, 3]), vec![0, 2]);
        assert_eq!(hits(&mut ix, &[7, 8]), vec![0, 0]);
        assert_eq!(hits(&mut ix, &[9]), vec![0, 0]);
        assert_eq!(ix.alive_nodes(), 2);
        ix.check_invariants().unwrap();
        // The purged slot restarts pristine.
        ix.insert(0, &[50, 51], 10);
        assert_eq!(ix.used_blocks(0), 2);
        assert_eq!(hits(&mut ix, &[50, 51]), vec![2, 0]);
        ix.check_invariants().unwrap();
    }

    #[test]
    fn purge_then_refill_never_inherits_stale_occupancy() {
        let mut ix = ShardedRadixIndex::with_shards(1, 2, 4);
        ix.insert(0, &[1, 2], 0);
        ix.purge_instance(0);
        assert_eq!(ix.insert(0, &[5, 6], 10), 2, "stale occupancy leaked");
        assert_eq!(ix.used_blocks(0), 2);
        ix.check_invariants().unwrap();
    }

    #[test]
    fn resize_grows_and_shrinks_across_word_boundaries() {
        let mut ix = ShardedRadixIndex::with_shards(2, 0, 4);
        ix.insert(0, &[1, 2], 0);
        ix.resize_instances(70);
        ix.insert(69, &[1, 2, 3], 1);
        let mut h = Vec::new();
        let mut m = InstanceMask::default();
        ix.match_into(&[1, 2, 3], &mut h, &mut m);
        assert_eq!(h.len(), 70);
        assert_eq!(h[0], 2);
        assert_eq!(h[69], 3);
        ix.check_invariants().unwrap();
        ix.purge_instance(69);
        ix.resize_instances(2);
        assert_eq!(hits(&mut ix, &[1, 2]), vec![2, 0]);
        ix.check_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "purged tail")]
    fn resize_shrink_rejects_occupied_tail() {
        let mut ix = ShardedRadixIndex::new(3, 0);
        ix.insert(2, &[1], 0);
        ix.resize_instances(2);
    }

    /// Direct sharded-vs-monolithic pin at the index layer: identical
    /// mixed traffic through `ShardedRadixIndex` (several shard counts)
    /// and `SharedRadixIndex` must produce identical hit vectors AND
    /// identical counters. The heavier three-way churn (vs the dedicated
    /// per-instance mirrors) lives in `kvcache/mod.rs`; the all-policies
    /// decision replay in `tests/policy_semantics.rs` closes the loop.
    #[test]
    fn sharded_equals_monolithic_under_churn() {
        for &n_shards in &[1usize, 3, 16] {
            for seed in 0..3u64 {
                for cap in [0usize, 8, 32] {
                    let n = 5usize;
                    let mut sharded = ShardedRadixIndex::with_shards(n, cap, n_shards);
                    let mut mono = SharedRadixIndex::new(n, cap);
                    let mut rng = Rng::new(seed.wrapping_mul(0x517c_c1b7) ^ 0x5eed);
                    for step in 0..800u64 {
                        let base = rng.gen_range(0, 6);
                        let len = rng.gen_range(1, 10) as usize;
                        let chain: Vec<u64> = (0..len as u64).map(|i| base * 1000 + i).collect();
                        match rng.gen_range(0, 3) {
                            0 | 1 => {
                                let i = rng.gen_range(0, n as u64) as usize;
                                sharded.insert(i, &chain, step);
                                mono.insert(i, &chain, step);
                            }
                            _ => {
                                let (mut hs, mut ms) = (Vec::new(), InstanceMask::default());
                                let (mut hm, mut mm) = (Vec::new(), InstanceMask::default());
                                sharded.match_into(&chain, &mut hs, &mut ms);
                                mono.match_into(&chain, &mut hm, &mut mm);
                                assert_eq!(
                                    hs, hm,
                                    "diverged: shards {n_shards} seed {seed} cap {cap} step {step}"
                                );
                                assert_eq!(ms, mm);
                            }
                        }
                        if step % 211 == 0 {
                            sharded.check_invariants().unwrap();
                        }
                    }
                    assert_eq!(sharded.total_lookup_blocks, mono.total_lookup_blocks);
                    assert_eq!(sharded.total_hit_blocks, mono.total_hit_blocks);
                    assert_eq!(sharded.total_evicted_blocks, mono.total_evicted_blocks);
                    sharded.check_invariants().unwrap();
                }
            }
        }
    }
}
