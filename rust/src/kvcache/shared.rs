//! The shared multi-instance prefix index: ONE radix tree over block-hash
//! chains whose nodes carry a per-instance presence bitmask, replacing N
//! independent per-instance radix mirrors on the router's hot path.
//!
//! A single walk from the root answers `KV$.match(req)` for *every*
//! instance at once: the walk ANDs node masks into a shrinking live-set,
//! and the depth at which an instance's bit drops out is that instance's
//! hit length — N× fewer hash-chain walks than the mirror design, and the
//! surviving first-level mask is exactly the hotspot detector's M-set
//! (instances holding any prefix of the request), produced for free.
//!
//! Writes (the router's optimistic insert at route time, authoritative
//! insert at response time) touch a single instance and replicate the
//! per-instance mirror semantics *exactly* — including per-instance LRU
//! eviction with the same lazy-heap algorithm, timestamps, slot-index
//! tie-breaks and free-list reuse order as [`super::RadixTree`] — so
//! routing decisions are bit-identical to the N-mirror design (see the
//! equivalence tests in `kvcache/mod.rs` and `tests/policy_semantics.rs`).
//! Nodes no instance holds are unlinked from the shared structure.
//!
//! Presence closure invariant: a node's mask is a subset of its parent's
//! (an instance holding a block holds the whole prefix), which is what
//! makes the single-walk AND correct and guarantees that an empty-mask
//! node has no children left to orphan.

use std::collections::{BinaryHeap, HashMap};

use crate::core::InstanceMask;
use crate::util::FastHash;

const ROOT: usize = 0;
const NONE: usize = usize::MAX;

#[derive(Debug)]
struct SharedNode {
    hash: u64,
    parent: usize,
    children: HashMap<u64, usize, FastHash>,
    alive: bool,
}

/// Max-heap entry ordered by *oldest* access first; ties break on the
/// smaller per-instance slot — the same ordering as the per-instance
/// mirror's `(last_access, node)` candidates.
#[derive(Debug, PartialEq, Eq)]
struct EvictCandidate {
    last_access: u64,
    slot: usize,
}

impl Ord for EvictCandidate {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other
            .last_access
            .cmp(&self.last_access)
            .then(other.slot.cmp(&self.slot))
    }
}
impl PartialOrd for EvictCandidate {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Per-(node, instance) LRU metadata, kept only for blocks the instance
/// actually holds.
#[derive(Debug)]
struct InstMeta {
    last_access: u64,
    /// Children of this node present on this instance (0 = instance-leaf).
    children: u32,
    /// The instance-local node id, replicating the index a dedicated
    /// per-instance mirror would have allocated (monotone counter + LIFO
    /// free-list reuse) so eviction tie-breaks match the mirror exactly.
    slot: usize,
}

/// Per-instance eviction state (used blocks, lazy heap, slot allocator).
#[derive(Debug)]
struct InstanceState {
    used: usize,
    meta: HashMap<usize, InstMeta, FastHash>,
    heap: BinaryHeap<EvictCandidate>,
    free_slots: Vec<usize>,
    next_slot: usize,
    /// slot -> shared node index currently occupying it (NONE = free).
    slot_node: Vec<usize>,
}

impl InstanceState {
    fn new() -> Self {
        InstanceState {
            used: 0,
            meta: HashMap::default(),
            heap: BinaryHeap::new(),
            free_slots: Vec::new(),
            // Slot 0 is the root sentinel (mirrors index their root at 0
            // and never push it), so real slots start at 1.
            next_slot: 1,
            slot_node: vec![NONE],
        }
    }
}

/// The shared presence-mask prefix index. `capacity` is per-instance, in
/// blocks (0 = unbounded), matching the per-instance mirror semantics.
#[derive(Debug)]
pub struct SharedRadixIndex {
    n_instances: usize,
    /// Mask words per node: ceil(n_instances / 64) — growable past 64.
    words: usize,
    capacity: usize,
    nodes: Vec<SharedNode>,
    /// Flat node masks: `masks[node*words .. (node+1)*words]`.
    masks: Vec<u64>,
    free_nodes: Vec<usize>,
    inst: Vec<InstanceState>,
    /// Scratch live-set for the match walk (no per-request allocation).
    live: Vec<u64>,
    /// Cumulative lookup accounting, aggregated over instances.
    pub total_lookup_blocks: u64,
    pub total_hit_blocks: u64,
    pub total_evicted_blocks: u64,
}

impl SharedRadixIndex {
    /// `capacity_blocks` is per instance; 0 means unbounded.
    pub fn new(n_instances: usize, capacity_blocks: usize) -> Self {
        let words = (n_instances + 63) / 64;
        SharedRadixIndex {
            n_instances,
            words,
            capacity: capacity_blocks,
            nodes: vec![SharedNode {
                hash: 0,
                parent: ROOT,
                children: HashMap::default(),
                alive: true,
            }],
            masks: vec![0; words],
            free_nodes: Vec::new(),
            inst: (0..n_instances).map(|_| InstanceState::new()).collect(),
            live: vec![0; words],
            total_lookup_blocks: 0,
            total_hit_blocks: 0,
            total_evicted_blocks: 0,
        }
    }

    pub fn n_instances(&self) -> usize {
        self.n_instances
    }

    pub fn capacity_blocks(&self) -> usize {
        self.capacity
    }

    /// Blocks instance `inst` currently holds.
    pub fn used_blocks(&self, inst: usize) -> usize {
        self.inst[inst].used
    }

    #[inline]
    fn mask_get(&self, node: usize, i: usize) -> bool {
        self.masks[node * self.words + i / 64] & (1u64 << (i % 64)) != 0
    }

    #[inline]
    fn mask_set(&mut self, node: usize, i: usize) {
        self.masks[node * self.words + i / 64] |= 1u64 << (i % 64);
    }

    #[inline]
    fn mask_clear(&mut self, node: usize, i: usize) {
        self.masks[node * self.words + i / 64] &= !(1u64 << (i % 64));
    }

    fn mask_is_empty(&self, node: usize) -> bool {
        self.masks[node * self.words..(node + 1) * self.words]
            .iter()
            .all(|&w| w == 0)
    }

    /// One walk, all instances: fills `hit_blocks[i]` with the number of
    /// leading blocks of `hashes` instance `i` holds, and `matched` with
    /// the set of instances holding ≥ 1 block (the hotspot M-set).
    /// Allocation-free in steady state (buffers are reused).
    pub fn match_into(
        &mut self,
        hashes: &[u64],
        hit_blocks: &mut Vec<usize>,
        matched: &mut InstanceMask,
    ) {
        let n = self.n_instances;
        let words = self.words;
        hit_blocks.clear();
        hit_blocks.resize(n, 0);
        matched.reset(n);
        self.live.clear();
        self.live.resize(words, 0);
        for w in 0..words {
            let rem = n - w * 64;
            self.live[w] = if rem >= 64 { u64::MAX } else { (1u64 << rem) - 1 };
        }
        let mut cur = ROOT;
        let mut depth = 0usize;
        for h in hashes {
            let Some(&next) = self.nodes[cur].children.get(h) else {
                break;
            };
            let mask = &self.masks[next * words..(next + 1) * words];
            let mut any = false;
            for w in 0..words {
                let dropped = self.live[w] & !mask[w];
                if dropped != 0 {
                    // Instances leaving the live-set matched exactly the
                    // blocks BEFORE this node.
                    let mut bits = dropped;
                    while bits != 0 {
                        let b = bits.trailing_zeros() as usize;
                        hit_blocks[w * 64 + b] = depth;
                        bits &= bits - 1;
                    }
                    self.live[w] &= mask[w];
                }
                if self.live[w] != 0 {
                    any = true;
                }
            }
            if !any {
                break; // no instance holds this block
            }
            depth += 1;
            if depth == 1 {
                // Survivors of the first block are exactly the instances
                // holding ≥ 1 block of this prompt.
                matched.copy_from_words(&self.live);
            }
            cur = next;
        }
        // Instances that survived the whole walk matched `depth` blocks.
        for w in 0..words {
            let mut bits = self.live[w];
            while bits != 0 {
                let b = bits.trailing_zeros() as usize;
                hit_blocks[w * 64 + b] = depth;
                bits &= bits - 1;
            }
        }
        self.total_lookup_blocks += (hashes.len() * n) as u64;
        self.total_hit_blocks += hit_blocks.iter().sum::<usize>() as u64;
    }

    /// Insert the chain for one instance, evicting that instance's LRU
    /// blocks as needed — byte-for-byte the per-instance mirror's insert
    /// semantics (including the re-push of refreshed free leaves; see the
    /// starvation regression in `radix.rs`). Returns new blocks added for
    /// this instance; on capacity pressure with nothing evictable, inserts
    /// as many leading blocks as fit.
    pub fn insert(&mut self, inst_id: usize, hashes: &[u64], now: u64) -> usize {
        let mut cur = ROOT;
        let mut cur_slot = 0usize; // root sentinel; never a candidate slot
        let mut created = 0usize;
        for h in hashes {
            let child = self.nodes[cur].children.get(h).copied();
            if let Some(c) = child {
                if self.mask_get(c, inst_id) {
                    // Already present: refresh LRU state; free leaves are
                    // re-pushed so they stay evictable.
                    let state = &mut self.inst[inst_id];
                    let m = state.meta.get_mut(&c).expect("present bit without meta");
                    m.last_access = now;
                    let slot = m.slot;
                    let is_leaf = m.children == 0;
                    if self.capacity != 0 && is_leaf {
                        state.heap.push(EvictCandidate {
                            last_access: now,
                            slot,
                        });
                    }
                    cur = c;
                    cur_slot = slot;
                    continue;
                }
            }
            // The instance doesn't hold this block: make room, then add
            // its presence (reusing the shared node when one exists).
            if self.capacity != 0
                && self.inst[inst_id].used >= self.capacity
                && !self.evict_one(inst_id, cur_slot)
            {
                break; // full and nothing evictable
            }
            let idx = match child {
                Some(c) => c,
                None => self.alloc_node(*h, cur),
            };
            self.mask_set(idx, inst_id);
            let push_candidate = self.capacity != 0;
            let state = &mut self.inst[inst_id];
            let slot = match state.free_slots.pop() {
                Some(s) => s,
                None => {
                    let s = state.next_slot;
                    state.next_slot += 1;
                    s
                }
            };
            if slot >= state.slot_node.len() {
                state.slot_node.resize(slot + 1, NONE);
            }
            state.slot_node[slot] = idx;
            state.meta.insert(
                idx,
                InstMeta {
                    last_access: now,
                    children: 0,
                    slot,
                },
            );
            if push_candidate {
                state.heap.push(EvictCandidate {
                    last_access: now,
                    slot,
                });
            }
            state.used += 1;
            if cur != ROOT {
                state
                    .meta
                    .get_mut(&cur)
                    .expect("parent missing instance meta")
                    .children += 1;
            }
            created += 1;
            cur = idx;
            cur_slot = slot;
        }
        self.maybe_compact_heap(inst_id);
        created
    }

    /// Compact an instance's lazy heap when stale entries dominate —
    /// the same trigger and validity predicate as
    /// `RadixTree::maybe_compact_heap`, so mirror equivalence is
    /// preserved (identical push sequences give identical lengths, and
    /// dropping now-invalid entries is behavior-preserving: they can
    /// never validate again, and every evictability transition re-pushes).
    fn maybe_compact_heap(&mut self, inst_id: usize) {
        let state = &mut self.inst[inst_id];
        if state.heap.len() <= 4 * state.used.max(16) {
            return;
        }
        let old = std::mem::take(&mut state.heap);
        let meta = &state.meta;
        let slot_node = &state.slot_node;
        state.heap = old
            .into_iter()
            .filter(|c| {
                let node = slot_node.get(c.slot).copied().unwrap_or(NONE);
                if node == NONE {
                    return false;
                }
                match meta.get(&node) {
                    Some(m) => {
                        m.slot == c.slot
                            && m.children == 0
                            && m.last_access == c.last_access
                    }
                    None => false,
                }
            })
            .collect();
    }

    /// Evict one LRU block of `inst_id`. `protect_slot` is the slot of the
    /// path node currently being extended (0 = root sentinel) — never
    /// evicted mid-insert. Returns false if nothing is evictable.
    fn evict_one(&mut self, inst_id: usize, protect_slot: usize) -> bool {
        // Same deferred-candidate discipline as `RadixTree::evict_one`:
        // a valid-but-protected entry is parked and restored on exit, so
        // protection skips it without discarding it (dropping it starved
        // eviction after a truncated insert — see the regression tests).
        let mut deferred: Option<EvictCandidate> = None;
        let mut evicted = false;
        while let Some(cand) = self.inst[inst_id].heap.pop() {
            let node = self.inst[inst_id]
                .slot_node
                .get(cand.slot)
                .copied()
                .unwrap_or(NONE);
            if node == NONE {
                continue;
            }
            // Lazy validation: the entry must still describe reality
            // (instance-leaf, timestamp unchanged since push).
            let valid = match self.inst[inst_id].meta.get(&node) {
                Some(m) => {
                    m.slot == cand.slot
                        && m.children == 0
                        && m.last_access == cand.last_access
                }
                None => false,
            };
            if !valid {
                continue;
            }
            if cand.slot == protect_slot {
                deferred = Some(cand);
                continue;
            }
            self.mask_clear(node, inst_id);
            let parent = self.nodes[node].parent;
            {
                let state = &mut self.inst[inst_id];
                state.meta.remove(&node);
                state.slot_node[cand.slot] = NONE;
                state.free_slots.push(cand.slot);
                state.used -= 1;
                if parent != ROOT {
                    if let Some(pm) = state.meta.get_mut(&parent) {
                        pm.children -= 1;
                        if pm.children == 0 {
                            // Parent became this instance's leaf.
                            let (la, slot) = (pm.last_access, pm.slot);
                            state.heap.push(EvictCandidate {
                                last_access: la,
                                slot,
                            });
                        }
                    }
                }
            }
            self.total_evicted_blocks += 1;
            // Shared-structure GC: unlink nodes no instance holds. By the
            // closure invariant such a node has no live children.
            if self.mask_is_empty(node) {
                debug_assert!(
                    self.nodes[node].children.is_empty(),
                    "presence closure violated"
                );
                let hash = self.nodes[node].hash;
                self.nodes[parent].children.remove(&hash);
                self.nodes[node].alive = false;
                self.free_nodes.push(node);
            }
            evicted = true;
            break;
        }
        if let Some(c) = deferred {
            self.inst[inst_id].heap.push(c);
        }
        evicted
    }

    fn alloc_node(&mut self, hash: u64, parent: usize) -> usize {
        let idx = if let Some(idx) = self.free_nodes.pop() {
            debug_assert!(
                self.masks[idx * self.words..(idx + 1) * self.words]
                    .iter()
                    .all(|&w| w == 0),
                "recycled node with live presence bits"
            );
            let n = &mut self.nodes[idx];
            debug_assert!(n.children.is_empty());
            n.hash = hash;
            n.parent = parent;
            n.alive = true;
            idx
        } else {
            self.nodes.push(SharedNode {
                hash,
                parent,
                children: HashMap::default(),
                alive: true,
            });
            self.masks.resize(self.nodes.len() * self.words, 0);
            self.nodes.len() - 1
        };
        self.nodes[parent].children.insert(hash, idx);
        idx
    }

    /// Remove every trace of `inst_id` from the index: presence bits, LRU
    /// metadata, slot allocator, heap and free-lists — the instance slot
    /// comes back as if freshly constructed, so a later scale-up reusing
    /// it inherits no stale occupancy. Shared nodes no remaining instance
    /// holds are GC'd: by the presence-closure invariant a node the purge
    /// empties had mask == {inst_id}, so its children's masks were
    /// subsets of {inst_id} — also emptied, and also in this instance's
    /// meta set — meaning the single pass below unlinks the whole dead
    /// subtree with no dangling child links. Purged blocks are not
    /// counted as evictions (the instance died; it didn't run its LRU).
    pub fn purge_instance(&mut self, inst_id: usize) {
        let state = std::mem::replace(&mut self.inst[inst_id], InstanceState::new());
        // meta is a hash map: sort the touched set so free-list order
        // (and therefore later node reuse) is deterministic.
        let mut touched: Vec<usize> = state.meta.keys().copied().collect();
        touched.sort_unstable();
        for &node in &touched {
            self.mask_clear(node, inst_id);
        }
        for &node in &touched {
            if self.nodes[node].alive && self.mask_is_empty(node) {
                let parent = self.nodes[node].parent;
                let hash = self.nodes[node].hash;
                self.nodes[parent].children.remove(&hash);
                self.nodes[node].alive = false;
                // Any remaining child links point at nodes this same pass
                // kills (their masks were ⊆ ours); clear them so the
                // recycled node satisfies `alloc_node`'s empty-children
                // contract regardless of processing order.
                self.nodes[node].children.clear();
                self.free_nodes.push(node);
            }
        }
    }

    /// Change the fleet width (the mask-width refactor behind
    /// scale-up/scale-down). Growth appends fresh, empty instance slots
    /// and widens every node's mask row when a new 64-bit word is needed;
    /// shrink requires the dropped tail slots to have been purged first
    /// (asserted) and narrows the rows back.
    pub fn resize_instances(&mut self, new_n: usize) {
        assert!(new_n > 0, "fleet cannot resize to zero instances");
        if new_n < self.n_instances {
            for i in new_n..self.n_instances {
                assert_eq!(
                    self.inst[i].used, 0,
                    "resize_instances shrink requires purged tail slot {i}"
                );
            }
        }
        let new_words = (new_n + 63) / 64;
        if new_words != self.words {
            let n_nodes = self.nodes.len();
            let copy = self.words.min(new_words);
            let mut masks = vec![0u64; n_nodes * new_words];
            for node in 0..n_nodes {
                masks[node * new_words..node * new_words + copy]
                    .copy_from_slice(&self.masks[node * self.words..node * self.words + copy]);
            }
            self.masks = masks;
            self.words = new_words;
            self.live = vec![0; new_words];
        }
        self.inst.resize_with(new_n, InstanceState::new);
        self.n_instances = new_n;
    }

    /// Lifetime block hit rate across all instances.
    pub fn hit_rate(&self) -> f64 {
        if self.total_lookup_blocks == 0 {
            0.0
        } else {
            self.total_hit_blocks as f64 / self.total_lookup_blocks as f64
        }
    }

    /// Invariant checker used by the property/equivalence tests.
    pub fn check_invariants(&self) -> Result<(), String> {
        let words = self.words;
        let mut per_inst_live = vec![0usize; self.n_instances];
        for (i, n) in self.nodes.iter().enumerate() {
            if !n.alive {
                continue;
            }
            if i != ROOT {
                let p = &self.nodes[n.parent];
                if !p.alive {
                    return Err(format!("node {i} has dead parent {}", n.parent));
                }
                if p.children.get(&n.hash) != Some(&i) {
                    return Err(format!("node {i} not linked from parent"));
                }
                let mut empty = true;
                for w in 0..words {
                    let nm = self.masks[i * words + w];
                    // The root implicitly holds everything.
                    let pm = if n.parent == ROOT {
                        u64::MAX
                    } else {
                        self.masks[n.parent * words + w]
                    };
                    if nm & !pm != 0 {
                        return Err(format!("presence closure violated at node {i}"));
                    }
                    if nm != 0 {
                        empty = false;
                    }
                }
                if empty {
                    return Err(format!("alive node {i} held by no instance"));
                }
                for inst in 0..self.n_instances {
                    if self.mask_get(i, inst) {
                        per_inst_live[inst] += 1;
                    }
                }
            }
            for (&h, &c) in &n.children {
                let ch = &self.nodes[c];
                if !ch.alive || ch.parent != i || ch.hash != h {
                    return Err(format!("bad child link {i}->{c}"));
                }
            }
        }
        for (inst, state) in self.inst.iter().enumerate() {
            if state.used != per_inst_live[inst] {
                return Err(format!(
                    "instance {inst}: used={} but mask bits={}",
                    state.used, per_inst_live[inst]
                ));
            }
            if self.capacity != 0 && state.used > self.capacity {
                return Err(format!(
                    "instance {inst} over capacity: {}>{}",
                    state.used, self.capacity
                ));
            }
            if state.meta.len() != state.used {
                return Err(format!(
                    "instance {inst}: meta {} entries vs used {}",
                    state.meta.len(),
                    state.used
                ));
            }
            for (&node, m) in &state.meta {
                if !self.nodes[node].alive || !self.mask_get(node, inst) {
                    return Err(format!("instance {inst}: meta for absent node {node}"));
                }
                if state.slot_node.get(m.slot).copied().unwrap_or(NONE) != node {
                    return Err(format!("instance {inst}: slot map broken at node {node}"));
                }
                let cnt = self.nodes[node]
                    .children
                    .values()
                    .filter(|&&c| self.mask_get(c, inst))
                    .count() as u32;
                if cnt != m.children {
                    return Err(format!(
                        "instance {inst}: node {node} children {} vs counted {cnt}",
                        m.children
                    ));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hits(ix: &mut SharedRadixIndex, hashes: &[u64]) -> Vec<usize> {
        let mut h = Vec::new();
        let mut m = InstanceMask::default();
        ix.match_into(hashes, &mut h, &mut m);
        h
    }

    #[test]
    fn one_walk_matches_all_instances() {
        let mut ix = SharedRadixIndex::new(3, 0);
        ix.insert(1, &[1, 2], 10);
        ix.insert(2, &[1, 2, 3, 4], 20);
        assert_eq!(hits(&mut ix, &[1, 2, 3, 4, 5]), vec![0, 2, 4]);
        assert_eq!(hits(&mut ix, &[9]), vec![0, 0, 0]);
        ix.check_invariants().unwrap();
    }

    #[test]
    fn matched_mask_is_first_block_survivors() {
        let mut ix = SharedRadixIndex::new(4, 0);
        ix.insert(0, &[1, 2], 0);
        ix.insert(3, &[1], 0);
        let mut h = Vec::new();
        let mut m = InstanceMask::default();
        ix.match_into(&[1, 2, 3], &mut h, &mut m);
        assert_eq!(h, vec![2, 0, 0, 1]);
        assert_eq!(m.iter_ones().collect::<Vec<_>>(), vec![0, 3]);
        // A miss leaves the mask empty.
        ix.match_into(&[7, 8], &mut h, &mut m);
        assert_eq!(h, vec![0; 4]);
        assert!(m.is_empty());
    }

    #[test]
    fn per_instance_capacity_and_eviction() {
        let mut ix = SharedRadixIndex::new(2, 4);
        ix.insert(0, &[1, 2], 0);
        ix.insert(0, &[10, 20], 100);
        // Instance 0 is at capacity; instance 1 untouched.
        ix.insert(0, &[30], 200); // evicts instance-0 LRU leaf (2)
        assert_eq!(ix.used_blocks(0), 4);
        assert_eq!(ix.used_blocks(1), 0);
        assert_eq!(hits(&mut ix, &[1, 2]), vec![1, 0]);
        assert_eq!(hits(&mut ix, &[10, 20]), vec![2, 0]);
        assert_eq!(hits(&mut ix, &[30]), vec![1, 0]);
        // Instance 1 has its own budget: same chains fit fresh.
        ix.insert(1, &[1, 2], 300);
        assert_eq!(ix.used_blocks(1), 2);
        ix.check_invariants().unwrap();
    }

    #[test]
    fn shared_node_gc_when_no_instance_holds_it() {
        let mut ix = SharedRadixIndex::new(2, 2);
        ix.insert(0, &[1, 2], 0);
        // Evict everything on instance 0 by churning fresh chains through.
        ix.insert(0, &[7], 10);
        ix.insert(0, &[8], 20);
        ix.insert(0, &[9], 30);
        ix.check_invariants().unwrap();
        assert!(ix.total_evicted_blocks >= 2);
        // GC reclaims empty-mask nodes: the churn above reuses them, so
        // the arena never grows past root + the two original blocks.
        assert_eq!(ix.nodes.len(), 3);
    }

    #[test]
    fn refreshed_leaves_stay_evictable_per_instance() {
        // The same starvation regression as RadixTree, through the shared
        // index: refresh then over-capacity insert must still evict.
        let mut ix = SharedRadixIndex::new(1, 2);
        ix.insert(0, &[1, 2], 0);
        assert_eq!(ix.insert(0, &[1, 2], 5), 0); // pure refresh
        assert_eq!(ix.insert(0, &[9], 10), 1, "eviction starved");
        assert_eq!(hits(&mut ix, &[9]), vec![1]);
        ix.check_invariants().unwrap();
    }

    #[test]
    fn truncated_insert_keeps_tail_evictable() {
        // A truncated insert pops the protected path tail as a valid
        // candidate; it must be parked and restored, not discarded, or
        // the instance's eviction heap drains permanently.
        let mut ix = SharedRadixIndex::new(1, 2);
        assert_eq!(ix.insert(0, &[1, 2, 3], 10), 2);
        assert_eq!(ix.insert(0, &[9], 20), 1, "protected candidate was discarded");
        assert_eq!(hits(&mut ix, &[9]), vec![1]);
        ix.check_invariants().unwrap();
    }

    #[test]
    fn supports_more_than_64_instances() {
        let n = 70;
        let mut ix = SharedRadixIndex::new(n, 8);
        ix.insert(68, &[1, 2, 3], 0);
        ix.insert(1, &[1, 2], 1);
        let mut h = Vec::new();
        let mut m = InstanceMask::default();
        ix.match_into(&[1, 2, 3], &mut h, &mut m);
        assert_eq!(h.len(), n);
        assert_eq!(h[68], 3);
        assert_eq!(h[1], 2);
        assert_eq!(h[0], 0);
        assert_eq!(m.iter_ones().collect::<Vec<_>>(), vec![1, 68]);
        ix.check_invariants().unwrap();
    }

    #[test]
    fn truncates_when_everything_unevictable() {
        // capacity 1, chain of 3: only the first block fits, and the
        // in-flight path node is protected from self-eviction.
        let mut ix = SharedRadixIndex::new(1, 1);
        assert_eq!(ix.insert(0, &[1, 2, 3], 0), 1);
        assert_eq!(ix.used_blocks(0), 1);
        assert_eq!(hits(&mut ix, &[1, 2, 3]), vec![1]);
        ix.check_invariants().unwrap();
    }

    #[test]
    fn refresh_heap_stays_bounded_below_capacity() {
        let mut ix = SharedRadixIndex::new(2, 1024);
        ix.insert(0, &[1, 2, 3], 0);
        for now in 1..5000u64 {
            ix.insert(0, &[1, 2, 3], now); // pure refresh, one push each
        }
        assert!(
            ix.inst[0].heap.len() <= 4 * ix.used_blocks(0).max(16),
            "heap leaked: {} entries for {} blocks",
            ix.inst[0].heap.len(),
            ix.used_blocks(0)
        );
        ix.check_invariants().unwrap();
    }

    #[test]
    fn purge_instance_clears_occupancy_and_gcs() {
        let mut ix = SharedRadixIndex::new(2, 4);
        ix.insert(0, &[1, 2, 3], 0);
        ix.insert(1, &[1, 2], 5);
        ix.purge_instance(0);
        assert_eq!(ix.used_blocks(0), 0);
        // Instance 1's presence survives; the [3] tail (held only by the
        // purged instance) is gone from the shared structure.
        assert_eq!(hits(&mut ix, &[1, 2, 3]), vec![0, 2]);
        ix.check_invariants().unwrap();
        // The purged slot restarts pristine: inserting again must not
        // inherit stale occupancy (used, slots, heap, free-list).
        ix.insert(0, &[7, 8], 10);
        assert_eq!(ix.used_blocks(0), 2);
        assert_eq!(hits(&mut ix, &[7, 8]), vec![2, 0]);
        ix.check_invariants().unwrap();
    }

    #[test]
    fn purge_then_refill_never_inherits_stale_occupancy() {
        // Fill instance 0 to capacity, purge, refill: leaked `used` or a
        // stale eviction heap would evict prematurely or starve.
        let mut ix = SharedRadixIndex::new(1, 2);
        ix.insert(0, &[1, 2], 0);
        ix.purge_instance(0);
        assert_eq!(ix.insert(0, &[5, 6], 10), 2, "stale occupancy leaked");
        assert_eq!(ix.used_blocks(0), 2);
        ix.check_invariants().unwrap();
    }

    #[test]
    fn purge_gcs_whole_dead_subtree() {
        // A purged instance holding a deep exclusive chain must release
        // every node; the arena reuses them for the next insert.
        let mut ix = SharedRadixIndex::new(2, 0);
        ix.insert(0, &[1, 2, 3, 4, 5], 0);
        let before = ix.nodes.len();
        ix.purge_instance(0);
        ix.check_invariants().unwrap();
        assert_eq!(ix.free_nodes.len(), 5);
        ix.insert(1, &[7, 8, 9, 10, 11], 1);
        assert_eq!(ix.nodes.len(), before, "GC'd nodes were not reused");
        ix.check_invariants().unwrap();
    }

    #[test]
    fn resize_grows_and_shrinks_across_word_boundaries() {
        let mut ix = SharedRadixIndex::new(2, 0);
        ix.insert(0, &[1, 2], 0);
        ix.resize_instances(70);
        ix.insert(69, &[1, 2, 3], 1);
        let mut h = Vec::new();
        let mut m = InstanceMask::default();
        ix.match_into(&[1, 2, 3], &mut h, &mut m);
        assert_eq!(h.len(), 70);
        assert_eq!(h[0], 2);
        assert_eq!(h[69], 3);
        assert_eq!(m.iter_ones().collect::<Vec<_>>(), vec![0, 69]);
        ix.check_invariants().unwrap();
        // Shrink back below the word boundary: purge the tail first.
        ix.purge_instance(69);
        ix.resize_instances(2);
        assert_eq!(hits(&mut ix, &[1, 2]), vec![2, 0]);
        ix.check_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "purged tail")]
    fn resize_shrink_rejects_occupied_tail() {
        let mut ix = SharedRadixIndex::new(3, 0);
        ix.insert(2, &[1], 0);
        ix.resize_instances(2);
    }

    #[test]
    fn hit_accounting_aggregates_instances() {
        let mut ix = SharedRadixIndex::new(2, 0);
        ix.insert(0, &[1, 2], 0);
        hits(&mut ix, &[1, 2]); // inst0: 2/2, inst1: 0/2
        assert!((ix.hit_rate() - 0.5).abs() < 1e-12);
    }
}
